"""L2 model function tests: tile objective vs hand computation, and the
AOT lowering path (HLO text generation + structural checks)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.mark.parametrize("loss", ref.LOSSES)
def test_tile_objective_matches_manual(loss):
    rng = np.random.default_rng(1)
    bm, bd = 12, 6
    x = rng.standard_normal((bm, bd)).astype(np.float32)
    y = np.where(rng.random(bm) < 0.5, 1.0, -1.0).astype(np.float32)
    w = rng.standard_normal(bd).astype(np.float32) * 0.3
    active = np.ones(bm, np.float32)
    fn = model.tile_objective_fn(loss, bm, bd)
    risk_sum, margins = fn(x, y, w, active)
    np.testing.assert_allclose(np.asarray(margins), x @ w, rtol=1e-5, atol=1e-6)

    u = x @ w
    if loss == "hinge":
        expected = np.maximum(0.0, 1.0 - y * u).sum()
    elif loss == "logistic":
        expected = np.log1p(np.exp(-y * u)).sum()
    else:
        expected = (0.5 * (u - y) ** 2).sum()
    np.testing.assert_allclose(float(risk_sum), expected, rtol=1e-5)


def test_tile_objective_mask_excludes_padding():
    bm, bd = 8, 4
    x = np.ones((bm, bd), np.float32)
    y = np.ones(bm, np.float32)
    w = np.zeros(bd, np.float32)
    half = np.array([1, 1, 1, 1, 0, 0, 0, 0], np.float32)
    fn = model.tile_objective_fn("hinge", bm, bd)
    full, _ = fn(x, y, w, np.ones(bm, np.float32))
    masked, _ = fn(x, y, w, half)
    assert float(full) == pytest.approx(8.0)  # hinge(0) = 1 per row
    assert float(masked) == pytest.approx(4.0)


def test_objective_consistency_with_ref_objective():
    rng = np.random.default_rng(2)
    bm, bd = 16, 5
    x = rng.standard_normal((bm, bd)).astype(np.float32)
    y = np.where(rng.random(bm) < 0.5, 1.0, -1.0).astype(np.float32)
    w = rng.standard_normal(bd).astype(np.float32) * 0.2
    lam = 0.01
    fn = model.tile_objective_fn("logistic", bm, bd)
    risk_sum, _ = fn(x, y, w, np.ones(bm, np.float32))
    via_tiles = lam * float(jnp.sum(jnp.square(w))) + float(risk_sum) / bm
    direct = float(ref.primal_objective("logistic", x, y, w, lam))
    assert via_tiles == pytest.approx(direct, rel=1e-5)


@pytest.mark.parametrize("loss", ["hinge"])
def test_lowering_produces_hlo_text(loss):
    text = aot.to_hlo_text(aot.lower_tile_update(loss, 8, 8))
    assert "HloModule" in text
    assert "ENTRY" in text
    # 9 parameters in, 4-tuple out.
    assert text.count("parameter(") >= 9
    text2 = aot.to_hlo_text(aot.lower_tile_objective(loss, 8, 8))
    assert "HloModule" in text2


def test_manifest_written(tmp_path):
    import subprocess
    import sys
    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--tiles", "8x8"],
        capture_output=True,
        text=True,
        cwd=str(aot.os.path.dirname(aot.os.path.dirname(aot.__file__))),
    )
    assert r.returncode == 0, r.stderr
    import json
    manifest = json.loads((out / "manifest.json").read_text())
    # 3 losses x 1 tile x (2 fused-iter update variants + 1 objective).
    assert len(manifest["entries"]) == 9
    for e in manifest["entries"]:
        assert (out / e["path"]).exists()
        assert e["bm"] == 8 and e["bd"] == 8
