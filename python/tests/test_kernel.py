"""L1 correctness: the Pallas tile kernel vs the pure-jnp oracle
(ref.py), swept over shapes/losses/magnitudes with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import dso_tile, ref

RNG = np.random.default_rng(0)


def make_inputs(bm, bd, seed, loss="hinge", scale=1.0):
    rng = np.random.default_rng(seed)
    f32 = np.float32
    x = (rng.standard_normal((bm, bd)) * scale).astype(f32)
    w = (rng.standard_normal(bd) * 0.1).astype(f32)
    w_acc = np.abs(rng.standard_normal(bd)).astype(f32) * 0.01
    y = np.where(rng.random(bm) < 0.5, 1.0, -1.0).astype(f32)
    if loss == "hinge":
        beta = rng.random(bm).astype(f32)
        alpha = (y * beta).astype(f32)
    elif loss == "logistic":
        beta = np.clip(rng.random(bm), 1e-3, 1 - 1e-3).astype(f32)
        alpha = (y * beta).astype(f32)
    else:
        alpha = rng.standard_normal(bm).astype(f32)
    a_acc = np.abs(rng.standard_normal(bm)).astype(f32) * 0.01
    m = 4 * bm
    row_counts = rng.integers(1, bd + 1, size=bm)
    row_scale = (1.0 / (m * row_counts)).astype(f32)
    col_counts = rng.integers(1, 4 * bm, size=bd)
    col_scale = (1.0 / col_counts).astype(f32)
    lam = 1e-3
    params = np.array([0.1, lam, 1.0 / m, 1.0 / np.sqrt(lam)], dtype=f32)
    return (x, w, w_acc, alpha, a_acc, y, row_scale, col_scale, params)


def run_both(loss, bm, bd, args):
    got = dso_tile.tile_update(loss, bm, bd, *args)
    want = ref.tile_update(loss, *args)
    return got, want


@pytest.mark.parametrize("loss", ref.LOSSES)
@pytest.mark.parametrize("bm,bd", [(8, 8), (16, 4), (4, 16), (32, 32)])
def test_kernel_matches_ref(loss, bm, bd):
    args = make_inputs(bm, bd, seed=42, loss=loss)
    got, want = run_both(loss, bm, bd, args)
    names = ("w", "w_acc", "alpha", "a_acc")
    for g, r, name in zip(got, want, names):
        np.testing.assert_allclose(g, r, rtol=1e-5, atol=1e-6, err_msg=f"{loss}:{name}")


@settings(max_examples=40, deadline=None)
@given(
    bm=st.integers(1, 48),
    bd=st.integers(1, 48),
    seed=st.integers(0, 2**31),
    loss=st.sampled_from(ref.LOSSES),
    scale=st.floats(0.01, 10.0),
)
def test_kernel_matches_ref_hypothesis(bm, bd, seed, loss, scale):
    args = make_inputs(bm, bd, seed=seed, loss=loss, scale=scale)
    got, want = run_both(loss, bm, bd, args)
    for g, r in zip(got, want):
        np.testing.assert_allclose(g, r, rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("loss", ref.LOSSES)
def test_outputs_respect_constraints(loss):
    bm, bd = 16, 12
    args = make_inputs(bm, bd, seed=7, loss=loss)
    # Huge eta to force projections to bind.
    params = args[-1].copy()
    params[0] = 1e4
    args = args[:-1] + (params,)
    w2, _, alpha2, _ = dso_tile.tile_update(loss, bm, bd, *args)
    w_bound = params[3]
    assert np.all(np.abs(np.asarray(w2)) <= w_bound + 1e-5)
    y = args[5]
    beta = np.asarray(y * alpha2)
    if loss == "hinge":
        assert np.all(beta >= -1e-6) and np.all(beta <= 1 + 1e-6)
    elif loss == "logistic":
        assert np.all(beta > 0) and np.all(beta < 1)


def test_padding_rows_and_cols_are_inert():
    """Zero-padded rows/cols (zero x, zero scales, zero state) must not
    move — the invariant the Rust tile engine's edge-padding relies on."""
    bm, bd = 16, 16
    args = list(make_inputs(bm, bd, seed=3, loss="hinge"))
    pad_r, pad_c = 12, 10  # rows >= pad_r and cols >= pad_c are padding
    x = np.array(args[0])
    x[pad_r:, :] = 0.0
    x[:, pad_c:] = 0.0
    args[0] = x
    for idx, cut in ((1, pad_c), (2, pad_c)):  # w, w_acc
        v = np.array(args[idx])
        v[cut:] = 0.0
        args[idx] = v
    for idx, cut in ((3, pad_r), (4, pad_r)):  # alpha, a_acc
        v = np.array(args[idx])
        v[cut:] = 0.0
        args[idx] = v
    rs = np.array(args[6]); rs[pad_r:] = 0.0; args[6] = rs
    cs = np.array(args[7]); cs[pad_c:] = 0.0; args[7] = cs

    w2, w_acc2, alpha2, a_acc2 = dso_tile.tile_update("hinge", bm, bd, *args)
    # Padded w coords: g_w = lam*2*0*0 - 0 = 0 -> w stays 0.
    assert np.all(np.asarray(w2)[pad_c:] == 0.0)
    assert np.all(np.asarray(alpha2)[pad_r:] == 0.0)
    assert np.all(np.asarray(w_acc2)[pad_c:] == 0.0)
    assert np.all(np.asarray(a_acc2)[pad_r:] == 0.0)
    # Active coords still updated.
    assert np.any(np.asarray(w2)[:pad_c] != np.asarray(args[1])[:pad_c])


def test_deterministic():
    bm, bd = 8, 8
    args = make_inputs(bm, bd, seed=11)
    a = dso_tile.tile_update("hinge", bm, bd, *args)
    b = dso_tile.tile_update("hinge", bm, bd, *args)
    for x, y_ in zip(a, b):
        np.testing.assert_array_equal(x, y_)


def test_adagrad_accumulators_monotone():
    bm, bd = 8, 8
    args = list(make_inputs(bm, bd, seed=13))
    for _ in range(5):
        w2, w_acc2, alpha2, a_acc2 = dso_tile.tile_update("hinge", bm, bd, *args)
        assert np.all(np.asarray(w_acc2) >= np.asarray(args[2]) - 1e-7)
        assert np.all(np.asarray(a_acc2) >= np.asarray(args[4]) - 1e-7)
        args[1], args[2], args[3], args[4] = w2, w_acc2, alpha2, a_acc2


def test_repeated_updates_reduce_primal_on_tiny_problem():
    """Sanity: iterating the tile update on a full (non-padded) tile
    should walk toward the saddle — primal objective decreases."""
    bm, bd = 32, 8
    rng = np.random.default_rng(5)
    f32 = np.float32
    wstar = rng.standard_normal(bd)
    x = rng.standard_normal((bm, bd)).astype(f32) / np.sqrt(bd)
    y = np.sign(x @ wstar + 1e-9).astype(f32)
    lam = 1e-2
    m = bm
    w = np.zeros(bd, f32)
    w_acc = np.zeros(bd, f32)
    alpha = np.zeros(bm, f32)
    a_acc = np.zeros(bm, f32)
    row_scale = np.full(bm, 1.0 / (m * bd), f32)
    col_scale = np.full(bd, 1.0 / bm, f32)
    params = np.array([0.5, lam, 1.0 / m, 1.0 / np.sqrt(lam)], f32)
    p0 = float(ref.primal_objective("hinge", x, y, w, lam))
    for _ in range(300):
        w, w_acc, alpha, a_acc = dso_tile.tile_update(
            "hinge", bm, bd, x, w, w_acc, alpha, a_acc, y, row_scale, col_scale, params
        )
    p1 = float(ref.primal_objective("hinge", x, np.asarray(y), np.asarray(w), lam))
    assert p1 < 0.6 * p0, f"{p0} -> {p1}"


def test_vmem_estimate_sane():
    assert dso_tile.vmem_bytes(256, 256) < 16 * 2**20 / 8
    assert dso_tile.vmem_bytes(128, 128) > 4 * 128 * 128


@pytest.mark.parametrize("loss", ref.LOSSES)
def test_fused_iters_matches_repeated_ref(loss):
    """The iters=k fused kernel must equal k sequential applications of
    the oracle (the optimization must not change semantics)."""
    bm, bd = 16, 12
    args = make_inputs(bm, bd, seed=17, loss=loss)
    got = dso_tile.tile_update(loss, bm, bd, *args, iters=5)
    state = args[1], args[2], args[3], args[4]
    for _ in range(5):
        w2, wa2, al2, aa2 = ref.tile_update(
            loss, args[0], state[0], state[1], state[2], state[3], *args[5:]
        )
        state = (w2, wa2, al2, aa2)
    for g, r in zip(got, state):
        np.testing.assert_allclose(g, r, rtol=3e-5, atol=1e-5, err_msg=loss)


def test_fused_iters_one_equals_plain():
    bm, bd = 8, 8
    args = make_inputs(bm, bd, seed=19)
    a = dso_tile.tile_update("hinge", bm, bd, *args, iters=1)
    b = dso_tile.tile_update("hinge", bm, bd, *args)
    for x, y_ in zip(a, b):
        np.testing.assert_array_equal(x, y_)
