"""AOT lowering: JAX/Pallas -> HLO *text* -> artifacts/ for the Rust
PJRT runtime.

HLO text (NOT lowered.compiler_ir("hlo") protos or .serialize()) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage: python -m compile.aot --out ../artifacts [--tiles 128x128,64x64]
Writes one .hlo.txt per (kind, loss, tile shape) plus manifest.json.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import dso_tile, ref

DEFAULT_TILES = "256x256,128x128,64x64,32x32"
DEFAULT_ITERS = (1, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_tile_update(loss, bm, bd, iters=1):
    fn = model.tile_update_fn(loss, bm, bd, iters)
    return jax.jit(fn).lower(*dso_tile.example_args(bm, bd))


def lower_tile_objective(loss, bm, bd):
    fn = model.tile_objective_fn(loss, bm, bd)
    return jax.jit(fn).lower(*model.objective_example_args(bm, bd))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--tiles", default=DEFAULT_TILES)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    tiles = []
    for spec in args.tiles.split(","):
        bm, bd = spec.lower().split("x")
        tiles.append((int(bm), int(bd)))

    entries = []
    for loss in ref.LOSSES:
        for bm, bd in tiles:
            # tile_update at each fused iteration count (amortizes the
            # PJRT per-call overhead — see EXPERIMENTS.md §Perf).
            for iters in DEFAULT_ITERS:
                name = f"tile_update_{loss}_{bm}x{bd}_x{iters}"
                path = f"{name}.hlo.txt"
                text = to_hlo_text(lower_tile_update(loss, bm, bd, iters))
                with open(os.path.join(args.out, path), "w") as f:
                    f.write(text)
                entries.append(
                    {
                        "name": name,
                        "kind": "tile_update",
                        "loss": loss,
                        "bm": bm,
                        "bd": bd,
                        "iters": iters,
                        "path": path,
                        "vmem_bytes": dso_tile.vmem_bytes(bm, bd),
                    }
                )
                print(f"wrote {path} ({len(text)} chars)")
            name = f"tile_objective_{loss}_{bm}x{bd}"
            path = f"{name}.hlo.txt"
            text = to_hlo_text(lower_tile_objective(loss, bm, bd))
            with open(os.path.join(args.out, path), "w") as f:
                f.write(text)
            entries.append(
                {
                    "name": name,
                    "kind": "tile_objective",
                    "loss": loss,
                    "bm": bm,
                    "bd": bd,
                    "iters": 1,
                    "path": path,
                    "vmem_bytes": dso_tile.vmem_bytes(bm, bd),
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "schema": 1,
        "jax_version": jax.__version__,
        "entries": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} artifacts")


if __name__ == "__main__":
    main()
