"""Pure-jnp reference ("oracle") for the DSO tile-update kernel.

This file intentionally contains no Pallas: it is the ground truth the
Pallas kernel (dso_tile.py) is validated against by pytest/hypothesis,
and it mirrors, in batched form, the scalar update (Eq. 8 of the paper)
implemented in rust/src/coordinator/updates.rs:

    u    = X.w                                   (margins of the tile)
    g_a  = h'(alpha, y) * row_scale - u / m      (dual ascent direction)
    g_w  = lambda * phi'(w) * col_scale - X^T.alpha / m
    AdaGrad accumulate + step on both halves, evaluated at the OLD
    (w, alpha) — the simultaneous step analyzed by Lemma 2 — followed by
    the App. B projections (w box, dual feasible interval).

row_scale encodes |Omega_i ∩ tile_cols| / (m*|Omega_i|) and col_scale
encodes |Omega_bar_j ∩ tile_rows| / |Omega_bar_j| — the tile-restricted
nonzero counts, so the batched step is the exact gradient of f
restricted to the tile (zero scales on padding rows/columns).
"""

import jax.numpy as jnp

ADAGRAD_EPS = 1e-8
LOGISTIC_EPS = 1e-6  # f32 kernel cannot resolve the paper's 1e-14

LOSSES = ("hinge", "logistic", "square")


def dual_utility_grad(loss, alpha, y):
    """h'(alpha, y) = -grad of the conjugate, per Table 1."""
    if loss == "hinge":
        return y * jnp.ones_like(alpha)
    if loss == "logistic":
        beta = jnp.clip(y * alpha, LOGISTIC_EPS, 1.0 - LOGISTIC_EPS)
        return y * jnp.log((1.0 - beta) / beta)
    if loss == "square":
        return y - alpha
    raise ValueError(f"unknown loss {loss}")


def project_alpha(loss, alpha, y):
    """Projection onto the dual feasible set (App. B)."""
    if loss == "hinge":
        return y * jnp.clip(y * alpha, 0.0, 1.0)
    if loss == "logistic":
        return y * jnp.clip(y * alpha, LOGISTIC_EPS, 1.0 - LOGISTIC_EPS)
    if loss == "square":
        return alpha
    raise ValueError(f"unknown loss {loss}")


def tile_update(loss, x, w, w_acc, alpha, a_acc, y, row_scale, col_scale, params):
    """One batched saddle step on a dense tile.

    Args:
      loss: one of LOSSES (static python string).
      x: (bm, bd) tile of the data matrix.
      w: (bd,) weight block.       w_acc: (bd,) AdaGrad accumulators.
      alpha: (bm,) dual block.     a_acc: (bm,) AdaGrad accumulators.
      y: (bm,) labels (+-1; regression targets for square loss).
      row_scale: (bm,) |Omega_i ∩ tile|/(m*|Omega_i|), 0 on padding rows.
      col_scale: (bd,) |Omega_bar_j ∩ tile|/|Omega_bar_j|, 0 on padding.
      params: (4,) f32 [eta0, lambda, inv_m, w_bound].

    Returns (w', w_acc', alpha', a_acc'), all f32.
    """
    x = x.astype(jnp.float32)
    eta0, lam, inv_m, w_bound = params[0], params[1], params[2], params[3]

    u = x @ w  # (bm,)
    g_a = dual_utility_grad(loss, alpha, y) * row_scale - u * inv_m
    t = x.T @ alpha  # (bd,) — OLD alpha: simultaneous step
    # phi(w) = w^2 (the paper's square-norm regularizer): phi' = 2w.
    g_w = lam * (2.0 * w) * col_scale - t * inv_m

    a_acc2 = a_acc + g_a * g_a
    eta_a = eta0 / jnp.sqrt(ADAGRAD_EPS + a_acc2)
    alpha2 = project_alpha(loss, alpha + eta_a * g_a, y)

    w_acc2 = w_acc + g_w * g_w
    eta_w = eta0 / jnp.sqrt(ADAGRAD_EPS + w_acc2)
    w2 = jnp.clip(w - eta_w * g_w, -w_bound, w_bound)

    return (
        w2.astype(jnp.float32),
        w_acc2.astype(jnp.float32),
        alpha2.astype(jnp.float32),
        a_acc2.astype(jnp.float32),
    )


def primal_objective(loss, x, y, w, lam):
    """Dense primal P(w) = lam*sum(w^2) + mean loss (Eq. 1), used to
    validate the L2 model objective against hand computations and the
    Rust evaluator."""
    u = x @ w
    if loss == "hinge":
        risk = jnp.maximum(0.0, 1.0 - y * u)
    elif loss == "logistic":
        z = -y * u
        risk = jnp.log1p(jnp.exp(-jnp.abs(z))) + jnp.maximum(z, 0.0)
    elif loss == "square":
        risk = 0.5 * (u - y) ** 2
    else:
        raise ValueError(loss)
    return lam * jnp.sum(w * w) + jnp.mean(risk)
