"""L1 — the DSO tile-update Pallas kernel.

The paper's hot spot is the stream of stochastic saddle updates (Eq. 8)
over the active block Omega^(q, sigma_r(q)). On dense data the batched
(tile) form of that update is two matmuls plus elementwise work — an
MXU-shaped computation. This kernel fuses the whole tile step:

    u      = X_tile @ w_blk            # MXU, (bm,bd)x(bd,) -> (bm,)
    g_a    = h'(alpha,y)*row_scale - u/m
    t      = X_tile^T @ alpha          # MXU (old alpha: simultaneous)
    g_w    = lam*2w*col_scale - t/m
    AdaGrad accumulate/step + projections on both halves (VPU)

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the original
paper is CPU/MPI; the TPU formulation holds the (bm, bd) f32 tile in
VMEM (256x256 -> 256 KiB, far under the ~16 MiB budget, leaving room
for double buffering), feeds the MXU with both matmuls, and fuses the
AdaGrad/projection elementwise tail into the same kernel so the tile is
read exactly once per visit.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which runs bit-for-bit
on the Rust side. Real-TPU performance is therefore *estimated* in
DESIGN.md §Perf, not measured here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

ADAGRAD_EPS = ref.ADAGRAD_EPS
LOGISTIC_EPS = ref.LOGISTIC_EPS


def _kernel_body(
    loss,
    iters,
    x_ref,
    w_ref,
    w_acc_ref,
    alpha_ref,
    a_acc_ref,
    y_ref,
    row_scale_ref,
    col_scale_ref,
    params_ref,
    w_out_ref,
    w_acc_out_ref,
    alpha_out_ref,
    a_acc_out_ref,
):
    x = x_ref[...]
    y = y_ref[...]
    row_scale = row_scale_ref[...]
    col_scale = col_scale_ref[...]
    eta0 = params_ref[0]
    lam = params_ref[1]
    inv_m = params_ref[2]
    w_bound = params_ref[3]

    def step(_, carry):
        w, w_acc, alpha, a_acc = carry
        # --- dual (alpha) half ---
        u = x @ w  # (bm,)
        if loss == "hinge":
            hp = y
        elif loss == "logistic":
            beta = jnp.clip(y * alpha, LOGISTIC_EPS, 1.0 - LOGISTIC_EPS)
            hp = y * jnp.log((1.0 - beta) / beta)
        else:  # square
            hp = y - alpha
        g_a = hp * row_scale - u * inv_m

        # --- primal (w) half, old alpha (simultaneous step) ---
        t = x.T @ alpha  # (bd,)
        g_w = lam * (2.0 * w) * col_scale - t * inv_m

        # --- AdaGrad + projections ---
        a_acc2 = a_acc + g_a * g_a
        eta_a = eta0 / jnp.sqrt(ADAGRAD_EPS + a_acc2)
        alpha2 = alpha + eta_a * g_a
        if loss == "hinge":
            alpha2 = y * jnp.clip(y * alpha2, 0.0, 1.0)
        elif loss == "logistic":
            alpha2 = y * jnp.clip(y * alpha2, LOGISTIC_EPS, 1.0 - LOGISTIC_EPS)

        w_acc2 = w_acc + g_w * g_w
        eta_w = eta0 / jnp.sqrt(ADAGRAD_EPS + w_acc2)
        w2 = jnp.clip(w - eta_w * g_w, -w_bound, w_bound)
        return (w2, w_acc2, alpha2, a_acc2)

    carry = (w_ref[...], w_acc_ref[...], alpha_ref[...], a_acc_ref[...])
    # `iters` batched steps fused into one kernel invocation: amortizes
    # the PJRT call overhead, which profiling showed dominates small
    # tiles (EXPERIMENTS.md §Perf).
    if iters == 1:
        carry = step(0, carry)
    else:
        carry = jax.lax.fori_loop(0, iters, step, carry)
    w2, w_acc2, alpha2, a_acc2 = carry

    w_out_ref[...] = w2
    w_acc_out_ref[...] = w_acc2
    alpha_out_ref[...] = alpha2
    a_acc_out_ref[...] = a_acc2


@functools.partial(jax.jit, static_argnames=("loss", "bm", "bd", "iters"))
def tile_update(
    loss, bm, bd, x, w, w_acc, alpha, a_acc, y, row_scale, col_scale, params, iters=1
):
    """Pallas tile update; same signature/semantics as `iters`
    applications of ref.tile_update, with static (loss, bm, bd, iters)."""
    f32 = jnp.float32
    out_shape = (
        jax.ShapeDtypeStruct((bd,), f32),  # w
        jax.ShapeDtypeStruct((bd,), f32),  # w_acc
        jax.ShapeDtypeStruct((bm,), f32),  # alpha
        jax.ShapeDtypeStruct((bm,), f32),  # a_acc
    )
    return pl.pallas_call(
        functools.partial(_kernel_body, loss, iters),
        out_shape=out_shape,
        interpret=True,
    )(x, w, w_acc, alpha, a_acc, y, row_scale, col_scale, params)


def make_tile_fn(loss, bm, bd, iters=1):
    """A jittable function of the 9 array args with the statics bound —
    the unit aot.py lowers to one HLO artifact."""

    def fn(x, w, w_acc, alpha, a_acc, y, row_scale, col_scale, params):
        return tile_update(
            loss, bm, bd, x, w, w_acc, alpha, a_acc, y, row_scale, col_scale, params,
            iters=iters,
        )

    fn.__name__ = f"dso_tile_{loss}_{bm}x{bd}_x{iters}"
    return fn


def example_args(bm, bd):
    """ShapeDtypeStructs for lowering (order matters — the Rust runtime
    packs literals in exactly this order)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((bm, bd), f32),  # x
        jax.ShapeDtypeStruct((bd,), f32),     # w
        jax.ShapeDtypeStruct((bd,), f32),     # w_acc
        jax.ShapeDtypeStruct((bm,), f32),     # alpha
        jax.ShapeDtypeStruct((bm,), f32),     # a_acc
        jax.ShapeDtypeStruct((bm,), f32),     # y
        jax.ShapeDtypeStruct((bm,), f32),     # row_scale
        jax.ShapeDtypeStruct((bd,), f32),     # col_scale
        jax.ShapeDtypeStruct((4,), f32),      # params
    )


def vmem_bytes(bm, bd):
    """Estimated VMEM residency of one tile invocation (f32):
    tile + 2*(bd) + 2*(bm) vectors in and the same out + y/scales."""
    return 4 * (bm * bd + 4 * bd + 6 * bm + 4)
