"""L2 — JAX model functions built around the L1 kernel.

Two computations are AOT-exported for the Rust coordinator:

  * the tile saddle-update step (wraps kernels.dso_tile; one artifact
    per (loss, bm, bd) variant), and
  * a dense-tile objective evaluator `tile_objective` used by the tile
    engine's monitor to accumulate the primal risk and margins
    block-by-block without leaving the PJRT runtime.

Everything here is build-time only: `make artifacts` lowers these
functions to HLO text; Python never runs during training.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import dso_tile


def tile_update_fn(loss, bm, bd, iters=1):
    """The exported tile update (see kernels.dso_tile.make_tile_fn)."""
    return dso_tile.make_tile_fn(loss, bm, bd, iters)


@functools.partial(jax.jit, static_argnames=("loss",))
def _tile_objective(loss, x, y, w, active):
    """Partial primal risk of one dense tile.

    Returns (risk_sum, margins): `risk_sum` the summed loss over the
    tile's *active* rows (active is a 0/1 mask covering padding), and
    `margins` = X.w for downstream test-error evaluation. The Rust
    monitor adds the regularizer term and divides by m.
    """
    u = x @ w
    if loss == "hinge":
        risk = jnp.maximum(0.0, 1.0 - y * u)
    elif loss == "logistic":
        z = -y * u
        risk = jnp.log1p(jnp.exp(-jnp.abs(z))) + jnp.maximum(z, 0.0)
    elif loss == "square":
        risk = 0.5 * (u - y) ** 2
    else:
        raise ValueError(loss)
    return (jnp.sum(risk * active), u)


def tile_objective_fn(loss, bm, bd):
    def fn(x, y, w, active):
        return _tile_objective(loss, x, y, w, active)

    fn.__name__ = f"tile_objective_{loss}_{bm}x{bd}"
    return fn


def objective_example_args(bm, bd):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((bm, bd), f32),  # x
        jax.ShapeDtypeStruct((bm,), f32),     # y
        jax.ShapeDtypeStruct((bd,), f32),     # w
        jax.ShapeDtypeStruct((bm,), f32),     # active mask
    )
