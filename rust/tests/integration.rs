//! Cross-module integration: full pipelines from config text / CLI /
//! libsvm files through training; tile-vs-scalar cross-checks through
//! the real PJRT runtime; failure injection (bad configs, corrupt
//! artifacts, malformed data files) yields clean errors, not panics.

// NOTE: this suite deliberately exercises the deprecated free-function
// shims — it pins them bit-for-bit against the `dso::api::Trainer`
// facade (DESIGN.md §Solver-API deprecation map).
#![allow(deprecated)]

use dso::config::{Algorithm, ExecMode, TrainConfig};
use dso::data::synth::DenseSpec;
use dso::losses::{Loss, Problem, Regularizer};

fn have_artifacts() -> bool {
    dso::runtime::Manifest::load_default().is_ok()
}

#[test]
fn toml_config_to_training_pipeline() {
    let text = r#"
[data]
name = "real-sim"
scale = 0.08
test_frac = 0.2

[model]
loss = "hinge"
lambda = 1e-3

[optim]
algorithm = "dso"
epochs = 8
eta0 = 0.2

[cluster]
machines = 2
cores = 2

[monitor]
every = 2
"#;
    let cfg = TrainConfig::from_toml(text).unwrap();
    let ds = dso::cli::load_dataset(&cfg).unwrap();
    let (train, test) = ds.split(cfg.data.test_frac, cfg.data.seed);
    let r = dso::coordinator::train(&cfg, &train, Some(&test)).unwrap();
    assert!(r.final_primal.is_finite());
    assert!(r.history.len() >= 4);
    assert!(r.final_gap >= -1e-6);
}

#[test]
fn libsvm_file_to_training_pipeline() {
    let dir = std::env::temp_dir().join("dso-int-libsvm");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("data.libsvm");
    let ds = dso::data::registry::generate("news20", 0.05, 3).unwrap();
    dso::data::libsvm::write(&ds, &path).unwrap();

    let mut cfg = TrainConfig::default();
    cfg.data.path = Some(path.to_str().unwrap().to_string());
    cfg.optim.epochs = 5;
    cfg.cluster.machines = 2;
    cfg.cluster.cores = 1;
    let loaded = dso::cli::load_dataset(&cfg).unwrap();
    assert_eq!(loaded.m(), ds.m());
    let r = dso::coordinator::train(&cfg, &loaded, None).unwrap();
    assert!(r.final_primal.is_finite());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tile_and_scalar_engines_reach_similar_optima() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let ds = DenseSpec {
        name: "int-dense".into(),
        m: 128,
        d: 48,
        density: 1.0,
        label_noise: 0.03,
        pos_frac: 0.5,
        prototypes: 16,
        seed: 11,
    }
    .generate();
    let mk = |mode: ExecMode| {
        let mut c = TrainConfig::default();
        c.optim.epochs = 80;
        c.optim.eta0 = 0.3;
        c.model.lambda = 1e-3;
        c.cluster.machines = 2;
        c.cluster.cores = 1;
        c.cluster.mode = mode;
        c
    };
    let scalar = dso::coordinator::train(&mk(ExecMode::Scalar), &ds, None).unwrap();
    let tile = dso::coordinator::train(&mk(ExecMode::Tile), &ds, None).unwrap();
    // Different update granularity (Gauss-Seidel scalar vs Jacobi tile)
    // but the same saddle problem: optima must agree loosely.
    let rel = (scalar.final_primal - tile.final_primal).abs()
        / scalar.final_primal.abs().max(1e-12);
    assert!(
        rel < 0.15,
        "scalar {} vs tile {}",
        scalar.final_primal,
        tile.final_primal
    );
    assert!(tile.final_gap >= -1e-5);
}

#[test]
fn tile_engine_beats_zero_and_tracks_dcd() {
    if !have_artifacts() {
        return;
    }
    let ds = DenseSpec {
        name: "int-dense2".into(),
        m: 160,
        d: 64,
        density: 1.0,
        label_noise: 0.02,
        pos_frac: 0.5,
        prototypes: 20,
        seed: 13,
    }
    .generate();
    let mut c = TrainConfig::default();
    c.optim.epochs = 120;
    c.optim.eta0 = 0.5;
    c.model.lambda = 1e-3;
    c.cluster.machines = 2;
    c.cluster.cores = 1;
    c.cluster.mode = ExecMode::Tile;
    let r = dso::coordinator::train(&c, &ds, None).unwrap();
    let dcd = dso::optim::dcd::solve_hinge_l2(&ds, 1e-3, 800, 1e-10, 1);
    let p = Problem::new(Loss::Hinge, Regularizer::L2, 1e-3);
    let p_star = p.primal(&ds, &dcd.w);
    let rel = (r.final_primal - p_star) / p_star.abs().max(1e-12);
    assert!(rel < 0.12, "tile {} vs optimum {p_star} (rel {rel})", r.final_primal);
}

// ---------- failure injection ----------

#[test]
fn invalid_configs_error_cleanly() {
    for bad in [
        "[model]\nlambda = -1\n",
        "[optim]\nalgorithm = \"nope\"\n",
        "[cluster]\ncores = 0\n",
        "[data]\nscale = 0\n",
        "model.lambda = \n",
    ] {
        assert!(TrainConfig::from_toml(bad).is_err(), "{bad:?} accepted");
    }
}

#[test]
fn missing_libsvm_file_errors() {
    let mut cfg = TrainConfig::default();
    cfg.data.path = Some("/nonexistent/path/data.libsvm".into());
    assert!(dso::cli::load_dataset(&cfg).is_err());
}

#[test]
fn corrupt_libsvm_errors_with_line_number() {
    let dir = std::env::temp_dir().join("dso-int-corrupt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.libsvm");
    std::fs::write(&path, "1 1:0.5\n1 garbage\n").unwrap();
    let err = dso::data::libsvm::read(&path, 0).unwrap_err();
    assert!(format!("{err}").contains("line 2"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_artifact_manifest_errors() {
    let dir = std::env::temp_dir().join("dso-int-badmanifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(dso::runtime::Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"schema": 1, "entries": []}"#).unwrap();
    assert!(dso::runtime::Manifest::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_hlo_artifact_fails_at_load_not_panic() {
    let dir = std::env::temp_dir().join("dso-int-badhlo");
    std::fs::create_dir_all(&dir).unwrap();
    let hlo = dir.join("bad.hlo.txt");
    std::fs::write(&hlo, "HloModule garbage\nthis is not hlo\n").unwrap();
    let mut rt = dso::runtime::PjrtRuntime::cpu().unwrap();
    assert!(rt.load("bad", &hlo).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tile_mode_without_artifacts_errors_cleanly() {
    // Point artifact discovery at an empty dir via env override.
    // (Run serially with other tests — env var is process-global; the
    // variable is restored immediately.)
    let dir = std::env::temp_dir().join("dso-int-noartifacts");
    std::fs::create_dir_all(&dir).unwrap();
    let old = std::env::var("DSO_ARTIFACTS").ok();
    std::env::set_var("DSO_ARTIFACTS", dir.to_str().unwrap());
    let ds = DenseSpec {
        name: "x".into(),
        m: 32,
        d: 16,
        density: 1.0,
        label_noise: 0.0,
        pos_frac: 0.5,
        prototypes: 4,
        seed: 1,
    }
    .generate();
    let mut c = TrainConfig::default();
    c.cluster.mode = ExecMode::Tile;
    c.optim.epochs = 2;
    let res = dso::coordinator::train(&c, &ds, None);
    match old {
        Some(v) => std::env::set_var("DSO_ARTIFACTS", v),
        None => std::env::remove_var("DSO_ARTIFACTS"),
    }
    assert!(res.is_err());
}

#[test]
fn degenerate_datasets_handled() {
    use dso::data::{Csr, Dataset};
    // All-positive labels.
    let x = Csr::from_rows(2, vec![vec![(0, 1.0)], vec![(1, 1.0)], vec![(0, 0.5)]]);
    let ds = Dataset::new("allpos", x, vec![1.0, 1.0, 1.0]);
    let mut c = TrainConfig::default();
    c.optim.epochs = 3;
    c.cluster.machines = 1;
    c.cluster.cores = 1;
    let r = dso::coordinator::train(&c, &ds, None).unwrap();
    assert!(r.final_primal.is_finite());

    // Dataset with an empty row (no features).
    let x = Csr::from_rows(2, vec![vec![(0, 1.0)], vec![], vec![(1, -1.0)]]);
    let ds = Dataset::new("emptyrow", x, vec![1.0, -1.0, -1.0]);
    let r = dso::coordinator::train(&c, &ds, None).unwrap();
    assert!(r.final_primal.is_finite());

    // Single data point, single feature, p capped to 1.
    let x = Csr::from_rows(1, vec![vec![(0, 1.0)]]);
    let ds = Dataset::new("single", x, vec![1.0]);
    let mut c8 = c.clone();
    c8.cluster.machines = 8;
    let r = dso::coordinator::train(&c8, &ds, None).unwrap();
    assert!(r.final_primal.is_finite());
}

#[test]
fn all_baselines_run_on_all_registry_serial_datasets() {
    for &name in dso::data::registry::SERIAL_NAMES {
        let ds = dso::data::registry::generate(name, 0.05, 1).unwrap();
        for algo in [Algorithm::Dso, Algorithm::Sgd, Algorithm::Psgd, Algorithm::Bmrm] {
            let mut c = TrainConfig::default();
            c.optim.algorithm = algo;
            c.optim.epochs = 3;
            c.cluster.machines = 2;
            c.cluster.cores = 1;
            let r = dso::coordinator::train(&c, &ds, None)
                .unwrap_or_else(|e| panic!("{name}/{algo:?}: {e}"));
            assert!(r.final_primal.is_finite(), "{name}/{algo:?}");
        }
    }
}

#[test]
fn balanced_partition_reduces_epoch_imbalance_on_skewed_data() {
    use dso::config::PartitionKind;
    use dso::coordinator::engine::make_partitions;
    use dso::partition::PackedBlocks;
    // Heavily zipf-skewed features: even column cuts put all hot
    // features in one block.
    let ds = dso::data::synth::SparseSpec {
        name: "skew".into(),
        m: 600,
        d: 400,
        nnz_per_row: 10.0,
        zipf_s: 1.3,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 3,
    }
    .generate();
    let mut cfg = TrainConfig::default();
    cfg.cluster.machines = 4;
    cfg.cluster.cores = 1;

    cfg.cluster.partition = PartitionKind::Even;
    let (re, ce) = make_partitions(&cfg, &ds, 4);
    let even = PackedBlocks::build(&ds.x, &re, &ce).epoch_imbalance();

    cfg.cluster.partition = PartitionKind::Balanced;
    let (rb, cb) = make_partitions(&cfg, &ds, 4);
    let om = PackedBlocks::build(&ds.x, &rb, &cb);
    om.validate(&ds.x).unwrap();
    let balanced = om.epoch_imbalance();
    assert!(
        balanced < even,
        "balanced {balanced} !< even {even} (epoch imbalance)"
    );

    // And training still works + serializability holds under balanced.
    cfg.optim.epochs = 3;
    let a = dso::coordinator::train_dso(&cfg, &ds, None).unwrap();
    let b = dso::coordinator::run_replay(&cfg, &ds, None).unwrap();
    assert_eq!(a.w, b.w);
    assert!(a.final_gap >= -1e-6);
}
