//! Property-based integration tests: randomized problem shapes feed
//! full training runs and system invariants are asserted on the
//! results (weak duality, feasibility, projection boxes, replay
//! equality, libsvm round-tripping of generated data).

// NOTE: this suite deliberately exercises the deprecated free-function
// shims — it pins them bit-for-bit against the `dso::api::Trainer`
// facade (DESIGN.md §Solver-API deprecation map).
#![allow(deprecated)]

use dso::config::{LossKind, TrainConfig};
use dso::data::synth::SparseSpec;
use dso::losses::{Loss, Problem, Regularizer};
use dso::util::prop;

fn random_dataset(g: &mut prop::Gen) -> dso::data::Dataset {
    SparseSpec {
        name: "prop".into(),
        m: g.usize_in(20, 200),
        d: g.usize_in(10, 120),
        nnz_per_row: g.f64_in(2.0, 8.0),
        zipf_s: g.f64_in(0.0, 1.1),
        label_noise: g.f64_in(0.0, 0.1),
        pos_frac: g.f64_in(0.2, 0.8),
        seed: g.case_seed,
    }
    .generate()
}

fn random_cfg(g: &mut prop::Gen) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.optim.epochs = g.usize_in(1, 6);
    c.optim.eta0 = g.f64_in(0.01, 1.0);
    c.model.lambda = *g.pick(&[1e-2, 1e-3, 1e-4]);
    c.model.loss = *g.pick(&[LossKind::Hinge, LossKind::Logistic, LossKind::Square]);
    c.cluster.machines = g.usize_in(1, 5);
    c.cluster.cores = 1;
    c.monitor.every = 0;
    c
}

#[test]
fn prop_weak_duality_and_feasibility_after_training() {
    prop::check("weak duality after DSO", 25, |g| {
        let ds = random_dataset(g);
        let cfg = random_cfg(g);
        let r = dso::coordinator::train(&cfg, &ds, None).map_err(|e| e.to_string())?;
        prop::assert_that(
            r.final_gap >= -1e-5,
            format!("negative gap {}", r.final_gap),
        )?;
        // α feasibility per loss.
        let loss = Loss::from(cfg.model.loss);
        for (i, &a) in r.alpha.iter().enumerate() {
            let pa = loss.project_alpha(a as f64, ds.y[i] as f64);
            prop::assert_close(pa, a as f64, 1e-5, &format!("alpha[{i}] feasible"))?;
        }
        // w box (App. B).
        let b = loss.w_bound(cfg.model.lambda) as f32 + 1e-3;
        prop::assert_that(
            r.w.iter().all(|&wj| (-b..=b).contains(&wj)),
            "w outside box",
        )?;
        // All finite.
        prop::assert_that(
            r.w.iter().all(|v| v.is_finite()) && r.alpha.iter().all(|v| v.is_finite()),
            "non-finite parameters",
        )
    });
}

#[test]
fn prop_threaded_equals_replay() {
    prop::check("replay equality", 15, |g| {
        let ds = random_dataset(g);
        let cfg = random_cfg(g);
        let a = dso::coordinator::train_dso(&cfg, &ds, None).map_err(|e| e.to_string())?;
        let b = dso::coordinator::run_replay(&cfg, &ds, None).map_err(|e| e.to_string())?;
        prop::assert_that(a.w == b.w, "w differs from replay")?;
        prop::assert_that(a.alpha == b.alpha, "alpha differs from replay")
    });
}

#[test]
fn prop_training_never_worsens_vs_zero_start_much() {
    // Stochastic saddle steps can transiently increase the primal, but
    // a full run should never end dramatically above P(0).
    prop::check("no blowup", 20, |g| {
        let ds = random_dataset(g);
        let cfg = random_cfg(g);
        let problem = Problem::new(
            Loss::from(cfg.model.loss),
            Regularizer::from(cfg.model.reg),
            cfg.model.lambda,
        );
        let at_zero = problem.primal(&ds, &vec![0.0; ds.d()]);
        let r = dso::coordinator::train(&cfg, &ds, None).map_err(|e| e.to_string())?;
        prop::assert_that(
            r.final_primal < at_zero * 2.0 + 1.0,
            format!("blowup: {} vs P(0)={at_zero}", r.final_primal),
        )
    });
}

#[test]
fn prop_generated_datasets_roundtrip_libsvm() {
    prop::check("libsvm roundtrip", 20, |g| {
        let ds = random_dataset(g);
        let text = dso::data::libsvm::emit(&ds);
        let back =
            dso::data::libsvm::parse(&ds.name, &text, ds.d()).map_err(|e| e.to_string())?;
        prop::assert_that(back.m() == ds.m(), "m")?;
        prop::assert_that(back.d() == ds.d(), "d")?;
        prop::assert_that(back.y == ds.y, "labels")?;
        prop::assert_that(back.x.nnz() == ds.x.nnz(), "nnz")?;
        // Values survive the decimal round-trip to f32 precision.
        for i in 0..ds.m() {
            let (ia, va) = ds.x.row(i);
            let (ib, vb) = back.x.row(i);
            prop::assert_that(ia == ib, format!("row {i} indices"))?;
            for k in 0..va.len() {
                prop::assert_close(va[k] as f64, vb[k] as f64, 1e-6, "value")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_monitor_history_wellformed() {
    prop::check("history well-formed", 10, |g| {
        let ds = random_dataset(g);
        let mut cfg = random_cfg(g);
        cfg.monitor.every = 1;
        let r = dso::coordinator::train(&cfg, &ds, None).map_err(|e| e.to_string())?;
        prop::assert_that(r.history.len() == cfg.optim.epochs, "one row per epoch")?;
        let epochs = r.history.col("epoch").unwrap();
        let virt = r.history.col("virtual_s").unwrap();
        let updates = r.history.col("updates").unwrap();
        for k in 1..epochs.len() {
            prop::assert_that(epochs[k] > epochs[k - 1], "epochs increasing")?;
            prop::assert_that(virt[k] >= virt[k - 1], "virtual time monotone")?;
            prop::assert_that(updates[k] >= updates[k - 1], "updates monotone")?;
        }
        Ok(())
    });
}

#[test]
fn prop_partitioned_training_matches_worker_count_invariants() {
    prop::check("worker count invariants", 15, |g| {
        let ds = random_dataset(g);
        let mut cfg = random_cfg(g);
        cfg.monitor.every = 0;
        cfg.optim.epochs = 2;
        let r = dso::coordinator::train_dso(&cfg, &ds, None).map_err(|e| e.to_string())?;
        // Every nonzero is visited once per epoch (full sweeps).
        let expected = 2 * ds.nnz() as u64;
        prop::assert_that(
            r.total_updates == expected,
            format!("updates {} != 2*nnz {}", r.total_updates, expected),
        )?;
        prop::assert_that(r.w.len() == ds.d(), "w length")?;
        prop::assert_that(r.alpha.len() == ds.m(), "alpha length")
    });
}
