//! Differential property suite for the **affine-α** square-loss lane
//! kernel (`sweep_lanes_affine`), mirroring `tests/lane_kernel.rs`: on
//! random sparse blocks × {L1, L2} × {Fixed, AdaGrad}, one affine sweep
//! must match the checked COO scalar oracle (`sweep_block`) within 1e-5
//! relative error — including ragged tails, short scalar-fallback
//! groups, and sentinel-padded storage (sentinel mutation must be
//! bitwise inert) — and the engines' (size, loss) dispatch must keep
//! Lemma-2 threaded ≡ replay bit-identity on the new path.
//!
//! Tolerance rationale: the affine path diverges from the scalar α
//! recurrence only at f32-ulp level per entry — the coefficient lanes
//! round `y·hr − w·x` through f32, the running α skips the scalar
//! path's per-entry f32 round-trip, and the fixed-step fold associates
//! η differently (α ← a·α + η·c vs α ← α + η·(c − hr·α)). Each is
//! ~6e-8 relative per update, so one sweep stays well inside 1e-5 of
//! the oracle (which itself sits ≪1e-5 from the packed scalar kernel).
//! Hinge/logistic never take this path: `Loss::affine_alpha()` is
//! false for them, and even a direct call degrades to `sweep_lanes`
//! bit for bit (pinned below).

// NOTE: this suite deliberately exercises the deprecated free-function
// shims — it pins them bit-for-bit against the `dso::api::Trainer`
// facade (DESIGN.md §Solver-API deprecation map).
#![allow(deprecated)]

use dso::config::{LossKind, PartitionKind, RegKind, StepKind, TrainConfig};
use dso::coordinator::updates::{
    sweep_block, sweep_lanes, sweep_lanes_affine, sweep_packed, BlockState, PackedCtx,
    PackedState, StepRule, SweepCtx,
};
use dso::coordinator::DsoSetup;
use dso::data::synth::SparseSpec;
use dso::data::Dataset;
use dso::losses::{Loss, Regularizer};
use dso::partition::{PackedBlock, PackedBlocks, Partition, LANES};
use dso::util::prop;

/// Dense-ish random dataset so row groups straddle LANES: blocks carry
/// a mix of lane-eligible groups, ragged tails, and short
/// scalar-fallback groups. Labels are real-valued (regression targets):
/// the square loss is not restricted to ±1 and the affine recurrence
/// must hold for any y.
fn random_regression_dataset(g: &mut prop::Gen) -> Dataset {
    let mut ds = SparseSpec {
        name: "alpha-prop".into(),
        m: g.usize_in(20, 100),
        d: g.usize_in(16, 64),
        nnz_per_row: g.f64_in(4.0, 3.0 * LANES as f64),
        zipf_s: g.f64_in(0.0, 1.0),
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: g.case_seed,
    }
    .generate();
    // Replace the ±1 classification labels with bounded real targets.
    for yv in ds.y.iter_mut() {
        *yv = g.f32_in(-2.0, 2.0);
    }
    ds
}

/// Run `sweeps` COO-oracle sweeps of block (q, r) and return the final
/// stripe-local (w, α).
#[allow(clippy::too_many_arguments)]
fn oracle_trajectory(
    ds: &Dataset,
    om: &PackedBlocks,
    q: usize,
    r: usize,
    reg: Regularizer,
    lambda: f64,
    rule: StepRule,
    sweeps: usize,
) -> (Vec<f32>, Vec<f32>) {
    let loss = Loss::Square;
    let entries = om.block_entries(&ds.x, q, r);
    let ctx = SweepCtx {
        loss,
        reg,
        lambda,
        m: ds.m() as f64,
        row_counts: &om.row_counts,
        col_counts: &om.col_counts,
        y: &ds.y,
        w_bound: loss.w_bound(lambda),
        rule,
    };
    let mut w = vec![0.01f32; om.col_part.block_len(r)];
    let mut w_acc = vec![0f32; w.len()];
    let mut alpha = vec![0f32; om.row_part.block_len(q)];
    let mut a_acc = vec![0f32; alpha.len()];
    for _ in 0..sweeps {
        let mut st = BlockState {
            w: &mut w,
            w_acc: &mut w_acc,
            w_off: om.col_part.bounds[r],
            alpha: &mut alpha,
            a_acc: &mut a_acc,
            a_off: om.row_part.bounds[q],
        };
        sweep_block(&entries, &ctx, &mut st);
    }
    (w, alpha)
}

/// Run `sweeps` sweeps of block (q, r) with the given packed kernel on
/// a possibly-overridden block (for the sentinel-mutation tests) and
/// return the full final state.
#[allow(clippy::too_many_arguments)]
fn packed_trajectory(
    kernel: fn(&PackedBlock, &PackedCtx, &mut PackedState) -> usize,
    block: &PackedBlock,
    ds: &Dataset,
    om: &PackedBlocks,
    q: usize,
    r: usize,
    loss: Loss,
    reg: Regularizer,
    lambda: f64,
    rule: StepRule,
    sweeps: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let y_local = om.stripe_labels(&ds.y);
    let alpha_bias = om.stripe_alpha_bias(&ds.y);
    let ctx = PackedCtx {
        loss,
        reg,
        lambda,
        w_bound: loss.w_bound(lambda),
        rule,
        inv_col: &om.inv_col[r],
        inv_col32: &om.inv_col32[r],
        inv_row: &om.inv_row[q],
        y: &y_local[q],
        alpha_bias32: &alpha_bias[q],
    };
    let mut w = vec![0.01f32; om.col_part.block_len(r)];
    let mut w_acc = vec![0f32; w.len()];
    let mut alpha = vec![0f32; om.row_part.block_len(q)];
    let mut a_acc = vec![0f32; alpha.len()];
    for _ in 0..sweeps {
        let mut st = PackedState {
            w: &mut w,
            w_acc: &mut w_acc,
            alpha: &mut alpha,
            a_acc: &mut a_acc,
        };
        kernel(block, &ctx, &mut st);
    }
    (w, w_acc, alpha, a_acc)
}

#[test]
fn prop_affine_matches_coo_oracle() {
    // The headline contract: one affine-α sweep agrees with the COO
    // scalar oracle to ≤1e-5 relative error across random blocks ×
    // {L1, L2} × {Fixed, AdaGrad}.
    prop::check("affine α kernel vs scalar oracle", 40, |g| {
        let ds = random_regression_dataset(g);
        let p = g.usize_in(1, 2.min(ds.m()).min(ds.d()));
        let rp = Partition::even(ds.m(), p);
        let cp = Partition::even(ds.d(), p);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        om.validate(&ds.x).map_err(|e| e)?;

        let reg = Regularizer::from(*g.pick(&[RegKind::L2, RegKind::L1]));
        let eta = g.f64_in(0.05, 0.5);
        let rule = if g.bool() { StepRule::Fixed(eta) } else { StepRule::AdaGrad(eta) };
        let lambda = *g.pick(&[1e-2, 1e-3, 1e-4]);
        let q = g.usize_in(0, p - 1);
        let r = g.usize_in(0, p - 1);

        let (rw, ra) = oracle_trajectory(&ds, &om, q, r, reg, lambda, rule, 1);
        let (aw, _, aa, _) = packed_trajectory(
            sweep_lanes_affine,
            om.block(q, r),
            &ds,
            &om,
            q,
            r,
            Loss::Square,
            reg,
            lambda,
            rule,
            1,
        );
        for k in 0..rw.len() {
            prop::assert_close(rw[k] as f64, aw[k] as f64, 1e-5, &format!("w[{k}]"))?;
        }
        for k in 0..ra.len() {
            prop::assert_close(ra[k] as f64, aa[k] as f64, 1e-5, &format!("alpha[{k}]"))?;
        }
        Ok(())
    });
}

#[test]
fn affine_matches_oracle_ragged_and_short_groups() {
    // Deterministic restatement across {L1, L2} × {Fixed, AdaGrad} on a
    // block whose row groups deliberately straddle LANES (lengths 1,
    // LANES−1, LANES, LANES+3, 2·LANES+5): full chunks, ragged tails,
    // sentinel padding, and scalar-fallback groups in one sweep, with
    // non-unit regression targets.
    let lens = [1usize, LANES - 1, LANES, LANES + 3, 2 * LANES + 5];
    let d = 2 * LANES + 5;
    let rows: Vec<Vec<(u32, f32)>> = lens
        .iter()
        .enumerate()
        .map(|(i, &len)| {
            (0..len).map(|j| (j as u32, 0.3 + 0.1 * (i + j) as f32)).collect()
        })
        .collect();
    let x = dso::data::sparse::Csr::from_rows(d, rows);
    let y: Vec<f32> = (0..lens.len()).map(|i| 1.5 - 0.7 * i as f32).collect();
    let ds = Dataset::new("ragged-ridge", x, y);
    let rp = Partition::even(ds.m(), 1);
    let cp = Partition::even(ds.d(), 1);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    om.validate(&ds.x).unwrap();
    let b = om.block(0, 0);
    assert!(b.has_lanes());
    assert!(b.padded_nnz() > b.nnz(), "test must exercise sentinels");

    for reg in [Regularizer::L2, Regularizer::L1] {
        for rule in [StepRule::Fixed(0.2), StepRule::AdaGrad(0.2)] {
            let (rw, ra) = oracle_trajectory(&ds, &om, 0, 0, reg, 1e-3, rule, 1);
            let (aw, _, aa, _) = packed_trajectory(
                sweep_lanes_affine,
                b,
                &ds,
                &om,
                0,
                0,
                Loss::Square,
                reg,
                1e-3,
                rule,
                1,
            );
            for k in 0..rw.len() {
                let rel = (rw[k] - aw[k]).abs() as f64 / (rw[k].abs() as f64).max(1e-3);
                assert!(rel <= 1e-5, "{reg:?}/{rule:?} w[{k}]: {} vs {}", rw[k], aw[k]);
            }
            for k in 0..ra.len() {
                let rel = (ra[k] - aa[k]).abs() as f64 / (ra[k].abs() as f64).max(1e-3);
                assert!(rel <= 1e-5, "{reg:?}/{rule:?} alpha[{k}]: {} vs {}", ra[k], aa[k]);
            }
        }
    }
}

#[test]
fn affine_long_row_stays_within_tolerance() {
    // The divergence sources of the affine fold (f32 coefficient
    // rounding, skipped per-entry f32 α round-trip) accumulate *per
    // entry within a row group*, so the ≤1e-5/sweep contract needs
    // validating in the long-row regime the kernel exists for — not
    // just the ≤3·LANES rows of the property suite. One 32-chunk row
    // (256 entries) leaves ~10× headroom under the bound for the
    // √N-growth of f32 rounding noise; a future regression that
    // rounds per chunk instead of per entry would blow through it.
    let n = 32 * LANES;
    let rows: Vec<Vec<(u32, f32)>> =
        vec![(0..n).map(|j| (j as u32, 0.5 + 0.1 * (j % 16) as f32)).collect()];
    let x = dso::data::sparse::Csr::from_rows(n, rows);
    let ds = Dataset::new("long-row", x, vec![1.2f32]);
    let rp = Partition::even(1, 1);
    let cp = Partition::even(n, 1);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    om.validate(&ds.x).unwrap();
    let b = om.block(0, 0);
    assert!(b.has_lanes());
    assert_eq!(b.nnz(), n);
    for reg in [Regularizer::L2, Regularizer::L1] {
        for rule in [StepRule::Fixed(0.2), StepRule::AdaGrad(0.2)] {
            let (rw, ra) = oracle_trajectory(&ds, &om, 0, 0, reg, 1e-3, rule, 1);
            let (aw, _, aa, _) = packed_trajectory(
                sweep_lanes_affine,
                b,
                &ds,
                &om,
                0,
                0,
                Loss::Square,
                reg,
                1e-3,
                rule,
                1,
            );
            for k in 0..rw.len() {
                let rel = (rw[k] - aw[k]).abs() as f64 / (rw[k].abs() as f64).max(1e-3);
                assert!(rel <= 1e-5, "{reg:?}/{rule:?} w[{k}]: {} vs {}", rw[k], aw[k]);
            }
            let rel = (ra[0] - aa[0]).abs() as f64 / (ra[0].abs() as f64).max(1e-3);
            assert!(rel <= 1e-5, "{reg:?}/{rule:?} α: {} vs {}", ra[0], aa[0]);
        }
    }
}

#[test]
fn prop_affine_sentinel_mutation_inert() {
    // Sentinels are read-only on the affine path exactly as on the
    // plain lane path: rewriting every sentinel slot to a different
    // valid column and an arbitrary value must leave the affine
    // sweep's entire output — w, α, and both accumulators — bitwise
    // unchanged.
    prop::check("affine sentinel padding inert", 25, |g| {
        let ds = random_regression_dataset(g);
        let rp = Partition::even(ds.m(), 1);
        let cp = Partition::even(ds.d(), 1);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        let b = om.block(0, 0);
        if !b.has_lanes() {
            return Ok(());
        }
        let mut mutated = b.clone();
        let mut n_sentinels = 0usize;
        for gi in 0..mutated.groups.len() {
            let g = mutated.groups[gi];
            let ps = g.pad_start as usize;
            for k in ps + g.len()..ps + g.padded_len() {
                mutated.cols[k] = mutated.n_cols - 1;
                mutated.vals[k] = -3.25;
                n_sentinels += 1;
            }
        }
        let reg = Regularizer::from(*g.pick(&[RegKind::L2, RegKind::L1]));
        let eta = g.f64_in(0.05, 0.5);
        let rule = if g.bool() { StepRule::Fixed(eta) } else { StepRule::AdaGrad(eta) };
        let run = |blk: &PackedBlock| {
            packed_trajectory(
                sweep_lanes_affine,
                blk,
                &ds,
                &om,
                0,
                0,
                Loss::Square,
                reg,
                1e-3,
                rule,
                2,
            )
        };
        prop::assert_that(
            run(b) == run(&mutated),
            format!("affine output depends on {n_sentinels} sentinel slots"),
        )
    });
}

#[test]
fn affine_entry_point_is_bitwise_lane_kernel_for_nonaffine_losses() {
    // Hinge/logistic have no affine dual: the affine entry point must
    // degrade to `sweep_lanes` exactly, so misrouting could never
    // change a trajectory. Square on a short-group block likewise is
    // the scalar kernel bit for bit.
    let ds = SparseSpec {
        name: "fallback".into(),
        m: 80,
        d: 32,
        nnz_per_row: 2.0 * LANES as f64,
        zipf_s: 0.4,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 17,
    }
    .generate();
    let rp = Partition::even(ds.m(), 1);
    let cp = Partition::even(ds.d(), 1);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    let b = om.block(0, 0);
    assert!(b.has_lanes());
    for loss in [Loss::Hinge, Loss::Logistic] {
        for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
            let affine = packed_trajectory(
                sweep_lanes_affine,
                b,
                &ds,
                &om,
                0,
                0,
                loss,
                Regularizer::L2,
                1e-3,
                rule,
                3,
            );
            let lanes = packed_trajectory(
                sweep_lanes,
                b,
                &ds,
                &om,
                0,
                0,
                loss,
                Regularizer::L2,
                1e-3,
                rule,
                3,
            );
            assert_eq!(affine, lanes, "{loss:?} {rule:?}");
        }
    }

    // Short-group block (nnz_per_row ≪ LANES): square through the
    // affine entry point is the scalar packed kernel, bitwise.
    let sparse = SparseSpec {
        name: "fallback-short".into(),
        m: 60,
        d: 40,
        nnz_per_row: 3.0,
        zipf_s: 0.5,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 23,
    }
    .generate();
    let rp = Partition::even(sparse.m(), 2);
    let cp = Partition::even(sparse.d(), 2);
    let om = PackedBlocks::build(&sparse.x, &rp, &cp);
    for q in 0..2 {
        for r in 0..2 {
            let b = om.block(q, r);
            if b.has_lanes() {
                continue;
            }
            for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                let affine = packed_trajectory(
                    sweep_lanes_affine,
                    b,
                    &sparse,
                    &om,
                    q,
                    r,
                    Loss::Square,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    3,
                );
                let scalar = packed_trajectory(
                    sweep_packed,
                    b,
                    &sparse,
                    &om,
                    q,
                    r,
                    Loss::Square,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    3,
                );
                assert_eq!(affine, scalar, "block ({q},{r}) {rule:?}");
            }
        }
    }
}

#[test]
fn engine_affine_dispatch_threaded_equals_replay() {
    // Lemma-2 bit-identity through the engines' (size, loss) dispatch:
    // dense rows force the lane path, the square loss routes it to the
    // affine-α kernel, and the threaded run must still replay exactly —
    // for even and lane-aligned balanced partitions, full and
    // subsampled sweeps, and both step-rule families.
    let ds = SparseSpec {
        name: "affine-engine".into(),
        m: 160,
        d: 48,
        nnz_per_row: 20.0,
        zipf_s: 0.6,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed: 37,
    }
    .generate();
    // Sanity: the decomposition actually has lane-eligible groups and
    // the square loss takes the affine path on them.
    let rp = Partition::even(ds.m(), 2);
    let cp = Partition::even(ds.d(), 2);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    assert!((0..2).any(|q| (0..2).any(|r| om.block(q, r).has_lanes())));
    assert!(Loss::Square.affine_alpha());

    for (partition, upb, step) in [
        (PartitionKind::Even, 0usize, StepKind::AdaGrad),
        (PartitionKind::Balanced, 0, StepKind::AdaGrad),
        (PartitionKind::Even, 9, StepKind::AdaGrad),
        (PartitionKind::Even, 0, StepKind::InvSqrt),
    ] {
        let mut c = TrainConfig::default();
        c.optim.epochs = 3;
        c.optim.eta0 = 0.2;
        c.optim.step = step;
        c.model.loss = LossKind::Square;
        c.model.lambda = 1e-3;
        c.cluster.machines = 2;
        c.cluster.cores = 1;
        c.cluster.partition = partition;
        c.cluster.updates_per_block = upb;
        c.monitor.every = 0;
        let threaded = dso::coordinator::train_dso(&c, &ds, None).unwrap();
        let replayed = dso::coordinator::run_replay(&c, &ds, None).unwrap();
        assert_eq!(threaded.w, replayed.w, "{partition:?} upb {upb} {step:?}");
        assert_eq!(threaded.alpha, replayed.alpha, "{partition:?} upb {upb} {step:?}");
        assert_eq!(threaded.total_updates, replayed.total_updates);
        assert!(threaded.final_primal.is_finite());
    }
}

#[test]
fn affine_path_reduces_square_objective() {
    // End-to-end sanity on the production dispatch: a dense square-loss
    // run (which the engine routes through `sweep_lanes_affine`) must
    // actually optimize, not just match kernels.
    let ds = SparseSpec {
        name: "affine-obj".into(),
        m: 200,
        d: 40,
        nnz_per_row: 16.0,
        zipf_s: 0.3,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed: 41,
    }
    .generate();
    let mut c = TrainConfig::default();
    c.optim.epochs = 30;
    c.optim.eta0 = 0.3;
    c.model.loss = LossKind::Square;
    c.model.lambda = 1e-3;
    c.cluster.machines = 2;
    c.cluster.cores = 1;
    c.monitor.every = 0;
    // The decomposition the engine will build must have lane groups,
    // otherwise this test would silently exercise the scalar path.
    let setup = DsoSetup::new(&c, &ds);
    assert!(
        (0..setup.p).any(|q| (0..setup.p).any(|r| setup.omega.block(q, r).has_lanes())),
        "dataset not dense enough for the lane path"
    );
    let r = dso::coordinator::train_dso(&c, &ds, None).unwrap();
    let at_zero = setup.problem.primal(&ds, &vec![0.0; ds.d()]);
    assert!(r.final_primal < at_zero, "{} !< {at_zero}", r.final_primal);
    assert!(r.final_gap >= -1e-6, "weak duality violated: {}", r.final_gap);
}

// ---------------------------------------------------------------------
// Explicit-SIMD backend differentials (PR 5): the AVX2 affine-α path
// ---------------------------------------------------------------------
// #[cfg]-gated to x86_64 + runtime detection; auto-skips elsewhere.

#[cfg(target_arch = "x86_64")]
mod avx2_backend {
    use super::*;
    use dso::config::SimdKind;
    use dso::coordinator::updates::{sweep_lanes_affine_with, sweep_lanes_with};
    use dso::simd::{avx2_supported, Avx2};

    fn guard() -> bool {
        if avx2_supported() {
            true
        } else {
            eprintln!("skipping avx2 affine test: host lacks avx2+fma");
            false
        }
    }

    #[test]
    fn prop_avx2_affine_matches_portable_and_oracle() {
        // AVX2 affine-α fold vs the portable fold and the COO oracle,
        // on random ragged square-loss blocks × {L1, L2} × {Fixed,
        // AdaGrad}: ≤1e-5 relative per sweep (FMA contraction in the
        // coefficient lanes and w side is the only divergence — the α
        // fold itself stays scalar f64 in `alpha_chunk_affine`).
        if !guard() {
            return;
        }
        prop::check("avx2 vs portable affine α", 40, |g| {
            let ds = random_regression_dataset(g);
            let p = g.usize_in(1, 2.min(ds.m()).min(ds.d()));
            let rp = Partition::even(ds.m(), p);
            let cp = Partition::even(ds.d(), p);
            let om = PackedBlocks::build(&ds.x, &rp, &cp);
            let reg = Regularizer::from(*g.pick(&[RegKind::L2, RegKind::L1]));
            let eta = g.f64_in(0.05, 0.5);
            let rule = if g.bool() { StepRule::Fixed(eta) } else { StepRule::AdaGrad(eta) };
            let lambda = *g.pick(&[1e-2, 1e-3, 1e-4]);
            let q = g.usize_in(0, p - 1);
            let r = g.usize_in(0, p - 1);
            let run = |kernel: fn(&PackedBlock, &PackedCtx, &mut PackedState) -> usize| {
                packed_trajectory(
                    kernel,
                    om.block(q, r),
                    &ds,
                    &om,
                    q,
                    r,
                    Loss::Square,
                    reg,
                    lambda,
                    rule,
                    1,
                )
            };
            let (aw, _, aa, _) = run(sweep_lanes_affine_with::<Avx2>);
            let (pw, _, pa, _) = run(sweep_lanes_affine);
            for k in 0..aw.len() {
                prop::assert_close(pw[k] as f64, aw[k] as f64, 1e-5, &format!("w[{k}]"))?;
            }
            for k in 0..aa.len() {
                prop::assert_close(pa[k] as f64, aa[k] as f64, 1e-5, &format!("alpha[{k}]"))?;
            }
            let (rw, ra) = oracle_trajectory(&ds, &om, q, r, reg, lambda, rule, 1);
            for k in 0..rw.len() {
                prop::assert_close(rw[k] as f64, aw[k] as f64, 1e-5, &format!("oracle w[{k}]"))?;
            }
            for k in 0..ra.len() {
                prop::assert_close(ra[k] as f64, aa[k] as f64, 1e-5, &format!("oracle a[{k}]"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn avx2_affine_entry_point_is_avx2_lane_kernel_for_nonaffine_losses() {
        // The non-affine degrade contract holds per backend: calling
        // the AVX2 affine entry point with hinge/logistic is bitwise
        // the AVX2 plain lane kernel (same backend, same chunks).
        if !guard() {
            return;
        }
        let ds = SparseSpec {
            name: "avx2-nonaffine".into(),
            m: 40,
            d: 32,
            nnz_per_row: 12.0,
            zipf_s: 0.3,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 81,
        }
        .generate();
        let rp = Partition::even(ds.m(), 1);
        let cp = Partition::even(ds.d(), 1);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        assert!(om.block(0, 0).has_lanes());
        for loss in [Loss::Hinge, Loss::Logistic] {
            for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                let affine = packed_trajectory(
                    sweep_lanes_affine_with::<Avx2>,
                    om.block(0, 0),
                    &ds,
                    &om,
                    0,
                    0,
                    loss,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    3,
                );
                let plain = packed_trajectory(
                    sweep_lanes_with::<Avx2>,
                    om.block(0, 0),
                    &ds,
                    &om,
                    0,
                    0,
                    loss,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    3,
                );
                assert_eq!(affine, plain, "{loss:?} {rule:?}");
            }
        }
    }

    #[test]
    fn engine_avx2_affine_dispatch_threaded_equals_replay() {
        // Lemma-2 bit-identity on the AVX2 affine path: square loss,
        // dense rows (lane dispatch), `--simd avx2`, threaded vs
        // serial replay bitwise equal.
        if !guard() {
            return;
        }
        let ds = {
            let mut d = SparseSpec {
                name: "avx2-affine-engine".into(),
                m: 120,
                d: 40,
                nnz_per_row: 18.0,
                zipf_s: 0.4,
                label_noise: 0.0,
                pos_frac: 0.5,
                seed: 91,
            }
            .generate();
            for (i, yv) in d.y.iter_mut().enumerate() {
                *yv = ((i % 7) as f32 - 3.0) * 0.5;
            }
            d
        };
        let mut c = TrainConfig::default();
        c.optim.epochs = 3;
        c.optim.eta0 = 0.2;
        c.optim.step = StepKind::AdaGrad;
        c.model.loss = LossKind::Square;
        c.model.lambda = 1e-3;
        c.cluster.machines = 2;
        c.cluster.cores = 1;
        c.cluster.simd = SimdKind::Avx2;
        c.monitor.every = 0;
        let threaded = dso::coordinator::train_dso(&c, &ds, None).unwrap();
        let replayed = dso::coordinator::run_replay(&c, &ds, None).unwrap();
        assert_eq!(threaded.w, replayed.w);
        assert_eq!(threaded.alpha, replayed.alpha);
        assert_eq!(threaded.total_updates, replayed.total_updates);
    }
}

// ---------------------------------------------------------------------
// AVX-512 paired backend on the affine-α path: 16-wide coefficient
// lanes split into two sequential 8-wide serial α folds (bitwise the
// unpaired recurrence), w side fully 16-wide. Same guard discipline as
// the avx2 module; the machine-independent pair-loop logic is pinned
// by PairedPortable inside coordinator::updates.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx512_backend {
    use super::*;
    use dso::config::SimdKind;
    use dso::coordinator::updates::{sweep_lanes_affine_with, sweep_lanes_with};
    use dso::simd::{avx512_supported, Avx2, Avx512};

    fn guard() -> bool {
        if avx512_supported() {
            true
        } else {
            eprintln!("skipping avx512 affine test: host lacks avx512f+avx2+fma");
            false
        }
    }

    #[test]
    fn prop_avx512_affine_matches_portable_and_oracle() {
        // AVX-512 affine-α fold vs the portable fold and the COO
        // oracle, on random ragged square-loss blocks × {L1, L2} ×
        // {Fixed, AdaGrad}: ≤1e-5 relative per sweep. The α fold stays
        // scalar f64 even on the pair path (two sequential 8-wide
        // folds), so only the w side widens.
        if !guard() {
            return;
        }
        prop::check("avx512 vs portable affine α", 40, |g| {
            let ds = random_regression_dataset(g);
            let p = g.usize_in(1, 2.min(ds.m()).min(ds.d()));
            let rp = Partition::even(ds.m(), p);
            let cp = Partition::even(ds.d(), p);
            let om = PackedBlocks::build(&ds.x, &rp, &cp);
            let reg = Regularizer::from(*g.pick(&[RegKind::L2, RegKind::L1]));
            let eta = g.f64_in(0.05, 0.5);
            let rule = if g.bool() { StepRule::Fixed(eta) } else { StepRule::AdaGrad(eta) };
            let lambda = *g.pick(&[1e-2, 1e-3, 1e-4]);
            let q = g.usize_in(0, p - 1);
            let r = g.usize_in(0, p - 1);
            let run = |kernel: fn(&PackedBlock, &PackedCtx, &mut PackedState) -> usize| {
                packed_trajectory(
                    kernel,
                    om.block(q, r),
                    &ds,
                    &om,
                    q,
                    r,
                    Loss::Square,
                    reg,
                    lambda,
                    rule,
                    1,
                )
            };
            let (aw, _, aa, _) = run(sweep_lanes_affine_with::<Avx512>);
            let (pw, _, pa, _) = run(sweep_lanes_affine);
            for k in 0..aw.len() {
                prop::assert_close(pw[k] as f64, aw[k] as f64, 1e-5, &format!("w[{k}]"))?;
            }
            for k in 0..aa.len() {
                prop::assert_close(pa[k] as f64, aa[k] as f64, 1e-5, &format!("alpha[{k}]"))?;
            }
            let (rw, ra) = oracle_trajectory(&ds, &om, q, r, reg, lambda, rule, 1);
            for k in 0..rw.len() {
                prop::assert_close(rw[k] as f64, aw[k] as f64, 1e-5, &format!("oracle w[{k}]"))?;
            }
            for k in 0..ra.len() {
                prop::assert_close(ra[k] as f64, aa[k] as f64, 1e-5, &format!("oracle a[{k}]"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn avx512_affine_sweep_is_bitwise_avx2() {
        // The pair ops round per-lane exactly like the 256-bit ops and
        // the α fold order is unchanged, so the affine AVX-512 sweep is
        // bitwise the AVX2 sweep on the same block — pairs, odd
        // trailing chunks and ragged tails included.
        if !guard() {
            return;
        }
        let ds = {
            let mut d = SparseSpec {
                name: "avx512-affine-pairs".into(),
                m: 60,
                d: 44,
                nnz_per_row: 21.0,
                zipf_s: 0.4,
                label_noise: 0.0,
                pos_frac: 0.5,
                seed: 93,
            }
            .generate();
            for (i, yv) in d.y.iter_mut().enumerate() {
                *yv = ((i % 5) as f32 - 2.0) * 0.7;
            }
            d
        };
        let rp = Partition::even(ds.m(), 1);
        let cp = Partition::even(ds.d(), 1);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        assert!(om.block(0, 0).has_lanes());
        for reg in [Regularizer::L2, Regularizer::L1] {
            for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                let run = |kernel: fn(&PackedBlock, &PackedCtx, &mut PackedState) -> usize| {
                    packed_trajectory(
                        kernel,
                        om.block(0, 0),
                        &ds,
                        &om,
                        0,
                        0,
                        Loss::Square,
                        reg,
                        1e-3,
                        rule,
                        3,
                    )
                };
                assert_eq!(
                    run(sweep_lanes_affine_with::<Avx512>),
                    run(sweep_lanes_affine_with::<Avx2>),
                    "{reg:?}/{rule:?}"
                );
            }
        }
    }

    #[test]
    fn avx512_affine_entry_point_degrades_for_nonaffine_losses() {
        // The non-affine degrade contract holds per backend, pair loop
        // included: the AVX-512 affine entry with hinge/logistic is
        // bitwise the AVX-512 plain lane kernel.
        if !guard() {
            return;
        }
        let ds = SparseSpec {
            name: "avx512-nonaffine".into(),
            m: 40,
            d: 32,
            nnz_per_row: 19.0,
            zipf_s: 0.3,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 82,
        }
        .generate();
        let rp = Partition::even(ds.m(), 1);
        let cp = Partition::even(ds.d(), 1);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        assert!(om.block(0, 0).has_lanes());
        for loss in [Loss::Hinge, Loss::Logistic] {
            for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                let affine = packed_trajectory(
                    sweep_lanes_affine_with::<Avx512>,
                    om.block(0, 0),
                    &ds,
                    &om,
                    0,
                    0,
                    loss,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    3,
                );
                let plain = packed_trajectory(
                    sweep_lanes_with::<Avx512>,
                    om.block(0, 0),
                    &ds,
                    &om,
                    0,
                    0,
                    loss,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    3,
                );
                assert_eq!(affine, plain, "{loss:?} {rule:?}");
            }
        }
    }

    #[test]
    fn engine_avx512_affine_dispatch_threaded_equals_replay() {
        // Lemma-2 bit-identity on the AVX-512 affine path: square
        // loss, dense rows, `--simd avx512`, threaded vs serial replay
        // bitwise equal.
        if !guard() {
            return;
        }
        let ds = {
            let mut d = SparseSpec {
                name: "avx512-affine-engine".into(),
                m: 120,
                d: 40,
                nnz_per_row: 18.0,
                zipf_s: 0.4,
                label_noise: 0.0,
                pos_frac: 0.5,
                seed: 91,
            }
            .generate();
            for (i, yv) in d.y.iter_mut().enumerate() {
                *yv = ((i % 7) as f32 - 3.0) * 0.5;
            }
            d
        };
        let mut c = TrainConfig::default();
        c.optim.epochs = 3;
        c.optim.eta0 = 0.2;
        c.optim.step = StepKind::AdaGrad;
        c.model.loss = LossKind::Square;
        c.model.lambda = 1e-3;
        c.cluster.machines = 2;
        c.cluster.cores = 1;
        c.cluster.simd = SimdKind::Avx512;
        c.monitor.every = 0;
        let threaded = dso::coordinator::train_dso(&c, &ds, None).unwrap();
        let replayed = dso::coordinator::run_replay(&c, &ds, None).unwrap();
        assert_eq!(threaded.w, replayed.w);
        assert_eq!(threaded.alpha, replayed.alpha);
        assert_eq!(threaded.total_updates, replayed.total_updates);
    }
}
