//! Property tests for the packed sweep kernel: on random sparse blocks
//! × {Hinge, Logistic, Square} × {L1, L2} × {Fixed, AdaGrad}, the
//! packed kernel's (w, α) trajectory must match the checked scalar
//! reference path (`sweep_block`, whose math is `gradients()`) within
//! tolerance, and the serializability building blocks
//! (disjoint-updates commutation, threaded ≡ replay) must hold on the
//! packed path.
//!
//! Tolerance rationale: the packed kernel differs from the reference
//! only in (a) multiplying by precomputed reciprocals instead of
//! dividing (≤1 ulp in f64 per op) and (b) folding x/m into an f32
//! (≤2⁻²⁴ relative). A single update therefore agrees to ≪1e-5
//! relative error; repeated sweeps stay well inside 1e-4.

use dso::config::{LossKind, RegKind, StepKind, TrainConfig};
use dso::coordinator::updates::{
    sweep_block, sweep_packed, BlockState, PackedCtx, PackedState, StepRule, SweepCtx,
};
use dso::data::synth::SparseSpec;
use dso::data::Dataset;
use dso::losses::{Loss, Regularizer};
use dso::partition::{PackedBlocks, Partition};
use dso::util::prop;

fn random_dataset(g: &mut prop::Gen) -> Dataset {
    SparseSpec {
        name: "packed-prop".into(),
        m: g.usize_in(10, 120),
        d: g.usize_in(8, 80),
        nnz_per_row: g.f64_in(1.0, 8.0),
        zipf_s: g.f64_in(0.0, 1.2),
        label_noise: g.f64_in(0.0, 0.1),
        pos_frac: g.f64_in(0.2, 0.8),
        seed: g.case_seed,
    }
    .generate()
}

/// Run `sweeps` reference sweeps of block (q, r) and return the final
/// stripe-local (w, α).
#[allow(clippy::too_many_arguments)]
fn reference_trajectory(
    ds: &Dataset,
    om: &PackedBlocks,
    q: usize,
    r: usize,
    loss: Loss,
    reg: Regularizer,
    lambda: f64,
    rule: StepRule,
    sweeps: usize,
) -> (Vec<f32>, Vec<f32>) {
    let entries = om.block_entries(&ds.x, q, r);
    let ctx = SweepCtx {
        loss,
        reg,
        lambda,
        m: ds.m() as f64,
        row_counts: &om.row_counts,
        col_counts: &om.col_counts,
        y: &ds.y,
        w_bound: loss.w_bound(lambda),
        rule,
    };
    let w_off = om.col_part.bounds[r];
    let a_off = om.row_part.bounds[q];
    let mut w = vec![0.01f32; om.col_part.block_len(r)];
    let mut w_acc = vec![0f32; w.len()];
    let mut alpha: Vec<f32> = om
        .row_part
        .block(q)
        .map(|i| loss.alpha_init(ds.y[i] as f64) as f32)
        .collect();
    let mut a_acc = vec![0f32; alpha.len()];
    for _ in 0..sweeps {
        let mut st = BlockState {
            w: &mut w,
            w_acc: &mut w_acc,
            w_off,
            alpha: &mut alpha,
            a_acc: &mut a_acc,
            a_off,
        };
        sweep_block(&entries, &ctx, &mut st);
    }
    (w, alpha)
}

#[allow(clippy::too_many_arguments)]
fn packed_trajectory(
    ds: &Dataset,
    om: &PackedBlocks,
    q: usize,
    r: usize,
    loss: Loss,
    reg: Regularizer,
    lambda: f64,
    rule: StepRule,
    sweeps: usize,
) -> (Vec<f32>, Vec<f32>) {
    let y_local = om.stripe_labels(&ds.y);
    let alpha_bias = om.stripe_alpha_bias(&ds.y);
    let ctx = PackedCtx {
        loss,
        reg,
        lambda,
        w_bound: loss.w_bound(lambda),
        rule,
        inv_col: &om.inv_col[r],
        inv_col32: &om.inv_col32[r],
        inv_row: &om.inv_row[q],
        y: &y_local[q],
        alpha_bias32: &alpha_bias[q],
    };
    let block = om.block(q, r);
    let mut w = vec![0.01f32; om.col_part.block_len(r)];
    let mut w_acc = vec![0f32; w.len()];
    let mut alpha: Vec<f32> = om
        .row_part
        .block(q)
        .map(|i| loss.alpha_init(ds.y[i] as f64) as f32)
        .collect();
    let mut a_acc = vec![0f32; alpha.len()];
    for _ in 0..sweeps {
        let mut st = PackedState {
            w: &mut w,
            w_acc: &mut w_acc,
            alpha: &mut alpha,
            a_acc: &mut a_acc,
        };
        sweep_packed(block, &ctx, &mut st);
    }
    (w, alpha)
}

#[test]
fn prop_packed_matches_reference_across_losses_regs_rules() {
    prop::check("packed kernel vs scalar oracle", 40, |g| {
        let ds = random_dataset(g);
        let p = g.usize_in(1, 4.min(ds.m()).min(ds.d()));
        let rp = Partition::even(ds.m(), p);
        let cp = Partition::even(ds.d(), p);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        om.validate(&ds.x).map_err(|e| e)?;

        let loss = Loss::from(*g.pick(&[LossKind::Hinge, LossKind::Logistic, LossKind::Square]));
        let reg = Regularizer::from(*g.pick(&[RegKind::L2, RegKind::L1]));
        let eta = g.f64_in(0.05, 0.5);
        let rule = if g.bool() { StepRule::Fixed(eta) } else { StepRule::AdaGrad(eta) };
        let lambda = *g.pick(&[1e-2, 1e-3, 1e-4]);
        let q = g.usize_in(0, p - 1);
        let r = g.usize_in(0, p - 1);
        let sweeps = g.usize_in(1, 3);

        let (rw, ra) = reference_trajectory(&ds, &om, q, r, loss, reg, lambda, rule, sweeps);
        let (pw, pa) = packed_trajectory(&ds, &om, q, r, loss, reg, lambda, rule, sweeps);
        for k in 0..rw.len() {
            prop::assert_close(rw[k] as f64, pw[k] as f64, 1e-4, &format!("w[{k}]"))?;
        }
        for k in 0..ra.len() {
            prop::assert_close(ra[k] as f64, pa[k] as f64, 1e-4, &format!("alpha[{k}]"))?;
        }
        Ok(())
    });
}

#[test]
fn single_sweep_matches_reference_to_1e5() {
    // The headline contract: one packed sweep of a real block agrees
    // with the reference update to ≤1e-5 relative error, for every
    // loss × reg × rule combination.
    let ds = SparseSpec {
        name: "contract".into(),
        m: 200,
        d: 80,
        nnz_per_row: 6.0,
        zipf_s: 0.8,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed: 42,
    }
    .generate();
    let p = 2;
    let rp = Partition::even(ds.m(), p);
    let cp = Partition::even(ds.d(), p);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
        for reg in [Regularizer::L2, Regularizer::L1] {
            for rule in [StepRule::Fixed(0.2), StepRule::AdaGrad(0.2)] {
                for (q, r) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let (rw, ra) =
                        reference_trajectory(&ds, &om, q, r, loss, reg, 1e-3, rule, 1);
                    let (pw, pa) =
                        packed_trajectory(&ds, &om, q, r, loss, reg, 1e-3, rule, 1);
                    for k in 0..rw.len() {
                        let rel = (rw[k] - pw[k]).abs() as f64
                            / (rw[k].abs() as f64).max(1e-3);
                        assert!(
                            rel <= 1e-5,
                            "{loss:?}/{reg:?}/{rule:?} block ({q},{r}) w[{k}]: {} vs {}",
                            rw[k],
                            pw[k]
                        );
                    }
                    for k in 0..ra.len() {
                        let rel = (ra[k] - pa[k]).abs() as f64
                            / (ra[k].abs() as f64).max(1e-3);
                        assert!(
                            rel <= 1e-5,
                            "{loss:?}/{reg:?}/{rule:?} block ({q},{r}) alpha[{k}]: {} vs {}",
                            ra[k],
                            pa[k]
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_packed_disjoint_blocks_commute() {
    // Section 3's key observation on the packed path: sweeping blocks
    // whose row and column stripes are disjoint commutes exactly —
    // each sweep touches only its own stripe's state.
    prop::check("packed disjoint blocks commute", 20, |g| {
        let ds = random_dataset(g);
        let p = g.usize_in(2, 3.min(ds.m()).min(ds.d()));
        if p < 2 {
            return Ok(());
        }
        let rp = Partition::even(ds.m(), p);
        let cp = Partition::even(ds.d(), p);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        let y_local = om.stripe_labels(&ds.y);
        let alpha_bias = om.stripe_alpha_bias(&ds.y);
        let rule = StepRule::AdaGrad(0.3);
        let lambda = 1e-3;
        let loss = Loss::Hinge;

        // Fresh state for both stripes (q=0 uses block (0,0); q=1 uses
        // block (1,1) — row- and column-disjoint as in the diagonal
        // schedule).
        let run = |order: [usize; 2]| {
            let mut w0 = vec![0.01f32; om.col_part.block_len(0)];
            let mut w1 = vec![0.01f32; om.col_part.block_len(1)];
            let mut wa0 = vec![0f32; w0.len()];
            let mut wa1 = vec![0f32; w1.len()];
            let mut al0 = vec![0f32; om.row_part.block_len(0)];
            let mut al1 = vec![0f32; om.row_part.block_len(1)];
            let mut aa0 = vec![0f32; al0.len()];
            let mut aa1 = vec![0f32; al1.len()];
            for &q in &order {
                let ctx = PackedCtx {
                    loss,
                    reg: Regularizer::L2,
                    lambda,
                    w_bound: loss.w_bound(lambda),
                    rule,
                    inv_col: &om.inv_col[q],
                    inv_col32: &om.inv_col32[q],
                    inv_row: &om.inv_row[q],
                    y: &y_local[q],
                    alpha_bias32: &alpha_bias[q],
                };
                let mut st = if q == 0 {
                    PackedState {
                        w: &mut w0,
                        w_acc: &mut wa0,
                        alpha: &mut al0,
                        a_acc: &mut aa0,
                    }
                } else {
                    PackedState {
                        w: &mut w1,
                        w_acc: &mut wa1,
                        alpha: &mut al1,
                        a_acc: &mut aa1,
                    }
                };
                sweep_packed(om.block(q, q), &ctx, &mut st);
            }
            (w0, w1, al0, al1, wa0, wa1, aa0, aa1)
        };
        let a = run([0, 1]);
        let b = run([1, 0]);
        prop::assert_that(a == b, "disjoint block sweeps do not commute")
    });
}

#[test]
fn engine_bit_identity_survives_packed_path() {
    // End-to-end restatement of the Lemma-2 contract on the new
    // kernels: threaded engine ≡ serial replay, bit for bit.
    let ds = SparseSpec {
        name: "bit-id".into(),
        m: 180,
        d: 64,
        nnz_per_row: 5.0,
        zipf_s: 0.7,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed: 7,
    }
    .generate();
    for (step, upb) in [(StepKind::AdaGrad, 0), (StepKind::InvSqrt, 0), (StepKind::AdaGrad, 6)]
    {
        let mut c = TrainConfig::default();
        c.optim.epochs = 3;
        c.optim.eta0 = 0.3;
        c.optim.step = step;
        c.model.lambda = 1e-3;
        c.cluster.machines = 4;
        c.cluster.cores = 1;
        c.cluster.updates_per_block = upb;
        c.monitor.every = 0;
        let threaded = dso::coordinator::train_dso(&c, &ds, None).unwrap();
        let replayed = dso::coordinator::run_replay(&c, &ds, None).unwrap();
        assert_eq!(threaded.w, replayed.w, "step {step:?} upb {upb}");
        assert_eq!(threaded.alpha, replayed.alpha, "step {step:?} upb {upb}");
        assert_eq!(threaded.total_updates, replayed.total_updates);
    }
}
