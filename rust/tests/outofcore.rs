//! Out-of-core cache integration (DESIGN.md §Out-of-core).
//!
//! The contract under test: a `--cache use` run — packed blocks and
//! α-bias tables mmap'd from a `.dsoblk` file, payload demand-paged,
//! prefetch driven by the sweep schedule — produces **bit-identical**
//! `(w, α)` to the all-resident run, on both the synchronous scalar
//! engine and the asynchronous ring; a cache packed under a different
//! configuration is refused the same way a foreign checkpoint is; and
//! the pack/open round trip preserves every table, including the
//! 64-byte alignment the lane kernels require.

use dso::api::Trainer;
use dso::config::{Algorithm, CacheMode, TrainConfig};
use dso::coordinator::DsoSetup;
use dso::data::cache;
use dso::data::synth::SparseSpec;
use dso::data::Dataset;
use dso::partition::{PackedBlocks, Partition};
use dso::simd::is_aligned;
use std::path::PathBuf;

fn dataset(m: usize, d: usize, seed: u64) -> Dataset {
    SparseSpec {
        name: "outofcore-test".into(),
        m,
        d,
        nnz_per_row: 8.0,
        zipf_s: 0.8,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed,
    }
    .generate()
}

fn base_cfg(p: usize, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.optim.algorithm = Algorithm::Dso;
    cfg.optim.epochs = epochs;
    cfg.optim.eta0 = 0.5;
    cfg.optim.seed = 7;
    cfg.model.lambda = 1e-3;
    cfg.cluster.machines = p;
    cfg.cluster.cores = 1;
    cfg.monitor.every = 0;
    cfg
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dso-outofcore-{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

/// pack → open preserves every table bit-for-bit: partitions, counts,
/// reciprocal tables, α-bias, labels, every block's group/cols/vals
/// regions, and the sampling side tables — with the mapped f32/u32
/// tables landing on 64-byte boundaries (the §Alignment contract holds
/// for views into the file, not just owned buffers).
#[test]
fn cache_roundtrip_preserves_every_table() {
    let ds = dataset(120, 72, 3);
    let p = 3;
    let rp = Partition::even(ds.m(), p);
    let cp = Partition::even(ds.d(), p);
    let om = PackedBlocks::build(&ds.x, &rp, &cp).with_sampling_tables();
    let bias: Vec<dso::data::BlockStore<f32>> =
        om.stripe_alpha_bias(&ds.y).into_iter().map(Into::into).collect();
    let dir = temp_dir("roundtrip");
    let path = cache::cache_path(&dir, &ds.name);
    cache::pack(&path, &om, &bias, &ds.y, 0xA11C_E55E).unwrap();
    let opened = cache::open(&path).unwrap();
    assert_eq!(opened.config_fp, 0xA11C_E55E);
    assert_eq!((opened.m, opened.d, opened.p), (ds.m(), ds.d(), p));
    assert_bits_eq(&opened.y, &ds.y, "y");
    assert_eq!(opened.omega.row_part.bounds, om.row_part.bounds);
    assert_eq!(opened.omega.col_part.bounds, om.col_part.bounds);
    assert_eq!(opened.omega.row_counts, om.row_counts);
    assert_eq!(opened.omega.col_counts, om.col_counts);
    for r in 0..p {
        assert_eq!(opened.omega.inv_col[r], om.inv_col[r], "inv_col[{r}]");
        assert_eq!(opened.omega.inv_col32[r], om.inv_col32[r], "inv_col32[{r}]");
        assert!(is_aligned(&opened.omega.inv_col32[r][..]), "inv_col32[{r}] alignment");
    }
    for q in 0..p {
        assert_eq!(opened.omega.inv_row[q], om.inv_row[q], "inv_row[{q}]");
        assert_eq!(opened.alpha_bias[q], bias[q], "alpha_bias[{q}]");
        assert!(is_aligned(&opened.alpha_bias[q][..]), "alpha_bias[{q}] alignment");
    }
    for (i, (a, b)) in opened.omega.blocks.iter().zip(&om.blocks).enumerate() {
        assert_eq!(a.groups, b.groups, "block {i} groups");
        assert_eq!(a.cols, b.cols, "block {i} cols");
        assert_eq!(a.vals, b.vals, "block {i} vals");
        assert_eq!(a.entry_group, b.entry_group, "block {i} entry_group");
        assert_eq!(a.lane_groups, b.lane_groups, "block {i} lane_groups");
        assert_eq!(a.n_rows, b.n_rows, "block {i} n_rows");
        assert!(is_aligned(&a.cols[..]), "block {i} cols alignment");
        assert!(is_aligned(&a.vals[..]), "block {i} vals alignment");
    }
    // The reconstruction passes the same structural validation the
    // builder output does.
    opened.omega.validate(&ds.x).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// `--cache use` is bit-identical to the resident run on the threaded
/// synchronous engine, and the setup it trains from really is mapped.
#[test]
fn mapped_fit_matches_resident_bitwise_sync() {
    let ds = dataset(160, 64, 11);
    let cfg = base_cfg(2, 4);
    let dir = temp_dir("sync");
    let dir_s = dir.to_str().unwrap();

    let resident = Trainer::new(cfg.clone()).fit(&ds, None).unwrap();
    // Build mode trains resident too (it packs, then runs in memory).
    let built = Trainer::new(cfg.clone())
        .cache(CacheMode::Build)
        .cache_dir(dir_s)
        .fit(&ds, None)
        .unwrap();
    assert_bits_eq(&resident.result.w, &built.result.w, "build w");
    assert_bits_eq(&resident.result.alpha, &built.result.alpha, "build alpha");

    // The `use` setup is genuinely out-of-core on unix (resident
    // fallback elsewhere), and its packed geometry is validated.
    let mut cfg_use = cfg.clone();
    cfg_use.cluster.cache = CacheMode::Use;
    cfg_use.cluster.cache_dir = dir_s.to_string();
    let setup = DsoSetup::with_cache(&cfg_use, &ds).unwrap();
    #[cfg(unix)]
    {
        assert!(setup.omega.blocks.iter().all(|b| b.cols.is_mapped() && b.vals.is_mapped()));
        assert!(setup.alpha_bias.iter().all(|s| s.is_mapped()));
        assert!(setup.cache.is_active(), "prefetch handle inert on a mapped run");
    }
    assert_eq!(setup.p, 2);

    let mapped = Trainer::new(cfg_use).fit(&ds, None).unwrap();
    assert_bits_eq(&resident.result.w, &mapped.result.w, "mapped w");
    assert_bits_eq(&resident.result.alpha, &mapped.result.alpha, "mapped alpha");
    assert_eq!(resident.result.total_updates, mapped.result.total_updates);
    std::fs::remove_dir_all(&dir).ok();
}

/// Same bit-identity on the asynchronous ring. p = 1 pins the async
/// visit order (a single worker drains its own queue deterministically),
/// so the mapped/resident comparison is exact rather than statistical.
#[test]
fn mapped_fit_matches_resident_bitwise_async() {
    let ds = dataset(120, 48, 13);
    let mut cfg = base_cfg(1, 3);
    cfg.optim.algorithm = Algorithm::DsoAsync;
    let dir = temp_dir("async");
    let dir_s = dir.to_str().unwrap();

    let resident = Trainer::new(cfg.clone()).fit(&ds, None).unwrap();
    Trainer::new(cfg.clone())
        .cache(CacheMode::Build)
        .cache_dir(dir_s)
        .fit(&ds, None)
        .unwrap();
    let mapped = Trainer::new(cfg.clone())
        .cache(CacheMode::Use)
        .cache_dir(dir_s)
        .fit(&ds, None)
        .unwrap();
    assert_bits_eq(&resident.result.w, &mapped.result.w, "async mapped w");
    assert_bits_eq(&resident.result.alpha, &mapped.result.alpha, "async mapped alpha");
    std::fs::remove_dir_all(&dir).ok();
}

/// A cache packed under a different configuration (here: a different
/// optimizer seed, which changes the sampling streams) is refused with
/// both fingerprints named — the same contract as checkpoint resume
/// and the proc-worker handshake.
#[test]
fn foreign_fingerprint_cache_is_refused() {
    let ds = dataset(100, 40, 17);
    let cfg = base_cfg(2, 2);
    let dir = temp_dir("foreign");
    let dir_s = dir.to_str().unwrap();
    Trainer::new(cfg.clone())
        .cache(CacheMode::Build)
        .cache_dir(dir_s)
        .fit(&ds, None)
        .unwrap();
    let mut foreign = cfg.clone();
    foreign.optim.seed = cfg.optim.seed + 1;
    let err = Trainer::new(foreign)
        .cache(CacheMode::Use)
        .cache_dir(dir_s)
        .fit(&ds, None)
        .err()
        .expect("foreign-fingerprint cache must be refused");
    let msg = format!("{err}");
    assert!(msg.contains("different run"), "{msg}");
    // `use` against a missing cache is an error, not a silent rebuild.
    std::fs::remove_dir_all(&dir).ok();
    let err = Trainer::new(cfg)
        .cache(CacheMode::Use)
        .cache_dir(dir_s)
        .fit(&ds, None)
        .err()
        .expect("use mode with no cache on disk must error");
    assert!(!format!("{err}").is_empty());
}

/// Auto mode: first run packs (file appears), second run reuses the
/// same bytes (no rewrite) and stays bit-identical; a fingerprint
/// mismatch under auto falls back to a rebuild instead of refusing.
#[test]
fn auto_cache_builds_then_reuses() {
    let ds = dataset(110, 44, 19);
    let cfg = base_cfg(2, 3);
    let dir = temp_dir("auto");
    let dir_s = dir.to_str().unwrap();
    let path = cache::cache_path(&dir, &ds.name);

    let first = Trainer::new(cfg.clone())
        .cache(CacheMode::Auto)
        .cache_dir(dir_s)
        .fit(&ds, None)
        .unwrap();
    assert!(path.exists(), "auto's first run must leave a cache behind");
    let bytes_after_build = std::fs::read(&path).unwrap();

    let second = Trainer::new(cfg.clone())
        .cache(CacheMode::Auto)
        .cache_dir(dir_s)
        .fit(&ds, None)
        .unwrap();
    assert_bits_eq(&first.result.w, &second.result.w, "auto reuse w");
    assert_bits_eq(&first.result.alpha, &second.result.alpha, "auto reuse alpha");
    assert_eq!(
        std::fs::read(&path).unwrap(),
        bytes_after_build,
        "auto reuse must not rewrite the cache"
    );

    // A config change makes the cache foreign; auto rebuilds in place.
    let mut other = cfg.clone();
    other.optim.seed = cfg.optim.seed + 1;
    Trainer::new(other)
        .cache(CacheMode::Auto)
        .cache_dir(dir_s)
        .fit(&ds, None)
        .unwrap();
    assert_ne!(
        std::fs::read(&path).unwrap(),
        bytes_after_build,
        "a foreign cache under auto must be repacked"
    );
    std::fs::remove_dir_all(&dir).ok();
}
