//! Transport chaos suite: the multi-process DSO ring (`--mode
//! dso-proc`) under real process kills, injected deaths, link
//! partitions, and stragglers — plus the recorded-schedule replay that
//! pins Lemma 2 across the process boundary.
//!
//! Every test spawns real worker processes over a Unix-domain socket,
//! using this crate's own `dso` binary (`CARGO_BIN_EXE_dso`) as the
//! worker executable. Runs are serialized behind one mutex: process
//! spawn + socket churn from concurrent rings makes timeouts flaky,
//! and the fingerprint-skew test mutates the process environment.

use dso::api::Trainer;
use dso::config::{Algorithm, ExecMode, TrainConfig};
use dso::coordinator::TrainResult;
use dso::data::synth::SparseSpec;
use dso::data::Dataset;
use std::sync::Mutex;

/// All proc-mode tests run one at a time (see module docs).
static PROC_LOCK: Mutex<()> = Mutex::new(());

fn dataset(seed: u64) -> Dataset {
    SparseSpec {
        name: "transport-chaos".into(),
        m: 240,
        d: 60,
        nnz_per_row: 6.0,
        zipf_s: 0.7,
        label_noise: 0.03,
        pos_frac: 0.5,
        seed,
    }
    .generate()
}

fn cfg(p: usize, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.optim.algorithm = Algorithm::DsoAsync;
    cfg.optim.epochs = epochs;
    cfg.optim.eta0 = 0.2;
    cfg.optim.seed = 7;
    cfg.model.lambda = 1e-3;
    cfg.cluster.machines = p;
    cfg.cluster.cores = 1;
    cfg.cluster.mode = ExecMode::Proc;
    // Tight enough that death tests finish fast, loose enough that a
    // loaded CI box doesn't false-positive the hung-worker detector.
    cfg.cluster.heartbeat_ms = 25;
    cfg.cluster.death_timeout_ms = 1000;
    cfg
}

fn run(cfg: TrainConfig, ds: &Dataset) -> anyhow::Result<TrainResult> {
    Ok(Trainer::new(cfg)
        .worker_bin(env!("CARGO_BIN_EXE_dso"))
        .fit(ds, None)?
        .into_result())
}

fn assert_recovered_shape(r: &TrainResult, ds: &Dataset, label: &str) {
    assert_eq!(r.algorithm, "dso-proc", "{label}: wrong engine routed");
    assert_eq!(r.w.len(), ds.d(), "{label}: w not fully recovered");
    assert_eq!(r.alpha.len(), ds.m(), "{label}: alpha not fully recovered");
    assert!(r.final_primal.is_finite(), "{label}: non-finite objective");
}

/// The clean multi-process run is a working solver: it converges into
/// the same basin as the in-thread async ring (the differential
/// oracle), moves real bytes, and reports wall-clock time axes.
#[test]
fn proc_clean_run_matches_thread_ring_band() {
    let _g = PROC_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let ds = dataset(3);
    let r = run(cfg(4, 2), &ds).expect("clean proc run");
    assert_recovered_shape(&r, &ds, "clean");
    assert!(r.failures.is_empty(), "clean run reported failures: {:?}", r.failures);
    assert!(r.comm_bytes > 0, "real transport must count real bytes");
    assert!(r.total_updates > 0);
    // Real transport: virtual time IS wall time.
    assert_eq!(r.total_virtual_s, r.total_wall_s);

    let mut thread_cfg = cfg(4, 2);
    thread_cfg.cluster.mode = ExecMode::Scalar;
    let oracle =
        Trainer::new(thread_cfg).fit(&ds, None).expect("thread ring").into_result();
    let rel =
        (r.final_primal - oracle.final_primal).abs() / oracle.final_primal.abs().max(1e-12);
    assert!(
        rel < 0.5,
        "proc {} vs thread async {} (rel {rel})",
        r.final_primal,
        oracle.final_primal
    );
}

/// `kill@w.e.i` delivers a real SIGKILL at the fault-clock coordinate;
/// the degraded ring still converges inside the objective band of the
/// fault-free run (the ISSUE-7 acceptance gate).
#[test]
fn proc_sigkill_degrades_and_converges_in_band() {
    let _g = PROC_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let ds = dataset(3);
    let clean = run(cfg(4, 2), &ds).expect("fault-free proc run");

    let mut faulted_cfg = cfg(4, 2);
    faulted_cfg.cluster.faults = "kill@1.0.2".into();
    let r = run(faulted_cfg, &ds).expect("SIGKILLed proc run");
    assert_recovered_shape(&r, &ds, "kill@1.0.2");
    assert_eq!(r.failures.len(), 1, "exactly the injected kill: {:?}", r.failures);
    let f = &r.failures[0];
    assert_eq!(f.worker, 1);
    assert!(f.reason.contains("injected kill"), "reason: {}", f.reason);
    assert!(f.stripes_reassigned >= 1, "dead worker's stripes must move");
    // The failure surfaces in the history row too.
    assert_eq!(r.history.col("failures").unwrap(), vec![1.0]);

    let rel =
        (r.final_primal - clean.final_primal).abs() / clean.final_primal.abs().max(1e-12);
    assert!(
        rel < 0.5,
        "killed {} vs clean {} (rel {rel})",
        r.final_primal,
        clean.final_primal
    );
}

/// `die@` exits the worker gracefully (Bye); the supervisor reassigns
/// its stripes and the run completes with the same reason string the
/// thread ring reports.
#[test]
fn proc_injected_death_recovers_gracefully() {
    let _g = PROC_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let ds = dataset(3);
    let mut c = cfg(4, 2);
    c.cluster.faults = "die@2.0.1".into();
    let r = run(c, &ds).expect("die@ proc run");
    assert_recovered_shape(&r, &ds, "die@2.0.1");
    assert_eq!(r.failures.len(), 1);
    assert_eq!(r.failures[0].worker, 2);
    assert_eq!(r.failures[0].reason, "injected death");
}

/// `partition@w.e.i:ms` severs the link, waits, reconnects with
/// backoff, and resends unacked frames — inside the death timeout this
/// is a survivable fault: zero failures, full completion. A stall
/// (straggler) under the timeout is equally survivable.
#[test]
fn proc_partition_reconnects_and_stragglers_survive() {
    let _g = PROC_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let ds = dataset(3);
    let mut c = cfg(3, 2);
    c.cluster.faults = "partition@0.0.1:80,stall@1.0.0:60".into();
    let r = run(c, &ds).expect("partition proc run");
    assert_recovered_shape(&r, &ds, "partition+stall");
    assert!(
        r.failures.is_empty(),
        "a sub-timeout partition must not kill the worker: {:?}",
        r.failures
    );
    // The supervisor accrues bounded-wait time while the ring idles.
    let wait = r.history.col("wait_s").expect("wait_s column missing");
    assert!(wait.last().unwrap().is_finite());
}

/// The tentpole guarantee: a *faulted* multi-process run's recorded
/// schedule, re-executed serially, reproduces the reassembled (w, α)
/// bit for bit — Lemma-2 serializability certified across real
/// sockets, real SIGKILL, and ring degradation.
#[test]
fn proc_recorded_schedule_replays_bit_identically() {
    let _g = PROC_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let ds = dataset(3);
    let sched = std::env::temp_dir().join("dso-transport-replay.sched");
    let mut c = cfg(4, 2);
    c.cluster.faults = "die@2.0.1".into();
    c.cluster.sched_out = sched.to_string_lossy().into_owned();
    let r = run(c.clone(), &ds).expect("recorded proc run");
    assert_eq!(r.failures.len(), 1);

    let text = std::fs::read_to_string(&sched).expect("schedule written");
    let parsed = dso::net::Schedule::parse(&text).expect("schedule parses");
    assert_eq!(parsed.p, 4);
    assert_eq!(parsed.deaths, 1, "the injected death must be in the log");
    assert_eq!(
        parsed.entries.iter().map(|e| e.updates).sum::<u64>(),
        r.total_updates,
        "log must account for every update"
    );

    let replayed = dso::net::replay_recorded_schedule(&c, &ds, &sched).expect("replay");
    assert_eq!(replayed.total_updates, r.total_updates, "replay update count differs");
    assert_eq!(replayed.w.len(), r.w.len());
    for (i, (a, b)) in r.w.iter().zip(&replayed.w).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "w[{i}]: run {a} vs replay {b}");
    }
    for (i, (a, b)) in r.alpha.iter().zip(&replayed.alpha).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "alpha[{i}]: run {a} vs replay {b}");
    }

    // A foreign configuration must be refused, not replayed wrong.
    let mut foreign = c.clone();
    foreign.optim.seed ^= 1;
    let err = dso::net::replay_recorded_schedule(&foreign, &ds, &sched).unwrap_err();
    assert!(format!("{err}").contains("refusing"), "{err}");
    std::fs::remove_file(&sched).ok();
}

/// A worker whose independently recomputed fingerprint disagrees with
/// the coordinator's must be refused at the handshake — the same
/// contract the checkpoint resume path enforces.
#[test]
fn proc_refuses_fingerprint_skewed_worker() {
    let _g = PROC_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let ds = dataset(3);
    std::env::set_var("DSO_PROC_FINGERPRINT_SKEW", "1");
    let result = run(cfg(2, 1), &ds);
    std::env::remove_var("DSO_PROC_FINGERPRINT_SKEW");
    let err = result.expect_err("skewed fingerprint must refuse the ring");
    assert!(format!("{err}").contains("refusing"), "{err}");
}

/// Mode routing and validation: dso-proc requires the async algorithm,
/// and the proc-only fault kinds are rejected on the thread ring.
#[test]
fn proc_mode_validation_is_actionable() {
    let ds = dataset(3);
    let mut c = cfg(2, 1);
    c.optim.algorithm = Algorithm::Dso;
    let err = Trainer::new(c).fit(&ds, None).unwrap_err();
    assert!(format!("{err}").contains("dso-async"), "{err}");

    let mut c = cfg(2, 1);
    c.cluster.mode = ExecMode::Scalar;
    c.cluster.faults = "kill@0.0.0".into();
    let err = Trainer::new(c).fit(&ds, None).unwrap_err();
    assert!(format!("{err}").contains("dso-proc"), "{err}");
}
