//! Warm-start semantics (`Trainer::fit_from`, DESIGN.md §Serving):
//!
//! * a 0-epoch warm fit is **bit-identical** to the prior — seeding is
//!   a pure state copy, with the DCD initializer superseded,
//! * the warm threaded run and the warm serial replay agree bitwise
//!   (Lemma 2 holds with a non-zero initial state),
//! * retraining on appended rows/features from a prior lands in the
//!   same objective band as a cold fit of the widened data,
//! * a prior wider than the data is refused with an actionable error,
//! * warm lineage separates checkpoint fingerprints: a warm run's
//!   checkpoint can never seed a cold resume (and vice versa).

use dso::api::Trainer;
use dso::config::{Algorithm, TrainConfig};
use dso::coordinator::checkpoint::{warm_provenance, with_provenance};
use dso::data::synth::SparseSpec;
use dso::data::{Csr, Dataset};

fn base() -> Dataset {
    SparseSpec {
        name: "warm-base".into(),
        m: 260,
        d: 60,
        nnz_per_row: 6.0,
        zipf_s: 0.7,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed: 11,
    }
    .generate()
}

/// `base` plus `extra_rows` appended rows touching `extra_d` new
/// feature columns — the serving-loop growth case `fit_from` exists
/// for.
fn widened(base: &Dataset, extra_rows: usize, extra_d: usize) -> Dataset {
    let d = base.d() + extra_d;
    let mut rows: Vec<Vec<(u32, f32)>> = (0..base.m())
        .map(|i| {
            let (c, v) = base.x.row(i);
            c.iter().copied().zip(v.iter().copied()).collect()
        })
        .collect();
    let mut y = base.y.clone();
    for r in 0..extra_rows {
        let mut row: Vec<(u32, f32)> = (0..5)
            .map(|k| (((r * 7 + k * 13) % d) as u32, 0.3 * (k as f32 + 1.0) - 0.6))
            .collect();
        row.sort_by_key(|e| e.0);
        row.dedup_by_key(|e| e.0);
        rows.push(row);
        y.push(if r % 2 == 0 { 1.0 } else { -1.0 });
    }
    Dataset::new("warm-widened", Csr::from_rows(d, rows), y)
}

fn cfg(epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.optim.epochs = epochs;
    cfg.optim.eta0 = 0.2;
    cfg.optim.seed = 7;
    cfg.model.lambda = 1e-3;
    cfg.cluster.machines = 2;
    cfg.cluster.cores = 1;
    cfg.monitor.every = 0;
    cfg
}

#[test]
fn zero_epoch_fit_from_is_bit_identical_to_prior() {
    let ds = base();
    let prior = Trainer::new(cfg(8)).fit(&ds, None).unwrap();
    // epochs = 0 is the degenerate warm fit: seed, run nothing, return.
    // (Plain `fit` rejects epochs = 0 at validation; `fit_from` admits
    // it precisely for this state-copy identity.)
    let mut c0 = cfg(0);
    // The DCD initializer must be superseded by the prior, not added.
    c0.optim.dcd_init = true;
    let warm = Trainer::new(c0.clone()).fit_from(&prior, &ds, None).unwrap();
    assert_eq!(warm.result.w.len(), prior.result.w.len());
    for (a, b) in warm.result.w.iter().zip(&prior.result.w) {
        assert_eq!(a.to_bits(), b.to_bits(), "w must be the prior, bit for bit");
    }
    for (a, b) in warm.result.alpha.iter().zip(&prior.result.alpha) {
        assert_eq!(a.to_bits(), b.to_bits(), "alpha must be the prior, bit for bit");
    }
    // Same through the serial replay route.
    let replayed = Trainer::new(c0).replay(true).fit_from(&prior, &ds, None).unwrap();
    assert_eq!(replayed.result.w, prior.result.w);
    assert_eq!(replayed.result.alpha, prior.result.alpha);
}

#[test]
fn warm_threaded_equals_warm_replay_bitwise() {
    let ds = base();
    let wide = widened(&ds, 40, 20);
    let prior = Trainer::new(cfg(10)).fit(&ds, None).unwrap();
    let threaded = Trainer::new(cfg(4)).fit_from(&prior, &wide, None).unwrap();
    let replayed = Trainer::new(cfg(4)).replay(true).fit_from(&prior, &wide, None).unwrap();
    assert_eq!(threaded.result.w, replayed.result.w, "Lemma 2 must survive warm seeding");
    assert_eq!(threaded.result.alpha, replayed.result.alpha);
    assert_eq!(threaded.result.total_updates, replayed.result.total_updates);
}

#[test]
fn appended_rows_warm_start_stays_in_cold_objective_band() {
    let ds = base();
    let wide = widened(&ds, 40, 20);
    let prior = Trainer::new(cfg(30)).fit(&ds, None).unwrap();
    let warm = Trainer::new(cfg(20)).fit_from(&prior, &wide, None).unwrap();
    let cold = Trainer::new(cfg(40)).fit(&wide, None).unwrap();
    let (wp, cp) = (warm.result.final_primal, cold.result.final_primal);
    assert!(wp.is_finite() && cp.is_finite());
    // Both runs optimize the same convex objective; after this many
    // epochs they must agree to a few percent even though the warm run
    // spent half the epochs on the widened data.
    assert!(
        (wp - cp).abs() <= 0.05 * cp.abs().max(1e-9),
        "warm {wp} vs cold {cp} drifted out of the 5% band"
    );
}

#[test]
fn shrinking_prior_is_refused() {
    let ds = base();
    let wide = widened(&ds, 40, 20);
    let prior = Trainer::new(cfg(4)).fit(&wide, None).unwrap();
    let err = Trainer::new(cfg(4)).fit_from(&prior, &ds, None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("never shrink"), "msg: {msg}");
}

#[test]
fn fit_from_requires_the_scalar_dso_engine() {
    let ds = base();
    let prior = Trainer::new(cfg(4)).fit(&ds, None).unwrap();
    let mut c = cfg(4);
    c.optim.algorithm = Algorithm::Sgd;
    let err = Trainer::new(c).fit_from(&prior, &ds, None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fit_from") && msg.contains("algorithm = \"dso\""), "msg: {msg}");
}

#[test]
fn warm_provenance_separates_checkpoint_lineage() {
    let w = vec![0.5f32, -1.25, 0.0];
    let a = vec![0.125f32, 2.0];
    let p = warm_provenance(&w, &a);
    // Deterministic, and sensitive to every coordinate's bit pattern.
    assert_eq!(p, warm_provenance(&w, &a));
    let mut w2 = w.clone();
    w2[2] = -0.0; // same value under ==, different bits, different run
    assert_ne!(p, warm_provenance(&w2, &a));
    let mut a2 = a.clone();
    a2[0] = 0.25;
    assert_ne!(p, warm_provenance(&w, &a2));
    // Swapping a coordinate between the labeled fields must not alias.
    assert_ne!(warm_provenance(&[1.0], &[]), warm_provenance(&[], &[1.0]));
    // Warm lineage moves the run fingerprint: a warm checkpoint can
    // never be mistaken for the cold run's, nor for a warm run off a
    // different prior.
    let fp = 0x1234_5678_9abc_def0u64;
    assert_ne!(with_provenance(fp, p), fp);
    assert_ne!(with_provenance(fp, p), with_provenance(fp, warm_provenance(&w2, &a)));
}
