//! Serving subsystem acceptance (DESIGN.md §Serving):
//!
//! * the batched predict kernel is **bit-identical** to the old scalar
//!   per-row loop (`Csr::row_dot`) on the portable backend — replacing
//!   `Fitted::predict`'s internals moved zero bits,
//! * `simd::resolve(Auto)` routing yields the same bits (the f64
//!   storage-order fold is backend-invariant),
//! * AVX2 stays within the documented ≤1e-6 contract,
//! * the full server round trip over the framed transport: load →
//!   predict → warm-start retrain (`fit_from`) → hot reload → predict
//!   with the updated model → stats → shutdown, with malformed and
//!   mismatched batches answered as `ServeError` (line-numbered / with
//!   the dimension message) and a failed reload keeping the old model.

use dso::api::Trainer;
use dso::config::{SimdKind, TrainConfig};
use dso::data::synth::SparseSpec;
use dso::data::{libsvm, Dataset};
use dso::net::transport::{connect_with_backoff, ConnIn, FrameConn};
use dso::net::wire::Msg;
use dso::serve::{predict_batch, NullServeObserver, PackedRequests, ServeOptions, Server};
use dso::simd::{resolve, SimdLevel};
use std::time::Duration;

fn dataset(seed: u64) -> Dataset {
    SparseSpec {
        name: "serve".into(),
        m: 300,
        d: 80,
        nnz_per_row: 6.0,
        zipf_s: 0.7,
        label_noise: 0.03,
        pos_frac: 0.5,
        seed,
    }
    .generate()
}

fn cfg(epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.optim.epochs = epochs;
    cfg.optim.eta0 = 0.2;
    cfg.optim.seed = 7;
    cfg.model.lambda = 1e-3;
    cfg.cluster.machines = 2;
    cfg.cluster.cores = 1;
    cfg.monitor.every = 0;
    cfg
}

/// Sub-batch of `ds` rows as libsvm text — what a wire client sends.
fn batch_text(ds: &Dataset, rows: &[usize]) -> String {
    libsvm::emit(&Dataset::new(
        "batch",
        ds.x.select_rows(rows),
        rows.iter().map(|&i| ds.y[i]).collect(),
    ))
}

fn recv_msg(conn: &mut FrameConn) -> Msg {
    loop {
        match conn.recv().expect("client recv") {
            ConnIn::Msg(m) => return m,
            ConnIn::TimedOut => continue,
            other => panic!("connection dropped mid-reply: {other:?}"),
        }
    }
}

#[test]
fn batched_predict_is_bitwise_identical_to_scalar_predict() {
    let ds = dataset(3);
    let fitted = Trainer::new(cfg(6)).fit(&ds, None).unwrap();
    let w = fitted.w();
    let packed = PackedRequests::pack(&ds.x, w.len()).unwrap();
    let mut got = Vec::new();
    predict_batch(&packed, w, SimdLevel::Portable, &mut got);
    assert_eq!(got.len(), ds.m());
    // The old scalar predict was exactly one row_dot per row.
    for i in 0..ds.m() {
        assert_eq!(got[i].to_bits(), ds.x.row_dot(i, w).to_bits(), "row {i}");
    }
    // And the facade's predict (now routed through the batched kernel)
    // returns the same bits through its public surface.
    let facade = fitted.predict(&ds.x).unwrap();
    for i in 0..ds.m() {
        assert_eq!(facade[i].to_bits(), got[i].to_bits(), "facade row {i}");
    }
}

#[test]
fn auto_backend_matches_portable_bitwise() {
    let ds = dataset(5);
    let fitted = Trainer::new(cfg(4)).fit(&ds, None).unwrap();
    let w = fitted.w();
    let packed = PackedRequests::pack(&ds.x, w.len()).unwrap();
    let (mut auto, mut portable) = (Vec::new(), Vec::new());
    predict_batch(&packed, w, resolve(SimdKind::Auto), &mut auto);
    predict_batch(&packed, w, SimdLevel::Portable, &mut portable);
    // The f64 storage-order fold is backend-invariant, so whatever
    // `Auto` resolved to on this host must reproduce portable exactly.
    for i in 0..auto.len() {
        assert_eq!(auto[i].to_bits(), portable[i].to_bits(), "row {i}");
    }
}

#[cfg(target_arch = "x86_64")]
#[test]
fn avx2_batch_predict_stays_within_tolerance() {
    if !dso::simd::avx2_supported() {
        eprintln!("skipping: avx2+fma unavailable on this host");
        return;
    }
    let ds = dataset(9);
    let fitted = Trainer::new(cfg(4)).fit(&ds, None).unwrap();
    let w = fitted.w();
    let packed = PackedRequests::pack(&ds.x, w.len()).unwrap();
    let (mut a, mut p) = (Vec::new(), Vec::new());
    predict_batch(&packed, w, SimdLevel::Avx2, &mut a);
    predict_batch(&packed, w, SimdLevel::Portable, &mut p);
    for i in 0..p.len() {
        assert!(
            (a[i] - p[i]).abs() <= 1e-6 * p[i].abs().max(1.0),
            "row {i}: avx2 {} vs portable {}",
            a[i],
            p[i]
        );
    }
}

#[cfg(target_arch = "x86_64")]
#[test]
fn avx512_batch_predict_is_bitwise_portable() {
    // The 16-wide pair fold keeps the serial f64 storage-order
    // recurrence, so AVX-512 scores are bitwise portable scores — the
    // same (stronger) contract the whole serve suite pins for AVX2.
    if !dso::simd::avx512_supported() {
        eprintln!("skipping: avx512f+avx2+fma unavailable on this host");
        return;
    }
    let ds = dataset(9);
    let fitted = Trainer::new(cfg(4)).fit(&ds, None).unwrap();
    let w = fitted.w();
    let packed = PackedRequests::pack(&ds.x, w.len()).unwrap();
    let (mut a, mut p) = (Vec::new(), Vec::new());
    predict_batch(&packed, w, SimdLevel::Avx512, &mut a);
    predict_batch(&packed, w, SimdLevel::Portable, &mut p);
    for i in 0..p.len() {
        assert_eq!(a[i].to_bits(), p[i].to_bits(), "row {i}");
    }
}

#[test]
fn measured_auto_server_reports_its_selection() {
    // A server bound with `--simd auto` carries the measured report:
    // the chosen level matches the instance backend, every measurement
    // is for a host-supported level with positive throughput, and the
    // memoized resolution agrees with `simd::resolve(Auto)`.
    let ds = dataset(13);
    let fitted = Trainer::new(cfg(3)).fit(&ds, None).unwrap();
    let dir = std::env::temp_dir().join(format!("dso-serve-auto-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model = dir.join("auto.dso");
    fitted.save(&model).unwrap();
    let socket = dir.join("auto.sock");
    let server = Server::bind(&ServeOptions::new(&model, &socket)).unwrap();
    let report = server.autotune_report().expect("auto binding must carry the report");
    assert_eq!(report.chosen.name(), server.backend());
    assert_eq!(report.chosen, resolve(SimdKind::Auto), "memoized agreement");
    let supported = dso::simd::supported_levels();
    for m in &report.measured {
        assert!(supported.contains(&m.level), "{:?}", m.level);
        assert!(m.units_per_sec > 0.0 && m.reps >= 1, "{:?}", m.level);
    }
    // A forced binding never measures.
    let socket2 = dir.join("forced.sock");
    let mut opts = ServeOptions::new(&model, &socket2);
    opts.simd = SimdKind::Portable;
    let forced = Server::bind(&opts).unwrap();
    assert!(forced.autotune_report().is_none());
    assert_eq!(forced.backend(), "portable");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance round trip: a server on a background thread, a
/// framed-transport client driving every request kind, error paths
/// included.
#[test]
fn server_roundtrip_predict_reload_stats_shutdown() {
    let ds = dataset(11);
    let (train, test) = ds.split(0.2, 7);
    let fitted = Trainer::new(cfg(6)).fit(&train, Some(&test)).unwrap();
    let dir = std::env::temp_dir().join(format!("dso-serve-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model_v1 = dir.join("v1.dso");
    fitted.save(&model_v1).unwrap();

    let socket = dir.join("serve.sock");
    let server = Server::bind(&ServeOptions::new(&model_v1, &socket)).unwrap();
    let backend = server.backend();
    let d = server.model_dim();
    assert_eq!(d, fitted.w().len());
    let handle = {
        let mut server = server;
        std::thread::spawn(move || server.run(&mut NullServeObserver))
    };

    let stream = connect_with_backoff(&socket, Duration::from_secs(10)).unwrap();
    let mut conn = FrameConn::new(stream);
    conn.set_recv_timeout(Some(Duration::from_millis(100))).unwrap();

    // 1. A good batch scores bit-identically to the local predict.
    let rows: Vec<usize> = (0..12.min(test.m())).collect();
    let batch = batch_text(&test, &rows);
    let local = fitted.predict(&test.x.select_rows(&rows)).unwrap();
    conn.send(&Msg::Predict { id: 1, batch: batch.clone() }).unwrap();
    match recv_msg(&mut conn) {
        Msg::Scores { id, scores } => {
            assert_eq!(id, 1);
            assert_eq!(scores, local, "wire scores must equal local predict");
        }
        other => panic!("expected Scores, got {other:?}"),
    }

    // 2. A malformed batch: line-numbered refusal, connection intact.
    conn.send(&Msg::Predict { id: 7, batch: "+1 1:0.5\nbogus\n".into() }).unwrap();
    match recv_msg(&mut conn) {
        Msg::ServeError { id, message } => {
            assert_eq!(id, 7);
            assert!(message.contains("line 2"), "message: {message}");
        }
        other => panic!("expected ServeError, got {other:?}"),
    }

    // 3. A batch exceeding the model dimension: the packer's message.
    let wide = format!("+1 {}:1.0\n", d + 5);
    conn.send(&Msg::Predict { id: 8, batch: wide }).unwrap();
    match recv_msg(&mut conn) {
        Msg::ServeError { id, message } => {
            assert_eq!(id, 8);
            assert!(message.contains("the model has"), "message: {message}");
        }
        other => panic!("expected ServeError, got {other:?}"),
    }

    // 4. A failed reload keeps the old model serving.
    let bogus = dir.join("nope.dso").display().to_string();
    conn.send(&Msg::Reload { path: bogus }).unwrap();
    match recv_msg(&mut conn) {
        Msg::ServeError { message, .. } => {
            assert!(message.contains("reload"), "message: {message}")
        }
        other => panic!("expected ServeError, got {other:?}"),
    }
    conn.send(&Msg::Predict { id: 2, batch: batch.clone() }).unwrap();
    match recv_msg(&mut conn) {
        Msg::Scores { scores, .. } => assert_eq!(scores, local, "old model must keep serving"),
        other => panic!("expected Scores, got {other:?}"),
    }

    // 5. Warm-start retrain, save v2, hot reload, predict the update.
    let refit = Trainer::new(cfg(25)).fit_from(&fitted, &train, Some(&test)).unwrap();
    let model_v2 = dir.join("v2.dso");
    refit.save(&model_v2).unwrap();
    conn.send(&Msg::Reload { path: model_v2.display().to_string() }).unwrap();
    assert!(matches!(recv_msg(&mut conn), Msg::Ack { seq: 1 }), "reload must ack seq 1");
    let relocal = refit.predict(&test.x.select_rows(&rows)).unwrap();
    conn.send(&Msg::Predict { id: 3, batch }).unwrap();
    match recv_msg(&mut conn) {
        Msg::Scores { id, scores } => {
            assert_eq!(id, 3);
            assert_eq!(scores, relocal, "post-reload scores must be the retrained model's");
            assert_ne!(scores, local, "25 warm epochs must have moved the weights");
        }
        other => panic!("expected Scores, got {other:?}"),
    }

    // 6. Stats carry the counters and the recorded backend.
    conn.send(&Msg::StatsReq).unwrap();
    match recv_msg(&mut conn) {
        Msg::StatsReply { served, rows: r, errors, reloads, backend: b, d: dim, .. } => {
            assert_eq!(served, 3, "three successful predicts");
            assert_eq!(r, 3 * rows.len() as u64, "rows counted on successful predicts only");
            assert_eq!(errors, 3, "malformed + mismatch + failed reload");
            assert_eq!(reloads, 1);
            assert_eq!(b, backend);
            assert_eq!(dim, d as u64);
        }
        other => panic!("expected StatsReply, got {other:?}"),
    }

    // 7. Clean shutdown.
    conn.send(&Msg::Shutdown).unwrap();
    assert!(matches!(recv_msg(&mut conn), Msg::Bye));
    handle.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
