//! Property tests for the SIMD lane sweep (`sweep_lanes`) over the
//! lane-major packed layout: on random sparse blocks × {Hinge,
//! Logistic, Square} × {L1, L2} × {Fixed, AdaGrad}, one lane sweep must
//! match the checked COO scalar oracle (`sweep_block`) within 1e-5
//! relative error — including ragged-tail groups (|group| not a lane
//! multiple) and sentinel-padded storage — and sentinel padding must
//! never perturb any w/α/accumulator state (bitwise-tested by mutating
//! the sentinels). Lane-aligned balanced stripes and the engines'
//! size-based dispatch are exercised end to end.
//!
//! Tolerance rationale: the lane kernel's α recurrence is
//! arithmetically identical to the scalar kernel's (sequential f64 over
//! the same entries); only the w side runs in 8-wide f32. A single
//! update therefore differs by ~f32 ulp (≈6e-8 relative) from the
//! scalar path, which itself sits ≪1e-5 from the COO oracle
//! (reciprocal-multiply and x/m-fold rounding) — one sweep stays well
//! inside 1e-5. Bit-identity tests remain pinned to the scalar path
//! (`tests/packed_kernel.rs`); the float-summation-order caveat is
//! documented in `partition::omega`.

// NOTE: this suite deliberately exercises the deprecated free-function
// shims — it pins them bit-for-bit against the `dso::api::Trainer`
// facade (DESIGN.md §Solver-API deprecation map).
#![allow(deprecated)]

use dso::config::{LossKind, PartitionKind, RegKind, StepKind, TrainConfig};
use dso::coordinator::updates::{
    sweep_block, sweep_lanes, sweep_packed, BlockState, PackedCtx, PackedState, StepRule,
    SweepCtx,
};
use dso::data::synth::SparseSpec;
use dso::data::Dataset;
use dso::losses::{Loss, Regularizer};
use dso::partition::{PackedBlock, PackedBlocks, Partition, LANES};
use dso::util::prop;

/// Dense-ish random dataset so row groups straddle LANES: with
/// nnz_per_row up to ~3·LANES and p ≤ 2, blocks carry a mix of
/// lane-eligible groups, ragged tails, and short scalar-fallback
/// groups.
fn random_dataset(g: &mut prop::Gen) -> Dataset {
    SparseSpec {
        name: "lane-prop".into(),
        m: g.usize_in(20, 100),
        d: g.usize_in(16, 64),
        nnz_per_row: g.f64_in(4.0, 3.0 * LANES as f64),
        zipf_s: g.f64_in(0.0, 1.0),
        label_noise: g.f64_in(0.0, 0.1),
        pos_frac: g.f64_in(0.2, 0.8),
        seed: g.case_seed,
    }
    .generate()
}

fn fresh_state(
    om: &PackedBlocks,
    q: usize,
    r: usize,
    loss: Loss,
    ds: &Dataset,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let w = vec![0.01f32; om.col_part.block_len(r)];
    let w_acc = vec![0f32; w.len()];
    let alpha: Vec<f32> = om
        .row_part
        .block(q)
        .map(|i| loss.alpha_init(ds.y[i] as f64) as f32)
        .collect();
    let a_acc = vec![0f32; alpha.len()];
    (w, w_acc, alpha, a_acc)
}

/// Run `sweeps` COO-oracle sweeps of block (q, r) and return the final
/// stripe-local (w, α).
#[allow(clippy::too_many_arguments)]
fn oracle_trajectory(
    ds: &Dataset,
    om: &PackedBlocks,
    q: usize,
    r: usize,
    loss: Loss,
    reg: Regularizer,
    lambda: f64,
    rule: StepRule,
    sweeps: usize,
) -> (Vec<f32>, Vec<f32>) {
    let entries = om.block_entries(&ds.x, q, r);
    let ctx = SweepCtx {
        loss,
        reg,
        lambda,
        m: ds.m() as f64,
        row_counts: &om.row_counts,
        col_counts: &om.col_counts,
        y: &ds.y,
        w_bound: loss.w_bound(lambda),
        rule,
    };
    let (mut w, mut w_acc, mut alpha, mut a_acc) = fresh_state(om, q, r, loss, ds);
    for _ in 0..sweeps {
        let mut st = BlockState {
            w: &mut w,
            w_acc: &mut w_acc,
            w_off: om.col_part.bounds[r],
            alpha: &mut alpha,
            a_acc: &mut a_acc,
            a_off: om.row_part.bounds[q],
        };
        sweep_block(&entries, &ctx, &mut st);
    }
    (w, alpha)
}

/// Run `sweeps` sweeps of block (q, r) with the given packed kernel on
/// a possibly-overridden block (for the sentinel-mutation tests) and
/// return the full final state.
#[allow(clippy::too_many_arguments)]
fn packed_trajectory(
    kernel: fn(&PackedBlock, &PackedCtx, &mut PackedState) -> usize,
    block: &PackedBlock,
    ds: &Dataset,
    om: &PackedBlocks,
    q: usize,
    r: usize,
    loss: Loss,
    reg: Regularizer,
    lambda: f64,
    rule: StepRule,
    sweeps: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let y_local = om.stripe_labels(&ds.y);
    let alpha_bias = om.stripe_alpha_bias(&ds.y);
    let ctx = PackedCtx {
        loss,
        reg,
        lambda,
        w_bound: loss.w_bound(lambda),
        rule,
        inv_col: &om.inv_col[r],
        inv_col32: &om.inv_col32[r],
        inv_row: &om.inv_row[q],
        y: &y_local[q],
        alpha_bias32: &alpha_bias[q],
    };
    let (mut w, mut w_acc, mut alpha, mut a_acc) = fresh_state(om, q, r, loss, ds);
    for _ in 0..sweeps {
        let mut st = PackedState {
            w: &mut w,
            w_acc: &mut w_acc,
            alpha: &mut alpha,
            a_acc: &mut a_acc,
        };
        kernel(block, &ctx, &mut st);
    }
    (w, w_acc, alpha, a_acc)
}

#[test]
fn prop_lanes_match_scalar_oracle() {
    // The headline contract: one lane sweep agrees with the COO scalar
    // oracle to ≤1e-5 relative error across random blocks and all
    // loss/reg/rule draws.
    prop::check("lane kernel vs scalar oracle", 40, |g| {
        let ds = random_dataset(g);
        let p = g.usize_in(1, 2.min(ds.m()).min(ds.d()));
        let rp = Partition::even(ds.m(), p);
        let cp = Partition::even(ds.d(), p);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        om.validate(&ds.x).map_err(|e| e)?;

        let loss =
            Loss::from(*g.pick(&[LossKind::Hinge, LossKind::Logistic, LossKind::Square]));
        let reg = Regularizer::from(*g.pick(&[RegKind::L2, RegKind::L1]));
        let eta = g.f64_in(0.05, 0.5);
        let rule = if g.bool() { StepRule::Fixed(eta) } else { StepRule::AdaGrad(eta) };
        let lambda = *g.pick(&[1e-2, 1e-3, 1e-4]);
        let q = g.usize_in(0, p - 1);
        let r = g.usize_in(0, p - 1);

        let (rw, ra) = oracle_trajectory(&ds, &om, q, r, loss, reg, lambda, rule, 1);
        let (lw, _, la, _) = packed_trajectory(
            sweep_lanes,
            om.block(q, r),
            &ds,
            &om,
            q,
            r,
            loss,
            reg,
            lambda,
            rule,
            1,
        );
        for k in 0..rw.len() {
            prop::assert_close(rw[k] as f64, lw[k] as f64, 1e-5, &format!("w[{k}]"))?;
        }
        for k in 0..ra.len() {
            prop::assert_close(ra[k] as f64, la[k] as f64, 1e-5, &format!("alpha[{k}]"))?;
        }
        Ok(())
    });
}

#[test]
fn lanes_match_oracle_all_combinations_with_ragged_tails() {
    // Deterministic restatement across every loss × reg × rule, on a
    // block whose row groups deliberately straddle LANES (lengths 1,
    // LANES−1, LANES, LANES+3, 2·LANES+5 → full chunks, ragged tails,
    // sentinel padding, and scalar-fallback groups all in one sweep).
    let lens = [1usize, LANES - 1, LANES, LANES + 3, 2 * LANES + 5];
    let d = 2 * LANES + 5;
    let rows: Vec<Vec<(u32, f32)>> = lens
        .iter()
        .enumerate()
        .map(|(i, &len)| {
            (0..len).map(|j| (j as u32, 0.3 + 0.1 * (i + j) as f32)).collect()
        })
        .collect();
    let x = dso::data::sparse::Csr::from_rows(d, rows);
    let y: Vec<f32> = (0..lens.len()).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let ds = Dataset::new("ragged", x, y);
    let rp = Partition::even(ds.m(), 1);
    let cp = Partition::even(ds.d(), 1);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    om.validate(&ds.x).unwrap();
    let b = om.block(0, 0);
    assert!(b.has_lanes());
    assert!(b.padded_nnz() > b.nnz(), "test must exercise sentinels");

    for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
        for reg in [Regularizer::L2, Regularizer::L1] {
            for rule in [StepRule::Fixed(0.2), StepRule::AdaGrad(0.2)] {
                let (rw, ra) =
                    oracle_trajectory(&ds, &om, 0, 0, loss, reg, 1e-3, rule, 1);
                let (lw, _, la, _) = packed_trajectory(
                    sweep_lanes,
                    b,
                    &ds,
                    &om,
                    0,
                    0,
                    loss,
                    reg,
                    1e-3,
                    rule,
                    1,
                );
                for k in 0..rw.len() {
                    let rel =
                        (rw[k] - lw[k]).abs() as f64 / (rw[k].abs() as f64).max(1e-3);
                    assert!(
                        rel <= 1e-5,
                        "{loss:?}/{reg:?}/{rule:?} w[{k}]: {} vs {}",
                        rw[k],
                        lw[k]
                    );
                }
                for k in 0..ra.len() {
                    let rel =
                        (ra[k] - la[k]).abs() as f64 / (ra[k].abs() as f64).max(1e-3);
                    assert!(
                        rel <= 1e-5,
                        "{loss:?}/{reg:?}/{rule:?} alpha[{k}]: {} vs {}",
                        ra[k],
                        la[k]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_sentinel_padding_never_perturbs_state() {
    // Sentinels are read-only by construction: rewriting every sentinel
    // slot to a different (valid) column and an arbitrary value must
    // leave the lane sweep's entire output — w, α, and both
    // accumulators — bitwise unchanged.
    prop::check("sentinel padding inert", 25, |g| {
        let ds = random_dataset(g);
        let rp = Partition::even(ds.m(), 1);
        let cp = Partition::even(ds.d(), 1);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        let b = om.block(0, 0);
        if !b.has_lanes() {
            return Ok(());
        }
        let mut mutated = b.clone();
        let mut n_sentinels = 0usize;
        for gi in 0..mutated.groups.len() {
            let g = mutated.groups[gi];
            let ps = g.pad_start as usize;
            for k in ps + g.len()..ps + g.padded_len() {
                mutated.cols[k] = mutated.n_cols - 1;
                mutated.vals[k] = 7.5;
                n_sentinels += 1;
            }
        }
        let loss = Loss::from(*g.pick(&[LossKind::Hinge, LossKind::Logistic]));
        let rule = StepRule::AdaGrad(g.f64_in(0.05, 0.5));
        let run = |blk: &PackedBlock| {
            packed_trajectory(
                sweep_lanes,
                blk,
                &ds,
                &om,
                0,
                0,
                loss,
                Regularizer::L2,
                1e-3,
                rule,
                2,
            )
        };
        prop::assert_that(
            run(b) == run(&mutated),
            format!("output depends on {n_sentinels} sentinel slots"),
        )
    });
}

#[test]
fn sentinel_column_zero_is_never_written() {
    // A lane-eligible row that skips column 0 entirely: the sentinels
    // point at col 0, and the sweep must leave w[0] and its accumulator
    // exactly at their initial values.
    let len = LANES + 1; // one full chunk + ragged tail of 1 → 7 sentinels
    let rows = vec![(0..len).map(|j| (j as u32 + 1, 1.0 + j as f32)).collect()];
    let x = dso::data::sparse::Csr::from_rows(len + 1, rows);
    let ds = Dataset::new("skip0", x, vec![1.0]);
    let rp = Partition::even(1, 1);
    let cp = Partition::even(len + 1, 1);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    let b = om.block(0, 0);
    assert!(b.has_lanes());
    assert_eq!(b.padded_nnz() - b.nnz(), LANES - 1);
    let (w, w_acc, _, _) = packed_trajectory(
        sweep_lanes,
        b,
        &ds,
        &om,
        0,
        0,
        Loss::Hinge,
        Regularizer::L2,
        1e-3,
        StepRule::AdaGrad(0.3),
        3,
    );
    assert_eq!(w[0], 0.01, "w[0] was touched by sentinel lanes");
    assert_eq!(w_acc[0], 0.0, "w_acc[0] was touched by sentinel lanes");
    // The real columns did move.
    assert!(w[1..].iter().any(|&v| v != 0.01));
}

#[test]
fn lanes_equal_scalar_on_blocks_without_lane_groups() {
    // On a block with only short groups the lane kernel *is* the scalar
    // kernel (same group loop) — bitwise, full state.
    let ds = SparseSpec {
        name: "short".into(),
        m: 60,
        d: 40,
        nnz_per_row: 3.0,
        zipf_s: 0.5,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 11,
    }
    .generate();
    let rp = Partition::even(ds.m(), 2);
    let cp = Partition::even(ds.d(), 2);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    for q in 0..2 {
        for r in 0..2 {
            let b = om.block(q, r);
            if b.has_lanes() {
                continue; // only interested in the fallback here
            }
            for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                let lanes = packed_trajectory(
                    sweep_lanes,
                    b,
                    &ds,
                    &om,
                    q,
                    r,
                    Loss::Hinge,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    3,
                );
                let scalar = packed_trajectory(
                    sweep_packed,
                    b,
                    &ds,
                    &om,
                    q,
                    r,
                    Loss::Hinge,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    3,
                );
                assert_eq!(lanes, scalar, "block ({q},{r}) {rule:?}");
            }
        }
    }
}

#[test]
fn lane_padded_balanced_stripes_validate_and_match_oracle() {
    // Balanced + lane_aligned column stripes: widths are lane
    // multiples (except the last), the packed blocks over them
    // validate, and the lane sweep still matches the oracle.
    let ds = SparseSpec {
        name: "balanced-lanes".into(),
        m: 300,
        d: 200,
        nnz_per_row: 12.0,
        zipf_s: 1.1,
        label_noise: 0.02,
        pos_frac: 0.5,
        seed: 21,
    }
    .generate();
    let p = 3;
    let col_w: Vec<u64> = ds.x.col_counts().iter().map(|&c| c as u64).collect();
    let cp = Partition::balanced(&col_w, p).lane_aligned(LANES);
    for q in 0..p - 1 {
        assert_eq!(cp.block_len(q) % LANES, 0, "stripe {q}: {:?}", cp.bounds);
    }
    let row_w: Vec<u64> = (0..ds.m()).map(|i| ds.x.row_nnz(i) as u64).collect();
    let rp = Partition::balanced(&row_w, p);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    om.validate(&ds.x).unwrap();
    for (q, r) in [(0, 0), (1, 2), (2, 1)] {
        let (rw, ra) = oracle_trajectory(
            &ds,
            &om,
            q,
            r,
            Loss::Hinge,
            Regularizer::L2,
            1e-3,
            StepRule::AdaGrad(0.3),
            1,
        );
        let (lw, _, la, _) = packed_trajectory(
            sweep_lanes,
            om.block(q, r),
            &ds,
            &om,
            q,
            r,
            Loss::Hinge,
            Regularizer::L2,
            1e-3,
            StepRule::AdaGrad(0.3),
            1,
        );
        for k in 0..rw.len() {
            let rel = (rw[k] - lw[k]).abs() as f64 / (rw[k].abs() as f64).max(1e-3);
            assert!(rel <= 1e-5, "block ({q},{r}) w[{k}]: {} vs {}", rw[k], lw[k]);
        }
        for k in 0..ra.len() {
            let rel = (ra[k] - la[k]).abs() as f64 / (ra[k].abs() as f64).max(1e-3);
            assert!(rel <= 1e-5, "block ({q},{r}) alpha[{k}]: {} vs {}", ra[k], la[k]);
        }
    }
}

#[test]
fn engine_lane_dispatch_threaded_equals_replay() {
    // Dense-enough rows that the engines take the lane path on most
    // blocks: the Lemma-2 bit-identity (threaded ≡ replay) must hold on
    // the lane kernel exactly as on the scalar one, for even and
    // lane-aligned balanced partitions, full and subsampled sweeps.
    let ds = SparseSpec {
        name: "lane-engine".into(),
        m: 160,
        d: 48,
        nnz_per_row: 20.0,
        zipf_s: 0.6,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed: 31,
    }
    .generate();
    // Sanity: the default decomposition actually has lane-eligible
    // groups, otherwise this test exercises nothing new.
    let rp = Partition::even(ds.m(), 2);
    let cp = Partition::even(ds.d(), 2);
    let om = PackedBlocks::build(&ds.x, &rp, &cp);
    assert!((0..2).any(|q| (0..2).any(|r| om.block(q, r).has_lanes())));

    for (partition, upb) in [
        (PartitionKind::Even, 0usize),
        (PartitionKind::Balanced, 0),
        (PartitionKind::Even, 9),
    ] {
        let mut c = TrainConfig::default();
        c.optim.epochs = 3;
        c.optim.eta0 = 0.3;
        c.optim.step = StepKind::AdaGrad;
        c.model.lambda = 1e-3;
        c.cluster.machines = 2;
        c.cluster.cores = 1;
        c.cluster.partition = partition;
        c.cluster.updates_per_block = upb;
        c.monitor.every = 0;
        let threaded = dso::coordinator::train_dso(&c, &ds, None).unwrap();
        let replayed = dso::coordinator::run_replay(&c, &ds, None).unwrap();
        assert_eq!(threaded.w, replayed.w, "{partition:?} upb {upb}");
        assert_eq!(threaded.alpha, replayed.alpha, "{partition:?} upb {upb}");
        assert_eq!(threaded.total_updates, replayed.total_updates);
        assert!(threaded.final_gap >= -1e-6);
    }
}

#[test]
fn async_engine_runs_lane_path() {
    // NOMAD-style async on dense rows: lane dispatch is exercised per
    // block visit; invariants (feasibility, boxes, recovery) hold.
    let ds = SparseSpec {
        name: "lane-async".into(),
        m: 200,
        d: 64,
        nnz_per_row: 18.0,
        zipf_s: 0.5,
        label_noise: 0.03,
        pos_frac: 0.5,
        seed: 41,
    }
    .generate();
    let mut c = TrainConfig::default();
    c.optim.epochs = 10;
    c.optim.eta0 = 0.2;
    c.model.lambda = 1e-3;
    c.cluster.machines = 4;
    c.cluster.cores = 1;
    c.monitor.every = 0;
    let r = dso::coordinator::train_dso_async(&c, &ds, None).unwrap();
    assert!(r.final_primal.is_finite());
    assert!(r.final_gap >= -1e-5);
    let b = Loss::Hinge.w_bound(1e-3) as f32 * (1.0 + f32::EPSILON);
    assert!(r.w.iter().all(|&x| (-b..=b).contains(&x)));
    for (i, &a) in r.alpha.iter().enumerate() {
        let beta = ds.y[i] as f64 * a as f64;
        assert!((-1e-6..=1.0 + 1e-6).contains(&beta), "α_{i} infeasible: {beta}");
    }
}

// ---------------------------------------------------------------------
// Explicit-SIMD backend differentials (PR 5): AVX2 vs portable
// ---------------------------------------------------------------------
// #[cfg]-gated to x86_64 and guarded on runtime detection, so the
// suite auto-skips (with a note) everywhere the AVX2 backend cannot
// run. The portable backend needs no new coverage: it is bit-identical
// to the pre-backend kernels by construction, which the whole existing
// suite pins.

#[cfg(target_arch = "x86_64")]
mod avx2_backend {
    use super::*;
    use dso::config::SimdKind;
    use dso::coordinator::updates::sweep_lanes_with;
    use dso::simd::{avx2_supported, Avx2};

    fn guard() -> bool {
        if avx2_supported() {
            true
        } else {
            eprintln!("skipping avx2 backend test: host lacks avx2+fma");
            false
        }
    }

    #[test]
    fn prop_avx2_matches_portable_and_oracle() {
        // The backend contract: on random ragged/sentinel-padded
        // blocks across every loss × reg × rule, one AVX2 sweep stays
        // within 1e-5 relative of both the portable backend (FMA
        // contraction is the only divergence) and the COO oracle.
        if !guard() {
            return;
        }
        prop::check("avx2 vs portable lane kernel", 40, |g| {
            let ds = random_dataset(g);
            let p = g.usize_in(1, 2.min(ds.m()).min(ds.d()));
            let rp = Partition::even(ds.m(), p);
            let cp = Partition::even(ds.d(), p);
            let om = PackedBlocks::build(&ds.x, &rp, &cp);
            om.validate(&ds.x).map_err(|e| e)?;
            let loss =
                Loss::from(*g.pick(&[LossKind::Hinge, LossKind::Logistic, LossKind::Square]));
            let reg = Regularizer::from(*g.pick(&[RegKind::L2, RegKind::L1]));
            let eta = g.f64_in(0.05, 0.5);
            let rule = if g.bool() { StepRule::Fixed(eta) } else { StepRule::AdaGrad(eta) };
            let lambda = *g.pick(&[1e-2, 1e-3, 1e-4]);
            let q = g.usize_in(0, p - 1);
            let r = g.usize_in(0, p - 1);

            let run = |kernel: fn(&PackedBlock, &PackedCtx, &mut PackedState) -> usize| {
                packed_trajectory(
                    kernel,
                    om.block(q, r),
                    &ds,
                    &om,
                    q,
                    r,
                    loss,
                    reg,
                    lambda,
                    rule,
                    1,
                )
            };
            let (aw, _, aa, _) = run(sweep_lanes_with::<Avx2>);
            let (pw, _, pa, _) = run(sweep_lanes);
            for k in 0..aw.len() {
                prop::assert_close(pw[k] as f64, aw[k] as f64, 1e-5, &format!("w[{k}]"))?;
            }
            for k in 0..aa.len() {
                prop::assert_close(pa[k] as f64, aa[k] as f64, 1e-5, &format!("alpha[{k}]"))?;
            }
            let (rw, ra) = oracle_trajectory(&ds, &om, q, r, loss, reg, lambda, rule, 1);
            for k in 0..rw.len() {
                prop::assert_close(rw[k] as f64, aw[k] as f64, 1e-5, &format!("oracle w[{k}]"))?;
            }
            for k in 0..ra.len() {
                prop::assert_close(ra[k] as f64, aa[k] as f64, 1e-5, &format!("oracle a[{k}]"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_avx2_sentinel_padding_inert() {
        // The AVX2 gathers read sentinel slots speculatively
        // (full-width `_mm256_i32gather_ps`), exactly like the
        // portable loads: rewriting every sentinel must leave the
        // output bitwise unchanged.
        if !guard() {
            return;
        }
        prop::check("avx2 sentinel padding inert", 20, |g| {
            let ds = random_dataset(g);
            let rp = Partition::even(ds.m(), 1);
            let cp = Partition::even(ds.d(), 1);
            let om = PackedBlocks::build(&ds.x, &rp, &cp);
            let b = om.block(0, 0);
            if !b.has_lanes() {
                return Ok(());
            }
            let mut mutated = b.clone();
            for gi in 0..mutated.groups.len() {
                let g = mutated.groups[gi];
                let ps = g.pad_start as usize;
                for k in ps + g.len()..ps + g.padded_len() {
                    mutated.cols[k] = mutated.n_cols - 1;
                    mutated.vals[k] = -3.25;
                }
            }
            let loss = Loss::from(*g.pick(&[LossKind::Hinge, LossKind::Logistic]));
            let rule = StepRule::AdaGrad(g.f64_in(0.05, 0.5));
            let run = |blk: &PackedBlock| {
                packed_trajectory(
                    sweep_lanes_with::<Avx2>,
                    blk,
                    &ds,
                    &om,
                    0,
                    0,
                    loss,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    2,
                )
            };
            prop::assert_that(run(b) == run(&mutated), "avx2 output depends on sentinels")
        });
    }

    #[test]
    fn avx2_short_groups_fall_back_bitwise_to_scalar() {
        // The backend only touches lane chunks; short-group blocks run
        // the shared scalar loop, so the AVX2 instantiation must be
        // bitwise the scalar kernel there — on any backend.
        if !guard() {
            return;
        }
        let ds = SparseSpec {
            name: "avx2-short".into(),
            m: 60,
            d: 40,
            nnz_per_row: 3.0,
            zipf_s: 0.5,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 61,
        }
        .generate();
        let rp = Partition::even(ds.m(), 2);
        let cp = Partition::even(ds.d(), 2);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        for q in 0..2 {
            for r in 0..2 {
                let b = om.block(q, r);
                if b.has_lanes() {
                    continue;
                }
                for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                    let avx = packed_trajectory(
                        sweep_lanes_with::<Avx2>,
                        b,
                        &ds,
                        &om,
                        q,
                        r,
                        Loss::Hinge,
                        Regularizer::L2,
                        1e-3,
                        rule,
                        3,
                    );
                    let scalar = packed_trajectory(
                        sweep_packed,
                        b,
                        &ds,
                        &om,
                        q,
                        r,
                        Loss::Hinge,
                        Regularizer::L2,
                        1e-3,
                        rule,
                        3,
                    );
                    assert_eq!(avx, scalar, "block ({q},{r}) {rule:?}");
                }
            }
        }
    }

    #[test]
    fn fused_avx2_entry_points_match_generic_bitwise() {
        // The `#[target_feature]` whole-sweep entry points the plan
        // and benches use must be bitwise the generic Avx2
        // monomorphization: fusing changes codegen, not results (the
        // intrinsics are explicit either way).
        if !guard() {
            return;
        }
        use dso::coordinator::updates::{
            sweep_lanes_affine_with, sweep_lanes_avx2, sweep_lanes_affine_avx2,
        };
        let ds = SparseSpec {
            name: "avx2-fused".into(),
            m: 50,
            d: 40,
            nnz_per_row: 14.0,
            zipf_s: 0.4,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 101,
        }
        .generate();
        let rp = Partition::even(ds.m(), 1);
        let cp = Partition::even(ds.d(), 1);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        assert!(om.block(0, 0).has_lanes());
        for loss in [Loss::Hinge, Loss::Square] {
            for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                let generic = packed_trajectory(
                    if loss == Loss::Square {
                        sweep_lanes_affine_with::<Avx2>
                    } else {
                        sweep_lanes_with::<Avx2>
                    },
                    om.block(0, 0),
                    &ds,
                    &om,
                    0,
                    0,
                    loss,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    2,
                );
                // Same trajectory through the fused entry point.
                let y_local = om.stripe_labels(&ds.y);
                let alpha_bias = om.stripe_alpha_bias(&ds.y);
                let ctx = PackedCtx {
                    loss,
                    reg: Regularizer::L2,
                    lambda: 1e-3,
                    w_bound: loss.w_bound(1e-3),
                    rule,
                    inv_col: &om.inv_col[0],
                    inv_col32: &om.inv_col32[0],
                    inv_row: &om.inv_row[0],
                    y: &y_local[0],
                    alpha_bias32: &alpha_bias[0],
                };
                let mut w = vec![0.01f32; om.col_part.block_len(0)];
                let mut w_acc = vec![0f32; w.len()];
                let mut alpha: Vec<f32> = om
                    .row_part
                    .block(0)
                    .map(|i| loss.alpha_init(ds.y[i] as f64) as f32)
                    .collect();
                let mut a_acc = vec![0f32; alpha.len()];
                for _ in 0..2 {
                    let mut st = PackedState {
                        w: &mut w,
                        w_acc: &mut w_acc,
                        alpha: &mut alpha,
                        a_acc: &mut a_acc,
                    };
                    // SAFETY: inside the guard() avx2+fma check.
                    unsafe {
                        if loss == Loss::Square {
                            sweep_lanes_affine_avx2(om.block(0, 0), &ctx, &mut st);
                        } else {
                            sweep_lanes_avx2(om.block(0, 0), &ctx, &mut st);
                        }
                    }
                }
                assert_eq!(
                    (w, w_acc, alpha, a_acc),
                    generic,
                    "{loss:?} {rule:?} fused != generic"
                );
            }
        }
    }

    #[test]
    fn engine_threaded_equals_replay_under_avx2() {
        // Lemma-2 bit-identity holds *within* the AVX2 backend: the
        // threaded engine and the serial replay dispatch the same
        // planned kernels, so `--simd avx2` trajectories are exactly
        // serializable too (even/balanced, all three losses).
        if !guard() {
            return;
        }
        let ds = SparseSpec {
            name: "avx2-engine".into(),
            m: 160,
            d: 48,
            nnz_per_row: 20.0,
            zipf_s: 0.6,
            label_noise: 0.05,
            pos_frac: 0.5,
            seed: 71,
        }
        .generate();
        for loss in [LossKind::Hinge, LossKind::Logistic, LossKind::Square] {
            for partition in [PartitionKind::Even, PartitionKind::Balanced] {
                let mut c = TrainConfig::default();
                c.optim.epochs = 3;
                c.optim.eta0 = 0.3;
                c.optim.step = StepKind::AdaGrad;
                c.model.loss = loss;
                c.model.lambda = 1e-3;
                c.cluster.machines = 2;
                c.cluster.cores = 1;
                c.cluster.partition = partition;
                c.cluster.simd = SimdKind::Avx2;
                c.monitor.every = 0;
                let threaded = dso::coordinator::train_dso(&c, &ds, None).unwrap();
                let replayed = dso::coordinator::run_replay(&c, &ds, None).unwrap();
                assert_eq!(threaded.w, replayed.w, "{loss:?}/{partition:?}");
                assert_eq!(threaded.alpha, replayed.alpha, "{loss:?}/{partition:?}");
                assert_eq!(threaded.total_updates, replayed.total_updates);
            }
        }
    }
}

// ---------------------------------------------------------------------
// AVX-512 paired backend. Same shape as the avx2 module: #[cfg]-gated
// to x86_64 and guarded on runtime detection, so the suite auto-skips
// (with a note) on hosts without avx512f. The pair-loop *logic*
// (boundaries, epilogue handoff) is separately pinned bitwise on every
// machine by the PairedPortable tests inside coordinator::updates; the
// suite here is the hardware half: the real 512-bit gathers, FMA and
// scatters against the portable/COO truth.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx512_backend {
    use super::*;
    use dso::config::SimdKind;
    use dso::coordinator::updates::sweep_lanes_with;
    use dso::simd::{avx512_supported, Avx2, Avx512};

    fn guard() -> bool {
        if avx512_supported() {
            true
        } else {
            eprintln!("skipping avx512 backend test: host lacks avx512f+avx2+fma");
            false
        }
    }

    /// Groups long enough that every regime appears: full pairs, a
    /// ragged tail behind a pair, an odd trailing full chunk, and
    /// short scalar-fallback groups.
    fn paired_dataset(seed: u64) -> Dataset {
        SparseSpec {
            name: "avx512-pairs".into(),
            m: 70,
            d: 48,
            nnz_per_row: 2.6 * LANES as f64,
            zipf_s: 0.4,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed,
        }
        .generate()
    }

    #[test]
    fn prop_avx512_matches_portable_and_oracle() {
        // The backend contract, now 16-wide: on random
        // ragged/sentinel-padded blocks across every loss × reg ×
        // rule, one AVX-512 sweep stays within 1e-5 relative of both
        // the portable backend and the COO oracle.
        if !guard() {
            return;
        }
        prop::check("avx512 vs portable lane kernel", 40, |g| {
            let ds = random_dataset(g);
            let p = g.usize_in(1, 2.min(ds.m()).min(ds.d()));
            let rp = Partition::even(ds.m(), p);
            let cp = Partition::even(ds.d(), p);
            let om = PackedBlocks::build(&ds.x, &rp, &cp);
            om.validate(&ds.x).map_err(|e| e)?;
            let loss =
                Loss::from(*g.pick(&[LossKind::Hinge, LossKind::Logistic, LossKind::Square]));
            let reg = Regularizer::from(*g.pick(&[RegKind::L2, RegKind::L1]));
            let eta = g.f64_in(0.05, 0.5);
            let rule = if g.bool() { StepRule::Fixed(eta) } else { StepRule::AdaGrad(eta) };
            let lambda = *g.pick(&[1e-2, 1e-3, 1e-4]);
            let q = g.usize_in(0, p - 1);
            let r = g.usize_in(0, p - 1);

            let run = |kernel: fn(&PackedBlock, &PackedCtx, &mut PackedState) -> usize| {
                packed_trajectory(
                    kernel,
                    om.block(q, r),
                    &ds,
                    &om,
                    q,
                    r,
                    loss,
                    reg,
                    lambda,
                    rule,
                    1,
                )
            };
            let (aw, _, aa, _) = run(sweep_lanes_with::<Avx512>);
            let (pw, _, pa, _) = run(sweep_lanes);
            for k in 0..aw.len() {
                prop::assert_close(pw[k] as f64, aw[k] as f64, 1e-5, &format!("w[{k}]"))?;
            }
            for k in 0..aa.len() {
                prop::assert_close(pa[k] as f64, aa[k] as f64, 1e-5, &format!("alpha[{k}]"))?;
            }
            let (rw, ra) = oracle_trajectory(&ds, &om, q, r, loss, reg, lambda, rule, 1);
            for k in 0..rw.len() {
                prop::assert_close(rw[k] as f64, aw[k] as f64, 1e-5, &format!("oracle w[{k}]"))?;
            }
            for k in 0..ra.len() {
                prop::assert_close(ra[k] as f64, aa[k] as f64, 1e-5, &format!("oracle a[{k}]"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_avx512_sentinel_padding_inert() {
        // Pair steps never reach sentinel slots (`rem >= 2·LANES`
        // implies 16 real entries); the 8-wide epilogue gathers them
        // speculatively like AVX2. Rewriting every sentinel must leave
        // the output bitwise unchanged.
        if !guard() {
            return;
        }
        prop::check("avx512 sentinel padding inert", 20, |g| {
            let ds = random_dataset(g);
            let rp = Partition::even(ds.m(), 1);
            let cp = Partition::even(ds.d(), 1);
            let om = PackedBlocks::build(&ds.x, &rp, &cp);
            let b = om.block(0, 0);
            if !b.has_lanes() {
                return Ok(());
            }
            let mut mutated = b.clone();
            for gi in 0..mutated.groups.len() {
                let g = mutated.groups[gi];
                let ps = g.pad_start as usize;
                for k in ps + g.len()..ps + g.padded_len() {
                    mutated.cols[k] = mutated.n_cols - 1;
                    mutated.vals[k] = -3.25;
                }
            }
            let loss = Loss::from(*g.pick(&[LossKind::Hinge, LossKind::Logistic]));
            let rule = StepRule::AdaGrad(g.f64_in(0.05, 0.5));
            let run = |blk: &PackedBlock| {
                packed_trajectory(
                    sweep_lanes_with::<Avx512>,
                    blk,
                    &ds,
                    &om,
                    0,
                    0,
                    loss,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    2,
                )
            };
            prop::assert_that(run(b) == run(&mutated), "avx512 output depends on sentinels")
        });
    }

    #[test]
    fn avx512_is_bitwise_avx2_including_odd_chunk_epilogue() {
        // Stronger than the 1e-5 contract: every pair op rounds
        // per-lane exactly like the 256-bit op on the same entries
        // (512-bit FMA is still one rounding per lane), gathers and
        // scatters move bits, and the α recurrence is the same serial
        // f64 fold — so a whole AVX-512 sweep is *bitwise* the AVX2
        // sweep, pairs, odd trailing chunks and ragged tails included.
        if !guard() {
            return;
        }
        let ds = paired_dataset(331);
        let rp = Partition::even(ds.m(), 1);
        let cp = Partition::even(ds.d(), 1);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        assert!(om.block(0, 0).has_lanes());
        for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
            for reg in [Regularizer::L2, Regularizer::L1] {
                for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                    let run = |kernel: fn(
                        &PackedBlock,
                        &PackedCtx,
                        &mut PackedState,
                    ) -> usize| {
                        packed_trajectory(
                            kernel,
                            om.block(0, 0),
                            &ds,
                            &om,
                            0,
                            0,
                            loss,
                            reg,
                            1e-3,
                            rule,
                            3,
                        )
                    };
                    assert_eq!(
                        run(sweep_lanes_with::<Avx512>),
                        run(sweep_lanes_with::<Avx2>),
                        "{loss:?}/{reg:?}/{rule:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_avx512_entry_points_match_generic_bitwise() {
        // The `#[target_feature]` whole-sweep entry points the plan
        // uses must be bitwise the generic Avx512 monomorphization.
        if !guard() {
            return;
        }
        use dso::coordinator::updates::{
            sweep_lanes_affine_avx512, sweep_lanes_affine_with, sweep_lanes_avx512,
        };
        let ds = paired_dataset(101);
        let rp = Partition::even(ds.m(), 1);
        let cp = Partition::even(ds.d(), 1);
        let om = PackedBlocks::build(&ds.x, &rp, &cp);
        assert!(om.block(0, 0).has_lanes());
        for loss in [Loss::Hinge, Loss::Square] {
            for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                let generic = packed_trajectory(
                    if loss == Loss::Square {
                        sweep_lanes_affine_with::<Avx512>
                    } else {
                        sweep_lanes_with::<Avx512>
                    },
                    om.block(0, 0),
                    &ds,
                    &om,
                    0,
                    0,
                    loss,
                    Regularizer::L2,
                    1e-3,
                    rule,
                    2,
                );
                let y_local = om.stripe_labels(&ds.y);
                let alpha_bias = om.stripe_alpha_bias(&ds.y);
                let ctx = PackedCtx {
                    loss,
                    reg: Regularizer::L2,
                    lambda: 1e-3,
                    w_bound: loss.w_bound(1e-3),
                    rule,
                    inv_col: &om.inv_col[0],
                    inv_col32: &om.inv_col32[0],
                    inv_row: &om.inv_row[0],
                    y: &y_local[0],
                    alpha_bias32: &alpha_bias[0],
                };
                let mut w = vec![0.01f32; om.col_part.block_len(0)];
                let mut w_acc = vec![0f32; w.len()];
                let mut alpha: Vec<f32> = om
                    .row_part
                    .block(0)
                    .map(|i| loss.alpha_init(ds.y[i] as f64) as f32)
                    .collect();
                let mut a_acc = vec![0f32; alpha.len()];
                for _ in 0..2 {
                    let mut st = PackedState {
                        w: &mut w,
                        w_acc: &mut w_acc,
                        alpha: &mut alpha,
                        a_acc: &mut a_acc,
                    };
                    // SAFETY: inside the guard() avx512f+avx2+fma check.
                    unsafe {
                        if loss == Loss::Square {
                            sweep_lanes_affine_avx512(om.block(0, 0), &ctx, &mut st);
                        } else {
                            sweep_lanes_avx512(om.block(0, 0), &ctx, &mut st);
                        }
                    }
                }
                assert_eq!(
                    (w, w_acc, alpha, a_acc),
                    generic,
                    "{loss:?} {rule:?} fused != generic"
                );
            }
        }
    }

    #[test]
    fn engine_threaded_equals_replay_under_avx512() {
        // Lemma-2 bit-identity holds *within* the paired backend: the
        // threaded engine and the serial replay dispatch the same
        // planned kernels, so `--simd avx512` trajectories are exactly
        // serializable too.
        if !guard() {
            return;
        }
        let ds = SparseSpec {
            name: "avx512-engine".into(),
            m: 160,
            d: 48,
            nnz_per_row: 20.0,
            zipf_s: 0.6,
            label_noise: 0.05,
            pos_frac: 0.5,
            seed: 71,
        }
        .generate();
        for loss in [LossKind::Hinge, LossKind::Logistic, LossKind::Square] {
            for partition in [PartitionKind::Even, PartitionKind::Balanced] {
                let mut c = TrainConfig::default();
                c.optim.epochs = 3;
                c.optim.eta0 = 0.3;
                c.optim.step = StepKind::AdaGrad;
                c.model.loss = loss;
                c.model.lambda = 1e-3;
                c.cluster.machines = 2;
                c.cluster.cores = 1;
                c.cluster.partition = partition;
                c.cluster.simd = SimdKind::Avx512;
                c.monitor.every = 0;
                let threaded = dso::coordinator::train_dso(&c, &ds, None).unwrap();
                let replayed = dso::coordinator::run_replay(&c, &ds, None).unwrap();
                assert_eq!(threaded.w, replayed.w, "{loss:?}/{partition:?}");
                assert_eq!(threaded.alpha, replayed.alpha, "{loss:?}/{partition:?}");
                assert_eq!(threaded.total_updates, replayed.total_updates);
            }
        }
    }
}

/// Measured `auto` pins: deterministic in-process resolution, a winner
/// from the supported set, and the report recorded on the plan. Runs on
/// every host (no feature guard — `auto` is always valid).
mod measured_auto {
    use super::*;
    use dso::config::SimdKind;

    #[test]
    fn auto_resolution_is_stable_and_recorded_on_the_plan() {
        let first = dso::simd::resolve(SimdKind::Auto);
        assert!(dso::simd::supported_levels().contains(&first));
        // Memoized: every later resolution in this process agrees —
        // the fingerprint-consistency contract.
        assert_eq!(dso::simd::resolve(SimdKind::Auto), first);

        let ds = SparseSpec {
            name: "auto-plan".into(),
            m: 60,
            d: 32,
            nnz_per_row: 18.0,
            zipf_s: 0.4,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 17,
        }
        .generate();
        let mut c = TrainConfig::default();
        c.optim.epochs = 1;
        c.cluster.machines = 2;
        c.cluster.cores = 1;
        c.monitor.every = 0;
        assert_eq!(c.cluster.simd, SimdKind::Auto, "auto is the default");
        let r = dso::coordinator::train_dso(&c, &ds, None).unwrap();
        assert!(r.w.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forced_levels_refuse_rather_than_degrade() {
        // validate() refuses a forced hardware backend the host lacks
        // with the shared refusal message; on hosts that support it,
        // the request passes validation unchanged.
        for (kind, supported) in [
            (SimdKind::Avx2, dso::simd::avx2_supported()),
            (SimdKind::Avx512, dso::simd::avx512_supported()),
        ] {
            let mut c = TrainConfig::default();
            c.cluster.simd = kind;
            match (c.validate(), supported) {
                (Ok(()), true) | (Err(_), false) => {}
                (Ok(()), false) => panic!("{kind:?} validated on an unsupported host"),
                (Err(e), true) => panic!("{kind:?} refused on a supporting host: {e}"),
            }
        }
    }
}
