//! Convergence validation against independent references:
//! * DSO ≈ DCD optimum (hinge), BMRM optimum (logistic),
//! * square loss + L2 against the closed-form ridge solution,
//! * Theorem 1's O(1/√T) gap shape: gap·√T stays bounded,
//! * all four algorithms agree on the optimum of the same problem.

// NOTE: this suite deliberately exercises the deprecated free-function
// shims — it pins them bit-for-bit against the `dso::api::Trainer`
// facade (DESIGN.md §Solver-API deprecation map).
#![allow(deprecated)]

use dso::config::{Algorithm, LossKind, TrainConfig};
use dso::data::synth::SparseSpec;
use dso::data::{Csr, Dataset};
use dso::losses::{Loss, Problem, Regularizer};

fn dataset(m: usize, d: usize, seed: u64) -> Dataset {
    SparseSpec {
        name: "conv".into(),
        m,
        d,
        nnz_per_row: 8.0,
        zipf_s: 0.6,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed,
    }
    .generate()
}

fn cfg(algo: Algorithm, epochs: usize, lambda: f64) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.optim.algorithm = algo;
    c.optim.epochs = epochs;
    c.optim.eta0 = 0.2;
    c.model.lambda = lambda;
    c.cluster.machines = 4;
    c.cluster.cores = 1;
    c.monitor.every = 1;
    c
}

#[test]
fn dso_reaches_dcd_optimum_hinge() {
    for seed in [1u64, 2, 3] {
        let ds = dataset(400, 80, seed);
        let lambda = 1e-3;
        let r = dso::coordinator::train(&cfg(Algorithm::Dso, 250, lambda), &ds, None).unwrap();
        let dcd = dso::optim::dcd::solve_hinge_l2(&ds, lambda, 1000, 1e-10, 1);
        let p = Problem::new(Loss::Hinge, Regularizer::L2, lambda);
        let p_star = p.primal(&ds, &dcd.w);
        let rel = (r.final_primal - p_star) / p_star.abs().max(1e-12);
        assert!(rel < 0.05, "seed {seed}: dso {} vs opt {p_star} (rel {rel})", r.final_primal);
        assert!(rel > -1e-6, "below the optimum?!");
    }
}

#[test]
fn all_algorithms_agree_on_optimum() {
    let ds = dataset(350, 60, 4);
    let lambda = 1e-3;
    let dso_r = dso::coordinator::train(&cfg(Algorithm::Dso, 250, lambda), &ds, None).unwrap();
    let sgd_r = dso::coordinator::train(&cfg(Algorithm::Sgd, 250, lambda), &ds, None).unwrap();
    let psgd_r = dso::coordinator::train(&cfg(Algorithm::Psgd, 250, lambda), &ds, None).unwrap();
    let bmrm_r = dso::coordinator::train(&cfg(Algorithm::Bmrm, 150, lambda), &ds, None).unwrap();
    let objs = [dso_r.final_primal, sgd_r.final_primal, psgd_r.final_primal, bmrm_r.final_primal];
    let lo = objs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = objs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    assert!(
        (hi - lo) / lo.abs().max(1e-12) < 0.12,
        "objectives disagree: {objs:?}"
    );
}

#[test]
fn logistic_dso_matches_bmrm() {
    let ds = dataset(300, 60, 5);
    let lambda = 1e-3;
    let mut dcfg = cfg(Algorithm::Dso, 300, lambda);
    dcfg.model.loss = LossKind::Logistic;
    let mut bcfg = cfg(Algorithm::Bmrm, 200, lambda);
    bcfg.model.loss = LossKind::Logistic;
    let d = dso::coordinator::train(&dcfg, &ds, None).unwrap();
    let b = dso::coordinator::train(&bcfg, &ds, None).unwrap();
    let rel = (d.final_primal - b.final_primal) / b.final_primal.abs().max(1e-12);
    assert!(rel.abs() < 0.05, "dso {} vs bmrm {}", d.final_primal, b.final_primal);
}

/// Small dense ridge problem: every row carries all d features, so
/// with p = 1 each row group has exactly d entries (lane-eligible when
/// d ≥ LANES → the engine takes the affine-α path) while p = 4 splits
/// rows into short groups (scalar path).
fn dense_ridge_dataset(m: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = dso::util::rng::Xoshiro256::new(seed);
    let rows: Vec<Vec<(u32, f32)>> = (0..m)
        .map(|_| (0..d).map(|j| (j as u32, rng.normal() as f32)).collect())
        .collect();
    let x = Csr::from_rows(d, rows);
    let wstar: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let y: Vec<f32> = (0..m)
        .map(|i| {
            let (idx, val) = x.row(i);
            let mut s = 0.0;
            for k in 0..idx.len() {
                s += wstar[idx[k] as usize] * val[k] as f64;
            }
            (s + 0.05 * rng.normal()) as f32
        })
        .collect();
    Dataset::new("ridge", x, y)
}

/// Closed-form ridge solution via Gaussian elimination on
/// (2λm·I + XᵀX) w = Xᵀy — the normal equations of the primal
/// (1/m)·Σ ½(xᵢᵀw − yᵢ)² + λ‖w‖².
fn ridge_closed_form(ds: &Dataset, lambda: f64) -> Vec<f64> {
    let m = ds.m();
    let d = ds.d();
    let mut a = vec![vec![0f64; d + 1]; d];
    for i in 0..m {
        let (idx, val) = ds.x.row(i);
        for p in 0..idx.len() {
            for q in 0..idx.len() {
                a[idx[p] as usize][idx[q] as usize] += val[p] as f64 * val[q] as f64;
            }
            a[idx[p] as usize][d] += val[p] as f64 * ds.y[i] as f64;
        }
    }
    for j in 0..d {
        a[j][j] += 2.0 * lambda * m as f64;
    }
    for col in 0..d {
        let piv = (col..d)
            .max_by(|&r1, &r2| a[r1][col].abs().partial_cmp(&a[r2][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        let pv = a[col][col];
        for r in 0..d {
            if r != col {
                let f = a[r][col] / pv;
                for c in col..=d {
                    a[r][c] -= f * a[col][c];
                }
            }
        }
    }
    (0..d).map(|j| a[j][d] / a[j][j]).collect()
}

/// Ridge regression sanity: square loss + L2 on a small dense system
/// has the closed form (2λm·I + XᵀX) w = Xᵀ y; DSO must approach it.
#[test]
fn square_loss_matches_closed_form_ridge() {
    let ds = dense_ridge_dataset(60, 8, 9);
    let lambda = 0.01;
    let w_closed = ridge_closed_form(&ds, lambda);

    let mut c = cfg(Algorithm::Dso, 400, lambda);
    c.model.loss = LossKind::Square;
    c.optim.eta0 = 0.5;
    let r = dso::coordinator::train(&c, &ds, None).unwrap();
    let p = Problem::new(Loss::Square, Regularizer::L2, lambda);
    let w_closed_f32: Vec<f32> = w_closed.iter().map(|&v| v as f32).collect();
    let p_closed = p.primal(&ds, &w_closed_f32);
    let rel = (r.final_primal - p_closed) / p_closed.abs().max(1e-12);
    assert!(rel < 0.05, "dso {} vs closed form {p_closed} (rel {rel})", r.final_primal);
}

/// The same analytic target, reached on **both α recurrences**: p = 1
/// makes every row group exactly d = 8 = LANES entries (lane-eligible,
/// so the engine dispatches the affine-α square-loss kernel) while
/// p = 4 splits rows into 2-entry groups (scalar kernel). Both must
/// converge to the normal-equations optimum — the affine closed-form
/// composition may differ from the scalar recurrence only at
/// tolerance level, never in the fixed point.
#[test]
fn square_ridge_scalar_and_affine_paths_match_closed_form() {
    let ds = dense_ridge_dataset(60, 8, 9);
    let lambda = 0.01;
    let w_closed = ridge_closed_form(&ds, lambda);
    let p = Problem::new(Loss::Square, Regularizer::L2, lambda);
    let w_closed_f32: Vec<f32> = w_closed.iter().map(|&v| v as f32).collect();
    let p_closed = p.primal(&ds, &w_closed_f32);

    let mut primals = Vec::new();
    for (machines, want_lanes) in [(1usize, true), (4usize, false)] {
        let mut c = cfg(Algorithm::Dso, 400, lambda);
        c.model.loss = LossKind::Square;
        c.optim.eta0 = 0.5;
        c.cluster.machines = machines;
        // Prove which kernel the run dispatches: with p = 1 the single
        // block's groups are lane-eligible (affine path for square),
        // with p = 4 every group is short (scalar path).
        let setup = dso::coordinator::DsoSetup::new(&c, &ds);
        let has_lanes = (0..setup.p)
            .any(|q| (0..setup.p).any(|r| setup.omega.block(q, r).has_lanes()));
        assert_eq!(has_lanes, want_lanes, "machines={machines}");
        let r = dso::coordinator::train(&c, &ds, None).unwrap();
        let rel = (r.final_primal - p_closed) / p_closed.abs().max(1e-12);
        assert!(
            rel < 0.05,
            "machines={machines} (affine={want_lanes}): dso {} vs closed form {p_closed} \
             (rel {rel})",
            r.final_primal
        );
        primals.push(r.final_primal);
    }
    // Both paths land on the same optimum (they differ only in
    // float-rounding of the trajectory, not in the problem solved).
    let rel = (primals[0] - primals[1]).abs() / primals[1].abs().max(1e-12);
    assert!(rel < 0.02, "affine {} vs scalar {} (rel {rel})", primals[0], primals[1]);
}

/// Theorem 1: duality gap ≲ C/√T. Check gap(T)·√T is bounded by a
/// small multiple of its early value (i.e. the rate is at least 1/√T
/// up to constants) and that the gap is monotonically shrinking in
/// coarse windows.
#[test]
fn gap_rate_matches_theorem1_shape() {
    // Theorem 1 analyzes η_t = η₀/√t with a problem-dependent η₀
    // (∝ √(D/C), C ∝ |Ω|²); the paper's experiments use AdaGrad, which
    // adapts those scales per coordinate. We run the experimental
    // configuration and assert the gap keeps shrinking at a sub-√T-
    // compatible pace over a long horizon.
    let ds = dataset(500, 100, 6);
    let c = cfg(Algorithm::Dso, 200, 1e-3);
    let r = dso::coordinator::train(&c, &ds, None).unwrap();
    let gaps = r.history.col("gap").unwrap();
    let epochs = r.history.col("epoch").unwrap();
    assert!(gaps.iter().all(|&g| g >= -1e-6), "weak duality violated");
    let idx10 = epochs.iter().position(|&e| e >= 10.0).unwrap();
    let early = gaps[idx10];
    let late = *gaps.last().unwrap();
    assert!(
        late < 0.6 * early,
        "gap stalled: epoch10 {early} -> epoch200 {late}"
    );
    // Coarse monotonicity: second-half mean < first-half mean.
    let half = gaps.len() / 2;
    let first: f64 = gaps[..half].iter().sum::<f64>() / half as f64;
    let second: f64 = gaps[half..].iter().sum::<f64>() / (gaps.len() - half) as f64;
    assert!(second < first, "gap not shrinking: {first} -> {second}");
}

/// The paper's §5.1 observation — DSO slower than SGD per epoch (it
/// optimizes m+d parameters) but both eventually converge; and §5.2 —
/// PSGD stalls above the optimum reached by DSO on sparse data.
#[test]
fn paper_shape_psgd_stalls_above_dso() {
    // On the paper's large sparse workloads PSGD's averaging bias keeps
    // it above DSO; on small well-conditioned synthetics both converge,
    // so the robust form of the claim is "DSO matches or beats PSGD"
    // (within stochastic tolerance) *and* provides a duality certificate
    // PSGD cannot.
    let ds = dataset(600, 120, 7);
    let lambda = 1e-4;
    let d = dso::coordinator::train(&cfg(Algorithm::Dso, 300, lambda), &ds, None).unwrap();
    let p = dso::coordinator::train(&cfg(Algorithm::Psgd, 300, lambda), &ds, None).unwrap();
    assert!(
        d.final_primal <= p.final_primal * 1.05,
        "dso {} vs psgd {}",
        d.final_primal,
        p.final_primal
    );
    assert!(d.final_gap.is_finite() && d.final_gap >= -1e-6);
    assert!(p.final_gap.is_nan(), "psgd has no dual certificate");
}
