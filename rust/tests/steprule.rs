//! The adaptive step rule (η = η₀/√(1+Σg²), arXiv:1802.05811) as a
//! first-class `StepRule` arm: convergence on the synthetic problem,
//! Lemma-2 bit-identity with its accumulators shipped around the ring,
//! an objective band against AdaGrad, and acceptance across the async
//! engine and the SGD baselines. The packed-vs-COO-oracle differential
//! coverage lives with the kernels (`coordinator::updates` tests, which
//! parametrize every rule including `Adaptive`).

use dso::api::Trainer;
use dso::config::{Algorithm, StepKind, TrainConfig};
use dso::data::synth::SparseSpec;
use dso::data::Dataset;

fn dataset(seed: u64) -> Dataset {
    SparseSpec {
        name: "steprule".into(),
        m: 300,
        d: 80,
        nnz_per_row: 6.0,
        zipf_s: 0.7,
        label_noise: 0.03,
        pos_frac: 0.5,
        seed,
    }
    .generate()
}

fn cfg(step: StepKind, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.optim.step = step;
    cfg.optim.epochs = epochs;
    cfg.optim.eta0 = 0.2;
    cfg.optim.seed = 7;
    cfg.model.lambda = 1e-3;
    cfg.cluster.machines = 2;
    cfg.cluster.cores = 1;
    cfg.monitor.every = 1;
    cfg
}

#[test]
fn adaptive_rule_converges_on_synthetic() {
    let ds = dataset(3);
    let (train, test) = ds.split(0.2, 7);
    let r = Trainer::new(cfg(StepKind::Adaptive, 30))
        .fit(&train, Some(&test))
        .unwrap()
        .into_result();
    let primal = r.history.col("primal").unwrap();
    assert!(primal.len() >= 2);
    let (first, last) = (primal[0], *primal.last().unwrap());
    assert!(last.is_finite() && last < first, "objective must decrease: {first} -> {last}");
    assert!(r.final_gap.is_finite() && r.final_gap >= -1e-9, "gap stays a gap");
    let err = r.history.col("test_error").and_then(|c| c.last().copied()).unwrap();
    assert!(err < 0.45, "adaptive rule should beat coin-flipping, got {err}");
}

#[test]
fn adaptive_threaded_equals_replay_bitwise() {
    // The unit-offset accumulators are state: Lemma 2 only survives if
    // they travel with the rotating blocks exactly like AdaGrad's.
    let ds = dataset(3);
    let c = cfg(StepKind::Adaptive, 4);
    let threaded = Trainer::new(c.clone()).fit(&ds, None).unwrap().into_result();
    let replayed = Trainer::new(c).replay(true).fit(&ds, None).unwrap().into_result();
    assert_eq!(threaded.w, replayed.w, "threaded and serial replay diverged");
    assert_eq!(threaded.alpha, replayed.alpha);
    assert_eq!(threaded.total_updates, replayed.total_updates);
}

#[test]
fn adaptive_tracks_adagrad_objective_band() {
    let ds = dataset(5);
    let adaptive = Trainer::new(cfg(StepKind::Adaptive, 40)).fit(&ds, None).unwrap().into_result();
    let adagrad = Trainer::new(cfg(StepKind::AdaGrad, 40)).fit(&ds, None).unwrap().into_result();
    let (ap, gp) = (adaptive.final_primal, adagrad.final_primal);
    assert!(ap.is_finite() && gp.is_finite());
    // Same accumulator discipline, ε floor vs unit offset: after 40
    // epochs on a small convex problem the two land close together.
    assert!(
        (ap - gp).abs() <= 0.25 * gp.abs().max(1e-9),
        "adaptive {ap} strayed from adagrad {gp}"
    );
}

#[test]
fn async_and_baselines_accept_adaptive() {
    let ds = dataset(9);
    // Async NOMAD ships the accumulator state with the blocks, so the
    // adaptive rule is admissible there too.
    for p in [1usize, 2] {
        let mut c = cfg(StepKind::Adaptive, 3);
        c.optim.algorithm = Algorithm::DsoAsync;
        c.cluster.machines = p;
        let r = Trainer::new(c).fit(&ds, None).unwrap().into_result();
        assert!(r.total_updates > 0 && r.final_primal.is_finite(), "async p={p}");
    }
    // And the serial/parallel SGD baselines take it as a schedule.
    for algo in [Algorithm::Sgd, Algorithm::Psgd] {
        let mut c = cfg(StepKind::Adaptive, 5);
        c.optim.algorithm = algo;
        let r = Trainer::new(c).fit(&ds, None).unwrap().into_result();
        assert!(r.final_primal.is_finite(), "{algo:?} under the adaptive schedule");
    }
}

#[test]
fn adaptive_parses_and_ships_accumulators() {
    assert_eq!(StepKind::parse("adaptive").unwrap(), StepKind::Adaptive);
    assert_eq!(StepKind::Adaptive.name(), "adaptive");
    let err = StepKind::parse("bogus").unwrap_err();
    assert!(err.contains("adaptive"), "the error must advertise the new arm: {err}");
    use dso::coordinator::updates::StepRule;
    assert!(StepRule::Adaptive(0.1).uses_acc(), "adaptive state must ride the ring");
    assert!(StepRule::AdaGrad(0.1).uses_acc());
    assert!(!StepRule::Fixed(0.1).uses_acc());
}
