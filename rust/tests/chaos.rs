//! Chaos suite: seeded fault schedules against the async NOMAD ring
//! and the sync engine's checkpoint/resume path.
//!
//! Every schedule here is deterministic — `FaultPlan` trigger points
//! are exact `(worker, epoch, iter)` coordinates, so a failing run
//! reproduces under `cargo test --test chaos`. The suite pins the
//! ISSUE-6 acceptance gates: injected death at p = 4 completes and
//! reports through the observer stream, crash-and-resume is
//! bit-identical to the uninterrupted run, and timing faults never
//! move the sync trajectory (Lemma 2).

use dso::api::Trainer;
use dso::config::{Algorithm, LossKind, TrainConfig};
use dso::coordinator::{EpochObserver, EvalRow, TrainResult, WorkerFailure};
use dso::data::synth::SparseSpec;
use dso::data::Dataset;

fn dataset(seed: u64) -> Dataset {
    SparseSpec {
        name: "chaos".into(),
        m: 240,
        d: 60,
        nnz_per_row: 6.0,
        zipf_s: 0.7,
        label_noise: 0.03,
        pos_frac: 0.5,
        seed,
    }
    .generate()
}

fn cfg(algo: Algorithm, p: usize, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.optim.algorithm = algo;
    cfg.optim.epochs = epochs;
    cfg.optim.eta0 = 0.2;
    cfg.optim.seed = 7;
    cfg.model.lambda = 1e-3;
    cfg.cluster.machines = p;
    cfg.cluster.cores = 1;
    cfg.monitor.every = 1;
    cfg
}

fn assert_recovered_shape(r: &TrainResult, ds: &Dataset, label: &str) {
    assert_eq!(r.w.len(), ds.d(), "{label}: w not fully recovered");
    assert_eq!(r.alpha.len(), ds.m(), "{label}: alpha not fully recovered");
    assert!(r.final_primal.is_finite(), "{label}: non-finite objective");
}

/// Observer that records both streams — the per-epoch rows and the
/// recovered worker failures (`on_failure` is the trait's optional
/// second channel; the closure blanket impl never sees it).
#[derive(Default)]
struct Recorder {
    rows: Vec<EvalRow>,
    failures: Vec<WorkerFailure>,
}

impl EpochObserver for Recorder {
    fn on_epoch(&mut self, row: &EvalRow) {
        self.rows.push(*row);
    }
    fn on_failure(&mut self, f: &WorkerFailure) {
        self.failures.push(f.clone());
    }
}

#[test]
fn chaos_async_death_is_recovered_and_reported() {
    let ds = dataset(3);
    let mut rec = Recorder::default();
    let r = Trainer::new(cfg(Algorithm::DsoAsync, 4, 2))
        .faults("die@2.0.2")
        .observer(&mut rec)
        .fit(&ds, None)
        .unwrap()
        .into_result();
    assert_eq!(r.failures.len(), 1, "exactly the injected death");
    let f = &r.failures[0];
    assert_eq!(f.worker, 2);
    assert_eq!(f.reason, "injected death");
    assert!(f.stripes_reassigned >= 1, "dead worker's stripes must move");
    // The same failure reaches the observer stream, before the final row.
    assert_eq!(rec.failures, r.failures, "observer saw a different failure set");
    let last = rec.rows.last().expect("async records one end-of-run row");
    assert_eq!(last.failures, 1, "failure count missing from the history row");
    assert_recovered_shape(&r, &ds, "die@2.0.2");
}

#[test]
fn chaos_schedules_complete_across_losses_and_ring_sizes() {
    let ds = dataset(3);
    for loss in [LossKind::Hinge, LossKind::Logistic, LossKind::Square] {
        for (p, faults) in [
            (2usize, "die@1.0.1,stall@0.0.0:3"),
            (4usize, "die@2.0.2,stall@0.0.1:3,delay@1.0.0:2"),
        ] {
            let mut c = cfg(Algorithm::DsoAsync, p, 3);
            c.model.loss = loss;
            let label = format!("{}/p{p}", loss.name());
            let clean = Trainer::new(c.clone())
                .fit(&ds, None)
                .unwrap_or_else(|e| panic!("{label} clean: {e}"))
                .into_result();
            let r = Trainer::new(c)
                .faults(faults)
                .fit(&ds, None)
                .unwrap_or_else(|e| panic!("{label}: {e}"))
                .into_result();
            assert_eq!(r.failures.len(), 1, "{label}: one death injected");
            assert_recovered_shape(&r, &ds, &label);
            // The degraded ring does the same total work (target visits
            // count survivors' sweeps), so the objective must land in
            // the same basin as the fault-free run — a lost stripe or a
            // double-counted token would blow this band.
            let rel = (r.final_primal - clean.final_primal).abs()
                / clean.final_primal.abs().max(1e-12);
            assert!(
                rel < 0.5,
                "{label}: faulted {} vs clean {} (rel {rel})",
                r.final_primal,
                clean.final_primal
            );
        }
    }
}

#[test]
fn chaos_checkpoint_resume_matches_uninterrupted_bitwise() {
    let ds = dataset(3);
    let ck = std::env::temp_dir().join("dso-chaos-resume.ck");
    let ck_path = ck.to_str().unwrap();

    let full = Trainer::new(cfg(Algorithm::Dso, 3, 8)).fit(&ds, None).unwrap().into_result();

    // "Crash" after epoch 3: train to 3, snapshotting at the boundary.
    Trainer::new(cfg(Algorithm::Dso, 3, 3))
        .checkpoint_every(3)
        .checkpoint_path(ck_path)
        .fit(&ds, None)
        .unwrap();
    assert!(ck.exists(), "no checkpoint written at epoch 3");

    // Resume a fresh process image and run out to epoch 8.
    let resumed = Trainer::new(cfg(Algorithm::Dso, 3, 8))
        .resume(ck_path)
        .fit(&ds, None)
        .unwrap()
        .into_result();
    std::fs::remove_file(&ck).ok();

    assert_eq!(resumed.w, full.w, "resume moved w");
    assert_eq!(resumed.alpha, full.alpha, "resume moved alpha");
    assert_eq!(resumed.total_updates, full.total_updates, "resume moved the update count");
}

#[test]
fn chaos_resume_refuses_foreign_checkpoint() {
    let ds = dataset(3);
    let ck = std::env::temp_dir().join("dso-chaos-foreign.ck");
    let ck_path = ck.to_str().unwrap();
    Trainer::new(cfg(Algorithm::Dso, 2, 2))
        .checkpoint_every(2)
        .checkpoint_path(ck_path)
        .fit(&ds, None)
        .unwrap();

    // Same data, different seed => different update sequence; the
    // fingerprint must reject rather than silently splice trajectories.
    let mut foreign = cfg(Algorithm::Dso, 2, 4);
    foreign.optim.seed = 8;
    let err = Trainer::new(foreign).resume(ck_path).fit(&ds, None).unwrap_err();
    assert!(format!("{err}").contains("refusing to resume"), "{err}");
    std::fs::remove_file(&ck).ok();

    // A missing checkpoint file is a load error, not a clean start.
    let missing = std::env::temp_dir().join("dso-chaos-no-such.ck");
    assert!(Trainer::new(cfg(Algorithm::Dso, 2, 2))
        .resume(missing.to_str().unwrap())
        .fit(&ds, None)
        .is_err());
}

#[test]
fn chaos_sync_timing_faults_preserve_bit_identity() {
    // Stalls and delays perturb scheduling only; Lemma 2 says the sync
    // trajectory is invariant to interleaving, so the faulted threaded
    // run must match the fault-free serial replay bit for bit.
    let ds = dataset(3);
    let faulted = Trainer::new(cfg(Algorithm::Dso, 3, 3))
        .faults("stall@0.1.0:5,delay@1.0.1:2")
        .fit(&ds, None)
        .unwrap()
        .into_result();
    let replay = Trainer::new(cfg(Algorithm::Dso, 3, 3))
        .replay(true)
        .fit(&ds, None)
        .unwrap()
        .into_result();
    assert_eq!(faulted.w, replay.w, "stall/delay moved w");
    assert_eq!(faulted.alpha, replay.alpha, "stall/delay moved alpha");
    assert!(faulted.failures.is_empty(), "timing faults are not failures");
}

#[test]
fn chaos_straggler_wait_time_surfaces_in_history() {
    let ds = dataset(3);
    let r = Trainer::new(cfg(Algorithm::DsoAsync, 4, 2))
        .faults("stall@1.0.0:20,stall@3.0.1:10")
        .fit(&ds, None)
        .unwrap()
        .into_result();
    assert!(r.failures.is_empty(), "stalls must not kill workers");
    let wait = r.history.col("wait_s").expect("wait_s column missing");
    let last = *wait.last().unwrap();
    // Every surviving worker exits through at least one bounded-wait
    // timeout, so a stalled ring always accrues positive wait time.
    assert!(last > 0.0 && last.is_finite(), "wait_s = {last}");
    assert_recovered_shape(&r, &ds, "straggler");
}
