//! Facade pinning: every `Algorithm` × `ExecMode` route through
//! `dso::api::Trainer` must (a) return the same history schema
//! (`HISTORY_COLUMNS`) and (b) be bit-identical to the pre-refactor
//! free functions on a pinned seed config — the API redesign moved the
//! routing and the kernel dispatch, not the trajectories.

// NOTE: this suite deliberately exercises the deprecated free-function
// shims — it pins them bit-for-bit against the `dso::api::Trainer`
// facade (DESIGN.md §Solver-API deprecation map).
#![allow(deprecated)]

use dso::api::{Model, Trainer};
use dso::config::{Algorithm, ExecMode, TrainConfig};
use dso::coordinator::monitor::HISTORY_COLUMNS;
use dso::coordinator::{EvalRow, TrainResult};
use dso::data::synth::SparseSpec;
use dso::data::Dataset;

fn dataset(seed: u64) -> Dataset {
    SparseSpec {
        name: "trainer-api".into(),
        m: 300,
        d: 80,
        nnz_per_row: 6.0,
        zipf_s: 0.7,
        label_noise: 0.03,
        pos_frac: 0.5,
        seed,
    }
    .generate()
}

/// The pinned seed config the bit-identity assertions run under.
fn base_cfg(algo: Algorithm, p: usize, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.optim.algorithm = algo;
    cfg.optim.epochs = epochs;
    cfg.optim.eta0 = 0.2;
    cfg.optim.seed = 7;
    cfg.model.lambda = 1e-3;
    cfg.cluster.machines = p;
    cfg.cluster.cores = 1;
    cfg.monitor.every = 1;
    cfg
}

fn fit(cfg: &TrainConfig) -> TrainResult {
    Trainer::new(cfg.clone()).fit(&dataset(3), None).unwrap().into_result()
}

fn assert_bit_identical(a: &TrainResult, b: &TrainResult, label: &str) {
    assert_eq!(a.w, b.w, "{label}: w moved");
    assert_eq!(a.alpha, b.alpha, "{label}: alpha moved");
    assert_eq!(a.total_updates, b.total_updates, "{label}: update count moved");
    assert_eq!(a.algorithm, b.algorithm, "{label}: algorithm label moved");
}

#[test]
fn trainer_dso_matches_free_function_bitwise() {
    let ds = dataset(3);
    let cfg = base_cfg(Algorithm::Dso, 3, 4);
    let old = dso::coordinator::train_dso(&cfg, &ds, None).unwrap();
    assert_bit_identical(&fit(&cfg), &old, "dso/scalar");
}

#[test]
fn trainer_replay_matches_run_replay_bitwise() {
    let ds = dataset(3);
    let cfg = base_cfg(Algorithm::Dso, 3, 4);
    let old = dso::coordinator::run_replay(&cfg, &ds, None).unwrap();
    let new = Trainer::new(cfg).replay(true).fit(&ds, None).unwrap().into_result();
    assert_bit_identical(&new, &old, "dso/replay");
    // And replay itself is still Lemma-2-identical to the threaded run.
    let threaded = fit(&base_cfg(Algorithm::Dso, 3, 4));
    assert_eq!(new.w, threaded.w);
    assert_eq!(new.alpha, threaded.alpha);
}

#[test]
fn trainer_sampled_route_matches_free_function_bitwise() {
    // The subsampled kernel's draw stream moved into SweepPlan; the
    // sequence must not have changed.
    let ds = dataset(3);
    let mut cfg = base_cfg(Algorithm::Dso, 2, 3);
    cfg.cluster.updates_per_block = 5;
    let old = dso::coordinator::train_dso(&cfg, &ds, None).unwrap();
    assert_bit_identical(&fit(&cfg), &old, "dso/sampled");
    let replayed = Trainer::new(cfg).replay(true).fit(&ds, None).unwrap().into_result();
    assert_eq!(old.w, replayed.w, "sampled replay identity");
}

#[test]
fn trainer_async_single_worker_matches_free_function_bitwise() {
    // Async trajectories depend on scheduling at p > 1; p = 1 is the
    // deterministic pinning point (one worker, one circulating block).
    let ds = dataset(3);
    let cfg = base_cfg(Algorithm::DsoAsync, 1, 3);
    let old = dso::coordinator::train_dso_async(&cfg, &ds, None).unwrap();
    assert_bit_identical(&fit(&cfg), &old, "dso-async/p1");
}

#[test]
fn trainer_baselines_match_free_functions_bitwise() {
    let ds = dataset(3);
    for (algo, label) in [
        (Algorithm::Sgd, "sgd"),
        (Algorithm::Psgd, "psgd"),
        (Algorithm::Bmrm, "bmrm"),
    ] {
        let cfg = base_cfg(algo, 2, 4);
        let old = match algo {
            Algorithm::Sgd => dso::baselines::sgd::train_sgd(&cfg, &ds, None).unwrap(),
            Algorithm::Psgd => dso::baselines::psgd::train_psgd(&cfg, &ds, None).unwrap(),
            Algorithm::Bmrm => dso::baselines::bmrm::train_bmrm(&cfg, &ds, None).unwrap(),
            _ => unreachable!(),
        };
        assert_bit_identical(&fit(&cfg), &old, label);
    }
}

#[test]
fn every_route_returns_the_same_history_schema() {
    let ds = dataset(3);
    let (train, test) = ds.split(0.25, 7);
    for algo in [
        Algorithm::Dso,
        Algorithm::DsoAsync,
        Algorithm::Sgd,
        Algorithm::Psgd,
        Algorithm::Bmrm,
    ] {
        let cfg = base_cfg(algo, 2, 3);
        let r = Trainer::new(cfg)
            .fit(&train, Some(&test))
            .unwrap()
            .into_result();
        let want: Vec<String> = HISTORY_COLUMNS.iter().map(|s| s.to_string()).collect();
        assert_eq!(r.history.columns, want, "{algo:?} history schema");
        assert!(!r.history.rows.is_empty(), "{algo:?} recorded no rows");
    }
}

#[cfg(not(feature = "xla"))]
#[test]
fn trainer_tile_route_reports_the_stub_error() {
    // Same actionable error through the facade as through the old
    // coordinator::train routing.
    let ds = dataset(3);
    let cfg = base_cfg(Algorithm::Dso, 2, 2);
    let new_err = Trainer::new(cfg.clone())
        .mode(ExecMode::Tile)
        .fit(&ds, None)
        .unwrap_err();
    let mut old_cfg = cfg;
    old_cfg.cluster.mode = ExecMode::Tile;
    let old_err = dso::coordinator::train(&old_cfg, &ds, None).unwrap_err();
    for err in [&new_err, &old_err] {
        let msg = format!("{err}");
        assert!(msg.contains("tile mode requires the PJRT runtime"), "msg: {msg}");
        assert!(msg.contains("--features xla"), "msg: {msg}");
    }
}

#[test]
fn replay_on_non_dso_routes_is_an_actionable_error() {
    let ds = dataset(3);
    for algo in [Algorithm::Sgd, Algorithm::DsoAsync, Algorithm::Bmrm] {
        let err = Trainer::new(base_cfg(algo, 2, 2))
            .replay(true)
            .fit(&ds, None)
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("replay"), "{algo:?}: {msg}");
        assert!(msg.contains("algorithm = \"dso\""), "{algo:?}: {msg}");
    }
}

#[test]
fn observer_streams_exactly_the_history_rows() {
    let ds = dataset(5);
    let (train, test) = ds.split(0.25, 7);
    let cfg = base_cfg(Algorithm::Dso, 2, 5);
    let mut streamed: Vec<EvalRow> = Vec::new();
    let mut on_epoch = |row: &EvalRow| streamed.push(*row);
    let r = Trainer::new(cfg)
        .observer(&mut on_epoch)
        .fit(&train, Some(&test))
        .unwrap()
        .into_result();
    assert_eq!(streamed.len(), r.history.len(), "one callback per recorded row");
    let primal = r.history.col("primal").unwrap();
    let epochs = r.history.col("epoch").unwrap();
    for (k, row) in streamed.iter().enumerate() {
        assert_eq!(row.epoch as f64, epochs[k]);
        assert_eq!(row.primal, primal[k]);
        assert_eq!(row.gap, row.primal - row.dual);
    }
}

#[test]
fn fitted_predict_and_model_roundtrip_through_training() {
    let ds = dataset(9);
    let (train, test) = ds.split(0.25, 7);
    let cfg = base_cfg(Algorithm::Dso, 2, 10);
    let fitted = Trainer::new(cfg).fit(&train, Some(&test)).unwrap();

    // predict() margins agree with the dataset's own error definition.
    let margins = fitted.predict(&test.x).unwrap();
    assert_eq!(margins.len(), test.m());
    let labels = fitted.predict_labels(&test.x).unwrap();
    let wrong = labels
        .iter()
        .zip(&test.y)
        .filter(|(a, b)| (**a - **b).abs() > 1e-6)
        .count();
    let err = wrong as f64 / test.m() as f64;
    assert!((err - fitted.error(&test)).abs() < 1e-12);

    // Save/load round trip is bit-exact and predicts identically.
    let path = std::env::temp_dir().join("dso-trainer-api.model");
    fitted.save(&path).unwrap();
    let model = Model::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(model.w.len(), fitted.w().len());
    for (a, b) in fitted.w().iter().zip(&model.w) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(model.predict(&test.x).unwrap(), margins);
    assert_eq!(model.algorithm, "dso");
}
