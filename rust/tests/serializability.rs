//! Lemma 2 in executable form: the p-threaded DSO run must be exactly
//! serializable — replaying the same update sequence on one thread in
//! the canonical (inner-iteration, worker-rank) order reproduces the
//! distributed parameters bit-for-bit, for every worker count, loss,
//! step rule, and sampling mode.

// NOTE: this suite deliberately exercises the deprecated free-function
// shims — it pins them bit-for-bit against the `dso::api::Trainer`
// facade (DESIGN.md §Solver-API deprecation map).
#![allow(deprecated)]

use dso::config::{LossKind, StepKind, TrainConfig};
use dso::coordinator::{run_replay, train_dso};
use dso::data::synth::SparseSpec;
use dso::data::Dataset;

fn dataset(m: usize, d: usize, seed: u64) -> Dataset {
    SparseSpec {
        name: "ser".into(),
        m,
        d,
        nnz_per_row: 5.0,
        zipf_s: 0.8,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed,
    }
    .generate()
}

fn cfg(p: usize, epochs: usize) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.optim.epochs = epochs;
    c.optim.eta0 = 0.3;
    c.model.lambda = 1e-3;
    c.cluster.machines = p;
    c.cluster.cores = 1;
    c.monitor.every = 0;
    c
}

fn assert_bitwise_equal(p: usize, c: &TrainConfig, ds: &Dataset) {
    let threaded = train_dso(c, ds, None).unwrap();
    let replayed = run_replay(c, ds, None).unwrap();
    assert_eq!(threaded.w, replayed.w, "w mismatch at p={p}");
    assert_eq!(threaded.alpha, replayed.alpha, "alpha mismatch at p={p}");
    assert_eq!(threaded.total_updates, replayed.total_updates);
}

#[test]
fn serializable_across_worker_counts() {
    let ds = dataset(240, 96, 1);
    for p in [1usize, 2, 3, 4, 6, 8] {
        let c = cfg(p, 4);
        assert_bitwise_equal(p, &c, &ds);
    }
}

#[test]
fn serializable_across_losses() {
    let ds = dataset(180, 60, 2);
    for loss in [LossKind::Hinge, LossKind::Logistic, LossKind::Square] {
        let mut c = cfg(4, 3);
        c.model.loss = loss;
        assert_bitwise_equal(4, &c, &ds);
    }
}

#[test]
fn serializable_across_step_rules() {
    let ds = dataset(180, 60, 3);
    for step in [StepKind::Const, StepKind::InvSqrt, StepKind::AdaGrad] {
        let mut c = cfg(3, 3);
        c.optim.step = step;
        assert_bitwise_equal(3, &c, &ds);
    }
}

#[test]
fn serializable_with_subsampling() {
    // updates_per_block > 0 exercises the seeded per-(epoch,q,r) RNG.
    let ds = dataset(200, 80, 4);
    let mut c = cfg(4, 5);
    c.cluster.updates_per_block = 7;
    assert_bitwise_equal(4, &c, &ds);
}

#[test]
fn serializable_with_dcd_warmstart() {
    let ds = dataset(200, 80, 5);
    let mut c = cfg(4, 3);
    c.optim.dcd_init = true;
    assert_bitwise_equal(4, &c, &ds);
}

#[test]
fn serializable_on_lane_dispatch_paths() {
    // The sparse datasets above keep row groups short, so the sweeps
    // above exercise Lemma 2 only through the scalar kernel. Dense rows
    // (nnz_per_row ≫ LANES-free threshold) force the engines' lane
    // dispatch: hinge/logistic take the SIMD lane kernel, square the
    // affine-α kernel — the bit-identity must hold through every one,
    // for full and subsampled sweeps and both step-rule families.
    let ds = SparseSpec {
        name: "ser-lanes".into(),
        m: 180,
        d: 60,
        nnz_per_row: 18.0,
        zipf_s: 0.6,
        label_noise: 0.05,
        pos_frac: 0.5,
        seed: 9,
    }
    .generate();
    // Prove the decomposition the engine builds actually has
    // lane-eligible groups — otherwise this test would silently
    // degenerate to the scalar coverage above.
    let p = 3;
    let rp = dso::partition::Partition::even(ds.m(), p);
    let cp = dso::partition::Partition::even(ds.d(), p);
    let om = dso::partition::PackedBlocks::build(&ds.x, &rp, &cp);
    assert!(
        (0..p).any(|q| (0..p).any(|r| om.block(q, r).has_lanes())),
        "dataset not dense enough for the lane path"
    );
    for loss in [LossKind::Hinge, LossKind::Logistic, LossKind::Square] {
        for (upb, step) in [(0usize, StepKind::AdaGrad), (7, StepKind::AdaGrad), (0, StepKind::Const)]
        {
            let mut c = cfg(p, 3);
            c.model.loss = loss;
            c.optim.step = step;
            c.cluster.updates_per_block = upb;
            assert_bitwise_equal(p, &c, &ds);
        }
    }
}

#[test]
fn repeated_threaded_runs_identical() {
    // Determinism under real thread scheduling: 10 repetitions must
    // agree exactly (disjoint blocks ⇒ no data races by construction).
    let ds = dataset(300, 100, 6);
    let c = cfg(8, 2);
    let first = train_dso(&c, &ds, None).unwrap();
    for rep in 0..9 {
        let r = train_dso(&c, &ds, None).unwrap();
        assert_eq!(first.w, r.w, "rep {rep}");
        assert_eq!(first.alpha, r.alpha, "rep {rep}");
    }
}

#[test]
fn different_seed_changes_nothing_when_sweeping_all_entries() {
    // With updates_per_block = 0 (full sweeps) the trajectory is
    // seed-independent: the sweep order is fixed by the block layout.
    let ds = dataset(150, 50, 7);
    let mut c1 = cfg(3, 3);
    c1.optim.seed = 1;
    let mut c2 = cfg(3, 3);
    c2.optim.seed = 999;
    let a = train_dso(&c1, &ds, None).unwrap();
    let b = train_dso(&c2, &ds, None).unwrap();
    assert_eq!(a.w, b.w);
}

#[test]
fn subsampling_seed_changes_trajectory() {
    let ds = dataset(150, 50, 8);
    let mut c1 = cfg(3, 3);
    c1.cluster.updates_per_block = 5;
    c1.optim.seed = 1;
    let mut c2 = c1.clone();
    c2.optim.seed = 2;
    let a = train_dso(&c1, &ds, None).unwrap();
    let b = train_dso(&c2, &ds, None).unwrap();
    assert_ne!(a.w, b.w);
}
