//! Serving microbenches (DESIGN.md §Serving):
//!
//! * `predict_*` — batched sparse inference vs the old per-row scalar
//!   loop on the same request batch: `predict_scalar_row_dot` (one
//!   `Csr::row_dot` per row — exactly what `Fitted::predict` did before
//!   the serve subsystem), `predict_batched_portable` (lane-major
//!   packed layout, portable fold) and `predict_batched_avx2` (hardware
//!   gathers, where avx2+fma is present). The packing cost is measured
//!   separately (`predict_pack`) — a server packs each request batch
//!   once and scores it once, so the honest comparison is pack+fold vs
//!   the scalar loop; both are recorded.
//!
//! * `steprule_*` — the adaptive rule (η₀/√(1+Σg²), arXiv:1802.05811)
//!   vs AdaGrad on the standard 64k-entry lane sweep: same accumulator
//!   traffic, ε floor swapped for the unit offset.
//!
//! Run with `DSO_BENCH_JSON=1` to record `BENCH_predict.json` and
//! `BENCH_steprule.json` (tracked by the CI smoke for the cross-PR
//! perf trajectory).

use dso::coordinator::updates::{sweep_lanes, PackedCtx, PackedState, StepRule};
use dso::data::synth::SparseSpec;
use dso::losses::{Loss, Regularizer};
use dso::partition::{PackedBlocks, Partition};
use dso::serve::{predict_batch, PackedRequests};
use dso::simd::SimdLevel;
use dso::util::bench::{human_time, Runner};

fn main() {
    // A serving-shaped batch: 4k request rows over a 2k-feature model,
    // ≈16 nnz per row (two full lane chunks on average).
    let ds = SparseSpec {
        name: "predict-bench".into(),
        m: 4000,
        d: 2000,
        nnz_per_row: 16.0,
        zipf_s: 0.8,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 1,
    }
    .generate();
    let d = ds.d();
    let w: Vec<f32> = (0..d).map(|j| ((j * 7) % 13) as f32 * 0.05 - 0.3).collect();
    let nnz = ds.nnz() as u64;

    let mut runner = Runner::from_env("predict");
    println!("batch: {} rows, {} nnz, d = {d}", ds.m(), nnz);

    // --- The old scalar predict: one storage-order row_dot per row ---
    runner.bench_units("predict_scalar_row_dot", nnz, || {
        let mut s = 0.0f64;
        for i in 0..ds.m() {
            s += ds.x.row_dot(i, &w);
        }
        s
    });

    // --- Request packing (per-batch server cost) ---
    runner.bench_units("predict_pack", nnz, || {
        PackedRequests::pack(&ds.x, d).expect("bench batch packs").nnz()
    });

    // --- Batched kernel, portable fold ---
    let packed = PackedRequests::pack(&ds.x, d).expect("bench batch packs");
    let mut out = Vec::new();
    runner.bench_units("predict_batched_portable", nnz, || {
        predict_batch(&packed, &w, SimdLevel::Portable, &mut out);
        out.len()
    });

    // --- Batched kernel, AVX2 gathers (where available) ---
    #[cfg(target_arch = "x86_64")]
    {
        if dso::simd::avx2_supported() {
            let mut aout = Vec::new();
            runner.bench_units("predict_batched_avx2", nnz, || {
                predict_batch(&packed, &w, SimdLevel::Avx2, &mut aout);
                aout.len()
            });
        } else {
            println!("    -> avx2 backend unavailable on this host; portable only");
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    println!("    -> avx2 backend unavailable (non-x86_64); portable only");

    let median = |name: &str| runner.results.iter().find(|r| r.name == name).map(|r| r.median());
    if let (Some(sm), Some(bm)) = (median("predict_scalar_row_dot"), median("predict_batched_portable")) {
        println!(
            "    -> scalar {:.1} M nnz/s ({}/batch)  batched-portable {:.1} M nnz/s ({}/batch)  speedup {:.2}x",
            nnz as f64 / sm / 1e6,
            human_time(sm),
            nnz as f64 / bm / 1e6,
            human_time(bm),
            sm / bm
        );
    }
    if let (Some(sm), Some(am)) = (median("predict_scalar_row_dot"), median("predict_batched_avx2")) {
        println!(
            "    -> batched-avx2 {:.1} M nnz/s ({}/batch)  speedup vs scalar {:.2}x",
            nnz as f64 / am / 1e6,
            human_time(am),
            sm / am
        );
    }

    // --- Step-rule pair: AdaGrad vs the adaptive unit-offset rule ---
    let mut rule_runner = Runner::from_env("steprule");
    {
        let rp = Partition::even(ds.m(), 1);
        let cp = Partition::even(ds.d(), 1);
        let omega = PackedBlocks::build(&ds.x, &rp, &cp);
        let block = omega.block(0, 0);
        let y_local = omega.stripe_labels(&ds.y);
        let alpha_bias = omega.stripe_alpha_bias(&ds.y);
        let n = block.nnz() as u64;
        let lambda = 1e-4;
        for (name, rule) in [
            ("steprule_adagrad_hinge", StepRule::AdaGrad(0.1)),
            ("steprule_adaptive_hinge", StepRule::Adaptive(0.1)),
        ] {
            let ctx = PackedCtx {
                loss: Loss::Hinge,
                reg: Regularizer::L2,
                lambda,
                w_bound: Loss::Hinge.w_bound(lambda),
                rule,
                inv_col: &omega.inv_col[0],
                inv_col32: &omega.inv_col32[0],
                inv_row: &omega.inv_row[0],
                y: &y_local[0],
                alpha_bias32: &alpha_bias[0],
            };
            let mut sw = vec![0.01f32; ds.d()];
            let mut sw_acc = vec![0f32; ds.d()];
            let mut salpha = vec![0f32; ds.m()];
            let mut sa_acc = vec![0f32; ds.m()];
            rule_runner.bench_units(name, n, || {
                let mut st = PackedState {
                    w: &mut sw,
                    w_acc: &mut sw_acc,
                    alpha: &mut salpha,
                    a_acc: &mut sa_acc,
                };
                sweep_lanes(block, &ctx, &mut st)
            });
        }
        let median =
            |name: &str| rule_runner.results.iter().find(|r| r.name == name).map(|r| r.median());
        if let (Some(gm), Some(am)) =
            (median("steprule_adagrad_hinge"), median("steprule_adaptive_hinge"))
        {
            println!(
                "    -> adagrad {:.1} M upd/s  adaptive {:.1} M upd/s  ratio {:.2}x",
                n as f64 / gm / 1e6,
                n as f64 / am / 1e6,
                gm / am
            );
        }
    }

    runner.finish("predict");
    rule_runner.finish("steprule");
}
