//! Microbench: the saddle-update hot loop (Eq. 8) — updates per second
//! per worker, across losses and step rules, for BOTH kernels:
//!
//! * `ref_*`    — the seed's COO `sweep_block` (global indices, live
//!                divisions, per-update enum dispatch),
//! * `packed_*` — the `PackedBlocks` + monomorphized `sweep_packed`
//!                production path.
//!
//! The acceptance target for the packed path is ≥2× the reference's
//! median updates/sec on the same 64k-entry block. Run with
//! `DSO_BENCH_JSON=1` to record `BENCH_updates.json` (name, median
//! s/iter, updates/sec) so the perf trajectory is tracked across PRs.

use dso::coordinator::updates::{
    sweep_block, sweep_packed, BlockState, PackedCtx, PackedState, StepRule, SweepCtx,
};
use dso::data::synth::SparseSpec;
use dso::losses::{Loss, Regularizer};
use dso::partition::{PackedBlocks, Partition};
use dso::util::bench::{human_time, Runner};

fn main() {
    let mut runner = Runner::from_env("updates");

    // A realistic block: 64k entries over 4k rows x 2k cols.
    let ds = SparseSpec {
        name: "bench".into(),
        m: 4000,
        d: 2000,
        nnz_per_row: 16.0,
        zipf_s: 0.8,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 1,
    }
    .generate();

    // p = 1: the whole matrix is one Ω^(0,0) block. The packed
    // constructor supplies the SoA layout, reciprocal tables, and the
    // exact entries the reference path sweeps — no hand-rolled per-row
    // collect() churn.
    let rp = Partition::even(ds.m(), 1);
    let cp = Partition::even(ds.d(), 1);
    let omega = PackedBlocks::build(&ds.x, &rp, &cp);
    let block = omega.block(0, 0);
    let entries = omega.block_entries(&ds.x, 0, 0);
    let y_local = omega.stripe_labels(&ds.y);
    let n = block.nnz();
    println!("block: {n} entries");

    let lambda = 1e-4;
    for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
        for (rname, rule) in
            [("fixed", StepRule::Fixed(0.1)), ("adagrad", StepRule::AdaGrad(0.1))]
        {
            let ref_name = format!("ref_sweep_{}_{rname}", loss.name());
            let packed_name = format!("packed_sweep_{}_{rname}", loss.name());
            // --- Seed COO kernel (reference) ---
            let ctx = SweepCtx {
                loss,
                reg: Regularizer::L2,
                lambda,
                m: ds.m() as f64,
                row_counts: &omega.row_counts,
                col_counts: &omega.col_counts,
                y: &ds.y,
                w_bound: loss.w_bound(lambda),
                rule,
            };
            let mut w = vec![0.01f32; ds.d()];
            let mut w_acc = vec![0f32; ds.d()];
            let mut alpha = vec![0f32; ds.m()];
            let mut a_acc = vec![0f32; ds.m()];
            runner.bench_units(&ref_name, n as u64, || {
                let mut st = BlockState {
                    w: &mut w,
                    w_acc: &mut w_acc,
                    w_off: 0,
                    alpha: &mut alpha,
                    a_acc: &mut a_acc,
                    a_off: 0,
                };
                sweep_block(&entries, &ctx, &mut st)
            });

            // --- Packed kernel (production) ---
            let pctx = PackedCtx {
                loss,
                reg: Regularizer::L2,
                lambda,
                w_bound: loss.w_bound(lambda),
                rule,
                inv_col: &omega.inv_col[0],
                inv_row: &omega.inv_row[0],
                y: &y_local[0],
            };
            let mut pw = vec![0.01f32; ds.d()];
            let mut pw_acc = vec![0f32; ds.d()];
            let mut palpha = vec![0f32; ds.m()];
            let mut pa_acc = vec![0f32; ds.m()];
            runner.bench_units(&packed_name, n as u64, || {
                let mut st = PackedState {
                    w: &mut pw,
                    w_acc: &mut pw_acc,
                    alpha: &mut palpha,
                    a_acc: &mut pa_acc,
                };
                sweep_packed(block, &pctx, &mut st)
            });

            // Look results up by name — a CLI bench filter may have
            // skipped either side, and results.last() would mispair.
            let median =
                |name: &str| runner.results.iter().find(|r| r.name == name).map(|r| r.median());
            if let (Some(rm), Some(pm)) = (median(&ref_name), median(&packed_name)) {
                println!(
                    "    -> ref {:.1} M upd/s ({}/upd)  packed {:.1} M upd/s ({}/upd)  speedup {:.2}x",
                    n as f64 / rm / 1e6,
                    human_time(rm / n as f64),
                    n as f64 / pm / 1e6,
                    human_time(pm / n as f64),
                    rm / pm
                );
            }
        }
    }
    runner.finish("updates");
}
