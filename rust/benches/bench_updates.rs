//! Microbench: the scalar saddle-update hot loop (Eq. 8) — updates per
//! second per worker, across losses and step rules. This is the number
//! the §Perf pass optimizes (EXPERIMENTS.md §Perf L3).

use dso::coordinator::updates::{sweep_block, BlockState, StepRule, SweepCtx};
use dso::data::synth::SparseSpec;
use dso::losses::{Loss, Regularizer};
use dso::partition::omega::Entry;
use dso::util::bench::{human_time, Runner};

fn main() {
    let mut runner = Runner::from_env("updates");

    // A realistic block: 64k entries over 4k rows x 2k cols.
    let ds = SparseSpec {
        name: "bench".into(),
        m: 4000,
        d: 2000,
        nnz_per_row: 16.0,
        zipf_s: 0.8,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 1,
    }
    .generate();
    let row_counts: Vec<u32> = (0..ds.m()).map(|i| ds.x.row_nnz(i) as u32).collect();
    let col_counts = ds.x.col_counts();
    let entries: Vec<Entry> = (0..ds.m())
        .flat_map(|i| {
            let (idx, val) = ds.x.row(i);
            idx.iter()
                .zip(val)
                .map(move |(&j, &x)| Entry { i: i as u32, j, x })
                .collect::<Vec<_>>()
        })
        .collect();
    let n = entries.len();
    println!("block: {n} entries");

    for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
        for (rname, rule) in
            [("fixed", StepRule::Fixed(0.1)), ("adagrad", StepRule::AdaGrad(0.1))]
        {
            let ctx = SweepCtx {
                loss,
                reg: Regularizer::L2,
                lambda: 1e-4,
                m: ds.m() as f64,
                row_counts: &row_counts,
                col_counts: &col_counts,
                y: &ds.y,
                w_bound: loss.w_bound(1e-4),
                rule,
            };
            let mut w = vec![0.01f32; ds.d()];
            let mut w_acc = vec![0f32; ds.d()];
            let mut alpha = vec![0f32; ds.m()];
            let mut a_acc = vec![0f32; ds.m()];
            runner.bench(&format!("sweep_{}_{rname}", loss.name()), || {
                let mut st = BlockState {
                    w: &mut w,
                    w_acc: &mut w_acc,
                    w_off: 0,
                    alpha: &mut alpha,
                    a_acc: &mut a_acc,
                    a_off: 0,
                };
                sweep_block(&entries, &ctx, &mut st)
            });
            if let Some(r) = runner.results.last() {
                println!(
                    "    -> {:.1} M updates/s ({}/update)",
                    n as f64 / r.median() / 1e6,
                    human_time(r.median() / n as f64)
                );
            }
        }
    }
    runner.finish("updates");
}
