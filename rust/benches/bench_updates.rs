//! Microbench: the saddle-update hot loop (Eq. 8) — updates per second
//! per worker, across losses and step rules, for ALL THREE kernels:
//!
//! * `ref_*`    — the seed's COO `sweep_block` (global indices, live
//!                divisions, per-update enum dispatch),
//! * `packed_*` — the `PackedBlocks` + monomorphized scalar
//!                `sweep_packed` path,
//! * `lanes_*`  — the lane-major SIMD `sweep_lanes` production path
//!                (8-wide f32 value lanes on the w side).
//!
//! * `affine_*` — square loss only: `sweep_lanes_affine`, the
//!                closed-form affine-α fold (h'(α) = y − α composes,
//!                so a chunk's α recurrence is 8 FMAs instead of 8
//!                sequential gradient evaluations).
//!
//! * `simd_*`   — the explicit-SIMD backend set: the portable
//!                (autovec) lane kernel vs the AVX2 gather/FMA backend
//!                vs the AVX-512 paired 16-wide backend on the same
//!                block (hardware entries recorded only where the host
//!                supports them).
//!
//! * `autotune_*` — the measured `--simd auto` selection: the
//!                per-backend probe throughput on the synthetic
//!                autotune workload, plus an `autotune_resolve_<name>`
//!                marker naming the backend this host's memoized
//!                resolution chose.
//!
//! * `faults_*` — end-to-end async NOMAD runs, fault-free vs with an
//!                injected straggler schedule: the cost of the
//!                bounded-wait token flow when nothing fails, and the
//!                degradation under stalls.
//!
//! * `transport_*` — end-to-end async ring runs across substrates: the
//!                in-thread ring vs the multi-process Unix-socket ring
//!                (`--mode dso-proc`), clean and with a straggler stall
//!                — the price of real frames, checksums, and
//!                process-level scheduling over shared memory.
//!
//! Acceptance targets: packed ≥2× the reference, lanes ≥1.5× packed,
//! both as median updates/sec on the same 64k-entry block. Run with
//! `DSO_BENCH_JSON=1` to record `BENCH_updates.json` (all kernels),
//! `BENCH_lanes.json` (the scalar-vs-lane pair), `BENCH_alpha_lanes.json`
//! (the square-loss scalar-α-vs-affine-α pair), `BENCH_simd.json`
//! (the portable/AVX2/AVX-512 backend set), `BENCH_autotune.json`
//! (the measured-auto probe), `BENCH_faults.json` (the
//! clean-vs-straggler async pair) and `BENCH_transport.json` (the
//! thread-vs-process ring pair) — the CI smoke tracks all of them so
//! the perf trajectory is recorded across PRs.

use dso::coordinator::updates::{
    sweep_block, sweep_lanes, sweep_lanes_affine, sweep_packed, BlockState, PackedCtx,
    PackedState, StepRule, SweepCtx,
};
use dso::data::synth::SparseSpec;
use dso::losses::{Loss, Regularizer};
use dso::partition::{PackedBlock, PackedBlocks, Partition};
use dso::util::bench::{human_time, Runner};

fn main() {
    let mut runner = Runner::from_env("updates");
    // Separate group for the scalar-vs-lane comparison: CI's quick
    // smoke records it as BENCH_lanes.json.
    let mut lane_runner = Runner::from_env("lanes");
    // Separate group for the square-loss α-recurrence comparison
    // (scalar-α lane kernel vs affine-α fold): BENCH_alpha_lanes.json.
    let mut alpha_runner = Runner::from_env("alpha_lanes");

    // A realistic block: 64k entries over 4k rows x 2k cols (≈16 nnz
    // per row group — two full lane chunks on average).
    let ds = SparseSpec {
        name: "bench".into(),
        m: 4000,
        d: 2000,
        nnz_per_row: 16.0,
        zipf_s: 0.8,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 1,
    }
    .generate();

    // p = 1: the whole matrix is one Ω^(0,0) block. The packed
    // constructor supplies the lane-major SoA layout, reciprocal
    // tables, and the exact entries the reference path sweeps — no
    // hand-rolled per-row collect() churn.
    let rp = Partition::even(ds.m(), 1);
    let cp = Partition::even(ds.d(), 1);
    let omega = PackedBlocks::build(&ds.x, &rp, &cp);
    let block = omega.block(0, 0);
    let entries = omega.block_entries(&ds.x, 0, 0);
    let y_local = omega.stripe_labels(&ds.y);
    let alpha_bias = omega.stripe_alpha_bias(&ds.y);
    let n = block.nnz();
    println!(
        "block: {n} entries ({} padded slots, {} lane-eligible groups)",
        block.padded_nnz(),
        block.lane_groups
    );

    let lambda = 1e-4;
    for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
        for (rname, rule) in
            [("fixed", StepRule::Fixed(0.1)), ("adagrad", StepRule::AdaGrad(0.1))]
        {
            let ref_name = format!("ref_sweep_{}_{rname}", loss.name());
            let packed_name = format!("packed_sweep_{}_{rname}", loss.name());
            let lanes_name = format!("lanes_sweep_{}_{rname}", loss.name());
            // --- Seed COO kernel (reference) ---
            let ctx = SweepCtx {
                loss,
                reg: Regularizer::L2,
                lambda,
                m: ds.m() as f64,
                row_counts: &omega.row_counts,
                col_counts: &omega.col_counts,
                y: &ds.y,
                w_bound: loss.w_bound(lambda),
                rule,
            };
            let mut w = vec![0.01f32; ds.d()];
            let mut w_acc = vec![0f32; ds.d()];
            let mut alpha = vec![0f32; ds.m()];
            let mut a_acc = vec![0f32; ds.m()];
            runner.bench_units(&ref_name, n as u64, || {
                let mut st = BlockState {
                    w: &mut w,
                    w_acc: &mut w_acc,
                    w_off: 0,
                    alpha: &mut alpha,
                    a_acc: &mut a_acc,
                    a_off: 0,
                };
                sweep_block(&entries, &ctx, &mut st)
            });

            // --- Packed kernels (scalar + lanes) ---
            let pctx = PackedCtx {
                loss,
                reg: Regularizer::L2,
                lambda,
                w_bound: loss.w_bound(lambda),
                rule,
                inv_col: &omega.inv_col[0],
                inv_col32: &omega.inv_col32[0],
                inv_row: &omega.inv_row[0],
                y: &y_local[0],
                alpha_bias32: &alpha_bias[0],
            };
            let mut pw = vec![0.01f32; ds.d()];
            let mut pw_acc = vec![0f32; ds.d()];
            let mut palpha = vec![0f32; ds.m()];
            let mut pa_acc = vec![0f32; ds.m()];
            runner.bench_units(&packed_name, n as u64, || {
                let mut st = PackedState {
                    w: &mut pw,
                    w_acc: &mut pw_acc,
                    alpha: &mut palpha,
                    a_acc: &mut pa_acc,
                };
                sweep_packed(block, &pctx, &mut st)
            });

            let mut lw = vec![0.01f32; ds.d()];
            let mut lw_acc = vec![0f32; ds.d()];
            let mut lalpha = vec![0f32; ds.m()];
            let mut la_acc = vec![0f32; ds.m()];
            runner.bench_units(&lanes_name, n as u64, || {
                let mut st = PackedState {
                    w: &mut lw,
                    w_acc: &mut lw_acc,
                    alpha: &mut lalpha,
                    a_acc: &mut la_acc,
                };
                sweep_lanes(block, &pctx, &mut st)
            });

            // Mirror the scalar/lane pair into the lanes group so
            // BENCH_lanes.json carries the comparison on its own.
            for name in [&packed_name, &lanes_name] {
                if let Some(r) = runner.results.iter().find(|r| &r.name == name) {
                    lane_runner.results.push(r.clone());
                }
            }

            // --- Affine-α fold (square loss only) ---
            if loss == Loss::Square {
                let affine_name = format!("affine_sweep_{}_{rname}", loss.name());
                let mut aw = vec![0.01f32; ds.d()];
                let mut aw_acc = vec![0f32; ds.d()];
                let mut aalpha = vec![0f32; ds.m()];
                let mut aa_acc = vec![0f32; ds.m()];
                runner.bench_units(&affine_name, n as u64, || {
                    let mut st = PackedState {
                        w: &mut aw,
                        w_acc: &mut aw_acc,
                        alpha: &mut aalpha,
                        a_acc: &mut aa_acc,
                    };
                    sweep_lanes_affine(block, &pctx, &mut st)
                });
                // The α-recurrence pair (scalar-α lane kernel vs
                // affine-α fold) gets its own tracked group.
                for name in [&lanes_name, &affine_name] {
                    if let Some(r) = runner.results.iter().find(|r| &r.name == name) {
                        alpha_runner.results.push(r.clone());
                    }
                }
                let median = |name: &str| {
                    runner.results.iter().find(|r| r.name == name).map(|r| r.median())
                };
                if let (Some(lm), Some(am)) = (median(&lanes_name), median(&affine_name)) {
                    println!(
                        "    -> affine-α {:.1} M upd/s ({}/upd)  speedup vs scalar-α lanes {:.2}x",
                        n as f64 / am / 1e6,
                        human_time(am / n as f64),
                        lm / am
                    );
                }
            }

            // Look results up by name — a CLI bench filter may have
            // skipped any side, and results.last() would mispair.
            let median =
                |name: &str| runner.results.iter().find(|r| r.name == name).map(|r| r.median());
            if let (Some(rm), Some(pm)) = (median(&ref_name), median(&packed_name)) {
                println!(
                    "    -> ref {:.1} M upd/s ({}/upd)  packed {:.1} M upd/s ({}/upd)  speedup {:.2}x",
                    n as f64 / rm / 1e6,
                    human_time(rm / n as f64),
                    n as f64 / pm / 1e6,
                    human_time(pm / n as f64),
                    rm / pm
                );
            }
            if let (Some(pm), Some(lm)) = (median(&packed_name), median(&lanes_name)) {
                println!(
                    "    -> lanes {:.1} M upd/s ({}/upd)  speedup vs packed {:.2}x (target ≥1.5x)",
                    n as f64 / lm / 1e6,
                    human_time(lm / n as f64),
                    pm / lm
                );
            }
        }
    }
    // --- Explicit-SIMD backend pair (BENCH_simd.json) ---
    // Portable vs AVX2 on the same standard 64k-entry block, one plain
    // lane case (hinge/adagrad — gathers + η batch dominate) and one
    // affine case (square/fixed — gathers + coefficient lanes). On
    // hosts without avx2+fma only the portable side is recorded, so
    // the artifact stays well-defined for the cross-PR trajectory.
    let mut simd_runner = Runner::from_env("simd");
    {
        use dso::coordinator::updates::{sweep_lanes_affine_with, sweep_lanes_with};
        use dso::simd::Portable;

        for (loss, rname, rule, affine) in [
            (Loss::Hinge, "adagrad", StepRule::AdaGrad(0.1), false),
            (Loss::Square, "fixed", StepRule::Fixed(0.1), true),
        ] {
            let pctx = PackedCtx {
                loss,
                reg: Regularizer::L2,
                lambda,
                w_bound: loss.w_bound(lambda),
                rule,
                inv_col: &omega.inv_col[0],
                inv_col32: &omega.inv_col32[0],
                inv_row: &omega.inv_row[0],
                y: &y_local[0],
                alpha_bias32: &alpha_bias[0],
            };
            let kernel_p: fn(&PackedBlock, &PackedCtx, &mut PackedState) -> usize = if affine {
                sweep_lanes_affine_with::<Portable>
            } else {
                sweep_lanes_with::<Portable>
            };
            let portable_name = format!("simd_portable_{}_{rname}", loss.name());
            let mut pw = vec![0.01f32; ds.d()];
            let mut pw_acc = vec![0f32; ds.d()];
            let mut palpha = vec![0f32; ds.m()];
            let mut pa_acc = vec![0f32; ds.m()];
            simd_runner.bench_units(&portable_name, n as u64, || {
                let mut st = PackedState {
                    w: &mut pw,
                    w_acc: &mut pw_acc,
                    alpha: &mut palpha,
                    a_acc: &mut pa_acc,
                };
                kernel_p(block, &pctx, &mut st)
            });

            #[cfg(target_arch = "x86_64")]
            {
                if dso::simd::avx2_supported() {
                    use dso::coordinator::updates::{sweep_lanes_affine_avx2, sweep_lanes_avx2};
                    let avx2_name = format!("simd_avx2_{}_{rname}", loss.name());
                    let mut aw = vec![0.01f32; ds.d()];
                    let mut aw_acc = vec![0f32; ds.d()];
                    let mut aalpha = vec![0f32; ds.m()];
                    let mut aa_acc = vec![0f32; ds.m()];
                    simd_runner.bench_units(&avx2_name, n as u64, || {
                        let mut st = PackedState {
                            w: &mut aw,
                            w_acc: &mut aw_acc,
                            alpha: &mut aalpha,
                            a_acc: &mut aa_acc,
                        };
                        // SAFETY: inside the avx2_supported() guard;
                        // the fused entry points are what the plan
                        // dispatches in production, so this measures
                        // the real kernel.
                        unsafe {
                            if affine {
                                sweep_lanes_affine_avx2(block, &pctx, &mut st)
                            } else {
                                sweep_lanes_avx2(block, &pctx, &mut st)
                            }
                        }
                    });
                    let median = |name: &str| {
                        simd_runner.results.iter().find(|r| r.name == name).map(|r| r.median())
                    };
                    if let (Some(pm), Some(am)) = (median(&portable_name), median(&avx2_name))
                    {
                        println!(
                            "    -> avx2 {:.1} M upd/s ({}/upd)  speedup vs portable {:.2}x",
                            n as f64 / am / 1e6,
                            human_time(am / n as f64),
                            pm / am
                        );
                    }
                } else {
                    println!("    -> avx2 backend unavailable on this host; portable only");
                }
                if dso::simd::avx512_supported() {
                    use dso::coordinator::updates::{
                        sweep_lanes_affine_avx512, sweep_lanes_avx512,
                    };
                    let avx512_name = format!("simd_avx512_{}_{rname}", loss.name());
                    let mut zw = vec![0.01f32; ds.d()];
                    let mut zw_acc = vec![0f32; ds.d()];
                    let mut zalpha = vec![0f32; ds.m()];
                    let mut za_acc = vec![0f32; ds.m()];
                    simd_runner.bench_units(&avx512_name, n as u64, || {
                        let mut st = PackedState {
                            w: &mut zw,
                            w_acc: &mut zw_acc,
                            alpha: &mut zalpha,
                            a_acc: &mut za_acc,
                        };
                        // SAFETY: inside the avx512_supported() guard;
                        // the fused entry points are what the plan
                        // dispatches in production.
                        unsafe {
                            if affine {
                                sweep_lanes_affine_avx512(block, &pctx, &mut st)
                            } else {
                                sweep_lanes_avx512(block, &pctx, &mut st)
                            }
                        }
                    });
                    let median = |name: &str| {
                        simd_runner.results.iter().find(|r| r.name == name).map(|r| r.median())
                    };
                    if let (Some(pm), Some(zm)) =
                        (median(&portable_name), median(&avx512_name))
                    {
                        println!(
                            "    -> avx512 {:.1} M upd/s ({}/upd)  speedup vs portable {:.2}x",
                            n as f64 / zm / 1e6,
                            human_time(zm / n as f64),
                            pm / zm
                        );
                    }
                } else {
                    println!("    -> avx512 backend unavailable on this host");
                }
            }
            #[cfg(not(target_arch = "x86_64"))]
            println!("    -> avx2/avx512 backends unavailable (non-x86_64); portable only");
        }
    }

    // --- Measured-auto probe (BENCH_autotune.json) ---
    // What `--simd auto` measures: each supported backend's throughput
    // on the synthetic probe workload (one `autotune_<name>` entry per
    // backend), plus an `autotune_resolve_<name>` marker recording
    // which backend this host's memoized auto resolution chose — so
    // the artifact answers both "how fast was each backend here" and
    // "which one won".
    let mut autotune_runner = Runner::from_env("autotune");
    {
        use dso::simd::autotune::{auto_report, ProbeWorkload};

        let levels = dso::simd::supported_levels();
        for &level in &levels {
            let mut wl = ProbeWorkload::standard();
            let units = wl.run(level) as u64; // warmup; also the per-rep unit count
            let name = format!("autotune_{}", level.name());
            autotune_runner.bench_units(&name, units, || wl.run(level));
        }
        let report = auto_report();
        println!(
            "    -> measured auto winner on this host: {}",
            report.chosen.name()
        );
        let marker = format!("autotune_resolve_{}", report.chosen.name());
        autotune_runner.bench_units(&marker, 1, || 1usize);
    }

    // --- Fault-tolerance overhead pair (BENCH_faults.json) ---
    // Full async NOMAD runs on a small problem: fault-free vs with a
    // deterministic straggler schedule (two 2 ms stalls). The clean
    // side prices the bounded-wait token flow when nothing fails; the
    // ratio shows how gracefully throughput degrades under stalls.
    let mut fault_runner = Runner::from_env("faults");
    {
        use dso::api::Trainer;
        use dso::config::{Algorithm, TrainConfig};

        let small = SparseSpec {
            name: "faults-bench".into(),
            m: 400,
            d: 100,
            nnz_per_row: 8.0,
            zipf_s: 0.7,
            label_noise: 0.03,
            pos_frac: 0.5,
            seed: 9,
        }
        .generate();
        let mut cfg = TrainConfig::default();
        cfg.optim.epochs = 2;
        cfg.optim.eta0 = 0.2;
        cfg.model.lambda = 1e-3;
        cfg.cluster.machines = 2;
        cfg.cluster.cores = 1;
        cfg.monitor.every = 0;
        for (name, faults) in [
            ("faults_async_clean", ""),
            ("faults_async_straggler", "stall@0.0.1:2,stall@1.1.0:2"),
        ] {
            fault_runner.bench(name, || {
                Trainer::new(cfg.clone())
                    .algorithm(Algorithm::DsoAsync)
                    .faults(faults)
                    .fit(&small, None)
                    .expect("bench async train run")
                    .result
                    .total_updates
            });
        }
        let median = |name: &str| {
            fault_runner.results.iter().find(|r| r.name == name).map(|r| r.median())
        };
        if let (Some(cm), Some(sm)) =
            (median("faults_async_clean"), median("faults_async_straggler"))
        {
            println!(
                "    -> clean {}/run  straggler {}/run  overhead {:.2}x",
                human_time(cm),
                human_time(sm),
                sm / cm
            );
        }
    }

    // --- Transport substrate pair (BENCH_transport.json) ---
    // The same async NOMAD run on both substrates: the in-thread ring
    // (shared memory, simulated costing) vs the multi-process ring
    // (real Unix-domain sockets: frames, checksums, delta encoding,
    // heartbeats), plus the process ring under a straggler stall. The
    // thread/process ratio prices the real transport; the stall case
    // shows the supervisor's bounded-wait degradation.
    let mut transport_runner = Runner::from_env("transport");
    {
        use dso::api::Trainer;
        use dso::config::{Algorithm, ExecMode, TrainConfig};

        let small = SparseSpec {
            name: "transport-bench".into(),
            m: 400,
            d: 100,
            nnz_per_row: 8.0,
            zipf_s: 0.7,
            label_noise: 0.03,
            pos_frac: 0.5,
            seed: 9,
        }
        .generate();
        let mut cfg = TrainConfig::default();
        cfg.optim.algorithm = Algorithm::DsoAsync;
        cfg.optim.epochs = 2;
        cfg.optim.eta0 = 0.2;
        cfg.model.lambda = 1e-3;
        cfg.cluster.machines = 2;
        cfg.cluster.cores = 1;
        cfg.monitor.every = 0;
        cfg.cluster.heartbeat_ms = 25;
        cfg.cluster.death_timeout_ms = 1000;
        for (name, mode, faults) in [
            ("transport_thread_ring", ExecMode::Scalar, ""),
            ("transport_proc_ring", ExecMode::Proc, ""),
            ("transport_proc_straggler", ExecMode::Proc, "stall@0.0.1:2,stall@1.1.0:2"),
        ] {
            transport_runner.bench(name, || {
                Trainer::new(cfg.clone())
                    .mode(mode)
                    .worker_bin(env!("CARGO_BIN_EXE_dso"))
                    .faults(faults)
                    .fit(&small, None)
                    .expect("bench transport train run")
                    .result
                    .total_updates
            });
        }
        let median = |name: &str| {
            transport_runner.results.iter().find(|r| r.name == name).map(|r| r.median())
        };
        if let (Some(tm), Some(pm)) =
            (median("transport_thread_ring"), median("transport_proc_ring"))
        {
            println!(
                "    -> thread {}/run  proc {}/run  socket overhead {:.2}x",
                human_time(tm),
                human_time(pm),
                pm / tm
            );
        }
    }

    runner.finish("updates");
    lane_runner.finish("lanes");
    alpha_runner.finish("alpha_lanes");
    simd_runner.finish("simd");
    autotune_runner.finish("autotune");
    fault_runner.finish("faults");
    transport_runner.finish("transport");
}
