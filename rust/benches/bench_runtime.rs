//! Microbench: PJRT tile-kernel execution latency per artifact shape,
//! plus literal pack/unpack overhead (EXPERIMENTS.md §Perf runtime).

#[cfg(not(feature = "xla"))]
fn main() {
    // Keep the cross-PR BENCH_runtime.json trajectory well-defined even
    // when the PJRT path is compiled out: record an empty result set
    // (under DSO_BENCH_JSON=1) so scripts/plot_results.py sees the
    // group was run-and-skipped rather than a silent gap. The group
    // set is open-ended (PR 5 added BENCH_simd.json); the plot script
    // keys strictly off each file's own "group" field, so this stub
    // never needs to know which other groups a snapshot carries.
    let runner = dso::util::bench::Runner::from_env("runtime");
    println!("bench_runtime requires the `xla` feature (PJRT bindings); skipping");
    runner.finish("runtime");
}

#[cfg(feature = "xla")]
use dso::runtime::pjrt::{lit_mat, lit_vec, PjrtRuntime};
#[cfg(feature = "xla")]
use dso::runtime::Manifest;
#[cfg(feature = "xla")]
use dso::util::bench::Runner;

#[cfg(feature = "xla")]
fn main() {
    let mut runner = Runner::from_env("runtime");
    let Ok(manifest) = Manifest::load_default() else {
        println!("no artifacts (run `make artifacts`); skipping runtime bench");
        return;
    };
    let mut rt = PjrtRuntime::cpu().expect("pjrt cpu client");

    for e in manifest.find("tile_update", "hinge") {
        rt.load(&e.name, &e.path).expect("load artifact");
        let (bm, bd) = (e.bm, e.bd);
        let x = vec![0.01f32; bm * bd];
        let w = vec![0.1f32; bd];
        let w_acc = vec![0.01f32; bd];
        let alpha = vec![0.1f32; bm];
        let a_acc = vec![0.01f32; bm];
        let y = vec![1.0f32; bm];
        let rs = vec![1e-4f32; bm];
        let cs = vec![1e-2f32; bd];
        let params = vec![0.1f32, 1e-4, 1e-4, 100.0];
        let inputs = vec![
            lit_mat(&x, bm, bd).unwrap(),
            lit_vec(&w),
            lit_vec(&w_acc),
            lit_vec(&alpha),
            lit_vec(&a_acc),
            lit_vec(&y),
            lit_vec(&rs),
            lit_vec(&cs),
            lit_vec(&params),
        ];
        runner.bench(&format!("exec_{}", e.name), || {
            rt.execute(&e.name, &inputs).unwrap()
        });
        if let Some(r) = runner.results.last() {
            // Tile update = 2 matmuls per fused step.
            let flops = 4.0 * bm as f64 * bd as f64 * e.iters as f64;
            println!(
                "    -> {:.2} MFLOP/s effective on the tile matmuls",
                flops / r.median() / 1e6
            );
        }
        // Literal packing cost (what the tile engine pays per call).
        runner.bench(&format!("literal_pack_{bm}x{bd}"), || {
            (lit_mat(&x, bm, bd).unwrap(), lit_vec(&w), lit_vec(&alpha))
        });
    }

    // Objective artifact.
    if let Some(e) = manifest.find("tile_objective", "hinge").first() {
        rt.load(&e.name, &e.path).expect("load objective");
        let (bm, bd) = (e.bm, e.bd);
        let x = vec![0.01f32; bm * bd];
        let y = vec![1.0f32; bm];
        let w = vec![0.1f32; bd];
        let active = vec![1.0f32; bm];
        let inputs = vec![
            lit_mat(&x, bm, bd).unwrap(),
            lit_vec(&y),
            lit_vec(&w),
            lit_vec(&active),
        ];
        runner.bench(&format!("tile_objective_exec_{bm}x{bd}"), || {
            rt.execute(&e.name, &inputs).unwrap()
        });
    }
    runner.finish("runtime");
}
