//! End-to-end figure regeneration bench: Figure 3 — cluster SVM on kdda (DSO vs BMRM vs PSGD).
//!
//! Runs the experiment driver once at bench scale, reports wall time,
//! and leaves the CSV series under results/bench-figures/. Scale via
//! DSO_BENCH_SCALE / DSO_BENCH_EPOCHS_MUL.
//!
//! kdda-size problems are the paper's out-of-core regime: to iterate
//! on this figure without re-packing blocks every run, do a one-time
//! `dso train --data kdda-sim --cache build --cache-dir CACHE`, then
//! rerun with `--cache use` — the mapped run is bit-identical to the
//! resident one (DESIGN.md §Out-of-core), so the series is unchanged.

use dso::exp::{self, ExpOptions};
use std::time::Instant;

fn main() {
    dso::util::logger::init();
    let mut opts = ExpOptions::default();
    opts.scale = std::env::var("DSO_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.15);
    opts.epochs_mul = std::env::var("DSO_BENCH_EPOCHS_MUL")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.3);
    opts.out_dir = "results/bench-figures".into();
    let t0 = Instant::now();
    exp::run("fig3", &opts).expect("experiment failed");
    println!(
        "\n[bench] fig3 regenerated in {:.2}s (scale {}, epochs x{})",
        t0.elapsed().as_secs_f64(),
        opts.scale,
        opts.epochs_mul
    );
}
