//! End-to-end figure regeneration bench: Figure 4 — cluster SVM on dense ocr (tile path when artifacts built).
//!
//! Runs the experiment driver once at bench scale, reports wall time,
//! and leaves the CSV series under results/bench-figures/. Scale via
//! DSO_BENCH_SCALE / DSO_BENCH_EPOCHS_MUL.
//!
//! The ocr stand-in is dense, so its packed blocks are the largest of
//! the figure set: a one-time `--cache build --cache-dir CACHE`
//! followed by `--cache use` reruns keeps iteration on this figure
//! out-of-core without changing the series (mapped fits are
//! bit-identical to resident — DESIGN.md §Out-of-core). Note the tile
//! path (`--mode tile`) never reads packed sparse blocks, so the cache
//! applies to the scalar engines only.

use dso::exp::{self, ExpOptions};
use std::time::Instant;

fn main() {
    dso::util::logger::init();
    let mut opts = ExpOptions::default();
    opts.scale = std::env::var("DSO_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.15);
    opts.epochs_mul = std::env::var("DSO_BENCH_EPOCHS_MUL")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.3);
    opts.out_dir = "results/bench-figures".into();
    let t0 = Instant::now();
    exp::run("fig4", &opts).expect("experiment failed");
    println!(
        "\n[bench] fig4 regenerated in {:.2}s (scale {}, epochs x{})",
        t0.elapsed().as_secs_f64(),
        opts.scale,
        opts.epochs_mul
    );
}
