//! End-to-end supplementary sweep bench: Figures 6–45 (serial sweep)
//! and Figures 46–77 (parallel sweep) at reduced scale. The full-scale
//! sweeps are `dso exp serial-sweep` / `dso exp parallel-sweep`.

use dso::exp::{self, ExpOptions};
use std::time::Instant;

fn main() {
    dso::util::logger::init();
    let mut opts = ExpOptions::default();
    opts.scale = std::env::var("DSO_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.06);
    opts.epochs_mul = 0.15;
    opts.out_dir = "results/bench-figures".into();
    for exp_name in ["serial-sweep", "parallel-sweep"] {
        let t0 = Instant::now();
        exp::run(exp_name, &opts).expect("sweep failed");
        println!("\n[bench] {exp_name} regenerated in {:.2}s", t0.elapsed().as_secs_f64());
    }
}
