//! Microbench: the substrates under the engine — Ω block construction,
//! partitioning, network router hop latency, dataset generation, CSR
//! ops. These bound how fast epochs can cycle outside the update loop.

use dso::data::synth::SparseSpec;
use dso::net::{CostModel, Router};
use dso::partition::{PackedBlocks, Partition, RingSchedule};
use dso::util::bench::Runner;

fn main() {
    let mut runner = Runner::from_env("substrates");

    let ds = SparseSpec {
        name: "bench".into(),
        m: 20_000,
        d: 8_000,
        nnz_per_row: 12.0,
        zipf_s: 0.9,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 2,
    }
    .generate();
    println!("dataset: m={} d={} nnz={}", ds.m(), ds.d(), ds.nnz());

    runner.bench("omega_build_p8", || {
        let rp = Partition::even(ds.m(), 8);
        let cp = Partition::even(ds.d(), 8);
        PackedBlocks::build(&ds.x, &rp, &cp)
    });

    let weights: Vec<u64> = (0..ds.m()).map(|i| ds.x.row_nnz(i) as u64).collect();
    runner.bench("partition_balanced_p32", || Partition::balanced(&weights, 32));

    runner.bench("csr_to_csc", || ds.x.to_csc());

    let w = vec![0.1f32; ds.d()];
    runner.bench("row_dot_full_pass", || {
        let mut s = 0.0;
        for i in 0..ds.m() {
            s += ds.x.row_dot(i, &w);
        }
        s
    });

    runner.bench("dense_block_256x256", || ds.x.dense_block(0, 256, 0, 256));

    // Ring hop: send + receive one w block through the router.
    let sched = RingSchedule::new(8);
    let mut router: Router<Vec<f32>> = Router::new(8, CostModel::new(100.0, 1000.0, 4));
    let eps = router.take_endpoints();
    let block = vec![0f32; ds.d() / 8];
    runner.bench("ring_rotate_8workers", || {
        for q in 0..8 {
            eps[q].send(sched.send_to(q), block.clone(), 4 * block.len());
        }
        for ep in &eps {
            ep.recv().unwrap();
        }
    });

    runner.bench("gen_realsim_scale0.2", || {
        dso::data::registry::generate("real-sim", 0.2, 3).unwrap()
    });

    runner.finish("substrates");
}
