//! End-to-end table regeneration bench: Table 2 (dataset summary) —
//! generation throughput for every registry dataset.

use dso::exp::{self, ExpOptions};
use dso::util::bench::Runner;
use std::time::Instant;

fn main() {
    dso::util::logger::init();
    let mut opts = ExpOptions::default();
    opts.scale = 0.25;
    opts.out_dir = "results/bench-figures".into();
    let t0 = Instant::now();
    exp::run("table2", &opts).expect("table2 failed");
    exp::run("table1", &opts).expect("table1 failed");
    println!("\n[bench] tables regenerated in {:.2}s", t0.elapsed().as_secs_f64());

    // Per-dataset generation microbench.
    let mut runner = Runner::from_env("datasets");
    for &name in dso::data::registry::NAMES {
        runner.bench(&format!("gen_{name}"), || {
            dso::data::registry::generate(name, 0.1, 1).unwrap()
        });
    }
    runner.finish("datasets");
}
