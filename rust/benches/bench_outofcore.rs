//! Microbench: out-of-core sweep throughput (DESIGN.md §Out-of-core).
//!
//! Three variants of the same lane sweep over the standard 64k-entry
//! block (4k rows × 2k cols, ≈16 nnz/row, p = 1):
//!
//! * `outofcore_resident_sweep`    — the in-memory baseline: the block
//!   built by `PackedBlocks::build`, every table an owned `AVec`.
//! * `outofcore_mapped_cold_sweep` — a fresh `cache::open` + mmap per
//!   iteration, sweeping straight off the mapping with no advice: the
//!   open/validate overhead plus demand page faults. (The OS page cache
//!   stays warm across iterations — a container bench cannot drop it —
//!   so the fault cost here is soft faults, a lower bound on true cold.)
//! * `outofcore_mapped_prefetched_sweep` — one long-lived mapping, the
//!   production shape: `CacheHandle::prefetch` posts `madvise(WILLNEED)`
//!   for the block's cols/vals window before each sweep, exactly as the
//!   engines do one slot ahead along the sweep schedule.
//!
//! Acceptance target: mapped-prefetched within 10% of resident on this
//! block. Run with `DSO_BENCH_JSON=1` to record `BENCH_outofcore.json`
//! (tracked by the CI smoke alongside the other bench artifacts).

use dso::coordinator::updates::{sweep_lanes, PackedCtx, PackedState, StepRule};
use dso::data::cache;
use dso::data::synth::SparseSpec;
use dso::losses::{Loss, Regularizer};
use dso::partition::{PackedBlocks, Partition};
use dso::util::bench::{human_time, Runner};

fn main() {
    let mut runner = Runner::from_env("outofcore");

    let ds = SparseSpec {
        name: "outofcore-bench".into(),
        m: 4000,
        d: 2000,
        nnz_per_row: 16.0,
        zipf_s: 0.8,
        label_noise: 0.0,
        pos_frac: 0.5,
        seed: 1,
    }
    .generate();

    let rp = Partition::even(ds.m(), 1);
    let cp = Partition::even(ds.d(), 1);
    let omega = PackedBlocks::build(&ds.x, &rp, &cp);
    let alpha_bias: Vec<dso::data::BlockStore<f32>> =
        omega.stripe_alpha_bias(&ds.y).into_iter().map(Into::into).collect();
    let y_local = omega.stripe_labels(&ds.y);
    let n = omega.block(0, 0).nnz();
    println!("block: {n} entries, resident vs mapped-cold vs mapped-prefetched");

    let dir = std::env::temp_dir().join("dso-bench-outofcore");
    std::fs::remove_dir_all(&dir).ok();
    let path = cache::cache_path(&dir, &ds.name);
    cache::pack(&path, &omega, &alpha_bias, &ds.y, 0).expect("pack bench cache");
    let file_len = std::fs::metadata(&path).expect("cache stat").len();
    println!("cache: {path:?} ({file_len} bytes)");

    let lambda = 1e-4;
    fn make_ctx<'a>(
        om: &'a PackedBlocks,
        bias: &'a [dso::data::BlockStore<f32>],
        y: &'a [f64],
        lambda: f64,
    ) -> PackedCtx<'a> {
        PackedCtx {
            loss: Loss::Hinge,
            reg: Regularizer::L2,
            lambda,
            w_bound: Loss::Hinge.w_bound(lambda),
            rule: StepRule::AdaGrad(0.1),
            inv_col: &om.inv_col[0],
            inv_col32: &om.inv_col32[0],
            inv_row: &om.inv_row[0],
            y,
            alpha_bias32: &bias[0],
        }
    }

    // --- Resident baseline ---
    {
        let pctx = make_ctx(&omega, &alpha_bias, &y_local[0], lambda);
        let block = omega.block(0, 0);
        let mut w = vec![0.01f32; ds.d()];
        let mut w_acc = vec![0f32; ds.d()];
        let mut alpha = vec![0f32; ds.m()];
        let mut a_acc = vec![0f32; ds.m()];
        runner.bench_units("outofcore_resident_sweep", n as u64, || {
            let mut st = PackedState {
                w: &mut w,
                w_acc: &mut w_acc,
                alpha: &mut alpha,
                a_acc: &mut a_acc,
            };
            sweep_lanes(block, &pctx, &mut st)
        });
    }

    // --- Mapped, cold: fresh open + mapping each iteration ---
    {
        let mut w = vec![0.01f32; ds.d()];
        let mut w_acc = vec![0f32; ds.d()];
        let mut alpha = vec![0f32; ds.m()];
        let mut a_acc = vec![0f32; ds.m()];
        runner.bench_units("outofcore_mapped_cold_sweep", n as u64, || {
            let opened = cache::open(&path).expect("open bench cache");
            let pctx = make_ctx(&opened.omega, &opened.alpha_bias, &y_local[0], lambda);
            let mut st = PackedState {
                w: &mut w,
                w_acc: &mut w_acc,
                alpha: &mut alpha,
                a_acc: &mut a_acc,
            };
            sweep_lanes(opened.omega.block(0, 0), &pctx, &mut st)
        });
    }

    // --- Mapped, prefetched: long-lived mapping + WILLNEED ahead ---
    {
        let opened = cache::open(&path).expect("open bench cache");
        let pctx = make_ctx(&opened.omega, &opened.alpha_bias, &y_local[0], lambda);
        let block = opened.omega.block(0, 0);
        let handle = opened.handle.clone();
        let mut w = vec![0.01f32; ds.d()];
        let mut w_acc = vec![0f32; ds.d()];
        let mut alpha = vec![0f32; ds.m()];
        let mut a_acc = vec![0f32; ds.m()];
        runner.bench_units("outofcore_mapped_prefetched_sweep", n as u64, || {
            handle.prefetch(0, 0);
            let mut st = PackedState {
                w: &mut w,
                w_acc: &mut w_acc,
                alpha: &mut alpha,
                a_acc: &mut a_acc,
            };
            sweep_lanes(block, &pctx, &mut st)
        });
    }

    let median = |name: &str| runner.results.iter().find(|r| r.name == name).map(|r| r.median());
    if let (Some(rm), Some(cm), Some(pm)) = (
        median("outofcore_resident_sweep"),
        median("outofcore_mapped_cold_sweep"),
        median("outofcore_mapped_prefetched_sweep"),
    ) {
        println!(
            "    -> resident {:.1} M upd/s ({}/upd)  mapped-cold {:.1} M upd/s  mapped-prefetched {:.1} M upd/s",
            n as f64 / rm / 1e6,
            human_time(rm / n as f64),
            n as f64 / cm / 1e6,
            n as f64 / pm / 1e6,
        );
        println!(
            "    -> prefetched/resident {:.3}x (target ≤1.10x)  cold/resident {:.2}x",
            pm / rm,
            cm / rm
        );
    }

    runner.finish("outofcore");
    std::fs::remove_dir_all(&dir).ok();
}
