//! # DSO — Distributed Stochastic Optimization of the Regularized Risk
//!
//! A production-quality reproduction of Matsushima, Yun & Vishwanathan
//! (2014): regularized risk minimization rewritten as the saddle-point
//! problem `max_α min_w f(w, α)` (Eq. 6), solved by a distributed
//! stochastic optimizer whose workers update disjoint (w_j, α_i) blocks
//! in parallel and rotate ownership of `w` around a ring (Algorithm 1).
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: data/partition/network
//!   substrates, the DSO engine, the paper's baselines (SGD, PSGD,
//!   BMRM), experiment drivers for every figure/table, CLI.
//! * **L2/L1 (python/, build-time only)** — a JAX model plus a Pallas
//!   tile-update kernel, AOT-lowered to HLO text and executed from Rust
//!   through the PJRT CPU client (`runtime`).
//!
//! The one entry point for training is the [`api::Trainer`] facade
//! (see DESIGN.md §Solver-API): it routes `Algorithm` × `ExecMode`
//! over every engine, streams per-epoch rows to an observer, and
//! returns a [`api::Fitted`] artifact with `predict` and model
//! persistence. The per-engine free functions remain as thin
//! deprecated shims. Persisted models are served back by the
//! [`serve`] subsystem (DESIGN.md §Serving): batched SIMD inference
//! over the training kernels' packed layout, warm-start retraining
//! via [`api::Trainer::fit_from`], and the `dso serve` model server.

pub mod api;
pub mod baselines;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod losses;
pub mod net;
pub mod optim;
pub mod partition;
pub mod runtime;
pub mod serve;
pub mod simd;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
