//! LIBSVM text format reader/writer.
//!
//! The paper's datasets (real-sim, news20, kdda, …) are distributed in
//! this format; users can point the CLI at real files, and the synthetic
//! generators can export to it for interchange with other tools.
//!
//! Format: one example per line, `label idx:val idx:val ...` with
//! 1-based feature indices. `#` starts a comment.

use super::dataset::Dataset;
use super::sparse::Csr;
use std::io::{BufReader, Write};
use std::path::Path;

#[derive(Debug)]
pub enum LibsvmError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
}

impl std::fmt::Display for LibsvmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LibsvmError::Io(e) => write!(f, "io: {e}"),
            LibsvmError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
        }
    }
}

impl std::error::Error for LibsvmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LibsvmError::Io(e) => Some(e),
            LibsvmError::Parse { .. } => None,
        }
    }
}

impl From<std::io::Error> for LibsvmError {
    fn from(e: std::io::Error) -> Self {
        LibsvmError::Io(e)
    }
}

/// Parse from a string. `min_dim` lets callers force a dimensionality
/// larger than the max observed index (e.g. to align train/test).
pub fn parse(name: &str, text: &str, min_dim: usize) -> Result<Dataset, LibsvmError> {
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut labels: Vec<f32> = Vec::new();
    let mut max_col: usize = 0;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        // The trim + is_empty skip above makes an empty token stream
        // unreachable for ASCII whitespace, but `trim` and
        // `split_ascii_whitespace` disagree on non-ASCII whitespace
        // (e.g. U+00A0) — never panic on data, report the line instead.
        let Some(label_tok) = parts.next() else {
            return Err(LibsvmError::Parse {
                line: lineno + 1,
                msg: "no label token on non-empty line".into(),
            });
        };
        let label: f32 = label_tok.parse().map_err(|_| LibsvmError::Parse {
            line: lineno + 1,
            msg: format!("bad label '{label_tok}'"),
        })?;
        let mut row = Vec::new();
        for tok in parts {
            let (idx_s, val_s) = tok.split_once(':').ok_or_else(|| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad feature token '{tok}'"),
            })?;
            let idx: usize = idx_s.parse().map_err(|_| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad index '{idx_s}'"),
            })?;
            if idx == 0 {
                return Err(LibsvmError::Parse {
                    line: lineno + 1,
                    msg: "libsvm indices are 1-based; found 0".into(),
                });
            }
            // Same hardening on the out-of-range side: `(idx - 1) as
            // u32` below would silently truncate, and the SIMD gather
            // path additionally requires column ids ≤ i32::MAX (signed
            // 32-bit gather indices). Refuse with the line number.
            if idx - 1 > i32::MAX as usize {
                return Err(LibsvmError::Parse {
                    line: lineno + 1,
                    msg: format!("index {idx} out of range (max {})", i32::MAX as i64 + 1),
                });
            }
            let val: f32 = val_s.parse().map_err(|_| LibsvmError::Parse {
                line: lineno + 1,
                msg: format!("bad value '{val_s}'"),
            })?;
            max_col = max_col.max(idx);
            row.push(((idx - 1) as u32, val));
        }
        rows.push(row);
        labels.push(label);
    }
    let dim = max_col.max(min_dim);
    Ok(Dataset::new(name, Csr::from_rows(dim, rows), labels))
}

pub fn read(path: &Path, min_dim: usize) -> Result<Dataset, LibsvmError> {
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| "libsvm".to_string());
    // Stream to keep memory proportional to the data, not 2x.
    let f = std::fs::File::open(path)?;
    let mut reader = BufReader::new(f);
    let mut text = String::new();
    reader.read_to_string(&mut text)?;
    parse(&name, &text, min_dim)
}

use std::io::Read as _;

/// Serialize a dataset to libsvm text.
pub fn emit(ds: &Dataset) -> String {
    let mut out = String::new();
    for i in 0..ds.m() {
        let y = ds.y[i];
        if y == y.trunc() {
            out.push_str(&format!("{}", y as i64));
        } else {
            out.push_str(&format!("{y}"));
        }
        let (idx, val) = ds.x.row(i);
        for k in 0..idx.len() {
            out.push_str(&format!(" {}:{}", idx[k] + 1, val[k]));
        }
        out.push('\n');
    }
    out
}

pub fn write(ds: &Dataset, path: &Path) -> Result<(), LibsvmError> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(emit(ds).as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n";
        let ds = parse("t", text, 0).unwrap();
        assert_eq!(ds.m(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.row(0).0, &[0, 2]);
        assert_eq!(ds.x.row(1).1, &[2.0]);
    }

    #[test]
    fn parse_skips_comments_and_blank_lines() {
        let text = "# header\n\n1 1:1 # trailing\n";
        let ds = parse("t", text, 0).unwrap();
        assert_eq!(ds.m(), 1);
        assert_eq!(ds.nnz(), 1);
    }

    #[test]
    fn parse_min_dim() {
        let ds = parse("t", "1 1:1\n", 10).unwrap();
        assert_eq!(ds.d(), 10);
    }

    #[test]
    fn parse_rejects_zero_index() {
        assert!(parse("t", "1 0:1\n", 0).is_err());
    }

    #[test]
    fn parse_handles_whitespace_only_line() {
        // ASCII whitespace-only lines are skipped, not parsed as rows —
        // and must never panic.
        let ds = parse("t", "1 1:1\n \t \n-1 2:1\n", 0).unwrap();
        assert_eq!(ds.m(), 2);
        // Non-ASCII whitespace (U+00A0) survives `trim`'s skip but
        // yields no ASCII tokens: reported as a parse error with the
        // line number, not a panic.
        let err = parse("t", "1 1:1\n\u{a0}\u{a0}\n", 0).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("line 2") && msg.contains("label"), "{msg}");
    }

    #[test]
    fn parse_rejects_out_of_range_index_with_line() {
        // Indices past i32::MAX would truncate in the u32 narrowing and
        // break the SIMD gather bound; refused, naming the line.
        let text = format!("1 1:1\n1 {}:1\n", (i32::MAX as i64) + 2);
        let err = parse("t", &text, 0).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("line 2") && msg.contains("out of range"), "{msg}");
        // The largest admissible index still parses.
        let ok = parse("t", &format!("1 {}:1\n", (i32::MAX as i64) + 1), 0);
        assert!(ok.is_ok());
    }

    #[test]
    fn parse_rejects_bad_tokens() {
        assert!(parse("t", "abc 1:1\n", 0).is_err());
        assert!(parse("t", "1 12\n", 0).is_err());
        assert!(parse("t", "1 x:1\n", 0).is_err());
        assert!(parse("t", "1 1:y\n", 0).is_err());
    }

    #[test]
    fn roundtrip() {
        let text = "1 1:0.5 3:1.5\n-1 2:2\n1 1:-3\n";
        let ds = parse("t", text, 0).unwrap();
        let ds2 = parse("t", &emit(&ds), 0).unwrap();
        assert_eq!(ds.y, ds2.y);
        assert_eq!(ds.x, ds2.x);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("dso_libsvm_test");
        let path = dir.join("d.libsvm");
        let ds = parse("t", "1 1:1 2:0.25\n-1 2:-1\n", 0).unwrap();
        write(&ds, &path).unwrap();
        let ds2 = read(&path, 0).unwrap();
        assert_eq!(ds.x, ds2.x);
        assert_eq!(ds.y, ds2.y);
        assert_eq!(ds2.name, "d");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fractional_labels_roundtrip() {
        let ds = parse("t", "0.5 1:1\n", 0).unwrap();
        let ds2 = parse("t", &emit(&ds), 0).unwrap();
        assert_eq!(ds2.y, vec![0.5]);
    }

    #[test]
    fn parse_error_reports_line() {
        let err = parse("t", "1 1:1\nbogus\n", 0).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("line 2"), "{msg}");
    }
}
