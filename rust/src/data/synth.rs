//! Synthetic dataset generators.
//!
//! The paper evaluates on nine LIBSVM datasets (Table 2) that range up
//! to 63 GB; those files are not available in this environment, so each
//! is replaced by a generator matched on the statistics that drive DSO's
//! behaviour: m, d, density (and its skew), dense vs sparse storage, and
//! the positive:negative label ratio. Labels come from a planted linear
//! model with controllable noise so that (a) the problem is learnable,
//! (b) regularized optima are non-trivial, and (c) test error curves are
//! meaningful. See DESIGN.md §"What the paper used → what we build".

use super::dataset::Dataset;
use super::sparse::Csr;
use crate::util::rng::Xoshiro256;

/// Parameters of the sparse generator.
#[derive(Clone, Debug)]
pub struct SparseSpec {
    pub name: String,
    pub m: usize,
    pub d: usize,
    /// Mean nonzeros per row.
    pub nnz_per_row: f64,
    /// Zipf exponent for feature popularity (0 = uniform; text-like ≈ 1).
    pub zipf_s: f64,
    /// Fraction of labels flipped after the planted model assigns them.
    pub label_noise: f64,
    /// Target fraction of positive examples (shifts the plant's bias).
    pub pos_frac: f64,
    pub seed: u64,
}

impl SparseSpec {
    pub fn generate(&self) -> Dataset {
        assert!(self.m > 0 && self.d > 0);
        assert!(self.nnz_per_row >= 1.0);
        let mut rng = Xoshiro256::new(self.seed);

        // Planted model: dense gaussian weights over features; feature
        // values are positive tf-idf-like magnitudes so the popular
        // (low-index) features carry most signal, as in text data.
        let wstar: Vec<f64> = (0..self.d).map(|_| rng.normal()).collect();

        let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(self.m);
        let mut margins: Vec<f64> = Vec::with_capacity(self.m);
        for _ in 0..self.m {
            // Row nnz ~ 1 + Poisson-ish around the target (geometric mix
            // keeps it integer and cheap).
            let target = self.nnz_per_row.max(1.0);
            let jitter = 0.5 + rng.next_f64();
            let k = ((target * jitter).round() as usize).clamp(1, self.d);
            let mut row: Vec<(u32, f32)> = Vec::with_capacity(k);
            let mut seen = std::collections::HashSet::with_capacity(k);
            let mut attempts = 0;
            while row.len() < k && attempts < 20 * k {
                attempts += 1;
                let j = rng.zipf(self.d, self.zipf_s);
                if seen.insert(j) {
                    let v = (0.1 + rng.next_f64()) as f32;
                    row.push((j as u32, v));
                }
            }
            // L2-normalize the row (standard for these datasets).
            let norm: f64 = row.iter().map(|&(_, v)| (v as f64).powi(2)).sum::<f64>().sqrt();
            for e in &mut row {
                e.1 = (e.1 as f64 / norm) as f32;
            }
            let margin: f64 = row.iter().map(|&(j, v)| wstar[j as usize] * v as f64).sum();
            margins.push(margin);
            rows.push(row);
        }

        // Choose the bias so that `pos_frac` of examples land positive.
        let mut sorted = margins.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut_idx = (((1.0 - self.pos_frac) * self.m as f64) as usize).min(self.m - 1);
        let bias = sorted[cut_idx];

        let mut y: Vec<f32> = margins
            .iter()
            .map(|&mg| if mg >= bias { 1.0 } else { -1.0 })
            .collect();
        for lbl in y.iter_mut() {
            if rng.bernoulli(self.label_noise) {
                *lbl = -*lbl;
            }
        }
        Dataset::new(self.name.clone(), Csr::from_rows(self.d, rows), y)
    }
}

/// Parameters of the dense generator (ocr / alpha / dna analogs:
/// fully-dense or block-dense numeric features).
#[derive(Clone, Debug)]
pub struct DenseSpec {
    pub name: String,
    pub m: usize,
    pub d: usize,
    /// Fraction of columns that are active per row (1.0 = fully dense,
    /// 0.25 = dna-like).
    pub density: f64,
    pub label_noise: f64,
    pub pos_frac: f64,
    /// Redundancy: number of distinct "prototype" rows; rows are noisy
    /// copies of prototypes. Low values mimic the high redundancy of ocr
    /// that makes PSGD competitive (paper §5.2).
    pub prototypes: usize,
    pub seed: u64,
}

impl DenseSpec {
    pub fn generate(&self) -> Dataset {
        assert!(self.m > 0 && self.d > 0);
        assert!(self.density > 0.0 && self.density <= 1.0);
        let mut rng = Xoshiro256::new(self.seed);
        let wstar: Vec<f64> = (0..self.d).map(|_| rng.normal()).collect();
        let protos: Vec<Vec<f32>> = (0..self.prototypes.max(1))
            .map(|_| (0..self.d).map(|_| rng.normal() as f32).collect())
            .collect();

        let active_cols = ((self.d as f64) * self.density).round().max(1.0) as usize;
        let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(self.m);
        let mut margins: Vec<f64> = Vec::with_capacity(self.m);
        for _ in 0..self.m {
            let proto = &protos[rng.gen_index(protos.len())];
            let mut row: Vec<(u32, f32)> = Vec::with_capacity(active_cols);
            // Active columns are a contiguous window (dna-like block
            // density) starting at a random offset; fully dense when
            // density = 1.
            let start = if active_cols >= self.d { 0 } else { rng.gen_index(self.d - active_cols + 1) };
            let mut margin = 0.0;
            let scale = 1.0 / (active_cols as f64).sqrt();
            for j in start..start + active_cols {
                let v = (proto[j] as f64 + 0.3 * rng.normal()) * scale;
                margin += wstar[j] * v;
                row.push((j as u32, v as f32));
            }
            margins.push(margin);
            rows.push(row);
        }

        let mut sorted = margins.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut_idx = (((1.0 - self.pos_frac) * self.m as f64) as usize).min(self.m - 1);
        let bias = sorted[cut_idx];
        let mut y: Vec<f32> =
            margins.iter().map(|&mg| if mg >= bias { 1.0 } else { -1.0 }).collect();
        for lbl in y.iter_mut() {
            if rng.bernoulli(self.label_noise) {
                *lbl = -*lbl;
            }
        }
        Dataset::new(self.name.clone(), Csr::from_rows(self.d, rows), y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SparseSpec {
        SparseSpec {
            name: "test-sparse".into(),
            m: 500,
            d: 400,
            nnz_per_row: 12.0,
            zipf_s: 1.0,
            label_noise: 0.02,
            pos_frac: 0.4,
            seed: 7,
        }
    }

    #[test]
    fn sparse_shapes_and_validity() {
        let ds = spec().generate();
        assert_eq!(ds.m(), 500);
        assert_eq!(ds.d(), 400);
        ds.x.validate().unwrap();
        // nnz per row near target.
        let mean_nnz = ds.nnz() as f64 / ds.m() as f64;
        assert!((mean_nnz - 12.0).abs() < 4.0, "mean nnz {mean_nnz}");
    }

    #[test]
    fn sparse_rows_unit_norm() {
        let ds = spec().generate();
        for i in 0..ds.m() {
            let (_, vals) = ds.x.row(i);
            let n: f64 = vals.iter().map(|&v| (v as f64).powi(2)).sum();
            assert!((n - 1.0).abs() < 1e-5, "row {i} norm {n}");
        }
    }

    #[test]
    fn sparse_pos_frac_respected() {
        let ds = spec().generate();
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count() as f64 / ds.m() as f64;
        assert!((pos - 0.4).abs() < 0.08, "pos frac {pos}");
    }

    #[test]
    fn sparse_deterministic() {
        let a = spec().generate();
        let b = spec().generate();
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let mut s2 = spec();
        s2.seed = 8;
        let c = s2.generate();
        assert!(a.x != c.x || a.y != c.y);
    }

    #[test]
    fn sparse_is_learnable() {
        // With low noise a planted linear model must beat chance easily;
        // check that the plant's own structure is recoverable by a few
        // epochs of perceptron — a weak but fast learnability probe.
        let ds = spec().generate();
        let mut w = vec![0f32; ds.d()];
        for _ in 0..20 {
            for i in 0..ds.m() {
                let pred = ds.x.row_dot(i, &w);
                let y = ds.y[i] as f64;
                if y * pred <= 0.0 {
                    let (idx, val) = ds.x.row(i);
                    for k in 0..idx.len() {
                        w[idx[k] as usize] += (y as f32) * val[k];
                    }
                }
            }
        }
        let err = ds.test_error(&w);
        assert!(err < 0.25, "perceptron train error {err}");
    }

    #[test]
    fn dense_full_density() {
        let ds = DenseSpec {
            name: "test-dense".into(),
            m: 200,
            d: 64,
            density: 1.0,
            label_noise: 0.01,
            pos_frac: 0.5,
            prototypes: 10,
            seed: 3,
        }
        .generate();
        assert_eq!(ds.nnz(), 200 * 64);
        ds.x.validate().unwrap();
    }

    #[test]
    fn dense_partial_density_window() {
        let ds = DenseSpec {
            name: "test-dna".into(),
            m: 100,
            d: 80,
            density: 0.25,
            label_noise: 0.0,
            pos_frac: 0.1,
            prototypes: 4,
            seed: 3,
        }
        .generate();
        let per_row = 80 / 4;
        for i in 0..ds.m() {
            assert_eq!(ds.x.row_nnz(i), per_row);
        }
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count() as f64 / ds.m() as f64;
        assert!((pos - 0.1).abs() < 0.05, "pos frac {pos}");
    }

    #[test]
    fn dense_redundancy_low_rank() {
        // With few prototypes, many rows should be highly correlated:
        // check the mean absolute cosine similarity between random row
        // pairs is much higher than for independent gaussian rows.
        let ds = DenseSpec {
            name: "t".into(),
            m: 60,
            d: 32,
            density: 1.0,
            label_noise: 0.0,
            pos_frac: 0.5,
            prototypes: 3,
            seed: 5,
        }
        .generate();
        let dense = ds.x.to_dense();
        let row = |i: usize| &dense[i * 32..(i + 1) * 32];
        let cos = |a: &[f32], b: &[f32]| {
            let (mut ab, mut aa, mut bb) = (0.0f64, 0.0f64, 0.0f64);
            for k in 0..a.len() {
                ab += a[k] as f64 * b[k] as f64;
                aa += (a[k] as f64).powi(2);
                bb += (b[k] as f64).powi(2);
            }
            (ab / (aa.sqrt() * bb.sqrt())).abs()
        };
        let mut total = 0.0;
        let mut n = 0;
        for i in 0..20 {
            for j in (i + 1)..20 {
                total += cos(row(i), row(j));
                n += 1;
            }
        }
        let mean_cos = total / n as f64;
        assert!(mean_cos > 0.3, "mean |cos| {mean_cos} — rows not redundant");
    }
}
