//! Labeled dataset container + train/test splitting + summary stats
//! (the quantities reported in the paper's Table 2).

use super::sparse::Csr;
use crate::util::rng::Xoshiro256;

/// A binary-classification (or regression) dataset: X is m×d CSR,
/// labels are ±1 for classification (f32 targets for square loss).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Csr,
    pub y: Vec<f32>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: Csr, y: Vec<f32>) -> Dataset {
        assert_eq!(x.rows, y.len(), "labels/rows mismatch");
        Dataset { name: name.into(), x, y }
    }

    pub fn m(&self) -> usize {
        self.x.rows
    }

    pub fn d(&self) -> usize {
        self.x.cols
    }

    pub fn nnz(&self) -> usize {
        self.x.nnz()
    }

    /// Summary statistics matching the columns of the paper's Table 2.
    pub fn stats(&self) -> DatasetStats {
        let pos = self.y.iter().filter(|&&v| v > 0.0).count();
        let neg = self.y.len() - pos;
        DatasetStats {
            name: self.name.clone(),
            m: self.m(),
            d: self.d(),
            nnz: self.nnz(),
            density_pct: 100.0 * self.x.density(),
            pos_neg_ratio: if neg > 0 { pos as f64 / neg as f64 } else { f64::INFINITY },
        }
    }

    /// Deterministic shuffled train/test split.
    pub fn split(&self, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_frac));
        let mut order: Vec<usize> = (0..self.m()).collect();
        let mut rng = Xoshiro256::new(seed);
        rng.shuffle(&mut order);
        let n_test = ((self.m() as f64) * test_frac).round() as usize;
        let (test_rows, train_rows) = order.split_at(n_test);
        let mk = |rows: &[usize], tag: &str| {
            Dataset::new(
                format!("{}-{tag}", self.name),
                self.x.select_rows(rows),
                rows.iter().map(|&i| self.y[i]).collect(),
            )
        };
        (mk(train_rows, "train"), mk(test_rows, "test"))
    }

    /// 0/1 test error of a linear model sign(⟨w, x⟩).
    pub fn test_error(&self, w: &[f32]) -> f64 {
        assert_eq!(w.len(), self.d());
        let mut wrong = 0usize;
        for i in 0..self.m() {
            let pred = self.x.row_dot(i, w);
            let yhat = if pred >= 0.0 { 1.0 } else { -1.0 };
            if (yhat as f32 - self.y[i]).abs() > 1e-6 {
                wrong += 1;
            }
        }
        wrong as f64 / self.m().max(1) as f64
    }
}

#[derive(Clone, Debug)]
pub struct DatasetStats {
    pub name: String,
    pub m: usize,
    pub d: usize,
    pub nnz: usize,
    pub density_pct: f64,
    pub pos_neg_ratio: f64,
}

impl DatasetStats {
    pub fn header() -> String {
        format!(
            "{:<16} {:>9} {:>9} {:>11} {:>9} {:>8}",
            "name", "m", "d", "|Omega|", "s(%)", "m+:m-"
        )
    }

    pub fn row(&self) -> String {
        format!(
            "{:<16} {:>9} {:>9} {:>11} {:>9.4} {:>8.2}",
            self.name, self.m, self.d, self.nnz, self.density_pct, self.pos_neg_ratio
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;

    fn toy() -> Dataset {
        let x = Csr::from_rows(
            2,
            vec![
                vec![(0, 1.0)],
                vec![(1, 1.0)],
                vec![(0, -1.0)],
                vec![(1, -1.0)],
                vec![(0, 2.0), (1, 0.5)],
                vec![(0, -2.0)],
            ],
        );
        let y = vec![1.0, 1.0, -1.0, -1.0, 1.0, -1.0];
        Dataset::new("toy", x, y)
    }

    #[test]
    fn stats_fields() {
        let d = toy();
        let s = d.stats();
        assert_eq!(s.m, 6);
        assert_eq!(s.d, 2);
        assert_eq!(s.nnz, 7);
        assert!((s.pos_neg_ratio - 1.0).abs() < 1e-12);
        assert!(s.density_pct > 0.0 && s.density_pct <= 100.0);
    }

    #[test]
    fn split_partitions_rows() {
        let d = toy();
        let (tr, te) = d.split(0.33, 1);
        assert_eq!(tr.m() + te.m(), d.m());
        assert_eq!(te.m(), 2);
        assert_eq!(tr.d(), d.d());
        // Determinism.
        let (tr2, te2) = d.split(0.33, 1);
        assert_eq!(tr.y, tr2.y);
        assert_eq!(te.y, te2.y);
        // Different seed shuffles differently (with high probability).
        let (tr3, _) = d.split(0.33, 2);
        assert!(tr.y != tr3.y || tr.x != tr3.x || d.m() < 4);
    }

    #[test]
    fn test_error_perfect_and_flipped() {
        let d = toy();
        // w = (1, 1) classifies everything correctly.
        assert_eq!(d.test_error(&[1.0, 1.0]), 0.0);
        // Flipped model gets everything wrong.
        assert_eq!(d.test_error(&[-1.0, -1.0]), 1.0);
    }

    #[test]
    #[should_panic(expected = "labels/rows mismatch")]
    fn mismatched_labels_panics() {
        let x = Csr::from_rows(1, vec![vec![(0, 1.0)]]);
        Dataset::new("bad", x, vec![1.0, -1.0]);
    }

    #[test]
    fn header_and_row_render() {
        let s = toy().stats();
        assert!(DatasetStats::header().contains("|Omega|"));
        assert!(s.row().contains("toy"));
    }
}
