//! `BlockStore<T>` — the storage cell behind every packed-block table.
//!
//! `partition::omega` historically stored its lane regions and
//! reciprocal tables in `AVec<T>` (64-byte-aligned owned buffers). Out
//! -of-core training needs the same tables to be *views into an mmap'd
//! cache file* instead, without the sweep kernels or `PackedCtx`
//! noticing. `BlockStore` is that seam: a two-arm enum (`Resident`
//! owned `AVec`, `Mapped` view into a shared [`MapArena`]) that derefs
//! to `&[T]` exactly like `AVec` does, so every existing consumer —
//! kernels, validators, tests comparing against `Vec<T>` — keeps
//! compiling unchanged.
//!
//! Builders (`PackedBlocks::build`, `finalize_lanes`) only ever create
//! the `Resident` arm; the `Mapped` arm is created exclusively by
//! `data::cache::open`, which validates the section geometry (64-byte
//! offset, in-bounds, length a multiple of the element size) before a
//! view is ever constructed. Mutating a `Mapped` store is a programmer
//! error and panics.

#[cfg(unix)]
use std::sync::Arc;

use crate::simd::AVec;

#[cfg(unix)]
use super::mmap::MapArena;

/// Aligned table storage: owned (`Resident`) or an mmap view (`Mapped`).
pub enum BlockStore<T: Copy> {
    Resident(AVec<T>),
    #[cfg(unix)]
    Mapped {
        /// Keeps the mapping alive for the lifetime of the view.
        arena: Arc<MapArena>,
        /// Byte offset of the section inside the arena (64-byte multiple).
        off: usize,
        /// Length in *elements* of `T`.
        len: usize,
    },
}

impl<T: Copy> BlockStore<T> {
    /// Construct a mapped view. Callers (only `data::cache::open`) must
    /// have validated that `off` is `ALIGN`-aligned and that
    /// `off + len * size_of::<T>() <= arena.len()`; this re-checks both
    /// so an unvalidated call cannot create an out-of-bounds view.
    #[cfg(unix)]
    pub(crate) fn mapped(arena: Arc<MapArena>, off: usize, len: usize) -> BlockStore<T> {
        assert_eq!(off % crate::simd::aligned::ALIGN, 0, "mapped section offset not 64-byte aligned");
        assert!(
            off + len * std::mem::size_of::<T>() <= arena.len(),
            "mapped section overruns the arena"
        );
        assert!(std::mem::align_of::<T>() <= crate::simd::aligned::ALIGN);
        BlockStore::Mapped { arena, off, len }
    }

    /// True when backed by the mmap arena (used by the bit-identity and
    /// alignment tests to assert a cache run really is out-of-core).
    pub fn is_mapped(&self) -> bool {
        match self {
            BlockStore::Resident(_) => false,
            #[cfg(unix)]
            BlockStore::Mapped { .. } => true,
        }
    }

    pub fn as_slice(&self) -> &[T] {
        match self {
            BlockStore::Resident(v) => v,
            #[cfg(unix)]
            BlockStore::Mapped { arena, off, len } => {
                if *len == 0 {
                    return &[];
                }
                // SAFETY: `mapped()` checked that [off, off + len·size)
                // lies inside the arena and that off satisfies T's
                // alignment (64 ≥ align_of::<T>() for the POD element
                // types used here); the Arc keeps the mapping alive for
                // the returned borrow's lifetime (tied to &self); the
                // mapping is PROT_READ and never mutated, and T is
                // Copy/POD so any byte pattern is a valid value for the
                // u32/f32/f64 instantiations this crate creates.
                unsafe { std::slice::from_raw_parts(arena.base().add(*off) as *const T, *len) }
            }
        }
    }

    /// Mutable view for builders and the sentinel-mutation test
    /// harnesses. Panics on `Mapped`: the cache file is PROT_READ and
    /// immutable by construction; no builder ever sees that arm.
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        match self {
            BlockStore::Resident(v) => v.as_mut_slice(),
            #[cfg(unix)]
            BlockStore::Mapped { .. } => panic!("mapped block storage is immutable"),
        }
    }

    /// Builder-path append. Panics on `Mapped`: the cache file is
    /// immutable by construction and no builder ever sees that arm.
    pub fn push(&mut self, value: T) {
        match self {
            BlockStore::Resident(v) => v.push(value),
            #[cfg(unix)]
            BlockStore::Mapped { .. } => panic!("mapped block storage is immutable"),
        }
    }

    /// Builder-path bulk append. Same `Mapped` panic as [`push`].
    pub fn extend_from_slice(&mut self, src: &[T]) {
        match self {
            BlockStore::Resident(v) => v.extend_from_slice(src),
            #[cfg(unix)]
            BlockStore::Mapped { .. } => panic!("mapped block storage is immutable"),
        }
    }
}

impl<T: Copy> std::ops::Deref for BlockStore<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Copy> std::ops::DerefMut for BlockStore<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

impl<T: Copy> Default for BlockStore<T> {
    fn default() -> Self {
        BlockStore::Resident(AVec::new())
    }
}

impl<T: Copy> From<AVec<T>> for BlockStore<T> {
    fn from(v: AVec<T>) -> Self {
        BlockStore::Resident(v)
    }
}

impl<T: Copy> Clone for BlockStore<T> {
    fn clone(&self) -> Self {
        match self {
            BlockStore::Resident(v) => BlockStore::Resident(v.clone()),
            // Cloning a view shares the arena — cheap, and keeps a
            // cloned PackedBlocks out-of-core instead of faulting the
            // whole file in.
            #[cfg(unix)]
            BlockStore::Mapped { arena, off, len } => {
                BlockStore::Mapped { arena: Arc::clone(arena), off: *off, len: *len }
            }
        }
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for BlockStore<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl<T: Copy + PartialEq> PartialEq for BlockStore<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// Mixed comparisons mirroring `AVec`'s, so the omega tests keep
/// writing `assert_eq!(block.cols, vec![..])`.
impl<T: Copy + PartialEq> PartialEq<Vec<T>> for BlockStore<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Copy + PartialEq, const N: usize> PartialEq<[T; N]> for BlockStore<T> {
    fn eq(&self, other: &[T; N]) -> bool {
        self.as_slice() == &other[..]
    }
}

impl<T: Copy> FromIterator<T> for BlockStore<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        BlockStore::Resident(iter.into_iter().collect())
    }
}

impl<'a, T: Copy> IntoIterator for &'a BlockStore<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::aligned::is_aligned;

    #[test]
    fn resident_store_behaves_like_avec() {
        let mut s: BlockStore<u32> = BlockStore::default();
        assert!(!s.is_mapped());
        s.push(1);
        s.extend_from_slice(&[2, 3]);
        assert_eq!(s, vec![1, 2, 3]);
        assert_eq!(s, [1u32, 2, 3]);
        assert_eq!(s.len(), 3);
        assert!(is_aligned(&s[..]));
        let t = s.clone();
        assert_eq!(t, s);
        let u: BlockStore<u32> = (1..=3).collect();
        assert_eq!(u, s);
        assert_eq!(format!("{:?}", u), "[1, 2, 3]");
        assert_eq!(u.iter().sum::<u32>(), 6);
    }

    #[cfg(unix)]
    #[test]
    fn mapped_store_views_the_arena_aligned() {
        let dir = std::env::temp_dir().join("dso-blockstore-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("store.bin");
        // 64 bytes of padding, then 4 f32 values at offset 64.
        let mut bytes = vec![0u8; 64];
        for v in [1.5f32, -2.0, 0.25, 8.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let arena = Arc::new(MapArena::map(&path).unwrap());
        let s: BlockStore<f32> = BlockStore::mapped(Arc::clone(&arena), 64, 4);
        assert!(s.is_mapped());
        assert_eq!(s, vec![1.5f32, -2.0, 0.25, 8.0]);
        assert!(is_aligned(&s[..]));
        let t = s.clone();
        drop(arena);
        drop(s);
        // The clone's Arc keeps the mapping alive.
        assert_eq!(t[3], 8.0);
        let empty: BlockStore<u32> = BlockStore::mapped(t.clone_arena(), 0, 0);
        assert_eq!(empty.len(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[cfg(unix)]
    #[test]
    #[should_panic(expected = "immutable")]
    fn mapped_store_rejects_mutation() {
        let dir = std::env::temp_dir().join("dso-blockstore-immut");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("store.bin");
        std::fs::write(&path, vec![0u8; 64]).unwrap();
        let arena = Arc::new(MapArena::map(&path).unwrap());
        let mut s: BlockStore<u32> = BlockStore::mapped(arena, 0, 4);
        std::fs::remove_dir_all(&dir).ok();
        s.push(7);
    }

    #[cfg(unix)]
    impl<T: Copy> BlockStore<T> {
        fn clone_arena(&self) -> Arc<MapArena> {
            match self {
                BlockStore::Mapped { arena, .. } => Arc::clone(arena),
                BlockStore::Resident(_) => unreachable!(),
            }
        }
    }
}
