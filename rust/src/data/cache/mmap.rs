//! Read-only `mmap` arena for the packed-block cache, plus the
//! `madvise(WILLNEED)` hook the schedule-driven prefetcher uses.
//!
//! This is the **only** place in the repo allowed to call `mmap` /
//! `munmap` / `madvise` (enforced by a grep gate in `scripts/ci.sh`).
//! The syscalls are declared directly — `std` already links libc on
//! every unix target, so no external crate is needed. Constants are the
//! Linux values; the module is `#[cfg(unix)]` and `data/cache` falls
//! back to a fully resident read elsewhere.
//!
//! Alignment contract: the cache format (see `data/cache`) places every
//! payload section at a 64-byte multiple file offset, and `mmap` maps
//! the file at a page boundary (4096 = 64 × 64). A section's in-memory
//! address is therefore `base + off` with `off % 64 == 0`, which
//! preserves the `AVec` ALIGN=64 contract from the SIMD layer without
//! copying — `simd::aligned::is_aligned` holds on every mapped table.

#![cfg(unix)]

use std::os::raw::{c_int, c_void};
use std::os::unix::io::AsRawFd;
use std::path::Path;

const PROT_READ: c_int = 1;
const MAP_PRIVATE: c_int = 2;
const MADV_WILLNEED: c_int = 3;
const PAGE: usize = 4096;

extern "C" {
    fn mmap(
        addr: *mut c_void,
        len: usize,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        // 64-bit off_t: correct on every 64-bit unix target this repo
        // builds for (the x86-64/aarch64 perf targets); a 32-bit build
        // would need mmap64 — out of scope, documented here.
        offset: i64,
    ) -> *mut c_void;
    fn munmap(addr: *mut c_void, len: usize) -> c_int;
    fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
}

/// A whole cache file mapped read-only. Sections hand out `&[T]` views
/// into it via `BlockStore::Mapped`; the `Arc<MapArena>` inside each
/// store keeps the mapping alive for as long as any view exists.
pub struct MapArena {
    base: *mut c_void,
    len: usize,
}

// SAFETY: the mapping is PROT_READ and never written through after
// construction; shared `&MapArena` only exposes const pointers and
// advisory madvise calls, so concurrent access from many threads is
// sound (same argument as a shared &[u8]).
unsafe impl Send for MapArena {}
// SAFETY: see the Send impl above.
unsafe impl Sync for MapArena {}

impl MapArena {
    /// Map `path` read-only in its entirety. Zero-length files get an
    /// empty arena without touching `mmap` (mapping 0 bytes is EINVAL).
    pub fn map(path: &Path) -> std::io::Result<MapArena> {
        let f = std::fs::File::open(path)?;
        let len = f.metadata()?.len() as usize;
        if len == 0 {
            return Ok(MapArena { base: std::ptr::null_mut(), len: 0 });
        }
        // SAFETY: fd is a valid open file descriptor for the whole
        // call; len > 0; we request a fresh private read-only mapping
        // (addr = null, offset = 0) and check for MAP_FAILED before
        // using the result. The fd may be closed after mmap returns —
        // the mapping keeps its own reference to the file.
        let base = unsafe { mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, f.as_raw_fd(), 0) };
        if base as isize == -1 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(MapArena { base, len })
    }

    pub fn base(&self) -> *const u8 {
        self.base as *const u8
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Advise the kernel that `[off, off + len)` will be needed soon.
    /// Purely advisory: the range is page-aligned down/up as madvise
    /// requires, clamped to the mapping, and the result is ignored —
    /// a failed hint must never fail a training run.
    pub fn advise_willneed(&self, off: usize, len: usize) {
        if self.len == 0 || len == 0 || off >= self.len {
            return;
        }
        let start = off / PAGE * PAGE;
        let end = (off + len).min(self.len);
        // SAFETY: start is page-aligned and start..end lies within the
        // live mapping ([0, self.len)); madvise does not dereference.
        let rc = unsafe { madvise(self.base.add(start), end - start, MADV_WILLNEED) };
        let _ = rc;
    }
}

impl Drop for MapArena {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: base/len describe exactly the mapping created in
            // `map`, unmapped exactly once; no &[T] view can outlive
            // this arena (every view holds the owning Arc).
            unsafe {
                munmap(self.base, self.len);
            }
        }
    }
}

impl std::fmt::Debug for MapArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MapArena").field("len", &self.len).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_a_file_and_reads_it_back() {
        let dir = std::env::temp_dir().join("dso-maparena-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("arena.bin");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &payload).unwrap();
        let arena = MapArena::map(&path).unwrap();
        assert_eq!(arena.len(), payload.len());
        // SAFETY: test-only view; the arena maps the whole file read-only.
        let view = unsafe { std::slice::from_raw_parts(arena.base(), arena.len()) };
        assert_eq!(view, &payload[..]);
        // Page alignment of the base implies 64-byte alignment.
        assert_eq!(arena.base() as usize % 4096, 0);
        arena.advise_willneed(0, payload.len());
        arena.advise_willneed(8192, 100_000); // clamped past EOF: no-op
        arena.advise_willneed(payload.len() + 5, 1); // out of range: no-op
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_file_maps_to_empty_arena() {
        let dir = std::env::temp_dir().join("dso-maparena-empty");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.bin");
        std::fs::write(&path, b"").unwrap();
        let arena = MapArena::map(&path).unwrap();
        assert!(arena.is_empty());
        arena.advise_willneed(0, 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
