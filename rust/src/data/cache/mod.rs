//! Out-of-core packed-block cache: pack once, mmap thereafter.
//!
//! The paper's headline datasets (kdda, ocr, webspam-t — Table 2) do
//! not fit comfortably in RAM next to the optimizer state, and packing
//! `PackedBlocks` from text is itself a multi-pass job. This module
//! serializes the packed form — lane-major `cols`/`vals` chunks, the
//! `inv_col`/`inv_col32`/`inv_row` reciprocal tables, the
//! `stripe_alpha_bias` coefficients, labels, and the optional
//! `entry_group` sampling side tables — into one versioned,
//! fingerprinted file, and reopens it as an mmap-backed arena so a
//! later run demand-pages exactly the blocks it sweeps instead of
//! re-parsing and re-packing the dataset.
//!
//! ## File format (`DSOBLK1`, little-endian)
//!
//! ```text
//! header   magic[8] version:u32 flags:u32 config_fp:u64 content_hash:u64
//!          m:u64 d:u64 nnz:u64 p:u64 n_sections:u64          (72 bytes)
//! table    n_sections × { kind:u32 index:u32 off:u64 len:u64 } (24 B each)
//! payload  sections, every `off` a 64-byte multiple
//! ```
//!
//! Section kinds (index = stripe r/q or block q·p+r):
//! row/col bounds (u64), row/col counts (u32), labels y (f32),
//! `inv_col` (f64), `inv_col32` (f32, **mapped**), `inv_row` (f64),
//! `alpha_bias` (f32, **mapped**), per-block `groups` (4×u32 per
//! `RowGroup`), `cols` (u32, **mapped**), `vals` (f32, **mapped**),
//! and optional `entry_group` (u32).
//!
//! **Alignment-on-mmap:** every section offset is a 64-byte multiple
//! and `mmap` places the file at a page boundary (4096 = 64·64), so a
//! mapped table's base address satisfies the `AVec` ALIGN=64 contract
//! from the SIMD layer with zero copies — `simd::aligned::is_aligned`
//! holds on every `BlockStore::Mapped` view (pinned by
//! `tests/outofcore.rs`).
//!
//! **Integrity contract:** `config_fp` is the same run fingerprint the
//! checkpoint/handshake layers use (`coordinator::checkpoint::
//! fingerprint`); [`OpenedCache::require_fingerprint`] refuses a cache
//! packed under a different configuration exactly like a foreign
//! checkpoint. `content_hash` (FNV-1a) covers the *eagerly read*
//! sections — bounds, counts, labels, f64 tables, group geometry, side
//! tables — so corruption there is caught at open. The mapped payloads
//! (`cols`/`vals`/`inv_col32`/`alpha_bias`) are deliberately excluded:
//! hashing them would fault the whole file in and defeat demand
//! paging. Their geometry is fully validated at open, and every sweep
//! re-runs `check_packed_bounds` over the mapped slices, so corrupt
//! payload bytes surface as a bounds panic, not silent divergence.
//!
//! **Prefetch coupling:** the DSO schedule is known per (worker,
//! epoch, r) (`RingSchedule::owned_block`), so [`CacheHandle::
//! prefetch`] lets the engines `madvise(WILLNEED)` the next block's
//! `cols`/`vals` regions while the current block sweeps — each
//! worker's resident set stays ~one block plus readahead.

pub mod mmap;
mod store;

pub use store::BlockStore;

use crate::partition::omega::{lane_span, PackedBlock, PackedBlocks, RowGroup};
use crate::partition::Partition;
use anyhow::Result;
use std::path::{Path, PathBuf};
#[cfg(unix)]
use std::sync::Arc;

#[cfg(unix)]
use mmap::MapArena;

const MAGIC: &[u8; 8] = b"DSOBLK1\0";
const VERSION: u32 = 1;
/// Header flag bit: `entry_group` sampling side tables are present.
const FLAG_ENTRY_GROUP: u32 = 1;
const HEADER_LEN: usize = 72;
const TABLE_ENTRY_LEN: usize = 24;
const SECTION_ALIGN: usize = 64;

const K_ROW_BOUNDS: u32 = 1;
const K_COL_BOUNDS: u32 = 2;
const K_ROW_COUNTS: u32 = 3;
const K_COL_COUNTS: u32 = 4;
const K_Y: u32 = 5;
const K_INV_COL: u32 = 6;
const K_INV_COL32: u32 = 7;
const K_INV_ROW: u32 = 8;
const K_ALPHA_BIAS: u32 = 9;
const K_GROUPS: u32 = 10;
const K_COLS: u32 = 11;
const K_VALS: u32 = 12;
const K_ENTRY_GROUP: u32 = 13;

/// Element size by section kind, for the `len % elem` geometry check.
fn elem_size(kind: u32) -> usize {
    match kind {
        K_ROW_BOUNDS | K_COL_BOUNDS => 8,
        K_INV_COL | K_INV_ROW => 8,
        K_GROUPS => 16,
        _ => 4,
    }
}

/// Which kinds the open path reads eagerly (and `content_hash` covers).
/// The complement — the mapped payload kinds — stays demand-paged.
fn is_eager(kind: u32) -> bool {
    !matches!(kind, K_INV_COL32 | K_ALPHA_BIAS | K_COLS | K_VALS)
}

/// FNV-1a, local to the cache layer (the checkpoint layer has its own
/// private copy; sharing it would couple the format to an unrelated
/// module's internals).
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn u32_at(b: &[u8], off: usize) -> u32 {
    let mut x = [0u8; 4];
    x.copy_from_slice(&b[off..off + 4]);
    u32::from_le_bytes(x)
}

fn u64_at(b: &[u8], off: usize) -> u64 {
    let mut x = [0u8; 8];
    x.copy_from_slice(&b[off..off + 8]);
    u64::from_le_bytes(x)
}

fn read_u32s(b: &[u8]) -> Vec<u32> {
    b.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn read_u64s(b: &[u8]) -> Vec<u64> {
    b.chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

fn read_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn read_f64s(b: &[u8]) -> Vec<f64> {
    b.chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

fn bytes_of_u32s(xs: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        put_u32(&mut out, x);
    }
    out
}

fn bytes_of_f32s(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_of_f64s(xs: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_of_usizes(xs: &[usize]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for &x in xs {
        put_u64(&mut out, x as u64);
    }
    out
}

fn align_up(off: usize) -> usize {
    off.div_ceil(SECTION_ALIGN) * SECTION_ALIGN
}

/// Canonical cache file path for a dataset inside `dir`: the dataset
/// name with path separators neutralized, plus the `.dsoblk` suffix.
pub fn cache_path(dir: &Path, dataset: &str) -> PathBuf {
    let safe: String = dataset
        .chars()
        .map(|c| if c == '/' || c == '\\' || c == ':' || c.is_whitespace() { '_' } else { c })
        .collect();
    dir.join(format!("{safe}.dsoblk"))
}

/// Serialize packed blocks (+ labels and the per-stripe α-bias tables)
/// into the cache file at `path`, atomically and durably.
pub fn pack(
    path: &Path,
    omega: &PackedBlocks,
    alpha_bias: &[BlockStore<f32>],
    y: &[f32],
    config_fp: u64,
) -> Result<()> {
    let p = omega.p;
    anyhow::ensure!(alpha_bias.len() == p, "alpha_bias stripes != p");
    anyhow::ensure!(y.len() == omega.row_part.n(), "labels != rows");
    let with_tables = omega.blocks.iter().any(|b| !b.entry_group.is_empty());
    let flags = if with_tables { FLAG_ENTRY_GROUP } else { 0 };

    // (kind, index, payload bytes) in file order. Per block, `cols` is
    // immediately followed by `vals` so one prefetch window covers the
    // whole sweep working set of the block.
    let mut secs: Vec<(u32, u32, Vec<u8>)> = Vec::new();
    secs.push((K_ROW_BOUNDS, 0, bytes_of_usizes(&omega.row_part.bounds)));
    secs.push((K_COL_BOUNDS, 0, bytes_of_usizes(&omega.col_part.bounds)));
    secs.push((K_ROW_COUNTS, 0, bytes_of_u32s(&omega.row_counts)));
    secs.push((K_COL_COUNTS, 0, bytes_of_u32s(&omega.col_counts)));
    secs.push((K_Y, 0, bytes_of_f32s(y)));
    for r in 0..p {
        secs.push((K_INV_COL, r as u32, bytes_of_f64s(&omega.inv_col[r])));
        secs.push((K_INV_COL32, r as u32, bytes_of_f32s(&omega.inv_col32[r])));
    }
    for q in 0..p {
        secs.push((K_INV_ROW, q as u32, bytes_of_f64s(&omega.inv_row[q])));
        secs.push((K_ALPHA_BIAS, q as u32, bytes_of_f32s(&alpha_bias[q])));
    }
    for qr in 0..p * p {
        let b = &omega.blocks[qr];
        let mut gbytes = Vec::with_capacity(b.groups.len() * 16);
        for g in &b.groups {
            put_u32(&mut gbytes, g.li);
            put_u32(&mut gbytes, g.start);
            put_u32(&mut gbytes, g.end);
            put_u32(&mut gbytes, g.pad_start);
        }
        secs.push((K_GROUPS, qr as u32, gbytes));
        secs.push((K_COLS, qr as u32, bytes_of_u32s(&b.cols)));
        secs.push((K_VALS, qr as u32, bytes_of_f32s(&b.vals)));
        if with_tables {
            secs.push((K_ENTRY_GROUP, qr as u32, bytes_of_u32s(&b.entry_group)));
        }
    }

    // Assign 64-byte-aligned offsets and hash the eager sections
    // (framing + bytes) exactly as `open` will recompute it.
    let table_end = HEADER_LEN + secs.len() * TABLE_ENTRY_LEN;
    let mut off = align_up(table_end);
    let mut offs = Vec::with_capacity(secs.len());
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for (kind, index, bytes) in &secs {
        offs.push(off);
        off = align_up(off + bytes.len());
        if is_eager(*kind) {
            hash = fnv1a(hash, &kind.to_le_bytes());
            hash = fnv1a(hash, &index.to_le_bytes());
            hash = fnv1a(hash, &(bytes.len() as u64).to_le_bytes());
            hash = fnv1a(hash, bytes);
        }
    }
    let file_len = off;

    let mut out = Vec::with_capacity(file_len);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, flags);
    put_u64(&mut out, config_fp);
    put_u64(&mut out, hash);
    put_u64(&mut out, omega.row_part.n() as u64);
    put_u64(&mut out, omega.col_part.n() as u64);
    put_u64(&mut out, omega.total_nnz() as u64);
    put_u64(&mut out, p as u64);
    put_u64(&mut out, secs.len() as u64);
    debug_assert_eq!(out.len(), HEADER_LEN);
    for ((kind, index, bytes), &o) in secs.iter().zip(&offs) {
        put_u32(&mut out, *kind);
        put_u32(&mut out, *index);
        put_u64(&mut out, o as u64);
        put_u64(&mut out, bytes.len() as u64);
    }
    for ((_, _, bytes), &o) in secs.iter().zip(&offs) {
        out.resize(o, 0);
        out.extend_from_slice(bytes);
    }
    out.resize(file_len, 0);

    if let Some(parent) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)
            .map_err(|e| anyhow::anyhow!("creating cache dir {}: {e}", parent.display()))?;
    }
    crate::util::fsio::write_atomic_durable(path, &out)
        .map_err(|e| anyhow::anyhow!("writing cache {}: {e}", path.display()))?;
    Ok(())
}

/// The backing bytes of an opened cache: an mmap arena on unix, a fully
/// resident buffer elsewhere (or wherever mapping is unavailable).
enum Payload {
    #[cfg(unix)]
    Map(Arc<MapArena>),
    #[cfg_attr(unix, allow(dead_code))]
    Buf(Vec<u8>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            #[cfg(unix)]
            Payload::Map(a) => a.len(),
            Payload::Buf(b) => b.len(),
        }
    }

    /// Borrow `[off, off + len)`. Callers validate the range against
    /// `len()` first (the section geometry checks in `open`).
    fn bytes(&self, off: usize, len: usize) -> &[u8] {
        match self {
            #[cfg(unix)]
            Payload::Map(a) => {
                assert!(off + len <= a.len(), "section range outside arena");
                if len == 0 {
                    return &[];
                }
                // SAFETY: the assert above keeps [off, off+len) inside
                // the live read-only mapping; u8 has alignment 1; the
                // borrow is tied to &self, which owns the Arc keeping
                // the mapping alive.
                unsafe { std::slice::from_raw_parts(a.base().add(off), len) }
            }
            Payload::Buf(b) => &b[off..off + len],
        }
    }

    /// A `BlockStore<u32>` over `[off, off + bytes)`: a zero-copy
    /// mapped view when the payload is an arena, a decoded resident
    /// table otherwise.
    fn store_u32(&self, off: usize, bytes: usize) -> BlockStore<u32> {
        match self {
            #[cfg(unix)]
            Payload::Map(a) => BlockStore::mapped(Arc::clone(a), off, bytes / 4),
            Payload::Buf(_) => read_u32s(self.bytes(off, bytes)).into_iter().collect(),
        }
    }

    fn store_f32(&self, off: usize, bytes: usize) -> BlockStore<f32> {
        match self {
            #[cfg(unix)]
            Payload::Map(a) => BlockStore::mapped(Arc::clone(a), off, bytes / 4),
            Payload::Buf(_) => read_f32s(self.bytes(off, bytes)).into_iter().collect(),
        }
    }
}

/// Schedule-driven prefetch driver over the mapped arena. Cheap to
/// clone and share; a default handle (resident run, or non-unix build)
/// makes every `prefetch` a no-op.
#[derive(Clone, Debug, Default)]
pub struct CacheHandle {
    #[cfg(unix)]
    inner: Option<Arc<Prefetcher>>,
}

#[cfg(unix)]
#[derive(Debug)]
struct Prefetcher {
    arena: Arc<MapArena>,
    p: usize,
    /// Per block q·p+r: byte ranges of the `cols` and `vals` sections.
    regions: Vec<[(usize, usize); 2]>,
}

impl CacheHandle {
    /// Advise the kernel that block Ω^(q,r) will be swept soon. Purely
    /// advisory (never fails, never blocks); no-op on resident runs.
    pub fn prefetch(&self, q: usize, r: usize) {
        #[cfg(unix)]
        if let Some(pf) = &self.inner {
            if q < pf.p && r < pf.p {
                for &(off, len) in &pf.regions[q * pf.p + r] {
                    pf.arena.advise_willneed(off, len);
                }
            }
        }
        #[cfg(not(unix))]
        {
            let _ = (q, r);
        }
    }

    /// Whether this handle actually drives an mmap arena (true only
    /// for caches opened via [`open`] on unix).
    pub fn is_active(&self) -> bool {
        #[cfg(unix)]
        {
            self.inner.is_some()
        }
        #[cfg(not(unix))]
        {
            false
        }
    }
}

/// Everything `open` reconstructs from a cache file. `omega`'s hot
/// tables (`cols`/`vals`/`inv_col32`) and `alpha_bias` are mmap views;
/// the rest is resident (small, and read eagerly for validation).
pub struct OpenedCache {
    pub config_fp: u64,
    pub m: usize,
    pub d: usize,
    pub nnz: usize,
    pub p: usize,
    pub y: Vec<f32>,
    pub omega: PackedBlocks,
    pub alpha_bias: Vec<BlockStore<f32>>,
    pub handle: CacheHandle,
}

impl OpenedCache {
    /// Refuse a cache packed under a different configuration — the same
    /// contract (and message shape) as checkpoint resume and the proc
    /// handshake.
    pub fn require_fingerprint(&self, expected: u64, path: &Path) -> Result<()> {
        anyhow::ensure!(
            self.config_fp == expected,
            "cache {} was packed by a different run (fingerprint {:016x}, this configuration \
             {expected:016x}); refusing to train from a foreign cache",
            path.display(),
            self.config_fp,
        );
        Ok(())
    }
}

struct Sec {
    kind: u32,
    index: u32,
    off: usize,
    len: usize,
}

/// Open a cache file: validate header, geometry, and content hash, and
/// reconstruct [`PackedBlocks`] with the hot tables as mmap views.
pub fn open(path: &Path) -> Result<OpenedCache> {
    #[cfg(unix)]
    let payload = Payload::Map(Arc::new(
        MapArena::map(path).map_err(|e| anyhow::anyhow!("mapping cache {}: {e}", path.display()))?,
    ));
    #[cfg(not(unix))]
    let payload = Payload::Buf(
        std::fs::read(path).map_err(|e| anyhow::anyhow!("reading cache {}: {e}", path.display()))?,
    );
    let file_len = payload.len();
    let ctx = |msg: String| anyhow::anyhow!("cache {}: {msg}", path.display());

    anyhow::ensure!(file_len >= HEADER_LEN, ctx("truncated header".into()));
    let header = payload.bytes(0, HEADER_LEN);
    anyhow::ensure!(&header[..8] == MAGIC, ctx("not a dso block cache (bad magic)".into()));
    let version = u32_at(header, 8);
    anyhow::ensure!(version == VERSION, ctx(format!("unsupported cache version {version}")));
    let flags = u32_at(header, 12);
    let config_fp = u64_at(header, 16);
    let content_hash = u64_at(header, 24);
    let m = u64_at(header, 32) as usize;
    let d = u64_at(header, 40) as usize;
    let nnz = u64_at(header, 48) as usize;
    let p = u64_at(header, 56) as usize;
    let n_sections = u64_at(header, 64) as usize;
    anyhow::ensure!(p >= 1 && p <= 1 << 12, ctx(format!("implausible p = {p}")));
    anyhow::ensure!(
        m <= 1 << 40 && d <= 1 << 40 && nnz <= 1 << 48,
        ctx("implausible dimensions".into())
    );
    let table_end = HEADER_LEN
        .checked_add(n_sections.checked_mul(TABLE_ENTRY_LEN).unwrap_or(usize::MAX))
        .unwrap_or(usize::MAX);
    anyhow::ensure!(table_end <= file_len, ctx("section table truncated".into()));

    // Parse + geometry-check the section table, recomputing the
    // content hash over the eager sections as we go.
    let mut secs = Vec::with_capacity(n_sections);
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for s in 0..n_sections {
        let e = payload.bytes(HEADER_LEN + s * TABLE_ENTRY_LEN, TABLE_ENTRY_LEN);
        let kind = u32_at(e, 0);
        let index = u32_at(e, 4);
        let off = u64_at(e, 8) as usize;
        let len = u64_at(e, 16) as usize;
        anyhow::ensure!(
            off % SECTION_ALIGN == 0,
            ctx(format!("section {s} offset {off} not 64-byte aligned"))
        );
        anyhow::ensure!(
            off >= table_end && off.checked_add(len).is_some_and(|end| end <= file_len),
            ctx(format!("section {s} range {off}+{len} outside file"))
        );
        anyhow::ensure!(
            len % elem_size(kind) == 0,
            ctx(format!("section {s} length {len} not a multiple of its element size"))
        );
        if is_eager(kind) {
            hash = fnv1a(hash, &kind.to_le_bytes());
            hash = fnv1a(hash, &index.to_le_bytes());
            hash = fnv1a(hash, &(len as u64).to_le_bytes());
            hash = fnv1a(hash, payload.bytes(off, len));
        }
        secs.push(Sec { kind, index, off, len });
    }
    anyhow::ensure!(
        hash == content_hash,
        ctx(format!("content hash mismatch ({hash:016x} != {content_hash:016x}) — corrupt file"))
    );

    let find = |kind: u32, index: usize| -> Result<&Sec> {
        secs.iter()
            .find(|s| s.kind == kind && s.index as usize == index)
            .ok_or_else(|| ctx(format!("missing section kind {kind} index {index}")))
    };
    let eager = |s: &Sec| payload.bytes(s.off, s.len);

    // Partitions: monotone bounds from 0 to m/d, exactly p+1 entries.
    let decode_bounds = |kind: u32, n: usize, what: &str| -> Result<Partition> {
        let s = find(kind, 0)?;
        let raw = read_u64s(eager(s));
        anyhow::ensure!(raw.len() == p + 1, ctx(format!("{what} bounds: {} != p+1", raw.len())));
        let bounds: Vec<usize> = raw.iter().map(|&v| v as usize).collect();
        anyhow::ensure!(
            bounds[0] == 0 && bounds[p] == n && bounds.windows(2).all(|w| w[0] <= w[1]),
            ctx(format!("{what} bounds not a monotone cover of [0, {n})"))
        );
        Ok(Partition { bounds })
    };
    let row_part = decode_bounds(K_ROW_BOUNDS, m, "row")?;
    let col_part = decode_bounds(K_COL_BOUNDS, d, "col")?;

    let row_counts = read_u32s(eager(find(K_ROW_COUNTS, 0)?));
    let col_counts = read_u32s(eager(find(K_COL_COUNTS, 0)?));
    let y = read_f32s(eager(find(K_Y, 0)?));
    anyhow::ensure!(row_counts.len() == m, ctx("row_counts length".into()));
    anyhow::ensure!(col_counts.len() == d, ctx("col_counts length".into()));
    anyhow::ensure!(y.len() == m, ctx("label section length".into()));

    let mut inv_col = Vec::with_capacity(p);
    let mut inv_col32 = Vec::with_capacity(p);
    for r in 0..p {
        let want = col_part.block_len(r);
        let f64s = read_f64s(eager(find(K_INV_COL, r)?));
        anyhow::ensure!(f64s.len() == want, ctx(format!("inv_col[{r}] length")));
        inv_col.push(f64s);
        let s32 = find(K_INV_COL32, r)?;
        anyhow::ensure!(s32.len / 4 == want, ctx(format!("inv_col32[{r}] length")));
        inv_col32.push(payload.store_f32(s32.off, s32.len));
    }
    let mut inv_row = Vec::with_capacity(p);
    let mut alpha_bias = Vec::with_capacity(p);
    for q in 0..p {
        let want = row_part.block_len(q);
        let f64s = read_f64s(eager(find(K_INV_ROW, q)?));
        anyhow::ensure!(f64s.len() == want, ctx(format!("inv_row[{q}] length")));
        inv_row.push(f64s);
        let sb = find(K_ALPHA_BIAS, q)?;
        anyhow::ensure!(sb.len / 4 == want, ctx(format!("alpha_bias[{q}] length")));
        alpha_bias.push(payload.store_f32(sb.off, sb.len));
    }

    let with_tables = flags & FLAG_ENTRY_GROUP != 0;
    let mut blocks = Vec::with_capacity(p * p);
    #[cfg(unix)]
    let mut regions: Vec<[(usize, usize); 2]> = Vec::with_capacity(p * p);
    let mut total_nnz = 0usize;
    for qr in 0..p * p {
        let (q, r) = (qr / p, qr % p);
        let n_rows = row_part.block_len(q) as u32;
        let n_cols = col_part.block_len(r) as u32;
        let gsec = find(K_GROUPS, qr)?;
        let gb = eager(gsec);
        let n_groups = gsec.len / 16;
        let mut groups = Vec::with_capacity(n_groups);
        let (mut next, mut pnext, mut padded, mut lane_groups) = (0u32, 0u32, 0usize, 0u32);
        let mut prev_li: Option<u32> = None;
        for gi in 0..n_groups {
            let g = RowGroup {
                li: u32_at(gb, gi * 16),
                start: u32_at(gb, gi * 16 + 4),
                end: u32_at(gb, gi * 16 + 8),
                pad_start: u32_at(gb, gi * 16 + 12),
            };
            anyhow::ensure!(
                g.start == next && g.end > g.start && g.pad_start == pnext,
                ctx(format!("block ({q},{r}) group {gi} does not tile the block"))
            );
            anyhow::ensure!(
                g.li < n_rows && prev_li.map_or(true, |pl| g.li > pl),
                ctx(format!("block ({q},{r}) group {gi} row id out of order or stripe"))
            );
            let span = lane_span(g.len());
            anyhow::ensure!(
                (pnext as usize).checked_add(span).is_some_and(|v| v <= u32::MAX as usize),
                ctx(format!("block ({q},{r}) physical layout overflows u32"))
            );
            if g.lane_eligible() {
                lane_groups += 1;
            }
            next = g.end;
            pnext += span as u32;
            padded += span;
            prev_li = Some(g.li);
            groups.push(g);
        }
        let block_nnz = next as usize;
        total_nnz += block_nnz;
        let csec = find(K_COLS, qr)?;
        let vsec = find(K_VALS, qr)?;
        anyhow::ensure!(
            csec.len / 4 == padded && vsec.len / 4 == padded,
            ctx(format!("block ({q},{r}) cols/vals length != padded nnz {padded}"))
        );
        let entry_group = if with_tables {
            let esec = find(K_ENTRY_GROUP, qr)?;
            let table = read_u32s(eager(esec));
            anyhow::ensure!(
                table.len() == block_nnz && table.iter().all(|&gi| (gi as usize) < groups.len()),
                ctx(format!("block ({q},{r}) entry_group table inconsistent"))
            );
            table
        } else {
            Vec::new()
        };
        #[cfg(unix)]
        regions.push([(csec.off, csec.len), (vsec.off, vsec.len)]);
        blocks.push(PackedBlock {
            groups,
            cols: payload.store_u32(csec.off, csec.len),
            vals: payload.store_f32(vsec.off, vsec.len),
            n_rows,
            n_cols,
            entry_group,
            lane_groups,
        });
    }
    anyhow::ensure!(
        total_nnz == nnz,
        ctx(format!("blocks cover {total_nnz} nonzeros, header says {nnz}"))
    );

    let handle = match &payload {
        #[cfg(unix)]
        Payload::Map(arena) => CacheHandle {
            inner: Some(Arc::new(Prefetcher { arena: Arc::clone(arena), p, regions })),
        },
        Payload::Buf(_) => CacheHandle::default(),
    };

    let omega = PackedBlocks {
        p,
        blocks,
        row_counts,
        col_counts,
        inv_col,
        inv_col32,
        inv_row,
        m,
        row_part,
        col_part,
    };
    Ok(OpenedCache { config_fp, m, d, nnz, p, y, omega, alpha_bias, handle })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SparseSpec;

    fn toy() -> (crate::data::Dataset, PackedBlocks, Vec<BlockStore<f32>>) {
        let ds = SparseSpec {
            name: "cache-toy".into(),
            m: 60,
            d: 40,
            nnz_per_row: 10.0,
            zipf_s: 0.7,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 7,
        }
        .generate();
        let rp = Partition::even(ds.m(), 3);
        let cp = Partition::even(ds.d(), 3);
        let om = PackedBlocks::build(&ds.x, &rp, &cp).with_sampling_tables();
        let bias: Vec<BlockStore<f32>> =
            om.stripe_alpha_bias(&ds.y).into_iter().map(Into::into).collect();
        (ds, om, bias)
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("dso-cache-mod-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn pack_open_round_trips_all_tables() {
        let (ds, om, bias) = toy();
        let path = tmp("roundtrip.dsoblk");
        pack(&path, &om, &bias, &ds.y, 0xABCD).unwrap();
        let opened = open(&path).unwrap();
        assert_eq!(opened.config_fp, 0xABCD);
        assert_eq!((opened.m, opened.d, opened.p), (ds.m(), ds.d(), 3));
        assert_eq!(opened.nnz, om.total_nnz());
        assert_eq!(opened.y, ds.y);
        assert_eq!(opened.omega.row_part, om.row_part);
        assert_eq!(opened.omega.col_part, om.col_part);
        assert_eq!(opened.omega.row_counts, om.row_counts);
        assert_eq!(opened.omega.col_counts, om.col_counts);
        assert_eq!(opened.omega.inv_col, om.inv_col);
        assert_eq!(opened.omega.inv_row, om.inv_row);
        for r in 0..3 {
            assert_eq!(opened.omega.inv_col32[r], om.inv_col32[r]);
            assert_eq!(opened.alpha_bias[r], bias[r]);
        }
        for qr in 0..9 {
            assert_eq!(opened.omega.blocks[qr], om.blocks[qr], "block {qr}");
        }
        // The reconstructed blocks pass the full structural validator
        // against the original matrix.
        opened.omega.validate(&ds.x).unwrap();
        // On unix the hot tables really are mapped and the prefetch
        // handle is live.
        #[cfg(unix)]
        {
            assert!(opened.omega.blocks[0].cols.is_mapped());
            assert!(opened.handle.is_active());
            opened.handle.prefetch(0, 2);
            opened.handle.prefetch(9, 9); // out of range: no-op
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fingerprint_refusal_names_both_prints() {
        let (ds, om, bias) = toy();
        let path = tmp("foreign.dsoblk");
        pack(&path, &om, &bias, &ds.y, 0x1111).unwrap();
        let opened = open(&path).unwrap();
        opened.require_fingerprint(0x1111, &path).unwrap();
        let err = opened.require_fingerprint(0x2222, &path).unwrap_err().to_string();
        assert!(err.contains("different run") && err.contains("0000000000001111"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_eager_bytes_and_bad_magic_are_refused() {
        let (ds, om, bias) = toy();
        let path = tmp("corrupt.dsoblk");
        pack(&path, &om, &bias, &ds.y, 1).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one byte in the first payload section (row bounds —
        // eager, so hash-covered; the trailing bytes of the file can be
        // alignment padding, which is rightly *not* covered).
        let n = u64_at(&bytes, 64) as usize;
        let first_payload = align_up(HEADER_LEN + n * TABLE_ENTRY_LEN);
        bytes[first_payload] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = open(&path).unwrap_err().to_string();
        assert!(err.contains("hash"), "{err}");
        bytes[first_payload] ^= 0xFF; // restore
        // Bad magic.
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(open(&path).unwrap_err().to_string().contains("magic"));
        // Truncation.
        std::fs::write(&path, &bytes[..40]).unwrap();
        assert!(open(&path).unwrap_err().to_string().contains("truncated"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cache_path_neutralizes_separators() {
        let p = cache_path(Path::new("/tmp/caches"), "data/set name");
        assert_eq!(p, Path::new("/tmp/caches").join("data_set_name.dsoblk"));
    }
}
