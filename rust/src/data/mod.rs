//! Data substrate: sparse matrix storage, LIBSVM interchange, synthetic
//! dataset generators, and the Table 2 dataset registry.

pub mod cache;
pub mod dataset;
pub mod libsvm;
pub mod registry;
pub mod sparse;
pub mod synth;

pub use cache::{BlockStore, CacheHandle};
pub use dataset::{Dataset, DatasetStats};
pub use sparse::{Csc, Csr};
