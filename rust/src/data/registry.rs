//! Named dataset recipes mirroring the paper's Table 2, scaled down so
//! every experiment runs on one box in seconds-to-minutes. Each recipe
//! preserves the *ratios* that matter to DSO: d/m, density and its skew,
//! dense-vs-sparse storage, and the m+:m− label balance. The `scale`
//! multiplier lets experiments (and the perf pass) grow them.
//!
//! Paper Table 2 for reference:
//!   reuters-ccat  m=23149   d=47236   s=0.161%    m+:m-=0.87   (sparse)
//!   real-sim      m=57763   d=20958   s=0.245%    m+:m-=0.44   (sparse)
//!   news20        m=15960   d=1.36M   s=0.033%    m+:m-=1.00   (sparse)
//!   worm          m=0.82M   d=804     s=25.12%    m+:m-=0.06   (block-dense)
//!   alpha         m=0.4M    d=500     s=100%      m+:m-=0.99   (dense)
//!   kdda          m=8.41M   d=20.22M  s=1.82e-4%  m+:m-=6.56   (ultra-sparse)
//!   kddb          m=19.26M  d=29.89M  s=1.02e-4%  m+:m-=7.91   (ultra-sparse)
//!   ocr           m=2.8M    d=1156    s=100%      m+:m-=0.96   (dense, redundant)
//!   dna           m=40M     d=800     s=25%       m+:m-=3e-3   (block-dense)

use super::dataset::Dataset;
use super::synth::{DenseSpec, SparseSpec};

/// All dataset names in paper order.
pub const NAMES: &[&str] = &[
    "reuters-ccat",
    "real-sim",
    "news20",
    "worm",
    "alpha",
    "kdda",
    "kddb",
    "ocr",
    "dna",
];

/// Which datasets the paper uses in the serial experiments (Figs 6–45).
pub const SERIAL_NAMES: &[&str] = &["reuters-ccat", "real-sim", "news20", "worm", "alpha"];

/// Which datasets the paper uses in the parallel experiments (Figs 46–77).
pub const PARALLEL_NAMES: &[&str] = &["kdda", "kddb", "ocr", "dna"];

/// Generate the named dataset at `scale` (1.0 = default reduced size).
/// Returns an error for unknown names listing the valid ones.
pub fn generate(name: &str, scale: f64, seed: u64) -> Result<Dataset, String> {
    let s = |x: usize| ((x as f64 * scale).round() as usize).max(8);
    let ds = match name {
        // Text-like sparse datasets. Row counts reduced ~10–20x, feature
        // space reduced to keep d/m and density(%) close to Table 2.
        "reuters-ccat" => SparseSpec {
            name: name.into(),
            m: s(2400),
            d: s(4800),
            nnz_per_row: 7.7, // -> density ≈ 0.161%
            zipf_s: 1.0,
            label_noise: 0.05,
            pos_frac: 0.465, // m+:m- = 0.87
            seed,
        }
        .generate(),
        "real-sim" => SparseSpec {
            name: name.into(),
            m: s(5800),
            d: s(2100),
            nnz_per_row: 5.1, // -> density ≈ 0.245%
            zipf_s: 0.9,
            label_noise: 0.05,
            pos_frac: 0.306, // 0.44
            seed,
        }
        .generate(),
        "news20" => SparseSpec {
            name: name.into(),
            m: s(1600),
            d: s(27000),
            nnz_per_row: 8.9, // -> density ≈ 0.033%
            zipf_s: 1.05,
            label_noise: 0.05,
            pos_frac: 0.5, // 1.00
            seed,
        }
        .generate(),
        "worm" => DenseSpec {
            name: name.into(),
            m: s(8000),
            d: s(160),
            density: 0.2512,
            label_noise: 0.03,
            pos_frac: 0.057, // 0.06
            prototypes: 64,
            seed,
        }
        .generate(),
        "alpha" => DenseSpec {
            name: name.into(),
            m: s(4000),
            d: s(100),
            density: 1.0,
            label_noise: 0.08,
            pos_frac: 0.497, // 0.99
            prototypes: 256,
            seed,
        }
        .generate(),
        // Ultra-sparse kdd datasets: huge d relative to m, few nnz/row.
        "kdda" => SparseSpec {
            name: name.into(),
            m: s(8400),
            d: s(20200),
            nnz_per_row: 36.0, // paper: ~37 nnz/row
            zipf_s: 1.1,
            label_noise: 0.05,
            pos_frac: 0.868, // 6.56
            seed,
        }
        .generate(),
        "kddb" => SparseSpec {
            name: name.into(),
            m: s(9600),
            d: s(15000),
            nnz_per_row: 30.0,
            zipf_s: 1.1,
            label_noise: 0.05,
            pos_frac: 0.888, // 7.91
            seed,
        }
        .generate(),
        // Dense + highly redundant (few prototypes) — the regime where
        // the paper reports PSGD winning and BMRM being time-competitive.
        "ocr" => DenseSpec {
            name: name.into(),
            m: s(7000),
            d: s(289),
            density: 1.0,
            label_noise: 0.06,
            pos_frac: 0.49, // 0.96
            prototypes: 24,
            seed,
        }
        .generate(),
        "dna" => DenseSpec {
            name: name.into(),
            m: s(16000),
            d: s(200),
            density: 0.25,
            label_noise: 0.01,
            pos_frac: 0.003, // 3e-3 — extreme imbalance
            prototypes: 48,
            seed,
        }
        .generate(),
        other => {
            return Err(format!(
                "unknown dataset '{other}'; valid: {}",
                NAMES.join(", ")
            ))
        }
    };
    Ok(ds)
}

/// Whether the named dataset is dense enough for the tile (PJRT kernel)
/// execution path to be the natural choice.
pub fn is_dense(name: &str) -> bool {
    matches!(name, "worm" | "alpha" | "ocr" | "dna")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_generate_small() {
        for &n in NAMES {
            let ds = generate(n, 0.05, 1).unwrap_or_else(|e| panic!("{n}: {e}"));
            assert!(ds.m() >= 8, "{n}");
            assert!(ds.d() >= 8, "{n}");
            ds.x.validate().unwrap();
        }
    }

    #[test]
    fn unknown_name_lists_options() {
        let e = generate("nope", 1.0, 1).unwrap_err();
        assert!(e.contains("real-sim"));
    }

    #[test]
    fn density_ratios_roughly_match_table2() {
        // (name, expected density %, tolerance factor)
        for (name, target_pct) in
            [("reuters-ccat", 0.161), ("real-sim", 0.245), ("news20", 0.033)]
        {
            let ds = generate(name, 0.25, 2).unwrap();
            let s = ds.stats().density_pct;
            assert!(
                s / target_pct < 5.0 && target_pct / s < 5.0,
                "{name}: density {s}% vs target {target_pct}%"
            );
        }
        let ocr = generate("ocr", 0.1, 2).unwrap();
        assert!((ocr.stats().density_pct - 100.0).abs() < 1e-6);
        let dna = generate("dna", 0.25, 2).unwrap();
        assert!((dna.stats().density_pct - 25.0).abs() < 3.0);
    }

    #[test]
    fn label_ratios_roughly_match_table2() {
        for (name, ratio) in [("kdda", 6.56), ("real-sim", 0.44), ("news20", 1.0)] {
            let ds = generate(name, 0.25, 3).unwrap();
            let r = ds.stats().pos_neg_ratio;
            assert!(
                (r / ratio) < 1.6 && (ratio / r) < 1.6,
                "{name}: ratio {r} vs {ratio}"
            );
        }
    }

    #[test]
    fn scale_scales_m() {
        let a = generate("real-sim", 0.1, 1).unwrap();
        let b = generate("real-sim", 0.2, 1).unwrap();
        assert!(b.m() > (a.m() as f64 * 1.7) as usize);
    }

    #[test]
    fn dense_flags() {
        assert!(is_dense("ocr"));
        assert!(is_dense("dna"));
        assert!(!is_dense("kdda"));
        assert!(!is_dense("real-sim"));
    }

    #[test]
    fn serial_and_parallel_subsets_are_known() {
        for &n in SERIAL_NAMES.iter().chain(PARALLEL_NAMES) {
            assert!(NAMES.contains(&n), "{n}");
        }
    }
}
