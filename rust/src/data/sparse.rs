//! Sparse matrix storage.
//!
//! The training data `X` (m × d, stacked xᵢᵀ) is stored in CSR form —
//! the DSO worker loop iterates rows within an (I_q × J_r) block — with
//! an optional CSC view for column-wise statistics (|Ω̄_j|, needed by
//! the regularizer scaling in Eq. 6/8).

/// Compressed sparse row matrix (f32 values, usize indices).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer, len = rows + 1.
    pub indptr: Vec<usize>,
    /// Column index per nonzero, len = nnz. Sorted within each row.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Build from per-row (col, value) lists. Columns are sorted and
    /// duplicate columns within a row are summed.
    pub fn from_rows(cols: usize, rows: Vec<Vec<(u32, f32)>>) -> Csr {
        let nrows = rows.len();
        let mut indptr = Vec::with_capacity(nrows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for mut row in rows {
            row.sort_by_key(|&(c, _)| c);
            let mut last: Option<u32> = None;
            for (c, v) in row {
                assert!((c as usize) < cols, "column {c} out of bounds ({cols})");
                if last == Some(c) {
                    *values.last_mut().unwrap() += v;
                } else {
                    indices.push(c);
                    values.push(v);
                    last = Some(c);
                }
            }
            indptr.push(indices.len());
        }
        Csr { rows: nrows, cols, indptr, indices, values }
    }

    /// Row slice as (indices, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// ⟨w, x_i⟩ for a dense w.
    pub fn row_dot(&self, i: usize, w: &[f32]) -> f64 {
        let (idx, val) = self.row(i);
        let mut s = 0.0f64;
        for k in 0..idx.len() {
            s += val[k] as f64 * w[idx[k] as usize] as f64;
        }
        s
    }

    /// Number of nonzeros in each column (|Ω̄_j| in the paper).
    pub fn col_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.cols];
        for &c in &self.indices {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Transpose into CSC (same data viewed column-major).
    pub fn to_csc(&self) -> Csc {
        let mut counts = vec![0usize; self.cols];
        for &c in &self.indices {
            counts[c as usize] += 1;
        }
        let mut indptr = vec![0usize; self.cols + 1];
        for j in 0..self.cols {
            indptr[j + 1] = indptr[j] + counts[j];
        }
        let mut pos = indptr.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for k in 0..idx.len() {
                let j = idx[k] as usize;
                indices[pos[j]] = i as u32;
                values[pos[j]] = val[k];
                pos[j] += 1;
            }
        }
        Csc { rows: self.rows, cols: self.cols, indptr, indices, values }
    }

    /// Dense row-major copy (for the dense/tile execution path).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows * self.cols];
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for k in 0..idx.len() {
                out[i * self.cols + idx[k] as usize] = val[k];
            }
        }
        out
    }

    /// Dense sub-block copy, rows [r0, r1) × cols [c0, c1), row-major.
    pub fn dense_block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Vec<f32> {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols);
        let (h, w) = (r1 - r0, c1 - c0);
        let mut out = vec![0f32; h * w];
        for i in r0..r1 {
            let (idx, val) = self.row(i);
            for k in 0..idx.len() {
                let j = idx[k] as usize;
                if j >= c0 && j < c1 {
                    out[(i - r0) * w + (j - c0)] = val[k];
                }
            }
        }
        out
    }

    /// Extract the sub-matrix of the given rows (keeps all columns).
    pub fn select_rows(&self, rows: &[usize]) -> Csr {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for &i in rows {
            let (idx, val) = self.row(i);
            indices.extend_from_slice(idx);
            values.extend_from_slice(val);
            indptr.push(indices.len());
        }
        Csr { rows: rows.len(), cols: self.cols, indptr, indices, values }
    }

    /// Scale every row to unit L2 norm (common preprocessing for the
    /// paper's text datasets).
    pub fn normalize_rows(&mut self) {
        for i in 0..self.rows {
            let (s, e) = (self.indptr[i], self.indptr[i + 1]);
            let norm: f64 =
                self.values[s..e].iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
            if norm > 0.0 {
                for v in &mut self.values[s..e] {
                    *v = (*v as f64 / norm) as f32;
                }
            }
        }
    }

    /// Structural validation (sorted, in-bounds, monotone indptr).
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.rows + 1 {
            return Err("indptr length".into());
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() != self.nnz() {
            return Err("indptr endpoints".into());
        }
        for i in 0..self.rows {
            if self.indptr[i] > self.indptr[i + 1] {
                return Err(format!("indptr not monotone at {i}"));
            }
            let (idx, _) = self.row(i);
            for k in 0..idx.len() {
                if idx[k] as usize >= self.cols {
                    return Err(format!("col out of bounds row {i}"));
                }
                if k > 0 && idx[k - 1] >= idx[k] {
                    return Err(format!("row {i} not strictly sorted"));
                }
            }
        }
        if self.indices.len() != self.values.len() {
            return Err("indices/values length".into());
        }
        Ok(())
    }
}

/// Compressed sparse column matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    /// Row index per nonzero, sorted within each column.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csc {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    pub fn col_nnz(&self, j: usize) -> usize {
        self.indptr[j + 1] - self.indptr[j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [0, 3, 4]]
        Csr::from_rows(
            3,
            vec![vec![(0, 1.0), (2, 2.0)], vec![], vec![(2, 4.0), (1, 3.0)]],
        )
    }

    #[test]
    fn from_rows_sorts_and_counts() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(2).0, &[1, 2]);
        assert_eq!(m.row(2).1, &[3.0, 4.0]);
        assert_eq!(m.row_nnz(1), 0);
        m.validate().unwrap();
    }

    #[test]
    fn duplicate_columns_summed() {
        let m = Csr::from_rows(2, vec![vec![(1, 1.0), (1, 2.5)], vec![(0, 1.0)]]);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row(0).1, &[3.5]);
        m.validate().unwrap();
    }

    #[test]
    fn row_dot_matches_dense() {
        let m = sample();
        let w = [2.0f32, -1.0, 0.5];
        assert!((m.row_dot(0, &w) - (1.0 * 2.0 + 2.0 * 0.5)).abs() < 1e-9);
        assert_eq!(m.row_dot(1, &w), 0.0);
        assert!((m.row_dot(2, &w) - (3.0 * -1.0 + 4.0 * 0.5)).abs() < 1e-9);
    }

    #[test]
    fn col_counts_match() {
        let m = sample();
        assert_eq!(m.col_counts(), vec![1, 1, 2]);
    }

    #[test]
    fn csc_roundtrip_structure() {
        let m = sample();
        let c = m.to_csc();
        assert_eq!(c.nnz(), m.nnz());
        assert_eq!(c.col(2).0, &[0, 2]);
        assert_eq!(c.col(2).1, &[2.0, 4.0]);
        assert_eq!(c.col_nnz(0), 1);
        assert_eq!(c.col_nnz(1), 1);
    }

    #[test]
    fn dense_copy() {
        let m = sample();
        let d = m.to_dense();
        assert_eq!(d, vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn dense_block_copy() {
        let m = sample();
        let b = m.dense_block(1, 3, 1, 3);
        assert_eq!(b, vec![0.0, 0.0, 3.0, 4.0]);
        let full = m.dense_block(0, 3, 0, 3);
        assert_eq!(full, m.to_dense());
        let empty = m.dense_block(0, 0, 0, 0);
        assert!(empty.is_empty());
    }

    #[test]
    fn select_rows_subsets() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows, 2);
        assert_eq!(s.row(0).0, &[1, 2]);
        assert_eq!(s.row(1).0, &[0, 2]);
        s.validate().unwrap();
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut m = sample();
        m.normalize_rows();
        for i in [0usize, 2] {
            let (_, vals) = m.row(i);
            let n: f64 = vals.iter().map(|&v| (v as f64).powi(2)).sum();
            assert!((n - 1.0).abs() < 1e-6, "row {i} norm {n}");
        }
    }

    #[test]
    fn validate_catches_unsorted() {
        let mut m = sample();
        m.indices.swap(0, 1);
        assert!(m.validate().is_err());
    }

    #[test]
    fn density() {
        let m = sample();
        assert!((m.density() - 4.0 / 9.0).abs() < 1e-12);
    }
}
