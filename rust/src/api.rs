//! `dso::api` — the one solver facade.
//!
//! The paper describes one algorithm family — saddle-point sweeps over
//! Ω-blocks — executed under different schedules (bulk-synchronous
//! Algorithm 1, the §6 NOMAD-style async variant, the tile/PJRT path)
//! next to three baselines. This module is the single entry point over
//! all of them:
//!
//! ```no_run
//! use dso::api::Trainer;
//! use dso::config::TrainConfig;
//!
//! # fn main() -> anyhow::Result<()> {
//! let ds = dso::data::registry::generate("real-sim", 0.5, 42)
//!     .map_err(anyhow::Error::msg)?;
//! let (train, test) = ds.split(0.2, 42);
//! let mut cfg = TrainConfig::default();
//! cfg.optim.epochs = 40;
//! let fitted = Trainer::new(cfg).fit(&train, Some(&test))?;
//! println!("objective {:.6}", fitted.result.final_primal);
//! let margins = fitted.predict(&test.x)?;
//! fitted.save(std::path::Path::new("model.dso"))?;
//! # let _ = margins;
//! # Ok(())
//! # }
//! ```
//!
//! [`Trainer`] owns the `Algorithm` × `ExecMode` routing (formerly
//! split between `coordinator::train` and a `bail!` inside
//! `train_dso`), streams per-epoch [`crate::coordinator::EvalRow`]s to
//! an optional [`EpochObserver`], and reaches the Lemma-2 serial replay via
//! [`Trainer::replay`]. [`Fitted`] carries the [`TrainResult`] plus
//! the assembled `(w, α)` with `predict` and libsvm-style model
//! persistence ([`Model`]).
//!
//! Deprecation map (old free function → facade call):
//!
//! | old | new |
//! |---|---|
//! | `coordinator::train(cfg, tr, te)` | `Trainer::new(cfg).fit(tr, te)` |
//! | `coordinator::train_dso` | `Trainer::new(cfg).fit(..)` (algorithm = dso) |
//! | `coordinator::run_replay` | `Trainer::new(cfg).replay(true).fit(..)` |
//! | `coordinator::train_dso_async` | `.algorithm(Algorithm::DsoAsync)` |
//! | `tile::train_dso_tile` | `.mode(ExecMode::Tile)` |
//! | `baselines::{sgd,psgd,bmrm}::train_*` | `.algorithm(Algorithm::{Sgd,Psgd,Bmrm})` |

use crate::config::{Algorithm, ExecMode, LossKind, RegKind, SimdKind, TrainConfig};
use crate::coordinator::monitor::{EpochObserver, TrainResult};
use crate::data::{Csr, Dataset};
use anyhow::Result;
use std::path::Path;

/// Builder-style facade over every engine. Construct with the full
/// [`TrainConfig`], override the routing knobs, then [`Trainer::fit`].
pub struct Trainer<'a> {
    cfg: TrainConfig,
    replay: bool,
    observer: Option<&'a mut dyn EpochObserver>,
}

impl<'a> Trainer<'a> {
    pub fn new(cfg: TrainConfig) -> Trainer<'a> {
        Trainer { cfg, replay: false, observer: None }
    }

    /// Select the solver (`optim.algorithm`).
    pub fn algorithm(mut self, algo: Algorithm) -> Self {
        self.cfg.optim.algorithm = algo;
        self
    }

    /// Select the DSO execution mode (`cluster.mode`): scalar sweeps,
    /// the tile/PJRT path, or the multi-process socket transport
    /// (`ExecMode::Proc`, which requires `Algorithm::DsoAsync`).
    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.cfg.cluster.mode = mode;
        self
    }

    /// Worker heartbeat cadence for the multi-process transport
    /// (`cluster.heartbeat_ms`): a silent link is probed at this
    /// interval so the supervisor can tell slow from dead.
    pub fn heartbeat_ms(mut self, ms: u64) -> Self {
        self.cfg.cluster.heartbeat_ms = ms;
        self
    }

    /// How long a silent or disconnected worker gets before the
    /// supervisor declares it dead and degrades the ring
    /// (`cluster.death_timeout_ms`; must exceed the heartbeat).
    pub fn death_timeout_ms(mut self, ms: u64) -> Self {
        self.cfg.cluster.death_timeout_ms = ms;
        self
    }

    /// Record the multi-process run's delivered-message schedule to
    /// this path (`cluster.sched_out`), replayable bit-for-bit with
    /// [`crate::net::replay_recorded_schedule`].
    pub fn schedule_out(mut self, path: &str) -> Self {
        self.cfg.cluster.sched_out = path.to_string();
        self
    }

    /// Override the worker executable the supervisor spawns
    /// (`cluster.worker_bin`; default: `$DSO_WORKER_BIN`, then the
    /// current executable).
    pub fn worker_bin(mut self, path: &str) -> Self {
        self.cfg.cluster.worker_bin = path.to_string();
        self
    }

    /// Out-of-core packed-block cache policy (`cluster.cache`, default
    /// [`crate::config::CacheMode::Off`]): `Build` packs the training
    /// blocks and writes a fingerprinted `.dsoblk` file under the cache
    /// dir, `Use` mmaps that file and trains with the payload
    /// demand-paged (bit-identical to the resident run), `Auto` picks
    /// whichever applies. Pair with [`Trainer::cache_dir`].
    pub fn cache(mut self, mode: crate::config::CacheMode) -> Self {
        self.cfg.cluster.cache = mode;
        self
    }

    /// Directory holding `.dsoblk` cache files (`cluster.cache_dir`;
    /// required whenever the cache mode is not `Off`).
    pub fn cache_dir(mut self, path: &str) -> Self {
        self.cfg.cluster.cache_dir = path.to_string();
        self
    }

    /// Pin the SIMD kernel backend (`cluster.simd`, default
    /// [`SimdKind::Auto`] = *measured* selection: setup times every
    /// host-supported backend for a few milliseconds on this run's own
    /// packed blocks and keeps the observed winner — recorded on the
    /// sweep plan). `Portable` forces the autovec baseline —
    /// bit-identical to the pre-backend kernels — for reproducibility;
    /// `Avx2` / `Avx512` force the gather/FMA resp. paired 16-wide
    /// backend and fail validation on hosts missing their features
    /// (never a silent fallback). The CLI override is
    /// `--simd {auto,portable,avx2,avx512}`.
    pub fn simd(mut self, kind: SimdKind) -> Self {
        self.cfg.cluster.simd = kind;
        self
    }

    /// Run the Lemma-2 serial replay instead of the threaded engine:
    /// one thread, the canonical (epoch, q, r) order, bit-identical
    /// parameters. Only defined for the scalar DSO engine.
    pub fn replay(mut self, yes: bool) -> Self {
        self.replay = yes;
        self
    }

    /// Inject a deterministic fault schedule (`cluster.faults`), e.g.
    /// `"die@2.0.2,stall@1.0.1:30"` or `"rand:seed=7,die=0.01"` — see
    /// [`crate::net::FaultPlan::parse_with`] for the grammar. Death and
    /// drop faults require `Algorithm::DsoAsync` (the sync ring can
    /// only survive timing faults); [`Trainer::fit`] validates this.
    pub fn faults(mut self, spec: &str) -> Self {
        self.cfg.cluster.faults = spec.to_string();
        self
    }

    /// Write an atomic checkpoint of the full optimizer state every `n`
    /// epochs (`checkpoint.every`) to the configured path. Scalar sync
    /// DSO only; pair with [`Trainer::checkpoint_path`].
    pub fn checkpoint_every(mut self, n: usize) -> Self {
        self.cfg.checkpoint.every = n;
        self
    }

    /// Where periodic checkpoints are written (`checkpoint.path`).
    pub fn checkpoint_path(mut self, path: &str) -> Self {
        self.cfg.checkpoint.path = path.to_string();
        self
    }

    /// Resume from a checkpoint file (`checkpoint.resume`): training
    /// restarts at the epoch after the snapshot and — the sampling
    /// streams being stateless across epochs — finishes bit-identical
    /// to the uninterrupted run. The engine refuses a checkpoint whose
    /// fingerprint does not match this run's configuration.
    pub fn resume(mut self, path: &str) -> Self {
        self.cfg.checkpoint.resume = path.to_string();
        self
    }

    /// Stream every recorded per-epoch [`crate::coordinator::EvalRow`]
    /// to `obs` as training runs (any `FnMut(&EvalRow)` closure works).
    ///
    /// Evaluation cadence follows the engine: most routes record every
    /// `monitor.every` epochs, but `Algorithm::DsoAsync` has no epoch
    /// barrier to evaluate at — it fires the observer once, with the
    /// single end-of-run evaluation.
    pub fn observer(mut self, obs: &'a mut dyn EpochObserver) -> Self {
        self.observer = Some(obs);
        self
    }

    /// The effective configuration (for inspection or further edits).
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn config_mut(&mut self) -> &mut TrainConfig {
        &mut self.cfg
    }

    /// Train on `train`; `test` enables the history's test-error
    /// column. Returns the fitted artifact.
    pub fn fit(self, train: &Dataset, test: Option<&Dataset>) -> Result<Fitted> {
        let Trainer { cfg, replay, observer } = self;
        cfg.validate().map_err(anyhow::Error::msg)?;
        if replay {
            anyhow::ensure!(
                cfg.optim.algorithm == Algorithm::Dso && cfg.cluster.mode == ExecMode::Scalar,
                "replay is the Lemma-2 serial re-execution of the scalar DSO \
                 engine; set algorithm = \"dso\" and mode = \"scalar\""
            );
        }
        let result = match cfg.optim.algorithm {
            Algorithm::Dso => match cfg.cluster.mode {
                ExecMode::Tile => {
                    crate::coordinator::tile::train_dso_tile_with(&cfg, train, test, observer)?
                }
                ExecMode::Scalar if replay => {
                    crate::coordinator::engine::run_replay_with(&cfg, train, test, observer)?
                }
                ExecMode::Scalar => {
                    crate::coordinator::engine::train_dso_with(&cfg, train, test, observer)?
                }
                // validate() rejects this combination; unreachable via
                // fit(), kept as a typed error for direct construction.
                ExecMode::Proc => anyhow::bail!(
                    "mode = \"dso-proc\" is the multi-process async transport; \
                     set algorithm = \"dso-async\""
                ),
            },
            Algorithm::DsoAsync => match cfg.cluster.mode {
                ExecMode::Proc => {
                    crate::net::supervisor::train_dso_proc_with(&cfg, train, test, observer)?
                }
                _ => crate::coordinator::async_engine::train_dso_async_with(
                    &cfg, train, test, observer,
                )?,
            },
            Algorithm::Sgd => crate::baselines::sgd::train_sgd_with(&cfg, train, test, observer)?,
            Algorithm::Psgd => {
                crate::baselines::psgd::train_psgd_with(&cfg, train, test, observer)?
            }
            Algorithm::Bmrm => {
                crate::baselines::bmrm::train_bmrm_with(&cfg, train, test, observer)?
            }
        };
        Ok(Fitted {
            loss: cfg.model.loss,
            reg: cfg.model.reg,
            lambda: cfg.model.lambda,
            result,
        })
    }

    /// Warm-start retraining (DESIGN.md §Serving): train on `train`
    /// with the optimizer seeded from `prior`'s assembled `(w, α)`
    /// instead of the cold initialization. Supported for the scalar
    /// DSO engine (threaded, or the Lemma-2 replay via
    /// [`Trainer::replay`]).
    ///
    /// Reconciliation when `train` is wider than the prior (appended
    /// rows and/or features): the prior occupies the leading
    /// coordinates, appended features start at `w = 0`, appended rows
    /// at the loss's feasible cold-start dual (`alpha_init`), and
    /// every step-rule accumulator starts fresh — exactly what those
    /// coordinates would get in a cold fit. Data *narrower* than the
    /// prior is refused (dropping learned coordinates would silently
    /// change the objective).
    ///
    /// With `optim.epochs = 0` — allowed here, though the cold-fit
    /// validator pins `epochs >= 1` — no sweeps run and the returned
    /// [`Fitted`] carries the prior's parameters bit-identically
    /// (pinned by tests/warmstart.rs): the "just re-wrap the model
    /// against new data" degenerate case.
    ///
    /// Checkpoint lineage: the run's fingerprint additionally mixes in
    /// a provenance hash of the seeding `(w, α)` bit patterns, so warm
    /// checkpoints are never resumable by cold runs (or by warm runs
    /// off a different prior) and vice versa.
    pub fn fit_from(self, prior: &Fitted, train: &Dataset, test: Option<&Dataset>) -> Result<Fitted> {
        let Trainer { cfg, replay, observer } = self;
        // Validate a copy with the epochs floor applied; the engine
        // gets the real value (its epoch loop is simply empty at 0).
        let mut vcfg = cfg.clone();
        vcfg.optim.epochs = cfg.optim.epochs.max(1);
        vcfg.validate().map_err(anyhow::Error::msg)?;
        anyhow::ensure!(
            cfg.optim.algorithm == Algorithm::Dso && cfg.cluster.mode == ExecMode::Scalar,
            "fit_from warm-starts the scalar DSO engine; set algorithm = \"dso\" \
             and mode = \"scalar\" (use .replay(true) for the serial replay)"
        );
        let ws = crate::coordinator::engine::WarmStart {
            provenance: crate::coordinator::checkpoint::warm_provenance(
                &prior.result.w,
                &prior.result.alpha,
            ),
            w: prior.result.w.clone(),
            alpha: prior.result.alpha.clone(),
        };
        let result = if replay {
            crate::coordinator::engine::run_replay_warm_with(&cfg, train, test, Some(&ws), observer)?
        } else {
            crate::coordinator::engine::train_dso_warm_with(&cfg, train, test, Some(&ws), observer)?
        };
        Ok(Fitted {
            loss: cfg.model.loss,
            reg: cfg.model.reg,
            lambda: cfg.model.lambda,
            result,
        })
    }
}

/// The artifact a [`Trainer`] run produces: the full [`TrainResult`]
/// (history, final objective/gap, time axes) plus the assembled
/// `(w, α)` with prediction and persistence.
pub struct Fitted {
    pub result: TrainResult,
    loss: LossKind,
    reg: RegKind,
    lambda: f64,
}

impl Fitted {
    /// The assembled primal weights.
    pub fn w(&self) -> &[f32] {
        &self.result.w
    }

    /// The dual variables where the solver maintains them (empty for
    /// the primal-only baselines).
    pub fn alpha(&self) -> &[f32] {
        &self.result.alpha
    }

    /// Unwrap into the raw [`TrainResult`] (what the deprecated free
    /// functions returned).
    pub fn into_result(self) -> TrainResult {
        self.result
    }

    /// Margins ⟨w, xᵢ⟩ for every row of `x`. Errors on a feature
    /// dimension mismatch (e.g. data generated at a different scale).
    pub fn predict(&self, x: &Csr) -> Result<Vec<f64>> {
        self.model_ref().predict_into(x)
    }

    /// ±1 label predictions sign(⟨w, xᵢ⟩) for every row of `x`.
    pub fn predict_labels(&self, x: &Csr) -> Result<Vec<f32>> {
        self.model_ref().labels_into(x)
    }

    /// 0/1 error on a labeled dataset.
    pub fn error(&self, ds: &Dataset) -> f64 {
        self.model_ref().error_on(ds)
    }

    /// Detach a standalone, persistable linear model.
    pub fn model(&self) -> Model {
        Model {
            algorithm: self.result.algorithm.clone(),
            loss: self.loss,
            reg: self.reg,
            lambda: self.lambda,
            w: self.result.w.clone(),
        }
    }

    /// Save the model in the libsvm-style text format ([`Model::save`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        self.model_ref().save_to(path)
    }

    /// Borrow-free view used by predict/save without cloning w.
    fn model_ref(&self) -> ModelView<'_> {
        ModelView {
            algorithm: &self.result.algorithm,
            loss: self.loss,
            reg: self.reg,
            lambda: self.lambda,
            w: &self.result.w,
        }
    }
}

/// A standalone linear model: the persisted subset of a [`Fitted`]
/// (hyperparameters + w). Saved in a libsvm/liblinear-style plain-text
/// format so models interoperate with scripts:
///
/// ```text
/// dso-model v1
/// algorithm dso
/// loss hinge
/// regularizer l2
/// lambda 0.0001
/// d 20958
/// w
/// <one ASCII float per line, shortest round-trip form>
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct Model {
    pub algorithm: String,
    pub loss: LossKind,
    pub reg: RegKind,
    pub lambda: f64,
    pub w: Vec<f32>,
}

/// Internal borrowed twin of [`Model`] (predict/save without cloning).
struct ModelView<'a> {
    algorithm: &'a str,
    loss: LossKind,
    reg: RegKind,
    lambda: f64,
    w: &'a [f32],
}

impl ModelView<'_> {
    /// ±1 sign map over the margins — the one place the decision
    /// threshold lives (matches `Dataset::test_error`).
    fn labels_into(&self, x: &Csr) -> Result<Vec<f32>> {
        Ok(self
            .predict_into(x)?
            .iter()
            .map(|&u| if u >= 0.0 { 1.0 } else { -1.0 })
            .collect())
    }

    fn error_on(&self, ds: &Dataset) -> f64 {
        ds.test_error(self.w)
    }

    fn predict_into(&self, x: &Csr) -> Result<Vec<f64>> {
        anyhow::ensure!(
            x.cols == self.w.len(),
            "feature dimension mismatch: model d={}, data d={}",
            self.w.len(),
            x.cols
        );
        // Batched predict (DESIGN.md §Serving): pack the rows into the
        // lane-major layout once, then score through the resolved SIMD
        // backend. The fold is an f64 storage-order recurrence on
        // every backend, so this returns bit-identical scores to the
        // old per-row `row_dot` loop regardless of which backend the
        // host resolves (pinned by tests/serve.rs).
        let packed =
            crate::serve::PackedRequests::pack(x, self.w.len()).map_err(anyhow::Error::msg)?;
        let mut out = Vec::new();
        crate::serve::predict_batch(
            &packed,
            self.w,
            crate::simd::resolve(SimdKind::Auto),
            &mut out,
        );
        Ok(out)
    }

    fn save_to(&self, path: &Path) -> Result<()> {
        let mut out = String::new();
        out.push_str("dso-model v1\n");
        out.push_str(&format!("algorithm {}\n", self.algorithm));
        out.push_str(&format!("loss {}\n", self.loss.name()));
        out.push_str(&format!("regularizer {}\n", self.reg.name()));
        // Rust float Display prints the shortest string that parses
        // back to the identical value — the round trip is bit-exact.
        out.push_str(&format!("lambda {}\n", self.lambda));
        out.push_str(&format!("d {}\n", self.w.len()));
        out.push_str("w\n");
        for v in self.w {
            out.push_str(&format!("{v}\n"));
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

impl Model {
    /// Margins ⟨w, xᵢ⟩ for every row of `x`. Errors on a feature
    /// dimension mismatch.
    pub fn predict(&self, x: &Csr) -> Result<Vec<f64>> {
        self.view().predict_into(x)
    }

    /// ±1 label predictions for every row of `x`.
    pub fn predict_labels(&self, x: &Csr) -> Result<Vec<f32>> {
        self.view().labels_into(x)
    }

    /// 0/1 error on a labeled dataset.
    pub fn error(&self, ds: &Dataset) -> f64 {
        self.view().error_on(ds)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.view().save_to(path)
    }

    fn view(&self) -> ModelView<'_> {
        ModelView {
            algorithm: &self.algorithm,
            loss: self.loss,
            reg: self.reg,
            lambda: self.lambda,
            w: &self.w,
        }
    }

    /// Load a model saved by [`Model::save`] / [`Fitted::save`].
    ///
    /// Hardened to the same standard as the libsvm ingest
    /// (`data::libsvm::parse`): every refusal names the 1-based line
    /// it tripped on, and non-finite weights (NaN/±Inf — which would
    /// silently poison every margin a server computes) are refused at
    /// load time rather than discovered per request.
    pub fn load(path: &Path) -> Result<Model> {
        let text = std::fs::read_to_string(path)?;
        // 1-based line numbers, matching the libsvm parser's errors.
        let mut lines = text.lines().enumerate().map(|(i, l)| (i + 1, l));
        let (_, magic) = lines.next().unwrap_or((1, ""));
        anyhow::ensure!(
            magic == "dso-model v1",
            "{}: not a dso model file (bad magic '{magic}')",
            path.display()
        );
        let mut algorithm: Option<String> = None;
        let mut loss: Option<LossKind> = None;
        let mut reg: Option<RegKind> = None;
        let mut lambda: Option<f64> = None;
        let mut d: Option<usize> = None;
        let at = |ln: usize| format!("{}: line {ln}", path.display());
        for (ln, line) in lines.by_ref() {
            if line == "w" {
                break;
            }
            let (key, val) = line
                .split_once(' ')
                .ok_or_else(|| anyhow::anyhow!("{}: malformed model header '{line}'", at(ln)))?;
            match key {
                "algorithm" => algorithm = Some(val.to_string()),
                "loss" => {
                    loss = Some(
                        LossKind::parse(val)
                            .map_err(|e| anyhow::anyhow!("{}: {e}", at(ln)))?,
                    )
                }
                "regularizer" => {
                    reg = Some(
                        RegKind::parse(val)
                            .map_err(|e| anyhow::anyhow!("{}: {e}", at(ln)))?,
                    )
                }
                "lambda" => {
                    lambda = Some(
                        val.parse()
                            .map_err(|_| anyhow::anyhow!("{}: bad lambda '{val}'", at(ln)))?,
                    )
                }
                "d" => {
                    d = Some(
                        val.parse()
                            .map_err(|_| anyhow::anyhow!("{}: bad dimension '{val}'", at(ln)))?,
                    )
                }
                other => anyhow::bail!("{}: unknown model header key '{other}'", at(ln)),
            }
        }
        // Every header written by `save` is required back: a truncated
        // or foreign file must fail loudly, not load with silently
        // defaulted metadata.
        let missing = |k: &'static str| move || anyhow::anyhow!("model header missing '{k}'");
        let algorithm = algorithm.ok_or_else(missing("algorithm"))?;
        let loss = loss.ok_or_else(missing("loss"))?;
        let reg = reg.ok_or_else(missing("regularizer"))?;
        let lambda = lambda.ok_or_else(missing("lambda"))?;
        let d = d.ok_or_else(missing("d"))?;
        // The header is untrusted: don't pre-allocate from a declared
        // dimension a corrupt file could set to anything — cap the
        // hint; the w.len() == d check below still enforces exactness.
        let mut w = Vec::with_capacity(d.min(1 << 20));
        for (ln, line) in lines {
            if line.is_empty() {
                continue;
            }
            let v: f32 = line
                .parse()
                .map_err(|_| anyhow::anyhow!("{}: bad weight '{line}'", at(ln)))?;
            anyhow::ensure!(
                v.is_finite(),
                "{}: non-finite weight '{line}' (a NaN/Inf coordinate would poison \
                 every score; refusing the model)",
                at(ln)
            );
            w.push(v);
        }
        anyhow::ensure!(
            w.len() == d,
            "{}: model declares d={d} but carries {} weights",
            path.display(),
            w.len()
        );
        anyhow::ensure!(
            lambda > 0.0 && lambda.is_finite(),
            "{}: model lambda must be finite and > 0, got {lambda}",
            path.display()
        );
        Ok(Model { algorithm, loss, reg, lambda, w })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_save_load_roundtrip_is_bit_exact() {
        let model = Model {
            algorithm: "dso".into(),
            loss: LossKind::Logistic,
            reg: RegKind::L1,
            lambda: 1e-4,
            w: vec![0.125, -3.5e-8, 1.0, f32::MIN_POSITIVE, -0.0, 0.333_333_34],
        };
        let path = std::env::temp_dir().join("dso-api-roundtrip.model");
        model.save(&path).unwrap();
        let back = Model::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.algorithm, "dso");
        assert_eq!(back.loss, LossKind::Logistic);
        assert_eq!(back.reg, RegKind::L1);
        assert_eq!(back.lambda, 1e-4);
        assert_eq!(back.w.len(), model.w.len());
        for (a, b) in model.w.iter().zip(&back.w) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("dso-api-garbage.model");
        std::fs::write(&path, "not a model\n").unwrap();
        assert!(Model::load(&path).is_err());
        std::fs::write(&path, "dso-model v1\nloss hinge\nw\n0.5\n").unwrap();
        // Missing 'd' header.
        assert!(Model::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_requires_every_header_saved() {
        // A truncated file must not load with silently defaulted
        // metadata: drop each header line in turn and expect an error
        // naming it.
        let full = "dso-model v1\nalgorithm dso\nloss hinge\nregularizer l2\n\
                    lambda 0.001\nd 1\nw\n0.5\n";
        let path = std::env::temp_dir().join("dso-api-headers.model");
        std::fs::write(&path, full).unwrap();
        assert!(Model::load(&path).is_ok());
        for key in ["algorithm", "loss", "regularizer", "lambda", "d"] {
            let truncated: String = full
                .lines()
                .filter(|l| !l.starts_with(&format!("{key} ")))
                .map(|l| format!("{l}\n"))
                .collect();
            std::fs::write(&path, truncated).unwrap();
            let err = Model::load(&path).unwrap_err();
            assert!(format!("{err}").contains(key), "{key}: {err}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn predict_labels_signs() {
        let x = Csr::from_rows(2, vec![vec![(0, 1.0)], vec![(1, 1.0)]]);
        let model = Model {
            algorithm: "dso".into(),
            loss: LossKind::Hinge,
            reg: RegKind::L2,
            lambda: 1e-3,
            w: vec![0.5, -0.5],
        };
        assert_eq!(model.predict(&x).unwrap(), vec![0.5, -0.5]);
        assert_eq!(model.predict_labels(&x).unwrap(), vec![1.0, -1.0]);
        // Dimension mismatch is an error, not a panic.
        let wide = Csr::from_rows(3, vec![vec![(2, 1.0)]]);
        assert!(model.predict(&wide).is_err());
    }
}
