//! Tile-batched DSO for dense data — the L1/L2 execution path.
//!
//! For dense datasets (ocr/alpha/dna analogs) the scalar sweep is
//! memory-bound; the TPU-shaped formulation batches each active block's
//! update into two MXU matmuls (see DESIGN.md §Hardware-Adaptation).
//! The kernel is authored in Pallas (python/compile/kernels/dso_tile.py),
//! AOT-lowered to HLO text, and executed here through the PJRT runtime.
//!
//! Implemented in full once `runtime::artifacts` are built — see
//! `train_dso_tile`.

use super::monitor::TrainResult;
use crate::config::TrainConfig;
use crate::data::Dataset;
use anyhow::Result;

/// Train DSO with tile-batched block updates through the PJRT runtime.
pub fn train_dso_tile(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
) -> Result<TrainResult> {
    crate::runtime::tile_engine::train(cfg, train, test)
}
