//! Tile-batched DSO for dense data — the L1/L2 execution path.
//!
//! For dense datasets (ocr/alpha/dna analogs) the scalar sweep is
//! memory-bound; the TPU-shaped formulation batches each active block's
//! update into two MXU matmuls (see DESIGN.md §Hardware-Adaptation).
//! The kernel is authored in Pallas (python/compile/kernels/dso_tile.py),
//! AOT-lowered to HLO text, and executed here through the PJRT runtime.
//!
//! Implemented in full once `runtime::artifacts` are built — see
//! `train_dso_tile`.

use super::monitor::{EpochObserver, TrainResult};
use crate::config::TrainConfig;
use crate::data::Dataset;
use anyhow::Result;

/// Train DSO with tile-batched block updates through the PJRT runtime.
///
/// Deprecated shim: prefer
/// `dso::api::Trainer::new(cfg).mode(ExecMode::Tile)`.
#[deprecated(since = "0.1.0", note = "use dso::api::Trainer::mode(ExecMode::Tile)")]
pub fn train_dso_tile(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
) -> Result<TrainResult> {
    train_dso_tile_with(cfg, train, test, None)
}

/// [`train_dso_tile`] with an optional per-epoch observer.
pub fn train_dso_tile_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    crate::runtime::tile_engine::train_with(cfg, train, test, obs)
}
