//! The DSO engine — Algorithm 1.
//!
//! p = machines × cores workers run as OS threads on a simulated
//! cluster ([`crate::net`]). Rows/α are partitioned once (I_q); w is
//! partitioned (J_r) and its blocks *rotate* around the ring: at inner
//! iteration r worker q sweeps Ω^(q, σ_r(q)) — stochastic saddle
//! updates (Eq. 8) on coordinates nobody else is touching — then ships
//! its w block (plus that block's AdaGrad state) to the next owner.
//! p inner iterations = 1 epoch; after each epoch the leader
//! re-assembles (w, α) for monitoring.
//!
//! The engine is deterministic given a seed: the same configuration
//! produces bit-identical parameters whether executed on p threads or
//! replayed serially ([`run_replay`]) — the serializability property of
//! Lemma 2, enforced by test.
//!
//! Kernel selection lives in [`super::plan::SweepPlan`] (precompiled
//! per block at setup time); this module only executes the plan. The
//! preferred entry point is the `dso::api::Trainer` facade — the free
//! functions here are kept as thin shims for existing callers.

use super::checkpoint::{self, Checkpoint};
use super::monitor::{EpochObserver, Monitor, TrainResult};
use super::plan::SweepPlan;
use super::updates::{PackedCtx, PackedState, StepRule};
use crate::config::{ExecMode, StepKind, TrainConfig};
use crate::data::Dataset;
use crate::losses::{Loss, Problem, Regularizer};
use crate::net::{Backoff, CostModel, FaultPlan, MsgFault, Recv, Router, VirtualClock, WorkerFault};
use crate::partition::{PackedBlocks, Partition, RingSchedule, LANES};
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

/// Message carrying a w block (and its AdaGrad accumulators) around the
/// ring.
struct WMsg {
    block_id: usize,
    w: Vec<f32>,
    acc: Vec<f32>,
}

/// Everything a worker needs for one epoch, moved in and out of the
/// worker threads.
struct WorkerSlot {
    q: usize,
    w: Vec<f32>,
    w_acc: Vec<f32>,
    alpha: Vec<f32>,
    a_acc: Vec<f32>,
    clock: VirtualClock,
    block_id: usize,
    updates: u64,
    /// Reusable buffer for subsampled entry indices
    /// (`cluster.updates_per_block`) — no per-iteration allocation.
    scratch: Vec<u32>,
}

/// Precomputed, immutable run setup shared by threads — the one
/// constructor of partitions, packed blocks, stripe tables, the cost
/// model, and the kernel dispatch plan, for the sync, replay, *and*
/// async engines (the async engine used to rebuild its own drifting
/// copy with hardcoded even partitions).
pub struct DsoSetup {
    pub problem: Problem,
    pub omega: PackedBlocks,
    /// Per row-stripe label tables (f64) for the packed kernel.
    pub y_local: Vec<Vec<f64>>,
    /// Per row-stripe (y·1/(m|Ω_i|)) as f32 — the square loss's affine
    /// α-bias precompute consumed by the affine lane kernel
    /// (64-byte-aligned per the §Alignment contract; resident or a
    /// cache-file view, see [`crate::data::cache`]).
    pub alpha_bias: Vec<crate::data::cache::BlockStore<f32>>,
    pub schedule: RingSchedule,
    pub p: usize,
    pub w_bound: f64,
    pub cost: CostModel,
    /// Precompiled per-block kernel dispatch (PR 1–3 decision tree).
    pub plan: SweepPlan,
    /// Deterministic fault-injection plan (`cluster.faults`); empty on
    /// normal runs. The sync engine honors timing faults (stall/delay)
    /// and rejects death/drop; the async engine honors all of them.
    pub faults: FaultPlan,
    /// Out-of-core prefetch handle: inert on resident runs, advises
    /// the kernel about the next block's payload on cache-backed runs
    /// ([`crate::data::cache::CacheHandle`]).
    pub cache: crate::data::cache::CacheHandle,
}

impl DsoSetup {
    pub fn new(cfg: &TrainConfig, train: &Dataset) -> DsoSetup {
        let p = cfg.workers().min(train.m()).min(train.d()).max(1);
        let loss = Loss::from(cfg.model.loss);
        let reg = Regularizer::from(cfg.model.reg);
        let problem = Problem::new(loss, reg, cfg.model.lambda);
        let (row_part, col_part) = Self::make_partitions(cfg, train, p);
        let mut omega = PackedBlocks::build(&train.x, &row_part, &col_part);
        if cfg.cluster.updates_per_block > 0 {
            // Only the subsampled sweep reads the per-entry side
            // tables; don't pay +4 bytes/nnz on full-sweep runs.
            omega = omega.with_sampling_tables();
        }
        let y_local = omega.stripe_labels(&train.y);
        let alpha_bias: Vec<crate::data::cache::BlockStore<f32>> =
            omega.stripe_alpha_bias(&train.y).into_iter().map(Into::into).collect();
        let cost = CostModel::new(
            cfg.cluster.latency_us,
            cfg.cluster.bandwidth_mbps,
            cfg.cluster.cores.max(1),
        );
        // Resolve the SIMD backend once per run and record it in the
        // plan's backend dimension. Validating callers have already
        // rejected a forced-level request on unsupported hosts;
        // `resolve` panics rather than silently degrading for any
        // caller that skipped validation. For `auto`, the resolution
        // is a *measurement*: if this is the process's first `auto`
        // resolution, the micro-autotune times every supported backend
        // on a deterministic sample of this run's own packed blocks
        // (`plan::autotune_levels`); the memoized report keeps every
        // later resolution — fingerprints included — in agreement.
        let rule = Self::step_rule_for(cfg);
        let w_bound = loss.w_bound(cfg.model.lambda);
        let (simd, report) = if cfg.cluster.simd == crate::config::SimdKind::Auto {
            let report = crate::simd::autotune::auto_report_with(|levels| {
                crate::coordinator::plan::autotune_levels(
                    &omega,
                    &y_local,
                    &alpha_bias,
                    loss,
                    reg,
                    cfg.model.lambda,
                    w_bound,
                    rule,
                    levels,
                )
            });
            (report.chosen, Some(report.clone()))
        } else {
            (crate::simd::resolve(cfg.cluster.simd), None)
        };
        let plan = SweepPlan::build(
            &omega,
            loss,
            cfg.cluster.updates_per_block,
            cfg.optim.seed,
            simd,
        )
        .with_autotune(report);
        // `validate()` rejects malformed specs with a proper error on
        // every API route before construction gets here.
        let faults = FaultPlan::parse_with(&cfg.cluster.faults, p, cfg.optim.epochs)
            .unwrap_or_else(|e| panic!("invalid cluster.faults (validate() catches this): {e}"));
        DsoSetup {
            problem,
            omega,
            y_local,
            alpha_bias,
            schedule: RingSchedule::new(p),
            p,
            w_bound,
            cost,
            plan,
            faults,
            cache: Default::default(),
        }
    }

    /// [`DsoSetup::new`] with the `cluster.cache` policy applied
    /// (DESIGN.md §Out-of-core): `Build` packs in memory and leaves a
    /// fingerprinted `.dsoblk` behind, `Use` mmaps an existing cache
    /// (refusing a missing file or a foreign fingerprint), `Auto`
    /// picks whichever applies. `Off` (or an empty cache_dir, for
    /// direct callers that skipped `validate()`) is exactly `new`.
    pub fn with_cache(cfg: &TrainConfig, train: &Dataset) -> Result<DsoSetup> {
        use crate::config::CacheMode;
        if cfg.cluster.cache == CacheMode::Off || cfg.cluster.cache_dir.is_empty() {
            return Ok(Self::new(cfg, train));
        }
        let dir = std::path::Path::new(&cfg.cluster.cache_dir);
        let path = crate::data::cache::cache_path(dir, &train.name);
        match cfg.cluster.cache {
            CacheMode::Off => unreachable!("handled above"),
            CacheMode::Build => {
                let setup = Self::new(cfg, train);
                setup.pack_to(cfg, train, &path)?;
                Ok(setup)
            }
            CacheMode::Use => {
                let opened = crate::data::cache::open(&path)?;
                // The fingerprint hashes the cache's own geometry, so a
                // same-named cache of a *different* dataset would pass
                // it — compare against the supplied dataset explicitly.
                if (opened.m, opened.d, opened.nnz)
                    != (train.m(), train.d(), train.x.nnz())
                {
                    anyhow::bail!(
                        "cache {} was packed from a different dataset \
                         ({}x{}, {} nnz; this run {}x{}, {} nnz); refusing to use it",
                        path.display(),
                        opened.m,
                        opened.d,
                        opened.nnz,
                        train.m(),
                        train.d(),
                        train.x.nnz()
                    );
                }
                let fp = Self::cache_fingerprint(cfg, opened.m, opened.d, opened.nnz);
                opened.require_fingerprint(fp, &path)?;
                Ok(Self::from_cache(cfg, opened))
            }
            CacheMode::Auto => {
                if path.exists() {
                    // A stale or foreign cache under auto falls through
                    // to a rebuild instead of refusing the run.
                    if let Ok(opened) = crate::data::cache::open(&path) {
                        let fp =
                            Self::cache_fingerprint(cfg, opened.m, opened.d, opened.nnz);
                        if opened.config_fp == fp
                            && (opened.m, opened.d, opened.nnz)
                                == (train.m(), train.d(), train.x.nnz())
                        {
                            return Ok(Self::from_cache(cfg, opened));
                        }
                    }
                }
                let setup = Self::new(cfg, train);
                setup.pack_to(cfg, train, &path)?;
                Ok(setup)
            }
        }
    }

    /// Build a setup from an opened cache file: the packed blocks and
    /// α-bias tables come from the mapped arena (demand-paged), while
    /// the run machinery (problem, cost model, sweep plan, fault plan)
    /// is rebuilt from the configuration exactly as [`DsoSetup::new`]
    /// does — so a cache-backed run executes the identical update
    /// sequence.
    pub fn from_cache(cfg: &TrainConfig, opened: crate::data::cache::OpenedCache) -> DsoSetup {
        let loss = Loss::from(cfg.model.loss);
        let reg = Regularizer::from(cfg.model.reg);
        let problem = Problem::new(loss, reg, cfg.model.lambda);
        let crate::data::cache::OpenedCache { p, y, omega, alpha_bias, handle, .. } = opened;
        let y_local = omega.stripe_labels(&y);
        let cost = CostModel::new(
            cfg.cluster.latency_us,
            cfg.cluster.bandwidth_mbps,
            cfg.cluster.cores.max(1),
        );
        // Same measured-`auto` resolution as `new` (in the `Use` path
        // the cache fingerprint has already resolved `auto` once, so
        // this returns the memoized report — the fingerprint and the
        // plan can never disagree within a process).
        let rule = Self::step_rule_for(cfg);
        let w_bound = loss.w_bound(cfg.model.lambda);
        let (simd, report) = if cfg.cluster.simd == crate::config::SimdKind::Auto {
            let report = crate::simd::autotune::auto_report_with(|levels| {
                crate::coordinator::plan::autotune_levels(
                    &omega,
                    &y_local,
                    &alpha_bias,
                    loss,
                    reg,
                    cfg.model.lambda,
                    w_bound,
                    rule,
                    levels,
                )
            });
            (report.chosen, Some(report.clone()))
        } else {
            (crate::simd::resolve(cfg.cluster.simd), None)
        };
        let plan = SweepPlan::build(
            &omega,
            loss,
            cfg.cluster.updates_per_block,
            cfg.optim.seed,
            simd,
        )
        .with_autotune(report);
        let faults = FaultPlan::parse_with(&cfg.cluster.faults, p, cfg.optim.epochs)
            .unwrap_or_else(|e| panic!("invalid cluster.faults (validate() catches this): {e}"));
        DsoSetup {
            problem,
            omega,
            y_local,
            alpha_bias,
            schedule: RingSchedule::new(p),
            p,
            w_bound,
            cost,
            plan,
            faults,
            cache: handle,
        }
    }

    /// The epoch-1 step rule — what the autotune probe sweeps with.
    /// Kernel monomorphization depends only on the rule *kind* (fixed
    /// vs accumulator-carrying), not the epoch-dependent η value, so
    /// the first epoch's rule is representative for timing.
    fn step_rule_for(cfg: &TrainConfig) -> StepRule {
        match cfg.optim.step {
            StepKind::Const | StepKind::InvSqrt => StepRule::Fixed(cfg.optim.eta0),
            StepKind::AdaGrad => StepRule::AdaGrad(cfg.optim.eta0),
            StepKind::Adaptive => StepRule::Adaptive(cfg.optim.eta0),
        }
    }

    /// The fingerprint a cache for this configuration must carry —
    /// the checkpoint/handshake fingerprint over the same fields, with
    /// p and the SIMD backend derived the way `new` derives them.
    fn cache_fingerprint(cfg: &TrainConfig, m: usize, d: usize, nnz: usize) -> u64 {
        let p = cfg.workers().min(m).min(d).max(1);
        let simd = crate::simd::resolve(cfg.cluster.simd);
        checkpoint::fingerprint(cfg, m, d, nnz, p, simd)
    }

    /// Serialize this setup's packed tables to `path` (atomic +
    /// durable), stamped with this configuration's fingerprint.
    fn pack_to(
        &self,
        cfg: &TrainConfig,
        train: &Dataset,
        path: &std::path::Path,
    ) -> Result<()> {
        let fp = checkpoint::fingerprint(
            cfg,
            train.m(),
            train.d(),
            train.x.nnz(),
            self.p,
            self.plan.simd(),
        );
        crate::data::cache::pack(path, &self.omega, &self.alpha_bias, &train.y, fp)
    }

    /// Advise the OS that worker `q`'s visit of w block `block_id` is
    /// imminent (madvise(WILLNEED) on the block's cols/vals regions).
    /// Inert on resident runs.
    #[inline]
    pub fn prefetch(&self, q: usize, block_id: usize) {
        self.cache.prefetch(q, block_id);
    }

    /// Build row/column partitions per the configured strategy: equal
    /// index counts, or contiguous blocks balanced by nonzeros so that
    /// |Ω^(q,r)| ≈ |Ω|/p² even on zipf-skewed data (Theorem 1's load
    /// assumption).
    pub fn make_partitions(
        cfg: &TrainConfig,
        train: &Dataset,
        p: usize,
    ) -> (Partition, Partition) {
        match cfg.cluster.partition {
            crate::config::PartitionKind::Even => {
                (Partition::even(train.m(), p), Partition::even(train.d(), p))
            }
            crate::config::PartitionKind::Balanced => {
                let row_w: Vec<u64> =
                    (0..train.m()).map(|i| train.x.row_nnz(i) as u64).collect();
                let col_w: Vec<u64> =
                    train.x.col_counts().iter().map(|&c| c as u64).collect();
                // Column (w) stripes are padded to a lane multiple so the
                // lane-major packed blocks end on chunk boundaries; the
                // cost is at most LANES/2 columns of imbalance per cut.
                (
                    Partition::balanced(&row_w, p),
                    Partition::balanced(&col_w, p).lane_aligned(LANES),
                )
            }
        }
    }

    /// The immutable per-sweep kernel context for worker `q` visiting
    /// w block `block_id` — shared by the sync, replay, and async
    /// engines so the table wiring can never drift between them again.
    pub fn packed_ctx(&self, q: usize, block_id: usize, rule: StepRule) -> PackedCtx<'_> {
        PackedCtx {
            loss: self.problem.loss,
            reg: self.problem.reg,
            lambda: self.problem.lambda,
            w_bound: self.w_bound,
            rule,
            inv_col: &self.omega.inv_col[block_id],
            inv_col32: &self.omega.inv_col32[block_id],
            inv_row: &self.omega.inv_row[q],
            y: &self.y_local[q],
            alpha_bias32: &self.alpha_bias[q],
        }
    }
}

/// Free-function form of [`DsoSetup::make_partitions`], kept for
/// existing callers (tests pin the balanced/lane-aligned behavior
/// through this path).
pub fn make_partitions(
    cfg: &TrainConfig,
    train: &Dataset,
    p: usize,
) -> (Partition, Partition) {
    DsoSetup::make_partitions(cfg, train, p)
}

/// Train with DSO (Algorithm 1). `test` enables test-error columns.
///
/// Deprecated shim: prefer `dso::api::Trainer`, which owns the
/// algorithm/mode routing and adds observer streaming.
#[deprecated(since = "0.1.0", note = "use dso::api::Trainer")]
pub fn train_dso(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainResult> {
    train_dso_with(cfg, train, test, None)
}

/// [`train_dso`] with an optional per-epoch observer (the facade's
/// streaming hook).
pub fn train_dso_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    train_dso_warm_with(cfg, train, test, None, obs)
}

/// Prior state seeding a warm-start run (`api::Trainer::fit_from`):
/// the source model's assembled `(w, α)` plus a provenance hash that
/// [`run_epochs`] mixes into the checkpoint fingerprint, so a warm
/// run's checkpoints are never resumable by the cold run of the same
/// configuration (or by a warm run off a different prior).
///
/// Widening is the supported direction: the prior may be *shorter*
/// than the dataset's `d`/`m` (appended features / appended rows);
/// the tail keeps the cold-start initialization (`w = 0`,
/// `α = loss.alpha_init(y)`) and fresh zero step-rule accumulators —
/// exactly what a cold run would give those coordinates. A prior
/// *longer* than the dataset is refused: silently dropping learned
/// coordinates would change the objective out from under the caller.
pub struct WarmStart {
    pub w: Vec<f32>,
    pub alpha: Vec<f32>,
    pub provenance: u64,
}

/// [`train_dso_with`] seeded from a [`WarmStart`] prior.
pub fn train_dso_warm_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    warm: Option<&WarmStart>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    if cfg.cluster.mode == ExecMode::Tile {
        anyhow::bail!("tile mode is handled by coordinator::tile::train_dso_tile");
    }
    check_warm(warm, train)?;
    let setup = DsoSetup::with_cache(cfg, train)?;
    anyhow::ensure!(
        !setup.faults.has_deaths() && !setup.faults.has_drops(),
        "fault plan injects worker death or message drops, which the bulk-synchronous \
         dso engine cannot survive (a lost ring token deadlocks the epoch barrier); \
         use algorithm = \"dso-async\" for those, or restrict the plan to stall/delay"
    );
    run_epochs(cfg, train, test, &setup, false, warm, obs)
}

/// Refuse priors the dataset cannot hold (see [`WarmStart`]).
fn check_warm(warm: Option<&WarmStart>, train: &Dataset) -> Result<()> {
    if let Some(ws) = warm {
        anyhow::ensure!(
            ws.w.len() <= train.d() && ws.alpha.len() <= train.m(),
            "warm-start prior carries d={} m={} but the dataset has d={} m={}; \
             fit_from can widen (appended rows/features) but never shrink",
            ws.w.len(),
            ws.alpha.len(),
            train.d(),
            train.m(),
        );
    }
    Ok(())
}

/// Serial replay of the identical update sequence (Lemma 2): one
/// thread, same per-(epoch, q, r) ordering. Produces bit-identical
/// parameters to [`train_dso`]; used by tests and for debugging.
///
/// Deprecated shim: prefer `dso::api::Trainer::new(cfg).replay(true)`.
#[deprecated(since = "0.1.0", note = "use dso::api::Trainer::replay(true)")]
pub fn run_replay(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainResult> {
    run_replay_with(cfg, train, test, None)
}

/// [`run_replay`] with an optional per-epoch observer.
pub fn run_replay_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    run_replay_warm_with(cfg, train, test, None, obs)
}

/// [`run_replay_with`] seeded from a [`WarmStart`] prior — warm runs
/// keep the Lemma-2 property (threaded ≡ serial replay bit-identical),
/// since the seed only changes the initial state, not the schedule.
pub fn run_replay_warm_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    warm: Option<&WarmStart>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    check_warm(warm, train)?;
    let setup = DsoSetup::with_cache(cfg, train)?;
    run_epochs(cfg, train, test, &setup, true, warm, obs)
}

fn init_state(
    cfg: &TrainConfig,
    train: &Dataset,
    setup: &DsoSetup,
    warm: Option<&WarmStart>,
) -> (Vec<WorkerSlot>, u64) {
    let p = setup.p;
    let loss = setup.problem.loss;
    let mut slots = Vec::with_capacity(p);
    let mut init_comm: u64 = 0;

    // Optional App. B warm start: every worker runs DCD on its local
    // rows, α initialized locally, w averaged across workers.
    let mut w_full = vec![0f32; train.d()];
    let mut alpha_full: Vec<f32> =
        (0..train.m()).map(|i| loss.alpha_init(train.y[i] as f64) as f32).collect();
    // A warm-start prior supersedes the DCD warm start: the prior IS
    // the initialization, and rerunning DCD over it would clobber the
    // seeded α stripes.
    if cfg.optim.dcd_init && warm.is_none() {
        let mut w_sum = vec![0f64; train.d()];
        for q in 0..p {
            let rows: Vec<usize> = setup.omega.row_part.block(q).collect();
            let local = Dataset::new(
                format!("{}-shard{q}", train.name),
                train.x.select_rows(&rows),
                rows.iter().map(|&i| train.y[i]).collect(),
            );
            let r = crate::optim::dcd::solve_hinge_l2(
                &local,
                cfg.model.lambda,
                10,
                1e-3,
                cfg.optim.seed ^ (q as u64),
            );
            for j in 0..train.d() {
                w_sum[j] += r.w[j] as f64;
            }
            for (k, &i) in rows.iter().enumerate() {
                alpha_full[i] = loss.project_alpha(r.alpha[k] as f64, train.y[i] as f64) as f32;
            }
            // Averaging w is an allreduce: d floats in and out.
            init_comm += 2 * 4 * train.d() as u64;
        }
        for j in 0..train.d() {
            w_full[j] = (w_sum[j] / p as f64) as f32;
        }
    }

    // Warm start (`fit_from`): the prior overwrites the prefix; any
    // appended coordinates keep the cold-start values set above, and
    // every step-rule accumulator starts fresh at zero.
    if let Some(ws) = warm {
        w_full[..ws.w.len()].copy_from_slice(&ws.w);
        alpha_full[..ws.alpha.len()].copy_from_slice(&ws.alpha);
    }

    for q in 0..p {
        let wr = setup.omega.col_part.block(q);
        let ar = setup.omega.row_part.block(q);
        slots.push(WorkerSlot {
            q,
            w: w_full[wr.clone()].to_vec(),
            w_acc: vec![0f32; wr.len()],
            alpha: alpha_full[ar.clone()].to_vec(),
            a_acc: vec![0f32; ar.len()],
            clock: VirtualClock::new(),
            block_id: q,
            updates: 0,
            scratch: Vec::new(),
        });
    }
    (slots, init_comm)
}

fn run_epochs(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    setup: &DsoSetup,
    replay: bool,
    warm: Option<&WarmStart>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    let p = setup.p;
    let (mut slots, init_comm) = init_state(cfg, train, setup, warm);
    let mut monitor = Monitor::observed(cfg.monitor.every, obs);
    let wall = Stopwatch::new();
    let mut router: Router<WMsg> = Router::new(p, setup.cost);
    let stats = router.stats();
    let mut endpoints = if replay { Vec::new() } else { router.take_endpoints() };
    let mut virtual_now;

    // The fingerprint binds checkpoints to this exact update sequence;
    // a warm-start run additionally mixes in its prior's provenance,
    // so warm and cold runs of the same configuration — or warm runs
    // off different priors — never exchange checkpoints.
    let fp =
        checkpoint::fingerprint(cfg, train.m(), train.d(), train.x.nnz(), p, setup.plan.simd());
    let fp = match warm {
        Some(ws) => checkpoint::with_provenance(fp, ws.provenance),
        None => fp,
    };
    let mut start_epoch = 1usize;
    if !cfg.checkpoint.resume.is_empty() {
        let ck = Checkpoint::load(std::path::Path::new(&cfg.checkpoint.resume))?;
        anyhow::ensure!(
            ck.fingerprint == fp,
            "checkpoint {} was written by a different run (fingerprint {:016x}, this \
             configuration {fp:016x}); refusing to resume a foreign optimization",
            cfg.checkpoint.resume,
            ck.fingerprint,
        );
        // After any epoch the blocks are home, so the snapshot splits
        // back into worker stripes along the same partitions.
        for slot in slots.iter_mut() {
            let wr = setup.omega.col_part.block(slot.q);
            let ar = setup.omega.row_part.block(slot.q);
            slot.w.copy_from_slice(&ck.w[wr.clone()]);
            slot.w_acc.copy_from_slice(&ck.w_acc[wr]);
            slot.alpha.copy_from_slice(&ck.alpha[ar.clone()]);
            slot.a_acc.copy_from_slice(&ck.a_acc[ar]);
            slot.updates = 0;
        }
        // The split of the cumulative count across slots is arbitrary;
        // only the sum is ever read.
        slots[0].updates = ck.updates;
        start_epoch = ck.epoch + 1;
    }

    for epoch in start_epoch..=cfg.optim.epochs {
        let rule = match cfg.optim.step {
            StepKind::Const => StepRule::Fixed(cfg.optim.eta0),
            StepKind::InvSqrt => StepRule::Fixed(cfg.optim.eta0 / (epoch as f64).sqrt()),
            StepKind::AdaGrad => StepRule::AdaGrad(cfg.optim.eta0),
            StepKind::Adaptive => StepRule::Adaptive(cfg.optim.eta0),
        };

        if replay {
            run_epoch_serial(setup, &mut slots, rule, epoch);
        } else {
            endpoints = run_epoch_threaded(setup, &mut slots, rule, epoch, endpoints)?;
        }

        // Bulk synchronization barrier.
        let mut clocks: Vec<VirtualClock> = slots.iter().map(|s| s.clock).collect();
        virtual_now = VirtualClock::synchronize(&mut clocks);
        for (s, c) in slots.iter_mut().zip(clocks) {
            s.clock = c;
        }

        if monitor.due(epoch) || epoch == cfg.optim.epochs {
            let (w, alpha) = assemble(setup, &slots);
            let updates: u64 = slots.iter().map(|s| s.updates).sum();
            monitor.set_wait_secs(stats.total_wait_secs());
            monitor.record_saddle(
                &setup.problem,
                train,
                test,
                &w,
                &alpha,
                epoch,
                virtual_now,
                wall.elapsed_secs(),
                updates,
                stats.total_bytes() + init_comm,
            );
        }

        if cfg.checkpoint.every > 0 && epoch % cfg.checkpoint.every == 0 {
            let (w, alpha) = assemble(setup, &slots);
            let (w_acc, a_acc) = assemble_acc(setup, &slots);
            let updates: u64 = slots.iter().map(|s| s.updates).sum();
            Checkpoint { fingerprint: fp, epoch, updates, w, w_acc, alpha, a_acc }
                .save(std::path::Path::new(&cfg.checkpoint.path))?;
        }
    }

    let (w, alpha) = assemble(setup, &slots);
    let updates: u64 = slots.iter().map(|s| s.updates).sum();
    let final_primal = setup.problem.primal(train, &w);
    let final_gap = final_primal - setup.problem.dual(train, &alpha);
    Ok(TrainResult {
        algorithm: if replay { "dso-replay".into() } else { "dso".into() },
        w,
        alpha,
        history: monitor.history,
        final_primal,
        final_gap,
        total_updates: updates,
        total_virtual_s: slots.iter().map(|s| s.clock.total()).fold(0.0, f64::max),
        total_wall_s: wall.elapsed_secs(),
        comm_bytes: stats.total_bytes() + init_comm,
        // The sync engine reports unrecoverable failures as a typed
        // error instead of degrading; a returned result saw none.
        failures: Vec::new(),
    })
}

/// Reassemble the full (w, α) from the slots. After a completed epoch,
/// worker q holds w block q (blocks make a full ring tour per epoch).
fn assemble(setup: &DsoSetup, slots: &[WorkerSlot]) -> (Vec<f32>, Vec<f32>) {
    let d = setup.omega.col_part.n();
    let m = setup.omega.row_part.n();
    let mut w = vec![0f32; d];
    let mut alpha = vec![0f32; m];
    for s in slots {
        debug_assert_eq!(s.block_id, s.q, "block not home after epoch");
        w[setup.omega.col_part.block(s.block_id)].copy_from_slice(&s.w);
        alpha[setup.omega.row_part.block(s.q)].copy_from_slice(&s.alpha);
    }
    (w, alpha)
}

/// [`assemble`]'s AdaGrad twin: the accumulator halves of the
/// checkpointed state, split along the same partitions.
fn assemble_acc(setup: &DsoSetup, slots: &[WorkerSlot]) -> (Vec<f32>, Vec<f32>) {
    let d = setup.omega.col_part.n();
    let m = setup.omega.row_part.n();
    let mut w_acc = vec![0f32; d];
    let mut a_acc = vec![0f32; m];
    for s in slots {
        w_acc[setup.omega.col_part.block(s.block_id)].copy_from_slice(&s.w_acc);
        a_acc[setup.omega.row_part.block(s.q)].copy_from_slice(&s.a_acc);
    }
    (w_acc, a_acc)
}

/// One block visit: execute the precompiled plan for Ω^(q, block_id)
/// (full packed/lane sweep or subsampled updates — the decision tree
/// lives in [`SweepPlan`]). Shared by the threaded and serial epoch
/// loops (identical update sequence).
fn visit_block(
    setup: &DsoSetup,
    slot: &mut WorkerSlot,
    rule: StepRule,
    epoch: usize,
    r: usize,
) -> usize {
    let q = slot.q;
    let block = setup.omega.block(q, slot.block_id);
    let ctx = setup.packed_ctx(q, slot.block_id, rule);
    let mut st = PackedState {
        w: &mut slot.w,
        w_acc: &mut slot.w_acc,
        alpha: &mut slot.alpha,
        a_acc: &mut slot.a_acc,
    };
    setup
        .plan
        .sweep(block, q, slot.block_id, epoch, r, &ctx, &mut st, &mut slot.scratch)
}

/// Drop guard that raises the shared abort flag if its thread unwinds,
/// so ring peers blocked in a bounded-wait receive stop spinning
/// instead of waiting for a message that will never come.
struct AbortOnPanic<'a>(&'a AtomicBool);

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Relaxed);
        }
    }
}

fn run_epoch_threaded(
    setup: &DsoSetup,
    slots: &mut Vec<WorkerSlot>,
    rule: StepRule,
    epoch: usize,
    endpoints: Vec<crate::net::router::Endpoint<WMsg>>,
) -> Result<Vec<crate::net::router::Endpoint<WMsg>>> {
    let p = setup.p;
    // Accumulator-carrying rules (AdaGrad, Adaptive) ship their state
    // with the rotating block; fixed steps pay only for w.
    let ship_acc = rule.uses_acc();
    let taken: Vec<(WorkerSlot, crate::net::router::Endpoint<WMsg>)> =
        slots.drain(..).zip(endpoints).collect();
    // Raised by any worker that fails; peers poll it between bounded
    // ring waits, so one failure drains the whole epoch promptly
    // instead of deadlocking the barrier.
    let abort = AtomicBool::new(false);

    let results: Vec<Result<(WorkerSlot, crate::net::router::Endpoint<WMsg>), String>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = taken
                .into_iter()
                .map(|(mut slot, ep)| {
                    let abort = &abort;
                    scope.spawn(move || {
                        let _guard = AbortOnPanic(abort);
                        let q = slot.q;
                        let mut backoff = Backoff::new(1, 32);
                        // Out-of-core: fault in this epoch's first block
                        // before the sweep touches it.
                        setup.prefetch(q, slot.block_id);
                        for r in 0..p {
                            debug_assert_eq!(slot.block_id, setup.schedule.owned_block(q, r));
                            // Schedule-driven prefetch: while this block
                            // sweeps, the next one along the ring pages in.
                            if r + 1 < p {
                                setup.prefetch(q, setup.schedule.owned_block(q, r + 1));
                            }
                            // Injected stall: this worker is a straggler
                            // here. Outside the timed section — virtual
                            // compute stays that of the real kernel; the
                            // slowdown shows up in peers' wait stats.
                            if let Some(WorkerFault::Stall { millis }) =
                                setup.faults.worker_fault(q, epoch - 1, r)
                            {
                                std::thread::sleep(Duration::from_millis(millis));
                            }
                            let t0 = std::time::Instant::now();
                            let n = visit_block(setup, &mut slot, rule, epoch, r);
                            slot.updates += n as u64;
                            slot.clock.add_compute(t0.elapsed().as_secs_f64());

                            // Rotate the w block (with its AdaGrad state).
                            if let Some(MsgFault::Delay { millis }) =
                                setup.faults.message_fault(q, epoch - 1, r)
                            {
                                std::thread::sleep(Duration::from_millis(millis));
                            }
                            let w = std::mem::take(&mut slot.w);
                            let acc = std::mem::take(&mut slot.w_acc);
                            let bytes =
                                16 + 4 * w.len() + if ship_acc { 4 * acc.len() } else { 0 };
                            let dst = setup.schedule.send_to(q);
                            let msg = WMsg { block_id: slot.block_id, w, acc };
                            if ep.send(dst, msg, bytes).is_err() {
                                abort.store(true, Ordering::Relaxed);
                                return Err(format!(
                                    "worker {q}: ring peer {dst} hung up (epoch {epoch}, iter {r})"
                                ));
                            }
                            backoff.reset();
                            let d = loop {
                                if abort.load(Ordering::Relaxed) {
                                    return Err(format!(
                                        "worker {q}: epoch {epoch} aborted by a peer failure"
                                    ));
                                }
                                match ep.recv_timeout(backoff.next()) {
                                    Recv::Msg(d) => break d,
                                    Recv::Timeout => {}
                                    Recv::Disconnected => {
                                        abort.store(true, Ordering::Relaxed);
                                        return Err(format!(
                                            "worker {q}: ring channel disconnected (epoch {epoch})"
                                        ));
                                    }
                                }
                            };
                            slot.clock.add_comm(d.comm_secs);
                            slot.block_id = d.payload.block_id;
                            slot.w = d.payload.w;
                            slot.w_acc = d.payload.acc;
                        }
                        Ok((slot, ep))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err("worker thread panicked".into())))
                .collect()
        });

    let mut eps = Vec::with_capacity(p);
    let mut errors: Vec<String> = Vec::new();
    for res in results {
        match res {
            Ok((slot, ep)) => {
                slots.push(slot);
                eps.push(ep);
            }
            Err(e) => errors.push(e),
        }
    }
    anyhow::ensure!(errors.is_empty(), "dso epoch {epoch} failed: {}", errors.join("; "));
    slots.sort_by_key(|s| s.q);
    eps.sort_by_key(|e| e.id);
    Ok(eps)
}

/// One epoch executed on a single thread in the canonical serial order
/// (inner iteration r outer, worker rank q inner) — the order Lemma 2
/// serializes to. No network involved; comm costs are charged from the
/// cost model directly.
fn run_epoch_serial(
    setup: &DsoSetup,
    slots: &mut [WorkerSlot],
    rule: StepRule,
    epoch: usize,
) {
    let p = setup.p;
    let ship_acc = rule.uses_acc();
    for r in 0..p {
        for slot in slots.iter_mut() {
            debug_assert_eq!(slot.block_id, setup.schedule.owned_block(slot.q, r));
            // Schedule-driven prefetch, same order as the threaded loop.
            if r + 1 < p {
                setup.prefetch(slot.q, setup.schedule.owned_block(slot.q, r + 1));
            }
            let t0 = std::time::Instant::now();
            let n = visit_block(setup, slot, rule, epoch, r);
            slot.updates += n as u64;
            slot.clock.add_compute(t0.elapsed().as_secs_f64());
        }
        // Rotate all blocks one hop (dst = q-1 ring).
        let mut moved: Vec<(usize, usize, Vec<f32>, Vec<f32>)> = Vec::with_capacity(p);
        for slot in slots.iter_mut() {
            let dst = setup.schedule.send_to(slot.q);
            let w = std::mem::take(&mut slot.w);
            let acc = std::mem::take(&mut slot.w_acc);
            let bytes = 16 + 4 * w.len() + if ship_acc { 4 * acc.len() } else { 0 };
            let secs = setup.cost.transfer_secs(slot.q, dst, bytes);
            moved.push((dst, slot.block_id, w, acc));
            let _ = secs;
        }
        for (dst, block_id, w, acc) in moved {
            let src = setup.schedule.recv_from(dst);
            let bytes = 16 + 4 * w.len() + if ship_acc { 4 * acc.len() } else { 0 };
            let secs = setup.cost.transfer_secs(src, dst, bytes);
            let slot = &mut slots[dst];
            slot.block_id = block_id;
            slot.w = w;
            slot.w_acc = acc;
            slot.clock.add_comm(secs);
        }
    }
}

#[cfg(test)]
// The shim entry points stay under test on purpose: these suites pin
// them bit-for-bit against the facade (see tests/trainer_api.rs).
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, StepKind, TrainConfig};
    use crate::data::synth::SparseSpec;

    fn dataset(m: usize, d: usize, seed: u64) -> Dataset {
        SparseSpec {
            name: "engine-test".into(),
            m,
            d,
            nnz_per_row: 6.0,
            zipf_s: 0.7,
            label_noise: 0.03,
            pos_frac: 0.5,
            seed,
        }
        .generate()
    }

    fn base_cfg(p: usize, epochs: usize) -> TrainConfig {
        let mut cfg = TrainConfig::default();
        cfg.optim.algorithm = Algorithm::Dso;
        cfg.optim.epochs = epochs;
        cfg.optim.eta0 = 0.5;
        cfg.optim.step = StepKind::AdaGrad;
        cfg.model.lambda = 1e-3;
        cfg.cluster.machines = p;
        cfg.cluster.cores = 1;
        cfg.monitor.every = 0;
        cfg
    }

    #[test]
    fn single_worker_reduces_objective_and_gap() {
        let ds = dataset(300, 80, 5);
        let cfg = base_cfg(1, 30);
        let setup = DsoSetup::new(&cfg, &ds);
        let r = train_dso(&cfg, &ds, None).unwrap();
        let at_zero = setup.problem.primal(&ds, &vec![0.0; ds.d()]);
        assert!(r.final_primal < at_zero, "{} !< {at_zero}", r.final_primal);
        assert!(r.final_gap >= -1e-6);
        assert!(r.final_gap < at_zero, "gap {}", r.final_gap);
        assert!(r.total_updates > 0);
    }

    #[test]
    fn multi_worker_matches_serial_replay_bitwise() {
        // Lemma 2: the threaded run must be exactly serializable.
        let ds = dataset(200, 64, 9);
        for p in [2usize, 3, 4] {
            let cfg = base_cfg(p, 5);
            let threaded = train_dso(&cfg, &ds, None).unwrap();
            let replayed = run_replay(&cfg, &ds, None).unwrap();
            assert_eq!(threaded.w, replayed.w, "w differs at p={p}");
            assert_eq!(threaded.alpha, replayed.alpha, "alpha differs at p={p}");
            assert_eq!(threaded.total_updates, replayed.total_updates);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let ds = dataset(150, 40, 11);
        let cfg = base_cfg(4, 4);
        let a = train_dso(&cfg, &ds, None).unwrap();
        let b = train_dso(&cfg, &ds, None).unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.alpha, b.alpha);
    }

    #[test]
    fn invsqrt_schedule_also_converges() {
        let ds = dataset(250, 60, 13);
        let mut cfg = base_cfg(2, 40);
        cfg.optim.step = StepKind::InvSqrt;
        cfg.optim.eta0 = 1.0;
        let r = train_dso(&cfg, &ds, None).unwrap();
        let p = DsoSetup::new(&cfg, &ds).problem;
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        assert!(r.final_primal < at_zero);
    }

    #[test]
    fn gap_decreases_over_epochs() {
        let ds = dataset(300, 80, 17);
        let mut cfg = base_cfg(2, 40);
        cfg.monitor.every = 1;
        let r = train_dso(&cfg, &ds, None).unwrap();
        let gaps = r.history.col("gap").unwrap();
        assert!(gaps.len() >= 30);
        let early: f64 = gaps[..5].iter().sum::<f64>() / 5.0;
        let late: f64 = gaps[gaps.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(late < early * 0.8, "early {early} late {late}");
        // Gaps are nonnegative (weak duality) throughout.
        assert!(gaps.iter().all(|&g| g >= -1e-6));
    }

    #[test]
    fn comm_bytes_scale_with_p_and_epochs() {
        let ds = dataset(120, 100, 19);
        let mut cfg = base_cfg(4, 3);
        cfg.monitor.every = 0;
        let r = train_dso(&cfg, &ds, None).unwrap();
        // Per epoch: p inner iters × p workers... each worker sends its
        // block once per inner iteration: p*p messages of ~(d/p)*8 bytes.
        let approx = 3 * 4 * (2 * 4 * ds.d() / 4 + 16) * 4;
        assert!(r.comm_bytes > 0);
        assert!(
            (r.comm_bytes as f64) < 3.0 * approx as f64,
            "bytes {} vs approx {approx}",
            r.comm_bytes
        );
    }

    #[test]
    fn dcd_init_starts_closer() {
        // With a negligible step size the run's final point is ~the
        // initial point, so this isolates the warm start's quality.
        let ds = dataset(400, 60, 23);
        let mut cfg = base_cfg(2, 1);
        cfg.optim.eta0 = 1e-9;
        cfg.monitor.every = 1;
        let cold = train_dso(&cfg, &ds, None).unwrap();
        cfg.optim.dcd_init = true;
        let warm = train_dso(&cfg, &ds, None).unwrap();
        assert!(
            warm.final_primal < cold.final_primal,
            "warm {} !< cold {}",
            warm.final_primal,
            cold.final_primal
        );
        // Warm start also charges communication for the w averaging.
        assert!(warm.comm_bytes > cold.comm_bytes);
    }

    #[test]
    fn updates_per_block_subsamples() {
        let ds = dataset(200, 50, 29);
        let mut cfg = base_cfg(2, 2);
        cfg.cluster.updates_per_block = 5;
        let r = train_dso(&cfg, &ds, None).unwrap();
        // ≤ 5 updates × p inner iters × p workers × epochs.
        assert!(r.total_updates <= (5 * 2 * 2 * 2) as u64);
        assert!(r.total_updates > 0);
    }

    #[test]
    fn setup_records_resolved_simd_backend() {
        // The backend is resolved exactly once, in DsoSetup, and lives
        // in the plan's backend dimension; engines never re-detect.
        let ds = dataset(60, 40, 43);
        let mut cfg = base_cfg(2, 1);
        cfg.cluster.simd = crate::config::SimdKind::Portable;
        let setup = DsoSetup::new(&cfg, &ds);
        assert_eq!(setup.plan.simd(), crate::simd::SimdLevel::Portable);
        cfg.cluster.simd = crate::config::SimdKind::Auto;
        let setup = DsoSetup::new(&cfg, &ds);
        assert_eq!(setup.plan.simd(), crate::simd::resolve(crate::config::SimdKind::Auto));
    }

    #[test]
    fn forced_portable_backend_is_bit_identical_to_prior_kernels() {
        // `--simd portable` pins the run to the pre-backend (PR 3)
        // kernels; with auto resolving to portable (non-AVX2 host) the
        // trajectories must be bitwise equal, and on any host the
        // portable run must be deterministic and replay-identical.
        let ds = dataset(150, 48, 47);
        let mut cfg = base_cfg(2, 3);
        cfg.cluster.simd = crate::config::SimdKind::Portable;
        let a = train_dso(&cfg, &ds, None).unwrap();
        let b = run_replay(&cfg, &ds, None).unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.alpha, b.alpha);
        if crate::simd::resolve(crate::config::SimdKind::Auto)
            == crate::simd::SimdLevel::Portable
        {
            cfg.cluster.simd = crate::config::SimdKind::Auto;
            let c = train_dso(&cfg, &ds, None).unwrap();
            assert_eq!(a.w, c.w);
            assert_eq!(a.alpha, c.alpha);
        }
    }

    #[test]
    fn p_capped_by_dimensions() {
        let ds = dataset(20, 6, 31);
        let mut cfg = base_cfg(16, 2);
        cfg.cluster.machines = 16;
        let setup = DsoSetup::new(&cfg, &ds);
        assert!(setup.p <= 6);
        // Still runs.
        let r = train_dso(&cfg, &ds, None).unwrap();
        assert!(r.final_primal.is_finite());
    }

    #[test]
    fn logistic_loss_runs_and_converges() {
        let ds = dataset(250, 60, 37);
        let mut cfg = base_cfg(3, 30);
        cfg.model.loss = crate::config::LossKind::Logistic;
        let r = train_dso(&cfg, &ds, None).unwrap();
        let p = DsoSetup::new(&cfg, &ds).problem;
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        assert!(r.final_primal < at_zero);
        assert!(r.final_gap >= -1e-6);
    }

    #[test]
    fn sync_engine_rejects_death_and_drop_faults() {
        let ds = dataset(60, 30, 53);
        let mut cfg = base_cfg(2, 2);
        cfg.cluster.faults = "die@0.0.0".into();
        let err = train_dso(&cfg, &ds, None).unwrap_err().to_string();
        assert!(err.contains("dso-async"), "{err}");
        cfg.cluster.faults = "drop@0.0.0".into();
        assert!(train_dso(&cfg, &ds, None).is_err());
    }

    #[test]
    fn timing_faults_do_not_change_the_trajectory() {
        // Stalls and delays are timing-only: the faulted threaded run
        // stays bit-identical to the clean one (Lemma 2 serializability
        // is about ordering, which the ring still enforces).
        let ds = dataset(120, 40, 59);
        let mut cfg = base_cfg(3, 2);
        let clean = train_dso(&cfg, &ds, None).unwrap();
        cfg.cluster.faults = "stall@1.0.1:30,delay@2.1.0:10".into();
        let faulted = train_dso(&cfg, &ds, None).unwrap();
        assert_eq!(clean.w, faulted.w);
        assert_eq!(clean.alpha, faulted.alpha);
        assert_eq!(clean.total_updates, faulted.total_updates);
    }

    #[test]
    fn test_error_reported_when_test_given() {
        let ds = dataset(300, 50, 41);
        let (train, test) = ds.split(0.25, 7);
        let mut cfg = base_cfg(2, 10);
        cfg.monitor.every = 1;
        let r = train_dso(&cfg, &train, Some(&test)).unwrap();
        let errs = r.history.col("test_error").unwrap();
        assert!(errs.iter().all(|&e| (0.0..=1.0).contains(&e)));
        // Should learn something.
        assert!(*errs.last().unwrap() < 0.5);
    }
}
