//! Epoch-boundary checkpointing for the DSO engine.
//!
//! After any epoch the blocks are home (worker q holds w block q), so
//! the *entire* optimizer state is four dense vectors — w, its AdaGrad
//! accumulators, α, and its accumulators — plus the epoch counter and
//! the cumulative update count. The per-visit sampling streams are
//! keyed `(seed, epoch, q, r)` and carry no state across epochs
//! ([`super::plan::SweepPlan`]), so nothing else needs to survive a
//! crash: resuming from a checkpoint at epoch k reproduces the
//! uninterrupted run *bit-identically* (pinned by `tests/chaos.rs`).
//!
//! Persistence reuses the model-file contract ([`crate::api::Model`]):
//! plain text, one float per line in Rust's shortest-round-trip
//! `Display` form, so the save/load cycle is exact. Writes go through
//! [`crate::util::fsio::write_atomic_durable`] — a pid-suffixed temp
//! file in the same directory, fsynced before an atomic rename and a
//! parent-directory fsync after — so a crash (or power cut) mid-write
//! leaves either the previous checkpoint or the new one, never a torn
//! or empty-after-reboot file, and two concurrent runs pointed at the
//! same path cannot clobber each other's in-flight temp file.
//!
//! A checkpoint is only valid against the run that wrote it, so the
//! header carries a fingerprint of everything that shapes the update
//! sequence (loss, seed, partitions, data shape, SIMD backend, …);
//! [`Checkpoint::load`] hands it back and the engine refuses a
//! mismatch rather than silently continuing a different optimization.

use crate::config::TrainConfig;
use anyhow::Result;
use std::path::Path;

/// Full optimizer state at an epoch boundary. `w`/`w_acc` have length
/// d, `alpha`/`a_acc` length m; the engine re-splits them into worker
/// stripes on resume.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// [`fingerprint`] of the writing run's configuration.
    pub fingerprint: u64,
    /// 1-based epoch this state is the *end* of; resume starts at +1.
    pub epoch: usize,
    /// Cumulative update count through `epoch`.
    pub updates: u64,
    pub w: Vec<f32>,
    pub w_acc: Vec<f32>,
    pub alpha: Vec<f32>,
    pub a_acc: Vec<f32>,
}

const MAGIC: &str = "dso-checkpoint v1";

/// FNV-1a over a field's raw bytes, with a label byte-string mixed in
/// first so adjacent fields can't alias under concatenation.
fn mix(mut h: u64, label: &str, bytes: &[u8]) -> u64 {
    for &b in label.as_bytes().iter().chain(bytes) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Hash of everything that determines the update sequence: model and
/// optimizer hyperparameters, data shape, partition strategy, worker
/// count, the resolved SIMD backend (kernels differ bitwise across
/// backends), and the fault spec. Faults are part of run identity:
/// under the async engines a death permanently reroutes stripes and
/// tokens, and the multi-process transport replays recorded schedules
/// against this fingerprint — resuming or replaying a faulted run
/// under a different spec would silently diverge.
pub fn fingerprint(
    cfg: &TrainConfig,
    m: usize,
    d: usize,
    nnz: usize,
    p: usize,
    simd: crate::simd::SimdLevel,
) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = mix(h, "loss", cfg.model.loss.name().as_bytes());
    h = mix(h, "reg", cfg.model.reg.name().as_bytes());
    h = mix(h, "lambda", &cfg.model.lambda.to_bits().to_le_bytes());
    h = mix(h, "seed", &cfg.optim.seed.to_le_bytes());
    h = mix(h, "step", cfg.optim.step.name().as_bytes());
    h = mix(h, "eta0", &cfg.optim.eta0.to_bits().to_le_bytes());
    h = mix(h, "dcd_init", &[cfg.optim.dcd_init as u8]);
    h = mix(h, "partition", cfg.cluster.partition.name().as_bytes());
    h = mix(h, "upb", &(cfg.cluster.updates_per_block as u64).to_le_bytes());
    h = mix(h, "p", &(p as u64).to_le_bytes());
    h = mix(h, "m", &(m as u64).to_le_bytes());
    h = mix(h, "d", &(d as u64).to_le_bytes());
    h = mix(h, "nnz", &(nnz as u64).to_le_bytes());
    h = mix(h, "simd", simd.name().as_bytes());
    h = mix(h, "faults", cfg.cluster.faults.as_bytes());
    h
}

/// Extend a run fingerprint with warm-start lineage. A warm run
/// (`Trainer::fit_from`) optimizes a different trajectory than the
/// cold run of the identical configuration — its initial state is the
/// prior, not zeros — so their checkpoints must not be interchangeable.
/// Mixing the prior's [`warm_provenance`] hash under a dedicated label
/// separates warm from cold *and* warm runs off different priors.
pub fn with_provenance(fp: u64, provenance: u64) -> u64 {
    mix(fp, "warm", &provenance.to_le_bytes())
}

/// Provenance hash of a warm-start prior: FNV-1a over the exact bit
/// patterns of the seeding `(w, α)` (little-endian, labeled per
/// field). Bit patterns — not values — so `-0.0` and `0.0` priors,
/// which produce different downstream trajectories under the sweep
/// kernels' f32 arithmetic, fingerprint differently too.
pub fn warm_provenance(w: &[f32], alpha: &[f32]) -> u64 {
    let pack = |v: &[f32]| -> Vec<u8> {
        let mut b = Vec::with_capacity(4 * v.len());
        for x in v {
            b.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        b
    };
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = mix(h, "w", &pack(w));
    h = mix(h, "alpha", &pack(alpha));
    h
}

impl Checkpoint {
    /// Atomic, crash-durable save: write `<path>.<pid>.tmp` in the same
    /// directory, fsync it, rename over `path`, fsync the directory
    /// (see `util::fsio`).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut out = String::new();
        out.push_str(MAGIC);
        out.push('\n');
        out.push_str(&format!("fingerprint {:016x}\n", self.fingerprint));
        out.push_str(&format!("epoch {}\n", self.epoch));
        out.push_str(&format!("updates {}\n", self.updates));
        out.push_str(&format!("d {}\n", self.w.len()));
        out.push_str(&format!("m {}\n", self.alpha.len()));
        for (name, vec) in
            [("w", &self.w), ("w_acc", &self.w_acc), ("alpha", &self.alpha), ("a_acc", &self.a_acc)]
        {
            out.push_str(name);
            out.push('\n');
            for v in vec.iter() {
                // Shortest round-trip Display — parses back bit-exact.
                out.push_str(&format!("{v}\n"));
            }
        }
        crate::util::fsio::write_atomic_durable(path, out.as_bytes())
            .map_err(|e| anyhow::anyhow!("writing checkpoint {}: {e}", path.display()))?;
        Ok(())
    }

    /// Load a checkpoint written by [`Checkpoint::save`]. The caller
    /// (the engine's resume path) is responsible for comparing the
    /// returned fingerprint against its own run's.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {}: {e}", path.display()))?;
        let mut lines = text.lines();
        let magic = lines.next().unwrap_or_default();
        anyhow::ensure!(
            magic == MAGIC,
            "{}: not a dso checkpoint (bad magic '{magic}')",
            path.display()
        );
        let mut header = |key: &'static str| -> Result<String> {
            let line = lines
                .next()
                .ok_or_else(|| anyhow::anyhow!("checkpoint truncated before '{key}'"))?;
            let (k, v) = line
                .split_once(' ')
                .ok_or_else(|| anyhow::anyhow!("malformed checkpoint header '{line}'"))?;
            anyhow::ensure!(k == key, "expected checkpoint header '{key}', found '{k}'");
            Ok(v.to_string())
        };
        let fingerprint = u64::from_str_radix(&header("fingerprint")?, 16)
            .map_err(|_| anyhow::anyhow!("bad checkpoint fingerprint"))?;
        let epoch: usize =
            header("epoch")?.parse().map_err(|_| anyhow::anyhow!("bad checkpoint epoch"))?;
        let updates: u64 =
            header("updates")?.parse().map_err(|_| anyhow::anyhow!("bad checkpoint updates"))?;
        let d: usize = header("d")?.parse().map_err(|_| anyhow::anyhow!("bad checkpoint d"))?;
        let m: usize = header("m")?.parse().map_err(|_| anyhow::anyhow!("bad checkpoint m"))?;

        let mut section = |name: &'static str, len: usize| -> Result<Vec<f32>> {
            let marker = lines.next().unwrap_or_default();
            anyhow::ensure!(marker == name, "expected section '{name}', found '{marker}'");
            // The header is untrusted — cap the pre-allocation hint;
            // the exact-length check below still holds.
            let mut vec = Vec::with_capacity(len.min(1 << 22));
            for _ in 0..len {
                let line = lines
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("checkpoint section '{name}' truncated"))?;
                vec.push(
                    line.parse::<f32>()
                        .map_err(|_| anyhow::anyhow!("bad float '{line}' in '{name}'"))?,
                );
            }
            Ok(vec)
        };
        let w = section("w", d)?;
        let w_acc = section("w_acc", d)?;
        let alpha = section("alpha", m)?;
        let a_acc = section("a_acc", m)?;
        anyhow::ensure!(
            lines.all(|l| l.is_empty()),
            "trailing garbage after checkpoint sections"
        );
        Ok(Checkpoint { fingerprint, epoch, updates, w, w_acc, alpha, a_acc })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            fingerprint: 0xdead_beef_0123_4567,
            epoch: 7,
            updates: 4242,
            // Exercise the Display round trip on awkward values.
            w: vec![0.125, -3.5e-8, f32::MIN_POSITIVE, -0.0, 0.333_333_34],
            w_acc: vec![1.0, 2.0, 3.0, 4.0, 5.0],
            alpha: vec![-1.0, 1.0, 0.5],
            a_acc: vec![0.0, 9.75, 1e-30],
        }
    }

    #[test]
    fn round_trip_is_bit_exact() {
        let path = std::env::temp_dir().join("dso-ck-roundtrip.txt");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        // Bitwise, not just PartialEq (−0.0 == 0.0 under PartialEq).
        for (a, b) in ck.w.iter().zip(&back.w) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_leaves_no_temp_file() {
        let path = std::env::temp_dir().join("dso-ck-atomic.txt");
        sample().save(&path).unwrap();
        assert!(path.exists());
        // The temp name is pid-suffixed now — scan the directory for
        // any `dso-ck-atomic.txt*.tmp` leftover rather than probing
        // one fixed name.
        for entry in std::fs::read_dir(std::env::temp_dir()).unwrap() {
            let n = entry.unwrap().file_name().to_string_lossy().to_string();
            assert!(
                !(n.starts_with("dso-ck-atomic.txt") && n.ends_with(".tmp")),
                "leftover checkpoint temp file {n}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_bad_magic_and_truncation() {
        let path = std::env::temp_dir().join("dso-ck-bad.txt");
        std::fs::write(&path, "not a checkpoint\n").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // Truncated mid-section: declare 5 weights, carry 2.
        std::fs::write(
            &path,
            "dso-checkpoint v1\nfingerprint 00000000000000ff\nepoch 1\nupdates 2\nd 5\nm 1\nw\n0.5\n0.25\n",
        )
        .unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_trailing_garbage() {
        let path = std::env::temp_dir().join("dso-ck-trailing.txt");
        let mut ck = sample();
        ck.w = vec![1.0];
        ck.w_acc = vec![0.0];
        ck.alpha = vec![0.5];
        ck.a_acc = vec![0.0];
        ck.save(&path).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("9.0\n");
        std::fs::write(&path, text).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fingerprint_tracks_run_identity() {
        let cfg = TrainConfig::default();
        let a = fingerprint(&cfg, 100, 50, 600, 4, crate::simd::SimdLevel::Portable);
        let b = fingerprint(&cfg, 100, 50, 600, 4, crate::simd::SimdLevel::Portable);
        assert_eq!(a, b, "fingerprint must be deterministic");
        let mut seeded = cfg.clone();
        seeded.optim.seed ^= 1;
        assert_ne!(a, fingerprint(&seeded, 100, 50, 600, 4, crate::simd::SimdLevel::Portable));
        assert_ne!(a, fingerprint(&cfg, 101, 50, 600, 4, crate::simd::SimdLevel::Portable));
        assert_ne!(a, fingerprint(&cfg, 100, 50, 600, 2, crate::simd::SimdLevel::Portable));
    }

    /// The fault spec is part of run identity: a checkpoint written
    /// under injection must be refused by a fault-free resume (and
    /// vice versa), because async deaths permanently reroute state.
    #[test]
    fn fingerprint_tracks_fault_spec() {
        let clean = TrainConfig::default();
        let mut faulted = clean.clone();
        faulted.cluster.faults = "die@1.0.1".into();
        let a = fingerprint(&clean, 100, 50, 600, 4, crate::simd::SimdLevel::Portable);
        let b = fingerprint(&faulted, 100, 50, 600, 4, crate::simd::SimdLevel::Portable);
        assert_ne!(a, b, "fault spec must change the fingerprint");
        // Different specs are different runs too.
        let mut other = clean.clone();
        other.cluster.faults = "kill@1.0.1".into();
        assert_ne!(b, fingerprint(&other, 100, 50, 600, 4, crate::simd::SimdLevel::Portable));
    }
}
