//! Convergence monitoring shared by every solver (DSO and baselines).
//!
//! Each evaluation produces one row of the run history with the exact
//! quantities the paper plots: objective value (primal), dual value and
//! duality gap where the algorithm maintains duals, test error, and
//! both time axes ("number of iterations" and "time spent" — here
//! simulated cluster time plus measured wall time).
//!
//! Rows can additionally be *streamed* to an [`EpochObserver`] as they
//! are recorded (the `dso::api::Trainer` facade wires one through
//! every engine), so callers see convergence live instead of only via
//! the collected history table.

use crate::data::Dataset;
use crate::losses::Problem;
use crate::util::csv::Table;

/// Per-epoch callback: receives every [`EvalRow`] the moment the
/// monitor records it. Implemented for any `FnMut(&EvalRow)` closure.
pub trait EpochObserver {
    fn on_epoch(&mut self, row: &EvalRow);

    /// Called the moment a worker failure is recorded — the run is
    /// degrading (stripes reassigned to survivors), not aborting.
    /// Default: ignore, so `FnMut(&EvalRow)` closures stay observers.
    fn on_failure(&mut self, _failure: &WorkerFailure) {}
}

impl<F: FnMut(&EvalRow)> EpochObserver for F {
    fn on_epoch(&mut self, row: &EvalRow) {
        self(row)
    }
}

/// A worker that died mid-run (injected fault or genuine panic). The
/// fault-tolerant engines recover — the dead worker's w tokens and
/// α row stripe are adopted by survivors — and report the event here
/// instead of aborting.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkerFailure {
    pub worker: usize,
    /// Worker-local 0-based epoch at the failure (async: visits / p).
    pub epoch: usize,
    /// Inner iteration within that epoch (async: visits % p).
    pub iter: usize,
    /// The panic message (or injected-fault description).
    pub reason: String,
    /// Row stripes handed off to the surviving workers.
    pub stripes_reassigned: usize,
}

// New columns append at the end: downstream positional readers
// (`last_primal` = col 3, `last_gap` = col 5) and every existing CSV
// consumer keep their indices.
pub const HISTORY_COLUMNS: [&str; 11] = [
    "epoch",
    "virtual_s",
    "wall_s",
    "primal",
    "dual",
    "gap",
    "test_error",
    "updates",
    "comm_bytes",
    "failures",
    "wait_s",
];

/// Collects per-epoch evaluation rows, optionally streaming each row
/// to an [`EpochObserver`] as it is recorded.
pub struct Monitor<'a> {
    pub history: Table,
    /// Evaluate every `every` epochs (0 = only on demand).
    pub every: usize,
    observer: Option<&'a mut dyn EpochObserver>,
    /// Worker failures recorded so far (the `failures` column).
    failures: u64,
    /// Cumulative bounded-wait receive time (the `wait_s` column).
    wait_s: f64,
}

impl<'a> Monitor<'a> {
    pub fn new(every: usize) -> Monitor<'a> {
        Self::observed(every, None)
    }

    /// A monitor that also streams every recorded row to `observer`.
    pub fn observed(
        every: usize,
        observer: Option<&'a mut dyn EpochObserver>,
    ) -> Monitor<'a> {
        Monitor {
            history: Table::new(&HISTORY_COLUMNS),
            every,
            observer,
            failures: 0,
            wait_s: 0.0,
        }
    }

    /// Record a worker failure: counts toward the `failures` column of
    /// every subsequent row and streams to the observer immediately.
    pub fn record_failure(&mut self, failure: &WorkerFailure) {
        self.failures += 1;
        if let Some(obs) = self.observer.as_mut() {
            obs.on_failure(failure);
        }
    }

    /// Update the cumulative straggler wait time reported in the
    /// `wait_s` column (from `NetStats::total_wait_secs`).
    pub fn set_wait_secs(&mut self, wait_s: f64) {
        self.wait_s = wait_s;
    }

    pub fn due(&self, epoch: usize) -> bool {
        self.every > 0 && (epoch % self.every == 0 || epoch == 1)
    }

    /// Record a full saddle-point evaluation (algorithms with duals).
    #[allow(clippy::too_many_arguments)]
    pub fn record_saddle(
        &mut self,
        problem: &Problem,
        train: &Dataset,
        test: Option<&Dataset>,
        w: &[f32],
        alpha: &[f32],
        epoch: usize,
        virtual_s: f64,
        wall_s: f64,
        updates: u64,
        comm_bytes: u64,
    ) -> EvalRow {
        let primal = problem.primal(train, w);
        let dual = problem.dual(train, alpha);
        let test_error = test.map(|t| t.test_error(w)).unwrap_or(f64::NAN);
        let row = EvalRow {
            epoch,
            virtual_s,
            wall_s,
            primal,
            dual,
            gap: primal - dual,
            test_error,
            updates,
            comm_bytes,
            failures: self.failures,
            wait_s: self.wait_s,
        };
        self.push(row);
        row
    }

    /// Record a primal-only evaluation (SGD/PSGD have no duals).
    #[allow(clippy::too_many_arguments)]
    pub fn record_primal(
        &mut self,
        problem: &Problem,
        train: &Dataset,
        test: Option<&Dataset>,
        w: &[f32],
        epoch: usize,
        virtual_s: f64,
        wall_s: f64,
        updates: u64,
        comm_bytes: u64,
    ) -> EvalRow {
        let primal = problem.primal(train, w);
        let test_error = test.map(|t| t.test_error(w)).unwrap_or(f64::NAN);
        let row = EvalRow {
            epoch,
            virtual_s,
            wall_s,
            primal,
            dual: f64::NAN,
            gap: f64::NAN,
            test_error,
            updates,
            comm_bytes,
            failures: self.failures,
            wait_s: self.wait_s,
        };
        self.push(row);
        row
    }

    /// Record with an externally computed lower bound (BMRM's cutting
    /// plane model value stands in for the dual).
    #[allow(clippy::too_many_arguments)]
    pub fn record_with_bound(
        &mut self,
        problem: &Problem,
        train: &Dataset,
        test: Option<&Dataset>,
        w: &[f32],
        lower_bound: f64,
        epoch: usize,
        virtual_s: f64,
        wall_s: f64,
        updates: u64,
        comm_bytes: u64,
    ) -> EvalRow {
        let primal = problem.primal(train, w);
        let test_error = test.map(|t| t.test_error(w)).unwrap_or(f64::NAN);
        let row = EvalRow {
            epoch,
            virtual_s,
            wall_s,
            primal,
            dual: lower_bound,
            gap: primal - lower_bound,
            test_error,
            updates,
            comm_bytes,
            failures: self.failures,
            wait_s: self.wait_s,
        };
        self.push(row);
        row
    }

    fn push(&mut self, r: EvalRow) {
        self.history.push(vec![
            r.epoch as f64,
            r.virtual_s,
            r.wall_s,
            r.primal,
            r.dual,
            r.gap,
            r.test_error,
            r.updates as f64,
            r.comm_bytes as f64,
            r.failures as f64,
            r.wait_s,
        ]);
        if let Some(obs) = self.observer.as_mut() {
            obs.on_epoch(&r);
        }
    }

    pub fn last_primal(&self) -> Option<f64> {
        self.history.rows.last().map(|r| r[3])
    }

    pub fn last_gap(&self) -> Option<f64> {
        self.history.rows.last().map(|r| r[5])
    }
}

#[derive(Clone, Copy, Debug)]
pub struct EvalRow {
    pub epoch: usize,
    pub virtual_s: f64,
    pub wall_s: f64,
    pub primal: f64,
    pub dual: f64,
    pub gap: f64,
    pub test_error: f64,
    pub updates: u64,
    pub comm_bytes: u64,
    /// Worker failures recorded up to this row.
    pub failures: u64,
    /// Cumulative bounded-wait receive time (straggler staleness).
    pub wait_s: f64,
}

/// Final result of a training run (all solvers return this).
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub algorithm: String,
    pub w: Vec<f32>,
    /// Dual variables where maintained (empty otherwise).
    pub alpha: Vec<f32>,
    pub history: Table,
    pub final_primal: f64,
    pub final_gap: f64,
    pub total_updates: u64,
    pub total_virtual_s: f64,
    pub total_wall_s: f64,
    pub comm_bytes: u64,
    /// Worker failures the run recovered from (empty on a clean run).
    pub failures: Vec<WorkerFailure>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;
    use crate::losses::{Loss, Regularizer};

    fn setup() -> (Problem, Dataset) {
        let x = Csr::from_rows(2, vec![vec![(0, 1.0)], vec![(1, -1.0)]]);
        let ds = Dataset::new("t", x, vec![1.0, -1.0]);
        (Problem::new(Loss::Hinge, Regularizer::L2, 0.1), ds)
    }

    #[test]
    fn due_schedule() {
        let m = Monitor::new(5);
        assert!(m.due(1)); // always evaluate first epoch
        assert!(m.due(5));
        assert!(m.due(10));
        assert!(!m.due(3));
        let m0 = Monitor::new(0);
        assert!(!m0.due(1));
    }

    #[test]
    fn saddle_row_has_gap() {
        let (p, ds) = setup();
        let mut m = Monitor::new(1);
        let w = vec![0.5f32, -0.5];
        let alpha = vec![0.5f32, -0.5];
        let row = m.record_saddle(&p, &ds, Some(&ds), &w, &alpha, 1, 0.1, 0.2, 10, 100);
        assert!((row.gap - (row.primal - row.dual)).abs() < 1e-12);
        assert!(row.gap >= -1e-9); // weak duality
        assert_eq!(m.history.len(), 1);
        assert_eq!(m.last_primal().unwrap(), row.primal);
    }

    #[test]
    fn primal_row_has_nan_dual() {
        let (p, ds) = setup();
        let mut m = Monitor::new(1);
        let row = m.record_primal(&p, &ds, None, &[0.0, 0.0], 1, 0.0, 0.0, 0, 0);
        assert!(row.dual.is_nan());
        assert!(row.test_error.is_nan());
        assert!((row.primal - 1.0).abs() < 1e-12); // hinge at margin 0
    }

    #[test]
    fn bound_row_uses_bound() {
        let (p, ds) = setup();
        let mut m = Monitor::new(1);
        let row = m.record_with_bound(&p, &ds, None, &[0.0, 0.0], 0.4, 2, 0.0, 0.0, 0, 0);
        assert!((row.gap - (row.primal - 0.4)).abs() < 1e-12);
        assert_eq!(m.last_gap().unwrap(), row.gap);
    }

    #[test]
    fn history_columns_stable() {
        let m = Monitor::new(1);
        assert_eq!(m.history.columns.len(), HISTORY_COLUMNS.len());
        // Positional readers (`last_primal`, `last_gap`) and existing
        // CSV consumers rely on the original indices; the degradation
        // columns append strictly at the end.
        assert_eq!(m.history.columns[3], "primal");
        assert_eq!(m.history.columns[5], "gap");
        assert_eq!(m.history.columns[9], "failures");
        assert_eq!(m.history.columns[10], "wait_s");
    }

    #[test]
    fn failures_and_waits_flow_into_rows_and_observer() {
        let (p, ds) = setup();
        struct Obs {
            rows: usize,
            failures: Vec<(usize, String)>,
        }
        impl EpochObserver for Obs {
            fn on_epoch(&mut self, _row: &EvalRow) {
                self.rows += 1;
            }
            fn on_failure(&mut self, f: &WorkerFailure) {
                self.failures.push((f.worker, f.reason.clone()));
            }
        }
        let mut obs = Obs { rows: 0, failures: Vec::new() };
        let mut m = Monitor::observed(1, Some(&mut obs));
        let w = vec![0.5f32, -0.5];
        let alpha = vec![0.5f32, -0.5];
        let r1 = m.record_saddle(&p, &ds, None, &w, &alpha, 1, 0.0, 0.0, 1, 0);
        assert_eq!(r1.failures, 0);
        m.record_failure(&WorkerFailure {
            worker: 2,
            epoch: 1,
            iter: 0,
            reason: "injected".into(),
            stripes_reassigned: 1,
        });
        m.set_wait_secs(0.25);
        let r2 = m.record_saddle(&p, &ds, None, &w, &alpha, 2, 0.0, 0.0, 2, 0);
        assert_eq!(r2.failures, 1);
        assert_eq!(r2.wait_s, 0.25);
        assert_eq!(m.history.col("failures").unwrap(), &[0.0, 1.0]);
        assert_eq!(m.history.col("wait_s").unwrap(), &[0.0, 0.25]);
        drop(m);
        assert_eq!(obs.rows, 2);
        assert_eq!(obs.failures, vec![(2, "injected".into())]);
    }

    #[test]
    fn observer_streams_every_recorded_row() {
        let (p, ds) = setup();
        let mut seen: Vec<(usize, f64)> = Vec::new();
        let mut obs = |row: &EvalRow| seen.push((row.epoch, row.primal));
        let mut m = Monitor::observed(1, Some(&mut obs));
        let w = vec![0.5f32, -0.5];
        let alpha = vec![0.5f32, -0.5];
        let r1 = m.record_saddle(&p, &ds, None, &w, &alpha, 1, 0.0, 0.0, 1, 0);
        let r2 = m.record_primal(&p, &ds, None, &w, 2, 0.0, 0.0, 2, 0);
        let rows = m.history.len();
        drop(m); // release the observer's borrow of `seen`
        assert_eq!(rows, 2);
        assert_eq!(seen, vec![(1, r1.primal), (2, r2.primal)]);
    }
}
