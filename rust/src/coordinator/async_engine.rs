//! Asynchronous DSO — the paper's §6 extension ("a natural next step is
//! to derive an asynchronous algorithm along the lines of the NOMAD
//! algorithm of Yun et al."), which the authors later published as
//! NOMAD-style saddle-point optimization.
//!
//! Differences from the bulk-synchronous engine:
//! * No inner-iteration barrier. Each w block (with its AdaGrad state)
//!   circulates continuously: a worker pops whatever block is in its
//!   inbox, sweeps the corresponding Ω^(q, b) entries, and immediately
//!   forwards the block to a uniformly random *other* worker (NOMAD's
//!   routing rule), then pops the next block.
//! * Workers never wait for stragglers; a slow worker simply handles
//!   fewer blocks per unit time while blocks keep moving elsewhere.
//! * The serializability argument of Lemma 2 still applies: at any
//!   instant a block is owned by exactly one worker, and updates touch
//!   only (w_j, α_i) with j in that block and i in the worker's rows —
//!   so every interleaving is equivalent to *some* serial order. The
//!   trajectory is no longer deterministic (it depends on scheduling),
//!   but every invariant (feasibility, boxes, weak duality) holds.
//!
//! # Fault tolerance
//!
//! The ring degrades gracefully instead of aborting (DESIGN.md
//! §Fault-tolerance). Each block visit runs under `catch_unwind`; a
//! worker that panics — or is killed by an injected
//! [`WorkerFault::Die`] — executes the same death protocol:
//! its row stripes (α block + AdaGrad state) are pushed to a shared
//! orphan list that the next surviving worker to route a token adopts
//! (and from then on sweeps Ω^(stripe, b) for every adopted stripe on
//! every visit, so the dead worker's rows keep training), the token it
//! held is re-routed to a survivor, and the failure is reported as a
//! [`WorkerFailure`] through the `Monitor`/`EpochObserver` stream and
//! `TrainResult::failures`. The dead worker's receiver then lives on
//! as a "zombie drain": in-flight tokens addressed to it are forwarded
//! to survivors until the run stops, so no block is ever lost. If every
//! worker dies the run simply ends early with whatever progress was
//! made. Sends to a gone receiver hand the token back
//! ([`Endpoint::send`]) and the sender re-routes it; bounded-wait
//! receives with exponential [`Backoff`] keep survivors responsive to
//! the stop flag, and their cumulative wait feeds the history's
//! `wait_s` staleness column.
//!
//! Setup (partitions, packed blocks, stripe tables, cost model, kernel
//! plan, fault plan) comes from the shared [`DsoSetup`] — the same
//! constructor the sync and replay engines use, so
//! `cluster.partition = "balanced"` is honored here too. Kernel
//! dispatch executes the precompiled [`super::plan::SweepPlan`].
//! `cluster.updates_per_block` sampling is rejected with an actionable
//! error: its deterministic draw stream is defined by the synchronous
//! (epoch, worker, inner-iteration) schedule, which async does not
//! have — matching the existing AdaGrad-only guard. Fault-plan clocks
//! are worker-local here: worker q's visit v maps to
//! (epoch, iter) = (v / p, v mod p).
//!
//! Termination: the leader counts block-visits; an "epoch" is defined
//! as p² visits (the same work volume as one synchronous epoch), and
//! the run stops after the configured number of epochs, draining
//! in-flight blocks.

use super::engine::DsoSetup;
use super::monitor::{EpochObserver, Monitor, TrainResult, WorkerFailure};
use super::updates::{PackedState, StepRule};
use crate::config::{StepKind, TrainConfig};
use crate::data::Dataset;
use crate::net::router::Endpoint;
use crate::net::{lock_tolerant, Backoff, MsgFault, NetStats, Recv, Router, WorkerFault};
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// A circulating w block.
struct Token {
    block_id: usize,
    w: Vec<f32>,
    acc: Vec<f32>,
    /// Visits so far (for stats).
    hops: u64,
}

/// A row stripe — one worker's α block with its AdaGrad state. Stripes
/// outlive their worker: on death they move through
/// [`WorkerShared::orphans`] to a survivor.
struct Stripe {
    /// Home row-partition index (fixed; indexes `omega.row_part`,
    /// `y_local`, `inv_row` regardless of which worker holds it).
    q: usize,
    alpha: Vec<f32>,
    a_acc: Vec<f32>,
}

struct WorkerShared {
    visits: AtomicU64,
    stop: AtomicBool,
    /// Final blocks parked here as workers drain.
    parked: Mutex<Vec<Token>>,
    /// Liveness per worker; routing only targets live ones.
    alive: Vec<AtomicBool>,
    n_alive: AtomicUsize,
    /// Row stripes of dead workers, awaiting adoption by a survivor.
    orphans: Mutex<Vec<Stripe>>,
    /// Cheap flag so survivors don't take the orphans lock per visit.
    orphans_pending: AtomicBool,
    failures: Mutex<Vec<WorkerFailure>>,
}

/// Everything a worker thread borrows, bundled to keep the spawn site
/// readable.
struct AsyncCtx<'a> {
    setup: &'a DsoSetup,
    shared: &'a WorkerShared,
    updates_total: &'a AtomicU64,
    stats: &'a NetStats,
    rule: StepRule,
    p: usize,
    target_visits: u64,
}

/// Pick a live destination for a token: uniformly random among live
/// workers other than `q` when possible, `q` itself only as a last
/// resort (sole survivor), `None` when nobody is left.
fn pick_alive(rng: &mut Xoshiro256, shared: &WorkerShared, q: usize, p: usize) -> Option<usize> {
    if shared.n_alive.load(Ordering::Acquire) == 0 {
        return None;
    }
    // Rejection sampling keeps the common (all-alive) case uniform over
    // the p−1 others, NOMAD's routing rule.
    for _ in 0..4 * p {
        let c = rng.gen_index(p);
        if c != q && shared.alive[c].load(Ordering::Acquire) {
            return Some(c);
        }
    }
    // Mostly-dead ring: deterministic scan from a random start.
    let start = rng.gen_index(p);
    let mut type_self = None;
    for k in 0..p {
        let c = (start + k) % p;
        if shared.alive[c].load(Ordering::Acquire) {
            if c != q {
                return Some(c);
            }
            type_self = Some(c);
        }
    }
    type_self
}

/// Forward a token to some live worker. A send can fail if the chosen
/// receiver exited between the liveness check and the send — the
/// payload comes back and we retry elsewhere; with nobody reachable
/// the token parks (it is reassembled from `parked` at the end).
fn route_token(
    rng: &mut Xoshiro256,
    shared: &WorkerShared,
    ep: &Endpoint<Token>,
    q: usize,
    p: usize,
    mut token: Token,
) {
    let bytes = 16 + 8 * token.w.len();
    for _ in 0..2 * p + 2 {
        let Some(dst) = pick_alive(rng, shared, q, p) else { break };
        match ep.send(dst, token, bytes) {
            Ok(()) => return,
            Err(t) => token = t,
        }
    }
    lock_tolerant(&shared.parked).push(token);
}

/// One (stripe, block) sweep — the unit of work every DSO transport
/// executes. Factored out so the in-thread ring, the multi-process
/// worker ([`crate::net::supervisor`]), and the recorded-schedule
/// serial replayer all run the identical kernel path: Lemma-2 replay
/// bit-identity depends on there being exactly one sweep entry point.
#[allow(clippy::too_many_arguments)]
pub(crate) fn sweep_stripe_block(
    setup: &DsoSetup,
    rule: StepRule,
    q: usize,
    block_id: usize,
    w: &mut [f32],
    w_acc: &mut [f32],
    alpha: &mut [f32],
    a_acc: &mut [f32],
    scratch: &mut Vec<u32>,
) -> usize {
    let block = setup.omega.block(q, block_id);
    let ctx = setup.packed_ctx(q, block_id, rule);
    let mut st = PackedState { w, w_acc, alpha, a_acc };
    // Precompiled dispatch, same plan as the sync engine;
    // (epoch, r) = (0, 0) is inert for full-sweep kernels.
    setup.plan.sweep(block, q, block_id, 0, 0, &ctx, &mut st, scratch)
}

fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

/// The death protocol, shared by injected [`WorkerFault::Die`] and
/// genuine panics: hand off stripes, report the failure, keep the held
/// token moving, then drain in-flight arrivals until the run stops.
#[allow(clippy::too_many_arguments)]
fn die(
    cx: &AsyncCtx<'_>,
    ep: &Endpoint<Token>,
    rng: &mut Xoshiro256,
    q: usize,
    epoch: usize,
    iter: usize,
    reason: &str,
    stripes: Vec<Stripe>,
    token: Token,
) {
    let shared = cx.shared;
    shared.alive[q].store(false, Ordering::Release);
    let survivors = shared.n_alive.fetch_sub(1, Ordering::AcqRel) - 1;
    lock_tolerant(&shared.failures).push(WorkerFailure {
        worker: q,
        epoch,
        iter,
        reason: reason.to_string(),
        stripes_reassigned: stripes.len(),
    });
    lock_tolerant(&shared.orphans).extend(stripes);
    shared.orphans_pending.store(true, Ordering::Release);
    if survivors == 0 {
        // Nobody left to adopt or compute; end the run so the parked
        // blocks reassemble with whatever progress was made.
        shared.stop.store(true, Ordering::Release);
    }
    if shared.stop.load(Ordering::Acquire) {
        lock_tolerant(&shared.parked).push(token);
    } else {
        route_token(rng, shared, ep, q, cx.p, token);
    }
    // Zombie drain: the receiver stays alive so in-flight sends to this
    // worker are never lost; forward arrivals to survivors until stop,
    // then park stragglers. The endpoint is returned (not dropped) by
    // the caller, so even post-drain arrivals survive to the final
    // sweep in `train_dso_async_with`.
    let mut backoff = Backoff::new(1, 32);
    loop {
        match ep.recv_timeout(backoff.next()) {
            Recv::Msg(d) => {
                backoff.reset();
                if shared.stop.load(Ordering::Acquire) {
                    lock_tolerant(&shared.parked).push(d.payload);
                } else {
                    route_token(rng, shared, ep, q, cx.p, d.payload);
                }
            }
            Recv::Timeout => {
                if shared.stop.load(Ordering::Acquire) {
                    break;
                }
            }
            Recv::Disconnected => break,
        }
    }
}

/// One worker thread: pop a token, sweep it against every owned stripe,
/// route it onward. Returns the stripes it still owns and its endpoint
/// (kept alive so the main thread can drain un-received tokens).
fn worker_loop(
    cx: &AsyncCtx<'_>,
    ep: Endpoint<Token>,
    mut stripes: Vec<Stripe>,
    mut inbox: Option<Token>,
    mut rng: Xoshiro256,
) -> (Vec<Stripe>, Endpoint<Token>) {
    let q = ep.id;
    let p = cx.p;
    let setup = cx.setup;
    let shared = cx.shared;
    let mut scratch: Vec<u32> = Vec::new();
    let mut backoff = Backoff::new(1, 32);
    // Worker-local visit counter — the fault plan's async clock.
    let mut v: u64 = 0;
    loop {
        // Adopt row stripes orphaned by a dead worker: first live
        // worker through here takes them all and sweeps them on every
        // subsequent visit.
        if shared.orphans_pending.swap(false, Ordering::AcqRel) {
            let mut orphans = lock_tolerant(&shared.orphans);
            stripes.append(&mut orphans);
        }
        let mut token = match inbox.take() {
            Some(t) => t,
            None => match ep.recv_timeout(backoff.next()) {
                Recv::Msg(d) => {
                    backoff.reset();
                    d.payload
                }
                Recv::Timeout => {
                    if shared.stop.load(Ordering::Acquire) {
                        break;
                    }
                    continue;
                }
                // Unreachable in practice (every endpoint holds a
                // sender to itself), but exit cleanly if it happens.
                Recv::Disconnected => break,
            },
        };
        if shared.stop.load(Ordering::Acquire) {
            lock_tolerant(&shared.parked).push(token);
            continue; // keep draining the queue until it idles
        }
        // Out-of-core: the token names the block this visit sweeps —
        // page its payload in for every stripe before the kernel runs.
        // (Async has no ring schedule to look ahead along; the token's
        // own block is the best prediction available.)
        for s in stripes.iter() {
            setup.prefetch(s.q, token.block_id);
        }
        let (fe, fi) = ((v / p as u64) as usize, (v % p as u64) as usize);
        match setup.faults.worker_fault(q, fe, fi) {
            // Kill (real SIGKILL) and Partition (link fault) belong to
            // the multi-process transport and are rejected for this
            // engine by config validation; if a plan carrying them is
            // injected directly, degrade to the closest thread-ring
            // analogue rather than ignoring the event.
            Some(WorkerFault::Stall { millis }) | Some(WorkerFault::Partition { millis }) => {
                std::thread::sleep(Duration::from_millis(millis));
            }
            Some(WorkerFault::Die) | Some(WorkerFault::Kill) => {
                die(cx, &ep, &mut rng, q, fe, fi, "injected death", stripes, token);
                return (Vec::new(), ep);
            }
            None => {}
        }
        // The visit runs under catch_unwind so a kernel panic demotes
        // this worker to dead instead of aborting the run. A panic can
        // leave the mid-sweep token/stripe torn; recovery hands both
        // onward anyway — saddle-point SGD tolerates the perturbation.
        let swept = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut n = 0usize;
            for s in stripes.iter_mut() {
                n += sweep_stripe_block(
                    setup,
                    cx.rule,
                    s.q,
                    token.block_id,
                    &mut token.w,
                    &mut token.acc,
                    &mut s.alpha,
                    &mut s.a_acc,
                    &mut scratch,
                );
            }
            n
        }));
        let n = match swept {
            Ok(n) => n,
            Err(payload) => {
                let reason = panic_reason(payload.as_ref());
                die(cx, &ep, &mut rng, q, fe, fi, &reason, stripes, token);
                return (Vec::new(), ep);
            }
        };
        v += 1;
        cx.updates_total.fetch_add(n as u64, Ordering::Relaxed);
        token.hops += 1;
        let visits = shared.visits.fetch_add(1, Ordering::AcqRel) + 1;
        if visits >= cx.target_visits {
            shared.stop.store(true, Ordering::Release);
        }
        match setup.faults.message_fault(q, fe, fi) {
            Some(MsgFault::Delay { millis }) => {
                std::thread::sleep(Duration::from_millis(millis));
            }
            Some(MsgFault::Drop) => {
                // The first delivery attempt is lost in transit. The
                // simulated transport is reliable-with-acknowledgement,
                // so the sender notices, counts the drop, and the
                // re-route below carries the token instead.
                cx.stats.dropped_messages.fetch_add(1, Ordering::Relaxed);
            }
            None => {}
        }
        if shared.stop.load(Ordering::Acquire) {
            lock_tolerant(&shared.parked).push(token);
        } else {
            route_token(&mut rng, shared, &ep, q, p, token);
        }
    }
    (stripes, ep)
}

/// Train with asynchronous (NOMAD-style) DSO.
///
/// Deprecated shim: prefer
/// `dso::api::Trainer::new(cfg).algorithm(Algorithm::DsoAsync)`.
#[deprecated(since = "0.1.0", note = "use dso::api::Trainer::algorithm(Algorithm::DsoAsync)")]
pub fn train_dso_async(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
) -> Result<TrainResult> {
    train_dso_async_with(cfg, train, test, None)
}

/// [`train_dso_async`] with an optional per-epoch observer (async
/// evaluates once, at the end of the run; worker failures stream
/// through `EpochObserver::on_failure`).
pub fn train_dso_async_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    anyhow::ensure!(
        matches!(cfg.optim.step, StepKind::AdaGrad | StepKind::Adaptive),
        "async DSO supports the accumulator rules (adagrad, adaptive — \
         state travels with blocks); epoch-level η_t schedules need a \
         global clock, which async lacks"
    );
    anyhow::ensure!(
        cfg.cluster.updates_per_block == 0,
        "async DSO sweeps whole blocks: the deterministic updates_per_block \
         sampling stream is defined by the synchronous (epoch, worker, \
         inner-iteration) schedule, which async lacks; set \
         cluster.updates_per_block = 0 or use algorithm = \"dso\""
    );
    let setup = DsoSetup::with_cache(cfg, train)?;
    // The guard above keeps the plan sampling-free, so the workers'
    // (epoch, r) = (0, 0) sweep arguments below are inert.
    debug_assert!(!setup.plan.any_sampled());
    let p = setup.p;
    let loss = setup.problem.loss;
    let rule = match cfg.optim.step {
        StepKind::Adaptive => StepRule::Adaptive(cfg.optim.eta0),
        _ => StepRule::AdaGrad(cfg.optim.eta0),
    };

    // Initial state: worker q starts with its own row stripe and its
    // own w block already in its inbox (no channel round trip, so the
    // endpoints can move straight into the worker threads).
    let init_stripes: Vec<Stripe> = (0..p)
        .map(|q| Stripe {
            q,
            alpha: setup
                .omega
                .row_part
                .block(q)
                .map(|i| loss.alpha_init(train.y[i] as f64) as f32)
                .collect(),
            a_acc: vec![0f32; setup.omega.row_part.block_len(q)],
        })
        .collect();
    let init_tokens: Vec<Token> = (0..p)
        .map(|b| {
            let len = setup.omega.col_part.block(b).len();
            Token { block_id: b, w: vec![0f32; len], acc: vec![0f32; len], hops: 0 }
        })
        .collect();

    let mut router: Router<Token> = Router::new(p, setup.cost);
    let stats = router.stats();
    let endpoints = router.take_endpoints();
    let shared = WorkerShared {
        visits: AtomicU64::new(0),
        stop: AtomicBool::new(false),
        parked: Mutex::new(Vec::new()),
        alive: (0..p).map(|_| AtomicBool::new(true)).collect(),
        n_alive: AtomicUsize::new(p),
        orphans: Mutex::new(Vec::new()),
        orphans_pending: AtomicBool::new(false),
        failures: Mutex::new(Vec::new()),
    };
    let updates_total = AtomicU64::new(0);
    let cx = AsyncCtx {
        setup: &setup,
        shared: &shared,
        updates_total: &updates_total,
        stats: &stats,
        rule,
        p,
        target_visits: (cfg.optim.epochs as u64) * (p as u64) * (p as u64),
    };

    let wall = Stopwatch::new();
    let mut monitor = Monitor::observed(0, obs); // async: evaluate at the end only

    let mut stripe_pool: Vec<Stripe> = Vec::with_capacity(p);
    let mut back_eps: Vec<Endpoint<Token>> = Vec::with_capacity(p);
    let mut join_panics = 0usize;
    std::thread::scope(|scope| {
        let cx = &cx;
        let handles: Vec<_> = endpoints
            .into_iter()
            .zip(init_stripes)
            .zip(init_tokens)
            .map(|((ep, stripe), token)| {
                let rng = Xoshiro256::new(cfg.optim.seed ^ (0xA5A5 + ep.id as u64));
                scope.spawn(move || worker_loop(cx, ep, vec![stripe], Some(token), rng))
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok((stripes, ep)) => {
                    stripe_pool.extend(stripes);
                    back_eps.push(ep);
                }
                // A panic outside the catch_unwind guard (engine bug,
                // not a kernel fault) — its endpoint and stripes are
                // gone; the completeness checks below turn that into a
                // typed error instead of a process abort.
                Err(_) => join_panics += 1,
            }
        }
    });
    anyhow::ensure!(
        join_panics == 0,
        "{join_panics} async worker thread(s) panicked outside the recovery guard"
    );

    // Tokens still queued at exited receivers (racy last-moment sends)
    // were never lost because every endpoint outlived its worker; sweep
    // them into the parked pool now.
    let mut parked = shared.parked.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    for ep in &back_eps {
        while let Some(d) = ep.try_recv() {
            parked.push(d.payload);
        }
    }
    drop(back_eps);

    // Reassemble w from the parked blocks — every block exactly once,
    // deaths notwithstanding.
    let mut w = vec![0f32; train.d()];
    anyhow::ensure!(parked.len() == p, "lost blocks: {} of {p} recovered", parked.len());
    let mut seen = vec![false; p];
    for t in &parked {
        anyhow::ensure!(!seen[t.block_id], "duplicate block {}", t.block_id);
        seen[t.block_id] = true;
        w[setup.omega.col_part.block(t.block_id)].copy_from_slice(&t.w);
    }
    // And α from the stripes: survivors returned theirs (own +
    // adopted); stripes of workers that died with no survivor left to
    // adopt are still in the orphan list.
    let orphans = shared.orphans.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    stripe_pool.extend(orphans);
    let mut alpha = vec![0f32; train.m()];
    anyhow::ensure!(
        stripe_pool.len() == p,
        "lost row stripes: {} of {p} recovered",
        stripe_pool.len()
    );
    let mut seen = vec![false; p];
    for s in &stripe_pool {
        anyhow::ensure!(!seen[s.q], "duplicate row stripe {}", s.q);
        seen[s.q] = true;
        alpha[setup.omega.row_part.block(s.q)].copy_from_slice(&s.alpha);
    }

    let failures = shared.failures.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    for f in &failures {
        monitor.record_failure(f);
    }
    monitor.set_wait_secs(stats.total_wait_secs());

    let updates = updates_total.load(Ordering::Relaxed);
    let comm_bytes = stats.total_bytes();
    // Async has no per-worker barrier; virtual time ≈ wall of the run
    // plus the modeled per-hop latency amortized across p workers.
    let hop_cost = setup.cost.transfer_secs(0, cfg.cluster.cores, 16 + 8 * (train.d() / p));
    let virtual_s = wall.elapsed_secs()
        + hop_cost * (shared.visits.load(Ordering::Relaxed) as f64) / p as f64;

    let final_primal = setup.problem.primal(train, &w);
    let final_gap = final_primal - setup.problem.dual(train, &alpha);
    monitor.record_saddle(
        &setup.problem,
        train,
        test,
        &w,
        &alpha,
        cfg.optim.epochs,
        virtual_s,
        wall.elapsed_secs(),
        updates,
        comm_bytes,
    );
    Ok(TrainResult {
        algorithm: "dso-async".into(),
        w,
        alpha,
        history: monitor.history,
        final_primal,
        final_gap,
        total_updates: updates,
        total_virtual_s: virtual_s,
        total_wall_s: wall.elapsed_secs(),
        comm_bytes,
        failures,
    })
}

#[cfg(test)]
// The shim entry points stay under test on purpose: these suites pin
// them bit-for-bit against the facade (see tests/trainer_api.rs).
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::data::synth::SparseSpec;
    use crate::losses::{Loss, Problem, Regularizer};

    fn dataset(seed: u64) -> Dataset {
        SparseSpec {
            name: "async-test".into(),
            m: 400,
            d: 100,
            nnz_per_row: 8.0,
            zipf_s: 0.7,
            label_noise: 0.03,
            pos_frac: 0.5,
            seed,
        }
        .generate()
    }

    fn cfg(p: usize, epochs: usize) -> TrainConfig {
        let mut c = TrainConfig::default();
        c.optim.epochs = epochs;
        c.optim.eta0 = 0.2;
        c.model.lambda = 1e-3;
        c.cluster.machines = p;
        c.cluster.cores = 1;
        c.monitor.every = 0;
        c
    }

    #[test]
    fn async_converges_near_optimum() {
        let ds = dataset(1);
        let r = train_dso_async(&cfg(4, 150), &ds, None).unwrap();
        let dcd = crate::optim::dcd::solve_hinge_l2(&ds, 1e-3, 800, 1e-10, 1);
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 1e-3);
        let p_star = p.primal(&ds, &dcd.w);
        let rel = (r.final_primal - p_star) / p_star.abs().max(1e-12);
        assert!(rel < 0.10, "async {} vs optimum {p_star} (rel {rel})", r.final_primal);
        assert!(r.final_gap >= -1e-5);
    }

    #[test]
    fn async_blocks_all_recovered() {
        let ds = dataset(2);
        for p in [1usize, 2, 5, 8] {
            let r = train_dso_async(&cfg(p, 3), &ds, None).unwrap();
            assert_eq!(r.w.len(), ds.d(), "p={p}");
            assert!(r.final_primal.is_finite(), "p={p}");
            assert!(r.total_updates > 0, "p={p}");
            assert!(r.failures.is_empty(), "p={p}");
        }
    }

    #[test]
    fn async_work_volume_matches_epoch_definition() {
        let ds = dataset(3);
        let r = train_dso_async(&cfg(4, 10), &ds, None).unwrap();
        // Epoch := p² block visits; each visit sweeps that block's nnz.
        // Expected total ≈ epochs × nnz (every block visited ~epochs
        // times in expectation). Loose band: visits are stochastic in
        // *which* block lands where, but total visits are exact, and
        // block sizes vary — allow a 40% band.
        let expect = (10 * ds.nnz()) as f64;
        let got = r.total_updates as f64;
        assert!(
            got > 0.6 * expect && got < 1.4 * expect,
            "updates {got} vs expected ~{expect}"
        );
    }

    #[test]
    fn async_feasibility_invariants() {
        let ds = dataset(4);
        let c = cfg(6, 20);
        let r = train_dso_async(&c, &ds, None).unwrap();
        let loss = Loss::Hinge;
        for (i, &a) in r.alpha.iter().enumerate() {
            let beta = ds.y[i] as f64 * a as f64;
            assert!((-1e-6..=1.0 + 1e-6).contains(&beta), "α_{i} infeasible: {beta}");
        }
        let b = loss.w_bound(1e-3) as f32 + 1e-3;
        assert!(r.w.iter().all(|&x| (-b..=b).contains(&x)));
        assert!(loss.dual_utility(0.5, 1.0).is_finite());
    }

    #[test]
    fn async_rejects_non_adagrad() {
        let ds = dataset(5);
        let mut c = cfg(2, 2);
        c.optim.step = StepKind::InvSqrt;
        assert!(train_dso_async(&c, &ds, None).is_err());
    }

    #[test]
    fn async_logistic_runs() {
        let ds = dataset(6);
        let mut c = cfg(4, 40);
        c.model.loss = crate::config::LossKind::Logistic;
        let r = train_dso_async(&c, &ds, None).unwrap();
        let p = Problem::new(Loss::Logistic, Regularizer::L2, 1e-3);
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        assert!(r.final_primal < at_zero);
        assert!(r.final_gap >= -1e-5);
    }

    #[test]
    fn async_rejects_updates_per_block_sampling() {
        // Actionable rejection, matching the AdaGrad-only guard: the
        // deterministic sampling stream needs the sync schedule.
        let ds = dataset(7);
        let mut c = cfg(2, 2);
        c.cluster.updates_per_block = 5;
        let err = train_dso_async(&c, &ds, None).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("updates_per_block"), "msg: {msg}");
        assert!(msg.contains("algorithm = \"dso\""), "msg: {msg}");
    }

    #[test]
    fn async_honors_balanced_partition() {
        // The old engine hardcoded Partition::even and silently ignored
        // `cluster.partition = "balanced"`. Now setup is shared with the
        // sync engine: on zipf-skewed data the balanced column stripes
        // differ from even ones, and the run must still recover every
        // block and produce a full-width w.
        let ds = dataset(8);
        let mut c = cfg(4, 3);
        c.cluster.partition = crate::config::PartitionKind::Balanced;
        let setup = DsoSetup::new(&c, &ds);
        let even = crate::partition::Partition::even(ds.d(), setup.p);
        assert_ne!(
            setup.omega.col_part.bounds, even.bounds,
            "balanced stripes should differ from even on skewed data"
        );
        let r = train_dso_async(&c, &ds, None).unwrap();
        assert_eq!(r.w.len(), ds.d());
        assert!(r.final_primal.is_finite());
        assert!(r.total_updates > 0);
    }

    #[test]
    fn async_survives_injected_worker_death() {
        // Kill worker 2 on its third visit at p = 4 (the acceptance
        // scenario): the run must complete, recover every block and
        // stripe, and report exactly one failure.
        let ds = dataset(9);
        let mut c = cfg(4, 10);
        c.cluster.faults = "die@2.0.2".into();
        let r = train_dso_async(&c, &ds, None).unwrap();
        assert_eq!(r.w.len(), ds.d());
        assert_eq!(r.alpha.len(), ds.m());
        assert_eq!(r.failures.len(), 1, "failures: {:?}", r.failures);
        let f = &r.failures[0];
        assert_eq!(f.worker, 2);
        assert_eq!(f.reason, "injected death");
        assert!(f.stripes_reassigned >= 1);
        // The failure lands in the history's failures column too.
        assert_eq!(r.history.col("failures").unwrap(), vec![1.0]);
        assert!(r.final_primal.is_finite());
    }

    #[test]
    fn async_survives_every_worker_dying() {
        // Total annihilation: the run ends early with whatever progress
        // exists, still recovering all state instead of hanging or
        // aborting.
        let ds = dataset(10);
        let mut c = cfg(3, 50);
        c.cluster.faults = "die@0.0.1,die@1.0.2,die@2.1.0".into();
        let r = train_dso_async(&c, &ds, None).unwrap();
        assert_eq!(r.failures.len(), 3);
        assert_eq!(r.w.len(), ds.d());
        assert_eq!(r.alpha.len(), ds.m());
        assert!(r.final_primal.is_finite());
    }

    #[test]
    fn async_drop_and_stall_faults_tolerated() {
        let ds = dataset(11);
        let mut c = cfg(4, 8);
        c.cluster.faults = "drop@0.0.0,drop@1.1.0,stall@2.0.0:15,delay@3.0.1:5".into();
        let r = train_dso_async(&c, &ds, None).unwrap();
        assert!(r.failures.is_empty(), "timing/message faults are not failures");
        assert_eq!(r.w.len(), ds.d());
        assert!(r.total_updates > 0);
    }
}
