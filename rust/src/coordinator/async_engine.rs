//! Asynchronous DSO — the paper's §6 extension ("a natural next step is
//! to derive an asynchronous algorithm along the lines of the NOMAD
//! algorithm of Yun et al."), which the authors later published as
//! NOMAD-style saddle-point optimization.
//!
//! Differences from the bulk-synchronous engine:
//! * No inner-iteration barrier. Each w block (with its AdaGrad state)
//!   circulates continuously: a worker pops whatever block is in its
//!   inbox, sweeps the corresponding Ω^(q, b) entries, and immediately
//!   forwards the block to a uniformly random *other* worker (NOMAD's
//!   routing rule), then pops the next block.
//! * Workers never wait for stragglers; a slow worker simply handles
//!   fewer blocks per unit time while blocks keep moving elsewhere.
//! * The serializability argument of Lemma 2 still applies: at any
//!   instant a block is owned by exactly one worker, and updates touch
//!   only (w_j, α_i) with j in that block and i in the worker's rows —
//!   so every interleaving is equivalent to *some* serial order. The
//!   trajectory is no longer deterministic (it depends on scheduling),
//!   but every invariant (feasibility, boxes, weak duality) holds.
//!
//! Setup (partitions, packed blocks, stripe tables, cost model, kernel
//! plan) comes from the shared [`DsoSetup`] — the same constructor the
//! sync and replay engines use, so `cluster.partition = "balanced"`
//! is honored here too (this engine used to rebuild its own setup with
//! hardcoded even partitions and silently ignore it). Kernel dispatch
//! executes the precompiled [`super::plan::SweepPlan`].
//! `cluster.updates_per_block` sampling is rejected with an actionable
//! error: its deterministic draw stream is defined by the synchronous
//! (epoch, worker, inner-iteration) schedule, which async does not
//! have — matching the existing AdaGrad-only guard.
//!
//! Termination: the leader counts block-visits; an "epoch" is defined
//! as p² visits (the same work volume as one synchronous epoch), and
//! the run stops after the configured number of epochs, draining
//! in-flight blocks.

use super::engine::DsoSetup;
use super::monitor::{EpochObserver, Monitor, TrainResult};
use super::updates::{PackedState, StepRule};
use crate::config::{StepKind, TrainConfig};
use crate::data::Dataset;
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Mutex;

/// A circulating w block.
struct Token {
    block_id: usize,
    w: Vec<f32>,
    acc: Vec<f32>,
    /// Visits so far (for stats).
    hops: u64,
}

struct WorkerShared {
    senders: Vec<Sender<Token>>,
    visits: AtomicU64,
    stop: AtomicBool,
    /// Final blocks parked here as workers drain.
    parked: Mutex<Vec<Token>>,
    bytes: AtomicU64,
}

/// Train with asynchronous (NOMAD-style) DSO.
///
/// Deprecated shim: prefer
/// `dso::api::Trainer::new(cfg).algorithm(Algorithm::DsoAsync)`.
#[deprecated(since = "0.1.0", note = "use dso::api::Trainer::algorithm(Algorithm::DsoAsync)")]
pub fn train_dso_async(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
) -> Result<TrainResult> {
    train_dso_async_with(cfg, train, test, None)
}

/// [`train_dso_async`] with an optional per-epoch observer (async
/// evaluates once, at the end of the run).
pub fn train_dso_async_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    anyhow::ensure!(
        cfg.optim.step == StepKind::AdaGrad,
        "async DSO supports AdaGrad (state travels with blocks); \
         epoch-level η_t schedules need a global clock, which async lacks"
    );
    anyhow::ensure!(
        cfg.cluster.updates_per_block == 0,
        "async DSO sweeps whole blocks: the deterministic updates_per_block \
         sampling stream is defined by the synchronous (epoch, worker, \
         inner-iteration) schedule, which async lacks; set \
         cluster.updates_per_block = 0 or use algorithm = \"dso\""
    );
    let setup = DsoSetup::new(cfg, train);
    // The guard above keeps the plan sampling-free, so the workers'
    // (epoch, r) = (0, 0) sweep arguments below are inert.
    debug_assert!(!setup.plan.any_sampled());
    let p = setup.p;
    let loss = setup.problem.loss;
    let rule = StepRule::AdaGrad(cfg.optim.eta0);

    // Initial state.
    let mut alpha_blocks: Vec<Vec<f32>> = (0..p)
        .map(|q| {
            setup
                .omega
                .row_part
                .block(q)
                .map(|i| loss.alpha_init(train.y[i] as f64) as f32)
                .collect()
        })
        .collect();
    let mut a_acc_blocks: Vec<Vec<f32>> =
        (0..p).map(|q| vec![0f32; setup.omega.row_part.block_len(q)]).collect();

    let target_visits = (cfg.optim.epochs as u64) * (p as u64) * (p as u64);
    let mut receivers: Vec<Receiver<Token>> = Vec::with_capacity(p);
    let mut senders: Vec<Sender<Token>> = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = std::sync::mpsc::channel();
        senders.push(tx);
        receivers.push(rx);
    }
    // Seed: block b starts at worker b.
    for b in 0..p {
        let range = setup.omega.col_part.block(b);
        senders[b]
            .send(Token {
                block_id: b,
                w: vec![0f32; range.len()],
                acc: vec![0f32; range.len()],
                hops: 0,
            })
            .unwrap();
    }
    let shared = WorkerShared {
        senders,
        visits: AtomicU64::new(0),
        stop: AtomicBool::new(false),
        parked: Mutex::new(Vec::new()),
        bytes: AtomicU64::new(0),
    };

    let wall = Stopwatch::new();
    let mut monitor = Monitor::observed(0, obs); // async: evaluate at the end only
    let updates_total = AtomicU64::new(0);

    std::thread::scope(|scope| {
        let shared = &shared;
        let updates_total = &updates_total;
        let setup = &setup;
        let mut handles = Vec::new();
        for (q, rx) in receivers.into_iter().enumerate() {
            let mut alpha = std::mem::take(&mut alpha_blocks[q]);
            let mut a_acc = std::mem::take(&mut a_acc_blocks[q]);
            let mut rng = Xoshiro256::new(cfg.optim.seed ^ (0xA5A5 + q as u64));
            handles.push(scope.spawn(move || {
                // Sample-index scratch for the plan's sweep signature;
                // never written (the sampled kernel is rejected above).
                let mut scratch: Vec<u32> = Vec::new();
                loop {
                    // Poll with timeout so we observe the stop flag.
                    let mut token = match rx.recv_timeout(std::time::Duration::from_millis(20)) {
                        Ok(t) => t,
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                            if shared.stop.load(Ordering::Acquire) {
                                break;
                            }
                            continue;
                        }
                        Err(_) => break,
                    };
                    if shared.stop.load(Ordering::Acquire) {
                        shared.parked.lock().unwrap().push(token);
                        continue; // keep draining the queue
                    }
                    let block = setup.omega.block(q, token.block_id);
                    let ctx = setup.packed_ctx(q, token.block_id, rule);
                    let mut st = PackedState {
                        w: &mut token.w,
                        w_acc: &mut token.acc,
                        alpha: &mut alpha,
                        a_acc: &mut a_acc,
                    };
                    // Precompiled dispatch, same plan as the bulk-
                    // synchronous engine; (epoch, r) = (0, 0) is inert
                    // for full-sweep kernels.
                    let n = setup
                        .plan
                        .sweep(block, q, token.block_id, 0, 0, &ctx, &mut st, &mut scratch);
                    updates_total.fetch_add(n as u64, Ordering::Relaxed);
                    token.hops += 1;
                    let visits = shared.visits.fetch_add(1, Ordering::AcqRel) + 1;
                    if visits >= target_visits {
                        shared.stop.store(true, Ordering::Release);
                    }
                    // NOMAD routing: uniformly random other worker.
                    let mut dst = rng.gen_index(p);
                    if p > 1 && dst == q {
                        dst = (dst + 1 + rng.gen_index(p - 1)) % p;
                    }
                    shared
                        .bytes
                        .fetch_add((16 + 8 * token.w.len()) as u64, Ordering::Relaxed);
                    if shared.stop.load(Ordering::Acquire) {
                        shared.parked.lock().unwrap().push(token);
                    } else {
                        // Receiver may have exited already — then park.
                        if let Err(e) = shared.senders[dst].send(token) {
                            shared.parked.lock().unwrap().push(e.0);
                        }
                    }
                }
                (q, alpha, a_acc)
            }));
        }
        for h in handles {
            let (q, alpha, a_acc) = h.join().expect("async worker panicked");
            alpha_blocks[q] = alpha;
            a_acc_blocks[q] = a_acc;
        }
    });

    // Reassemble.
    let mut w = vec![0f32; train.d()];
    let parked = shared.parked.into_inner().unwrap();
    anyhow::ensure!(parked.len() == p, "lost blocks: {} of {p} recovered", parked.len());
    let mut seen = vec![false; p];
    for t in &parked {
        anyhow::ensure!(!seen[t.block_id], "duplicate block {}", t.block_id);
        seen[t.block_id] = true;
        w[setup.omega.col_part.block(t.block_id)].copy_from_slice(&t.w);
    }
    let mut alpha = vec![0f32; train.m()];
    for q in 0..p {
        alpha[setup.omega.row_part.block(q)].copy_from_slice(&alpha_blocks[q]);
    }

    let updates = updates_total.load(Ordering::Relaxed);
    let comm_bytes = shared.bytes.load(Ordering::Relaxed);
    // Async has no per-worker barrier; virtual time ≈ wall of the run
    // plus the modeled per-hop latency amortized across p workers.
    let hop_cost = setup.cost.transfer_secs(0, cfg.cluster.cores, 16 + 8 * (train.d() / p));
    let virtual_s = wall.elapsed_secs()
        + hop_cost * (shared.visits.load(Ordering::Relaxed) as f64) / p as f64;

    let final_primal = setup.problem.primal(train, &w);
    let final_gap = final_primal - setup.problem.dual(train, &alpha);
    monitor.record_saddle(
        &setup.problem,
        train,
        test,
        &w,
        &alpha,
        cfg.optim.epochs,
        virtual_s,
        wall.elapsed_secs(),
        updates,
        comm_bytes,
    );
    Ok(TrainResult {
        algorithm: "dso-async".into(),
        w,
        alpha,
        history: monitor.history,
        final_primal,
        final_gap,
        total_updates: updates,
        total_virtual_s: virtual_s,
        total_wall_s: wall.elapsed_secs(),
        comm_bytes,
    })
}

#[cfg(test)]
// The shim entry points stay under test on purpose: these suites pin
// them bit-for-bit against the facade (see tests/trainer_api.rs).
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::data::synth::SparseSpec;
    use crate::losses::{Loss, Problem, Regularizer};

    fn dataset(seed: u64) -> Dataset {
        SparseSpec {
            name: "async-test".into(),
            m: 400,
            d: 100,
            nnz_per_row: 8.0,
            zipf_s: 0.7,
            label_noise: 0.03,
            pos_frac: 0.5,
            seed,
        }
        .generate()
    }

    fn cfg(p: usize, epochs: usize) -> TrainConfig {
        let mut c = TrainConfig::default();
        c.optim.epochs = epochs;
        c.optim.eta0 = 0.2;
        c.model.lambda = 1e-3;
        c.cluster.machines = p;
        c.cluster.cores = 1;
        c.monitor.every = 0;
        c
    }

    #[test]
    fn async_converges_near_optimum() {
        let ds = dataset(1);
        let r = train_dso_async(&cfg(4, 150), &ds, None).unwrap();
        let dcd = crate::optim::dcd::solve_hinge_l2(&ds, 1e-3, 800, 1e-10, 1);
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 1e-3);
        let p_star = p.primal(&ds, &dcd.w);
        let rel = (r.final_primal - p_star) / p_star.abs().max(1e-12);
        assert!(rel < 0.10, "async {} vs optimum {p_star} (rel {rel})", r.final_primal);
        assert!(r.final_gap >= -1e-5);
    }

    #[test]
    fn async_blocks_all_recovered() {
        let ds = dataset(2);
        for p in [1usize, 2, 5, 8] {
            let r = train_dso_async(&cfg(p, 3), &ds, None).unwrap();
            assert_eq!(r.w.len(), ds.d(), "p={p}");
            assert!(r.final_primal.is_finite(), "p={p}");
            assert!(r.total_updates > 0, "p={p}");
        }
    }

    #[test]
    fn async_work_volume_matches_epoch_definition() {
        let ds = dataset(3);
        let r = train_dso_async(&cfg(4, 10), &ds, None).unwrap();
        // Epoch := p² block visits; each visit sweeps that block's nnz.
        // Expected total ≈ epochs × nnz (every block visited ~epochs
        // times in expectation). Loose band: visits are stochastic in
        // *which* block lands where, but total visits are exact, and
        // block sizes vary — allow a 40% band.
        let expect = (10 * ds.nnz()) as f64;
        let got = r.total_updates as f64;
        assert!(
            got > 0.6 * expect && got < 1.4 * expect,
            "updates {got} vs expected ~{expect}"
        );
    }

    #[test]
    fn async_feasibility_invariants() {
        let ds = dataset(4);
        let c = cfg(6, 20);
        let r = train_dso_async(&c, &ds, None).unwrap();
        let loss = Loss::Hinge;
        for (i, &a) in r.alpha.iter().enumerate() {
            let beta = ds.y[i] as f64 * a as f64;
            assert!((-1e-6..=1.0 + 1e-6).contains(&beta), "α_{i} infeasible: {beta}");
        }
        let b = loss.w_bound(1e-3) as f32 + 1e-3;
        assert!(r.w.iter().all(|&x| (-b..=b).contains(&x)));
        assert!(loss.dual_utility(0.5, 1.0).is_finite());
    }

    #[test]
    fn async_rejects_non_adagrad() {
        let ds = dataset(5);
        let mut c = cfg(2, 2);
        c.optim.step = StepKind::InvSqrt;
        assert!(train_dso_async(&c, &ds, None).is_err());
    }

    #[test]
    fn async_logistic_runs() {
        let ds = dataset(6);
        let mut c = cfg(4, 40);
        c.model.loss = crate::config::LossKind::Logistic;
        let r = train_dso_async(&c, &ds, None).unwrap();
        let p = Problem::new(Loss::Logistic, Regularizer::L2, 1e-3);
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        assert!(r.final_primal < at_zero);
        assert!(r.final_gap >= -1e-5);
    }

    #[test]
    fn async_rejects_updates_per_block_sampling() {
        // Actionable rejection, matching the AdaGrad-only guard: the
        // deterministic sampling stream needs the sync schedule.
        let ds = dataset(7);
        let mut c = cfg(2, 2);
        c.cluster.updates_per_block = 5;
        let err = train_dso_async(&c, &ds, None).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("updates_per_block"), "msg: {msg}");
        assert!(msg.contains("algorithm = \"dso\""), "msg: {msg}");
    }

    #[test]
    fn async_honors_balanced_partition() {
        // The old engine hardcoded Partition::even and silently ignored
        // `cluster.partition = "balanced"`. Now setup is shared with the
        // sync engine: on zipf-skewed data the balanced column stripes
        // differ from even ones, and the run must still recover every
        // block and produce a full-width w.
        let ds = dataset(8);
        let mut c = cfg(4, 3);
        c.cluster.partition = crate::config::PartitionKind::Balanced;
        let setup = DsoSetup::new(&c, &ds);
        let even = crate::partition::Partition::even(ds.d(), setup.p);
        assert_ne!(
            setup.omega.col_part.bounds, even.bounds,
            "balanced stripes should differ from even on skewed data"
        );
        let r = train_dso_async(&c, &ds, None).unwrap();
        assert_eq!(r.w.len(), ds.d());
        assert!(r.final_primal.is_finite());
        assert!(r.total_updates > 0);
    }
}
