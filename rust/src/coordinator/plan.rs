//! `SweepPlan` — the per-run, per-block precompiled kernel dispatch
//! table.
//!
//! PRs 1–3 grew a `has_lanes()` / `affine_alpha()` / short-group /
//! sampled decision tree that was copy-pasted into both the
//! bulk-synchronous engine and the async worker loop, and was already
//! drifting between them. The tree is *static per run*: which kernel a
//! block takes depends only on the block's shape (`has_lanes`), the
//! loss (`affine_alpha`), and the sampling configuration
//! (`cluster.updates_per_block` vs the block's nnz) — none of which
//! change between inner iterations. So the plan compiles the whole
//! tree once, at setup time, into a `block → kernel` table; engines
//! just call [`SweepPlan::sweep`].
//!
//! Dispatch rules (pinned by the unit tests below, matching PR 3):
//!
//! * `0 < updates_per_block < nnz` → [`PlannedKernel::Sampled`]
//!   (scalar subsampled updates; the draw stream is the deterministic
//!   `(seed, epoch, q, r)` mix, identical bit for bit to the PR 1–3
//!   engines, so Lemma-2 replay identity is preserved).
//! * otherwise, blocks with a lane-eligible row group take the SIMD
//!   kernels: losses with an affine dual (square) the closed-form
//!   α kernel [`PlannedKernel::LanesAffine`], the rest the plain lane
//!   kernel [`PlannedKernel::Lanes`].
//! * blocks with no lane-eligible group stay on the scalar
//!   [`PlannedKernel::Packed`] kernel.
//!
//! Since PR 5 the table also carries a **backend dimension**
//! (DESIGN.md §SIMD-backend): the [`SimdLevel`] resolved once per run
//! by `simd::resolve` — a measured `--simd auto` winner, or the
//! `--simd` override — is recorded here, and [`SweepPlan::sweep`]
//! dispatches the lane kernels' portable-autovec, AVX2, or AVX-512
//! monomorphization accordingly. Engines stay free of both the kernel
//! decision tree *and* feature detection (`scripts/ci.sh` greps for
//! either leaking back); the scalar kernels (`Packed`/`Sampled`) are
//! backend-independent by construction.
//!
//! When the run resolved its backend by measurement
//! (`cluster.simd = "auto"`), the plan additionally records the
//! [`AutotuneReport`] — winner plus per-backend throughputs — so the
//! selection is observable (`BENCH_autotune.json`, the supervisor's
//! worker-config pinning) instead of vanishing into a resolved enum.
//! [`autotune_levels`] is the probe that produced it: it times the
//! real sweep entry points on a deterministic sample of the run's own
//! packed blocks (largest lane-eligible blocks first). It lives here —
//! not in the engines — because it needs the block-shape predicate and
//! the per-backend entry points that `ci.sh` bans from engine code.
//!
//! Adding a solver variant (SPDC, mini-batch SDCA, …) means adding a
//! kernel and one arm *here* — not a new branch tree per engine.

#[cfg(target_arch = "x86_64")]
use super::updates::{
    sweep_lanes_affine_avx2, sweep_lanes_affine_avx512, sweep_lanes_avx2, sweep_lanes_avx512,
};
use super::updates::{
    sweep_lanes, sweep_lanes_affine, sweep_packed, sweep_packed_sampled, PackedCtx,
    PackedState, StepRule,
};
use crate::losses::{Loss, Regularizer};
use crate::partition::{PackedBlock, PackedBlocks};
use crate::simd::autotune::{self, AutotuneReport, Measurement};
use crate::simd::SimdLevel;
use crate::util::rng::Xoshiro256;
use std::time::Duration;

/// The kernel a block is planned to run. One entry per (q, b) block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannedKernel {
    /// Scalar packed sweep (no lane-eligible row group).
    Packed,
    /// SIMD lane sweep (8-wide w side, scalar α recurrence).
    Lanes,
    /// Closed-form affine-α lane sweep (square loss only).
    LanesAffine,
    /// Subsampled scalar updates: `k` flat entry draws per visit.
    Sampled {
        /// `cluster.updates_per_block`, guaranteed `0 < k < nnz`.
        k: usize,
    },
}

/// Per-run precompiled dispatch table: `(q, b) → kernel`.
///
/// Built once by `DsoSetup` from `(PackedBlocks, Loss, sampling
/// config)`; shared read-only by every worker thread.
pub struct SweepPlan {
    /// kernels[q * p + b] = kernel for block Ω^(q, b).
    kernels: Vec<PlannedKernel>,
    p: usize,
    /// `optim.seed` — the sampled path's RNG mix base.
    seed: u64,
    /// The SIMD backend the lane kernels run on — resolved once per
    /// run (the plan table's backend dimension).
    simd: SimdLevel,
    /// The measurement that picked `simd`, when the run resolved its
    /// backend via `--simd auto` (None for forced levels — they never
    /// measure).
    autotune: Option<AutotuneReport>,
}

impl SweepPlan {
    /// Compile the dispatch table. `updates_per_block` is the sampling
    /// configuration (0 = full sweeps, the paper default); `simd` is
    /// the backend resolved by `simd::resolve` — the **only** place a
    /// backend enters the engine stack.
    pub fn build(
        omega: &PackedBlocks,
        loss: Loss,
        updates_per_block: usize,
        seed: u64,
        simd: SimdLevel,
    ) -> SweepPlan {
        let p = omega.p;
        let mut kernels = Vec::with_capacity(p * p);
        for q in 0..p {
            for b in 0..p {
                kernels.push(plan_block(omega.block(q, b), loss, updates_per_block));
            }
        }
        SweepPlan { kernels, p, seed, simd, autotune: None }
    }

    /// Attach the autotune report that selected this plan's backend
    /// (`--simd auto` runs; forced levels pass `None`).
    pub fn with_autotune(mut self, report: Option<AutotuneReport>) -> SweepPlan {
        self.autotune = report;
        self
    }

    /// The SIMD backend every lane sweep of this run executes with.
    #[inline]
    pub fn simd(&self) -> SimdLevel {
        self.simd
    }

    /// The measured per-backend throughputs behind a `--simd auto`
    /// selection, if this run measured (None under a forced level).
    pub fn autotune(&self) -> Option<&AutotuneReport> {
        self.autotune.as_ref()
    }

    /// The kernel planned for block Ω^(q, b).
    #[inline]
    pub fn kernel(&self, q: usize, b: usize) -> PlannedKernel {
        self.kernels[q * self.p + b]
    }

    /// Whether any block is planned to run the subsampled kernel.
    pub fn any_sampled(&self) -> bool {
        self.kernels.iter().any(|k| matches!(k, PlannedKernel::Sampled { .. }))
    }

    /// Execute the planned kernel for block Ω^(q, b) once. `epoch`/`r`
    /// feed the deterministic sampling stream (ignored by full-sweep
    /// kernels); `scratch` is the caller's reusable sample-index buffer
    /// (no per-iteration allocation). Returns #updates.
    #[allow(clippy::too_many_arguments)]
    pub fn sweep(
        &self,
        block: &PackedBlock,
        q: usize,
        b: usize,
        epoch: usize,
        r: usize,
        ctx: &PackedCtx,
        st: &mut PackedState,
        scratch: &mut Vec<u32>,
    ) -> usize {
        match self.kernel(q, b) {
            PlannedKernel::Sampled { k } => {
                draw_indices(block.nnz(), k, self.seed, epoch, q, r, scratch);
                sweep_packed_sampled(block, scratch, ctx, st)
            }
            PlannedKernel::LanesAffine => match self.simd {
                SimdLevel::Portable => sweep_lanes_affine(block, ctx, st),
                #[cfg(target_arch = "x86_64")]
                SimdLevel::Avx2 => {
                    // SAFETY: the Avx2 level only enters a plan through
                    // `simd::resolve`, i.e. behind runtime avx2+fma
                    // detection — the entry point's feature contract
                    // holds for the whole run.
                    unsafe { sweep_lanes_affine_avx2(block, ctx, st) }
                }
                #[cfg(target_arch = "x86_64")]
                SimdLevel::Avx512 => {
                    // SAFETY: as for Avx2 — the Avx512 level only
                    // enters a plan behind runtime avx512f+avx2+fma
                    // detection (`simd::resolve`).
                    unsafe { sweep_lanes_affine_avx512(block, ctx, st) }
                }
                #[cfg(not(target_arch = "x86_64"))]
                // Unreachable by construction (`resolve` never returns
                // an x86 level off x86_64); degrade to portable rather
                // than panic in a release build.
                SimdLevel::Avx2 | SimdLevel::Avx512 => sweep_lanes_affine(block, ctx, st),
            },
            PlannedKernel::Lanes => match self.simd {
                SimdLevel::Portable => sweep_lanes(block, ctx, st),
                #[cfg(target_arch = "x86_64")]
                SimdLevel::Avx2 => {
                    // SAFETY: see the LanesAffine arm — Avx2 is only
                    // planned behind runtime detection.
                    unsafe { sweep_lanes_avx2(block, ctx, st) }
                }
                #[cfg(target_arch = "x86_64")]
                SimdLevel::Avx512 => {
                    // SAFETY: see the LanesAffine arm.
                    unsafe { sweep_lanes_avx512(block, ctx, st) }
                }
                #[cfg(not(target_arch = "x86_64"))]
                SimdLevel::Avx2 | SimdLevel::Avx512 => sweep_lanes(block, ctx, st),
            },
            PlannedKernel::Packed => sweep_packed(block, ctx, st),
        }
    }
}

/// The decision tree, in one place (formerly duplicated across
/// `engine.rs::visit_block` and the async worker loop).
fn plan_block(block: &PackedBlock, loss: Loss, updates_per_block: usize) -> PlannedKernel {
    if updates_per_block > 0 && updates_per_block < block.nnz() {
        PlannedKernel::Sampled { k: updates_per_block }
    } else if block.has_lanes() {
        if loss.affine_alpha() {
            PlannedKernel::LanesAffine
        } else {
            PlannedKernel::Lanes
        }
    } else {
        PlannedKernel::Packed
    }
}

/// How many blocks the real-block probe sweeps per rep, and its
/// per-backend timing budget. A couple of the largest lane blocks is
/// enough signal — the point is to measure the run's own gather
/// locality and chunk mix, not to survey the dataset.
const PROBE_BLOCKS: usize = 3;
const PROBE_BUDGET: Duration = Duration::from_millis(2);

/// Measure every candidate backend on a deterministic sample of the
/// run's **real packed blocks** — the probe `DsoSetup` injects into
/// [`crate::simd::autotune::auto_report_with`] when resolving
/// `--simd auto`. The sample is the (up to) [`PROBE_BLOCKS`] largest
/// lane-eligible blocks, ties broken by (q, b) — a pure function of the
/// partition, so the same run always times the same work (the wall
/// clock enters only through the measured durations, never the
/// sample or any fingerprint). Each rep sweeps the sampled blocks once
/// through the *production* entry points (the affine entry, which
/// degrades internally to the plain lane sweep for non-affine losses),
/// against zero-initialized scratch parameter state — the run's actual
/// iterates are never touched.
///
/// Returns one [`Measurement`] per level, or an empty vec when no
/// block is lane-eligible (nothing SIMD-dispatched to measure —
/// `report_from` then falls back to the widest supported level).
///
/// `y_local` / `alpha_bias` are the per-row-stripe label and α-bias
/// tables exactly as `DsoSetup` holds them.
#[allow(clippy::too_many_arguments)]
pub fn autotune_levels<Y, A>(
    omega: &PackedBlocks,
    y_local: &[Y],
    alpha_bias: &[A],
    loss: Loss,
    reg: Regularizer,
    lambda: f64,
    w_bound: f64,
    rule: StepRule,
    levels: &[SimdLevel],
) -> Vec<Measurement>
where
    Y: std::ops::Deref<Target = [f64]>,
    A: std::ops::Deref<Target = [f32]>,
{
    let p = omega.p;
    let mut picks: Vec<(usize, usize)> = (0..p)
        .flat_map(|q| (0..p).map(move |b| (q, b)))
        .filter(|&(q, b)| omega.block(q, b).has_lanes())
        .collect();
    if picks.is_empty() {
        return Vec::new();
    }
    picks.sort_by_key(|&(q, b)| (std::cmp::Reverse(omega.block(q, b).nnz()), q, b));
    picks.truncate(PROBE_BLOCKS);
    // Scratch parameter state per sampled block, zero-initialized and
    // reused across reps and levels (clamped by the kernels, so it
    // stays representable; only throughput leaves the probe).
    let mut states: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> = picks
        .iter()
        .map(|&(q, b)| {
            let nw = omega.inv_col[b].len().max(omega.block(q, b).n_cols as usize);
            let na = y_local[q].len().max(omega.block(q, b).n_rows as usize);
            (vec![0.0; nw], vec![0.0; nw], vec![0.0; na], vec![0.0; na])
        })
        .collect();
    autotune::measure(levels, PROBE_BUDGET, |level| {
        let mut units = 0usize;
        for (s, &(q, b)) in states.iter_mut().zip(&picks) {
            let block = omega.block(q, b);
            let ctx = PackedCtx {
                loss,
                reg,
                lambda,
                w_bound,
                rule,
                inv_col: &omega.inv_col[b],
                inv_col32: &omega.inv_col32[b],
                inv_row: &omega.inv_row[q],
                y: &y_local[q],
                alpha_bias32: &alpha_bias[q],
            };
            let mut st = PackedState {
                w: &mut s.0,
                w_acc: &mut s.1,
                alpha: &mut s.2,
                a_acc: &mut s.3,
            };
            units += match level {
                SimdLevel::Portable => sweep_lanes_affine(block, &ctx, &mut st),
                #[cfg(target_arch = "x86_64")]
                SimdLevel::Avx2 => {
                    // SAFETY: `levels` comes from
                    // `simd::supported_levels()` — Avx2 appears only
                    // behind runtime avx2+fma detection.
                    unsafe { sweep_lanes_affine_avx2(block, &ctx, &mut st) }
                }
                #[cfg(target_arch = "x86_64")]
                SimdLevel::Avx512 => {
                    // SAFETY: as above — Avx512 appears in `levels`
                    // only behind runtime avx512f+avx2+fma detection.
                    unsafe { sweep_lanes_affine_avx512(block, &ctx, &mut st) }
                }
                #[cfg(not(target_arch = "x86_64"))]
                SimdLevel::Avx2 | SimdLevel::Avx512 => {
                    unreachable!("supported_levels never yields {level:?} off x86_64")
                }
            };
        }
        units
    })
}

/// Draw the `k` flat entry indices a worker processes this inner
/// iteration into `out`. The RNG mix and call sequence match the
/// seed's COO sampling, and both the threaded and serial paths use the
/// same function — Lemma-2 bit-identity is preserved. Callers only
/// reach this with `0 < k < nnz` (the plan's `Sampled` precondition).
fn draw_indices(
    nnz: usize,
    k: usize,
    seed: u64,
    epoch: usize,
    q: usize,
    r: usize,
    out: &mut Vec<u32>,
) {
    debug_assert!(k > 0 && k < nnz);
    let mix = seed
        ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (q as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ (r as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
    let mut rng = Xoshiro256::new(mix);
    out.clear();
    out.extend((0..k).map(|_| rng.gen_index(nnz) as u32));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SparseSpec;
    use crate::partition::{Partition, LANES};

    /// A dataset whose Ω-blocks contain lane-eligible groups at p=2
    /// (long rows) — the shape the lane kernels target.
    fn long_row_blocks(p: usize) -> PackedBlocks {
        let ds = SparseSpec {
            name: "plan-long".into(),
            m: 60,
            d: 120,
            nnz_per_row: 40.0,
            zipf_s: 0.2,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 3,
        }
        .generate();
        let omega = PackedBlocks::build(
            &ds.x,
            &Partition::even(ds.m(), p),
            &Partition::even(ds.d(), p),
        );
        assert!(
            (0..p).any(|q| (0..p).any(|b| omega.block(q, b).has_lanes())),
            "fixture must contain lane-eligible blocks"
        );
        omega
    }

    /// A dataset whose Ω-blocks have only short groups at p=4 (few
    /// entries per row per column stripe).
    fn short_row_blocks(p: usize) -> PackedBlocks {
        let ds = SparseSpec {
            name: "plan-short".into(),
            m: 80,
            d: 64,
            nnz_per_row: 4.0,
            zipf_s: 0.7,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 5,
        }
        .generate();
        let omega = PackedBlocks::build(
            &ds.x,
            &Partition::even(ds.m(), p),
            &Partition::even(ds.d(), p),
        );
        assert!(
            (0..p).all(|q| (0..p).all(|b| !omega.block(q, b).has_lanes())),
            "fixture must have no lane-eligible block"
        );
        omega
    }

    #[test]
    fn lane_blocks_take_lane_kernels_per_loss() {
        // PR 3 rule: affine dual (square) → LanesAffine; hinge/logistic
        // → Lanes; never Packed on a lane-eligible block.
        let omega = long_row_blocks(2);
        for (loss, want) in [
            (Loss::Square, PlannedKernel::LanesAffine),
            (Loss::Hinge, PlannedKernel::Lanes),
            (Loss::Logistic, PlannedKernel::Lanes),
        ] {
            let plan = SweepPlan::build(&omega, loss, 0, 1, SimdLevel::Portable);
            for q in 0..2 {
                for b in 0..2 {
                    let k = plan.kernel(q, b);
                    if omega.block(q, b).has_lanes() {
                        assert_eq!(k, want, "loss {loss:?} block ({q},{b})");
                    } else {
                        assert_eq!(k, PlannedKernel::Packed, "loss {loss:?} block ({q},{b})");
                    }
                }
            }
            assert!(!plan.any_sampled());
        }
    }

    #[test]
    fn short_group_blocks_stay_scalar() {
        let omega = short_row_blocks(4);
        for loss in [Loss::Square, Loss::Hinge, Loss::Logistic] {
            let plan = SweepPlan::build(&omega, loss, 0, 1, SimdLevel::Portable);
            for q in 0..4 {
                for b in 0..4 {
                    assert_eq!(plan.kernel(q, b), PlannedKernel::Packed);
                }
            }
        }
    }

    #[test]
    fn sampling_overrides_lane_dispatch() {
        // 0 < k < nnz forces the scalar subsampled kernel even on
        // lane-eligible square-loss blocks (PR 2/3 rule: sampling draws
        // logical indices; the lane layout is bypassed).
        let omega = long_row_blocks(2);
        let plan = SweepPlan::build(&omega, Loss::Square, 5, 1, SimdLevel::Portable);
        for q in 0..2 {
            for b in 0..2 {
                let nnz = omega.block(q, b).nnz();
                let k = plan.kernel(q, b);
                if nnz > 5 {
                    assert_eq!(k, PlannedKernel::Sampled { k: 5 });
                } else {
                    assert_ne!(k, PlannedKernel::Sampled { k: 5 });
                }
            }
        }
        assert!(plan.any_sampled());
    }

    #[test]
    fn oversized_sample_count_falls_back_to_full_sweep() {
        // k >= nnz means a "sample" would cover the block: the engines
        // have always fallen back to the full sweep (and its lane
        // dispatch) in that case.
        let omega = long_row_blocks(2);
        let max_nnz = (0..2)
            .flat_map(|q| (0..2).map(move |b| (q, b)))
            .map(|(q, b)| omega.block(q, b).nnz())
            .max()
            .unwrap();
        let plan = SweepPlan::build(&omega, Loss::Hinge, max_nnz, 1, SimdLevel::Portable);
        for q in 0..2 {
            for b in 0..2 {
                let block = omega.block(q, b);
                let expect = if max_nnz < block.nnz() {
                    // unreachable by construction, but keep the rule explicit
                    PlannedKernel::Sampled { k: max_nnz }
                } else if block.has_lanes() {
                    PlannedKernel::Lanes
                } else {
                    PlannedKernel::Packed
                };
                assert_eq!(plan.kernel(q, b), expect);
            }
        }
        assert!(!plan.any_sampled());
    }

    #[test]
    fn plan_records_the_backend_dimension() {
        // The resolved SimdLevel is part of the plan — the one place
        // the run's backend lives. The kernel table itself is
        // backend-independent (same PlannedKernel per block either
        // way); only sweep()'s lane dispatch differs.
        let omega = long_row_blocks(2);
        for level in [SimdLevel::Portable, crate::simd::resolve(crate::config::SimdKind::Auto)]
        {
            let plan = SweepPlan::build(&omega, Loss::Hinge, 0, 1, level);
            assert_eq!(plan.simd(), level);
            for q in 0..2 {
                for b in 0..2 {
                    assert_eq!(
                        plan.kernel(q, b),
                        SweepPlan::build(&omega, Loss::Hinge, 0, 1, SimdLevel::Portable)
                            .kernel(q, b),
                        "kernel table must not depend on the backend"
                    );
                }
            }
        }
    }

    #[test]
    fn plan_records_the_autotune_report() {
        // A measured `auto` run attaches its report; forced levels
        // leave it None. The accessor is what the supervisor/bench
        // emission read.
        let omega = long_row_blocks(2);
        let plan = SweepPlan::build(&omega, Loss::Hinge, 0, 1, SimdLevel::Portable);
        assert!(plan.autotune().is_none(), "forced levels never measure");
        let report = autotune::report_from(
            &[SimdLevel::Portable],
            vec![Measurement { level: SimdLevel::Portable, units_per_sec: 1.0e9, reps: 3 }],
        );
        let plan = plan.with_autotune(Some(report));
        let got = plan.autotune().expect("report attached");
        assert_eq!(got.chosen, SimdLevel::Portable);
        assert_eq!(got.measured.len(), 1);
    }

    /// Per-stripe label / α-bias tables shaped like `DsoSetup`'s, for
    /// driving the probe without a full setup.
    fn probe_tables(omega: &PackedBlocks) -> (Vec<Vec<f64>>, Vec<Vec<f32>>) {
        let y: Vec<Vec<f64>> =
            omega.inv_row.iter().map(|r| vec![1.0f64; r.len()]).collect();
        let ab: Vec<Vec<f32>> =
            omega.inv_row.iter().map(|r| r.iter().map(|&hr| hr as f32).collect()).collect();
        (y, ab)
    }

    #[test]
    fn real_block_probe_measures_each_level_on_lane_blocks() {
        let omega = long_row_blocks(2);
        let (y, ab) = probe_tables(&omega);
        for loss in [Loss::Square, Loss::Hinge] {
            let ms = autotune_levels(
                &omega,
                &y,
                &ab,
                loss,
                Regularizer::L2,
                0.1,
                loss.w_bound(0.1),
                StepRule::AdaGrad(0.1),
                &[SimdLevel::Portable],
            );
            assert_eq!(ms.len(), 1, "{loss:?}: one measurement per candidate level");
            assert_eq!(ms[0].level, SimdLevel::Portable);
            assert!(ms[0].units_per_sec > 0.0, "{loss:?}: probe must process entries");
            assert!(ms[0].reps >= 3, "{loss:?}: at least MIN_REPS timed reps");
        }
    }

    #[test]
    fn real_block_probe_is_empty_without_lane_blocks() {
        // No lane-eligible work ⇒ nothing SIMD-dispatched to measure;
        // the autotune then falls back to the widest supported level
        // (flag order), pinned in simd::autotune.
        let omega = short_row_blocks(4);
        let (y, ab) = probe_tables(&omega);
        let ms = autotune_levels(
            &omega,
            &y,
            &ab,
            Loss::Hinge,
            Regularizer::L2,
            0.1,
            Loss::Hinge.w_bound(0.1),
            StepRule::Fixed(0.1),
            &[SimdLevel::Portable],
        );
        assert!(ms.is_empty());
    }

    #[test]
    fn lane_eligibility_matches_block_predicate() {
        // The plan's Lanes/Packed split must agree with the PR 2
        // predicate it precompiles, for both fixtures.
        for omega in [long_row_blocks(2), short_row_blocks(4)] {
            let p = omega.p;
            let plan = SweepPlan::build(&omega, Loss::Hinge, 0, 9, SimdLevel::Portable);
            for q in 0..p {
                for b in 0..p {
                    let lanes = omega.block(q, b).has_lanes();
                    assert_eq!(
                        plan.kernel(q, b) == PlannedKernel::Lanes,
                        lanes,
                        "({q},{b}) lane_groups disagree"
                    );
                }
            }
        }
        // And lane eligibility itself is the LANES threshold.
        assert_eq!(LANES, 8);
    }
}
