//! `SweepPlan` — the per-run, per-block precompiled kernel dispatch
//! table.
//!
//! PRs 1–3 grew a `has_lanes()` / `affine_alpha()` / short-group /
//! sampled decision tree that was copy-pasted into both the
//! bulk-synchronous engine and the async worker loop, and was already
//! drifting between them. The tree is *static per run*: which kernel a
//! block takes depends only on the block's shape (`has_lanes`), the
//! loss (`affine_alpha`), and the sampling configuration
//! (`cluster.updates_per_block` vs the block's nnz) — none of which
//! change between inner iterations. So the plan compiles the whole
//! tree once, at setup time, into a `block → kernel` table; engines
//! just call [`SweepPlan::sweep`].
//!
//! Dispatch rules (pinned by the unit tests below, matching PR 3):
//!
//! * `0 < updates_per_block < nnz` → [`PlannedKernel::Sampled`]
//!   (scalar subsampled updates; the draw stream is the deterministic
//!   `(seed, epoch, q, r)` mix, identical bit for bit to the PR 1–3
//!   engines, so Lemma-2 replay identity is preserved).
//! * otherwise, blocks with a lane-eligible row group take the SIMD
//!   kernels: losses with an affine dual (square) the closed-form
//!   α kernel [`PlannedKernel::LanesAffine`], the rest the plain lane
//!   kernel [`PlannedKernel::Lanes`].
//! * blocks with no lane-eligible group stay on the scalar
//!   [`PlannedKernel::Packed`] kernel.
//!
//! Since PR 5 the table also carries a **backend dimension**
//! (DESIGN.md §SIMD-backend): the [`SimdLevel`] resolved once per run
//! by `simd::resolve` — runtime CPU-feature detection, or the
//! `--simd` override — is recorded here, and [`SweepPlan::sweep`]
//! dispatches the lane kernels' portable-autovec or AVX2
//! monomorphization accordingly. Engines stay free of both the kernel
//! decision tree *and* feature detection (`scripts/ci.sh` greps for
//! either leaking back); the scalar kernels (`Packed`/`Sampled`) are
//! backend-independent by construction.
//!
//! Adding a solver variant (SPDC, mini-batch SDCA, …) means adding a
//! kernel and one arm *here* — not a new branch tree per engine.

#[cfg(target_arch = "x86_64")]
use super::updates::{sweep_lanes_affine_avx2, sweep_lanes_avx2};
use super::updates::{
    sweep_lanes, sweep_lanes_affine, sweep_packed, sweep_packed_sampled, PackedCtx,
    PackedState,
};
use crate::losses::Loss;
use crate::partition::{PackedBlock, PackedBlocks};
use crate::simd::SimdLevel;
use crate::util::rng::Xoshiro256;

/// The kernel a block is planned to run. One entry per (q, b) block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannedKernel {
    /// Scalar packed sweep (no lane-eligible row group).
    Packed,
    /// SIMD lane sweep (8-wide w side, scalar α recurrence).
    Lanes,
    /// Closed-form affine-α lane sweep (square loss only).
    LanesAffine,
    /// Subsampled scalar updates: `k` flat entry draws per visit.
    Sampled {
        /// `cluster.updates_per_block`, guaranteed `0 < k < nnz`.
        k: usize,
    },
}

/// Per-run precompiled dispatch table: `(q, b) → kernel`.
///
/// Built once by `DsoSetup` from `(PackedBlocks, Loss, sampling
/// config)`; shared read-only by every worker thread.
pub struct SweepPlan {
    /// kernels[q * p + b] = kernel for block Ω^(q, b).
    kernels: Vec<PlannedKernel>,
    p: usize,
    /// `optim.seed` — the sampled path's RNG mix base.
    seed: u64,
    /// The SIMD backend the lane kernels run on — resolved once per
    /// run (the plan table's backend dimension).
    simd: SimdLevel,
}

impl SweepPlan {
    /// Compile the dispatch table. `updates_per_block` is the sampling
    /// configuration (0 = full sweeps, the paper default); `simd` is
    /// the backend resolved by `simd::resolve` — the **only** place a
    /// backend enters the engine stack.
    pub fn build(
        omega: &PackedBlocks,
        loss: Loss,
        updates_per_block: usize,
        seed: u64,
        simd: SimdLevel,
    ) -> SweepPlan {
        let p = omega.p;
        let mut kernels = Vec::with_capacity(p * p);
        for q in 0..p {
            for b in 0..p {
                kernels.push(plan_block(omega.block(q, b), loss, updates_per_block));
            }
        }
        SweepPlan { kernels, p, seed, simd }
    }

    /// The SIMD backend every lane sweep of this run executes with.
    #[inline]
    pub fn simd(&self) -> SimdLevel {
        self.simd
    }

    /// The kernel planned for block Ω^(q, b).
    #[inline]
    pub fn kernel(&self, q: usize, b: usize) -> PlannedKernel {
        self.kernels[q * self.p + b]
    }

    /// Whether any block is planned to run the subsampled kernel.
    pub fn any_sampled(&self) -> bool {
        self.kernels.iter().any(|k| matches!(k, PlannedKernel::Sampled { .. }))
    }

    /// Execute the planned kernel for block Ω^(q, b) once. `epoch`/`r`
    /// feed the deterministic sampling stream (ignored by full-sweep
    /// kernels); `scratch` is the caller's reusable sample-index buffer
    /// (no per-iteration allocation). Returns #updates.
    #[allow(clippy::too_many_arguments)]
    pub fn sweep(
        &self,
        block: &PackedBlock,
        q: usize,
        b: usize,
        epoch: usize,
        r: usize,
        ctx: &PackedCtx,
        st: &mut PackedState,
        scratch: &mut Vec<u32>,
    ) -> usize {
        match self.kernel(q, b) {
            PlannedKernel::Sampled { k } => {
                draw_indices(block.nnz(), k, self.seed, epoch, q, r, scratch);
                sweep_packed_sampled(block, scratch, ctx, st)
            }
            PlannedKernel::LanesAffine => match self.simd {
                SimdLevel::Portable => sweep_lanes_affine(block, ctx, st),
                #[cfg(target_arch = "x86_64")]
                SimdLevel::Avx2 => {
                    // SAFETY: the Avx2 level only enters a plan through
                    // `simd::resolve`, i.e. behind runtime avx2+fma
                    // detection — the entry point's feature contract
                    // holds for the whole run.
                    unsafe { sweep_lanes_affine_avx2(block, ctx, st) }
                }
                #[cfg(not(target_arch = "x86_64"))]
                // Unreachable by construction (`resolve` never returns
                // Avx2 off x86_64); degrade to portable rather than
                // panic in a release build.
                SimdLevel::Avx2 => sweep_lanes_affine(block, ctx, st),
            },
            PlannedKernel::Lanes => match self.simd {
                SimdLevel::Portable => sweep_lanes(block, ctx, st),
                #[cfg(target_arch = "x86_64")]
                SimdLevel::Avx2 => {
                    // SAFETY: see the LanesAffine arm — Avx2 is only
                    // planned behind runtime detection.
                    unsafe { sweep_lanes_avx2(block, ctx, st) }
                }
                #[cfg(not(target_arch = "x86_64"))]
                SimdLevel::Avx2 => sweep_lanes(block, ctx, st),
            },
            PlannedKernel::Packed => sweep_packed(block, ctx, st),
        }
    }
}

/// The decision tree, in one place (formerly duplicated across
/// `engine.rs::visit_block` and the async worker loop).
fn plan_block(block: &PackedBlock, loss: Loss, updates_per_block: usize) -> PlannedKernel {
    if updates_per_block > 0 && updates_per_block < block.nnz() {
        PlannedKernel::Sampled { k: updates_per_block }
    } else if block.has_lanes() {
        if loss.affine_alpha() {
            PlannedKernel::LanesAffine
        } else {
            PlannedKernel::Lanes
        }
    } else {
        PlannedKernel::Packed
    }
}

/// Draw the `k` flat entry indices a worker processes this inner
/// iteration into `out`. The RNG mix and call sequence match the
/// seed's COO sampling, and both the threaded and serial paths use the
/// same function — Lemma-2 bit-identity is preserved. Callers only
/// reach this with `0 < k < nnz` (the plan's `Sampled` precondition).
fn draw_indices(
    nnz: usize,
    k: usize,
    seed: u64,
    epoch: usize,
    q: usize,
    r: usize,
    out: &mut Vec<u32>,
) {
    debug_assert!(k > 0 && k < nnz);
    let mix = seed
        ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (q as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ (r as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
    let mut rng = Xoshiro256::new(mix);
    out.clear();
    out.extend((0..k).map(|_| rng.gen_index(nnz) as u32));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SparseSpec;
    use crate::partition::{Partition, LANES};

    /// A dataset whose Ω-blocks contain lane-eligible groups at p=2
    /// (long rows) — the shape the lane kernels target.
    fn long_row_blocks(p: usize) -> PackedBlocks {
        let ds = SparseSpec {
            name: "plan-long".into(),
            m: 60,
            d: 120,
            nnz_per_row: 40.0,
            zipf_s: 0.2,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 3,
        }
        .generate();
        let omega = PackedBlocks::build(
            &ds.x,
            &Partition::even(ds.m(), p),
            &Partition::even(ds.d(), p),
        );
        assert!(
            (0..p).any(|q| (0..p).any(|b| omega.block(q, b).has_lanes())),
            "fixture must contain lane-eligible blocks"
        );
        omega
    }

    /// A dataset whose Ω-blocks have only short groups at p=4 (few
    /// entries per row per column stripe).
    fn short_row_blocks(p: usize) -> PackedBlocks {
        let ds = SparseSpec {
            name: "plan-short".into(),
            m: 80,
            d: 64,
            nnz_per_row: 4.0,
            zipf_s: 0.7,
            label_noise: 0.0,
            pos_frac: 0.5,
            seed: 5,
        }
        .generate();
        let omega = PackedBlocks::build(
            &ds.x,
            &Partition::even(ds.m(), p),
            &Partition::even(ds.d(), p),
        );
        assert!(
            (0..p).all(|q| (0..p).all(|b| !omega.block(q, b).has_lanes())),
            "fixture must have no lane-eligible block"
        );
        omega
    }

    #[test]
    fn lane_blocks_take_lane_kernels_per_loss() {
        // PR 3 rule: affine dual (square) → LanesAffine; hinge/logistic
        // → Lanes; never Packed on a lane-eligible block.
        let omega = long_row_blocks(2);
        for (loss, want) in [
            (Loss::Square, PlannedKernel::LanesAffine),
            (Loss::Hinge, PlannedKernel::Lanes),
            (Loss::Logistic, PlannedKernel::Lanes),
        ] {
            let plan = SweepPlan::build(&omega, loss, 0, 1, SimdLevel::Portable);
            for q in 0..2 {
                for b in 0..2 {
                    let k = plan.kernel(q, b);
                    if omega.block(q, b).has_lanes() {
                        assert_eq!(k, want, "loss {loss:?} block ({q},{b})");
                    } else {
                        assert_eq!(k, PlannedKernel::Packed, "loss {loss:?} block ({q},{b})");
                    }
                }
            }
            assert!(!plan.any_sampled());
        }
    }

    #[test]
    fn short_group_blocks_stay_scalar() {
        let omega = short_row_blocks(4);
        for loss in [Loss::Square, Loss::Hinge, Loss::Logistic] {
            let plan = SweepPlan::build(&omega, loss, 0, 1, SimdLevel::Portable);
            for q in 0..4 {
                for b in 0..4 {
                    assert_eq!(plan.kernel(q, b), PlannedKernel::Packed);
                }
            }
        }
    }

    #[test]
    fn sampling_overrides_lane_dispatch() {
        // 0 < k < nnz forces the scalar subsampled kernel even on
        // lane-eligible square-loss blocks (PR 2/3 rule: sampling draws
        // logical indices; the lane layout is bypassed).
        let omega = long_row_blocks(2);
        let plan = SweepPlan::build(&omega, Loss::Square, 5, 1, SimdLevel::Portable);
        for q in 0..2 {
            for b in 0..2 {
                let nnz = omega.block(q, b).nnz();
                let k = plan.kernel(q, b);
                if nnz > 5 {
                    assert_eq!(k, PlannedKernel::Sampled { k: 5 });
                } else {
                    assert_ne!(k, PlannedKernel::Sampled { k: 5 });
                }
            }
        }
        assert!(plan.any_sampled());
    }

    #[test]
    fn oversized_sample_count_falls_back_to_full_sweep() {
        // k >= nnz means a "sample" would cover the block: the engines
        // have always fallen back to the full sweep (and its lane
        // dispatch) in that case.
        let omega = long_row_blocks(2);
        let max_nnz = (0..2)
            .flat_map(|q| (0..2).map(move |b| (q, b)))
            .map(|(q, b)| omega.block(q, b).nnz())
            .max()
            .unwrap();
        let plan = SweepPlan::build(&omega, Loss::Hinge, max_nnz, 1, SimdLevel::Portable);
        for q in 0..2 {
            for b in 0..2 {
                let block = omega.block(q, b);
                let expect = if max_nnz < block.nnz() {
                    // unreachable by construction, but keep the rule explicit
                    PlannedKernel::Sampled { k: max_nnz }
                } else if block.has_lanes() {
                    PlannedKernel::Lanes
                } else {
                    PlannedKernel::Packed
                };
                assert_eq!(plan.kernel(q, b), expect);
            }
        }
        assert!(!plan.any_sampled());
    }

    #[test]
    fn plan_records_the_backend_dimension() {
        // The resolved SimdLevel is part of the plan — the one place
        // the run's backend lives. The kernel table itself is
        // backend-independent (same PlannedKernel per block either
        // way); only sweep()'s lane dispatch differs.
        let omega = long_row_blocks(2);
        for level in [SimdLevel::Portable, crate::simd::resolve(crate::config::SimdKind::Auto)]
        {
            let plan = SweepPlan::build(&omega, Loss::Hinge, 0, 1, level);
            assert_eq!(plan.simd(), level);
            for q in 0..2 {
                for b in 0..2 {
                    assert_eq!(
                        plan.kernel(q, b),
                        SweepPlan::build(&omega, Loss::Hinge, 0, 1, SimdLevel::Portable)
                            .kernel(q, b),
                        "kernel table must not depend on the backend"
                    );
                }
            }
        }
    }

    #[test]
    fn lane_eligibility_matches_block_predicate() {
        // The plan's Lanes/Packed split must agree with the PR 2
        // predicate it precompiles, for both fixtures.
        for omega in [long_row_blocks(2), short_row_blocks(4)] {
            let p = omega.p;
            let plan = SweepPlan::build(&omega, Loss::Hinge, 0, 9, SimdLevel::Portable);
            for q in 0..p {
                for b in 0..p {
                    let lanes = omega.block(q, b).has_lanes();
                    assert_eq!(
                        plan.kernel(q, b) == PlannedKernel::Lanes,
                        lanes,
                        "({q},{b}) lane_groups disagree"
                    );
                }
            }
        }
        // And lane eligibility itself is the LANES threshold.
        assert_eq!(LANES, 8);
    }
}
