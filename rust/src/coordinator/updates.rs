//! The scalar saddle-point update kernel — Eq. (8) plus AdaGrad and the
//! App. B projections. This is DSO's hot path for sparse data: every
//! worker calls [`sweep_block`] once per inner iteration on its active
//! block Ω^(q, σ_r(q)).
//!
//! Update for a sampled nonzero (i, j) with x = x_ij:
//!
//! ```text
//!   g_w = λ∇φ(w_j)/|Ω̄_j| − α_i·x/m          (descent direction in w_j)
//!   g_α = h'(α_i)/(m|Ω_i|) − w_j·x/m         (ascent direction in α_i)
//!   w_j ← Π_B [ w_j − η_w·g_w ]
//!   α_i ← Π_A [ α_i + η_α·g_α ]
//! ```
//!
//! Both gradients are evaluated at the *old* (w_j, α_i), matching the
//! simultaneous gradient step analyzed in Lemma 2 / Theorem 1. η is
//! either the epoch-level η_t = η₀/√t of Algorithm 1 or per-coordinate
//! AdaGrad (App. B); Π_B is the w box, Π_A the dual feasible set.

use crate::losses::{Loss, Regularizer};
use crate::optim::step::ADAGRAD_EPS;
use crate::partition::omega::Entry;

/// Which step rule the sweep applies.
#[derive(Clone, Copy, Debug)]
pub enum StepRule {
    /// Fixed η for this sweep (η_t of Algorithm 1).
    Fixed(f64),
    /// AdaGrad with η₀; accumulators supplied per sweep.
    AdaGrad(f64),
}

/// Immutable per-sweep context (problem constants and global count
/// tables shared read-only by every worker).
pub struct SweepCtx<'a> {
    pub loss: Loss,
    pub reg: Regularizer,
    pub lambda: f64,
    /// Number of training points m (as f64, used in every update).
    pub m: f64,
    /// |Ω_i| per global row.
    pub row_counts: &'a [u32],
    /// |Ω̄_j| per global column.
    pub col_counts: &'a [u32],
    /// Full label vector.
    pub y: &'a [f32],
    /// w box bound B (App. B): iterates clamped to [−B, B].
    pub w_bound: f64,
    pub rule: StepRule,
}

/// Mutable views of the worker's current parameter blocks. `w`/`w_acc`
/// are the travelling w-block (global coords `w_off ..`), `alpha` /
/// `a_acc` the worker-resident α block (global coords `a_off ..`).
pub struct BlockState<'a> {
    pub w: &'a mut [f32],
    pub w_acc: &'a mut [f32],
    pub w_off: usize,
    pub alpha: &'a mut [f32],
    pub a_acc: &'a mut [f32],
    pub a_off: usize,
}

/// Sweep every entry once, in storage order. Returns #updates.
pub fn sweep_block(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState) -> usize {
    match ctx.rule {
        StepRule::Fixed(eta) => sweep_fixed(entries, ctx, st, eta),
        StepRule::AdaGrad(eta0) => sweep_adagrad(entries, ctx, st, eta0),
    }
}

#[inline]
fn gradients(ctx: &SweepCtx, e: &Entry, wj: f64, ai: f64) -> (f64, f64) {
    let x = e.x as f64;
    let y = ctx.y[e.i as usize] as f64;
    let gw = ctx.lambda * ctx.reg.grad(wj) / ctx.col_counts[e.j as usize] as f64
        - ai * x / ctx.m;
    let ga = ctx.loss.dual_utility_grad(ai, y) / (ctx.m * ctx.row_counts[e.i as usize] as f64)
        - wj * x / ctx.m;
    (gw, ga)
}

fn sweep_fixed(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState, eta: f64) -> usize {
    let b = ctx.w_bound;
    // Same in-bounds-by-construction argument as `sweep_adagrad`.
    for e in entries {
        let jw = e.j as usize - st.w_off;
        let ia = e.i as usize - st.a_off;
        debug_assert!(jw < st.w.len() && ia < st.alpha.len());
        unsafe {
            let wj = *st.w.get_unchecked(jw) as f64;
            let ai = *st.alpha.get_unchecked(ia) as f64;
            let x = e.x as f64;
            let y = *ctx.y.get_unchecked(e.i as usize) as f64;
            let gw = ctx.lambda * ctx.reg.grad(wj)
                / *ctx.col_counts.get_unchecked(e.j as usize) as f64
                - ai * x / ctx.m;
            let ga = ctx.loss.dual_utility_grad(ai, y)
                / (ctx.m * *ctx.row_counts.get_unchecked(e.i as usize) as f64)
                - wj * x / ctx.m;
            *st.w.get_unchecked_mut(jw) = (wj - eta * gw).clamp(-b, b) as f32;
            *st.alpha.get_unchecked_mut(ia) = ctx.loss.project_alpha(ai + eta * ga, y) as f32;
        }
    }
    entries.len()
}

fn sweep_adagrad(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState, eta0: f64) -> usize {
    let b = ctx.w_bound;
    // Hot path (§Perf): entries come from `OmegaBlocks::build`, whose
    // indices are in-bounds by construction (validated by
    // `OmegaBlocks::validate` in tests); unchecked indexing removes 8
    // bounds checks per update.
    for e in entries {
        let jw = e.j as usize - st.w_off;
        let ia = e.i as usize - st.a_off;
        debug_assert!(jw < st.w.len() && ia < st.alpha.len());
        unsafe {
            let wj = *st.w.get_unchecked(jw) as f64;
            let ai = *st.alpha.get_unchecked(ia) as f64;
            let x = e.x as f64;
            let y = *ctx.y.get_unchecked(e.i as usize) as f64;
            let gw = ctx.lambda * ctx.reg.grad(wj)
                / *ctx.col_counts.get_unchecked(e.j as usize) as f64
                - ai * x / ctx.m;
            let ga = ctx.loss.dual_utility_grad(ai, y)
                / (ctx.m * *ctx.row_counts.get_unchecked(e.i as usize) as f64)
                - wj * x / ctx.m;

            let wa = *st.w_acc.get_unchecked(jw) as f64 + gw * gw;
            *st.w_acc.get_unchecked_mut(jw) = wa as f32;
            let eta_w = eta0 / (ADAGRAD_EPS + wa).sqrt();

            let aa = *st.a_acc.get_unchecked(ia) as f64 + ga * ga;
            *st.a_acc.get_unchecked_mut(ia) = aa as f32;
            let eta_a = eta0 / (ADAGRAD_EPS + aa).sqrt();

            *st.w.get_unchecked_mut(jw) = (wj - eta_w * gw).clamp(-b, b) as f32;
            *st.alpha.get_unchecked_mut(ia) =
                ctx.loss.project_alpha(ai + eta_a * ga, y) as f32;
        }
    }
    entries.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{Loss, Regularizer};

    fn ctx<'a>(
        row_counts: &'a [u32],
        col_counts: &'a [u32],
        y: &'a [f32],
        rule: StepRule,
    ) -> SweepCtx<'a> {
        SweepCtx {
            loss: Loss::Hinge,
            reg: Regularizer::L2,
            lambda: 0.1,
            m: y.len() as f64,
            row_counts,
            col_counts,
            y,
            w_bound: Loss::Hinge.w_bound(0.1),
            rule,
        }
    }

    #[test]
    fn single_update_matches_hand_computation() {
        let row_counts = [2u32, 1];
        let col_counts = [1u32, 2];
        let y = [1.0f32, -1.0];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(0.5));
        let entries = [Entry { i: 0, j: 1, x: 2.0 }];
        let mut w = [0.5f32];
        let mut wacc = [0f32];
        let mut alpha = [0.25f32];
        let mut aacc = [0f32];
        let mut st = BlockState {
            w: &mut w,
            w_acc: &mut wacc,
            w_off: 1,
            alpha: &mut alpha,
            a_acc: &mut aacc,
            a_off: 0,
        };
        let n = sweep_block(&entries, &c, &mut st);
        assert_eq!(n, 1);
        // m = 2, |Ω̄_1| = 2, |Ω_0| = 2.
        // g_w = 0.1 * 2*0.5 / 2 − 0.25*2/2 = 0.05 − 0.25 = −0.2
        // w   = 0.5 − 0.5*(−0.2) = 0.6
        assert!((w[0] - 0.6).abs() < 1e-6, "w {}", w[0]);
        // h'(α, y=1) = 1 (hinge). g_α = 1/(2·2) − 0.5·2/2 = 0.25 − 0.5 = −0.25
        // α = 0.25 + 0.5·(−0.25) = 0.125
        assert!((alpha[0] - 0.125).abs() < 1e-6, "α {}", alpha[0]);
    }

    #[test]
    fn projection_keeps_iterates_in_boxes() {
        let row_counts = [1u32];
        let col_counts = [1u32];
        let y = [1.0f32];
        // Huge step to force projection.
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(1e4));
        let entries = [Entry { i: 0, j: 0, x: 1.0 }];
        let mut w = [0f32];
        let mut wacc = [0f32];
        let mut alpha = [0f32];
        let mut aacc = [0f32];
        let mut st = BlockState {
            w: &mut w,
            w_acc: &mut wacc,
            w_off: 0,
            alpha: &mut alpha,
            a_acc: &mut aacc,
            a_off: 0,
        };
        for _ in 0..20 {
            sweep_block(&entries, &c, &mut st);
            let b = c.w_bound as f32;
            assert!((-b..=b).contains(&st.w[0]), "w {}", st.w[0]);
            let beta = y[0] * st.alpha[0];
            assert!((0.0..=1.0).contains(&beta), "β {beta}");
        }
    }

    #[test]
    fn adagrad_accumulators_grow_monotonically() {
        let row_counts = [1u32];
        let col_counts = [1u32];
        let y = [1.0f32];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.1));
        let entries = [Entry { i: 0, j: 0, x: 1.0 }];
        let mut w = [0.3f32];
        let mut wacc = [0f32];
        let mut alpha = [0.1f32];
        let mut aacc = [0f32];
        let mut prev_w = 0.0;
        let mut prev_a = 0.0;
        for _ in 0..10 {
            let mut st = BlockState {
                w: &mut w,
                w_acc: &mut wacc,
                w_off: 0,
                alpha: &mut alpha,
                a_acc: &mut aacc,
                a_off: 0,
            };
            sweep_block(&entries, &c, &mut st);
            assert!(wacc[0] >= prev_w);
            assert!(aacc[0] >= prev_a);
            prev_w = wacc[0];
            prev_a = aacc[0];
        }
        assert!(prev_w > 0.0);
        assert!(prev_a > 0.0);
    }

    #[test]
    fn disjoint_entries_commute() {
        // Updates on (i,j) and (i',j') with i≠i', j≠j' must commute
        // exactly — the key observation of Section 3.
        let row_counts = [1u32, 1];
        let col_counts = [1u32, 1];
        let y = [1.0f32, -1.0];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.2));
        let e0 = Entry { i: 0, j: 0, x: 1.5 };
        let e1 = Entry { i: 1, j: 1, x: -0.5 };
        let run = |order: [Entry; 2]| {
            let mut w = [0.1f32, -0.2];
            let mut wacc = [0f32; 2];
            let mut alpha = [0.05f32, -0.3];
            let mut aacc = [0f32; 2];
            let mut st = BlockState {
                w: &mut w,
                w_acc: &mut wacc,
                w_off: 0,
                alpha: &mut alpha,
                a_acc: &mut aacc,
                a_off: 0,
            };
            sweep_block(&order, &c, &mut st);
            (w, alpha, wacc, aacc)
        };
        let a = run([e0, e1]);
        let b = run([e1, e0]);
        assert_eq!(a, b);
    }

    #[test]
    fn fixed_step_deterministic() {
        let row_counts = [2u32, 2];
        let col_counts = [2u32, 2];
        let y = [1.0f32, -1.0];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(0.1));
        let entries = [
            Entry { i: 0, j: 0, x: 1.0 },
            Entry { i: 0, j: 1, x: 0.5 },
            Entry { i: 1, j: 0, x: -1.0 },
            Entry { i: 1, j: 1, x: 2.0 },
        ];
        let run = || {
            let mut w = [0f32; 2];
            let mut wacc = [0f32; 2];
            let mut alpha = [0f32; 2];
            let mut aacc = [0f32; 2];
            let mut st = BlockState {
                w: &mut w,
                w_acc: &mut wacc,
                w_off: 0,
                alpha: &mut alpha,
                a_acc: &mut aacc,
                a_off: 0,
            };
            for _ in 0..5 {
                sweep_block(&entries, &c, &mut st);
            }
            (w, alpha)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn square_loss_alpha_unconstrained() {
        let row_counts = [1u32];
        let col_counts = [1u32];
        let y = [3.0f32];
        let mut c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(1.0));
        c.loss = Loss::Square;
        let entries = [Entry { i: 0, j: 0, x: 1.0 }];
        let mut w = [0f32];
        let mut wacc = [0f32];
        let mut alpha = [0f32];
        let mut aacc = [0f32];
        let mut st = BlockState {
            w: &mut w,
            w_acc: &mut wacc,
            w_off: 0,
            alpha: &mut alpha,
            a_acc: &mut aacc,
            a_off: 0,
        };
        sweep_block(&entries, &c, &mut st);
        // g_α = (y − α)/m − wx/m = 3/1 − 0 = 3 → α = 3 (no clamp).
        assert!((alpha[0] - 3.0).abs() < 1e-6);
    }
}
