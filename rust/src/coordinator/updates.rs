//! The scalar saddle-point update kernel — Eq. (8) plus AdaGrad and the
//! App. B projections. This is DSO's hot path for sparse data: every
//! worker calls [`sweep_packed`] once per inner iteration on its active
//! block Ω^(q, σ_r(q)).
//!
//! Update for a sampled nonzero (i, j) with x = x_ij:
//!
//! ```text
//!   g_w = λ∇φ(w_j)/|Ω̄_j| − α_i·x/m          (descent direction in w_j)
//!   g_α = h'(α_i)/(m|Ω_i|) − w_j·x/m         (ascent direction in α_i)
//!   w_j ← Π_B [ w_j − η_w·g_w ]
//!   α_i ← Π_A [ α_i + η_α·g_α ]
//! ```
//!
//! Both gradients are evaluated at the *old* (w_j, α_i), matching the
//! simultaneous gradient step analyzed in Lemma 2 / Theorem 1. η is
//! either the epoch-level η_t = η₀/√t of Algorithm 1 or per-coordinate
//! AdaGrad (App. B); Π_B is the w box, Π_A the dual feasible set.
//!
//! ## Two implementations
//!
//! * [`sweep_packed`] — the production kernel over
//!   [`PackedBlock`](crate::partition::omega::PackedBlock) (§Perf). The
//!   `(Loss, Regularizer, StepRule)` triple is dispatched **once per
//!   sweep** into one of 12 monomorphized loops (`losses::kernel`), and
//!   the packed layout supplies block-local indices, `x/m` pre-folded
//!   into the stored value, and reciprocal tables for both Eq. (8)
//!   denominators — the inner loop performs zero divisions, zero offset
//!   subtractions, and zero enum dispatch. Row-invariant state (y_i,
//!   α_i and its AdaGrad accumulator, 1/(m|Ω_i|)) is loaded once per
//!   row group instead of once per nonzero; α stays in a register
//!   across the group (rounded through f32 after each update, exactly
//!   as the store/reload of the reference path rounds it).
//!   `sweep_packed_sampled` is the `updates_per_block` variant that
//!   processes an explicit list of flat entry indices.
//! * [`sweep_block`] — the seed's COO `Entry` kernel with per-update
//!   enum dispatch, global indices and live divisions. Kept as the
//!   *reference path*: property tests replay both on the same block
//!   and require agreement within 1e-5 relative error (the only
//!   permitted differences are reciprocal-multiply vs divide rounding
//!   and the f32 fold of x/m). `benches/bench_updates.rs` benchmarks
//!   the two side by side; `BENCH_updates.json` records the speedup.
//!
//! The packed sweep visits entries in the same (row, col) order as the
//! reference path, so Lemma-2 serializability — and the bit-identity
//! between the threaded engine and `run_replay`, which both call the
//! packed kernel — is unaffected.

use crate::losses::kernel::{HingeK, L1K, L2K, LogisticK, LossK, RegK, SquareK};
use crate::losses::{Loss, Regularizer};
use crate::optim::step::ADAGRAD_EPS;
use crate::partition::omega::{Entry, PackedBlock};

/// Which step rule the sweep applies.
#[derive(Clone, Copy, Debug)]
pub enum StepRule {
    /// Fixed η for this sweep (η_t of Algorithm 1).
    Fixed(f64),
    /// AdaGrad with η₀; accumulators supplied per sweep.
    AdaGrad(f64),
}

/// Immutable per-sweep context (problem constants and global count
/// tables shared read-only by every worker). Used by the COO
/// *reference* path.
pub struct SweepCtx<'a> {
    pub loss: Loss,
    pub reg: Regularizer,
    pub lambda: f64,
    /// Number of training points m (as f64, used in every update).
    pub m: f64,
    /// |Ω_i| per global row.
    pub row_counts: &'a [u32],
    /// |Ω̄_j| per global column.
    pub col_counts: &'a [u32],
    /// Full label vector.
    pub y: &'a [f32],
    /// w box bound B (App. B): iterates clamped to [−B, B].
    pub w_bound: f64,
    pub rule: StepRule,
}

/// Mutable views of the worker's current parameter blocks for the
/// reference path. `w`/`w_acc` are the travelling w-block (global
/// coords `w_off ..`), `alpha`/`a_acc` the worker-resident α block
/// (global coords `a_off ..`).
pub struct BlockState<'a> {
    pub w: &'a mut [f32],
    pub w_acc: &'a mut [f32],
    pub w_off: usize,
    pub alpha: &'a mut [f32],
    pub a_acc: &'a mut [f32],
    pub a_off: usize,
}

/// Immutable per-sweep context for the packed kernel. All tables are
/// stripe-local: `inv_col` belongs to the active column stripe (the
/// travelling w block), `inv_row`/`y` to the worker's row stripe.
pub struct PackedCtx<'a> {
    pub loss: Loss,
    pub reg: Regularizer,
    pub lambda: f64,
    pub w_bound: f64,
    pub rule: StepRule,
    /// 1/|Ω̄_j| per block-local column.
    pub inv_col: &'a [f64],
    /// 1/(m·|Ω_i|) per block-local row.
    pub inv_row: &'a [f64],
    /// Labels per block-local row.
    pub y: &'a [f64],
}

/// Mutable stripe-local parameter views for the packed kernel. No
/// offsets: packed blocks index these directly.
pub struct PackedState<'a> {
    pub w: &'a mut [f32],
    pub w_acc: &'a mut [f32],
    pub alpha: &'a mut [f32],
    pub a_acc: &'a mut [f32],
}

// ---------------------------------------------------------------------
// Packed kernel (production path)
// ---------------------------------------------------------------------

/// Step rule resolved at compile time. `eta` may update the AdaGrad
/// accumulator in place; the fixed rule ignores it.
trait StepK: Copy {
    fn eta(self, acc: &mut f32, g: f64) -> f64;
}

#[derive(Clone, Copy)]
struct FixedStep(f64);

impl StepK for FixedStep {
    #[inline(always)]
    fn eta(self, _acc: &mut f32, _g: f64) -> f64 {
        self.0
    }
}

#[derive(Clone, Copy)]
struct AdaGradStep(f64);

impl StepK for AdaGradStep {
    #[inline(always)]
    fn eta(self, acc: &mut f32, g: f64) -> f64 {
        // Accumulate in f64, store back f32 — same rounding as the
        // reference path and `optim::step::AdaGrad`.
        let a = *acc as f64 + g * g;
        *acc = a as f32;
        self.0 / (ADAGRAD_EPS + a).sqrt()
    }
}

/// Sweep every entry of a packed block once, in storage order.
/// Returns #updates.
pub fn sweep_packed(block: &PackedBlock, ctx: &PackedCtx, st: &mut PackedState) -> usize {
    match ctx.rule {
        StepRule::Fixed(eta) => dispatch_loss_reg(block, ctx, st, FixedStep(eta)),
        StepRule::AdaGrad(eta0) => dispatch_loss_reg(block, ctx, st, AdaGradStep(eta0)),
    }
}

/// Resolve (loss, reg) once per sweep into a monomorphized loop.
fn dispatch_loss_reg<S: StepK>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
    step: S,
) -> usize {
    match (ctx.loss, ctx.reg) {
        (Loss::Hinge, Regularizer::L2) => sweep_mono::<HingeK, L2K, S>(block, ctx, st, step),
        (Loss::Hinge, Regularizer::L1) => sweep_mono::<HingeK, L1K, S>(block, ctx, st, step),
        (Loss::Logistic, Regularizer::L2) => {
            sweep_mono::<LogisticK, L2K, S>(block, ctx, st, step)
        }
        (Loss::Logistic, Regularizer::L1) => {
            sweep_mono::<LogisticK, L1K, S>(block, ctx, st, step)
        }
        (Loss::Square, Regularizer::L2) => sweep_mono::<SquareK, L2K, S>(block, ctx, st, step),
        (Loss::Square, Regularizer::L1) => sweep_mono::<SquareK, L1K, S>(block, ctx, st, step),
    }
}

/// Validate, once per sweep, everything the unchecked inner loop
/// relies on: the stripe-local views cover the block's index spaces,
/// the row groups tile `0..nnz` with in-bounds rows, and every
/// block-local column is within the stripe. `PackedBlocks::build`
/// establishes these invariants, but `PackedBlock`'s fields are public
/// — re-checking here keeps `sweep_packed` sound for any safely
/// constructed block. Cost is O(groups) + one vectorizable u32 max
/// scan over `cols`, amortized over the ~20+ cycles each update costs.
#[inline]
fn check_packed_bounds(block: &PackedBlock, ctx: &PackedCtx, st: &PackedState) {
    assert!(block.n_cols as usize <= st.w.len());
    assert!(block.n_rows as usize <= st.alpha.len());
    assert!(st.w_acc.len() == st.w.len());
    assert!(st.a_acc.len() == st.alpha.len());
    assert!(block.n_cols as usize <= ctx.inv_col.len());
    assert!(block.n_rows as usize <= ctx.inv_row.len());
    assert!(block.n_rows as usize <= ctx.y.len());
    assert!(block.vals.len() == block.cols.len());
    let mut next = 0u32;
    for g in &block.groups {
        assert!(g.start == next && g.end >= g.start, "groups must tile 0..nnz");
        assert!(g.li < block.n_rows, "row group out of stripe");
        next = g.end;
    }
    assert!(next as usize == block.cols.len(), "groups must cover all entries");
    if let Some(&max_col) = block.cols.iter().max() {
        assert!(max_col < block.n_cols, "column out of stripe");
    }
}

fn sweep_mono<L: LossK, R: RegK, S: StepK>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
    step: S,
) -> usize {
    check_packed_bounds(block, ctx, st);
    let b = ctx.w_bound;
    let lambda = ctx.lambda;
    let cols = &block.cols[..];
    let vals = &block.vals[..];
    for g in &block.groups {
        let li = g.li as usize;
        debug_assert!(li < st.alpha.len());
        // Row-invariant state: loaded once per row group.
        let (y, hr, mut ai, mut aa) = unsafe {
            (
                *ctx.y.get_unchecked(li),
                *ctx.inv_row.get_unchecked(li),
                *st.alpha.get_unchecked(li) as f64,
                *st.a_acc.get_unchecked(li),
            )
        };
        for k in g.start as usize..g.end as usize {
            debug_assert!(k < cols.len());
            unsafe {
                let lj = *cols.get_unchecked(k) as usize;
                let xm = *vals.get_unchecked(k) as f64; // x/m, pre-folded
                debug_assert!(lj < st.w.len());
                let wj = *st.w.get_unchecked(lj) as f64;
                let gw = lambda * R::grad(wj) * *ctx.inv_col.get_unchecked(lj) - ai * xm;
                let ga = L::dual_grad(ai, y) * hr - wj * xm;
                let eta_w = step.eta(st.w_acc.get_unchecked_mut(lj), gw);
                let eta_a = step.eta(&mut aa, ga);
                *st.w.get_unchecked_mut(lj) = (wj - eta_w * gw).clamp(-b, b) as f32;
                // Round α through f32 like the reference path's
                // store/reload, so both paths see the same value when
                // a row has several entries.
                ai = L::project(ai + eta_a * ga, y) as f32 as f64;
            }
        }
        unsafe {
            *st.alpha.get_unchecked_mut(li) = ai as f32;
            *st.a_acc.get_unchecked_mut(li) = aa;
        }
    }
    block.vals.len()
}

/// Subsampled sweep (`cluster.updates_per_block`): process the given
/// flat entry indices, in order, one update each. Cold path — plain
/// enum dispatch and checked indexing; numerics are identical to
/// [`sweep_packed`] on the same entries.
pub fn sweep_packed_sampled(
    block: &PackedBlock,
    idxs: &[u32],
    ctx: &PackedCtx,
    st: &mut PackedState,
) -> usize {
    // No check_packed_bounds here: this path uses checked indexing
    // throughout (it is O(k), and the O(nnz) column scan of the full
    // sweep's validation would defeat the point of subsampling).
    let b = ctx.w_bound;
    for &k in idxs {
        let g = block.groups[block.group_of(k)];
        let li = g.li as usize;
        let lj = block.cols[k as usize] as usize;
        let xm = block.vals[k as usize] as f64;
        let y = ctx.y[li];
        let hr = ctx.inv_row[li];
        let wj = st.w[lj] as f64;
        let ai = st.alpha[li] as f64;
        let gw = ctx.lambda * ctx.reg.grad(wj) * ctx.inv_col[lj] - ai * xm;
        let ga = ctx.loss.dual_utility_grad(ai, y) * hr - wj * xm;
        let (eta_w, eta_a) = match ctx.rule {
            StepRule::Fixed(eta) => (eta, eta),
            StepRule::AdaGrad(eta0) => (
                AdaGradStep(eta0).eta(&mut st.w_acc[lj], gw),
                AdaGradStep(eta0).eta(&mut st.a_acc[li], ga),
            ),
        };
        st.w[lj] = (wj - eta_w * gw).clamp(-b, b) as f32;
        st.alpha[li] = ctx.loss.project_alpha(ai + eta_a * ga, y) as f32;
    }
    idxs.len()
}

// ---------------------------------------------------------------------
// COO reference path (correctness oracle + old-vs-new benchmark)
// ---------------------------------------------------------------------

/// Sweep every entry once, in storage order. Returns #updates.
/// Reference implementation over global-coordinate COO entries.
pub fn sweep_block(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState) -> usize {
    match ctx.rule {
        StepRule::Fixed(eta) => sweep_fixed(entries, ctx, st, eta),
        StepRule::AdaGrad(eta0) => sweep_adagrad(entries, ctx, st, eta0),
    }
}

/// The Eq. (8) gradient pair at the current iterate — the checked
/// scalar oracle the packed kernels are validated against.
#[inline]
pub fn gradients(ctx: &SweepCtx, e: &Entry, wj: f64, ai: f64) -> (f64, f64) {
    let x = e.x as f64;
    let y = ctx.y[e.i as usize] as f64;
    let gw = ctx.lambda * ctx.reg.grad(wj) / ctx.col_counts[e.j as usize] as f64
        - ai * x / ctx.m;
    let ga = ctx.loss.dual_utility_grad(ai, y) / (ctx.m * ctx.row_counts[e.i as usize] as f64)
        - wj * x / ctx.m;
    (gw, ga)
}

// The two loops below are kept verbatim from the seed (unchecked
// indexing, inline gradient expressions) so `bench_updates` compares
// the packed kernel against the genuine old hot path, not a slowed
// rewrite. `gradients()` above is the readable form of the same math.

fn sweep_fixed(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState, eta: f64) -> usize {
    let b = ctx.w_bound;
    // Same in-bounds-by-construction argument as `sweep_adagrad`.
    for e in entries {
        let jw = e.j as usize - st.w_off;
        let ia = e.i as usize - st.a_off;
        debug_assert!(jw < st.w.len() && ia < st.alpha.len());
        unsafe {
            let wj = *st.w.get_unchecked(jw) as f64;
            let ai = *st.alpha.get_unchecked(ia) as f64;
            let x = e.x as f64;
            let y = *ctx.y.get_unchecked(e.i as usize) as f64;
            let gw = ctx.lambda * ctx.reg.grad(wj)
                / *ctx.col_counts.get_unchecked(e.j as usize) as f64
                - ai * x / ctx.m;
            let ga = ctx.loss.dual_utility_grad(ai, y)
                / (ctx.m * *ctx.row_counts.get_unchecked(e.i as usize) as f64)
                - wj * x / ctx.m;
            *st.w.get_unchecked_mut(jw) = (wj - eta * gw).clamp(-b, b) as f32;
            *st.alpha.get_unchecked_mut(ia) = ctx.loss.project_alpha(ai + eta * ga, y) as f32;
        }
    }
    entries.len()
}

fn sweep_adagrad(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState, eta0: f64) -> usize {
    let b = ctx.w_bound;
    // Entries come from `PackedBlocks`-derived COO lists whose indices
    // are in-bounds by construction (validated by
    // `PackedBlocks::validate` in tests); unchecked indexing removes 8
    // bounds checks per update.
    for e in entries {
        let jw = e.j as usize - st.w_off;
        let ia = e.i as usize - st.a_off;
        debug_assert!(jw < st.w.len() && ia < st.alpha.len());
        unsafe {
            let wj = *st.w.get_unchecked(jw) as f64;
            let ai = *st.alpha.get_unchecked(ia) as f64;
            let x = e.x as f64;
            let y = *ctx.y.get_unchecked(e.i as usize) as f64;
            let gw = ctx.lambda * ctx.reg.grad(wj)
                / *ctx.col_counts.get_unchecked(e.j as usize) as f64
                - ai * x / ctx.m;
            let ga = ctx.loss.dual_utility_grad(ai, y)
                / (ctx.m * *ctx.row_counts.get_unchecked(e.i as usize) as f64)
                - wj * x / ctx.m;

            let wa = *st.w_acc.get_unchecked(jw) as f64 + gw * gw;
            *st.w_acc.get_unchecked_mut(jw) = wa as f32;
            let eta_w = eta0 / (ADAGRAD_EPS + wa).sqrt();

            let aa = *st.a_acc.get_unchecked(ia) as f64 + ga * ga;
            *st.a_acc.get_unchecked_mut(ia) = aa as f32;
            let eta_a = eta0 / (ADAGRAD_EPS + aa).sqrt();

            *st.w.get_unchecked_mut(jw) = (wj - eta_w * gw).clamp(-b, b) as f32;
            *st.alpha.get_unchecked_mut(ia) =
                ctx.loss.project_alpha(ai + eta_a * ga, y) as f32;
        }
    }
    entries.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{Loss, Regularizer};
    use crate::partition::omega::RowGroup;

    fn ctx<'a>(
        row_counts: &'a [u32],
        col_counts: &'a [u32],
        y: &'a [f32],
        rule: StepRule,
    ) -> SweepCtx<'a> {
        SweepCtx {
            loss: Loss::Hinge,
            reg: Regularizer::L2,
            lambda: 0.1,
            m: y.len() as f64,
            row_counts,
            col_counts,
            y,
            w_bound: Loss::Hinge.w_bound(0.1),
            rule,
        }
    }

    /// Hand-pack a single-block PackedBlock plus ctx tables from the
    /// reference inputs (m = y.len()); entries must be (i, j)-sorted.
    fn pack(
        entries: &[Entry],
        row_counts: &[u32],
        col_counts: &[u32],
        y: &[f32],
    ) -> (PackedBlock, Vec<f64>, Vec<f64>, Vec<f64>) {
        let m = y.len() as f64;
        let mut b = PackedBlock {
            n_rows: row_counts.len() as u32,
            n_cols: col_counts.len() as u32,
            ..PackedBlock::default()
        };
        for e in entries {
            let pos = b.cols.len() as u32;
            if matches!(b.groups.last(), Some(g) if g.li == e.i) {
                b.groups.last_mut().unwrap().end = pos + 1;
            } else {
                b.groups.push(RowGroup { li: e.i, start: pos, end: pos + 1 });
            }
            b.cols.push(e.j);
            b.vals.push((e.x as f64 / m) as f32);
        }
        let inv_col: Vec<f64> = col_counts.iter().map(|&c| 1.0 / c as f64).collect();
        let inv_row: Vec<f64> = row_counts.iter().map(|&c| 1.0 / (m * c as f64)).collect();
        let yl: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        (b, inv_col, inv_row, yl)
    }

    fn packed_ctx<'a>(c: &SweepCtx, inv_col: &'a [f64], inv_row: &'a [f64], y: &'a [f64]) -> PackedCtx<'a> {
        PackedCtx {
            loss: c.loss,
            reg: c.reg,
            lambda: c.lambda,
            w_bound: c.w_bound,
            rule: c.rule,
            inv_col,
            inv_row,
            y,
        }
    }

    #[test]
    fn single_update_matches_hand_computation() {
        let row_counts = [2u32, 1];
        let col_counts = [1u32, 2];
        let y = [1.0f32, -1.0];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(0.5));
        let entries = [Entry { i: 0, j: 1, x: 2.0 }];
        let mut w = [0.5f32];
        let mut wacc = [0f32];
        let mut alpha = [0.25f32];
        let mut aacc = [0f32];
        let mut st = BlockState {
            w: &mut w,
            w_acc: &mut wacc,
            w_off: 1,
            alpha: &mut alpha,
            a_acc: &mut aacc,
            a_off: 0,
        };
        let n = sweep_block(&entries, &c, &mut st);
        assert_eq!(n, 1);
        // m = 2, |Ω̄_1| = 2, |Ω_0| = 2.
        // g_w = 0.1 * 2*0.5 / 2 − 0.25*2/2 = 0.05 − 0.25 = −0.2
        // w   = 0.5 − 0.5*(−0.2) = 0.6
        assert!((w[0] - 0.6).abs() < 1e-6, "w {}", w[0]);
        // h'(α, y=1) = 1 (hinge). g_α = 1/(2·2) − 0.5·2/2 = 0.25 − 0.5 = −0.25
        // α = 0.25 + 0.5·(−0.25) = 0.125
        assert!((alpha[0] - 0.125).abs() < 1e-6, "α {}", alpha[0]);
    }

    #[test]
    fn packed_single_update_matches_hand_computation() {
        // Same problem as `single_update_matches_hand_computation`, in
        // block-local coordinates: one entry (li=0, lj=0, x=2, m=2), so
        // x/m = 1 is exact and the packed result is exactly 0.6/0.125.
        let row_counts = [2u32];
        let col_counts = [2u32];
        let y = [1.0f32, -1.0];
        let entries = [Entry { i: 0, j: 0, x: 2.0 }];
        let (b, inv_col, inv_row, yl) = pack(&entries, &row_counts, &col_counts, &y);
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(0.5));
        let pc = packed_ctx(&c, &inv_col, &inv_row, &yl);
        let mut w = [0.5f32];
        let mut wacc = [0f32];
        let mut alpha = [0.25f32];
        let mut aacc = [0f32];
        let mut st = PackedState {
            w: &mut w,
            w_acc: &mut wacc,
            alpha: &mut alpha,
            a_acc: &mut aacc,
        };
        let n = sweep_packed(&b, &pc, &mut st);
        assert_eq!(n, 1);
        assert!((w[0] - 0.6).abs() < 1e-6, "w {}", w[0]);
        assert!((alpha[0] - 0.125).abs() < 1e-6, "α {}", alpha[0]);
    }

    /// Packed vs reference on a small multi-row block, every loss ×
    /// reg × rule: agreement within 1e-5 relative error over repeated
    /// sweeps.
    #[test]
    fn packed_matches_reference_all_combinations() {
        let row_counts = [2u32, 2, 1];
        let col_counts = [2u32, 2, 1];
        let y = [1.0f32, -1.0, 1.0];
        let entries = [
            Entry { i: 0, j: 0, x: 1.5 },
            Entry { i: 0, j: 2, x: -0.5 },
            Entry { i: 1, j: 0, x: 0.7 },
            Entry { i: 1, j: 1, x: 2.0 },
            Entry { i: 2, j: 1, x: -1.2 },
        ];
        for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
            for reg in [Regularizer::L2, Regularizer::L1] {
                for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3)] {
                    let mut c = ctx(&row_counts, &col_counts, &y, rule);
                    c.loss = loss;
                    c.reg = reg;
                    c.m = 3.0;
                    c.w_bound = loss.w_bound(c.lambda);
                    let (b, inv_col, inv_row, yl) =
                        pack(&entries, &row_counts, &col_counts, &y);
                    let pc = packed_ctx(&c, &inv_col, &inv_row, &yl);

                    let mut rw = [0.2f32, -0.1, 0.05];
                    let mut rwa = [0f32; 3];
                    let mut ra: Vec<f32> = y
                        .iter()
                        .map(|&v| loss.alpha_init(v as f64) as f32)
                        .collect();
                    let mut raa = [0f32; 3];
                    let mut pw = rw;
                    let mut pwa = rwa;
                    let mut pa = ra.clone();
                    let mut paa = raa;

                    for _ in 0..5 {
                        let mut rst = BlockState {
                            w: &mut rw,
                            w_acc: &mut rwa,
                            w_off: 0,
                            alpha: &mut ra,
                            a_acc: &mut raa,
                            a_off: 0,
                        };
                        sweep_block(&entries, &c, &mut rst);
                        let mut pst = PackedState {
                            w: &mut pw,
                            w_acc: &mut pwa,
                            alpha: &mut pa,
                            a_acc: &mut paa,
                        };
                        sweep_packed(&b, &pc, &mut pst);
                    }
                    for k in 0..3 {
                        let dw = (rw[k] - pw[k]).abs() as f64;
                        let da = (ra[k] - pa[k]).abs() as f64;
                        assert!(
                            dw <= 1e-5 * rw[k].abs().max(1.0) as f64,
                            "{loss:?}/{reg:?}/{rule:?} w[{k}]: {} vs {}",
                            rw[k],
                            pw[k]
                        );
                        assert!(
                            da <= 1e-5 * ra[k].abs().max(1.0) as f64,
                            "{loss:?}/{reg:?}/{rule:?} α[{k}]: {} vs {}",
                            ra[k],
                            pa[k]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_sampled_matches_full_on_all_indices() {
        // Sampling every index once, in order, must equal a full sweep.
        let row_counts = [2u32, 2];
        let col_counts = [2u32, 2];
        let y = [1.0f32, -1.0];
        let entries = [
            Entry { i: 0, j: 0, x: 1.0 },
            Entry { i: 0, j: 1, x: 0.5 },
            Entry { i: 1, j: 0, x: -1.0 },
            Entry { i: 1, j: 1, x: 2.0 },
        ];
        let (b, inv_col, inv_row, yl) = pack(&entries, &row_counts, &col_counts, &y);
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.2));
        let pc = packed_ctx(&c, &inv_col, &inv_row, &yl);
        let run_full = || {
            let mut w = [0.1f32, -0.2];
            let mut wa = [0f32; 2];
            let mut a = [0.05f32, -0.3];
            let mut aa = [0f32; 2];
            let mut st =
                PackedState { w: &mut w, w_acc: &mut wa, alpha: &mut a, a_acc: &mut aa };
            sweep_packed(&b, &pc, &mut st);
            (w, a, wa, aa)
        };
        let run_sampled = || {
            let mut w = [0.1f32, -0.2];
            let mut wa = [0f32; 2];
            let mut a = [0.05f32, -0.3];
            let mut aa = [0f32; 2];
            let mut st =
                PackedState { w: &mut w, w_acc: &mut wa, alpha: &mut a, a_acc: &mut aa };
            sweep_packed_sampled(&b, &[0, 1, 2, 3], &pc, &mut st);
            (w, a, wa, aa)
        };
        assert_eq!(run_full(), run_sampled());
    }

    #[test]
    fn packed_disjoint_entries_commute() {
        // Updates on (i,j) and (i',j') with i≠i', j≠j' must commute
        // exactly — the key observation of Section 3, on the packed
        // path (exercised via the sampled variant to control order).
        let row_counts = [1u32, 1];
        let col_counts = [1u32, 1];
        let y = [1.0f32, -1.0];
        let entries = [
            Entry { i: 0, j: 0, x: 1.5 },
            Entry { i: 1, j: 1, x: -0.5 },
        ];
        let (b, inv_col, inv_row, yl) = pack(&entries, &row_counts, &col_counts, &y);
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.2));
        let pc = packed_ctx(&c, &inv_col, &inv_row, &yl);
        let run = |order: [u32; 2]| {
            let mut w = [0.1f32, -0.2];
            let mut wa = [0f32; 2];
            let mut a = [0.05f32, -0.3];
            let mut aa = [0f32; 2];
            let mut st =
                PackedState { w: &mut w, w_acc: &mut wa, alpha: &mut a, a_acc: &mut aa };
            sweep_packed_sampled(&b, &order, &pc, &mut st);
            (w, a, wa, aa)
        };
        assert_eq!(run([0, 1]), run([1, 0]));
    }

    #[test]
    fn projection_keeps_iterates_in_boxes() {
        let row_counts = [1u32];
        let col_counts = [1u32];
        let y = [1.0f32];
        // Huge step to force projection.
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(1e4));
        let entries = [Entry { i: 0, j: 0, x: 1.0 }];
        let (b, inv_col, inv_row, yl) = pack(&entries, &row_counts, &col_counts, &y);
        let pc = packed_ctx(&c, &inv_col, &inv_row, &yl);
        let mut w = [0f32];
        let mut wacc = [0f32];
        let mut alpha = [0f32];
        let mut aacc = [0f32];
        for _ in 0..20 {
            let mut st = PackedState {
                w: &mut w,
                w_acc: &mut wacc,
                alpha: &mut alpha,
                a_acc: &mut aacc,
            };
            sweep_packed(&b, &pc, &mut st);
            let bb = c.w_bound as f32;
            assert!((-bb..=bb).contains(&w[0]), "w {}", w[0]);
            let beta = y[0] * alpha[0];
            assert!((0.0..=1.0).contains(&beta), "β {beta}");
        }
    }

    #[test]
    fn adagrad_accumulators_grow_monotonically() {
        let row_counts = [1u32];
        let col_counts = [1u32];
        let y = [1.0f32];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.1));
        let entries = [Entry { i: 0, j: 0, x: 1.0 }];
        let (b, inv_col, inv_row, yl) = pack(&entries, &row_counts, &col_counts, &y);
        let pc = packed_ctx(&c, &inv_col, &inv_row, &yl);
        let mut w = [0.3f32];
        let mut wacc = [0f32];
        let mut alpha = [0.1f32];
        let mut aacc = [0f32];
        let mut prev_w = 0.0;
        let mut prev_a = 0.0;
        for _ in 0..10 {
            let mut st = PackedState {
                w: &mut w,
                w_acc: &mut wacc,
                alpha: &mut alpha,
                a_acc: &mut aacc,
            };
            sweep_packed(&b, &pc, &mut st);
            assert!(wacc[0] >= prev_w);
            assert!(aacc[0] >= prev_a);
            prev_w = wacc[0];
            prev_a = aacc[0];
        }
        assert!(prev_w > 0.0);
        assert!(prev_a > 0.0);
    }

    #[test]
    fn fixed_step_deterministic() {
        let row_counts = [2u32, 2];
        let col_counts = [2u32, 2];
        let y = [1.0f32, -1.0];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(0.1));
        let entries = [
            Entry { i: 0, j: 0, x: 1.0 },
            Entry { i: 0, j: 1, x: 0.5 },
            Entry { i: 1, j: 0, x: -1.0 },
            Entry { i: 1, j: 1, x: 2.0 },
        ];
        let (b, inv_col, inv_row, yl) = pack(&entries, &row_counts, &col_counts, &y);
        let pc = packed_ctx(&c, &inv_col, &inv_row, &yl);
        let run = || {
            let mut w = [0f32; 2];
            let mut wacc = [0f32; 2];
            let mut alpha = [0f32; 2];
            let mut aacc = [0f32; 2];
            for _ in 0..5 {
                let mut st = PackedState {
                    w: &mut w,
                    w_acc: &mut wacc,
                    alpha: &mut alpha,
                    a_acc: &mut aacc,
                };
                sweep_packed(&b, &pc, &mut st);
            }
            (w, alpha)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn square_loss_alpha_unconstrained() {
        let row_counts = [1u32];
        let col_counts = [1u32];
        let y = [3.0f32];
        let mut c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(1.0));
        c.loss = Loss::Square;
        let entries = [Entry { i: 0, j: 0, x: 1.0 }];
        let (b, inv_col, inv_row, yl) = pack(&entries, &row_counts, &col_counts, &y);
        let pc = packed_ctx(&c, &inv_col, &inv_row, &yl);
        let mut w = [0f32];
        let mut wacc = [0f32];
        let mut alpha = [0f32];
        let mut aacc = [0f32];
        let mut st = PackedState {
            w: &mut w,
            w_acc: &mut wacc,
            alpha: &mut alpha,
            a_acc: &mut aacc,
        };
        sweep_packed(&b, &pc, &mut st);
        // g_α = (y − α)/m − wx/m = 3/1 − 0 = 3 → α = 3 (no clamp).
        assert!((alpha[0] - 3.0).abs() < 1e-6);
    }
}
