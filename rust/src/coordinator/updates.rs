//! The saddle-point update kernels — Eq. (8) plus AdaGrad and the
//! App. B projections. This is DSO's hot path for sparse data: every
//! worker calls one of the packed sweeps once per inner iteration on
//! its active block Ω^(q, σ_r(q)). Which sweep a block takes is
//! precompiled per run by [`super::plan::SweepPlan`]; the engines no
//! longer carry the decision tree.
//!
//! Update for a sampled nonzero (i, j) with x = x_ij:
//!
//! ```text
//!   g_w = λ∇φ(w_j)/|Ω̄_j| − α_i·x/m          (descent direction in w_j)
//!   g_α = h'(α_i)/(m|Ω_i|) − w_j·x/m         (ascent direction in α_i)
//!   w_j ← Π_B [ w_j − η_w·g_w ]
//!   α_i ← Π_A [ α_i + η_α·g_α ]
//! ```
//!
//! Both gradients are evaluated at the *old* (w_j, α_i), matching the
//! simultaneous gradient step analyzed in Lemma 2 / Theorem 1. η is
//! either the epoch-level η_t = η₀/√t of Algorithm 1 or per-coordinate
//! AdaGrad (App. B); Π_B is the w box, Π_A the dual feasible set.
//!
//! ## Four implementations
//!
//! * [`sweep_lanes_affine`] — the square-loss specialization of the
//!   lane kernel. For the square loss h'(α) = y − α is **affine in α**
//!   with an identity projection ([`AffineLossK`]), so one saddle step
//!   on α is the affine map α ← a·α + b with a = 1 − η·hr and
//!   b = η·(y·hr − w_j·x) — and a chunk's 8 sequential steps *compose*
//!   in closed form. The kernel evaluates the α-independent
//!   coefficients in 8-wide f32 lanes (b's `y·hr` factor comes from
//!   the `PackedBlocks::stripe_alpha_bias` precompute) and folds the
//!   chunk into α_i with **one FMA per entry**
//!   (`StepK::alpha_chunk_affine`) instead of 8 full
//!   gradient/step/projection evaluations; AdaGrad keeps its serial η
//!   but consumes the same precomputed coefficient lanes. The w side
//!   is identical to [`sweep_lanes`]. Hinge/logistic
//!   (whose per-entry projection is load-bearing) fall back to
//!   `sweep_lanes` bit for bit, as do short groups and the sampled
//!   path — the dispatch plan ([`super::plan::SweepPlan`]) only routes
//!   square-loss lane blocks here.
//!
//!   **Numerics**: tolerance-equivalent (≤1e-5 relative per sweep,
//!   property-tested in `tests/alpha_lane.rs`), *not* bit-identical, to
//!   the scalar α recurrence: the coefficients round `y·hr − w·x`
//!   through f32, the running α is not rounded through f32 between
//!   entries, and the fixed-step fold associates η differently
//!   (a·α + η·c vs α + η·(c − hr·α)).
//!
//! * [`sweep_lanes`] — the SIMD production kernel over lane-major
//!   [`PackedBlock`](crate::partition::omega::PackedBlock)s (§Perf).
//!   Within a row group every entry touches a *distinct* w column, so
//!   the w side of the update is conflict-free and batches into
//!   [`LANES`] (= 8) f32 value lanes: per chunk the kernel gathers 8
//!   (w_j, x, 1/|Ω̄_j|) triples, evaluates ∇φ ([`RegK::grad_lane_b`]),
//!   the gradient FMA, the step rule (`StepK::eta_lane_b` — AdaGrad's
//!   accumulate/√/divide becomes one 8-wide op each) and the box clamp
//!   full-width branch-free (sentinel-padded lanes compute garbage that
//!   is *never stored*), then scatters the first `len` lanes back. The
//!   α side is inherently sequential — all 8 entries update the same
//!   α_i — so the α recurrence stays scalar f64, consuming the lanes'
//!   w·x dot products; it is arithmetically identical to the scalar
//!   kernel's. Groups shorter than `LANES` fall back to the scalar
//!   group loop (same code path as [`sweep_packed`]).
//!
//!   **Backends** (DESIGN.md §SIMD-backend): every lane-granular op —
//!   the chunk gather included — goes through the
//!   [`SimdBackend`](crate::simd::SimdBackend) the sweep was
//!   monomorphized with. [`sweep_lanes`] is the
//!   [`Portable`](crate::simd::Portable) (autovec, bit-identical to
//!   PR 3) instantiation; [`sweep_lanes_with`] exposes the generic so
//!   `SweepPlan` can dispatch the AVX2 gather/FMA backend selected
//!   once per run by CPU detection — engines and kernels stay
//!   dispatch-free.
//!
//!   **Numerics**: the w side computes in f32 (that is what buys the
//!   8-wide vectors), so `sweep_lanes` is *tolerance-equivalent* to the
//!   scalar kernel — ≤1e-5 relative after a sweep, property-tested in
//!   `tests/lane_kernel.rs` — not bit-identical. The AVX2 backend
//!   additionally contracts multiply-adds into FMAs, so backends are
//!   tolerance-equivalent (not bit-identical) to *each other*;
//!   threaded ≡ replay bit-identity is unaffected *within* a backend
//!   (both executions dispatch to the same planned kernel). Tests that
//!   pin exact trajectories stay on the scalar or portable path.
//!
//! * [`sweep_packed`] — the scalar packed kernel. The `(Loss,
//!   Regularizer, StepRule)` triple is dispatched **once per sweep**
//!   into one of 12 monomorphized loops (`losses::kernel`), and the
//!   packed layout supplies block-local indices, `x/m` pre-folded into
//!   the stored value, and reciprocal tables for both Eq. (8)
//!   denominators — the inner loop performs zero divisions, zero offset
//!   subtractions, and zero enum dispatch. Row-invariant state (y_i,
//!   α_i and its AdaGrad accumulator, 1/(m|Ω_i|)) is loaded once per
//!   row group instead of once per nonzero; α stays in a register
//!   across the group (rounded through f32 after each update, exactly
//!   as the store/reload of the reference path rounds it). The plan
//!   routes blocks with no lane-eligible group
//!   (`PackedBlock::has_lanes`) here, and [`sweep_packed_sampled`] — the
//!   `updates_per_block` variant, which resolves each sampled entry's
//!   row through the cold `entry_group` side table (one load, no
//!   binary search) — for the subsampled path.
//!
//! * [`sweep_block`] — the seed's COO `Entry` kernel with per-update
//!   enum dispatch, global indices and live divisions. Kept as the
//!   *reference path*: property tests replay the packed kernels against
//!   it on the same block and require agreement within 1e-5 relative
//!   error. `benches/bench_updates.rs` benchmarks all three side by
//!   side; `BENCH_updates.json` / `BENCH_lanes.json` record the
//!   speedups.
//!
//! The packed sweeps visit real entries in the same (row, col) order as
//! the reference path, so Lemma-2 serializability — and the bit-identity
//! between the threaded engine and `run_replay`, which dispatch to the
//! same kernel — is unaffected.

use crate::losses::kernel::{
    AffineLossK, HingeK, L1K, L2K, Lane, Lane2, LogisticK, LossK, RegK, SquareK, LANES2,
};
use crate::losses::{Loss, Regularizer};
use crate::optim::step::ADAGRAD_EPS;
use crate::partition::omega::{Entry, PackedBlock, LANES};
use crate::simd::backend::{join_lanes, split_lanes};
use crate::simd::{Portable, SimdBackend};

/// Which step rule the sweep applies.
#[derive(Clone, Copy, Debug)]
pub enum StepRule {
    /// Fixed η for this sweep (η_t of Algorithm 1).
    Fixed(f64),
    /// AdaGrad with η₀; accumulators supplied per sweep.
    AdaGrad(f64),
    /// Per-coordinate adaptive rate η₀/√(1+Σg²) in the spirit of
    /// Cutkosky & Busa-Fekete (arXiv:1802.05811): same accumulated
    /// second-moment statistic as AdaGrad, but the unit offset bounds
    /// η by η₀ from the first step — no ε floor and no early-step
    /// blow-up on sparse coordinates whose first gradient is tiny.
    Adaptive(f64),
}

impl StepRule {
    /// Whether the rule carries per-coordinate accumulator state (and
    /// therefore must ship it with the rotating w block).
    pub fn uses_acc(&self) -> bool {
        matches!(self, StepRule::AdaGrad(_) | StepRule::Adaptive(_))
    }
}

/// Immutable per-sweep context (problem constants and global count
/// tables shared read-only by every worker). Used by the COO
/// *reference* path.
pub struct SweepCtx<'a> {
    pub loss: Loss,
    pub reg: Regularizer,
    pub lambda: f64,
    /// Number of training points m (as f64, used in every update).
    pub m: f64,
    /// |Ω_i| per global row.
    pub row_counts: &'a [u32],
    /// |Ω̄_j| per global column.
    pub col_counts: &'a [u32],
    /// Full label vector.
    pub y: &'a [f32],
    /// w box bound B (App. B): iterates clamped to [−B, B].
    pub w_bound: f64,
    pub rule: StepRule,
}

/// Mutable views of the worker's current parameter blocks for the
/// reference path. `w`/`w_acc` are the travelling w-block (global
/// coords `w_off ..`), `alpha`/`a_acc` the worker-resident α block
/// (global coords `a_off ..`).
pub struct BlockState<'a> {
    pub w: &'a mut [f32],
    pub w_acc: &'a mut [f32],
    pub w_off: usize,
    pub alpha: &'a mut [f32],
    pub a_acc: &'a mut [f32],
    pub a_off: usize,
}

/// Immutable per-sweep context for the packed kernels. All tables are
/// stripe-local: `inv_col`/`inv_col32` belong to the active column
/// stripe (the travelling w block), `inv_row`/`y` to the worker's row
/// stripe.
pub struct PackedCtx<'a> {
    pub loss: Loss,
    pub reg: Regularizer,
    pub lambda: f64,
    pub w_bound: f64,
    pub rule: StepRule,
    /// 1/|Ω̄_j| per block-local column (scalar kernel, f64).
    pub inv_col: &'a [f64],
    /// 1/|Ω̄_j| per block-local column (lane kernel, f32).
    pub inv_col32: &'a [f32],
    /// 1/(m·|Ω_i|) per block-local row.
    pub inv_row: &'a [f64],
    /// Labels per block-local row.
    pub y: &'a [f64],
    /// (y_i·1/(m·|Ω_i|)) as f32 per block-local row — the precomputed
    /// chunk-invariant bias of the square loss's affine α recurrence
    /// (`partition::omega::PackedBlocks::stripe_alpha_bias`), read
    /// only by [`sweep_lanes_affine`].
    pub alpha_bias32: &'a [f32],
}

/// Mutable stripe-local parameter views for the packed kernels. No
/// offsets: packed blocks index these directly.
pub struct PackedState<'a> {
    pub w: &'a mut [f32],
    pub w_acc: &'a mut [f32],
    pub alpha: &'a mut [f32],
    pub a_acc: &'a mut [f32],
}

// ---------------------------------------------------------------------
// Step rules (compile-time dispatched)
// ---------------------------------------------------------------------

/// Step rule resolved at compile time. `eta` may update the AdaGrad
/// accumulator in place; the fixed rule ignores it. `eta_lane_b` is
/// the 8-wide f32 batch used by the lane kernel's w side, routed
/// through the sweep's [`SimdBackend`] (AdaGrad's accumulate/√/divide
/// is one backend op; the fixed rule is a splat on any backend).
trait StepK: Copy {
    /// Whether the rule reads/writes per-coordinate accumulators —
    /// lets the lane kernel skip the accumulator gather/scatter
    /// entirely for the fixed rule (const-folded per monomorphization).
    const USES_ACC: bool;

    fn eta(self, acc: &mut f32, g: f64) -> f64;

    fn eta_lane_b<B: SimdBackend>(self, acc: &mut Lane, g: &Lane) -> Lane;

    /// [`StepK::eta_lane_b`] over a fused chunk pair (the 16-wide path
    /// of `PAIRED` backends). Per-lane math is identical to two 8-wide
    /// calls — 512-bit FMA/√/÷ round per lane exactly like their
    /// 256-bit forms — so the pair path stays value-identical to the
    /// chunk-at-a-time path it fuses.
    fn eta_lane2_b<B: SimdBackend>(self, acc: &mut Lane2, g: &Lane2) -> Lane2;

    /// Fold one LANES-chunk of the **affine** α recurrence
    /// ([`AffineLossK`] losses, i.e. square): `cv[k]` holds the
    /// α-independent part of g_α at entry k (computed 8-wide by the
    /// caller), `slope_hr = DUAL_SLOPE·hr` its chunk-invariant slope,
    /// so g_α = cv[k] + slope_hr·α. Writes each real entry's
    /// *pre-update* α — the value its w-side gradient must see — into
    /// `av[..n]`, updates the row's AdaGrad accumulator `acc` when the
    /// rule uses one, and returns α after the chunk.
    ///
    /// The fixed rule composes the whole step into α ← a·α + b_k (one
    /// f64 FMA per entry — the chunk's entire serial dependency chain);
    /// AdaGrad's η depends on g_α itself, so it keeps a short serial
    /// loop but still consumes the precomputed coefficient lanes.
    fn alpha_chunk_affine(
        self,
        acc: &mut f32,
        ai: f64,
        cv: &Lane,
        n: usize,
        slope_hr: f64,
        av: &mut Lane,
    ) -> f64;
}

#[derive(Clone, Copy)]
struct FixedStep(f64);

impl StepK for FixedStep {
    const USES_ACC: bool = false;

    #[inline(always)]
    fn eta(self, _acc: &mut f32, _g: f64) -> f64 {
        self.0
    }

    #[inline(always)]
    fn eta_lane_b<B: SimdBackend>(self, _acc: &mut Lane, _g: &Lane) -> Lane {
        [self.0 as f32; LANES]
    }

    #[inline(always)]
    fn eta_lane2_b<B: SimdBackend>(self, _acc: &mut Lane2, _g: &Lane2) -> Lane2 {
        [self.0 as f32; LANES2]
    }

    /// Closed-form fold: with constant η the affine per-entry maps
    /// compose, so the chunk is α ← a·α + b_k with a = 1 + η·slope_hr
    /// hoisted out and b_k = η·cv[k]. The b lanes widen to f64 outside
    /// the dependency chain; the chain itself is one FMA per entry.
    #[inline(always)]
    fn alpha_chunk_affine(
        self,
        _acc: &mut f32,
        mut ai: f64,
        cv: &Lane,
        n: usize,
        slope_hr: f64,
        av: &mut Lane,
    ) -> f64 {
        let a = 1.0 + self.0 * slope_hr;
        let mut bv = [0f64; LANES];
        for k in 0..LANES {
            bv[k] = self.0 * cv[k] as f64;
        }
        for k in 0..n {
            av[k] = ai as f32;
            ai = a * ai + bv[k];
        }
        ai
    }
}

#[derive(Clone, Copy)]
struct AdaGradStep(f64);

impl StepK for AdaGradStep {
    const USES_ACC: bool = true;

    #[inline(always)]
    fn eta(self, acc: &mut f32, g: f64) -> f64 {
        // Accumulate in f64, store back f32 — same rounding as the
        // reference path and `optim::step::AdaGrad`.
        let a = *acc as f64 + g * g;
        *acc = a as f32;
        self.0 / (ADAGRAD_EPS + a).sqrt()
    }

    /// f32 lane batch: accumulate, √, divide — one 8-wide backend op
    /// each (this is where the lane kernel wins most; the scalar path
    /// pays a serial f64 sqrt + div per coordinate).
    #[inline(always)]
    fn eta_lane_b<B: SimdBackend>(self, acc: &mut Lane, g: &Lane) -> Lane {
        B::adagrad_eta_lane(self.0 as f32, ADAGRAD_EPS as f32, acc, g)
    }

    #[inline(always)]
    fn eta_lane2_b<B: SimdBackend>(self, acc: &mut Lane2, g: &Lane2) -> Lane2 {
        B::adagrad_eta_lane2(self.0 as f32, ADAGRAD_EPS as f32, acc, g)
    }

    /// AdaGrad's η is a function of g_α, so the per-entry maps do not
    /// compose into one affine map; the serial loop stays, but each
    /// iteration is one FMA for g_α plus the accumulate/√/divide —
    /// the dual-gradient/projection evaluations are already folded
    /// into the precomputed `cv` lanes.
    #[inline(always)]
    fn alpha_chunk_affine(
        self,
        acc: &mut f32,
        mut ai: f64,
        cv: &Lane,
        n: usize,
        slope_hr: f64,
        av: &mut Lane,
    ) -> f64 {
        for k in 0..n {
            av[k] = ai as f32;
            let ga = cv[k] as f64 + slope_hr * ai;
            let eta = self.eta(acc, ga);
            ai += eta * ga;
        }
        ai
    }
}

/// η₀/√(1+Σg²) — [`StepRule::Adaptive`]. Structurally AdaGrad with the
/// ε floor replaced by a unit offset *inside* the root, so it reuses
/// AdaGrad's backend lane op with `eps = 1.0` verbatim: the backend
/// computes η₀/√(eps + acc'), which for eps = 1 is exactly this rule.
#[derive(Clone, Copy)]
struct AdaptiveStep(f64);

impl StepK for AdaptiveStep {
    const USES_ACC: bool = true;

    #[inline(always)]
    fn eta(self, acc: &mut f32, g: f64) -> f64 {
        // Same f64-accumulate / f32-store rounding as AdaGradStep.
        let a = *acc as f64 + g * g;
        *acc = a as f32;
        self.0 / (1.0 + a).sqrt()
    }

    #[inline(always)]
    fn eta_lane_b<B: SimdBackend>(self, acc: &mut Lane, g: &Lane) -> Lane {
        B::adagrad_eta_lane(self.0 as f32, 1.0f32, acc, g)
    }

    #[inline(always)]
    fn eta_lane2_b<B: SimdBackend>(self, acc: &mut Lane2, g: &Lane2) -> Lane2 {
        B::adagrad_eta_lane2(self.0 as f32, 1.0f32, acc, g)
    }

    /// η depends on g_α (like AdaGrad), so the serial per-entry loop
    /// stays; the coefficient lanes are still precomputed 8-wide.
    #[inline(always)]
    fn alpha_chunk_affine(
        self,
        acc: &mut f32,
        mut ai: f64,
        cv: &Lane,
        n: usize,
        slope_hr: f64,
        av: &mut Lane,
    ) -> f64 {
        for k in 0..n {
            av[k] = ai as f32;
            let ga = cv[k] as f64 + slope_hr * ai;
            let eta = self.eta(acc, ga);
            ai += eta * ga;
        }
        ai
    }
}

// ---------------------------------------------------------------------
// Shared validation
// ---------------------------------------------------------------------

/// Validate, once per sweep, everything the unchecked inner loops rely
/// on: the stripe-local views cover the block's index spaces, the row
/// groups tile the logical entry numbering with in-bounds rows, the
/// physical lane regions tile the (possibly sentinel-padded) storage,
/// and every stored column — sentinels included, since the lane kernel
/// gathers full-width — is within the stripe. `PackedBlocks::build`
/// establishes these invariants, but `PackedBlock`'s fields are public
/// — re-checking here keeps the sweeps sound for any safely
/// constructed block. Cost is O(groups) + one vectorizable u32 max
/// scan over `cols`, amortized over the ~20+ cycles each update costs.
#[inline]
fn check_packed_bounds(block: &PackedBlock, ctx: &PackedCtx, st: &PackedState) {
    // The AVX2 backend's `_mm256_i32gather_ps` sign-extends i32 lane
    // indices: stripe widths must fit in i32 so stored columns can
    // never read as negative. (Real stripe widths are d/p — nowhere
    // near this; the assert keeps the gather's safety argument local.)
    assert!(block.n_cols <= i32::MAX as u32, "column stripe exceeds i32 gather range");
    // §Alignment: lane storage and gather tables are AVec-backed
    // (64-byte aligned) by construction; hand-assembled test blocks
    // inherit this through the public AVec fields.
    debug_assert!(crate::simd::is_aligned(&block.cols[..]));
    debug_assert!(crate::simd::is_aligned(&block.vals[..]));
    debug_assert!(crate::simd::is_aligned(ctx.inv_col32) || ctx.inv_col32.is_empty());
    assert!(block.n_cols as usize <= st.w.len());
    assert!(block.n_rows as usize <= st.alpha.len());
    assert!(st.w_acc.len() == st.w.len());
    assert!(st.a_acc.len() == st.alpha.len());
    assert!(block.n_cols as usize <= ctx.inv_col.len());
    assert!(block.n_cols as usize <= ctx.inv_col32.len());
    assert!(block.n_rows as usize <= ctx.inv_row.len());
    assert!(block.n_rows as usize <= ctx.y.len());
    assert!(block.n_rows as usize <= ctx.alpha_bias32.len());
    assert!(block.vals.len() == block.cols.len());
    let mut next = 0u32;
    let mut pnext = 0usize;
    for g in &block.groups {
        assert!(g.start == next && g.end >= g.start, "groups must tile 0..nnz");
        assert!(g.li < block.n_rows, "row group out of stripe");
        assert!(g.pad_start as usize == pnext, "lane regions must tile storage");
        next = g.end;
        pnext += g.padded_len();
    }
    // (`block.nnz()` is groups.last().end, so `next` equals it by
    // construction — the independent coverage checks are the physical
    // tiling below and, when built, the sampling side table.)
    assert!(pnext == block.cols.len(), "lane regions must cover storage");
    assert!(
        block.entry_group.is_empty() || block.entry_group.len() == next as usize,
        "entry_group side table must cover all logical entries"
    );
    if let Some(&max_col) = block.cols.iter().max() {
        assert!(max_col < block.n_cols, "column out of stripe");
    }
}

// ---------------------------------------------------------------------
// Scalar packed kernel
// ---------------------------------------------------------------------

/// Sweep every real entry of a packed block once, in storage order,
/// with the scalar f64 kernel. Returns #updates.
pub fn sweep_packed(block: &PackedBlock, ctx: &PackedCtx, st: &mut PackedState) -> usize {
    match ctx.rule {
        StepRule::Fixed(eta) => dispatch_loss_reg(block, ctx, st, FixedStep(eta)),
        StepRule::AdaGrad(eta0) => dispatch_loss_reg(block, ctx, st, AdaGradStep(eta0)),
        StepRule::Adaptive(eta0) => dispatch_loss_reg(block, ctx, st, AdaptiveStep(eta0)),
    }
}

/// Resolve (loss, reg) once per sweep into a monomorphized scalar loop.
fn dispatch_loss_reg<S: StepK>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
    step: S,
) -> usize {
    match (ctx.loss, ctx.reg) {
        (Loss::Hinge, Regularizer::L2) => sweep_mono::<HingeK, L2K, S>(block, ctx, st, step),
        (Loss::Hinge, Regularizer::L1) => sweep_mono::<HingeK, L1K, S>(block, ctx, st, step),
        (Loss::Logistic, Regularizer::L2) => {
            sweep_mono::<LogisticK, L2K, S>(block, ctx, st, step)
        }
        (Loss::Logistic, Regularizer::L1) => {
            sweep_mono::<LogisticK, L1K, S>(block, ctx, st, step)
        }
        (Loss::Square, Regularizer::L2) => sweep_mono::<SquareK, L2K, S>(block, ctx, st, step),
        (Loss::Square, Regularizer::L1) => sweep_mono::<SquareK, L1K, S>(block, ctx, st, step),
    }
}

/// One row group's entries swept with the scalar f64 update — the PR-1
/// kernel body, shared verbatim by [`sweep_packed`] (every group) and
/// [`sweep_lanes`] (groups shorter than `LANES`). `span` is a physical
/// range of real entries; `ai`/`aa` are the row's running α and AdaGrad
/// accumulator, stored back by the caller.
///
/// # Safety argument
/// Caller runs `check_packed_bounds` first; `span` lies inside a
/// group's real prefix, so every `cols[k]` is a validated in-stripe
/// column.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn sweep_group_scalar<L: LossK, R: RegK, S: StepK>(
    cols: &[u32],
    vals: &[f32],
    span: std::ops::Range<usize>,
    ctx: &PackedCtx,
    st: &mut PackedState,
    step: S,
    y: f64,
    hr: f64,
    ai: &mut f64,
    aa: &mut f32,
) {
    let b = ctx.w_bound;
    let lambda = ctx.lambda;
    for k in span {
        debug_assert!(k < cols.len());
        // SAFETY: `span` lies inside a group's real prefix and every
        // stored column is validated in-stripe — `check_packed_bounds`
        // ran first (see the function docs).
        unsafe {
            let lj = *cols.get_unchecked(k) as usize;
            let xm = *vals.get_unchecked(k) as f64; // x/m, pre-folded
            debug_assert!(lj < st.w.len());
            let wj = *st.w.get_unchecked(lj) as f64;
            let gw = lambda * R::grad(wj) * *ctx.inv_col.get_unchecked(lj) - *ai * xm;
            let ga = L::dual_grad(*ai, y) * hr - wj * xm;
            let eta_w = step.eta(st.w_acc.get_unchecked_mut(lj), gw);
            let eta_a = step.eta(aa, ga);
            *st.w.get_unchecked_mut(lj) = (wj - eta_w * gw).clamp(-b, b) as f32;
            // Round α through f32 like the reference path's
            // store/reload, so both paths see the same value when
            // a row has several entries.
            *ai = L::project(*ai + eta_a * ga, y) as f32 as f64;
        }
    }
}

fn sweep_mono<L: LossK, R: RegK, S: StepK>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
    step: S,
) -> usize {
    check_packed_bounds(block, ctx, st);
    let cols = &block.cols[..];
    let vals = &block.vals[..];
    for g in &block.groups {
        let li = g.li as usize;
        debug_assert!(li < st.alpha.len());
        // Row-invariant state: loaded once per row group.
        //
        // SAFETY: g.li < n_rows <= len of every row-stripe table/view
        // (`check_packed_bounds`).
        let (y, hr, mut ai, mut aa) = unsafe {
            (
                *ctx.y.get_unchecked(li),
                *ctx.inv_row.get_unchecked(li),
                *st.alpha.get_unchecked(li) as f64,
                *st.a_acc.get_unchecked(li),
            )
        };
        let s = g.pad_start as usize;
        sweep_group_scalar::<L, R, S>(
            cols,
            vals,
            s..s + g.len(),
            ctx,
            st,
            step,
            y,
            hr,
            &mut ai,
            &mut aa,
        );
        // SAFETY: same in-bounds argument as the load above.
        unsafe {
            *st.alpha.get_unchecked_mut(li) = ai as f32;
            *st.a_acc.get_unchecked_mut(li) = aa;
        }
    }
    block.nnz()
}

// ---------------------------------------------------------------------
// SIMD lane kernel
// ---------------------------------------------------------------------

/// Sweep every real entry of a lane-major packed block once, in storage
/// order, batching the w side of the update [`LANES`] entries at a time
/// (f32) on the **portable** backend — bit-identical to the pre-backend
/// (PR 2/3) kernel; the pinned suites run through here. Groups shorter
/// than `LANES` run the scalar group loop. Returns #updates (sentinel
/// padding excluded).
pub fn sweep_lanes(block: &PackedBlock, ctx: &PackedCtx, st: &mut PackedState) -> usize {
    sweep_lanes_with::<Portable>(block, ctx, st)
}

/// [`sweep_lanes`] monomorphized over an explicit [`SimdBackend`] —
/// the entry point `SweepPlan` dispatches (backend chosen once per run
/// by CPU-feature detection, recorded in the plan; see DESIGN.md
/// §SIMD-backend). Callers selecting a non-portable backend must
/// uphold its feature contract (`simd::resolve` / a test-side
/// `is_x86_feature_detected!` guard).
pub fn sweep_lanes_with<B: SimdBackend>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
) -> usize {
    match ctx.rule {
        StepRule::Fixed(eta) => dispatch_lanes::<B, _>(block, ctx, st, FixedStep(eta)),
        StepRule::AdaGrad(eta0) => dispatch_lanes::<B, _>(block, ctx, st, AdaGradStep(eta0)),
        StepRule::Adaptive(eta0) => dispatch_lanes::<B, _>(block, ctx, st, AdaptiveStep(eta0)),
    }
}

/// Resolve (loss, reg) once per sweep into a monomorphized lane loop.
fn dispatch_lanes<B: SimdBackend, S: StepK>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
    step: S,
) -> usize {
    match (ctx.loss, ctx.reg) {
        (Loss::Hinge, Regularizer::L2) => {
            sweep_lanes_mono::<B, HingeK, L2K, S>(block, ctx, st, step)
        }
        (Loss::Hinge, Regularizer::L1) => {
            sweep_lanes_mono::<B, HingeK, L1K, S>(block, ctx, st, step)
        }
        (Loss::Logistic, Regularizer::L2) => {
            sweep_lanes_mono::<B, LogisticK, L2K, S>(block, ctx, st, step)
        }
        (Loss::Logistic, Regularizer::L1) => {
            sweep_lanes_mono::<B, LogisticK, L1K, S>(block, ctx, st, step)
        }
        (Loss::Square, Regularizer::L2) => {
            sweep_lanes_mono::<B, SquareK, L2K, S>(block, ctx, st, step)
        }
        (Loss::Square, Regularizer::L1) => {
            sweep_lanes_mono::<B, SquareK, L1K, S>(block, ctx, st, step)
        }
    }
}

/// The w side of one lane chunk — ∇φ, gradient FMA, step rule, box
/// clamp, all branch-free full-width f32 backend ops — followed by the
/// explicit scatter of the first `n` (real) lanes only: sentinels are
/// never written through, so padding cannot perturb state (per-lane
/// stores; AVX2 has no scatter instruction and the partial write is
/// the point). `av[k]` is the α entry k's gradient must see (its
/// row's pre-update α). Shared verbatim by [`sweep_lanes_with`] and
/// [`sweep_lanes_affine_with`], whose chunks differ only in how the α
/// recurrence between gather and w side is computed.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn w_side_chunk<B: SimdBackend, R: RegK, S: StepK>(
    step: S,
    lj: &[usize; LANES],
    wv: &Lane,
    xv: &Lane,
    iv: &Lane,
    av: &Lane,
    n: usize,
    lam32: f32,
    b32: f32,
    st: &mut PackedState,
) {
    let rv = R::grad_lane_b::<B>(wv);
    let gw = B::w_grad(lam32, &rv, iv, av, xv);
    let mut accv: Lane = [0.0; LANES];
    if S::USES_ACC {
        // SAFETY: `lj` holds the chunk's column ids, validated
        // in-stripe by `check_packed_bounds` (w_acc.len() == w.len()).
        accv = unsafe { B::gather_idx(st.w_acc, lj) };
    }
    let etav = step.eta_lane_b::<B>(&mut accv, &gw);
    let wn = B::w_step_clamp(wv, &etav, &gw, b32);
    for k in 0..n {
        // SAFETY: lj[k] is a validated in-stripe column
        // (`check_packed_bounds`); k < n <= LANES real lanes only, so
        // sentinels are never written through.
        unsafe {
            *st.w.get_unchecked_mut(lj[k]) = wn[k];
            if S::USES_ACC {
                *st.w_acc.get_unchecked_mut(lj[k]) = accv[k];
            }
        }
    }
}

/// [`w_side_chunk`] over a fused chunk pair — the 16-wide path of
/// `PAIRED` backends. No `n` parameter: the pair path only runs when
/// the next [`LANES2`] physical slots are all real entries (see the
/// pair loops), so the writeback is full-width — which is what lets
/// AVX-512 use its native scatter instead of the per-lane stores the
/// partial 8-wide writeback needs.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn w_side_chunk2<B: SimdBackend, R: RegK, S: StepK>(
    step: S,
    lj: &[usize; LANES2],
    wv: &Lane2,
    xv: &Lane2,
    iv: &Lane2,
    av: &Lane2,
    lam32: f32,
    b32: f32,
    st: &mut PackedState,
) {
    let rv = R::grad_lane2_b::<B>(wv);
    let gw = B::w_grad2(lam32, &rv, iv, av, xv);
    let mut accv: Lane2 = [0.0; LANES2];
    if S::USES_ACC {
        // SAFETY: `lj` holds the pair's column ids, validated in-stripe
        // by `check_packed_bounds` (w_acc.len() == w.len()).
        accv = unsafe { B::gather_idx2(st.w_acc, lj) };
    }
    let etav = step.eta_lane2_b::<B>(&mut accv, &gw);
    let wn = B::w_step_clamp2(wv, &etav, &gw, b32);
    // SAFETY: every lj[k] is a validated in-stripe column, all 16 lanes
    // are real entries (the pair path never sees sentinels), and the
    // pair's ids are pairwise distinct — one row group is one CSR row —
    // so the full-width scatter is conflict-free.
    unsafe {
        B::scatter2(st.w, lj, &wn);
        if S::USES_ACC {
            B::scatter2(st.w_acc, lj, &accv);
        }
    }
}

fn sweep_lanes_mono<B: SimdBackend, L: LossK, R: RegK, S: StepK>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
    step: S,
) -> usize {
    check_packed_bounds(block, ctx, st);
    let b32 = ctx.w_bound as f32;
    let lam32 = ctx.lambda as f32;
    let cols = &block.cols[..];
    let vals = &block.vals[..];
    for g in &block.groups {
        let li = g.li as usize;
        debug_assert!(li < st.alpha.len());
        // SAFETY: g.li < n_rows <= len of every row-stripe table/view
        // (`check_packed_bounds`).
        let (y, hr, mut ai, mut aa) = unsafe {
            (
                *ctx.y.get_unchecked(li),
                *ctx.inv_row.get_unchecked(li),
                *st.alpha.get_unchecked(li) as f64,
                *st.a_acc.get_unchecked(li),
            )
        };
        let len = g.len();
        if len < LANES {
            // Short group: the scalar kernel body (identical numerics
            // to `sweep_packed`); full-width lanes would waste ≥ half
            // their slots here.
            let s = g.pad_start as usize;
            sweep_group_scalar::<L, R, S>(
                cols,
                vals,
                s..s + len,
                ctx,
                st,
                step,
                y,
                hr,
                &mut ai,
                &mut aa,
            );
        } else {
            let mut base = g.pad_start as usize;
            let mut rem = len;
            if B::PAIRED {
                // Fused chunk pairs (16-wide) while ≥ LANES2 real
                // entries remain, i.e. while both chunks of the pair
                // are full — the padded tail (and any odd trailing
                // chunk) drops to the 8-wide loop below. Gathering the
                // pair's two chunks *before* the first chunk's
                // writeback is value-identical to the sequential 8+8
                // order because the 16 entries belong to one row group
                // (one CSR row) and therefore touch 16 distinct
                // columns; per-lane 512-bit FMA rounds exactly like
                // 256-bit, so the fusion changes codegen, not results.
                while rem >= LANES2 {
                    // SAFETY: rem >= LANES2 real entries remain, so
                    // `base + LANES2` stays within the group's
                    // physical lane region and every slot of the pair
                    // is a real entry with a validated in-stripe
                    // column (`check_packed_bounds`).
                    let (lj, wv, xv, iv) =
                        unsafe { B::gather_chunk2(cols, vals, base, st.w, ctx.inv_col32) };
                    // α recurrence — scalar f64 over the 16 lanes,
                    // identical math (and order) to two 8-wide chunks.
                    let mut av: Lane2 = [0.0; LANES2];
                    for k in 0..LANES2 {
                        av[k] = ai as f32;
                        let ga = L::dual_grad(ai, y) * hr - wv[k] as f64 * (xv[k] as f64);
                        let eta_a = step.eta(&mut aa, ga);
                        ai = L::project(ai + eta_a * ga, y) as f32 as f64;
                    }
                    w_side_chunk2::<B, R, S>(step, &lj, &wv, &xv, &iv, &av, lam32, b32, st);
                    base += LANES2;
                    rem -= LANES2;
                }
            }
            while rem > 0 {
                let n = rem.min(LANES);
                // SAFETY: `base + LANES` stays within the group's
                // physical lane region (regions of lane-eligible
                // groups are padded to LANES multiples) and every
                // stored column — sentinels included — is a validated
                // in-stripe index (`check_packed_bounds`).
                let (lj, wv, xv, iv) =
                    unsafe { B::gather_chunk(cols, vals, base, st.w, ctx.inv_col32) };
                // α recurrence — scalar f64 over the real lanes only
                // (all entries of the chunk update the same α_i, so
                // this is inherently serial; the math matches
                // `sweep_group_scalar` exactly, consuming the gathered
                // w·x products — hence bit-identical across backends
                // given the same gathered bits). `av[k]` records α
                // *before* entry k — the value the w gradient of lane
                // k must see.
                let mut av: Lane = [0.0; LANES];
                for k in 0..n {
                    av[k] = ai as f32;
                    let ga = L::dual_grad(ai, y) * hr - wv[k] as f64 * (xv[k] as f64);
                    let eta_a = step.eta(&mut aa, ga);
                    ai = L::project(ai + eta_a * ga, y) as f32 as f64;
                }
                let tail = ai as f32;
                for lane in av.iter_mut().skip(n) {
                    *lane = tail;
                }
                w_side_chunk::<B, R, S>(step, &lj, &wv, &xv, &iv, &av, n, lam32, b32, st);
                base += LANES;
                rem -= n;
            }
        }
        // SAFETY: same in-bounds argument as the row-state load above.
        unsafe {
            *st.alpha.get_unchecked_mut(li) = ai as f32;
            *st.a_acc.get_unchecked_mut(li) = aa;
        }
    }
    block.nnz()
}

// ---------------------------------------------------------------------
// Affine-α SIMD lane kernel (square loss)
// ---------------------------------------------------------------------

/// Sweep every real entry of a lane-major packed block once, in storage
/// order, with the **closed-form affine α recurrence** for losses whose
/// dual gradient is affine in α with an identity projection
/// ([`AffineLossK`] — the square loss). The w side is identical to
/// [`sweep_lanes`]; the α side of each chunk folds via
/// [`StepK::alpha_chunk_affine`] instead of 8 sequential gradient
/// evaluations. Tolerance-equivalent (≤1e-5 relative per sweep,
/// property-tested in `tests/alpha_lane.rs`) to the scalar recurrence,
/// not bit-identical — see the module docs for the exact divergence
/// points.
///
/// Non-affine losses (hinge, logistic) delegate to [`sweep_lanes`] bit
/// for bit, so calling this entry point is always correct; the dispatch
/// plan nevertheless routes only `Loss::affine_alpha()` blocks here to
/// keep the planned kernels explicit. Groups shorter than `LANES` run the
/// scalar group loop (bit-identical to [`sweep_packed`]). Returns
/// #updates (sentinel padding excluded).
pub fn sweep_lanes_affine(block: &PackedBlock, ctx: &PackedCtx, st: &mut PackedState) -> usize {
    sweep_lanes_affine_with::<Portable>(block, ctx, st)
}

/// [`sweep_lanes_affine`] monomorphized over an explicit
/// [`SimdBackend`] — the entry point `SweepPlan` dispatches for
/// square-loss lane blocks. Same backend contract as
/// [`sweep_lanes_with`].
pub fn sweep_lanes_affine_with<B: SimdBackend>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
) -> usize {
    match ctx.rule {
        StepRule::Fixed(eta) => dispatch_lanes_affine::<B, _>(block, ctx, st, FixedStep(eta)),
        StepRule::AdaGrad(eta0) => {
            dispatch_lanes_affine::<B, _>(block, ctx, st, AdaGradStep(eta0))
        }
        StepRule::Adaptive(eta0) => {
            dispatch_lanes_affine::<B, _>(block, ctx, st, AdaptiveStep(eta0))
        }
    }
}

/// Whole-kernel AVX2 compilation units. A `#[target_feature]` function
/// cannot be inlined into a feature-neutral caller, so if the feature
/// boundary sat on each backend op the chunk pipeline would pay an
/// opaque call per gather/∇φ/FMA/η/clamp with `Lane` values spilled
/// between them. Placing the boundary at **sweep granularity** lets
/// everything fuse: feature-neutral callees (the `#[inline(always)]`
/// kernel bodies) inline *into* a target_feature caller, and the
/// backend's same-feature intrinsic wrappers do too — the whole sweep
/// compiles as one avx2+fma function. `SweepPlan` and the benches call
/// these; the generic [`sweep_lanes_with`] stays the differential-test
/// entry point (identical semantics — the intrinsics are explicit, so
/// fusing changes codegen, not results).
///
/// # Safety
/// The running CPU must support avx2+fma — guaranteed by
/// `simd::resolve` (plan construction) or an explicit
/// `simd::avx2_supported()` guard at the call site.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn sweep_lanes_avx2(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
) -> usize {
    sweep_lanes_with::<crate::simd::Avx2>(block, ctx, st)
}

/// [`sweep_lanes_avx2`]'s affine-α twin — see its docs for the
/// fusion rationale.
///
/// # Safety
/// Same contract as [`sweep_lanes_avx2`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn sweep_lanes_affine_avx2(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
) -> usize {
    sweep_lanes_affine_with::<crate::simd::Avx2>(block, ctx, st)
}

/// [`sweep_lanes_avx2`]'s AVX-512 sibling: the paired 16-wide chunk
/// pipeline (512-bit gather/FMA/scatter, 8-wide avx2 epilogue for odd
/// trailing chunks and ragged tails) fused into one
/// avx512f+avx2+fma compilation unit — the same sweep-granularity
/// feature boundary, for the same reason (see [`sweep_lanes_avx2`]).
///
/// # Safety
/// The running CPU must support avx512f+avx2+fma — guaranteed by
/// `simd::resolve` (plan construction) or an explicit
/// `simd::avx512_supported()` guard at the call site.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
pub unsafe fn sweep_lanes_avx512(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
) -> usize {
    sweep_lanes_with::<crate::simd::Avx512>(block, ctx, st)
}

/// [`sweep_lanes_avx512`]'s affine-α twin.
///
/// # Safety
/// Same contract as [`sweep_lanes_avx512`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
pub unsafe fn sweep_lanes_affine_avx512(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
) -> usize {
    sweep_lanes_affine_with::<crate::simd::Avx512>(block, ctx, st)
}

/// Resolve (loss, reg) once per sweep. Only the square loss has an
/// affine dual; hinge/logistic degrade to the plain lane dispatch
/// (their per-entry projection is load-bearing), bitwise identical to
/// calling [`sweep_lanes_with`] directly on the same backend.
fn dispatch_lanes_affine<B: SimdBackend, S: StepK>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
    step: S,
) -> usize {
    match (ctx.loss, ctx.reg) {
        (Loss::Square, Regularizer::L2) => {
            sweep_affine_mono::<B, SquareK, L2K, S>(block, ctx, st, step)
        }
        (Loss::Square, Regularizer::L1) => {
            sweep_affine_mono::<B, SquareK, L1K, S>(block, ctx, st, step)
        }
        _ => dispatch_lanes::<B, S>(block, ctx, st, step),
    }
}

fn sweep_affine_mono<B: SimdBackend, L: AffineLossK, R: RegK, S: StepK>(
    block: &PackedBlock,
    ctx: &PackedCtx,
    st: &mut PackedState,
    step: S,
) -> usize {
    check_packed_bounds(block, ctx, st);
    let b32 = ctx.w_bound as f32;
    let lam32 = ctx.lambda as f32;
    let cols = &block.cols[..];
    let vals = &block.vals[..];
    for g in &block.groups {
        let li = g.li as usize;
        debug_assert!(li < st.alpha.len());
        // SAFETY: g.li < n_rows <= len of every row-stripe table/view
        // (`check_packed_bounds`).
        let (y, hr, mut ai, mut aa) = unsafe {
            (
                *ctx.y.get_unchecked(li),
                *ctx.inv_row.get_unchecked(li),
                *st.alpha.get_unchecked(li) as f64,
                *st.a_acc.get_unchecked(li),
            )
        };
        let len = g.len();
        if len < LANES {
            // Short group: scalar kernel body, bit-identical to
            // `sweep_packed` (exactly as in `sweep_lanes`).
            let s = g.pad_start as usize;
            sweep_group_scalar::<L, R, S>(
                cols,
                vals,
                s..s + len,
                ctx,
                st,
                step,
                y,
                hr,
                &mut ai,
                &mut aa,
            );
        } else {
            // Row-invariant affine pieces, hoisted once per group:
            // g_α at entry k is cv[k] + slope_hr·α with
            // cv[k] = bias·hr − w_k·x_k. The bias·hr factor comes from
            // the `stripe_alpha_bias` precompute; the debug_assert
            // pins the table to the trait definition it caches.
            //
            // SAFETY: li < n_rows <= alpha_bias32.len()
            // (`check_packed_bounds`).
            let bias_hr = unsafe { *ctx.alpha_bias32.get_unchecked(li) };
            debug_assert_eq!(bias_hr, (L::dual_bias(y) * hr) as f32);
            let slope_hr = L::DUAL_SLOPE * hr;
            let mut base = g.pad_start as usize;
            let mut rem = len;
            if B::PAIRED {
                // Fused chunk pairs — same contract and argument as in
                // `sweep_lanes_mono`: both chunks full, 16 distinct
                // columns, gather-before-writeback value-identical to
                // the sequential 8+8 order. The affine coefficients
                // come out 16-wide; the α fold itself is a serial
                // dependency chain either way, so it stays the 8-wide
                // `alpha_chunk_affine` fed by the split halves —
                // bitwise the same recurrence the unpaired loop runs.
                while rem >= LANES2 {
                    // SAFETY: rem >= LANES2 ⇒ the next LANES2 physical
                    // slots are all real entries inside the group's
                    // lane region, columns validated in-stripe
                    // (`check_packed_bounds`).
                    let (lj, wv, xv, iv) =
                        unsafe { B::gather_chunk2(cols, vals, base, st.w, ctx.inv_col32) };
                    let cv = B::affine_coeffs2(bias_hr, &wv, &xv);
                    let (clo, chi) = split_lanes(&cv);
                    let mut alo: Lane = [0.0; LANES];
                    let mut ahi: Lane = [0.0; LANES];
                    ai = step.alpha_chunk_affine(&mut aa, ai, &clo, LANES, slope_hr, &mut alo);
                    ai = step.alpha_chunk_affine(&mut aa, ai, &chi, LANES, slope_hr, &mut ahi);
                    let av = join_lanes(&alo, &ahi);
                    w_side_chunk2::<B, R, S>(step, &lj, &wv, &xv, &iv, &av, lam32, b32, st);
                    base += LANES2;
                    rem -= LANES2;
                }
            }
            while rem > 0 {
                let n = rem.min(LANES);
                // SAFETY: same chunk argument as in `sweep_lanes_mono`
                // — base + LANES within the group's padded lane
                // region, all stored columns validated in-stripe.
                let (lj, wv, xv, iv) =
                    unsafe { B::gather_chunk(cols, vals, base, st.w, ctx.inv_col32) };
                // Per-entry affine coefficients in 8-wide f32 — the
                // α-independent part of g_α. This replaces the
                // sequential dual-gradient evaluations of
                // `sweep_lanes`; the serial remainder is the one-FMA-
                // per-entry fold below.
                let cv = B::affine_coeffs(bias_hr, &wv, &xv);
                // Fold the chunk's composed affine map into α_i. `av`
                // receives each real entry's pre-update α (what its w
                // gradient must see); tail lanes get the post-chunk α
                // (they are sentinels — computed, never stored).
                let mut av: Lane = [0.0; LANES];
                ai = step.alpha_chunk_affine(&mut aa, ai, &cv, n, slope_hr, &mut av);
                let tail = ai as f32;
                for lane in av.iter_mut().skip(n) {
                    *lane = tail;
                }
                w_side_chunk::<B, R, S>(step, &lj, &wv, &xv, &iv, &av, n, lam32, b32, st);
                base += LANES;
                rem -= n;
            }
        }
        // SAFETY: same in-bounds argument as the row-state load above.
        unsafe {
            *st.alpha.get_unchecked_mut(li) = ai as f32;
            *st.a_acc.get_unchecked_mut(li) = aa;
        }
    }
    block.nnz()
}

// ---------------------------------------------------------------------
// Subsampled sweep
// ---------------------------------------------------------------------

/// Subsampled sweep (`cluster.updates_per_block`): process the given
/// *logical* flat entry indices, in order, one update each. Cold path —
/// plain enum dispatch and checked indexing; numerics are identical to
/// [`sweep_packed`] on the same entries. Each sampled entry's row group
/// comes from the `entry_group` side table when the engine built it
/// (`PackedBlocks::with_sampling_tables` — one cold load instead of the
/// old per-sample binary search), falling back to the binary search on
/// lean blocks.
pub fn sweep_packed_sampled(
    block: &PackedBlock,
    idxs: &[u32],
    ctx: &PackedCtx,
    st: &mut PackedState,
) -> usize {
    // No check_packed_bounds here: this path uses checked indexing
    // throughout (it is O(k), and the O(nnz) column scan of the full
    // sweep's validation would defeat the point of subsampling).
    let b = ctx.w_bound;
    for &k in idxs {
        let g = block.groups[block.group_of_cached(k)];
        let kp = (g.pad_start + (k - g.start)) as usize;
        let li = g.li as usize;
        let lj = block.cols[kp] as usize;
        let xm = block.vals[kp] as f64;
        let y = ctx.y[li];
        let hr = ctx.inv_row[li];
        let wj = st.w[lj] as f64;
        let ai = st.alpha[li] as f64;
        let gw = ctx.lambda * ctx.reg.grad(wj) * ctx.inv_col[lj] - ai * xm;
        let ga = ctx.loss.dual_utility_grad(ai, y) * hr - wj * xm;
        let (eta_w, eta_a) = match ctx.rule {
            StepRule::Fixed(eta) => (eta, eta),
            StepRule::AdaGrad(eta0) => (
                AdaGradStep(eta0).eta(&mut st.w_acc[lj], gw),
                AdaGradStep(eta0).eta(&mut st.a_acc[li], ga),
            ),
            StepRule::Adaptive(eta0) => (
                AdaptiveStep(eta0).eta(&mut st.w_acc[lj], gw),
                AdaptiveStep(eta0).eta(&mut st.a_acc[li], ga),
            ),
        };
        st.w[lj] = (wj - eta_w * gw).clamp(-b, b) as f32;
        st.alpha[li] = ctx.loss.project_alpha(ai + eta_a * ga, y) as f32;
    }
    idxs.len()
}

// ---------------------------------------------------------------------
// COO reference path (correctness oracle + old-vs-new benchmark)
// ---------------------------------------------------------------------

/// Sweep every entry once, in storage order. Returns #updates.
/// Reference implementation over global-coordinate COO entries.
pub fn sweep_block(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState) -> usize {
    match ctx.rule {
        StepRule::Fixed(eta) => sweep_fixed(entries, ctx, st, eta),
        StepRule::AdaGrad(eta0) => sweep_adagrad(entries, ctx, st, eta0),
        StepRule::Adaptive(eta0) => sweep_adaptive(entries, ctx, st, eta0),
    }
}

/// The Eq. (8) gradient pair at the current iterate — the checked
/// scalar oracle the packed kernels are validated against.
#[inline]
pub fn gradients(ctx: &SweepCtx, e: &Entry, wj: f64, ai: f64) -> (f64, f64) {
    let x = e.x as f64;
    let y = ctx.y[e.i as usize] as f64;
    let gw = ctx.lambda * ctx.reg.grad(wj) / ctx.col_counts[e.j as usize] as f64
        - ai * x / ctx.m;
    let ga = ctx.loss.dual_utility_grad(ai, y) / (ctx.m * ctx.row_counts[e.i as usize] as f64)
        - wj * x / ctx.m;
    (gw, ga)
}

// The two loops below are kept verbatim from the seed (unchecked
// indexing, inline gradient expressions) so `bench_updates` compares
// the packed kernels against the genuine old hot path, not a slowed
// rewrite. `gradients()` above is the readable form of the same math.

fn sweep_fixed(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState, eta: f64) -> usize {
    let b = ctx.w_bound;
    // Same in-bounds-by-construction argument as `sweep_adagrad`.
    for e in entries {
        let jw = e.j as usize - st.w_off;
        let ia = e.i as usize - st.a_off;
        debug_assert!(jw < st.w.len() && ia < st.alpha.len());
        // SAFETY: entry indices are in-bounds by construction (see the
        // note above `sweep_adagrad`).
        unsafe {
            let wj = *st.w.get_unchecked(jw) as f64;
            let ai = *st.alpha.get_unchecked(ia) as f64;
            let x = e.x as f64;
            let y = *ctx.y.get_unchecked(e.i as usize) as f64;
            let gw = ctx.lambda * ctx.reg.grad(wj)
                / *ctx.col_counts.get_unchecked(e.j as usize) as f64
                - ai * x / ctx.m;
            let ga = ctx.loss.dual_utility_grad(ai, y)
                / (ctx.m * *ctx.row_counts.get_unchecked(e.i as usize) as f64)
                - wj * x / ctx.m;
            *st.w.get_unchecked_mut(jw) = (wj - eta * gw).clamp(-b, b) as f32;
            *st.alpha.get_unchecked_mut(ia) = ctx.loss.project_alpha(ai + eta * ga, y) as f32;
        }
    }
    entries.len()
}

fn sweep_adagrad(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState, eta0: f64) -> usize {
    let b = ctx.w_bound;
    // Entries come from `PackedBlocks`-derived COO lists whose indices
    // are in-bounds by construction (validated by
    // `PackedBlocks::validate` in tests); unchecked indexing removes 8
    // bounds checks per update.
    for e in entries {
        let jw = e.j as usize - st.w_off;
        let ia = e.i as usize - st.a_off;
        debug_assert!(jw < st.w.len() && ia < st.alpha.len());
        // SAFETY: entry indices are in-bounds by construction (see the
        // note above this loop).
        unsafe {
            let wj = *st.w.get_unchecked(jw) as f64;
            let ai = *st.alpha.get_unchecked(ia) as f64;
            let x = e.x as f64;
            let y = *ctx.y.get_unchecked(e.i as usize) as f64;
            let gw = ctx.lambda * ctx.reg.grad(wj)
                / *ctx.col_counts.get_unchecked(e.j as usize) as f64
                - ai * x / ctx.m;
            let ga = ctx.loss.dual_utility_grad(ai, y)
                / (ctx.m * *ctx.row_counts.get_unchecked(e.i as usize) as f64)
                - wj * x / ctx.m;

            let wa = *st.w_acc.get_unchecked(jw) as f64 + gw * gw;
            *st.w_acc.get_unchecked_mut(jw) = wa as f32;
            let eta_w = eta0 / (ADAGRAD_EPS + wa).sqrt();

            let aa = *st.a_acc.get_unchecked(ia) as f64 + ga * ga;
            *st.a_acc.get_unchecked_mut(ia) = aa as f32;
            let eta_a = eta0 / (ADAGRAD_EPS + aa).sqrt();

            *st.w.get_unchecked_mut(jw) = (wj - eta_w * gw).clamp(-b, b) as f32;
            *st.alpha.get_unchecked_mut(ia) =
                ctx.loss.project_alpha(ai + eta_a * ga, y) as f32;
        }
    }
    entries.len()
}

/// [`sweep_adagrad`] with the [`StepRule::Adaptive`] rate
/// η₀/√(1+Σg²): same accumulator discipline, unit offset in place of
/// the ε floor. Reference oracle for `AdaptiveStep`'s packed kernels.
fn sweep_adaptive(entries: &[Entry], ctx: &SweepCtx, st: &mut BlockState, eta0: f64) -> usize {
    let b = ctx.w_bound;
    for e in entries {
        let jw = e.j as usize - st.w_off;
        let ia = e.i as usize - st.a_off;
        debug_assert!(jw < st.w.len() && ia < st.alpha.len());
        // SAFETY: entry indices are in-bounds by construction (see the
        // note above `sweep_adagrad`'s loop).
        unsafe {
            let wj = *st.w.get_unchecked(jw) as f64;
            let ai = *st.alpha.get_unchecked(ia) as f64;
            let x = e.x as f64;
            let y = *ctx.y.get_unchecked(e.i as usize) as f64;
            let gw = ctx.lambda * ctx.reg.grad(wj)
                / *ctx.col_counts.get_unchecked(e.j as usize) as f64
                - ai * x / ctx.m;
            let ga = ctx.loss.dual_utility_grad(ai, y)
                / (ctx.m * *ctx.row_counts.get_unchecked(e.i as usize) as f64)
                - wj * x / ctx.m;

            let wa = *st.w_acc.get_unchecked(jw) as f64 + gw * gw;
            *st.w_acc.get_unchecked_mut(jw) = wa as f32;
            let eta_w = eta0 / (1.0 + wa).sqrt();

            let aa = *st.a_acc.get_unchecked(ia) as f64 + ga * ga;
            *st.a_acc.get_unchecked_mut(ia) = aa as f32;
            let eta_a = eta0 / (1.0 + aa).sqrt();

            *st.w.get_unchecked_mut(jw) = (wj - eta_w * gw).clamp(-b, b) as f32;
            *st.alpha.get_unchecked_mut(ia) =
                ctx.loss.project_alpha(ai + eta_a * ga, y) as f32;
        }
    }
    entries.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::{Loss, Regularizer};
    use crate::partition::omega::RowGroup;

    fn ctx<'a>(
        row_counts: &'a [u32],
        col_counts: &'a [u32],
        y: &'a [f32],
        rule: StepRule,
    ) -> SweepCtx<'a> {
        SweepCtx {
            loss: Loss::Hinge,
            reg: Regularizer::L2,
            lambda: 0.1,
            m: y.len() as f64,
            row_counts,
            col_counts,
            y,
            w_bound: Loss::Hinge.w_bound(0.1),
            rule,
        }
    }

    /// Everything `PackedCtx` borrows, hand-packed from the reference
    /// inputs (m = y.len()); entries must be (i, j)-sorted. The
    /// gather-table mirror uses `AVec` like the production build (the
    /// kernels debug-assert its 64-byte alignment).
    struct Packed {
        b: PackedBlock,
        inv_col: Vec<f64>,
        inv_col32: crate::simd::AVec<f32>,
        inv_row: Vec<f64>,
        y: Vec<f64>,
        alpha_bias32: Vec<f32>,
    }

    fn pack(entries: &[Entry], row_counts: &[u32], col_counts: &[u32], y: &[f32]) -> Packed {
        let m = y.len() as f64;
        let mut b = PackedBlock {
            n_rows: row_counts.len() as u32,
            n_cols: col_counts.len() as u32,
            ..PackedBlock::default()
        };
        for e in entries {
            let pos = b.cols.len() as u32;
            if matches!(b.groups.last(), Some(g) if g.li == e.i) {
                b.groups.last_mut().unwrap().end = pos + 1;
            } else {
                b.groups.push(RowGroup { li: e.i, start: pos, end: pos + 1, pad_start: 0 });
            }
            b.cols.push(e.j);
            b.vals.push((e.x as f64 / m) as f32);
        }
        b.finalize_lanes();
        b.build_entry_group(); // exercise the sampled path's side table
        let inv_col: Vec<f64> = col_counts.iter().map(|&c| 1.0 / c as f64).collect();
        let inv_col32: crate::simd::AVec<f32> = inv_col.iter().map(|&v| v as f32).collect();
        let inv_row: Vec<f64> = row_counts.iter().map(|&c| 1.0 / (m * c as f64)).collect();
        let yl: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        // Same definition as `PackedBlocks::stripe_alpha_bias`.
        let alpha_bias32: Vec<f32> =
            inv_row.iter().zip(y).map(|(&hr, &yv)| (yv as f64 * hr) as f32).collect();
        Packed { b, inv_col, inv_col32, inv_row, y: yl, alpha_bias32 }
    }

    fn packed_ctx<'a>(c: &SweepCtx, p: &'a Packed) -> PackedCtx<'a> {
        PackedCtx {
            loss: c.loss,
            reg: c.reg,
            lambda: c.lambda,
            w_bound: c.w_bound,
            rule: c.rule,
            inv_col: &p.inv_col,
            inv_col32: &p.inv_col32,
            inv_row: &p.inv_row,
            y: &p.y,
            alpha_bias32: &p.alpha_bias32,
        }
    }

    #[test]
    fn single_update_matches_hand_computation() {
        let row_counts = [2u32, 1];
        let col_counts = [1u32, 2];
        let y = [1.0f32, -1.0];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(0.5));
        let entries = [Entry { i: 0, j: 1, x: 2.0 }];
        let mut w = [0.5f32];
        let mut wacc = [0f32];
        let mut alpha = [0.25f32];
        let mut aacc = [0f32];
        let mut st = BlockState {
            w: &mut w,
            w_acc: &mut wacc,
            w_off: 1,
            alpha: &mut alpha,
            a_acc: &mut aacc,
            a_off: 0,
        };
        let n = sweep_block(&entries, &c, &mut st);
        assert_eq!(n, 1);
        // m = 2, |Ω̄_1| = 2, |Ω_0| = 2.
        // g_w = 0.1 * 2*0.5 / 2 − 0.25*2/2 = 0.05 − 0.25 = −0.2
        // w   = 0.5 − 0.5*(−0.2) = 0.6
        assert!((w[0] - 0.6).abs() < 1e-6, "w {}", w[0]);
        // h'(α, y=1) = 1 (hinge). g_α = 1/(2·2) − 0.5·2/2 = 0.25 − 0.5 = −0.25
        // α = 0.25 + 0.5·(−0.25) = 0.125
        assert!((alpha[0] - 0.125).abs() < 1e-6, "α {}", alpha[0]);
    }

    #[test]
    fn packed_single_update_matches_hand_computation() {
        // Same problem as `single_update_matches_hand_computation`, in
        // block-local coordinates: one entry (li=0, lj=0, x=2, m=2), so
        // x/m = 1 is exact and the packed result is exactly 0.6/0.125.
        let row_counts = [2u32];
        let col_counts = [2u32];
        let y = [1.0f32, -1.0];
        let entries = [Entry { i: 0, j: 0, x: 2.0 }];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(0.5));
        let pc = packed_ctx(&c, &p);
        let mut w = [0.5f32];
        let mut wacc = [0f32];
        let mut alpha = [0.25f32];
        let mut aacc = [0f32];
        let mut st = PackedState {
            w: &mut w,
            w_acc: &mut wacc,
            alpha: &mut alpha,
            a_acc: &mut aacc,
        };
        let n = sweep_packed(&p.b, &pc, &mut st);
        assert_eq!(n, 1);
        assert!((w[0] - 0.6).abs() < 1e-6, "w {}", w[0]);
        assert!((alpha[0] - 0.125).abs() < 1e-6, "α {}", alpha[0]);
    }

    #[test]
    fn lanes_single_update_matches_hand_computation() {
        // A single-entry group is below LANES, so `sweep_lanes` takes
        // the scalar fallback and must reproduce the exact values.
        let row_counts = [2u32];
        let col_counts = [2u32];
        let y = [1.0f32, -1.0];
        let entries = [Entry { i: 0, j: 0, x: 2.0 }];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(0.5));
        let pc = packed_ctx(&c, &p);
        let mut w = [0.5f32];
        let mut wacc = [0f32];
        let mut alpha = [0.25f32];
        let mut aacc = [0f32];
        let mut st = PackedState {
            w: &mut w,
            w_acc: &mut wacc,
            alpha: &mut alpha,
            a_acc: &mut aacc,
        };
        let n = sweep_lanes(&p.b, &pc, &mut st);
        assert_eq!(n, 1);
        assert!((w[0] - 0.6).abs() < 1e-6, "w {}", w[0]);
        assert!((alpha[0] - 0.125).abs() < 1e-6, "α {}", alpha[0]);
    }

    /// Packed vs reference on a small multi-row block, every loss ×
    /// reg × rule: agreement within 1e-5 relative error over repeated
    /// sweeps.
    #[test]
    fn packed_matches_reference_all_combinations() {
        let row_counts = [2u32, 2, 1];
        let col_counts = [2u32, 2, 1];
        let y = [1.0f32, -1.0, 1.0];
        let entries = [
            Entry { i: 0, j: 0, x: 1.5 },
            Entry { i: 0, j: 2, x: -0.5 },
            Entry { i: 1, j: 0, x: 0.7 },
            Entry { i: 1, j: 1, x: 2.0 },
            Entry { i: 2, j: 1, x: -1.2 },
        ];
        for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
            for reg in [Regularizer::L2, Regularizer::L1] {
                for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3), StepRule::Adaptive(0.3)] {
                    let mut c = ctx(&row_counts, &col_counts, &y, rule);
                    c.loss = loss;
                    c.reg = reg;
                    c.m = 3.0;
                    c.w_bound = loss.w_bound(c.lambda);
                    let p = pack(&entries, &row_counts, &col_counts, &y);
                    let pc = packed_ctx(&c, &p);

                    let mut rw = [0.2f32, -0.1, 0.05];
                    let mut rwa = [0f32; 3];
                    let mut ra: Vec<f32> = y
                        .iter()
                        .map(|&v| loss.alpha_init(v as f64) as f32)
                        .collect();
                    let mut raa = [0f32; 3];
                    let mut pw = rw;
                    let mut pwa = rwa;
                    let mut pa = ra.clone();
                    let mut paa = raa;

                    for _ in 0..5 {
                        let mut rst = BlockState {
                            w: &mut rw,
                            w_acc: &mut rwa,
                            w_off: 0,
                            alpha: &mut ra,
                            a_acc: &mut raa,
                            a_off: 0,
                        };
                        sweep_block(&entries, &c, &mut rst);
                        let mut pst = PackedState {
                            w: &mut pw,
                            w_acc: &mut pwa,
                            alpha: &mut pa,
                            a_acc: &mut paa,
                        };
                        sweep_packed(&p.b, &pc, &mut pst);
                    }
                    for k in 0..3 {
                        let dw = (rw[k] - pw[k]).abs() as f64;
                        let da = (ra[k] - pa[k]).abs() as f64;
                        assert!(
                            dw <= 1e-5 * rw[k].abs().max(1.0) as f64,
                            "{loss:?}/{reg:?}/{rule:?} w[{k}]: {} vs {}",
                            rw[k],
                            pw[k]
                        );
                        assert!(
                            da <= 1e-5 * ra[k].abs().max(1.0) as f64,
                            "{loss:?}/{reg:?}/{rule:?} α[{k}]: {} vs {}",
                            ra[k],
                            pa[k]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lanes_bitwise_equal_packed_when_all_groups_short() {
        // Every group below LANES ⇒ sweep_lanes is the scalar kernel,
        // bit for bit, including accumulators.
        let row_counts = [2u32, 2];
        let col_counts = [2u32, 2];
        let y = [1.0f32, -1.0];
        let entries = [
            Entry { i: 0, j: 0, x: 1.0 },
            Entry { i: 0, j: 1, x: 0.5 },
            Entry { i: 1, j: 0, x: -1.0 },
            Entry { i: 1, j: 1, x: 2.0 },
        ];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        assert!(!p.b.has_lanes());
        for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3), StepRule::Adaptive(0.3)] {
            let c = ctx(&row_counts, &col_counts, &y, rule);
            let pc = packed_ctx(&c, &p);
            let run = |lanes: bool| {
                let mut w = [0.1f32, -0.2];
                let mut wa = [0f32; 2];
                let mut a = [0.05f32, -0.3];
                let mut aa = [0f32; 2];
                for _ in 0..4 {
                    let mut st = PackedState {
                        w: &mut w,
                        w_acc: &mut wa,
                        alpha: &mut a,
                        a_acc: &mut aa,
                    };
                    if lanes {
                        sweep_lanes(&p.b, &pc, &mut st);
                    } else {
                        sweep_packed(&p.b, &pc, &mut st);
                    }
                }
                (w, a, wa, aa)
            };
            assert_eq!(run(true), run(false), "{rule:?}");
        }
    }

    #[test]
    fn lanes_long_group_matches_packed_within_tolerance() {
        // One 12-entry row group: 1 full chunk + a sentinel-padded
        // ragged tail. The lane kernel computes the w side in f32, so
        // agreement with the scalar kernel is tolerance-level.
        let row_counts = [12u32];
        let col_counts = [2u32; 12];
        let y = [1.0f32];
        let entries: Vec<Entry> = (0..12)
            .map(|j| Entry { i: 0, j, x: 0.5 + 0.25 * j as f32 })
            .collect();
        let p = pack(&entries, &row_counts, &col_counts, &y);
        assert!(p.b.has_lanes());
        assert_eq!(p.b.padded_nnz(), 16);
        for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
            for reg in [Regularizer::L2, Regularizer::L1] {
                for rule in [StepRule::Fixed(0.2), StepRule::AdaGrad(0.2), StepRule::Adaptive(0.2)] {
                    let mut c = ctx(&row_counts, &col_counts, &y, rule);
                    c.loss = loss;
                    c.reg = reg;
                    c.m = 1.0;
                    c.w_bound = loss.w_bound(c.lambda);
                    let pc = packed_ctx(&c, &p);
                    let run = |lanes: bool| {
                        let mut w = [0.01f32; 12];
                        let mut wa = [0f32; 12];
                        let mut a = [loss.alpha_init(1.0) as f32];
                        let mut aa = [0f32];
                        let mut st = PackedState {
                            w: &mut w,
                            w_acc: &mut wa,
                            alpha: &mut a,
                            a_acc: &mut aa,
                        };
                        if lanes {
                            sweep_lanes(&p.b, &pc, &mut st);
                        } else {
                            sweep_packed(&p.b, &pc, &mut st);
                        }
                        (w, a)
                    };
                    let (lw, la) = run(true);
                    let (sw, sa) = run(false);
                    for k in 0..12 {
                        let rel =
                            (lw[k] - sw[k]).abs() as f64 / (sw[k].abs() as f64).max(1e-3);
                        assert!(
                            rel <= 1e-5,
                            "{loss:?}/{reg:?}/{rule:?} w[{k}]: {} vs {}",
                            lw[k],
                            sw[k]
                        );
                    }
                    let rel = (la[0] - sa[0]).abs() as f64 / (sa[0].abs() as f64).max(1e-3);
                    assert!(rel <= 1e-5, "{loss:?}/{reg:?}/{rule:?} α: {} vs {}", la[0], sa[0]);
                }
            }
        }
    }

    #[test]
    fn packed_sampled_matches_full_on_all_indices() {
        // Sampling every index once, in order, must equal a full sweep.
        let row_counts = [2u32, 2];
        let col_counts = [2u32, 2];
        let y = [1.0f32, -1.0];
        let entries = [
            Entry { i: 0, j: 0, x: 1.0 },
            Entry { i: 0, j: 1, x: 0.5 },
            Entry { i: 1, j: 0, x: -1.0 },
            Entry { i: 1, j: 1, x: 2.0 },
        ];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.2));
        let pc = packed_ctx(&c, &p);
        let run_full = || {
            let mut w = [0.1f32, -0.2];
            let mut wa = [0f32; 2];
            let mut a = [0.05f32, -0.3];
            let mut aa = [0f32; 2];
            let mut st =
                PackedState { w: &mut w, w_acc: &mut wa, alpha: &mut a, a_acc: &mut aa };
            sweep_packed(&p.b, &pc, &mut st);
            (w, a, wa, aa)
        };
        let run_sampled = || {
            let mut w = [0.1f32, -0.2];
            let mut wa = [0f32; 2];
            let mut a = [0.05f32, -0.3];
            let mut aa = [0f32; 2];
            let mut st =
                PackedState { w: &mut w, w_acc: &mut wa, alpha: &mut a, a_acc: &mut aa };
            sweep_packed_sampled(&p.b, &[0, 1, 2, 3], &pc, &mut st);
            (w, a, wa, aa)
        };
        assert_eq!(run_full(), run_sampled());
    }

    #[test]
    fn packed_sampled_resolves_entries_across_padding() {
        // A lane-padded block: logical indices past the first group
        // must land on the right physical slots (side-table mapping),
        // and sampling all of them in order must equal the full scalar
        // sweep (both paths skip sentinels entirely).
        let row_counts = [9u32, 2];
        let col_counts = [2u32; 9];
        let y = [1.0f32, -1.0];
        let mut entries: Vec<Entry> =
            (0..9).map(|j| Entry { i: 0, j, x: 1.0 + j as f32 }).collect();
        entries.push(Entry { i: 1, j: 3, x: -2.0 });
        entries.push(Entry { i: 1, j: 6, x: 4.0 });
        let p = pack(&entries, &row_counts, &col_counts, &y);
        assert!(p.b.has_lanes());
        assert_eq!(p.b.nnz(), 11);
        assert_eq!(p.b.padded_nnz(), 16 + 2);
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.2));
        let pc = packed_ctx(&c, &p);
        let idxs: Vec<u32> = (0..11).collect();
        let run = |sampled: bool| {
            let mut w = [0.1f32; 9];
            let mut wa = [0f32; 9];
            let mut a = [0.05f32, -0.3];
            let mut aa = [0f32; 2];
            let mut st =
                PackedState { w: &mut w, w_acc: &mut wa, alpha: &mut a, a_acc: &mut aa };
            if sampled {
                sweep_packed_sampled(&p.b, &idxs, &pc, &mut st);
            } else {
                sweep_packed(&p.b, &pc, &mut st);
            }
            (w, a, wa, aa)
        };
        assert_eq!(run(true), run(false));
        // Lean block (no side table): the binary-search fallback of the
        // sampled path must be bitwise identical.
        let mut lean = p.b.clone();
        lean.entry_group.clear();
        let run_lean = || {
            let mut w = [0.1f32; 9];
            let mut wa = [0f32; 9];
            let mut a = [0.05f32, -0.3];
            let mut aa = [0f32; 2];
            let mut st =
                PackedState { w: &mut w, w_acc: &mut wa, alpha: &mut a, a_acc: &mut aa };
            sweep_packed_sampled(&lean, &idxs, &pc, &mut st);
            (w, a, wa, aa)
        };
        assert_eq!(run_lean(), run(false));
    }

    #[test]
    fn packed_disjoint_entries_commute() {
        // Updates on (i,j) and (i',j') with i≠i', j≠j' must commute
        // exactly — the key observation of Section 3, on the packed
        // path (exercised via the sampled variant to control order).
        let row_counts = [1u32, 1];
        let col_counts = [1u32, 1];
        let y = [1.0f32, -1.0];
        let entries = [
            Entry { i: 0, j: 0, x: 1.5 },
            Entry { i: 1, j: 1, x: -0.5 },
        ];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.2));
        let pc = packed_ctx(&c, &p);
        let run = |order: [u32; 2]| {
            let mut w = [0.1f32, -0.2];
            let mut wa = [0f32; 2];
            let mut a = [0.05f32, -0.3];
            let mut aa = [0f32; 2];
            let mut st =
                PackedState { w: &mut w, w_acc: &mut wa, alpha: &mut a, a_acc: &mut aa };
            sweep_packed_sampled(&p.b, &order, &pc, &mut st);
            (w, a, wa, aa)
        };
        assert_eq!(run([0, 1]), run([1, 0]));
    }

    #[test]
    fn projection_keeps_iterates_in_boxes() {
        let row_counts = [1u32];
        let col_counts = [1u32];
        let y = [1.0f32];
        // Huge step to force projection.
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(1e4));
        let entries = [Entry { i: 0, j: 0, x: 1.0 }];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        let pc = packed_ctx(&c, &p);
        let mut w = [0f32];
        let mut wacc = [0f32];
        let mut alpha = [0f32];
        let mut aacc = [0f32];
        for _ in 0..20 {
            let mut st = PackedState {
                w: &mut w,
                w_acc: &mut wacc,
                alpha: &mut alpha,
                a_acc: &mut aacc,
            };
            sweep_packed(&p.b, &pc, &mut st);
            let bb = c.w_bound as f32;
            assert!((-bb..=bb).contains(&w[0]), "w {}", w[0]);
            let beta = y[0] * alpha[0];
            assert!((0.0..=1.0).contains(&beta), "β {beta}");
        }
    }

    #[test]
    fn lanes_projection_keeps_iterates_in_boxes() {
        // Same invariant on the lane path, over a lane-eligible group.
        // The lane clamp runs in f32, so allow one ulp of slack at the
        // box boundary.
        let row_counts = [10u32];
        let col_counts = [1u32; 10];
        let y = [1.0f32];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(1e4));
        let entries: Vec<Entry> =
            (0..10).map(|j| Entry { i: 0, j, x: 1.0 + j as f32 }).collect();
        let p = pack(&entries, &row_counts, &col_counts, &y);
        assert!(p.b.has_lanes());
        let pc = packed_ctx(&c, &p);
        let mut w = [0f32; 10];
        let mut wacc = [0f32; 10];
        let mut alpha = [0f32];
        let mut aacc = [0f32];
        for _ in 0..20 {
            let mut st = PackedState {
                w: &mut w,
                w_acc: &mut wacc,
                alpha: &mut alpha,
                a_acc: &mut aacc,
            };
            sweep_lanes(&p.b, &pc, &mut st);
            let bb = c.w_bound as f32 * (1.0 + f32::EPSILON);
            assert!(w.iter().all(|&x| (-bb..=bb).contains(&x)), "w {w:?}");
            let beta = y[0] * alpha[0];
            assert!((0.0..=1.0).contains(&beta), "β {beta}");
        }
    }

    #[test]
    fn adagrad_accumulators_grow_monotonically() {
        let row_counts = [1u32];
        let col_counts = [1u32];
        let y = [1.0f32];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.1));
        let entries = [Entry { i: 0, j: 0, x: 1.0 }];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        let pc = packed_ctx(&c, &p);
        let mut w = [0.3f32];
        let mut wacc = [0f32];
        let mut alpha = [0.1f32];
        let mut aacc = [0f32];
        let mut prev_w = 0.0;
        let mut prev_a = 0.0;
        for _ in 0..10 {
            let mut st = PackedState {
                w: &mut w,
                w_acc: &mut wacc,
                alpha: &mut alpha,
                a_acc: &mut aacc,
            };
            sweep_packed(&p.b, &pc, &mut st);
            assert!(wacc[0] >= prev_w);
            assert!(aacc[0] >= prev_a);
            prev_w = wacc[0];
            prev_a = aacc[0];
        }
        assert!(prev_w > 0.0);
        assert!(prev_a > 0.0);
    }

    #[test]
    fn fixed_step_deterministic() {
        let row_counts = [2u32, 2];
        let col_counts = [2u32, 2];
        let y = [1.0f32, -1.0];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(0.1));
        let entries = [
            Entry { i: 0, j: 0, x: 1.0 },
            Entry { i: 0, j: 1, x: 0.5 },
            Entry { i: 1, j: 0, x: -1.0 },
            Entry { i: 1, j: 1, x: 2.0 },
        ];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        let pc = packed_ctx(&c, &p);
        let run = || {
            let mut w = [0f32; 2];
            let mut wacc = [0f32; 2];
            let mut alpha = [0f32; 2];
            let mut aacc = [0f32; 2];
            for _ in 0..5 {
                let mut st = PackedState {
                    w: &mut w,
                    w_acc: &mut wacc,
                    alpha: &mut alpha,
                    a_acc: &mut aacc,
                };
                sweep_packed(&p.b, &pc, &mut st);
            }
            (w, alpha)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn lanes_deterministic() {
        let row_counts = [9u32];
        let col_counts = [2u32; 9];
        let y = [1.0f32];
        let c = ctx(&row_counts, &col_counts, &y, StepRule::AdaGrad(0.1));
        let entries: Vec<Entry> =
            (0..9).map(|j| Entry { i: 0, j, x: 0.5 * (j + 1) as f32 }).collect();
        let p = pack(&entries, &row_counts, &col_counts, &y);
        let pc = packed_ctx(&c, &p);
        let run = || {
            let mut w = [0f32; 9];
            let mut wacc = [0f32; 9];
            let mut alpha = [0f32];
            let mut aacc = [0f32];
            for _ in 0..5 {
                let mut st = PackedState {
                    w: &mut w,
                    w_acc: &mut wacc,
                    alpha: &mut alpha,
                    a_acc: &mut aacc,
                };
                sweep_lanes(&p.b, &pc, &mut st);
            }
            (w, alpha, wacc, aacc)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn square_loss_alpha_unconstrained() {
        let row_counts = [1u32];
        let col_counts = [1u32];
        let y = [3.0f32];
        let mut c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(1.0));
        c.loss = Loss::Square;
        let entries = [Entry { i: 0, j: 0, x: 1.0 }];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        let pc = packed_ctx(&c, &p);
        let mut w = [0f32];
        let mut wacc = [0f32];
        let mut alpha = [0f32];
        let mut aacc = [0f32];
        let mut st = PackedState {
            w: &mut w,
            w_acc: &mut wacc,
            alpha: &mut alpha,
            a_acc: &mut aacc,
        };
        sweep_packed(&p.b, &pc, &mut st);
        // g_α = (y − α)/m − wx/m = 3/1 − 0 = 3 → α = 3 (no clamp).
        assert!((alpha[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn affine_falls_back_bitwise_on_short_groups_and_nonaffine_losses() {
        // Two fallback contracts of `sweep_lanes_affine`: (a) on a
        // block with only short groups it *is* the scalar kernel for
        // any loss; (b) on lane-eligible blocks with a non-affine loss
        // it *is* `sweep_lanes`. Both bitwise, full state.
        let run = |kernel: fn(&PackedBlock, &PackedCtx, &mut PackedState) -> usize,
                   blk: &PackedBlock,
                   pc: &PackedCtx,
                   nw: usize,
                   na: usize| {
            let mut w = vec![0.1f32; nw];
            let mut wa = vec![0f32; nw];
            let mut a = vec![0.05f32; na];
            let mut aa = vec![0f32; na];
            for _ in 0..3 {
                let mut st = PackedState {
                    w: &mut w,
                    w_acc: &mut wa,
                    alpha: &mut a,
                    a_acc: &mut aa,
                };
                kernel(blk, pc, &mut st);
            }
            (w, a, wa, aa)
        };
        // (a) short groups, square loss.
        let row_counts = [2u32, 2];
        let col_counts = [2u32, 2];
        let y = [1.0f32, -1.0];
        let entries = [
            Entry { i: 0, j: 0, x: 1.0 },
            Entry { i: 0, j: 1, x: 0.5 },
            Entry { i: 1, j: 0, x: -1.0 },
            Entry { i: 1, j: 1, x: 2.0 },
        ];
        let p = pack(&entries, &row_counts, &col_counts, &y);
        assert!(!p.b.has_lanes());
        for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3), StepRule::Adaptive(0.3)] {
            let mut c = ctx(&row_counts, &col_counts, &y, rule);
            c.loss = Loss::Square;
            let pc = packed_ctx(&c, &p);
            assert_eq!(
                run(sweep_lanes_affine, &p.b, &pc, 2, 2),
                run(sweep_packed, &p.b, &pc, 2, 2),
                "short-group square {rule:?}"
            );
        }
        // (b) lane-eligible block, hinge + logistic.
        let row_counts = [12u32];
        let col_counts = [2u32; 12];
        let y = [1.0f32];
        let entries: Vec<Entry> =
            (0..12).map(|j| Entry { i: 0, j, x: 0.5 + 0.25 * j as f32 }).collect();
        let p = pack(&entries, &row_counts, &col_counts, &y);
        assert!(p.b.has_lanes());
        for loss in [Loss::Hinge, Loss::Logistic] {
            for rule in [StepRule::Fixed(0.3), StepRule::AdaGrad(0.3), StepRule::Adaptive(0.3)] {
                let mut c = ctx(&row_counts, &col_counts, &y, rule);
                c.loss = loss;
                let pc = packed_ctx(&c, &p);
                assert_eq!(
                    run(sweep_lanes_affine, &p.b, &pc, 12, 1),
                    run(sweep_lanes, &p.b, &pc, 12, 1),
                    "lane-block {loss:?} {rule:?}"
                );
            }
        }
    }

    #[test]
    fn affine_long_group_matches_scalar_within_tolerance() {
        // Square loss on a 12-entry row group (1 full chunk + ragged
        // tail): the affine fold must agree with the scalar recurrence
        // to ≤1e-5 relative per sweep, both reg and both step rules.
        let row_counts = [12u32];
        let col_counts = [2u32; 12];
        let y = [2.0f32];
        let entries: Vec<Entry> =
            (0..12).map(|j| Entry { i: 0, j, x: 0.5 + 0.25 * j as f32 }).collect();
        let p = pack(&entries, &row_counts, &col_counts, &y);
        assert!(p.b.has_lanes());
        for reg in [Regularizer::L2, Regularizer::L1] {
            for rule in [StepRule::Fixed(0.2), StepRule::AdaGrad(0.2), StepRule::Adaptive(0.2)] {
                let mut c = ctx(&row_counts, &col_counts, &y, rule);
                c.loss = Loss::Square;
                c.reg = reg;
                c.m = 1.0;
                c.w_bound = Loss::Square.w_bound(c.lambda);
                let pc = packed_ctx(&c, &p);
                let run = |affine: bool| {
                    let mut w = [0.01f32; 12];
                    let mut wa = [0f32; 12];
                    let mut a = [0f32];
                    let mut aa = [0f32];
                    let mut st = PackedState {
                        w: &mut w,
                        w_acc: &mut wa,
                        alpha: &mut a,
                        a_acc: &mut aa,
                    };
                    if affine {
                        sweep_lanes_affine(&p.b, &pc, &mut st);
                    } else {
                        sweep_packed(&p.b, &pc, &mut st);
                    }
                    (w, a)
                };
                let (aw, aa_) = run(true);
                let (sw, sa) = run(false);
                for k in 0..12 {
                    let rel = (aw[k] - sw[k]).abs() as f64 / (sw[k].abs() as f64).max(1e-3);
                    assert!(rel <= 1e-5, "{reg:?}/{rule:?} w[{k}]: {} vs {}", aw[k], sw[k]);
                }
                let rel = (aa_[0] - sa[0]).abs() as f64 / (sa[0].abs() as f64).max(1e-3);
                assert!(rel <= 1e-5, "{reg:?}/{rule:?} α: {} vs {}", aa_[0], sa[0]);
            }
        }
    }

    #[test]
    fn affine_fixed_fold_composes_the_expected_map() {
        // Validate the closed form itself: one full chunk of the square
        // loss under a fixed step, against an independent f64 replay of
        // α ← (1 − η·hr)·α + η·(y·hr − w_k·x_k) (w side frozen at the
        // chunk's gathered values, exactly like the kernel).
        let row_counts = [8u32];
        let col_counts = [1u32; 8];
        let y = [1.5f32];
        let entries: Vec<Entry> =
            (0..8).map(|j| Entry { i: 0, j, x: 1.0 + 0.5 * j as f32 }).collect();
        let p = pack(&entries, &row_counts, &col_counts, &y);
        assert!(p.b.has_lanes());
        let eta = 0.3;
        let mut c = ctx(&row_counts, &col_counts, &y, StepRule::Fixed(eta));
        c.loss = Loss::Square;
        c.m = 1.0;
        c.w_bound = Loss::Square.w_bound(c.lambda);
        let pc = packed_ctx(&c, &p);
        let w0 = 0.02f32;
        let a0 = 0.4f32;
        let mut w = [w0; 8];
        let mut wa = [0f32; 8];
        let mut a = [a0];
        let mut aa = [0f32];
        let mut st =
            PackedState { w: &mut w, w_acc: &mut wa, alpha: &mut a, a_acc: &mut aa };
        sweep_lanes_affine(&p.b, &pc, &mut st);
        // Independent replay (hr = 1/(m·|Ω_0|) = 1/8).
        let hr = 1.0 / 8.0;
        let acoef = 1.0 - eta * hr;
        let mut ai = a0 as f64;
        for k in 0..8 {
            let xm = p.b.vals[k] as f64; // x/m as stored
            let b = eta * ((y[0] as f64 * hr) as f32 as f64 - (w0 as f64 * xm) as f32 as f64);
            ai = acoef * ai + b;
        }
        assert!(
            (a[0] as f64 - ai).abs() <= 1e-6 * ai.abs().max(1.0),
            "α fold {} vs replay {ai}",
            a[0]
        );
    }

    /// A `PAIRED` backend whose every op is `Portable`'s, with the
    /// pair ops inherited from the trait's composed defaults. Driving
    /// the sweeps through it exercises the 16-wide loop structure
    /// (pairing condition, α recurrence over 16, full-width scatter)
    /// with arithmetic that is definitionally two 8-wide chunks —
    /// so sweeps must be **bitwise** identical to plain `Portable`,
    /// on any host. This is the architecture-independent pin of the
    /// pair plumbing that the runtime-guarded AVX-512 suites then
    /// instantiate with real 512-bit ops.
    #[derive(Clone, Copy, Debug, Default)]
    struct PairedPortable;

    // SAFETY: every op delegates to `Portable` (safe scalar lane
    // loops — no CPU-feature contract) and the pair defaults compose
    // those same ops; `PAIRED` changes which sweep loop runs, never
    // what any op requires.
    unsafe impl SimdBackend for PairedPortable {
        const NAME: &'static str = "paired-portable";
        const PAIRED: bool = true;

        #[inline(always)]
        unsafe fn gather_chunk(
            cols: &[u32],
            vals: &[f32],
            base: usize,
            w: &[f32],
            inv: &[f32],
        ) -> ([usize; LANES], Lane, Lane, Lane) {
            // SAFETY: forwarded contract.
            unsafe { Portable::gather_chunk(cols, vals, base, w, inv) }
        }

        #[inline(always)]
        unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane {
            // SAFETY: forwarded contract.
            unsafe { Portable::gather_idx(src, lj) }
        }

        #[inline(always)]
        fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane {
            Portable::w_grad(lam, rv, iv, av, xv)
        }

        #[inline(always)]
        fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane {
            Portable::w_step_clamp(wv, etav, gw, b)
        }

        #[inline(always)]
        fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane {
            Portable::affine_coeffs(bias, wv, xv)
        }

        #[inline(always)]
        fn l1_grad_lane(w: &Lane) -> Lane {
            Portable::l1_grad_lane(w)
        }

        #[inline(always)]
        fn l2_grad_lane(w: &Lane) -> Lane {
            Portable::l2_grad_lane(w)
        }

        #[inline(always)]
        fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane {
            Portable::adagrad_eta_lane(e0, eps, acc, g)
        }

        #[inline(always)]
        unsafe fn predict_fold_chunk(
            cols: &[u32],
            vals: &[f32],
            base: usize,
            n: usize,
            w: &[f32],
            acc: &mut f64,
        ) {
            // SAFETY: forwarded contract.
            unsafe { Portable::predict_fold_chunk(cols, vals, base, n, w, acc) }
        }
    }

    /// Blocks that exercise every pair-loop boundary: a 20-entry group
    /// (1 pair + ragged 4-entry tail), a 24-entry group (1 pair + 1
    /// full odd chunk — the epilogue that pairing cannot absorb), and
    /// a short group (scalar fallback).
    fn pair_boundary_block() -> (Packed, [u32; 3], Vec<u32>, [f32; 3]) {
        let row_counts = [20u32, 24, 2];
        let col_counts = vec![3u32; 24];
        let y = [1.0f32, -1.0, 1.0];
        let mut entries: Vec<Entry> = Vec::new();
        for j in 0..20 {
            entries.push(Entry { i: 0, j, x: 0.3 + 0.11 * j as f32 });
        }
        for j in 0..24 {
            entries.push(Entry { i: 1, j, x: -0.8 + 0.07 * j as f32 });
        }
        entries.push(Entry { i: 2, j: 5, x: 1.4 });
        entries.push(Entry { i: 2, j: 11, x: -0.6 });
        let p = pack(&entries, &row_counts, &col_counts, &y);
        assert!(p.b.has_lanes());
        (p, row_counts, col_counts, y)
    }

    #[test]
    fn paired_portable_sweeps_bitwise_equal_portable() {
        let (p, row_counts, col_counts, y) = pair_boundary_block();
        for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
            for reg in [Regularizer::L2, Regularizer::L1] {
                for rule in
                    [StepRule::Fixed(0.25), StepRule::AdaGrad(0.25), StepRule::Adaptive(0.25)]
                {
                    let mut c = ctx(&row_counts, &col_counts, &y, rule);
                    c.loss = loss;
                    c.reg = reg;
                    c.m = 3.0;
                    c.w_bound = loss.w_bound(c.lambda);
                    let pc = packed_ctx(&c, &p);
                    let run = |paired: bool, affine: bool| {
                        let mut w = vec![0.02f32; 24];
                        let mut wa = vec![0f32; 24];
                        let mut a: Vec<f32> =
                            y.iter().map(|&v| loss.alpha_init(v as f64) as f32).collect();
                        let mut aa = vec![0f32; 3];
                        for _ in 0..3 {
                            let mut st = PackedState {
                                w: &mut w,
                                w_acc: &mut wa,
                                alpha: &mut a,
                                a_acc: &mut aa,
                            };
                            match (paired, affine) {
                                (true, false) => {
                                    sweep_lanes_with::<PairedPortable>(&p.b, &pc, &mut st)
                                }
                                (false, false) => sweep_lanes(&p.b, &pc, &mut st),
                                (true, true) => {
                                    sweep_lanes_affine_with::<PairedPortable>(&p.b, &pc, &mut st)
                                }
                                (false, true) => sweep_lanes_affine(&p.b, &pc, &mut st),
                            };
                        }
                        (w, a, wa, aa)
                    };
                    assert_eq!(
                        run(true, false),
                        run(false, false),
                        "plain sweep {loss:?}/{reg:?}/{rule:?}"
                    );
                    assert_eq!(
                        run(true, true),
                        run(false, true),
                        "affine sweep {loss:?}/{reg:?}/{rule:?}"
                    );
                }
            }
        }
    }

    /// Runtime-guarded: the real AVX-512 pipeline on the same
    /// boundary-heavy block, against the portable oracle (tolerance —
    /// FMA contraction) and against its own fused wrapper (bitwise —
    /// the `#[target_feature]` boundary must change codegen only).
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx512_sweeps_match_portable_and_fused_wrapper() {
        if !crate::simd::avx512_supported() {
            return;
        }
        let (p, row_counts, col_counts, y) = pair_boundary_block();
        for loss in [Loss::Hinge, Loss::Logistic, Loss::Square] {
            for rule in [StepRule::Fixed(0.25), StepRule::AdaGrad(0.25), StepRule::Adaptive(0.25)]
            {
                let mut c = ctx(&row_counts, &col_counts, &y, rule);
                c.loss = loss;
                c.m = 3.0;
                c.w_bound = loss.w_bound(c.lambda);
                let pc = packed_ctx(&c, &p);
                let run = |mode: u8| {
                    let mut w = vec![0.02f32; 24];
                    let mut wa = vec![0f32; 24];
                    let mut a: Vec<f32> =
                        y.iter().map(|&v| loss.alpha_init(v as f64) as f32).collect();
                    let mut aa = vec![0f32; 3];
                    for _ in 0..3 {
                        let mut st = PackedState {
                            w: &mut w,
                            w_acc: &mut wa,
                            alpha: &mut a,
                            a_acc: &mut aa,
                        };
                        match mode {
                            0 => sweep_lanes(&p.b, &pc, &mut st),
                            1 => sweep_lanes_with::<crate::simd::Avx512>(&p.b, &pc, &mut st),
                            // SAFETY: avx512_supported() checked above.
                            _ => unsafe { sweep_lanes_avx512(&p.b, &pc, &mut st) },
                        };
                    }
                    (w, a)
                };
                let (pw, pa) = run(0);
                let (vw, va) = run(1);
                for k in 0..24 {
                    let rel = (vw[k] - pw[k]).abs() as f64 / (pw[k].abs() as f64).max(1e-3);
                    assert!(rel <= 1e-5, "{loss:?}/{rule:?} w[{k}]: {} vs {}", vw[k], pw[k]);
                }
                for k in 0..3 {
                    let rel = (va[k] - pa[k]).abs() as f64 / (pa[k].abs() as f64).max(1e-3);
                    assert!(rel <= 1e-5, "{loss:?}/{rule:?} α[{k}]: {} vs {}", va[k], pa[k]);
                }
                assert_eq!(run(1), run(2), "fused wrapper must be bitwise {loss:?}/{rule:?}");
            }
        }
    }
}
