//! The DSO coordinator — the paper's system contribution (Section 3).

pub mod async_engine;
pub mod engine;
pub mod monitor;
pub mod tile;
pub mod updates;

pub use async_engine::train_dso_async;
pub use engine::{run_replay, train_dso, DsoSetup};
pub use monitor::{EvalRow, Monitor, TrainResult};

use crate::config::{Algorithm, TrainConfig};
use crate::data::Dataset;
use anyhow::Result;

/// Train with the algorithm selected in the config — DSO or one of the
/// paper's baselines. The one-stop entry point used by the CLI,
/// examples, and experiment drivers.
pub fn train(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainResult> {
    match cfg.optim.algorithm {
        Algorithm::Dso => {
            if cfg.cluster.mode == crate::config::ExecMode::Tile {
                tile::train_dso_tile(cfg, train, test)
            } else {
                train_dso(cfg, train, test)
            }
        }
        Algorithm::DsoAsync => async_engine::train_dso_async(cfg, train, test),
        Algorithm::Sgd => crate::baselines::sgd::train_sgd(cfg, train, test),
        Algorithm::Psgd => crate::baselines::psgd::train_psgd(cfg, train, test),
        Algorithm::Bmrm => crate::baselines::bmrm::train_bmrm(cfg, train, test),
    }
}
