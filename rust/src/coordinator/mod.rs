//! The DSO coordinator — the paper's system contribution (Section 3).

pub mod async_engine;
pub mod engine;
pub mod monitor;
pub mod plan;
pub mod tile;
pub mod updates;

// The deprecated shims stay re-exported (their callers get the
// deprecation note, not a broken path); the allow silences the warning
// on the re-export itself.
#[allow(deprecated)]
pub use async_engine::train_dso_async;
pub use engine::DsoSetup;
#[allow(deprecated)]
pub use engine::{run_replay, train_dso};
pub mod checkpoint;

pub use checkpoint::Checkpoint;
pub use monitor::{EpochObserver, EvalRow, Monitor, TrainResult, WorkerFailure};
pub use plan::{PlannedKernel, SweepPlan};

use crate::config::TrainConfig;
use crate::data::Dataset;
use anyhow::Result;

/// Train with the algorithm selected in the config — DSO or one of the
/// paper's baselines.
///
/// Deprecated shim: the `Algorithm` × `ExecMode` routing now lives in
/// the [`crate::api::Trainer`] facade, which this delegates to. Prefer
/// `Trainer::new(cfg.clone()).fit(train, test)` — it adds observer
/// streaming, replay, and the `Fitted` artifact.
#[deprecated(since = "0.1.0", note = "use dso::api::Trainer")]
pub fn train(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainResult> {
    crate::api::Trainer::new(cfg.clone())
        .fit(train, test)
        .map(crate::api::Fitted::into_result)
}
