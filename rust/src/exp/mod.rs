//! Experiment drivers — one per table/figure of the paper's evaluation
//! (see DESIGN.md §4 for the experiment index). Each driver runs the
//! relevant algorithms on the matching registry dataset, prints the
//! paper-style comparison to stdout, and writes one CSV per
//! (algorithm, setting) under the output directory so the series behind
//! every figure can be regenerated and plotted.

pub mod ablation;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod sweeps;
pub mod tables;

use crate::config::{Algorithm, TrainConfig};
use crate::coordinator::TrainResult;
use crate::data::Dataset;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Shared experiment options (CLI-settable).
#[derive(Clone, Debug)]
pub struct ExpOptions {
    /// Dataset scale multiplier (1.0 = default reduced sizes).
    pub scale: f64,
    /// Epoch-count multiplier.
    pub epochs_mul: f64,
    pub out_dir: PathBuf,
    pub seed: u64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self { scale: 1.0, epochs_mul: 1.0, out_dir: PathBuf::from("results"), seed: 42 }
    }
}

impl ExpOptions {
    /// Quick settings for tests / smoke runs.
    pub fn quick() -> Self {
        Self { scale: 0.08, epochs_mul: 0.15, out_dir: std::env::temp_dir().join("dso-exp"), seed: 42 }
    }

    pub fn epochs(&self, base: usize) -> usize {
        ((base as f64 * self.epochs_mul).round() as usize).max(2)
    }
}

/// Run one algorithm on a prepared train/test pair and persist its
/// history CSV as `<out>/<exp>/<label>.csv`.
pub fn run_and_save(
    exp: &str,
    label: &str,
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    out_dir: &Path,
) -> Result<TrainResult> {
    let t0 = std::time::Instant::now();
    let r = crate::api::Trainer::new(cfg.clone()).fit(train, test)?.into_result();
    let dir = out_dir.join(exp);
    std::fs::create_dir_all(&dir)?;
    r.history.write_csv(&dir.join(format!("{label}.csv")))?;
    crate::log_info!(
        "{exp}/{label}: primal={:.6} gap={:.3e} virt={:.3}s wall={:.2}s",
        r.final_primal,
        r.final_gap,
        r.total_virtual_s,
        t0.elapsed().as_secs_f64()
    );
    Ok(r)
}

/// Render the classic comparison summary the paper's figures show:
/// final objective / gap / test error / virtual time per algorithm.
pub fn summary_table(results: &[(&str, &TrainResult)]) -> String {
    let mut out = format!(
        "{:<12} {:>12} {:>12} {:>10} {:>12} {:>12}\n",
        "algorithm", "objective", "gap", "test_err", "virtual_s", "updates"
    );
    for (name, r) in results {
        let test_err = r
            .history
            .col("test_error")
            .and_then(|c| c.last().copied())
            .unwrap_or(f64::NAN);
        out.push_str(&format!(
            "{:<12} {:>12.6} {:>12.3e} {:>10.4} {:>12.4} {:>12}\n",
            name, r.final_primal, r.final_gap, test_err, r.total_virtual_s, r.total_updates
        ));
    }
    out
}

/// Standard three-way config builders used across experiments.
pub fn cfg_for(
    algo: Algorithm,
    dataset: &str,
    lambda: f64,
    epochs: usize,
    machines: usize,
    cores: usize,
    opts: &ExpOptions,
) -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.data.name = dataset.to_string();
    cfg.data.scale = opts.scale;
    cfg.data.seed = opts.seed;
    cfg.model.lambda = lambda;
    cfg.optim.algorithm = algo;
    cfg.optim.epochs = epochs;
    cfg.optim.eta0 = 0.1;
    cfg.optim.seed = opts.seed;
    cfg.cluster.machines = machines;
    cfg.cluster.cores = cores;
    cfg.monitor.every = 1;
    cfg
}

/// Dispatch by experiment name. `all` runs everything.
pub fn run(name: &str, opts: &ExpOptions) -> Result<()> {
    match name {
        "table1" => tables::table1(opts),
        "table2" => tables::table2(opts),
        "fig2" => fig2::run(opts),
        "fig3" => fig3::run(opts),
        "fig4" => fig4::run(opts),
        "fig5" => fig5::run(opts),
        "ablation" => ablation::run(opts),
        "serial-sweep" => sweeps::serial(opts),
        "parallel-sweep" => sweeps::parallel(opts),
        "all" => {
            for e in ALL {
                crate::log_info!("=== experiment {e} ===");
                run(e, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}'; valid: {} or all", ALL.join(", ")),
    }
}

pub const ALL: &[&str] = &[
    "ablation",
    "table1",
    "table2",
    "fig2",
    "fig3",
    "fig4",
    "fig5",
    "serial-sweep",
    "parallel-sweep",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_errors() {
        assert!(run("nope", &ExpOptions::quick()).is_err());
    }

    #[test]
    fn epochs_scaling() {
        let mut o = ExpOptions::default();
        o.epochs_mul = 0.5;
        assert_eq!(o.epochs(100), 50);
        assert_eq!(o.epochs(1), 2); // floor of 2
    }

    #[test]
    fn summary_table_renders() {
        use crate::coordinator::TrainResult;
        use crate::util::csv::Table;
        let r = TrainResult {
            algorithm: "dso".into(),
            w: vec![],
            alpha: vec![],
            history: Table::new(&crate::coordinator::monitor::HISTORY_COLUMNS),
            final_primal: 0.5,
            final_gap: 0.01,
            total_updates: 100,
            total_virtual_s: 1.5,
            total_wall_s: 2.0,
            comm_bytes: 0,
            failures: Vec::new(),
        };
        let s = summary_table(&[("dso", &r)]);
        assert!(s.contains("dso"));
        assert!(s.contains("0.5"));
    }
}
