//! Figure 5 (+ supplementary Figure 78) — scaling of DSO with the
//! number of machines (1, 2, 4, 8; 8 cores each) on kdda (very sparse)
//! and ocr (dense).
//!
//! Figure 5 plots objective vs seconds × #machines (total resource
//! time): overlapping lines = linear scaling. Figure 78 plots objective
//! vs elapsed seconds. Paper's observed shape: kdda scales sub-linearly
//! (ultra-sparse — little compute per inner iteration vs d/p
//! communication), ocr scales ~linearly or better (dense compute
//! dominates; cache effects in the real system).

use super::{cfg_for, run_and_save, ExpOptions};
use crate::config::Algorithm;
use anyhow::Result;

pub const LAMBDA: f64 = 1e-4;
pub const BASE_EPOCHS: usize = 25;
pub const MACHINE_COUNTS: [usize; 4] = [1, 2, 4, 8];
pub const CORES: usize = 8;

pub fn run(opts: &ExpOptions) -> Result<()> {
    for dataset in ["kdda", "ocr"] {
        let ds = crate::data::registry::generate(dataset, opts.scale, opts.seed)
            .map_err(anyhow::Error::msg)?;
        let (train, test) = ds.split(0.2, opts.seed);
        let epochs = opts.epochs(BASE_EPOCHS);

        println!("\nFigure 5 — DSO scaling on {dataset} (λ={LAMBDA}, {epochs} epochs)");
        println!(
            "{:>9} {:>9} {:>12} {:>12} {:>14} {:>12}",
            "machines", "workers", "objective", "virtual_s", "virt_x_mach", "comm_MB"
        );
        let mut virt1 = None;
        for &machines in &MACHINE_COUNTS {
            let cores = CORES.min((train.m() / machines / 2).max(1)).max(1);
            let cfg = cfg_for(Algorithm::Dso, dataset, LAMBDA, epochs, machines, cores, opts);
            let label = format!("{dataset}_m{machines}");
            let r = run_and_save("fig5", &label, &cfg, &train, Some(&test), &opts.out_dir)?;
            if machines == 1 {
                virt1 = Some(r.total_virtual_s);
            }
            println!(
                "{:>9} {:>9} {:>12.6} {:>12.4} {:>14.4} {:>12.3}",
                machines,
                machines * cores,
                r.final_primal,
                r.total_virtual_s,
                r.total_virtual_s * machines as f64,
                r.comm_bytes as f64 / 1e6,
            );
        }
        if let Some(v1) = virt1 {
            crate::log_info!("{dataset}: 1-machine virtual time {v1:.4}s (speedup baseline)");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_quick_scaling_shapes() {
        let opts = ExpOptions::quick();
        run(&opts).unwrap();
        let load = |name: &str| {
            crate::util::csv::Table::read_csv(&opts.out_dir.join("fig5").join(name)).unwrap()
        };
        // At quick scale communication dominates on ultra-sparse kdda —
        // the paper's own kdda slowdown, amplified. Assert the robust
        // invariants instead of time monotonicity: all machine counts
        // reach similar objectives, and comm volume grows with p.
        let o1 = *load("kdda_m1.csv").col("primal").unwrap().last().unwrap();
        let o8 = *load("kdda_m8.csv").col("primal").unwrap().last().unwrap();
        assert!((o1 - o8).abs() / o1.max(1e-9) < 0.35, "{o1} vs {o8}");
        let c1 = *load("kdda_m1.csv").col("comm_bytes").unwrap().last().unwrap();
        let c8 = *load("kdda_m8.csv").col("comm_bytes").unwrap().last().unwrap();
        assert!(c8 > c1, "comm bytes did not grow with machines: {c1} vs {c8}");
        // All eight series exist with finite, improving objectives.
        // (Virtual-time speedups only emerge at real scale — the quick
        // fixture is latency-dominated; the scaling example and bench
        // exercise the full-scale behavior.)
        for ds_name in ["kdda", "ocr"] {
            for m in MACHINE_COUNTS {
                let t = load(&format!("{ds_name}_m{m}.csv"));
                let primal = t.col("primal").unwrap();
                assert!(primal.iter().all(|p| p.is_finite()), "{ds_name} m{m}");
                // A handful of quick epochs: allow stochastic wobble.
                assert!(primal.last().unwrap() <= &(primal[0] * 1.5), "{ds_name} m{m}");
            }
        }
    }
}
