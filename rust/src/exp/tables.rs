//! Table 1 (loss conjugates — verified numerically) and Table 2
//! (dataset summary — regenerated from the registry).

use super::ExpOptions;
use crate::data::registry;
use crate::losses::Loss;
use anyhow::Result;

/// Table 1: print the loss/dual pairs and *verify* them numerically —
/// max Fenchel–Young violation and biconjugation error over a grid.
pub fn table1(_opts: &ExpOptions) -> Result<()> {
    println!("\nTable 1 — losses and their duals (numerically verified)");
    println!(
        "{:<10} {:<28} {:<34} {:>14} {:>14}",
        "name", "l(u)", "-l*(-a)", "max FY viol", "biconj err"
    );
    let specs = [
        (Loss::Hinge, "max(1 - y u, 0)", "y a  for  y a in [0, 1]"),
        (Loss::Logistic, "log(1 + exp(-y u))", "-[b ln b + (1-b) ln(1-b)], b = y a"),
        (Loss::Square, "(u - y)^2 / 2", "y a - a^2/2"),
    ];
    for (loss, prim, dual) in specs {
        let mut max_fy: f64 = 0.0; // FY inequality violations (should be ~0)
        let mut max_bc: f64 = 0.0; // biconjugation gap
        for &y in &[1.0, -1.0] {
            for iu in -40..=40 {
                let u = iu as f64 * 0.1;
                let mut sup = f64::NEG_INFINITY;
                for k in 0..=2000 {
                    let alpha = match loss {
                        // α* = y − u ranges over ±(1+4) on this u grid.
                        Loss::Square => -6.0 + 12.0 * k as f64 / 2000.0,
                        _ => y * (k as f64 / 2000.0),
                    };
                    let v = loss.dual_utility(alpha, y) - u * alpha;
                    if v > sup {
                        sup = v;
                    }
                    max_fy = max_fy.max(v - loss.primal(u, y));
                }
                max_bc = max_bc.max((loss.primal(u, y) - sup).abs());
            }
        }
        println!("{:<10} {:<28} {:<34} {:>14.2e} {:>14.2e}", loss.name(), prim, dual, max_fy, max_bc);
        anyhow::ensure!(max_fy < 1e-9, "{}: Fenchel–Young violated", loss.name());
        anyhow::ensure!(max_bc < 5e-3, "{}: biconjugation off", loss.name());
    }
    Ok(())
}

/// Table 2: dataset summary statistics from the registry generators.
pub fn table2(opts: &ExpOptions) -> Result<()> {
    println!("\nTable 2 — dataset summary (registry @ scale {})", opts.scale);
    println!("{}", crate::data::DatasetStats::header());
    let mut table = crate::util::csv::Table::new(&["m", "d", "nnz", "density_pct", "pos_neg"]);
    for &name in registry::NAMES {
        let ds = registry::generate(name, opts.scale, opts.seed).map_err(anyhow::Error::msg)?;
        let s = ds.stats();
        println!("{}", s.row());
        table.push(vec![
            s.m as f64,
            s.d as f64,
            s.nnz as f64,
            s.density_pct,
            s.pos_neg_ratio,
        ]);
    }
    let dir = opts.out_dir.join("table2");
    std::fs::create_dir_all(&dir)?;
    table.write_csv(&dir.join("datasets.csv"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_verifies() {
        table1(&ExpOptions::quick()).unwrap();
    }

    #[test]
    fn table2_writes_csv() {
        let mut opts = ExpOptions::quick();
        opts.out_dir = std::env::temp_dir().join("dso-table2-test");
        table2(&opts).unwrap();
        let t =
            crate::util::csv::Table::read_csv(&opts.out_dir.join("table2/datasets.csv")).unwrap();
        assert_eq!(t.len(), registry::NAMES.len());
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
