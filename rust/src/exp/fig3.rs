//! Figure 3 — multi-machine convergence on kdda (sparse, high-d) with
//! linear SVM: DSO vs BMRM vs PSGD on 4 machines × 8 cores.
//!
//! Paper's observed shape: DSO converges much faster than both BMRM
//! and PSGD in iterations *and* time on this sparse dataset; PSGD
//! stalls above the optimum (averaging bias).

use super::{cfg_for, run_and_save, summary_table, ExpOptions};
use crate::config::Algorithm;
use anyhow::Result;

pub const LAMBDA: f64 = 1e-4;
pub const BASE_EPOCHS: usize = 40;
pub const MACHINES: usize = 4;
pub const CORES: usize = 8;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let ds = crate::data::registry::generate("kdda", opts.scale, opts.seed)
        .map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, opts.seed);
    let epochs = opts.epochs(BASE_EPOCHS);
    // Cap worker count for reduced-scale runs.
    let cores = CORES.min((train.m() / MACHINES).max(1)).max(1);

    let mut results = Vec::new();
    for (label, algo) in
        [("dso", Algorithm::Dso), ("bmrm", Algorithm::Bmrm), ("psgd", Algorithm::Psgd)]
    {
        let mut cfg = cfg_for(algo, "kdda", LAMBDA, epochs, MACHINES, cores, opts);
        // Parallel experiments warm start via local DCD (App. B).
        cfg.optim.dcd_init = algo == Algorithm::Dso;
        let r = run_and_save("fig3", label, &cfg, &train, Some(&test), &opts.out_dir)?;
        results.push((label, r));
    }

    println!(
        "\nFigure 3 — cluster SVM on kdda ({MACHINES} machines × {cores} cores, λ={LAMBDA})"
    );
    let refs: Vec<(&str, &crate::coordinator::TrainResult)> =
        results.iter().map(|(l, r)| (*l, r)).collect();
    println!("{}", summary_table(&refs));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_quick_shape_dso_beats_psgd_on_objective() {
        let opts = ExpOptions::quick();
        run(&opts).unwrap();
        let load = |a: &str| {
            crate::util::csv::Table::read_csv(&opts.out_dir.join("fig3").join(format!("{a}.csv")))
                .unwrap()
        };
        let dso = load("dso");
        let psgd = load("psgd");
        let d_final = *dso.col("primal").unwrap().last().unwrap();
        let p_final = *psgd.col("primal").unwrap().last().unwrap();
        // Paper shape: DSO reaches a lower (or equal) objective than
        // PSGD, which is biased by averaging.
        assert!(d_final <= p_final * 1.10, "dso {d_final} vs psgd {p_final}");
    }
}
