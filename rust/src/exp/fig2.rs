//! Figure 2 — single-machine convergence on real-sim with linear SVM:
//! DSO vs SGD vs BMRM, objective value against epochs and time.
//!
//! Paper's observed shape: SGD fastest (optimizes d parameters), DSO in
//! the middle (stochastic but optimizes m + d parameters), BMRM slowest
//! per unit time early on (batch); all converge to the same objective.

use super::{cfg_for, run_and_save, summary_table, ExpOptions};
use crate::config::Algorithm;
use anyhow::Result;

pub const LAMBDA: f64 = 1e-4;
pub const BASE_EPOCHS: usize = 60;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let ds = crate::data::registry::generate("real-sim", opts.scale, opts.seed)
        .map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, opts.seed);
    let epochs = opts.epochs(BASE_EPOCHS);

    let mut results = Vec::new();
    for (label, algo) in
        [("dso", Algorithm::Dso), ("sgd", Algorithm::Sgd), ("bmrm", Algorithm::Bmrm)]
    {
        let cfg = cfg_for(algo, "real-sim", LAMBDA, epochs, 1, 1, opts);
        let r = run_and_save("fig2", label, &cfg, &train, Some(&test), &opts.out_dir)?;
        results.push((label, r));
    }

    println!("\nFigure 2 — serial SVM on real-sim (λ={LAMBDA}, {epochs} epochs)");
    let refs: Vec<(&str, &crate::coordinator::TrainResult)> =
        results.iter().map(|(l, r)| (*l, r)).collect();
    println!("{}", summary_table(&refs));

    // Paper-shape check (logged, not asserted): all three reach a
    // similar objective; SGD ≤ DSO ≤ BMRM in early-epoch objective.
    let obj: Vec<f64> = results.iter().map(|(_, r)| r.final_primal).collect();
    let spread = (obj.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - obj.iter().cloned().fold(f64::INFINITY, f64::min))
        / obj[0].abs().max(1e-9);
    crate::log_info!("fig2 final-objective relative spread: {spread:.3}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_quick_runs_and_writes_csvs() {
        let opts = ExpOptions::quick();
        run(&opts).unwrap();
        for algo in ["dso", "sgd", "bmrm"] {
            let p = opts.out_dir.join("fig2").join(format!("{algo}.csv"));
            assert!(p.exists(), "{p:?}");
            let t = crate::util::csv::Table::read_csv(&p).unwrap();
            assert!(t.len() >= 2);
            // Objective decreases from first to last evaluation.
            let primal = t.col("primal").unwrap();
            assert!(primal.last().unwrap() <= &(primal[0] * 1.01), "{algo}: {primal:?}");
        }
    }
}
