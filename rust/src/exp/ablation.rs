//! Ablations over the design choices DESIGN.md calls out:
//!
//! * step-size rule (Algorithm 1's η₀/√t vs const vs the paper's
//!   experimental AdaGrad),
//! * bulk-synchronous vs asynchronous (NOMAD-style, §6) coordination,
//! * tile_iters — the batched-steps-per-visit knob of the tile engine
//!   (only when AOT artifacts are built),
//! * DCD warm start on/off (App. B).

use super::{cfg_for, run_and_save, ExpOptions};
use crate::config::{Algorithm, StepKind};
use anyhow::Result;

pub const LAMBDA: f64 = 1e-4;
pub const BASE_EPOCHS: usize = 40;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let ds = crate::data::registry::generate("real-sim", opts.scale, opts.seed)
        .map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, opts.seed);
    let epochs = opts.epochs(BASE_EPOCHS);
    println!("\nAblation — DSO design choices on real-sim (λ={LAMBDA}, {epochs} epochs)");
    println!(
        "{:<26} {:>12} {:>12} {:>10} {:>12}",
        "variant", "objective", "gap", "test_err", "virtual_s"
    );

    let mut report = |label: &str, r: &crate::coordinator::TrainResult| {
        println!(
            "{:<26} {:>12.6} {:>12.3e} {:>10.4} {:>12.4}",
            label,
            r.final_primal,
            r.final_gap,
            r.history.col("test_error").and_then(|c| c.last().copied()).unwrap_or(f64::NAN),
            r.total_virtual_s
        );
    };

    // Step-size rules.
    for (label, step, eta0) in [
        ("step=adagrad (paper)", StepKind::AdaGrad, 0.1),
        ("step=adaptive (1802.05811)", StepKind::Adaptive, 0.1),
        ("step=invsqrt (thm 1)", StepKind::InvSqrt, 1.0),
        ("step=const", StepKind::Const, 0.05),
    ] {
        let mut cfg = cfg_for(Algorithm::Dso, "real-sim", LAMBDA, epochs, 2, 2, opts);
        cfg.optim.step = step;
        cfg.optim.eta0 = eta0;
        let r = run_and_save("ablation", &label.replace([' ', '='], "_"), &cfg, &train, Some(&test), &opts.out_dir)?;
        report(label, &r);
    }

    // Sync vs async coordination.
    for (label, algo) in [
        ("coord=bulk-sync", Algorithm::Dso),
        ("coord=async (NOMAD)", Algorithm::DsoAsync),
    ] {
        let cfg = cfg_for(algo, "real-sim", LAMBDA, epochs, 2, 2, opts);
        let r = run_and_save("ablation", &label.replace([' ', '='], "_"), &cfg, &train, Some(&test), &opts.out_dir)?;
        report(label, &r);
    }

    // DCD warm start.
    {
        let mut cfg = cfg_for(Algorithm::Dso, "real-sim", LAMBDA, epochs, 2, 2, opts);
        cfg.optim.dcd_init = true;
        let r = run_and_save("ablation", "dcd_init_on", &cfg, &train, Some(&test), &opts.out_dir)?;
        report("dcd-init=on (App. B)", &r);
    }

    // tile_iters (dense path), if artifacts are available.
    if crate::runtime::Manifest::load_default().is_ok() {
        let dense = crate::data::registry::generate("ocr", (opts.scale * 0.5).max(0.02), opts.seed)
            .map_err(anyhow::Error::msg)?;
        let (dtrain, dtest) = dense.split(0.2, opts.seed);
        println!("\n  tile_iters ablation (ocr analog, tile/PJRT engine):");
        for iters in [1usize, 2, 4, 8, 16] {
            let mut cfg =
                cfg_for(Algorithm::Dso, "ocr", LAMBDA, opts.epochs(15), 2, 1, opts);
            cfg.cluster.mode = crate::config::ExecMode::Tile;
            cfg.cluster.tile_iters = iters;
            let r = run_and_save(
                "ablation",
                &format!("tile_iters_{iters}"),
                &cfg,
                &dtrain,
                Some(&dtest),
                &opts.out_dir,
            )?;
            report(&format!("tile_iters={iters}"), &r);
        }
    } else {
        println!("  (tile_iters ablation skipped — run `make artifacts`)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_quick_runs() {
        let mut opts = ExpOptions::quick();
        opts.out_dir = std::env::temp_dir().join("dso-ablation-test");
        run(&opts).unwrap();
        // Step rules, coordination, and dcd CSVs all written.
        let dir = opts.out_dir.join("ablation");
        let n = std::fs::read_dir(&dir).unwrap().count();
        assert!(n >= 6, "only {n} ablation outputs in {dir:?}");
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }
}
