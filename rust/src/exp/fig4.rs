//! Figure 4 — multi-machine convergence on ocr (dense, redundant) with
//! linear SVM: DSO vs BMRM vs PSGD on 4 machines × 8 cores.
//!
//! Paper's observed shape: DSO still competitive per *iteration*, but
//! on dense data BMRM streams BLAS-friendly batch passes and wins on
//! wall-clock, and PSGD benefits from the dataset's redundancy and
//! outperforms both. DSO runs here in tile mode (the AOT Pallas kernel
//! through PJRT) when artifacts are present, scalar otherwise.

use super::{cfg_for, run_and_save, summary_table, ExpOptions};
use crate::config::{Algorithm, ExecMode};
use anyhow::Result;

pub const LAMBDA: f64 = 1e-4;
pub const BASE_EPOCHS: usize = 30;
pub const MACHINES: usize = 4;
pub const CORES: usize = 8;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let ds = crate::data::registry::generate("ocr", opts.scale, opts.seed)
        .map_err(anyhow::Error::msg)?;
    let (train, test) = ds.split(0.2, opts.seed);
    let epochs = opts.epochs(BASE_EPOCHS);
    let cores = CORES.min((train.m() / MACHINES).max(1)).max(1);
    let have_artifacts = crate::runtime::Manifest::load_default().is_ok();

    let mut results = Vec::new();
    for (label, algo) in
        [("dso", Algorithm::Dso), ("bmrm", Algorithm::Bmrm), ("psgd", Algorithm::Psgd)]
    {
        let mut cfg = cfg_for(algo, "ocr", LAMBDA, epochs, MACHINES, cores, opts);
        if algo == Algorithm::Dso && have_artifacts {
            cfg.cluster.mode = ExecMode::Tile;
        }
        let r = run_and_save("fig4", label, &cfg, &train, Some(&test), &opts.out_dir)?;
        results.push((label, r));
    }

    println!(
        "\nFigure 4 — cluster SVM on ocr (dense; {MACHINES}×{cores}, λ={LAMBDA}; \
         DSO mode: {})",
        if have_artifacts { "tile/PJRT" } else { "scalar (no artifacts)" }
    );
    let refs: Vec<(&str, &crate::coordinator::TrainResult)> =
        results.iter().map(|(l, r)| (*l, r)).collect();
    println!("{}", summary_table(&refs));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_quick_runs_all_three() {
        let opts = ExpOptions::quick();
        run(&opts).unwrap();
        for a in ["dso", "bmrm", "psgd"] {
            let t = crate::util::csv::Table::read_csv(
                &opts.out_dir.join("fig4").join(format!("{a}.csv")),
            )
            .unwrap();
            assert!(t.len() >= 2, "{a}");
            let primal = t.col("primal").unwrap();
            assert!(
                primal.last().unwrap() <= &(primal[0] * 1.01),
                "{a} did not improve: {primal:?}"
            );
        }
    }
}
