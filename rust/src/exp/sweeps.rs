//! Supplementary sweeps.
//!
//! * `serial` — Figures 6–45: serial experiments, {logistic, svm} ×
//!   {reuters-ccat, real-sim, news20, worm, alpha} × λ ∈ {1e-3 … 1e-6},
//!   DSO vs SGD vs BMRM.
//! * `parallel` — Figures 46–77: parallel experiments (4 machines × 8
//!   cores), {logistic, svm} × {kdda, kddb, ocr, dna} × λ ∈ {1e-3 …
//!   1e-6}, DSO vs BMRM vs PSGD, objective and test error vs iteration
//!   and time.
//!
//! Each cell writes `<out>/<sweep>/<dataset>_<loss>_<lambda>/<algo>.csv`.

use super::{cfg_for, run_and_save, ExpOptions};
use crate::config::{Algorithm, LossKind};
use crate::data::registry;
use anyhow::Result;

pub const LAMBDAS: [f64; 4] = [1e-3, 1e-4, 1e-5, 1e-6];
pub const LOSSES: [(LossKind, &str); 2] =
    [(LossKind::Hinge, "svm"), (LossKind::Logistic, "logistic")];

fn lambda_tag(l: f64) -> String {
    format!("{l:.0e}").replace('-', "m")
}

fn sweep(
    which: &str,
    datasets: &[&str],
    algos: &[(&str, Algorithm)],
    machines: usize,
    cores: usize,
    base_epochs: usize,
    opts: &ExpOptions,
) -> Result<()> {
    let epochs = opts.epochs(base_epochs);
    let mut rows = Vec::new();
    for &dataset in datasets {
        let ds = registry::generate(dataset, opts.scale, opts.seed)
            .map_err(anyhow::Error::msg)?;
        let (train, test) = ds.split(0.2, opts.seed);
        let cores = cores.min((train.m() / machines).max(1)).max(1);
        for (loss, loss_tag) in LOSSES {
            for lambda in LAMBDAS {
                let cell = format!("{dataset}_{loss_tag}_{}", lambda_tag(lambda));
                for (label, algo) in algos {
                    let mut cfg =
                        cfg_for(*algo, dataset, lambda, epochs, machines, cores, opts);
                    cfg.model.loss = loss;
                    let r = run_and_save(
                        &format!("{which}/{cell}"),
                        label,
                        &cfg,
                        &train,
                        Some(&test),
                        &opts.out_dir,
                    )?;
                    let test_err = r
                        .history
                        .col("test_error")
                        .and_then(|c| c.last().copied())
                        .unwrap_or(f64::NAN);
                    rows.push((cell.clone(), label.to_string(), r.final_primal, test_err));
                }
            }
        }
    }

    println!("\n{which} sweep summary ({} cells):", rows.len());
    println!("{:<34} {:<6} {:>12} {:>10}", "cell", "algo", "objective", "test_err");
    for (cell, label, obj, te) in &rows {
        println!("{cell:<34} {label:<6} {obj:>12.6} {te:>10.4}");
    }
    Ok(())
}

/// Figures 6–45.
pub fn serial(opts: &ExpOptions) -> Result<()> {
    sweep(
        "serial-sweep",
        registry::SERIAL_NAMES,
        &[("dso", Algorithm::Dso), ("sgd", Algorithm::Sgd), ("bmrm", Algorithm::Bmrm)],
        1,
        1,
        25,
        opts,
    )
}

/// Figures 46–77.
pub fn parallel(opts: &ExpOptions) -> Result<()> {
    sweep(
        "parallel-sweep",
        registry::PARALLEL_NAMES,
        &[("dso", Algorithm::Dso), ("bmrm", Algorithm::Bmrm), ("psgd", Algorithm::Psgd)],
        4,
        8,
        15,
        opts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The full sweeps are long; test one cell of each via a trimmed
    /// dataset list (the sweep function itself is what's exercised).
    #[test]
    fn one_serial_cell_runs() {
        let mut opts = ExpOptions::quick();
        opts.out_dir = std::env::temp_dir().join("dso-sweep-test");
        sweep(
            "serial-sweep",
            &["real-sim"],
            &[("dso", Algorithm::Dso)],
            1,
            1,
            3,
            &opts,
        )
        .unwrap();
        // 2 losses × 4 lambdas CSVs.
        let base = opts.out_dir.join("serial-sweep");
        let cells = std::fs::read_dir(&base).unwrap().count();
        assert_eq!(cells, 8, "expected 8 cells in {base:?}");
        std::fs::remove_dir_all(&opts.out_dir).ok();
    }

    #[test]
    fn lambda_tags_unique() {
        let tags: std::collections::HashSet<String> =
            LAMBDAS.iter().map(|&l| lambda_tag(l)).collect();
        assert_eq!(tags.len(), LAMBDAS.len());
    }
}
