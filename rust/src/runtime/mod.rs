//! PJRT runtime: the bridge from the Rust coordinator to the AOT
//! JAX/Pallas artifacts (HLO text → compile once → execute on the hot
//! path). Python never runs at training time.
//!
//! The PJRT client itself comes from the `xla` bindings, which are not
//! in the offline crate set — the modules that touch them are gated
//! behind the `xla` cargo feature. With the feature off (the default),
//! artifact-manifest handling still works and the tile engine returns a
//! clean runtime error instead of failing the build.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(feature = "xla")]
pub mod tile_engine;

#[cfg(not(feature = "xla"))]
pub mod tile_engine {
    //! Stub tile engine used when the `xla` feature is disabled.
    use crate::config::TrainConfig;
    use crate::coordinator::monitor::{EpochObserver, TrainResult};
    use crate::data::Dataset;
    use anyhow::Result;

    pub fn train(
        cfg: &TrainConfig,
        train: &Dataset,
        test: Option<&Dataset>,
    ) -> Result<TrainResult> {
        train_with(cfg, train, test, None)
    }

    pub fn train_with(
        _cfg: &TrainConfig,
        _train: &Dataset,
        _test: Option<&Dataset>,
        _obs: Option<&mut dyn EpochObserver>,
    ) -> Result<TrainResult> {
        anyhow::bail!(
            "tile mode requires the PJRT runtime; rebuild with \
             `--features xla` (needs the vendored xla bindings)"
        )
    }
}

pub use artifacts::{ArtifactEntry, Manifest};
#[cfg(feature = "xla")]
pub use pjrt::PjrtRuntime;

#[cfg(all(test, not(feature = "xla")))]
mod stub_tests {
    use crate::data::{Csr, Dataset};

    /// The gated stub must fail with the documented, actionable message
    /// — `--mode tile` on a non-xla build reports how to enable the
    /// path instead of a generic failure. Covered here (and at the CLI
    /// layer) so the stub can't silently regress.
    #[test]
    fn tile_stub_reports_feature_gate_error() {
        let cfg = crate::config::TrainConfig::default();
        let x = Csr::from_rows(2, vec![vec![(0, 1.0)], vec![(1, 1.0)]]);
        let ds = Dataset::new("stub", x, vec![1.0, -1.0]);
        let err = super::tile_engine::train(&cfg, &ds, None).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("tile mode requires the PJRT runtime"), "msg: {msg}");
        assert!(msg.contains("--features xla"), "msg: {msg}");
    }
}
