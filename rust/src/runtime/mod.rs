//! PJRT runtime: the bridge from the Rust coordinator to the AOT
//! JAX/Pallas artifacts (HLO text → compile once → execute on the hot
//! path). Python never runs at training time.
//!
//! The PJRT client itself comes from the `xla` bindings, which are not
//! in the offline crate set — the modules that touch them are gated
//! behind the `xla` cargo feature. With the feature off (the default),
//! artifact-manifest handling still works and the tile engine returns a
//! clean runtime error instead of failing the build.

pub mod artifacts;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(feature = "xla")]
pub mod tile_engine;

#[cfg(not(feature = "xla"))]
pub mod tile_engine {
    //! Stub tile engine used when the `xla` feature is disabled.
    use crate::config::TrainConfig;
    use crate::coordinator::monitor::TrainResult;
    use crate::data::Dataset;
    use anyhow::Result;

    pub fn train(
        _cfg: &TrainConfig,
        _train: &Dataset,
        _test: Option<&Dataset>,
    ) -> Result<TrainResult> {
        anyhow::bail!(
            "tile mode requires the PJRT runtime; rebuild with \
             `--features xla` (needs the vendored xla bindings)"
        )
    }
}

pub use artifacts::{ArtifactEntry, Manifest};
#[cfg(feature = "xla")]
pub use pjrt::PjrtRuntime;
