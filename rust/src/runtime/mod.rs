//! PJRT runtime: the bridge from the Rust coordinator to the AOT
//! JAX/Pallas artifacts (HLO text → compile once → execute on the hot
//! path). Python never runs at training time.

pub mod artifacts;
pub mod pjrt;
pub mod tile_engine;

pub use artifacts::{ArtifactEntry, Manifest};
pub use pjrt::PjrtRuntime;
