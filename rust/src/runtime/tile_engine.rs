//! Tile-batched DSO over the PJRT runtime — the dense-data execution
//! path (DESIGN.md §Hardware-Adaptation).
//!
//! Same coordination structure as the scalar engine (row/α blocks
//! pinned to workers, w blocks rotating on the ring, bulk sync per
//! inner iteration) but each block visit executes the AOT Pallas
//! kernel: the block is chunked into fixed-shape (bm × bd) dense
//! sub-tiles (shape chosen from the artifact manifest to minimize
//! padding) and each sub-tile performs one batched saddle step — two
//! MXU matmuls + fused AdaGrad/projections.
//!
//! The `xla` crate's PJRT client is single-threaded (`Rc` internals),
//! so workers here are *virtual*: their updates are strictly disjoint
//! (same argument as Lemma 2), execution is serialized on one thread,
//! and per-worker compute time feeds the same virtual-clock machinery
//! the scalar engine uses. The reported `virtual_s` axis is therefore
//! comparable across both engines.

use super::artifacts::Manifest;
use super::pjrt::{lit_mat, lit_to_vec, lit_vec, PjrtRuntime};
use crate::config::{StepKind, TrainConfig};
use crate::coordinator::monitor::{EpochObserver, Monitor, TrainResult};
use crate::data::Dataset;
use crate::losses::{Loss, Problem, Regularizer};
use crate::net::{CostModel, VirtualClock};
use crate::partition::{OmegaBlocks, Partition, RingSchedule};
use crate::util::timer::Stopwatch;
use anyhow::Result;

/// A prepared dense sub-tile: constant literals + coordinate ranges.
struct SubTile {
    x: xla::Literal,
    y: xla::Literal,
    row_scale: xla::Literal,
    col_scale: xla::Literal,
    rows: std::ops::Range<usize>,
    cols: std::ops::Range<usize>,
}

struct BlockTiles {
    tiles: Vec<SubTile>,
}

pub fn train(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainResult> {
    train_with(cfg, train, test, None)
}

/// [`train`] with an optional per-epoch observer (the facade's
/// streaming hook).
pub fn train_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    anyhow::ensure!(
        cfg.optim.step == StepKind::AdaGrad,
        "tile engine implements the paper's AdaGrad configuration (App. B); \
         set optim.step = \"adagrad\""
    );
    let loss = Loss::from(cfg.model.loss);
    let reg = Regularizer::from(cfg.model.reg);
    anyhow::ensure!(
        reg == Regularizer::L2,
        "tile kernel implements the paper's φ(w)=w² regularizer"
    );
    let problem = Problem::new(loss, reg, cfg.model.lambda);
    let p = cfg.workers().min(train.m()).min(train.d()).max(1);
    let m = train.m();
    let d = train.d();

    let manifest = Manifest::load_default()?;
    let row_part = Partition::even(m, p);
    let col_part = Partition::even(d, p);
    let omega = OmegaBlocks::build(&train.x, &row_part, &col_part);
    let schedule = RingSchedule::new(p);
    let cost = CostModel::new(
        cfg.cluster.latency_us,
        cfg.cluster.bandwidth_mbps,
        cfg.cluster.cores.max(1),
    );

    // Tile shape: one global choice sized to the typical block. Prefer
    // an artifact with the fused iteration count baked in (one PJRT
    // call per visit instead of tile_iters — §Perf).
    let typical_rows = m.div_ceil(p);
    let typical_cols = d.div_ceil(p);
    let shape = manifest
        .choose_tile("tile_update", loss.name(), typical_rows, typical_cols)
        .ok_or_else(|| {
            anyhow::anyhow!("no tile_update artifact for loss '{}'", loss.name())
        })?;
    let (bm, bd) = (shape.bm, shape.bd);
    let want_iters = cfg.cluster.tile_iters.max(1);
    let (entry, calls_per_visit) = match manifest
        .find_iters("tile_update", loss.name(), bm, bd, want_iters)
    {
        Some(e) => (e, 1usize),
        None => (
            manifest
                .find_iters("tile_update", loss.name(), bm, bd, 1)
                .ok_or_else(|| anyhow::anyhow!("no iters=1 artifact for {bm}x{bd}"))?,
            want_iters,
        ),
    };
    let mut rt = PjrtRuntime::cpu()?;
    rt.load(&entry.name, &entry.path)?;

    // --- Precompute sub-tiles for every (q, b) block ---
    //
    // The batched step takes the gradient of f restricted to the tile:
    //   f_tile = Σ_{j} λφ_j·|Ω̄_j ∩ rows|/|Ω̄_j|
    //          + Σ_{i} h(α_i)·|Ω_i ∩ cols|/(m|Ω_i|)
    //          − Σ_{(i,j)∈tile} α_i w_j x_ij / m
    // so the scale vectors carry the *tile-restricted* nonzero counts
    // (zero on padding): this is the exact batched analog of sweeping
    // the tile's entries with Eq. 8 — visiting w_j once per entry in
    // its tile column, α_i once per entry in its tile row.
    let mf = m as f64;
    let mut blocks: Vec<BlockTiles> = Vec::with_capacity(p * p);
    for q in 0..p {
        for b in 0..p {
            let rr = row_part.block(q);
            let cr = col_part.block(b);
            let mut tiles = Vec::new();
            let mut r0 = rr.start;
            while r0 < rr.end {
                let r1 = (r0 + bm).min(rr.end);
                let mut c0 = cr.start;
                while c0 < cr.end {
                    let c1 = (c0 + bd).min(cr.end);
                    // Dense padded x tile.
                    let sub = train.x.dense_block(r0, r1, c0, c1);
                    let mut x = vec![0f32; bm * bd];
                    for (ri, row) in sub.chunks(c1 - c0).enumerate() {
                        x[ri * bd..ri * bd + row.len()].copy_from_slice(row);
                    }
                    // Tile-restricted nonzero counts.
                    let mut row_nnz = vec![0u32; bm];
                    let mut col_nnz = vec![0u32; bd];
                    for ri in 0..bm {
                        for ci in 0..bd {
                            if x[ri * bd + ci] != 0.0 {
                                row_nnz[ri] += 1;
                                col_nnz[ci] += 1;
                            }
                        }
                    }
                    let mut y = vec![1.0f32; bm];
                    let mut rs = vec![0f32; bm];
                    for (k, i) in (r0..r1).enumerate() {
                        y[k] = train.y[i];
                        let c = omega.row_counts[i];
                        if c > 0 {
                            rs[k] = (row_nnz[k] as f64 / (mf * c as f64)) as f32;
                        }
                    }
                    let mut cs = vec![0f32; bd];
                    for (k, j) in (c0..c1).enumerate() {
                        let c = omega.col_counts[j];
                        if c > 0 {
                            cs[k] = (col_nnz[k] as f64 / c as f64) as f32;
                        }
                    }
                    tiles.push(SubTile {
                        x: lit_mat(&x, bm, bd)?,
                        y: lit_vec(&y),
                        row_scale: lit_vec(&rs),
                        col_scale: lit_vec(&cs),
                        rows: r0..r1,
                        cols: c0..c1,
                    });
                    c0 = c1;
                }
                r0 = r1;
            }
            blocks.push(BlockTiles { tiles });
        }
    }

    // --- State ---
    let mut w = vec![0f32; d];
    let mut w_acc = vec![0f32; d];
    let mut alpha: Vec<f32> =
        (0..m).map(|i| loss.alpha_init(train.y[i] as f64) as f32).collect();
    let mut a_acc = vec![0f32; m];
    let params = [
        cfg.optim.eta0 as f32,
        cfg.model.lambda as f32,
        (1.0 / mf) as f32,
        loss.w_bound(cfg.model.lambda) as f32,
    ];
    let params_lit = lit_vec(&params);

    let mut clocks = vec![VirtualClock::new(); p];
    let mut monitor = Monitor::observed(cfg.monitor.every, obs);
    let wall = Stopwatch::new();
    let mut updates: u64 = 0;
    let mut comm_bytes: u64 = 0;
    let mut wbuf = vec![0f32; bd];
    let mut wabuf = vec![0f32; bd];
    let mut abuf = vec![0f32; bm];
    let mut aabuf = vec![0f32; bm];

    for epoch in 1..=cfg.optim.epochs {
        for r in 0..p {
            for (q, clock) in clocks.iter_mut().enumerate() {
                let b = schedule.owned_block(q, r);
                let t0 = std::time::Instant::now();
                for tile in &blocks[q * p + b].tiles {
                    // Gather state slices (padded).
                    let (rl, cl) = (tile.rows.len(), tile.cols.len());
                    wbuf[..cl].copy_from_slice(&w[tile.cols.clone()]);
                    wbuf[cl..].fill(0.0);
                    wabuf[..cl].copy_from_slice(&w_acc[tile.cols.clone()]);
                    wabuf[cl..].fill(0.0);
                    abuf[..rl].copy_from_slice(&alpha[tile.rows.clone()]);
                    abuf[rl..].fill(0.0);
                    aabuf[..rl].copy_from_slice(&a_acc[tile.rows.clone()]);
                    aabuf[rl..].fill(0.0);

                    // Several batched steps per visit: one scalar sweep
                    // does |Ω_tile| sequential updates, so a handful of
                    // whole-tile (Jacobi) steps keeps per-epoch progress
                    // comparable (cfg.cluster.tile_iters). When a fused
                    // artifact exists, all steps run in ONE PJRT call.
                    for _ in 0..calls_per_visit {
                        let out = rt.execute(
                            &entry.name,
                            &[
                                tile.x.clone(),
                                lit_vec(&wbuf),
                                lit_vec(&wabuf),
                                lit_vec(&abuf),
                                lit_vec(&aabuf),
                                tile.y.clone(),
                                tile.row_scale.clone(),
                                tile.col_scale.clone(),
                                params_lit.clone(),
                            ],
                        )?;
                        let w2 = lit_to_vec(&out[0])?;
                        let wa2 = lit_to_vec(&out[1])?;
                        let al2 = lit_to_vec(&out[2])?;
                        let aa2 = lit_to_vec(&out[3])?;
                        wbuf.copy_from_slice(&w2);
                        wabuf.copy_from_slice(&wa2);
                        abuf.copy_from_slice(&al2);
                        aabuf.copy_from_slice(&aa2);
                        updates += (rl * cl * entry.iters) as u64;
                    }
                    w[tile.cols.clone()].copy_from_slice(&wbuf[..cl]);
                    w_acc[tile.cols.clone()].copy_from_slice(&wabuf[..cl]);
                    alpha[tile.rows.clone()].copy_from_slice(&abuf[..rl]);
                    a_acc[tile.rows.clone()].copy_from_slice(&aabuf[..rl]);
                }
                clock.add_compute(t0.elapsed().as_secs_f64());
            }
            // Ring rotation of w blocks: charge T_c.
            for q in 0..p {
                let b = schedule.owned_block(q, r);
                let dst = schedule.send_to(q);
                let bytes = 16 + 8 * col_part.block_len(b);
                comm_bytes += bytes as u64;
                let secs = cost.transfer_secs(q, dst, bytes);
                clocks[dst].add_comm(secs);
            }
        }
        let vnow = VirtualClock::synchronize(&mut clocks);
        if monitor.due(epoch) || epoch == cfg.optim.epochs {
            monitor.record_saddle(
                &problem,
                train,
                test,
                &w,
                &alpha,
                epoch,
                vnow,
                wall.elapsed_secs(),
                updates,
                comm_bytes,
            );
        }
    }

    let final_primal = problem.primal(train, &w);
    let final_gap = final_primal - problem.dual(train, &alpha);
    Ok(TrainResult {
        algorithm: "dso-tile".into(),
        w,
        alpha,
        history: monitor.history,
        final_primal,
        final_gap,
        total_updates: updates,
        total_virtual_s: clocks.iter().map(|c| c.total()).fold(0.0, f64::max),
        total_wall_s: wall.elapsed_secs(),
        comm_bytes,
        failures: Vec::new(),
    })
}

#[cfg(test)]
// Exercises the deprecated `coordinator::train` shim on purpose (the
// xla-gated tile route is pinned through both entry points).
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, ExecMode, LossKind, TrainConfig};
    use crate::data::synth::DenseSpec;

    fn dense_ds(seed: u64) -> Dataset {
        DenseSpec {
            name: "tile-test".into(),
            m: 96,
            d: 40,
            density: 1.0,
            label_noise: 0.02,
            pos_frac: 0.5,
            prototypes: 12,
            seed,
        }
        .generate()
    }

    fn cfg(p: usize, epochs: usize) -> TrainConfig {
        let mut c = TrainConfig::default();
        c.optim.algorithm = Algorithm::Dso;
        c.optim.epochs = epochs;
        c.optim.eta0 = 0.5;
        c.model.lambda = 1e-3;
        c.cluster.machines = p;
        c.cluster.cores = 1;
        c.cluster.mode = ExecMode::Tile;
        c.monitor.every = 0;
        c
    }

    fn have_artifacts() -> bool {
        Manifest::load_default().is_ok()
    }

    #[test]
    fn tile_engine_converges_on_dense_data() {
        if !have_artifacts() {
            return;
        }
        let ds = dense_ds(1);
        let r = train(&cfg(2, 60), &ds, None).unwrap();
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 1e-3);
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        assert!(
            r.final_primal < 0.8 * at_zero,
            "{} !< {at_zero}",
            r.final_primal
        );
        assert!(r.final_gap >= -1e-5);
        assert_eq!(r.algorithm, "dso-tile");
    }

    #[test]
    fn tile_engine_deterministic() {
        if !have_artifacts() {
            return;
        }
        let ds = dense_ds(2);
        let a = train(&cfg(2, 3), &ds, None).unwrap();
        let b = train(&cfg(2, 3), &ds, None).unwrap();
        assert_eq!(a.w, b.w);
        assert_eq!(a.alpha, b.alpha);
    }

    #[test]
    fn logistic_tile_converges() {
        if !have_artifacts() {
            return;
        }
        let ds = dense_ds(3);
        let mut c = cfg(2, 40);
        c.model.loss = LossKind::Logistic;
        let r = train(&c, &ds, None).unwrap();
        let p = Problem::new(Loss::Logistic, Regularizer::L2, 1e-3);
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        assert!(r.final_primal < at_zero);
        assert!(r.final_gap >= -1e-5);
    }

    #[test]
    fn rejects_non_adagrad() {
        if !have_artifacts() {
            return;
        }
        let ds = dense_ds(4);
        let mut c = cfg(2, 2);
        c.optim.step = crate::config::StepKind::InvSqrt;
        assert!(train(&c, &ds, None).is_err());
    }

    #[test]
    fn monitor_history_populated() {
        if !have_artifacts() {
            return;
        }
        let ds = dense_ds(5);
        let mut c = cfg(2, 4);
        c.monitor.every = 1;
        let r = train(&c, &ds, None).unwrap();
        assert_eq!(r.history.len(), 4);
        let gaps = r.history.col("gap").unwrap();
        assert!(gaps.iter().all(|&g| g >= -1e-5));
        assert!(r.comm_bytes > 0);
        assert!(r.total_virtual_s > 0.0);
    }
}
