//! PJRT runtime: load AOT HLO-text artifacts, compile them once on the
//! CPU PJRT client, and execute them from the coordinator's paths.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile`.
//! Executables are cached by name — compilation happens once per
//! artifact per process. The `xla` crate's client is `Rc`-based (not
//! `Send`), so a [`PjrtRuntime`] lives on one thread; the tile engine
//! simulates worker parallelism with virtual clocks instead (see
//! `tile_engine`).

use std::collections::HashMap;
use std::path::Path;

pub struct PjrtRuntime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    pub fn cpu() -> anyhow::Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, exes: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact under `name`. No-op if
    /// already loaded.
    pub fn load(&mut self, name: &str, path: &Path) -> anyhow::Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            anyhow::anyhow!("loading HLO text {}: {e:?}", path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| {
            anyhow::anyhow!("compiling {}: {e:?}", path.display())
        })?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Execute a loaded artifact. All our artifacts are lowered with
    /// `return_tuple=True`, so the single output is a tuple that we
    /// decompose into its element literals.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not loaded"))?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Pack an f32 slice as a rank-1 literal.
pub fn lit_vec(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Pack an f32 slice as a rank-2 (rows × cols) literal.
pub fn lit_mat(v: &[f32], rows: usize, cols: usize) -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(v.len() == rows * cols, "matrix literal size mismatch");
    Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

/// Unpack a rank-n f32 literal into a Vec.
pub fn lit_to_vec(l: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;

    // These tests require `make artifacts`; they are skipped (not
    // failed) when the manifest is absent so `cargo test` works on a
    // fresh checkout. CI/Makefile always builds artifacts first.
    fn manifest() -> Option<Manifest> {
        Manifest::load_default().ok()
    }

    #[test]
    fn client_comes_up() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn load_and_execute_tile_update() {
        let Some(m) = manifest() else { return };
        let e = m.find_exact("tile_update", "hinge", 32, 32).expect("32x32 artifact");
        let mut rt = PjrtRuntime::cpu().unwrap();
        rt.load(&e.name, &e.path).unwrap();
        assert!(rt.is_loaded(&e.name));
        // Idempotent load.
        rt.load(&e.name, &e.path).unwrap();

        let (bm, bd) = (e.bm, e.bd);
        let x = vec![0.01f32; bm * bd];
        let w = vec![0.5f32; bd];
        let w_acc = vec![0f32; bd];
        let alpha = vec![0.5f32; bm];
        let a_acc = vec![0f32; bm];
        let y = vec![1.0f32; bm];
        let row_scale = vec![1e-3f32; bm];
        let col_scale = vec![1e-2f32; bd];
        let lambda = 1e-3f32;
        let params = vec![0.1f32, lambda, 1e-3, 1.0 / lambda.sqrt()];
        let inputs = vec![
            lit_mat(&x, bm, bd).unwrap(),
            lit_vec(&w),
            lit_vec(&w_acc),
            lit_vec(&alpha),
            lit_vec(&a_acc),
            lit_vec(&y),
            lit_vec(&row_scale),
            lit_vec(&col_scale),
            lit_vec(&params),
        ];
        let out = rt.execute(&e.name, &inputs).unwrap();
        assert_eq!(out.len(), 4);
        let w2 = lit_to_vec(&out[0]).unwrap();
        let alpha2 = lit_to_vec(&out[2]).unwrap();
        assert_eq!(w2.len(), bd);
        assert_eq!(alpha2.len(), bm);
        // Must have moved and stayed feasible.
        assert!(w2.iter().any(|&v| (v - 0.5).abs() > 1e-9));
        assert!(alpha2.iter().all(|&a| (0.0..=1.0).contains(&a)));
    }

    #[test]
    fn execute_matches_rust_scalar_semantics_on_1x1() {
        // On a 1x1 tile the batched update *is* the scalar update (8):
        // cross-check the kernel against coordinator::updates.
        let Some(m) = manifest() else { return };
        let e = m.find_exact("tile_update", "hinge", 32, 32).unwrap();
        let mut rt = PjrtRuntime::cpu().unwrap();
        rt.load(&e.name, &e.path).unwrap();
        let (bm, bd) = (e.bm, e.bd);

        // Only cell (0,0) active; everything else padding.
        let mut x = vec![0f32; bm * bd];
        x[0] = 2.0;
        let mut w = vec![0f32; bd];
        w[0] = 0.5;
        let w_acc = vec![0f32; bd];
        let mut alpha = vec![0f32; bm];
        alpha[0] = 0.25;
        let a_acc = vec![0f32; bm];
        let mut y = vec![1.0f32; bm];
        y[0] = 1.0;
        // m=2, |Ω_0|=2, |Ω̄_0|=2 as in the updates.rs unit test.
        let mut row_scale = vec![0f32; bm];
        row_scale[0] = 1.0 / (2.0 * 2.0);
        let mut col_scale = vec![0f32; bd];
        col_scale[0] = 1.0 / 2.0;
        let lambda = 0.1f32;
        // Fixed-step equivalent: AdaGrad with fresh accumulators gives
        // eta = eta0/|g| — instead cross-check against the AdaGrad rust
        // path for exactness.
        let params = vec![0.5f32, lambda, 0.5, 1.0 / lambda.sqrt()];
        let inputs = vec![
            lit_mat(&x, bm, bd).unwrap(),
            lit_vec(&w),
            lit_vec(&w_acc),
            lit_vec(&alpha),
            lit_vec(&a_acc),
            lit_vec(&y),
            lit_vec(&row_scale),
            lit_vec(&col_scale),
            lit_vec(&params),
        ];
        let out = rt.execute(&e.name, &inputs).unwrap();
        let w2 = lit_to_vec(&out[0]).unwrap();
        let a2 = lit_to_vec(&out[2]).unwrap();

        // Rust scalar path (AdaGrad, same numbers).
        use crate::coordinator::updates::{sweep_block, BlockState, StepRule, SweepCtx};
        use crate::partition::omega::Entry;
        let row_counts = [2u32, 1];
        let col_counts = [2u32, 1];
        let ys = [1.0f32, -1.0];
        let ctx = SweepCtx {
            loss: crate::losses::Loss::Hinge,
            reg: crate::losses::Regularizer::L2,
            lambda: 0.1,
            m: 2.0,
            row_counts: &row_counts,
            col_counts: &col_counts,
            y: &ys,
            w_bound: crate::losses::Loss::Hinge.w_bound(0.1),
            rule: StepRule::AdaGrad(0.5),
        };
        let entries = [Entry { i: 0, j: 0, x: 2.0 }];
        let mut ws = [0.5f32];
        let mut wacc = [0f32];
        let mut al = [0.25f32];
        let mut aacc = [0f32];
        let mut st = BlockState {
            w: &mut ws,
            w_acc: &mut wacc,
            w_off: 0,
            alpha: &mut al,
            a_acc: &mut aacc,
            a_off: 0,
        };
        sweep_block(&entries, &ctx, &mut st);
        assert!((w2[0] - ws[0]).abs() < 1e-5, "kernel {} vs rust {}", w2[0], ws[0]);
        assert!((a2[0] - al[0]).abs() < 1e-5, "kernel {} vs rust {}", a2[0], al[0]);
        // Padding untouched.
        assert!(w2[1..].iter().all(|&v| v == 0.0));
        assert!(a2[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn tile_objective_margins_match_cpu() {
        let Some(m) = manifest() else { return };
        let e = m.find_exact("tile_objective", "logistic", 32, 32).unwrap();
        let mut rt = PjrtRuntime::cpu().unwrap();
        rt.load(&e.name, &e.path).unwrap();
        let (bm, bd) = (e.bm, e.bd);
        let mut rng = crate::util::rng::Xoshiro256::new(3);
        let x: Vec<f32> = (0..bm * bd).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let y: Vec<f32> =
            (0..bm).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let w: Vec<f32> = (0..bd).map(|_| rng.uniform(-0.3, 0.3) as f32).collect();
        let active = vec![1.0f32; bm];
        let out = rt
            .execute(
                &e.name,
                &[
                    lit_mat(&x, bm, bd).unwrap(),
                    lit_vec(&y),
                    lit_vec(&w),
                    lit_vec(&active),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 2);
        let margins = lit_to_vec(&out[1]).unwrap();
        for i in 0..bm {
            let u: f64 = (0..bd).map(|j| x[i * bd + j] as f64 * w[j] as f64).sum();
            assert!((margins[i] as f64 - u).abs() < 1e-4, "row {i}");
        }
        let risk = lit_to_vec(&out[0]).unwrap()[0] as f64;
        let expect: f64 = (0..bm)
            .map(|i| {
                crate::losses::Loss::Logistic.primal(margins[i] as f64, y[i] as f64)
            })
            .sum();
        assert!((risk - expect).abs() / expect.max(1.0) < 1e-4, "{risk} vs {expect}");
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = PjrtRuntime::cpu().unwrap();
        let err = match rt.execute("nope", &[]) {
            Ok(_) => panic!("expected error"),
            Err(e) => e,
        };
        assert!(format!("{err}").contains("not loaded"));
        let mut rt = rt;
        assert!(rt.load("x", Path::new("/nonexistent/file.hlo.txt")).is_err());
    }
}
