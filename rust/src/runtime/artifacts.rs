//! Artifact manifest — the contract between `python/compile/aot.py`
//! (build time) and the Rust runtime (run time).
//!
//! `make artifacts` writes `artifacts/manifest.json` listing every AOT
//! HLO module: its kind (`tile_update` / `tile_objective`), loss, tile
//! shape (bm × bd), file path, and estimated VMEM residency. The
//! runtime never guesses shapes: everything it loads is declared here.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub loss: String,
    pub bm: usize,
    pub bd: usize,
    /// Fused batched steps per invocation (tile_update artifacts).
    pub iters: usize,
    pub path: PathBuf,
    pub vmem_bytes: u64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub jax_version: String,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(dir, &text)
    }

    /// Default location: `$DSO_ARTIFACTS` or `artifacts/` under the
    /// current directory (walking up to 3 parents, so tests and
    /// examples work from any workspace subdirectory).
    pub fn load_default() -> anyhow::Result<Manifest> {
        if let Ok(dir) = std::env::var("DSO_ARTIFACTS") {
            return Self::load(Path::new(&dir));
        }
        let mut base = std::env::current_dir()?;
        for _ in 0..4 {
            let cand = base.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Self::load(&cand);
            }
            if !base.pop() {
                break;
            }
        }
        anyhow::bail!("no artifacts/manifest.json found; run `make artifacts`")
    }

    pub fn parse(dir: &Path, text: &str) -> anyhow::Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let schema = v.get("schema").and_then(|s| s.as_i64()).unwrap_or(0);
        anyhow::ensure!(schema == 1, "unsupported manifest schema {schema}");
        let jax_version =
            v.get("jax_version").and_then(|s| s.as_str()).unwrap_or("unknown").to_string();
        let mut entries = Vec::new();
        for e in v.get("entries").and_then(|a| a.as_arr()).unwrap_or(&[]) {
            let get_s = |k: &str| {
                e.get(k)
                    .and_then(|x| x.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow::anyhow!("manifest entry missing '{k}'"))
            };
            let get_n = |k: &str| {
                e.get(k)
                    .and_then(|x| x.as_i64())
                    .ok_or_else(|| anyhow::anyhow!("manifest entry missing '{k}'"))
            };
            entries.push(ArtifactEntry {
                name: get_s("name")?,
                kind: get_s("kind")?,
                loss: get_s("loss")?,
                bm: get_n("bm")? as usize,
                bd: get_n("bd")? as usize,
                iters: get_n("iters").unwrap_or(1) as usize,
                path: dir.join(get_s("path")?),
                vmem_bytes: get_n("vmem_bytes").unwrap_or(0) as u64,
            });
        }
        anyhow::ensure!(!entries.is_empty(), "manifest has no entries");
        Ok(Manifest { dir: dir.to_path_buf(), jax_version, entries })
    }

    /// Entries of a kind/loss, any shape.
    pub fn find(&self, kind: &str, loss: &str) -> Vec<&ArtifactEntry> {
        self.entries.iter().filter(|e| e.kind == kind && e.loss == loss).collect()
    }

    /// Exact shape lookup (any iters; prefers iters == 1).
    pub fn find_exact(&self, kind: &str, loss: &str, bm: usize, bd: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && e.loss == loss && e.bm == bm && e.bd == bd)
            .min_by_key(|e| e.iters)
    }

    /// Exact (shape, iters) lookup.
    pub fn find_iters(
        &self,
        kind: &str,
        loss: &str,
        bm: usize,
        bd: usize,
        iters: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.kind == kind && e.loss == loss && e.bm == bm && e.bd == bd && e.iters == iters
        })
    }

    /// Choose the tile shape that minimizes padded work for a block of
    /// `rows × cols`: minimal total padded area over the sub-tile grid.
    pub fn choose_tile(&self, kind: &str, loss: &str, rows: usize, cols: usize) -> Option<&ArtifactEntry> {
        self.find(kind, loss)
            .into_iter()
            .min_by_key(|e| {
                let tiles_r = rows.div_ceil(e.bm).max(1);
                let tiles_c = cols.div_ceil(e.bd).max(1);
                // Padded area + per-call overhead. Profiling (§Perf):
                // a PJRT call costs ~120µs fixed vs ~3ns per element,
                // i.e. one call ≈ 40k elements — so fewer, larger tiles
                // win until padding dwarfs the fixed cost.
                (tiles_r * e.bm * tiles_c * e.bd) as u64
                    + 40_000 * (tiles_r * tiles_c) as u64
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "schema": 1,
      "jax_version": "0.8.2",
      "entries": [
        {"name": "tile_update_hinge_64x64", "kind": "tile_update", "loss": "hinge",
         "bm": 64, "bd": 64, "path": "a.hlo.txt", "vmem_bytes": 100},
        {"name": "tile_update_hinge_32x32", "kind": "tile_update", "loss": "hinge",
         "bm": 32, "bd": 32, "path": "b.hlo.txt", "vmem_bytes": 50},
        {"name": "tile_objective_hinge_64x64", "kind": "tile_objective", "loss": "hinge",
         "bm": 64, "bd": 64, "path": "c.hlo.txt", "vmem_bytes": 80}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert_eq!(m.jax_version, "0.8.2");
        assert_eq!(m.entries[0].path, Path::new("/tmp/x/a.hlo.txt"));
    }

    #[test]
    fn find_filters() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        assert_eq!(m.find("tile_update", "hinge").len(), 2);
        assert_eq!(m.find("tile_update", "logistic").len(), 0);
        assert!(m.find_exact("tile_update", "hinge", 32, 32).is_some());
        assert!(m.find_exact("tile_update", "hinge", 16, 16).is_none());
    }

    #[test]
    fn choose_tile_minimizes_padding() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        // 33x33 block: 64x64 pads to 4096, 32x32 needs 4 tiles = 4096 +
        // more call overhead... 64x64 = 1 tile. Area equal; overhead
        // favors 64.
        let t = m.choose_tile("tile_update", "hinge", 33, 33).unwrap();
        assert_eq!(t.bm, 64);
        // 32x32 block fits 32 exactly.
        let t = m.choose_tile("tile_update", "hinge", 32, 32).unwrap();
        assert_eq!(t.bm, 32);
        // 128x128 block: 4 tiles of 64 (16384) vs 16 tiles of 32 — area
        // equal, fewer calls wins.
        let t = m.choose_tile("tile_update", "hinge", 128, 128).unwrap();
        assert_eq!(t.bm, 64);
    }

    #[test]
    fn rejects_bad_schema_and_empty() {
        assert!(Manifest::parse(Path::new("."), r#"{"schema": 2, "entries": []}"#).is_err());
        assert!(Manifest::parse(Path::new("."), r#"{"schema": 1, "entries": []}"#).is_err());
        assert!(Manifest::parse(Path::new("."), "not json").is_err());
    }

    #[test]
    fn loads_real_artifacts_if_built() {
        // Integration hook: when `make artifacts` has run, validate the
        // real manifest.
        if let Ok(m) = Manifest::load_default() {
            assert!(!m.entries.is_empty());
            for e in &m.entries {
                assert!(e.path.exists(), "{} missing", e.path.display());
                assert!(e.bm > 0 && e.bd > 0);
            }
            // All three losses present for tile_update.
            for loss in ["hinge", "logistic", "square"] {
                assert!(!m.find("tile_update", loss).is_empty(), "{loss}");
            }
        }
    }
}
