//! PSGD — Parallelized Stochastic Gradient Descent (Zinkevich et al.,
//! NIPS 2010), the distributed stochastic baseline of §5.2.
//!
//! Every epoch, each of the p workers runs one SGD pass over its own
//! shard of the data (same sparse-unbiased regularizer estimator as the
//! serial SGD baseline, AdaGrad steps), all starting from the shared
//! iterate; the p resulting weight vectors are then averaged
//! (`w ← (1/p) Σ_q w_q`). The averaging step is an allreduce whose cost
//! is charged through the simulated [`CostModel`]; local passes run on
//! real threads so compute time is measured, not modeled.

use crate::config::{StepKind, TrainConfig};
use crate::coordinator::monitor::{EpochObserver, Monitor, TrainResult};
use crate::data::Dataset;
use crate::losses::{Loss, Problem, Regularizer};
use crate::net::CostModel;
use crate::optim::step::ADAGRAD_EPS;
use crate::partition::Partition;
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use anyhow::Result;

struct Shard {
    rows: std::ops::Range<usize>,
    /// Worker-local AdaGrad accumulators (persist across epochs, as each
    /// worker adapts to its own shard's geometry).
    acc: Vec<f32>,
    rng: Xoshiro256,
}

#[deprecated(since = "0.1.0", note = "use dso::api::Trainer::algorithm(Algorithm::Psgd)")]
pub fn train_psgd(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainResult> {
    train_psgd_with(cfg, train, test, None)
}

/// [`train_psgd`] with an optional per-epoch observer (the
/// `dso::api::Trainer` facade's streaming hook).
pub fn train_psgd_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    let loss = Loss::from(cfg.model.loss);
    let reg = Regularizer::from(cfg.model.reg);
    let problem = Problem::new(loss, reg, cfg.model.lambda);
    let p = cfg.workers().min(train.m()).max(1);
    let d = train.d();
    let m = train.m();
    let mf = m as f64;
    let col_counts = std::sync::Arc::new(train.x.col_counts());
    let cost = CostModel::new(
        cfg.cluster.latency_us,
        cfg.cluster.bandwidth_mbps,
        cfg.cluster.cores.max(1),
    );
    let part = Partition::even(m, p);

    let mut root_rng = Xoshiro256::new(cfg.optim.seed);
    let mut shards: Vec<Shard> = (0..p)
        .map(|q| Shard { rows: part.block(q), acc: vec![0f32; d], rng: root_rng.split(q as u64) })
        .collect();

    let mut w = vec![0f32; d];
    let mut monitor = Monitor::observed(cfg.monitor.every, obs);
    let wall = Stopwatch::new();
    let mut virtual_s = 0.0;
    let mut updates: u64 = 0;
    let mut comm_bytes: u64 = 0;
    // Same accumulator-rule unification as `sgd`: Some(offset) selects
    // the adaptive denominators, None the scalar schedules.
    let acc_eps = match cfg.optim.step {
        StepKind::AdaGrad => Some(ADAGRAD_EPS),
        StepKind::Adaptive => Some(1.0),
        _ => None,
    };
    let eta0 = cfg.optim.eta0;
    let lambda = cfg.model.lambda;

    for epoch in 1..=cfg.optim.epochs {
        let eta_t = match cfg.optim.step {
            StepKind::Const => eta0,
            StepKind::InvSqrt => eta0 / (epoch as f64).sqrt(),
            StepKind::AdaGrad | StepKind::Adaptive => eta0,
        };

        // Parallel local passes.
        let w_shared = &w;
        let results: Vec<(Vec<f32>, Vec<f32>, Xoshiro256, f64, u64)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = shards
                    .drain(..)
                    .map(|shard| {
                        let col_counts = col_counts.clone();
                        scope.spawn(move || {
                            let mut wq = w_shared.to_vec();
                            let mut acc = shard.acc;
                            let mut rng = shard.rng;
                            let rows = shard.rows.clone();
                            let n_local = rows.len();
                            let t0 = std::time::Instant::now();
                            let mut local_updates = 0u64;
                            for _ in 0..n_local {
                                let i = rows.start + rng.gen_index(n_local);
                                let (idx, val) = train.x.row(i);
                                if idx.is_empty() {
                                    continue;
                                }
                                let u = train.x.row_dot(i, &wq);
                                let y = train.y[i] as f64;
                                let lg = loss.primal_grad(u, y);
                                for k in 0..idx.len() {
                                    let j = idx[k] as usize;
                                    let wj = wq[j] as f64;
                                    let g = lg * val[k] as f64
                                        + lambda * reg.grad(wj) * mf
                                            / col_counts[j].max(1) as f64;
                                    let eta = if let Some(eps) = acc_eps {
                                        let a = acc[j] as f64 + g * g;
                                        acc[j] = a as f32;
                                        eta0 / (eps + a).sqrt()
                                    } else {
                                        eta_t
                                    };
                                    wq[j] = (wj - eta * g) as f32;
                                }
                                local_updates += 1;
                            }
                            (wq, acc, rng, t0.elapsed().as_secs_f64(), local_updates)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("psgd worker panicked")).collect()
            });

        // Average (bulk-sync allreduce).
        let mut w_sum = vec![0f64; d];
        let mut max_compute = 0.0f64;
        for (q, (wq, acc, rng, secs, nup)) in results.into_iter().enumerate() {
            for j in 0..d {
                w_sum[j] += wq[j] as f64;
            }
            max_compute = max_compute.max(secs);
            updates += nup;
            shards.push(Shard { rows: part.block(q), acc, rng });
        }
        for j in 0..d {
            w[j] = (w_sum[j] / p as f64) as f32;
        }
        // Allreduce cost: each machine exchanges a d-vector with the
        // leader (in + out). Inter-machine links only.
        let machines = cfg.cluster.machines.max(1);
        let vec_bytes = 4 * d;
        let mut allreduce_s = 0.0f64;
        for mach in 1..machines {
            let from_worker = mach * cfg.cluster.cores;
            if from_worker < p {
                allreduce_s = allreduce_s
                    .max(2.0 * cost.transfer_secs(from_worker, 0, vec_bytes));
                comm_bytes += 2 * vec_bytes as u64;
            }
        }
        virtual_s += max_compute + allreduce_s;

        if monitor.due(epoch) || epoch == cfg.optim.epochs {
            monitor.record_primal(
                &problem,
                train,
                test,
                &w,
                epoch,
                virtual_s,
                wall.elapsed_secs(),
                updates,
                comm_bytes,
            );
        }
    }

    let final_primal = problem.primal(train, &w);
    Ok(TrainResult {
        algorithm: "psgd".into(),
        w,
        alpha: Vec::new(),
        history: monitor.history,
        final_primal,
        final_gap: f64::NAN,
        total_updates: updates,
        total_virtual_s: virtual_s,
        total_wall_s: wall.elapsed_secs(),
        comm_bytes,
        failures: Vec::new(),
    })
}

#[cfg(test)]
// The shim entry points stay under test on purpose: these suites pin
// them bit-for-bit against the facade (see tests/trainer_api.rs).
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, TrainConfig};
    use crate::data::synth::SparseSpec;

    fn dataset(seed: u64) -> Dataset {
        SparseSpec {
            name: "psgd-test".into(),
            m: 400,
            d: 80,
            nnz_per_row: 8.0,
            zipf_s: 0.6,
            label_noise: 0.03,
            pos_frac: 0.5,
            seed,
        }
        .generate()
    }

    fn cfg(p: usize, epochs: usize) -> TrainConfig {
        let mut c = TrainConfig::default();
        c.optim.algorithm = Algorithm::Psgd;
        c.optim.epochs = epochs;
        c.optim.eta0 = 0.1;
        c.model.lambda = 1e-3;
        c.cluster.machines = p;
        c.cluster.cores = 1;
        c.monitor.every = 0;
        c
    }

    #[test]
    fn reduces_objective() {
        let ds = dataset(1);
        let r = train_psgd(&cfg(4, 20), &ds, None).unwrap();
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 1e-3);
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        assert!(r.final_primal < 0.8 * at_zero);
    }

    #[test]
    fn deterministic() {
        let ds = dataset(2);
        let c = cfg(3, 3);
        let a = train_psgd(&c, &ds, None).unwrap();
        let b = train_psgd(&c, &ds, None).unwrap();
        assert_eq!(a.w, b.w);
    }

    #[test]
    fn single_worker_close_to_serial_sgd_quality() {
        let ds = dataset(3);
        let r_psgd = train_psgd(&cfg(1, 15), &ds, None).unwrap();
        let mut c = cfg(1, 15);
        c.optim.algorithm = Algorithm::Sgd;
        let r_sgd = super::super::sgd::train_sgd(&c, &ds, None).unwrap();
        // Same algorithm family; objectives should be in the same range.
        let rel = (r_psgd.final_primal - r_sgd.final_primal).abs()
            / r_sgd.final_primal.max(1e-9);
        assert!(rel < 0.35, "psgd {} sgd {}", r_psgd.final_primal, r_sgd.final_primal);
    }

    #[test]
    fn comm_accounted_with_multiple_machines() {
        let ds = dataset(4);
        let mut c = cfg(4, 3);
        c.cluster.machines = 4;
        c.cluster.cores = 1;
        let r = train_psgd(&c, &ds, None).unwrap();
        // 3 epochs × 3 non-leader machines × 2 d-vectors.
        assert_eq!(r.comm_bytes, 3 * 3 * 2 * 4 * ds.d() as u64);
        assert!(r.total_virtual_s > 0.0);
    }

    #[test]
    fn averaging_beats_any_stale_start() {
        // Smoke: more epochs → no worse objective (monotone-ish).
        let ds = dataset(5);
        let short = train_psgd(&cfg(4, 3), &ds, None).unwrap();
        let long = train_psgd(&cfg(4, 25), &ds, None).unwrap();
        assert!(long.final_primal <= short.final_primal * 1.05);
    }
}
