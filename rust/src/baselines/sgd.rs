//! Serial stochastic gradient descent baseline (Section 1, Eq. 3–4),
//! with AdaGrad step sizes as in the paper's experiments (§5).
//!
//! The textbook stochastic gradient (Eq. 3) contains the *dense*
//! regularizer term λ Σ_j ∇φ_j(w_j) e_j, which would make every update
//! O(d). Like all practical sparse SGD implementations we replace it
//! with the unbiased sparse estimator supported on Ω_i:
//!
//! ```text
//!   G_j = λ ∇φ_j(w_j) · m / |Ω̄_j|   for j ∈ Ω_i   (0 elsewhere)
//! ```
//!
//! E_i[G_j] = λ∇φ_j(w_j) since P(j ∈ Ω_i) = |Ω̄_j|/m, so updates stay
//! O(|Ω_i|) and unbiased; AdaGrad tames the variance this introduces.

use crate::config::{StepKind, TrainConfig};
use crate::coordinator::monitor::{EpochObserver, Monitor, TrainResult};
use crate::data::Dataset;
use crate::losses::{Loss, Problem, Regularizer};
use crate::optim::step::ADAGRAD_EPS;
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use anyhow::Result;

#[deprecated(since = "0.1.0", note = "use dso::api::Trainer::algorithm(Algorithm::Sgd)")]
pub fn train_sgd(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainResult> {
    train_sgd_with(cfg, train, test, None)
}

/// [`train_sgd`] with an optional per-epoch observer (the
/// `dso::api::Trainer` facade's streaming hook).
pub fn train_sgd_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    let loss = Loss::from(cfg.model.loss);
    let reg = Regularizer::from(cfg.model.reg);
    let problem = Problem::new(loss, reg, cfg.model.lambda);
    let m = train.m();
    let d = train.d();
    let mf = m as f64;
    let col_counts = train.x.col_counts();

    let mut w = vec![0f32; d];
    let mut acc = vec![0f32; d]; // AdaGrad accumulators
    let mut rng = Xoshiro256::new(cfg.optim.seed);
    let mut monitor = Monitor::observed(cfg.monitor.every, obs);
    let wall = Stopwatch::new();
    let mut virtual_s = 0.0;
    let mut updates: u64 = 0;
    // Accumulator rules share one loop; they differ only in the offset
    // inside the root (AdaGrad's ε floor, Adaptive's unit offset).
    let acc_eps = match cfg.optim.step {
        StepKind::AdaGrad => Some(ADAGRAD_EPS),
        StepKind::Adaptive => Some(1.0),
        _ => None,
    };

    for epoch in 1..=cfg.optim.epochs {
        let eta_t = match cfg.optim.step {
            StepKind::Const => cfg.optim.eta0,
            StepKind::InvSqrt => cfg.optim.eta0 / (epoch as f64).sqrt(),
            StepKind::AdaGrad | StepKind::Adaptive => cfg.optim.eta0,
        };
        let t0 = std::time::Instant::now();
        for _ in 0..m {
            let i = rng.gen_index(m);
            let (idx, val) = train.x.row(i);
            if idx.is_empty() {
                continue;
            }
            let u = train.x.row_dot(i, &w);
            let y = train.y[i] as f64;
            let lg = loss.primal_grad(u, y);
            for k in 0..idx.len() {
                let j = idx[k] as usize;
                let wj = w[j] as f64;
                // Loss part + sparse-unbiased regularizer part.
                let g = lg * val[k] as f64
                    + cfg.model.lambda * reg.grad(wj) * mf / col_counts[j].max(1) as f64;
                let eta = if let Some(eps) = acc_eps {
                    let a = acc[j] as f64 + g * g;
                    acc[j] = a as f32;
                    cfg.optim.eta0 / (eps + a).sqrt()
                } else {
                    eta_t
                };
                w[j] = (wj - eta * g) as f32;
            }
            updates += 1;
        }
        virtual_s += t0.elapsed().as_secs_f64();

        if monitor.due(epoch) || epoch == cfg.optim.epochs {
            monitor.record_primal(
                &problem,
                train,
                test,
                &w,
                epoch,
                virtual_s,
                wall.elapsed_secs(),
                updates,
                0,
            );
        }
    }

    let final_primal = problem.primal(train, &w);
    Ok(TrainResult {
        algorithm: "sgd".into(),
        w,
        alpha: Vec::new(),
        history: monitor.history,
        final_primal,
        final_gap: f64::NAN,
        total_updates: updates,
        total_virtual_s: virtual_s,
        total_wall_s: wall.elapsed_secs(),
        comm_bytes: 0,
        failures: Vec::new(),
    })
}

#[cfg(test)]
// The shim entry points stay under test on purpose: these suites pin
// them bit-for-bit against the facade (see tests/trainer_api.rs).
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, TrainConfig};
    use crate::data::synth::SparseSpec;

    fn dataset(seed: u64) -> Dataset {
        SparseSpec {
            name: "sgd-test".into(),
            m: 400,
            d: 100,
            nnz_per_row: 8.0,
            zipf_s: 0.7,
            label_noise: 0.03,
            pos_frac: 0.5,
            seed,
        }
        .generate()
    }

    fn cfg(epochs: usize) -> TrainConfig {
        let mut c = TrainConfig::default();
        c.optim.algorithm = Algorithm::Sgd;
        c.optim.epochs = epochs;
        c.optim.eta0 = 0.1;
        c.model.lambda = 1e-3;
        c.monitor.every = 0;
        c
    }

    #[test]
    fn reduces_objective() {
        let ds = dataset(1);
        let c = cfg(20);
        let r = train_sgd(&c, &ds, None).unwrap();
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 1e-3);
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        assert!(r.final_primal < 0.7 * at_zero, "{} vs {at_zero}", r.final_primal);
    }

    #[test]
    fn approaches_dcd_optimum() {
        let ds = dataset(2);
        let mut c = cfg(150);
        c.optim.eta0 = 0.2;
        let r = train_sgd(&c, &ds, None).unwrap();
        let opt = crate::optim::dcd::solve_hinge_l2(&ds, 1e-3, 500, 1e-9, 1);
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 1e-3);
        let p_opt = p.primal(&ds, &opt.w);
        assert!(
            r.final_primal < p_opt * 1.15 + 0.02,
            "sgd {} vs optimum {p_opt}",
            r.final_primal
        );
    }

    #[test]
    fn deterministic() {
        let ds = dataset(3);
        let c = cfg(3);
        let a = train_sgd(&c, &ds, None).unwrap();
        let b = train_sgd(&c, &ds, None).unwrap();
        assert_eq!(a.w, b.w);
    }

    #[test]
    fn logistic_works() {
        let ds = dataset(4);
        let mut c = cfg(30);
        c.model.loss = crate::config::LossKind::Logistic;
        let r = train_sgd(&c, &ds, None).unwrap();
        let p = Problem::new(Loss::Logistic, Regularizer::L2, 1e-3);
        let at_zero = p.primal(&ds, &vec![0.0; ds.d()]);
        assert!(r.final_primal < at_zero);
    }

    #[test]
    fn history_has_nan_dual() {
        let ds = dataset(5);
        let mut c = cfg(3);
        c.monitor.every = 1;
        let r = train_sgd(&c, &ds, None).unwrap();
        assert!(r.history.col("dual").unwrap().iter().all(|v| v.is_nan()));
        assert_eq!(r.history.len(), 3);
    }
}
