//! BMRM — Bundle Methods for Regularized Risk Minimization (Teo et al.,
//! JMLR 2010), the batch baseline of §5 (the paper drives it through
//! TAO; we implement the algorithm directly).
//!
//! At iteration t, evaluate the empirical risk R(w_t) and a subgradient
//! a_t = ∇R(w_t); add the cutting plane R(w) ≥ ⟨a_t, w⟩ + b_t with
//! b_t = R(w_t) − ⟨a_t, w_t⟩; then minimize the piecewise-linear model
//! plus regularizer
//!
//! ```text
//!   w_{t+1} = argmin_w  λ‖w‖² + max_k [⟨a_k, w⟩ + b_k]
//!           = −(1/2λ) Σ_k β_k a_k,   β = simplex-QP dual (optim::qp)
//! ```
//!
//! The model value J_t(w_{t+1}) is a certified lower bound on the true
//! objective, giving BMRM's gap. The risk/subgradient pass decomposes
//! over data, so the simulated cluster executes it embarrassingly
//! parallel: measured wall time ÷ p + an allreduce of a d-vector.

use crate::config::TrainConfig;
use crate::coordinator::monitor::{EpochObserver, Monitor, TrainResult};
use crate::data::Dataset;
use crate::losses::{Loss, Problem, Regularizer};
use crate::net::CostModel;
use crate::optim::qp::solve_bmrm_dual;
use crate::util::timer::Stopwatch;
use anyhow::Result;

/// Empirical risk and subgradient at w, computed over row range.
fn risk_and_subgrad(ds: &Dataset, loss: Loss, w: &[f32], rows: std::ops::Range<usize>) -> (f64, Vec<f64>) {
    let mut risk = 0.0;
    let mut a = vec![0f64; ds.d()];
    for i in rows {
        let u = ds.x.row_dot(i, w);
        let y = ds.y[i] as f64;
        risk += loss.primal(u, y);
        let g = loss.primal_grad(u, y);
        if g != 0.0 {
            let (idx, val) = ds.x.row(i);
            for k in 0..idx.len() {
                a[idx[k] as usize] += g * val[k] as f64;
            }
        }
    }
    (risk, a)
}

#[deprecated(since = "0.1.0", note = "use dso::api::Trainer::algorithm(Algorithm::Bmrm)")]
pub fn train_bmrm(cfg: &TrainConfig, train: &Dataset, test: Option<&Dataset>) -> Result<TrainResult> {
    train_bmrm_with(cfg, train, test, None)
}

/// [`train_bmrm`] with an optional per-epoch observer (the
/// `dso::api::Trainer` facade's streaming hook).
pub fn train_bmrm_with(
    cfg: &TrainConfig,
    train: &Dataset,
    test: Option<&Dataset>,
    obs: Option<&mut dyn EpochObserver>,
) -> Result<TrainResult> {
    let loss = Loss::from(cfg.model.loss);
    let reg = Regularizer::from(cfg.model.reg);
    if reg != Regularizer::L2 {
        anyhow::bail!("BMRM baseline implements the paper's L2 (φ=w²) setting only");
    }
    let problem = Problem::new(loss, reg, cfg.model.lambda);
    let lambda = cfg.model.lambda;
    let m = train.m();
    let d = train.d();
    let p = cfg.workers().max(1);
    let cost = CostModel::new(
        cfg.cluster.latency_us,
        cfg.cluster.bandwidth_mbps,
        cfg.cluster.cores.max(1),
    );

    let mut w = vec![0f32; d];
    let mut planes_a: Vec<Vec<f64>> = Vec::new();
    let mut planes_b: Vec<f64> = Vec::new();
    let mut gram: Vec<Vec<f64>> = Vec::new();
    let mut monitor = Monitor::observed(cfg.monitor.every, obs);
    let wall = Stopwatch::new();
    let mut virtual_s = 0.0;
    let mut comm_bytes: u64 = 0;
    let mut best_lb = f64::NEG_INFINITY;
    let mut best_primal = f64::INFINITY;

    for t in 1..=cfg.optim.epochs {
        // --- risk + subgradient pass (parallel over data) ---
        let t0 = std::time::Instant::now();
        let (risk_sum, mut a) = risk_and_subgrad(train, loss, &w, 0..m);
        let grad_wall = t0.elapsed().as_secs_f64();
        let risk = risk_sum / m as f64;
        for v in a.iter_mut() {
            *v /= m as f64;
        }
        // Ideal data-parallel speedup + allreduce of the d-vector.
        let machines = cfg.cluster.machines.max(1);
        let mut allreduce_s = 0.0f64;
        for mach in 1..machines {
            let from_worker = mach * cfg.cluster.cores;
            if from_worker < p {
                allreduce_s =
                    allreduce_s.max(2.0 * cost.transfer_secs(from_worker, 0, 4 * d));
                comm_bytes += 2 * 4 * d as u64;
            }
        }
        virtual_s += grad_wall / p as f64 + allreduce_s;

        // --- extend the bundle ---
        let wt_dot_a: f64 = w.iter().zip(&a).map(|(&wj, &aj)| wj as f64 * aj).sum();
        let b_t = risk - wt_dot_a;
        // Gram row/column for the new plane.
        let mut row: Vec<f64> = planes_a
            .iter()
            .map(|ak| ak.iter().zip(&a).map(|(x, y)| x * y).sum())
            .collect();
        let self_dot: f64 = a.iter().map(|x| x * x).sum();
        row.push(self_dot);
        for (k, g) in gram.iter_mut().enumerate() {
            g.push(row[k]);
        }
        gram.push(row);
        planes_a.push(a);
        planes_b.push(b_t);

        // --- solve the model QP (leader) ---
        let tq = std::time::Instant::now();
        let sol = solve_bmrm_dual(&gram, &planes_b, lambda, 1e-10, 20_000);
        let qp_wall = tq.elapsed().as_secs_f64();
        virtual_s += qp_wall;

        // w_{t+1} = −(1/2λ) Σ β_k a_k.
        let mut w_next = vec![0f64; d];
        for (k, ak) in planes_a.iter().enumerate() {
            let bk = sol.beta[k];
            if bk > 1e-14 {
                for j in 0..d {
                    w_next[j] += bk * ak[j];
                }
            }
        }
        for j in 0..d {
            w[j] = (-w_next[j] / (2.0 * lambda)) as f32;
        }

        // Certified lower bound: model value at the new minimizer.
        best_lb = best_lb.max(sol.value);
        best_primal = best_primal.min(problem.primal(train, &w));

        if monitor.due(t) || t == cfg.optim.epochs {
            monitor.record_with_bound(
                &problem,
                train,
                test,
                &w,
                best_lb,
                t,
                virtual_s,
                wall.elapsed_secs(),
                t as u64,
                comm_bytes,
            );
        }
        // BMRM's own stopping rule.
        if best_primal - best_lb < 1e-9 * best_primal.abs().max(1.0) {
            break;
        }
    }

    let final_primal = problem.primal(train, &w);
    Ok(TrainResult {
        algorithm: "bmrm".into(),
        w,
        alpha: Vec::new(),
        history: monitor.history,
        final_primal,
        final_gap: final_primal - best_lb,
        total_updates: planes_a.len() as u64,
        total_virtual_s: virtual_s,
        total_wall_s: wall.elapsed_secs(),
        comm_bytes,
        failures: Vec::new(),
    })
}

#[cfg(test)]
// The shim entry points stay under test on purpose: these suites pin
// them bit-for-bit against the facade (see tests/trainer_api.rs).
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, TrainConfig};
    use crate::data::synth::SparseSpec;

    fn dataset(seed: u64) -> Dataset {
        SparseSpec {
            name: "bmrm-test".into(),
            m: 300,
            d: 60,
            nnz_per_row: 7.0,
            zipf_s: 0.6,
            label_noise: 0.03,
            pos_frac: 0.5,
            seed,
        }
        .generate()
    }

    fn cfg(iters: usize) -> TrainConfig {
        let mut c = TrainConfig::default();
        c.optim.algorithm = Algorithm::Bmrm;
        c.optim.epochs = iters;
        c.model.lambda = 1e-3;
        c.monitor.every = 1;
        c
    }

    #[test]
    fn converges_to_dcd_optimum() {
        let ds = dataset(1);
        let r = train_bmrm(&cfg(100), &ds, None).unwrap();
        let opt = crate::optim::dcd::solve_hinge_l2(&ds, 1e-3, 800, 1e-10, 1);
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 1e-3);
        let p_opt = p.primal(&ds, &opt.w);
        assert!(
            (r.final_primal - p_opt).abs() / p_opt < 0.02,
            "bmrm {} vs dcd {p_opt}",
            r.final_primal
        );
    }

    #[test]
    fn lower_bound_below_primal_and_tightening() {
        let ds = dataset(2);
        let r = train_bmrm(&cfg(40), &ds, None).unwrap();
        let primal = r.history.col("primal").unwrap();
        let dual = r.history.col("dual").unwrap();
        for (p, d) in primal.iter().zip(&dual) {
            assert!(d <= &(p + 1e-9), "lb {d} above primal {p}");
        }
        let gaps = r.history.col("gap").unwrap();
        assert!(gaps.last().unwrap() < &(gaps[0] * 0.2 + 1e-9));
    }

    #[test]
    fn logistic_converges() {
        let ds = dataset(3);
        let mut c = cfg(80);
        c.model.loss = crate::config::LossKind::Logistic;
        let r = train_bmrm(&c, &ds, None).unwrap();
        assert!(r.final_gap.abs() < 0.05 * r.final_primal.max(1e-9) + 1e-3,
            "gap {} primal {}", r.final_gap, r.final_primal);
    }

    #[test]
    fn l1_rejected() {
        let ds = dataset(4);
        let mut c = cfg(5);
        c.model.reg = crate::config::RegKind::L1;
        assert!(train_bmrm(&c, &ds, None).is_err());
    }

    #[test]
    fn parallel_speedup_in_virtual_time() {
        // Large enough m that the gradient pass dominates the QP, and
        // few iterations so bundle size stays tiny.
        let ds = SparseSpec {
            name: "bmrm-speedup".into(),
            m: 4000,
            d: 80,
            nnz_per_row: 10.0,
            zipf_s: 0.5,
            label_noise: 0.02,
            pos_frac: 0.5,
            seed: 5,
        }
        .generate();
        let mut c1 = cfg(4);
        c1.monitor.every = 0;
        c1.cluster.machines = 1;
        c1.cluster.cores = 1;
        c1.cluster.latency_us = 0.0;
        let r1 = train_bmrm(&c1, &ds, None).unwrap();
        let mut c8 = c1.clone();
        c8.cluster.machines = 8;
        c8.cluster.bandwidth_mbps = 1e9;
        let r8 = train_bmrm(&c8, &ds, None).unwrap();
        // Virtual compute should shrink with p (QP time identical).
        assert!(
            r8.total_virtual_s < r1.total_virtual_s,
            "8m {} vs 1m {}",
            r8.total_virtual_s,
            r1.total_virtual_s
        );
    }

    #[test]
    fn deterministic() {
        let ds = dataset(6);
        let c = cfg(10);
        let a = train_bmrm(&c, &ds, None).unwrap();
        let b = train_bmrm(&c, &ds, None).unwrap();
        assert_eq!(a.w, b.w);
    }
}
