//! The paper's comparison algorithms (§5): serial SGD with AdaGrad,
//! PSGD (Zinkevich et al.) for the distributed stochastic comparison,
//! and BMRM (Teo et al.) for the batch comparison.

pub mod bmrm;
pub mod psgd;
pub mod sgd;
