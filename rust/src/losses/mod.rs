//! Loss functions, their Fenchel duals (Table 1), regularizers, and the
//! primal / dual / saddle objective evaluations (Eq. 1, Eq. 6, Eq. 10).

pub mod loss;
pub mod objective;
pub mod regularizer;

pub use loss::Loss;
pub use objective::Problem;
pub use regularizer::Regularizer;
