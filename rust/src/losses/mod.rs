//! Loss functions, their Fenchel duals (Table 1), regularizers, and the
//! primal / dual / saddle objective evaluations (Eq. 1, Eq. 6, Eq. 10).

pub mod kernel;
pub mod loss;
pub mod objective;
pub mod regularizer;

pub use kernel::{AffineLossK, HingeK, L1K, L2K, Lane, LogisticK, LossK, RegK, SquareK};
pub use loss::Loss;
pub use objective::Problem;
pub use regularizer::Regularizer;
