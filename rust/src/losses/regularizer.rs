//! Regularizers φ_j(·) of Eq. (1). The paper instantiates φ_j(w) = w²
//! (square-norm, used in all experiments) and notes φ_j(w) = |w| gives
//! LASSO; both are implemented.

use crate::config::RegKind;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regularizer {
    /// φ(w) = w² — the paper's choice for SVM / logistic experiments.
    L2,
    /// φ(w) = |w| — LASSO-style.
    L1,
}

impl From<RegKind> for Regularizer {
    fn from(k: RegKind) -> Self {
        match k {
            RegKind::L2 => Regularizer::L2,
            RegKind::L1 => Regularizer::L1,
        }
    }
}

impl Regularizer {
    #[inline]
    pub fn value(self, w: f64) -> f64 {
        match self {
            Regularizer::L2 => w * w,
            Regularizer::L1 => w.abs(),
        }
    }

    /// (Sub)gradient ∇φ(w); sign(w) with 0 at the kink for L1.
    #[inline]
    pub fn grad(self, w: f64) -> f64 {
        match self {
            Regularizer::L2 => 2.0 * w,
            Regularizer::L1 => {
                if w > 0.0 {
                    1.0
                } else if w < 0.0 {
                    -1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Total regularizer λ Σ_j φ(w_j).
    pub fn total(self, lambda: f64, w: &[f32]) -> f64 {
        let mut s = 0.0;
        match self {
            Regularizer::L2 => {
                for &x in w {
                    s += x as f64 * x as f64;
                }
            }
            Regularizer::L1 => {
                for &x in w {
                    s += x.abs() as f64;
                }
            }
        }
        lambda * s
    }

    /// Closed-form minimizer of λφ(w) − c·w (used by the dual objective):
    /// L2: w* = c / (2λ); L1: w* = 0 when |c| ≤ λ (else the problem is
    /// unbounded — callers clamp c, see `objective::dual_objective`).
    #[inline]
    pub fn conjugate_argmin(self, c: f64, lambda: f64) -> f64 {
        match self {
            Regularizer::L2 => c / (2.0 * lambda),
            Regularizer::L1 => 0.0,
        }
    }

    /// min_w [λφ(w) − c·w]. For L1 the value is 0 inside the dual-ball
    /// |c| ≤ λ and −∞ outside; we return the clipped value (0), which
    /// yields the standard "clipped" dual for LASSO-type problems.
    #[inline]
    pub fn conjugate_min_value(self, c: f64, lambda: f64) -> f64 {
        match self {
            Regularizer::L2 => -c * c / (4.0 * lambda),
            Regularizer::L1 => 0.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Regularizer::L2 => "l2",
            Regularizer::L1 => "l1",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn values_and_grads() {
        assert_eq!(Regularizer::L2.value(3.0), 9.0);
        assert_eq!(Regularizer::L2.grad(3.0), 6.0);
        assert_eq!(Regularizer::L1.value(-2.0), 2.0);
        assert_eq!(Regularizer::L1.grad(-2.0), -1.0);
        assert_eq!(Regularizer::L1.grad(0.0), 0.0);
    }

    #[test]
    fn grad_is_derivative_of_value() {
        for reg in [Regularizer::L2, Regularizer::L1] {
            for &w in &[-2.0, -0.5, 0.4, 1.7] {
                let eps = 1e-6;
                let fd = (reg.value(w + eps) - reg.value(w - eps)) / (2.0 * eps);
                assert!((fd - reg.grad(w)).abs() < 1e-5, "{reg:?} at {w}");
            }
        }
    }

    #[test]
    fn total_sums() {
        let w = [1.0f32, -2.0, 0.5];
        assert!((Regularizer::L2.total(0.1, &w) - 0.1 * (1.0 + 4.0 + 0.25)).abs() < 1e-9);
        assert!((Regularizer::L1.total(2.0, &w) - 2.0 * 3.5).abs() < 1e-9);
    }

    #[test]
    fn l2_conjugate_argmin_minimizes() {
        let (lambda, c) = (0.3, 1.7);
        let w_star = Regularizer::L2.conjugate_argmin(c, lambda);
        let val = |w: f64| lambda * Regularizer::L2.value(w) - c * w;
        let v_star = val(w_star);
        assert!((v_star - Regularizer::L2.conjugate_min_value(c, lambda)).abs() < 1e-12);
        for &dw in &[-0.1, -0.01, 0.01, 0.1] {
            assert!(val(w_star + dw) >= v_star);
        }
    }

    #[test]
    fn l1_conjugate_inside_ball() {
        // |c| <= lambda: minimum of lambda|w| - c w is 0 at w = 0.
        let v = Regularizer::L1.conjugate_min_value(0.5, 1.0);
        assert_eq!(v, 0.0);
        assert_eq!(Regularizer::L1.conjugate_argmin(0.5, 1.0), 0.0);
    }

    #[test]
    fn from_regkind() {
        use crate::config::RegKind;
        assert_eq!(Regularizer::from(RegKind::L2), Regularizer::L2);
        assert_eq!(Regularizer::from(RegKind::L1), Regularizer::L1);
    }
}
