//! Compile-time kernel dispatch for the packed sweeps (§Perf).
//!
//! The scalar hot loop (`coordinator::updates::sweep_packed`) used to
//! pay an enum `match` on [`Loss`] and [`Regularizer`] for every
//! nonzero. These zero-sized marker types lift the choice to a generic
//! parameter so the `(Loss, Regularizer, StepRule)` combination is
//! resolved **once per sweep**: each of the 12 combinations
//! monomorphizes into its own straight-line loop where LLVM constant-
//! folds the match away (hinge's `h'(α) = y` hoists to the row level,
//! L2's `∇φ = 2w` fuses into the FMA, …).
//!
//! The scalar impls delegate to the enum methods with a `const`
//! discriminant — the numerical definitions live in exactly one place
//! ([`Loss`] / [`Regularizer`]), so the monomorphized scalar kernels
//! are bit-identical to the enum-dispatched reference path by
//! construction.
//!
//! For the SIMD sweep (`coordinator::updates::sweep_lanes`) the traits
//! additionally carry **lane-batched** methods over [`Lane`] =
//! `[f32; LANES]` arrays, routed through the
//! [`SimdBackend`](crate::simd::SimdBackend) the sweep was
//! monomorphized with ([`RegK::grad_lane_b`]): the
//! [`Portable`](crate::simd::Portable) backend is the PR 2 per-lane
//! loop (independent f32 ops — the shape stable-Rust LLVM reliably
//! auto-vectorizes to one 256-bit op per lane array, bit-identical to
//! the pre-backend kernels), the AVX2 backend issues the explicit
//! intrinsics. Both compute in f32 (that's the whole point: 8 lanes
//! per vector), so they are tolerance-equivalent, not bit-identical,
//! to the f64 scalar methods.

use super::{Loss, Regularizer};
use crate::partition::omega::LANES;
use crate::simd::{Portable, SimdBackend};

/// One SIMD-width batch of f32 values (8 × f32 = one 256-bit vector).
pub type Lane = [f32; LANES];

/// Two adjacent lane chunks fused into one step (16 × f32 = one
/// 512-bit vector). The lane-major block layout is unchanged — a
/// `Lane2` is always the concatenation of two *adjacent* 8-wide chunks
/// of the same row group, so backends without 512-bit registers
/// process it as two [`Lane`] halves (the trait defaults) and AVX-512
/// processes it as one register.
pub const LANES2: usize = 2 * LANES;
pub type Lane2 = [f32; LANES2];

/// Loss selected at compile time. `dual_grad`/`project` match
/// [`Loss::dual_utility_grad`] / [`Loss::project_alpha`] exactly.
///
/// No lane-batched methods: the α recurrence is sequential within a
/// row group (every entry of a group updates the *same* α_i), so the
/// lane kernel keeps the loss math scalar — see
/// `coordinator::updates::sweep_lanes`. Losses whose recurrence *does*
/// have exploitable structure additionally implement [`AffineLossK`]
/// and advertise it through [`LossK::AFFINE_ALPHA`].
pub trait LossK: Copy + Send + Sync + 'static {
    const LOSS: Loss;

    /// Whether this loss implements [`AffineLossK`] — i.e. h'(α, y) is
    /// affine in α *and* the dual projection is the identity, so the α
    /// recurrence of a lane chunk composes into a closed-form affine
    /// map. The engines' runtime mirror is [`Loss::affine_alpha`];
    /// `kernels_match_enum_dispatch` pins the two together.
    const AFFINE_ALPHA: bool = false;

    #[inline(always)]
    fn dual_grad(alpha: f64, y: f64) -> f64 {
        Self::LOSS.dual_utility_grad(alpha, y)
    }

    #[inline(always)]
    fn project(alpha: f64, y: f64) -> f64 {
        Self::LOSS.project_alpha(alpha, y)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct HingeK;
#[derive(Clone, Copy, Debug)]
pub struct LogisticK;
#[derive(Clone, Copy, Debug)]
pub struct SquareK;

impl LossK for HingeK {
    const LOSS: Loss = Loss::Hinge;
}
impl LossK for LogisticK {
    const LOSS: Loss = Loss::Logistic;
}
impl LossK for SquareK {
    const LOSS: Loss = Loss::Square;
    const AFFINE_ALPHA: bool = true;
}

/// Capability trait for losses whose α side of update (8) is an
/// **affine map**: the dual gradient decomposes as
///
/// ```text
///     h'(α, y) = dual_bias(y) + DUAL_SLOPE · α
/// ```
///
/// with a constant slope, *and* the dual feasible set is all of ℝ
/// (`project` is the identity), so one saddle step on α is
///
/// ```text
///     α ← α + η·g_α = (1 + η·DUAL_SLOPE·hr)·α + η·(dual_bias(y)·hr − w·x)
/// ```
///
/// (hr = 1/(m|Ω_i|)) — an affine map α ← a·α + b whose composition
/// over a lane chunk has a closed form, exploited by
/// `coordinator::updates::sweep_lanes_affine`: the α-independent
/// coefficients
/// are evaluated in 8-wide f32 lanes and the chunk folds into α with
/// one FMA per entry, instead of 8 sequential gradient/projection
/// evaluations.
///
/// Only the square loss qualifies: h'(α) = y − α (slope −1, bias y,
/// α ∈ ℝ). Hinge and logistic have constant/transcendental duals whose
/// per-entry *projection* is load-bearing, so they keep the sequential
/// scalar recurrence of `sweep_lanes`.
pub trait AffineLossK: LossK {
    /// ∂h'/∂α — the constant slope of the affine dual gradient.
    const DUAL_SLOPE: f64;

    /// The α-independent part of h'(α, y).
    fn dual_bias(y: f64) -> f64;
}

impl AffineLossK for SquareK {
    const DUAL_SLOPE: f64 = -1.0;

    /// Square loss: h'(α, y) = y − α.
    #[inline(always)]
    fn dual_bias(y: f64) -> f64 {
        y
    }
}

/// Regularizer selected at compile time. `grad` matches
/// [`Regularizer::grad`] exactly; `grad_lane` is its 8-wide f32 batch
/// (same subgradient definition, f32 precision).
pub trait RegK: Copy + Send + Sync + 'static {
    const REG: Regularizer;

    #[inline(always)]
    fn grad(w: f64) -> f64 {
        Self::REG.grad(w)
    }

    /// Lane-batched ∇φ over 8 f32 weights, on the sweep's SIMD
    /// backend. The concrete impls below route to the backend's
    /// single-multiply (L2) / sign-select (L1) op; this default
    /// delegates per lane to the f64 definition (correct but
    /// round-trips through f64) so exotic future regularizers work
    /// before they grow a backend op.
    #[inline(always)]
    fn grad_lane_b<B: SimdBackend>(w: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            out[k] = Self::REG.grad(w[k] as f64) as f32;
        }
        out
    }

    /// Paired-chunk ∇φ over 16 f32 weights — the fused step of a
    /// [`SimdBackend::PAIRED`] backend. The default splits into two
    /// 8-wide calls (exactly what a non-paired backend would have
    /// computed); the concrete impls below route to the backend's
    /// single 512-bit op instead.
    #[inline(always)]
    fn grad_lane2_b<B: SimdBackend>(w: &Lane2) -> Lane2 {
        let mut out = [0f32; LANES2];
        for k in 0..LANES2 {
            out[k] = Self::REG.grad(w[k] as f64) as f32;
        }
        out
    }

    /// Portable-backend ∇φ lanes — the PR 2 entry point, kept so
    /// existing differential tests keep reading naturally.
    #[inline(always)]
    fn grad_lane(w: &Lane) -> Lane {
        Self::grad_lane_b::<Portable>(w)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct L1K;
#[derive(Clone, Copy, Debug)]
pub struct L2K;

impl RegK for L1K {
    const REG: Regularizer = Regularizer::L1;

    /// sign(w) with 0 at the kink — exact in f32 on every backend
    /// (portable: branch-free select after vectorization; AVX2:
    /// compare + mask-select).
    #[inline(always)]
    fn grad_lane_b<B: SimdBackend>(w: &Lane) -> Lane {
        B::l1_grad_lane(w)
    }

    #[inline(always)]
    fn grad_lane2_b<B: SimdBackend>(w: &Lane2) -> Lane2 {
        B::l1_grad_lane2(w)
    }
}
impl RegK for L2K {
    const REG: Regularizer = Regularizer::L2;

    /// 2·w — exact in f32 on every backend.
    #[inline(always)]
    fn grad_lane_b<B: SimdBackend>(w: &Lane) -> Lane {
        B::l2_grad_lane(w)
    }

    #[inline(always)]
    fn grad_lane2_b<B: SimdBackend>(w: &Lane2) -> Lane2 {
        B::l2_grad_lane2(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernels_match_enum_dispatch() {
        for &(a, y) in &[(0.3, 1.0), (-0.7, -1.0), (0.99, 1.0), (0.0, -1.0)] {
            assert_eq!(HingeK::dual_grad(a, y), Loss::Hinge.dual_utility_grad(a, y));
            assert_eq!(LogisticK::dual_grad(a, y), Loss::Logistic.dual_utility_grad(a, y));
            assert_eq!(SquareK::dual_grad(a, y), Loss::Square.dual_utility_grad(a, y));
            assert_eq!(HingeK::project(a, y), Loss::Hinge.project_alpha(a, y));
            assert_eq!(LogisticK::project(a, y), Loss::Logistic.project_alpha(a, y));
            assert_eq!(SquareK::project(a, y), Loss::Square.project_alpha(a, y));
        }
        for &w in &[-1.5, 0.0, 0.4] {
            assert_eq!(L1K::grad(w), Regularizer::L1.grad(w));
            assert_eq!(L2K::grad(w), Regularizer::L2.grad(w));
        }
        // The compile-time capability flag and its runtime mirror must
        // agree, or the engines would dispatch the wrong kernel.
        assert_eq!(HingeK::AFFINE_ALPHA, Loss::Hinge.affine_alpha());
        assert_eq!(LogisticK::AFFINE_ALPHA, Loss::Logistic.affine_alpha());
        assert_eq!(SquareK::AFFINE_ALPHA, Loss::Square.affine_alpha());
    }

    /// The [`AffineLossK`] contract for the square loss: the bias/slope
    /// decomposition reproduces h'(α, y) exactly, and the projection is
    /// the identity (both bitwise — the affine kernel relies on them).
    #[test]
    fn square_affine_decomposition_matches_dual_grad() {
        for &y in &[1.0, -1.0, 3.0, -0.25] {
            for &a in &[-7.5, -1.0, -1e-3, 0.0, 0.4, 2.0, 100.0] {
                assert_eq!(
                    SquareK::dual_bias(y) + SquareK::DUAL_SLOPE * a,
                    Loss::Square.dual_utility_grad(a, y),
                    "y={y} α={a}"
                );
                assert_eq!(SquareK::project(a, y), a, "projection must be identity");
            }
        }
    }

    #[test]
    fn grad_lane_matches_scalar_grad_per_lane() {
        let w: Lane = [-1.5, -0.25, 0.0, 0.4, 1.0, -0.0, 3.25, -7.5];
        let l1 = L1K::grad_lane(&w);
        let l2 = L2K::grad_lane(&w);
        for k in 0..LANES {
            // These inputs and outputs are exactly representable in
            // f32, so lane and scalar agree bitwise.
            assert_eq!(l1[k] as f64, Regularizer::L1.grad(w[k] as f64), "l1 lane {k}");
            assert_eq!(l2[k] as f64, Regularizer::L2.grad(w[k] as f64), "l2 lane {k}");
        }
        // -0.0 sits on the kink for L1 (sign convention: 0).
        assert_eq!(l1[5], 0.0);
    }

    /// The paired-chunk reg gradient is definitionally two adjacent
    /// 8-wide chunks: the default (and every backend's pair op, pinned
    /// in `simd::backend`) must match the lane op half-by-half bitwise.
    #[test]
    fn grad_lane2_is_two_lane_halves_bitwise() {
        let w2: Lane2 = [
            -1.5, -0.25, 0.0, 0.4, 1.0, -0.0, 3.25, -7.5, //
            2.0, -3.0, 0.125, -0.5, 9.0, -0.0, 0.0, 1e-3,
        ];
        let (mut lo, mut hi) = ([0f32; LANES], [0f32; LANES]);
        lo.copy_from_slice(&w2[..LANES]);
        hi.copy_from_slice(&w2[LANES..]);
        for (pair, a, b) in [
            (L1K::grad_lane2_b::<Portable>(&w2), L1K::grad_lane(&lo), L1K::grad_lane(&hi)),
            (L2K::grad_lane2_b::<Portable>(&w2), L2K::grad_lane(&lo), L2K::grad_lane(&hi)),
        ] {
            for k in 0..LANES {
                assert_eq!(pair[k].to_bits(), a[k].to_bits(), "lo lane {k}");
                assert_eq!(pair[LANES + k].to_bits(), b[k].to_bits(), "hi lane {k}");
            }
        }
    }
}
