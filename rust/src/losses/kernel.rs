//! Compile-time kernel dispatch for the packed sweeps (§Perf).
//!
//! The scalar hot loop (`coordinator::updates::sweep_packed`) used to
//! pay an enum `match` on [`Loss`] and [`Regularizer`] for every
//! nonzero. These zero-sized marker types lift the choice to a generic
//! parameter so the `(Loss, Regularizer, StepRule)` combination is
//! resolved **once per sweep**: each of the 12 combinations
//! monomorphizes into its own straight-line loop where LLVM constant-
//! folds the match away (hinge's `h'(α) = y` hoists to the row level,
//! L2's `∇φ = 2w` fuses into the FMA, …).
//!
//! The scalar impls delegate to the enum methods with a `const`
//! discriminant — the numerical definitions live in exactly one place
//! ([`Loss`] / [`Regularizer`]), so the monomorphized scalar kernels
//! are bit-identical to the enum-dispatched reference path by
//! construction.
//!
//! For the SIMD sweep (`coordinator::updates::sweep_lanes`) the traits
//! additionally carry **lane-batched** methods over [`Lane`] =
//! `[f32; LANES]` arrays. These are written as plain per-lane loops of
//! independent f32 operations — the shape stable-Rust LLVM reliably
//! auto-vectorizes to one 256-bit op per lane array, with no `std::simd`
//! dependency. They compute in f32 (that's the whole point: 8 lanes per
//! vector), so they are tolerance-equivalent, not bit-identical, to the
//! f64 scalar methods.

use super::{Loss, Regularizer};
use crate::partition::omega::LANES;

/// One SIMD-width batch of f32 values (8 × f32 = one 256-bit vector).
pub type Lane = [f32; LANES];

/// Loss selected at compile time. `dual_grad`/`project` match
/// [`Loss::dual_utility_grad`] / [`Loss::project_alpha`] exactly.
///
/// No lane-batched methods: the α recurrence is sequential within a
/// row group (every entry of a group updates the *same* α_i), so the
/// lane kernel keeps the loss math scalar — see
/// `coordinator::updates::sweep_lanes`.
pub trait LossK: Copy + Send + Sync + 'static {
    const LOSS: Loss;

    #[inline(always)]
    fn dual_grad(alpha: f64, y: f64) -> f64 {
        Self::LOSS.dual_utility_grad(alpha, y)
    }

    #[inline(always)]
    fn project(alpha: f64, y: f64) -> f64 {
        Self::LOSS.project_alpha(alpha, y)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct HingeK;
#[derive(Clone, Copy, Debug)]
pub struct LogisticK;
#[derive(Clone, Copy, Debug)]
pub struct SquareK;

impl LossK for HingeK {
    const LOSS: Loss = Loss::Hinge;
}
impl LossK for LogisticK {
    const LOSS: Loss = Loss::Logistic;
}
impl LossK for SquareK {
    const LOSS: Loss = Loss::Square;
}

/// Regularizer selected at compile time. `grad` matches
/// [`Regularizer::grad`] exactly; `grad_lane` is its 8-wide f32 batch
/// (same subgradient definition, f32 precision).
pub trait RegK: Copy + Send + Sync + 'static {
    const REG: Regularizer;

    #[inline(always)]
    fn grad(w: f64) -> f64 {
        Self::REG.grad(w)
    }

    /// Lane-batched ∇φ over 8 f32 weights. Default: per-lane delegation
    /// to the f64 definition (correct but round-trips through f64);
    /// the concrete impls below override with pure-f32 bodies that
    /// vectorize to a single multiply / sign-select.
    #[inline(always)]
    fn grad_lane(w: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            out[k] = Self::REG.grad(w[k] as f64) as f32;
        }
        out
    }
}

#[derive(Clone, Copy, Debug)]
pub struct L1K;
#[derive(Clone, Copy, Debug)]
pub struct L2K;

impl RegK for L1K {
    const REG: Regularizer = Regularizer::L1;

    #[inline(always)]
    fn grad_lane(w: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            // sign(w) with 0 at the kink — exact in f32, branch-free
            // select after vectorization.
            out[k] = if w[k] > 0.0 {
                1.0
            } else if w[k] < 0.0 {
                -1.0
            } else {
                0.0
            };
        }
        out
    }
}
impl RegK for L2K {
    const REG: Regularizer = Regularizer::L2;

    #[inline(always)]
    fn grad_lane(w: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            out[k] = 2.0 * w[k];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernels_match_enum_dispatch() {
        for &(a, y) in &[(0.3, 1.0), (-0.7, -1.0), (0.99, 1.0), (0.0, -1.0)] {
            assert_eq!(HingeK::dual_grad(a, y), Loss::Hinge.dual_utility_grad(a, y));
            assert_eq!(LogisticK::dual_grad(a, y), Loss::Logistic.dual_utility_grad(a, y));
            assert_eq!(SquareK::dual_grad(a, y), Loss::Square.dual_utility_grad(a, y));
            assert_eq!(HingeK::project(a, y), Loss::Hinge.project_alpha(a, y));
            assert_eq!(LogisticK::project(a, y), Loss::Logistic.project_alpha(a, y));
            assert_eq!(SquareK::project(a, y), Loss::Square.project_alpha(a, y));
        }
        for &w in &[-1.5, 0.0, 0.4] {
            assert_eq!(L1K::grad(w), Regularizer::L1.grad(w));
            assert_eq!(L2K::grad(w), Regularizer::L2.grad(w));
        }
    }

    #[test]
    fn grad_lane_matches_scalar_grad_per_lane() {
        let w: Lane = [-1.5, -0.25, 0.0, 0.4, 1.0, -0.0, 3.25, -7.5];
        let l1 = L1K::grad_lane(&w);
        let l2 = L2K::grad_lane(&w);
        for k in 0..LANES {
            // These inputs and outputs are exactly representable in
            // f32, so lane and scalar agree bitwise.
            assert_eq!(l1[k] as f64, Regularizer::L1.grad(w[k] as f64), "l1 lane {k}");
            assert_eq!(l2[k] as f64, Regularizer::L2.grad(w[k] as f64), "l2 lane {k}");
        }
        // -0.0 sits on the kink for L1 (sign convention: 0).
        assert_eq!(l1[5], 0.0);
    }
}
