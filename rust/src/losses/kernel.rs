//! Compile-time kernel dispatch for the packed sweep (§Perf).
//!
//! The scalar hot loop (`coordinator::updates::sweep_packed`) used to
//! pay an enum `match` on [`Loss`] and [`Regularizer`] for every
//! nonzero. These zero-sized marker types lift the choice to a generic
//! parameter so the `(Loss, Regularizer, StepRule)` combination is
//! resolved **once per sweep**: each of the 12 combinations
//! monomorphizes into its own straight-line loop where LLVM constant-
//! folds the match away (hinge's `h'(α) = y` hoists to the row level,
//! L2's `∇φ = 2w` fuses into the FMA, …).
//!
//! The impls delegate to the enum methods with a `const` discriminant —
//! the numerical definitions live in exactly one place ([`Loss`] /
//! [`Regularizer`]), so the monomorphized kernels are bit-identical to
//! the enum-dispatched reference path by construction.

use super::{Loss, Regularizer};

/// Loss selected at compile time. `dual_grad`/`project` match
/// [`Loss::dual_utility_grad`] / [`Loss::project_alpha`] exactly.
pub trait LossK: Copy + Send + Sync + 'static {
    const LOSS: Loss;

    #[inline(always)]
    fn dual_grad(alpha: f64, y: f64) -> f64 {
        Self::LOSS.dual_utility_grad(alpha, y)
    }

    #[inline(always)]
    fn project(alpha: f64, y: f64) -> f64 {
        Self::LOSS.project_alpha(alpha, y)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct HingeK;
#[derive(Clone, Copy, Debug)]
pub struct LogisticK;
#[derive(Clone, Copy, Debug)]
pub struct SquareK;

impl LossK for HingeK {
    const LOSS: Loss = Loss::Hinge;
}
impl LossK for LogisticK {
    const LOSS: Loss = Loss::Logistic;
}
impl LossK for SquareK {
    const LOSS: Loss = Loss::Square;
}

/// Regularizer selected at compile time. `grad` matches
/// [`Regularizer::grad`] exactly.
pub trait RegK: Copy + Send + Sync + 'static {
    const REG: Regularizer;

    #[inline(always)]
    fn grad(w: f64) -> f64 {
        Self::REG.grad(w)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct L1K;
#[derive(Clone, Copy, Debug)]
pub struct L2K;

impl RegK for L1K {
    const REG: Regularizer = Regularizer::L1;
}
impl RegK for L2K {
    const REG: Regularizer = Regularizer::L2;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernels_match_enum_dispatch() {
        for &(a, y) in &[(0.3, 1.0), (-0.7, -1.0), (0.99, 1.0), (0.0, -1.0)] {
            assert_eq!(HingeK::dual_grad(a, y), Loss::Hinge.dual_utility_grad(a, y));
            assert_eq!(LogisticK::dual_grad(a, y), Loss::Logistic.dual_utility_grad(a, y));
            assert_eq!(SquareK::dual_grad(a, y), Loss::Square.dual_utility_grad(a, y));
            assert_eq!(HingeK::project(a, y), Loss::Hinge.project_alpha(a, y));
            assert_eq!(LogisticK::project(a, y), Loss::Logistic.project_alpha(a, y));
            assert_eq!(SquareK::project(a, y), Loss::Square.project_alpha(a, y));
        }
        for &w in &[-1.5, 0.0, 0.4] {
            assert_eq!(L1K::grad(w), Regularizer::L1.grad(w));
            assert_eq!(L2K::grad(w), Regularizer::L2.grad(w));
        }
    }
}
