//! Loss functions and their Fenchel duals (paper Table 1).
//!
//! For each supported loss ℓ_i(u) = ℓ(u, y_i) we need, besides the
//! primal value/derivative, the *dual utility*
//!
//! ```text
//!     h(α, y) := −ℓ*(−α)
//! ```
//!
//! (ℓ* the Fenchel–Legendre conjugate of ℓ(·, y)), its derivative
//! h'(α, y) — which is the `−∇ℓ*(−α_i)` appearing in update (8) — and
//! the dual feasible interval onto which α_i is projected (App. B).
//!
//! Table 1 with our parameterization (β := y·α ∈ [0, 1]):
//!
//! ```text
//!   hinge:    ℓ = max(0, 1−yu)        h = y·α          β ∈ [0, 1]
//!   logistic: ℓ = log(1+exp(−yu))     h = H(β)         β ∈ (0, 1)
//!             (H the binary entropy −β ln β − (1−β) ln(1−β))
//!   square:   ℓ = (u−y)²/2            h = y·α − α²/2   α ∈ ℝ
//! ```
//!
//! Enum (not trait-object) dispatch so the scalar update loop inlines.

use crate::config::LossKind;

/// Margin clamp for the logistic dual (App. B: values projected to lie
/// in (1e−14, 1−1e−14) to prevent degeneracy of the entropy terms).
pub const LOGISTIC_EPS: f64 = 1e-14;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    Hinge,
    Logistic,
    Square,
}

impl From<LossKind> for Loss {
    fn from(k: LossKind) -> Self {
        match k {
            LossKind::Hinge => Loss::Hinge,
            LossKind::Logistic => Loss::Logistic,
            LossKind::Square => Loss::Square,
        }
    }
}

impl Loss {
    /// Primal loss ℓ(u, y).
    #[inline]
    pub fn primal(self, u: f64, y: f64) -> f64 {
        match self {
            Loss::Hinge => (1.0 - y * u).max(0.0),
            Loss::Logistic => {
                // Numerically stable log(1 + exp(-yu)).
                let z = -y * u;
                if z > 35.0 {
                    z
                } else {
                    z.exp().ln_1p()
                }
            }
            Loss::Square => 0.5 * (u - y) * (u - y),
        }
    }

    /// Primal (sub)derivative dℓ/du.
    #[inline]
    pub fn primal_grad(self, u: f64, y: f64) -> f64 {
        match self {
            Loss::Hinge => {
                if y * u < 1.0 {
                    -y
                } else {
                    0.0
                }
            }
            Loss::Logistic => {
                let z = -y * u;
                // -y * sigmoid(-yu), stable in both tails.
                let s = if z >= 0.0 {
                    1.0 / (1.0 + (-z).exp())
                } else {
                    let e = z.exp();
                    e / (1.0 + e)
                };
                -y * s
            }
            Loss::Square => u - y,
        }
    }

    /// Dual utility h(α, y) = −ℓ*(−α). Callers must pass a feasible α
    /// (use [`Loss::project_alpha`]); infeasible hinge/logistic α return
    /// −∞ consistent with the conjugate's domain.
    #[inline]
    pub fn dual_utility(self, alpha: f64, y: f64) -> f64 {
        match self {
            Loss::Hinge => {
                let beta = y * alpha;
                if (-1e-12..=1.0 + 1e-12).contains(&beta) {
                    y * alpha
                } else {
                    f64::NEG_INFINITY
                }
            }
            Loss::Logistic => {
                let beta = y * alpha;
                if (0.0..=1.0).contains(&beta) {
                    entropy(beta)
                } else {
                    f64::NEG_INFINITY
                }
            }
            Loss::Square => y * alpha - 0.5 * alpha * alpha,
        }
    }

    /// h'(α, y) — the `−∇ℓ*(−α_i)` of update (8). Feasible α assumed;
    /// for logistic the derivative is evaluated at the ε-clamped β.
    #[inline]
    pub fn dual_utility_grad(self, alpha: f64, y: f64) -> f64 {
        match self {
            Loss::Hinge => y,
            Loss::Logistic => {
                let beta = (y * alpha).clamp(LOGISTIC_EPS, 1.0 - LOGISTIC_EPS);
                y * ((1.0 - beta) / beta).ln()
            }
            Loss::Square => y - alpha,
        }
    }

    /// Project α onto the dual feasible set (App. B): β = yα clamped to
    /// [0,1] (hinge), (ε, 1−ε) (logistic); identity for square loss.
    #[inline]
    pub fn project_alpha(self, alpha: f64, y: f64) -> f64 {
        match self {
            Loss::Hinge => y * (y * alpha).clamp(0.0, 1.0),
            Loss::Logistic => y * (y * alpha).clamp(LOGISTIC_EPS, 1.0 - LOGISTIC_EPS),
            Loss::Square => alpha,
        }
    }

    /// Box bound B for the primal weights (App. B): |w_j| ≤ 1/√λ for
    /// SVM, √(log 2 / λ) for logistic. Square loss gets the SVM bound
    /// (the paper does not run square loss; the bound keeps iterates
    /// compact, satisfying Theorem 1's bounded-diameter assumption).
    #[inline]
    pub fn w_bound(self, lambda: f64) -> f64 {
        match self {
            Loss::Hinge | Loss::Square => 1.0 / lambda.sqrt(),
            Loss::Logistic => (std::f64::consts::LN_2 / lambda).sqrt(),
        }
    }

    /// Initial α recommended by App. B: 0 for SVM, 0.0005·y for
    /// logistic (strictly inside the open feasible interval).
    #[inline]
    pub fn alpha_init(self, y: f64) -> f64 {
        match self {
            Loss::Hinge | Loss::Square => 0.0,
            Loss::Logistic => 0.0005 * y,
        }
    }

    /// Whether the α side of update (8) is an affine map for this loss
    /// — h'(α) affine in α with an identity projection — so the lane
    /// engines may dispatch the closed-form α kernel
    /// (`coordinator::updates::sweep_lanes_affine`). Runtime mirror of
    /// the compile-time `losses::kernel::LossK::AFFINE_ALPHA` /
    /// [`losses::kernel::AffineLossK`](crate::losses::AffineLossK)
    /// capability (tied together by test). True only for the square
    /// loss: h'(α) = y − α, α ∈ ℝ.
    #[inline]
    pub fn affine_alpha(self) -> bool {
        matches!(self, Loss::Square)
    }

    pub fn name(self) -> &'static str {
        match self {
            Loss::Hinge => "hinge",
            Loss::Logistic => "logistic",
            Loss::Square => "square",
        }
    }
}

/// Binary entropy H(β) = −β ln β − (1−β) ln(1−β), with the 0·ln 0 = 0
/// convention.
#[inline]
pub fn entropy(beta: f64) -> f64 {
    let mut h = 0.0;
    if beta > 0.0 {
        h -= beta * beta.ln();
    }
    if beta < 1.0 {
        h -= (1.0 - beta) * (1.0 - beta).ln();
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    const LOSSES: [Loss; 3] = [Loss::Hinge, Loss::Logistic, Loss::Square];

    #[test]
    fn hinge_primal_values() {
        assert_eq!(Loss::Hinge.primal(0.0, 1.0), 1.0);
        assert_eq!(Loss::Hinge.primal(2.0, 1.0), 0.0);
        assert_eq!(Loss::Hinge.primal(-1.0, 1.0), 2.0);
        assert_eq!(Loss::Hinge.primal(-2.0, -1.0), 0.0);
    }

    #[test]
    fn logistic_primal_stable() {
        let l = Loss::Logistic;
        assert!((l.primal(0.0, 1.0) - std::f64::consts::LN_2).abs() < 1e-12);
        // Large margins: loss → 0; large negative margins: loss ≈ |yu|.
        assert!(l.primal(100.0, 1.0) < 1e-12);
        assert!((l.primal(-100.0, 1.0) - 100.0).abs() < 1e-9);
        assert!(l.primal(1e6, 1.0).is_finite());
        assert!(l.primal(-1e6, 1.0).is_finite());
    }

    #[test]
    fn square_primal() {
        assert_eq!(Loss::Square.primal(3.0, 1.0), 2.0);
        assert_eq!(Loss::Square.primal(1.0, 1.0), 0.0);
    }

    #[test]
    fn primal_grad_matches_finite_difference() {
        let eps = 1e-6;
        for loss in LOSSES {
            for &y in &[1.0, -1.0] {
                for &u in &[-2.0, -0.5, 0.3, 0.99, 1.7] {
                    // Skip hinge kink.
                    if loss == Loss::Hinge && (y * u - 1.0f64).abs() < 1e-3 {
                        continue;
                    }
                    let fd = (loss.primal(u + eps, y) - loss.primal(u - eps, y)) / (2.0 * eps);
                    let g = loss.primal_grad(u, y);
                    assert!(
                        (fd - g).abs() < 1e-5,
                        "{loss:?} y={y} u={u}: fd {fd} vs {g}"
                    );
                }
            }
        }
    }

    #[test]
    fn dual_grad_matches_finite_difference() {
        let eps = 1e-7;
        for loss in LOSSES {
            for &y in &[1.0, -1.0] {
                for &beta in &[0.2, 0.5, 0.8] {
                    let alpha = y * beta;
                    let fd = (loss.dual_utility(alpha + eps, y)
                        - loss.dual_utility(alpha - eps, y))
                        / (2.0 * eps);
                    let g = loss.dual_utility_grad(alpha, y);
                    assert!(
                        (fd - g).abs() < 1e-4,
                        "{loss:?} y={y} α={alpha}: fd {fd} vs {g}"
                    );
                }
            }
        }
    }

    /// Fenchel–Young: ℓ(u) + ℓ*(−α) ≥ −u·α, with equality at the
    /// maximizing α. Equivalently ℓ(u) ≥ h(α) − u·α... checking the
    /// inequality over a grid validates the Table 1 conjugate pairs.
    #[test]
    fn fenchel_young_inequality() {
        for loss in LOSSES {
            for &y in &[1.0, -1.0] {
                for iu in -20..=20 {
                    let u = iu as f64 * 0.25;
                    for ib in 1..20 {
                        let alpha = match loss {
                            Loss::Square => -2.0 + 4.0 * ib as f64 / 20.0,
                            _ => y * (ib as f64 / 20.0),
                        };
                        let lhs = loss.primal(u, y);
                        let rhs = loss.dual_utility(alpha, y) - u * alpha;
                        assert!(
                            lhs >= rhs - 1e-9,
                            "{loss:?} y={y} u={u} α={alpha}: {lhs} < {rhs}"
                        );
                    }
                }
            }
        }
    }

    /// sup_α [h(α) − uα] should recover ℓ(u) (biconjugation; ℓ convex
    /// closed). Grid-maximize and compare.
    #[test]
    fn biconjugation_recovers_primal() {
        for loss in LOSSES {
            for &y in &[1.0, -1.0] {
                for &u in &[-1.5, -0.3, 0.0, 0.7, 2.0] {
                    let mut best = f64::NEG_INFINITY;
                    for k in 0..=4000 {
                        let alpha = match loss {
                            Loss::Square => -4.0 + 8.0 * k as f64 / 4000.0,
                            _ => y * (k as f64 / 4000.0),
                        };
                        let v = loss.dual_utility(alpha, y) - u * alpha;
                        if v > best {
                            best = v;
                        }
                    }
                    let lhs = loss.primal(u, y);
                    let tol = match loss {
                        Loss::Square => 1e-3, // grid resolution
                        _ => 2e-3,
                    };
                    assert!(
                        (lhs - best).abs() < tol,
                        "{loss:?} y={y} u={u}: primal {lhs} vs sup {best}"
                    );
                }
            }
        }
    }

    #[test]
    fn projection_feasible_and_idempotent() {
        for loss in LOSSES {
            for &y in &[1.0, -1.0] {
                for &a in &[-5.0, -0.5, 0.0, 0.3, 0.9, 1.0, 7.0] {
                    let p = loss.project_alpha(a, y);
                    let pp = loss.project_alpha(p, y);
                    assert!(
                        (p - pp).abs() < 1e-15,
                        "{loss:?} projection not idempotent at {a}"
                    );
                    assert!(loss.dual_utility(p, y).is_finite(), "{loss:?} infeasible {p}");
                }
            }
        }
    }

    #[test]
    fn hinge_projection_box() {
        assert_eq!(Loss::Hinge.project_alpha(2.0, 1.0), 1.0);
        assert_eq!(Loss::Hinge.project_alpha(-0.5, 1.0), 0.0);
        assert_eq!(Loss::Hinge.project_alpha(-2.0, -1.0), -1.0);
        assert_eq!(Loss::Hinge.project_alpha(0.5, -1.0), 0.0);
    }

    #[test]
    fn logistic_projection_open_interval() {
        let p = Loss::Logistic.project_alpha(0.0, 1.0);
        assert!(p > 0.0 && p < 1e-10);
        let q = Loss::Logistic.project_alpha(1.0, 1.0);
        assert!(q < 1.0);
        assert!(Loss::Logistic.dual_utility_grad(p, 1.0).is_finite());
    }

    #[test]
    fn w_bounds_match_appendix_b() {
        let lam = 0.01;
        assert!((Loss::Hinge.w_bound(lam) - 10.0).abs() < 1e-12);
        assert!(
            (Loss::Logistic.w_bound(lam) - (std::f64::consts::LN_2 / lam).sqrt()).abs() < 1e-12
        );
    }

    #[test]
    fn alpha_init_feasible() {
        for loss in LOSSES {
            for &y in &[1.0, -1.0] {
                let a = loss.alpha_init(y);
                assert!(loss.dual_utility(a, y).is_finite());
            }
        }
        assert_eq!(Loss::Logistic.alpha_init(-1.0), -0.0005);
    }

    #[test]
    fn entropy_endpoints() {
        assert_eq!(entropy(0.0), 0.0);
        assert_eq!(entropy(1.0), 0.0);
        assert!((entropy(0.5) - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn from_losskind() {
        use crate::config::LossKind;
        assert_eq!(Loss::from(LossKind::Hinge), Loss::Hinge);
        assert_eq!(Loss::from(LossKind::Logistic), Loss::Logistic);
        assert_eq!(Loss::from(LossKind::Square), Loss::Square);
    }
}
