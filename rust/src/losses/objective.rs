//! Objective evaluation: the primal regularized risk P(w) of Eq. (1),
//! the dual objective D(α) = min_w f(w, α), the saddle value f(w, α)
//! of Eq. (6), and the duality gap ε(w, α) = P(w) − D(α) used as the
//! convergence measure throughout the paper (Theorem 1).

use super::loss::Loss;
use super::regularizer::Regularizer;
use crate::data::Dataset;

/// Problem definition shared by all solvers.
#[derive(Clone, Copy, Debug)]
pub struct Problem {
    pub loss: Loss,
    pub reg: Regularizer,
    pub lambda: f64,
}

impl Problem {
    pub fn new(loss: Loss, reg: Regularizer, lambda: f64) -> Problem {
        assert!(lambda > 0.0);
        Problem { loss, reg, lambda }
    }

    /// Primal regularized risk P(w), Eq. (1).
    pub fn primal(&self, ds: &Dataset, w: &[f32]) -> f64 {
        assert_eq!(w.len(), ds.d());
        let mut risk = 0.0;
        for i in 0..ds.m() {
            let u = ds.x.row_dot(i, w);
            risk += self.loss.primal(u, ds.y[i] as f64);
        }
        self.reg.total(self.lambda, w) + risk / ds.m() as f64
    }

    /// c_j(α) = (1/m) Σ_i α_i x_ij — the linear coefficient of w_j in
    /// f(w, α). Returned for all j.
    pub fn linear_coeff(&self, ds: &Dataset, alpha: &[f32]) -> Vec<f64> {
        assert_eq!(alpha.len(), ds.m());
        let m = ds.m() as f64;
        let mut c = vec![0f64; ds.d()];
        for i in 0..ds.m() {
            let (idx, val) = ds.x.row(i);
            let a = alpha[i] as f64;
            if a != 0.0 {
                for k in 0..idx.len() {
                    c[idx[k] as usize] += a * val[k] as f64;
                }
            }
        }
        for cj in c.iter_mut() {
            *cj /= m;
        }
        c
    }

    /// The w minimizing f(·, α): w_j = argmin_w λφ(w) − c_j w.
    /// (For L1, the argmin is 0 on the feasible dual ball.)
    pub fn w_from_alpha(&self, ds: &Dataset, alpha: &[f32]) -> Vec<f32> {
        self.linear_coeff(ds, alpha)
            .into_iter()
            .map(|c| self.reg.conjugate_argmin(c, self.lambda) as f32)
            .collect()
    }

    /// Dual objective D(α) = min_w f(w, α)
    ///   = Σ_j min_w [λφ(w) − c_j w] + (1/m) Σ_i h(α_i, y_i).
    /// Infeasible α (outside the conjugate domain) yields −∞; callers
    /// that maintain projections never see that.
    pub fn dual(&self, ds: &Dataset, alpha: &[f32]) -> f64 {
        let c = self.linear_coeff(ds, alpha);
        let mut v = 0.0;
        for &cj in &c {
            v += self.reg.conjugate_min_value(cj, self.lambda);
        }
        let m = ds.m() as f64;
        for i in 0..ds.m() {
            v += self.loss.dual_utility(alpha[i] as f64, ds.y[i] as f64) / m;
        }
        v
    }

    /// Saddle value f(w, α) of Eq. (6).
    pub fn saddle(&self, ds: &Dataset, w: &[f32], alpha: &[f32]) -> f64 {
        assert_eq!(w.len(), ds.d());
        assert_eq!(alpha.len(), ds.m());
        let m = ds.m() as f64;
        let mut v = self.reg.total(self.lambda, w);
        for i in 0..ds.m() {
            let u = ds.x.row_dot(i, w);
            let a = alpha[i] as f64;
            v -= a * u / m;
            v += self.loss.dual_utility(a, ds.y[i] as f64) / m;
        }
        v
    }

    /// Duality gap ε(w, α) = P(w) − D(α) ≥ 0 (Eq. 10's measure).
    pub fn duality_gap(&self, ds: &Dataset, w: &[f32], alpha: &[f32]) -> f64 {
        self.primal(ds, w) - self.dual(ds, alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Csr;
    use crate::util::rng::Xoshiro256;

    fn toy() -> Dataset {
        let x = Csr::from_rows(
            3,
            vec![
                vec![(0, 1.0), (1, 0.5)],
                vec![(1, -1.0), (2, 0.25)],
                vec![(0, -0.5), (2, 1.0)],
                vec![(0, 0.75)],
            ],
        );
        Dataset::new("toy", x, vec![1.0, -1.0, -1.0, 1.0])
    }

    fn problems() -> Vec<Problem> {
        vec![
            Problem::new(Loss::Hinge, Regularizer::L2, 0.1),
            Problem::new(Loss::Logistic, Regularizer::L2, 0.05),
            Problem::new(Loss::Square, Regularizer::L2, 0.2),
        ]
    }

    #[test]
    fn primal_at_zero_is_loss_at_zero_margin() {
        let ds = toy();
        let w = vec![0f32; 3];
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 0.1);
        assert!((p.primal(&ds, &w) - 1.0).abs() < 1e-12); // hinge(0) = 1
        let p = Problem::new(Loss::Logistic, Regularizer::L2, 0.1);
        assert!((p.primal(&ds, &w) - std::f64::consts::LN_2).abs() < 1e-12);
    }

    #[test]
    fn linear_coeff_matches_manual() {
        let ds = toy();
        let alpha = [1.0f32, -1.0, 0.5, 0.0];
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 0.1);
        let c = p.linear_coeff(&ds, &alpha);
        // c_0 = (1*1 + 0.5*(-0.5)) / 4 = 0.75/4
        assert!((c[0] - 0.75 / 4.0).abs() < 1e-9);
        // c_1 = (1*0.5 + (-1)*(-1)) / 4 = 1.5/4
        assert!((c[1] - 1.5 / 4.0).abs() < 1e-9);
        // c_2 = ((-1)*0.25 + 0.5*1) / 4 = 0.25/4
        assert!((c[2] - 0.25 / 4.0).abs() < 1e-9);
    }

    #[test]
    fn weak_duality_random_points() {
        let ds = toy();
        let mut rng = Xoshiro256::new(99);
        for p in problems() {
            for _ in 0..200 {
                let w: Vec<f32> = (0..3).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
                let alpha: Vec<f32> = (0..4)
                    .map(|i| {
                        p.loss.project_alpha(rng.uniform(-1.5, 1.5), ds.y[i] as f64) as f32
                    })
                    .collect();
                let gap = p.duality_gap(&ds, &w, &alpha);
                assert!(gap >= -1e-9, "{:?}: negative gap {gap}", p.loss);
            }
        }
    }

    #[test]
    fn dual_equals_saddle_at_w_star() {
        let ds = toy();
        let mut rng = Xoshiro256::new(7);
        for p in problems() {
            for _ in 0..50 {
                let alpha: Vec<f32> = (0..4)
                    .map(|i| {
                        p.loss.project_alpha(rng.uniform(-1.0, 1.0), ds.y[i] as f64) as f32
                    })
                    .collect();
                let w_star = p.w_from_alpha(&ds, &alpha);
                let d = p.dual(&ds, &alpha);
                let s = p.saddle(&ds, &w_star, &alpha);
                assert!((d - s).abs() < 1e-6, "{:?}: dual {d} vs saddle {s}", p.loss);
            }
        }
    }

    #[test]
    fn w_star_minimizes_saddle() {
        let ds = toy();
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 0.1);
        let alpha = [0.5f32, -0.25, -1.0, 1.0];
        let w_star = p.w_from_alpha(&ds, &alpha);
        let base = p.saddle(&ds, &w_star, &alpha);
        let mut rng = Xoshiro256::new(3);
        for _ in 0..100 {
            let w: Vec<f32> =
                w_star.iter().map(|&x| x + rng.uniform(-0.5, 0.5) as f32).collect();
            assert!(p.saddle(&ds, &w, &alpha) >= base - 1e-9);
        }
    }

    /// At the optimum of a tiny SVM solved by brute force, the duality
    /// gap should be ~0: strong duality sanity check.
    #[test]
    fn strong_duality_on_grid_solved_problem() {
        // One feature, two points: min λw² + (1/2)[hinge(w; y=1) + hinge(-w·1; y=-1)]
        let x = Csr::from_rows(1, vec![vec![(0, 1.0)], vec![(0, 1.0)]]);
        let ds = Dataset::new("line", x, vec![1.0, -1.0]);
        let p = Problem::new(Loss::Hinge, Regularizer::L2, 0.25);
        // Grid search the primal.
        let mut best_w = 0.0f32;
        let mut best_p = f64::INFINITY;
        for k in -400..=400 {
            let w = [k as f32 * 0.01];
            let v = p.primal(&ds, &w);
            if v < best_p {
                best_p = v;
                best_w = w[0];
            }
        }
        // Grid search the dual.
        let mut best_d = f64::NEG_INFINITY;
        let mut best_alpha = [0f32; 2];
        for a in 0..=100 {
            for b in 0..=100 {
                let alpha = [a as f32 / 100.0, -(b as f32) / 100.0];
                let v = p.dual(&ds, &alpha);
                if v > best_d {
                    best_d = v;
                    best_alpha = alpha;
                }
            }
        }
        assert!(
            (best_p - best_d).abs() < 1e-2,
            "primal {best_p} (w={best_w}) vs dual {best_d} (α={best_alpha:?})"
        );
    }

    #[test]
    fn gap_shrinks_towards_optimum() {
        // Moving w towards w*(α) with α near-optimal should reduce the gap.
        let ds = toy();
        let p = Problem::new(Loss::Square, Regularizer::L2, 0.5);
        let alpha: Vec<f32> =
            (0..4).map(|i| (ds.y[i] as f64 * 0.5) as f32).collect();
        let w_star = p.w_from_alpha(&ds, &alpha);
        let w_far: Vec<f32> = w_star.iter().map(|&x| x + 1.0).collect();
        assert!(p.duality_gap(&ds, &w_star, &alpha) < p.duality_gap(&ds, &w_far, &alpha));
    }
}
