//! `AVec<T>` — a minimal 64-byte-aligned growable array for the kernel
//! data plane.
//!
//! The explicit-SIMD backend (`simd::backend`) issues 256-bit loads and
//! gathers against the packed-block storage (`partition::omega`: the
//! `cols`/`vals` lane regions) and the per-stripe `inv_col32` /
//! `stripe_alpha_bias` tables. `Vec<f32>`'s allocation is only
//! 4-byte-aligned, so a table could start mid-cache-line and every
//! vector touching its head would straddle two lines. `AVec` allocates
//! at [`ALIGN`] = 64 bytes (one cache line, and ≥ the 32-byte AVX2
//! vector width), which makes the *base* of every lane region and
//! table cache-line aligned; in-loop chunk accesses still use
//! unaligned-tolerant instructions because a chunk's physical offset
//! inside the storage need not be a lane multiple (short groups are
//! stored tight — see the layout invariants in `partition::omega`).
//!
//! Scope is deliberately tiny: `Copy` elements only (no drop glue to
//! run), the handful of `Vec` operations the packed-block builders use
//! (`push`, `extend_from_slice`, `with_capacity`, `collect`), and
//! slice access through `Deref`/`DerefMut` so every consumer keeps
//! reading plain `&[T]`.

use std::alloc::{alloc, dealloc, Layout};
use std::ptr::NonNull;

/// Allocation alignment: one x86 cache line, ≥ 2× the 256-bit AVX2
/// vector. Asserted (in debug builds) by the kernels' bounds check and
/// pinned by unit tests in `partition::omega`.
pub const ALIGN: usize = 64;

/// A growable array whose buffer is always [`ALIGN`]-byte aligned.
/// `T: Copy` keeps (de)allocation trivial — no element drop glue.
pub struct AVec<T: Copy> {
    ptr: NonNull<T>,
    len: usize,
    cap: usize,
}

// SAFETY: AVec owns its buffer exclusively (no interior sharing), so it
// is Send/Sync exactly when a Vec<T> of the same element type would be.
unsafe impl<T: Copy + Send> Send for AVec<T> {}
// SAFETY: see the Send impl above — &AVec only hands out &[T].
unsafe impl<T: Copy + Sync> Sync for AVec<T> {}

impl<T: Copy> AVec<T> {
    /// A dangling-but-aligned pointer for the empty state, so that even
    /// a zero-length `AVec` reports an [`ALIGN`]-aligned base (the
    /// alignment regression tests assert this unconditionally).
    fn dangling() -> NonNull<T> {
        let align = ALIGN.max(std::mem::align_of::<T>());
        // SAFETY: `align` is nonzero, so the pointer is non-null; it is
        // never dereferenced while cap == 0.
        unsafe { NonNull::new_unchecked(align as *mut T) }
    }

    pub fn new() -> AVec<T> {
        AVec { ptr: Self::dangling(), len: 0, cap: 0 }
    }

    pub fn with_capacity(cap: usize) -> AVec<T> {
        let mut v = AVec::new();
        v.reserve_exact(cap);
        v
    }

    fn layout(cap: usize) -> Layout {
        let align = ALIGN.max(std::mem::align_of::<T>());
        Layout::from_size_align(cap * std::mem::size_of::<T>(), align)
            .expect("AVec layout overflow")
    }

    /// Grow to exactly `cap` slots (no-op when already large enough).
    fn reserve_exact(&mut self, cap: usize) {
        if cap <= self.cap || std::mem::size_of::<T>() == 0 {
            return;
        }
        let layout = Self::layout(cap);
        // SAFETY: `layout` has nonzero size (cap > self.cap >= 0 and
        // T is not a ZST on this path); on success the new buffer is
        // valid for `cap` elements at ALIGN alignment.
        let raw = unsafe { alloc(layout) } as *mut T;
        let ptr = NonNull::new(raw).unwrap_or_else(|| std::alloc::handle_alloc_error(layout));
        // SAFETY: both buffers are valid for `self.len` elements
        // (old cap >= len, new cap > old cap) and cannot overlap —
        // the new one was just allocated.
        unsafe { std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), ptr.as_ptr(), self.len) };
        self.dealloc_buf();
        self.ptr = ptr;
        self.cap = cap;
    }

    fn dealloc_buf(&mut self) {
        if self.cap > 0 && std::mem::size_of::<T>() > 0 {
            // SAFETY: `ptr` was allocated by `reserve_exact` with this
            // exact layout and has not been freed since.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap)) };
        }
    }

    pub fn push(&mut self, value: T) {
        if self.len == self.cap {
            self.reserve_exact((self.cap * 2).max(8));
        }
        // SAFETY: len < cap after the reserve, so the slot is in
        // bounds of the allocation.
        unsafe { self.ptr.as_ptr().add(self.len).write(value) };
        self.len += 1;
    }

    pub fn extend_from_slice(&mut self, src: &[T]) {
        let need = self.len + src.len();
        if need > self.cap {
            self.reserve_exact(need.max(self.cap * 2));
        }
        // SAFETY: the reserve guarantees `need <= cap`; `src` cannot
        // alias the freshly (re)allocated tail because `&mut self`
        // excludes borrows of self's buffer.
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), self.ptr.as_ptr().add(self.len), src.len())
        };
        self.len = need;
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    pub fn as_slice(&self) -> &[T] {
        // SAFETY: the first `len` slots are initialized (push/extend
        // only advance len over written slots).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [T] {
        // SAFETY: as in `as_slice`, plus `&mut self` gives exclusivity.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }

    /// Copy a plain slice into a fresh aligned vector.
    pub fn from_slice(src: &[T]) -> AVec<T> {
        let mut v = AVec::with_capacity(src.len());
        v.extend_from_slice(src);
        v
    }
}

impl<T: Copy> Drop for AVec<T> {
    fn drop(&mut self) {
        self.dealloc_buf();
    }
}

impl<T: Copy> Default for AVec<T> {
    fn default() -> Self {
        AVec::new()
    }
}

impl<T: Copy> std::ops::Deref for AVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Copy> std::ops::DerefMut for AVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

impl<T: Copy> Clone for AVec<T> {
    fn clone(&self) -> Self {
        AVec::from_slice(self)
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for AVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl<T: Copy + PartialEq> PartialEq for AVec<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// Mixed comparisons so existing tests can keep writing
/// `assert_eq!(block.cols, vec![..])`.
impl<T: Copy + PartialEq> PartialEq<Vec<T>> for AVec<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Copy + PartialEq, const N: usize> PartialEq<[T; N]> for AVec<T> {
    fn eq(&self, other: &[T; N]) -> bool {
        self.as_slice() == &other[..]
    }
}

impl<T: Copy> FromIterator<T> for AVec<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        let it = iter.into_iter();
        let mut v = AVec::with_capacity(it.size_hint().0);
        for x in it {
            v.push(x);
        }
        v
    }
}

impl<'a, T: Copy> IntoIterator for &'a AVec<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

/// Whether a slice's base pointer is [`ALIGN`]-byte aligned — the
/// assertion the packed-block builders and their regression tests use.
pub fn is_aligned<T>(s: &[T]) -> bool {
    (s.as_ptr() as usize) % ALIGN == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_grown_vectors_are_aligned() {
        let mut v: AVec<f32> = AVec::new();
        assert!(is_aligned(&v));
        assert_eq!(v.len(), 0);
        for i in 0..1000 {
            v.push(i as f32);
            assert!(is_aligned(&v), "misaligned after {} pushes", i + 1);
        }
        assert_eq!(v.len(), 1000);
        assert_eq!(v[999], 999.0);
    }

    #[test]
    fn behaves_like_vec() {
        let mut v: AVec<u32> = AVec::new();
        v.extend_from_slice(&[1, 2, 3]);
        v.push(4);
        v.extend_from_slice(&[5, 6]);
        assert_eq!(v, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(v.iter().max(), Some(&6));
        v[0] = 9;
        assert_eq!(&v[..2], &[9, 2]);
        let w = v.clone();
        assert_eq!(w, v);
        assert!(is_aligned(&w));
        v.clear();
        assert!(v.is_empty());
        assert_ne!(w, v);
    }

    #[test]
    fn collect_and_from_slice_round_trip() {
        let v: AVec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        assert!(is_aligned(&v));
        assert_eq!(v.len(), 37);
        let w = AVec::from_slice(&v);
        assert_eq!(w, v);
        // Debug formatting mirrors the slice (used in test failures).
        assert_eq!(format!("{:?}", AVec::from_slice(&[1u32, 2])), "[1, 2]");
    }

    #[test]
    fn with_capacity_preallocates_aligned() {
        let v: AVec<u32> = AVec::with_capacity(123);
        assert!(is_aligned(&v));
        assert_eq!(v.len(), 0);
    }
}
