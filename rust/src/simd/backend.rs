//! `SimdBackend` — the explicit-SIMD kernel backend abstraction.
//!
//! PR 2's lane kernel expressed the 8-wide w-side arithmetic as plain
//! per-lane loops over `Lane = [f32; LANES]`, relying on LLVM
//! autovectorization. That covers the arithmetic but not the **column
//! gathers**: loading 8 `(w_j, 1/|Ω̄_j|)` pairs through block-local
//! `u32` column ids compiles to 8 scalar loads per chunk — by PR 4 the
//! dominant cost of the hot loop (the ROADMAP gather-intrinsics item).
//!
//! This trait factors every lane-granular operation of the sweep —
//! chunk gather, ∇φ, gradient FMA, AdaGrad accumulate/√/divide, box
//! clamp, the affine-α coefficient lanes — behind one monomorphization
//! parameter, with three implementations:
//!
//! * [`Portable`] — the PR 2/3 per-lane loops, **bit-identical by
//!   construction** to the pre-backend kernels (it is the same code,
//!   moved). Compiles on every architecture; stable-Rust
//!   autovectorizable.
//! * [`Avx2`] (`x86_64` only) — `core::arch` intrinsics:
//!   `_mm256_i32gather_ps` for the column gathers, 256-bit FMA for the
//!   gradient/step pipeline, `_mm256_sqrt_ps`/`_mm256_div_ps` for the
//!   AdaGrad η batch, min/max for the clamp. The scatter of the `wn`
//!   lanes stays explicit per-lane stores in the shared kernel code
//!   (AVX2 has no scatter instruction; only the first `len` lanes of a
//!   chunk may be written).
//! * [`Avx512`] (`x86_64` only) — **chunk pairing** over the unchanged
//!   8-lane layout: a [`SimdBackend::PAIRED`] backend makes the sweep
//!   fuse two *adjacent* chunks per step, so full pairs run one
//!   512-bit `_mm512_i32gather_ps` / FMA / native
//!   `_mm512_i32scatter_ps` pipeline per 16 entries, and the odd
//!   trailing chunk (plus short remainders with sentinels) takes the
//!   8-wide 256-bit epilogue shared with [`Avx2`]. No relayout: the
//!   packed block format, sentinels, and `LANES = 8` are untouched.
//!
//! Which backend runs is decided **once per run** by
//! `coordinator::plan::SweepPlan` — forced levels via runtime
//! CPU-feature validation, `auto` via the measured micro-autotune
//! ([`super::resolve`] / [`super::autotune`]) — kernels monomorphize
//! over `B: SimdBackend`, so there is zero per-chunk (or even
//! per-sweep) dispatch, and engines never touch feature detection
//! (`scripts/ci.sh` greps them).
//!
//! ## Float-summation-order caveat, per backend
//!
//! [`Portable`] reproduces the PR 3 kernels bit for bit, so every
//! pinned suite keeps passing unchanged. [`Avx2`] contracts
//! multiply-adds into fused FMAs (single rounding where the portable
//! path rounds twice), so it is *tolerance-equivalent* to the portable
//! backend — ≤1e-5 relative per sweep against the COO oracle,
//! property-tested in `tests/lane_kernel.rs`/`tests/alpha_lane.rs` —
//! not bit-identical across backends. The same caveat extends to
//! 512-bit: [`Avx512`]'s pair ops are the elementwise IEEE operations
//! of the 256-bit pipeline at double width (a 512-bit FMA rounds each
//! lane exactly like a 256-bit FMA), so pairing itself moves no bits
//! relative to two 8-wide AVX steps — the cross-backend drift is still
//! the FMA contraction, bounded by the same ≤1e-5 suites. Threaded ≡
//! replay bit-identity holds *within* a backend (both executions run
//! the same plan). The predict fold is the exception on every backend:
//! f64 storage-order by contract, bit-identical across all three.
//!
//! # Safety
//!
//! This is an `unsafe trait`: an implementation asserts that its
//! methods are sound to execute on the CPU the process is running on.
//! [`Portable`] is unconditionally sound; [`Avx2`] requires AVX2+FMA
//! and [`Avx512`] additionally AVX-512F, which every production path
//! guarantees by construction — the only producers of an
//! intrinsics-backed monomorphized call are `SweepPlan`/
//! [`super::resolve`] (behind `is_x86_feature_detected!`) and tests
//! that perform the same guard.

use crate::losses::kernel::{Lane, Lane2, LANES2};
use crate::partition::omega::LANES;

/// Concatenate two adjacent lane chunks into one paired chunk.
#[inline(always)]
pub fn join_lanes(lo: &Lane, hi: &Lane) -> Lane2 {
    let mut out: Lane2 = [0.0; LANES2];
    out[..LANES].copy_from_slice(lo);
    out[LANES..].copy_from_slice(hi);
    out
}

/// Split a paired chunk back into its two adjacent lane chunks.
#[inline(always)]
pub fn split_lanes(v: &Lane2) -> (Lane, Lane) {
    let (mut lo, mut hi): (Lane, Lane) = ([0.0; LANES], [0.0; LANES]);
    lo.copy_from_slice(&v[..LANES]);
    hi.copy_from_slice(&v[LANES..]);
    (lo, hi)
}

/// Concatenate two chunks' column-id arrays.
#[inline(always)]
pub fn join_idx(lo: &[usize; LANES], hi: &[usize; LANES]) -> [usize; LANES2] {
    let mut out = [0usize; LANES2];
    out[..LANES].copy_from_slice(lo);
    out[LANES..].copy_from_slice(hi);
    out
}

/// Split a paired chunk's column ids back into its two halves.
#[inline(always)]
pub fn split_idx(v: &[usize; LANES2]) -> ([usize; LANES], [usize; LANES]) {
    let (mut lo, mut hi) = ([0usize; LANES], [0usize; LANES]);
    lo.copy_from_slice(&v[..LANES]);
    hi.copy_from_slice(&v[LANES..]);
    (lo, hi)
}

/// Lane-granular kernel operations, monomorphized into the sweeps.
///
/// The two `unsafe fn`s carry the kernels' usual unchecked-indexing
/// contract: the caller has validated (via `check_packed_bounds`) that
/// `base + LANES` is within `cols`/`vals` and that every stored column
/// id — sentinels included — indexes within `w` and `inv`.
///
/// # Safety
///
/// Implementations must be sound on the running CPU; see the module
/// docs for how `Avx2` discharges this via runtime detection.
pub unsafe trait SimdBackend: Copy + Send + Sync + 'static {
    /// Backend tag recorded by `SweepPlan` and the benches.
    const NAME: &'static str;

    /// Whether the sweeps should fuse two adjacent chunks per step
    /// (16-wide operation over the unchanged 8-lane layout). `false`
    /// const-folds the kernels' pair loop away entirely, so non-paired
    /// backends keep their pinned bit-exact code paths; [`Avx512`]
    /// overrides it. Pair steps run only on **full** pairs (16 real
    /// entries — sentinels never reach a pair op); the remainder takes
    /// the ordinary 8-wide chunk path as an epilogue.
    const PAIRED: bool = false;

    /// Full-width gather of one LANES chunk at physical `base`:
    /// (column ids, w values, x/m values, 1/|Ω̄_j|).
    ///
    /// # Safety
    /// `base + LANES <= cols.len() == vals.len()`, and every
    /// `cols[base..base + LANES]` is `< w.len() <= inv.len()` (resp.
    /// `<= w.len()`); both validated once per sweep by the caller.
    unsafe fn gather_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES], Lane, Lane, Lane);

    /// Gather 8 f32 by the chunk's precomputed column ids (the AdaGrad
    /// w-accumulator load).
    ///
    /// # Safety
    /// Every `lj[k] < src.len()` — the same validated column ids
    /// returned by [`SimdBackend::gather_chunk`].
    unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane;

    /// The w gradient lanes: `gw[k] = lam·rv[k]·iv[k] − av[k]·xv[k]`.
    fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane;

    /// Step + box clamp: `wn[k] = clamp(wv[k] − etav[k]·gw[k], −b, b)`.
    fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane;

    /// Affine-α coefficient lanes: `cv[k] = bias − wv[k]·xv[k]`.
    fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane;

    /// ∇φ for L1: `sign(w)` with 0 at the kink.
    fn l1_grad_lane(w: &Lane) -> Lane;

    /// ∇φ for L2: `2·w`.
    fn l2_grad_lane(w: &Lane) -> Lane;

    /// AdaGrad η batch: `acc[k] += g[k]²; out[k] = e0/√(eps + acc[k])`.
    fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane;

    /// Batched-predict fold (the serving kernel's one lane op): gather
    /// the chunk's w values through its column ids, multiply by the
    /// stored feature values, and fold the first `n` *real* lanes into
    /// `acc` — widening each f32·f32 product to f64 (exact: a product
    /// of two f32s is representable in f64) and accumulating in storage
    /// order, exactly `Csr::row_dot`'s recurrence. Sentinel lanes
    /// (`k >= n`) may be gathered speculatively but are never folded,
    /// so padding cannot perturb a score. Because the fold is f64 in
    /// storage order on every backend and the gather moves bits, this
    /// op — unlike the FMA-contracted training pipeline — is
    /// **bit-identical across backends**; AVX2's win is the hardware
    /// gather replacing 8 scalar indexed loads.
    ///
    /// # Safety
    /// `base + LANES <= cols.len() == vals.len()`, `n <= LANES`, and
    /// every `cols[base..base + LANES]` — sentinels included — is
    /// `< w.len()`; validated once per batch by `serve::predict`.
    unsafe fn predict_fold_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        n: usize,
        w: &[f32],
        acc: &mut f64,
    );

    // -----------------------------------------------------------------
    // Paired-chunk ops (two adjacent chunks fused into one step).
    //
    // The defaults compose the 8-wide ops half-by-half — exactly what a
    // non-paired backend computes for the same entries — so every
    // backend gets a correct pair surface for free and `Avx512`
    // replaces each with one 512-bit op. Only `PAIRED` backends are
    // ever driven through these by the sweeps.
    // -----------------------------------------------------------------

    /// Paired gather: the chunks at `base` and `base + LANES` in one
    /// step.
    ///
    /// # Safety
    /// As [`SimdBackend::gather_chunk`], with `base + 2·LANES` within
    /// `cols`/`vals`.
    #[inline(always)]
    unsafe fn gather_chunk2(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES2], Lane2, Lane2, Lane2) {
        // SAFETY: forwarded contract — both chunk bases are in bounds
        // because base + 2·LANES is.
        let (lj0, wv0, xv0, iv0) = unsafe { Self::gather_chunk(cols, vals, base, w, inv) };
        // SAFETY: as above.
        let (lj1, wv1, xv1, iv1) = unsafe { Self::gather_chunk(cols, vals, base + LANES, w, inv) };
        (
            join_idx(&lj0, &lj1),
            join_lanes(&wv0, &wv1),
            join_lanes(&xv0, &xv1),
            join_lanes(&iv0, &iv1),
        )
    }

    /// Gather 16 f32 by the paired chunk's column ids (the AdaGrad
    /// w-accumulator load).
    ///
    /// # Safety
    /// Every `lj[k] < src.len()` — the validated ids returned by
    /// [`SimdBackend::gather_chunk2`].
    #[inline(always)]
    unsafe fn gather_idx2(src: &[f32], lj: &[usize; LANES2]) -> Lane2 {
        let (lo, hi) = split_idx(lj);
        // SAFETY: forwarded contract.
        let (a, b) = unsafe { (Self::gather_idx(src, &lo), Self::gather_idx(src, &hi)) };
        join_lanes(&a, &b)
    }

    /// Paired [`SimdBackend::w_grad`].
    #[inline(always)]
    fn w_grad2(lam: f32, rv: &Lane2, iv: &Lane2, av: &Lane2, xv: &Lane2) -> Lane2 {
        let (r0, r1) = split_lanes(rv);
        let (i0, i1) = split_lanes(iv);
        let (a0, a1) = split_lanes(av);
        let (x0, x1) = split_lanes(xv);
        join_lanes(&Self::w_grad(lam, &r0, &i0, &a0, &x0), &Self::w_grad(lam, &r1, &i1, &a1, &x1))
    }

    /// Paired [`SimdBackend::w_step_clamp`].
    #[inline(always)]
    fn w_step_clamp2(wv: &Lane2, etav: &Lane2, gw: &Lane2, b: f32) -> Lane2 {
        let (w0, w1) = split_lanes(wv);
        let (e0, e1) = split_lanes(etav);
        let (g0, g1) = split_lanes(gw);
        join_lanes(&Self::w_step_clamp(&w0, &e0, &g0, b), &Self::w_step_clamp(&w1, &e1, &g1, b))
    }

    /// Paired [`SimdBackend::affine_coeffs`].
    #[inline(always)]
    fn affine_coeffs2(bias: f32, wv: &Lane2, xv: &Lane2) -> Lane2 {
        let (w0, w1) = split_lanes(wv);
        let (x0, x1) = split_lanes(xv);
        join_lanes(&Self::affine_coeffs(bias, &w0, &x0), &Self::affine_coeffs(bias, &w1, &x1))
    }

    /// Paired [`SimdBackend::l1_grad_lane`].
    #[inline(always)]
    fn l1_grad_lane2(w: &Lane2) -> Lane2 {
        let (lo, hi) = split_lanes(w);
        join_lanes(&Self::l1_grad_lane(&lo), &Self::l1_grad_lane(&hi))
    }

    /// Paired [`SimdBackend::l2_grad_lane`].
    #[inline(always)]
    fn l2_grad_lane2(w: &Lane2) -> Lane2 {
        let (lo, hi) = split_lanes(w);
        join_lanes(&Self::l2_grad_lane(&lo), &Self::l2_grad_lane(&hi))
    }

    /// Paired [`SimdBackend::adagrad_eta_lane`].
    #[inline(always)]
    fn adagrad_eta_lane2(e0: f32, eps: f32, acc: &mut Lane2, g: &Lane2) -> Lane2 {
        let (mut a0, mut a1) = split_lanes(acc);
        let (g0, g1) = split_lanes(g);
        let out = join_lanes(
            &Self::adagrad_eta_lane(e0, eps, &mut a0, &g0),
            &Self::adagrad_eta_lane(e0, eps, &mut a1, &g1),
        );
        *acc = join_lanes(&a0, &a1);
        out
    }

    /// Scatter the paired chunk's 16 values back through its column
    /// ids — the w-side writeback. Pair steps run only on full pairs
    /// of one row group, and a row group is one CSR row, so the 16
    /// column ids are distinct and the scatter is conflict-free (the
    /// same property the 8-wide per-lane store loop relies on).
    ///
    /// # Safety
    /// Every `lj[k] < dst.len()` — the validated ids returned by
    /// [`SimdBackend::gather_chunk2`].
    #[inline(always)]
    unsafe fn scatter2(dst: &mut [f32], lj: &[usize; LANES2], v: &Lane2) {
        for k in 0..LANES2 {
            debug_assert!(lj[k] < dst.len());
            // SAFETY: caller guarantees lj[k] < dst.len().
            unsafe { *dst.get_unchecked_mut(lj[k]) = v[k] };
        }
    }

    /// Paired predict fold over a **full** pair (16 real entries — the
    /// caller's pair loop never reaches sentinels, so there is no `n`
    /// parameter). The fold stays serial f64 in storage order, so this
    /// is bit-identical to two [`SimdBackend::predict_fold_chunk`]
    /// calls on every backend; a paired backend's win is the single
    /// 16-wide gather.
    ///
    /// # Safety
    /// As [`SimdBackend::predict_fold_chunk`], with `base + 2·LANES`
    /// within `cols`/`vals` and all 16 entries real.
    #[inline(always)]
    unsafe fn predict_fold_chunk2(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        // SAFETY: forwarded contract (both chunks full and in bounds).
        unsafe {
            Self::predict_fold_chunk(cols, vals, base, LANES, w, acc);
            Self::predict_fold_chunk(cols, vals, base + LANES, LANES, w, acc);
        }
    }
}

// ---------------------------------------------------------------------
// Portable backend — the PR 2/3 per-lane loops, verbatim
// ---------------------------------------------------------------------

/// Autovectorized baseline backend. Bit-identical to the pre-backend
/// (PR 3) kernels: these bodies are the exact loops that previously
/// lived inline in `coordinator::updates` / `losses::kernel`.
#[derive(Clone, Copy, Debug, Default)]
pub struct Portable;

// SAFETY: plain per-lane Rust with no target-feature requirements —
// sound on every CPU.
unsafe impl SimdBackend for Portable {
    const NAME: &'static str = "portable";

    #[inline(always)]
    unsafe fn gather_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES], Lane, Lane, Lane) {
        let mut lj = [0usize; LANES];
        let mut wv: Lane = [0.0; LANES];
        let mut xv: Lane = [0.0; LANES];
        let mut iv: Lane = [0.0; LANES];
        for k in 0..LANES {
            // SAFETY: the caller's contract — base + LANES in bounds of
            // cols/vals, every stored column validated in-stripe.
            unsafe {
                let c = *cols.get_unchecked(base + k) as usize;
                debug_assert!(c < w.len() && c < inv.len());
                lj[k] = c;
                wv[k] = *w.get_unchecked(c);
                xv[k] = *vals.get_unchecked(base + k);
                iv[k] = *inv.get_unchecked(c);
            }
        }
        (lj, wv, xv, iv)
    }

    #[inline(always)]
    unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane {
        let mut out: Lane = [0.0; LANES];
        for k in 0..LANES {
            debug_assert!(lj[k] < src.len());
            // SAFETY: caller guarantees lj[k] < src.len() (validated
            // column ids from gather_chunk).
            out[k] = unsafe { *src.get_unchecked(lj[k]) };
        }
        out
    }

    #[inline(always)]
    fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane {
        let mut gw: Lane = [0.0; LANES];
        for k in 0..LANES {
            gw[k] = lam * rv[k] * iv[k] - av[k] * xv[k];
        }
        gw
    }

    #[inline(always)]
    fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane {
        let mut wn: Lane = [0.0; LANES];
        for k in 0..LANES {
            wn[k] = (wv[k] - etav[k] * gw[k]).clamp(-b, b);
        }
        wn
    }

    #[inline(always)]
    fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane {
        let mut cv: Lane = [0.0; LANES];
        for k in 0..LANES {
            cv[k] = bias - wv[k] * xv[k];
        }
        cv
    }

    #[inline(always)]
    fn l1_grad_lane(w: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            // sign(w) with 0 at the kink — exact in f32, branch-free
            // select after vectorization.
            out[k] = if w[k] > 0.0 {
                1.0
            } else if w[k] < 0.0 {
                -1.0
            } else {
                0.0
            };
        }
        out
    }

    #[inline(always)]
    fn l2_grad_lane(w: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            out[k] = 2.0 * w[k];
        }
        out
    }

    #[inline(always)]
    fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            let a = acc[k] + g[k] * g[k];
            acc[k] = a;
            out[k] = e0 / (eps + a).sqrt();
        }
        out
    }

    #[inline(always)]
    unsafe fn predict_fold_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        n: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        debug_assert!(n <= LANES && base + LANES <= cols.len() && base + LANES <= vals.len());
        for k in 0..n {
            // SAFETY: the caller's contract — base + LANES in bounds of
            // cols/vals, every stored column id < w.len().
            unsafe {
                let c = *cols.get_unchecked(base + k) as usize;
                debug_assert!(c < w.len());
                *acc += *vals.get_unchecked(base + k) as f64 * *w.get_unchecked(c) as f64;
            }
        }
    }
}

// ---------------------------------------------------------------------
// AVX2/FMA backend (x86_64)
// ---------------------------------------------------------------------

/// Explicit AVX2 + FMA backend: hardware gathers for the column loads,
/// fused multiply-adds for the arithmetic pipeline.
///
/// Every production `Avx2`-monomorphized call is produced behind
/// `is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")`
/// ([`super::resolve`], recorded in `SweepPlan`); tests perform the
/// same guard. See the trait-level safety contract.
#[cfg(target_arch = "x86_64")]
#[derive(Clone, Copy, Debug, Default)]
pub struct Avx2;

// SAFETY: all methods delegate to `#[target_feature(enable = "avx2",
// enable = "fma")]` functions; the trait contract (module docs) makes
// the caller guarantee those features are present before an Avx2
// monomorphization executes.
#[cfg(target_arch = "x86_64")]
unsafe impl SimdBackend for Avx2 {
    const NAME: &'static str = "avx2";

    #[inline(always)]
    unsafe fn gather_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES], Lane, Lane, Lane) {
        // SAFETY: bounds per the trait contract; AVX2+FMA present per
        // the backend-selection contract (module docs).
        unsafe { avx2::gather_chunk(cols, vals, base, w, inv) }
    }

    #[inline(always)]
    unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane {
        // SAFETY: indices per the trait contract; features per the
        // backend-selection contract.
        unsafe { avx2::gather_idx(src, lj) }
    }

    #[inline(always)]
    fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane {
        // SAFETY: pure lane arithmetic on stack arrays; AVX2+FMA
        // present per the backend-selection contract.
        unsafe { avx2::w_grad(lam, rv, iv, av, xv) }
    }

    #[inline(always)]
    fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::w_step_clamp(wv, etav, gw, b) }
    }

    #[inline(always)]
    fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::affine_coeffs(bias, wv, xv) }
    }

    #[inline(always)]
    fn l1_grad_lane(w: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::l1_grad_lane(w) }
    }

    #[inline(always)]
    fn l2_grad_lane(w: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::l2_grad_lane(w) }
    }

    #[inline(always)]
    fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::adagrad_eta_lane(e0, eps, acc, g) }
    }

    #[inline(always)]
    unsafe fn predict_fold_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        n: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        // SAFETY: bounds per the trait contract; AVX2+FMA present per
        // the backend-selection contract.
        unsafe { avx2::predict_fold_chunk(cols, vals, base, n, w, acc) }
    }
}

/// The intrinsic bodies. `#[target_feature]` cannot be applied to
/// trait methods, so the `SimdBackend for Avx2` impl wraps these free
/// functions. All are `unsafe fn`: callers guarantee AVX2+FMA (and the
/// gathers' index bounds).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{Lane, LANES};
    use core::arch::x86_64::*;

    /// Round-trip helpers: `Lane` is only 4-byte aligned, so use
    /// unaligned vector moves (same throughput as aligned on AVX2).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn ld(l: &Lane) -> __m256 {
        // SAFETY: `l` is a valid [f32; 8]; loadu has no alignment
        // requirement.
        unsafe { _mm256_loadu_ps(l.as_ptr()) }
    }

    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn st(v: __m256) -> Lane {
        let mut out: Lane = [0.0; LANES];
        // SAFETY: `out` is a valid 8-f32 destination; storeu has no
        // alignment requirement.
        unsafe { _mm256_storeu_ps(out.as_mut_ptr(), v) };
        out
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn gather_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES], Lane, Lane, Lane) {
        debug_assert!(base + LANES <= cols.len() && base + LANES <= vals.len());
        // SAFETY: (whole body) caller guarantees base + LANES within
        // cols/vals and every stored column id < w.len() <= inv.len().
        // Column ids fit i32 (checked against the stripe width by
        // `check_packed_bounds`), so the sign-extending i32 gather
        // indices are non-negative.
        unsafe {
            let idx = _mm256_loadu_si256(cols.as_ptr().add(base) as *const __m256i);
            // Hardware gathers: 8 w values and 8 reciprocal-table
            // values through one index vector each — this replaces the
            // 8 + 8 scalar loads that dominated the autovec kernel.
            let wv = _mm256_i32gather_ps::<4>(w.as_ptr(), idx);
            let iv = _mm256_i32gather_ps::<4>(inv.as_ptr(), idx);
            let xv = _mm256_loadu_ps(vals.as_ptr().add(base));
            let mut lj = [0usize; LANES];
            for (k, slot) in lj.iter_mut().enumerate() {
                *slot = *cols.get_unchecked(base + k) as usize;
            }
            (lj, st(wv), st(xv), st(iv))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane {
        debug_assert!(lj.iter().all(|&j| j < src.len()));
        // SAFETY: caller guarantees every lj[k] < src.len(); ids were
        // validated < i32::MAX with the stripe width.
        unsafe {
            let idx = _mm256_setr_epi32(
                lj[0] as i32,
                lj[1] as i32,
                lj[2] as i32,
                lj[3] as i32,
                lj[4] as i32,
                lj[5] as i32,
                lj[6] as i32,
                lj[7] as i32,
            );
            st(_mm256_i32gather_ps::<4>(src.as_ptr(), idx))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            // t = λ·∇φ·(1/|Ω̄_j|); gw = t − α·x  (fused: one rounding
            // on the subtract-multiply, vs two on the portable path —
            // the per-backend float-order caveat).
            let t = _mm256_mul_ps(_mm256_mul_ps(_mm256_set1_ps(lam), ld(rv)), ld(iv));
            st(_mm256_fnmadd_ps(ld(av), ld(xv), t))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let wn = _mm256_fnmadd_ps(ld(etav), ld(gw), ld(wv));
            st(_mm256_min_ps(_mm256_max_ps(wn, _mm256_set1_ps(-b)), _mm256_set1_ps(b)))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe { st(_mm256_fnmadd_ps(ld(wv), ld(xv), _mm256_set1_ps(bias))) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn l1_grad_lane(w: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let wv = ld(w);
            let zero = _mm256_setzero_ps();
            // sign(w) with 0 at the kink (±0.0 compare equal to 0):
            // mask-select +1 where w > 0, −1 where w < 0.
            let pos =
                _mm256_and_ps(_mm256_cmp_ps::<{ _CMP_GT_OQ }>(wv, zero), _mm256_set1_ps(1.0));
            let neg =
                _mm256_and_ps(_mm256_cmp_ps::<{ _CMP_LT_OQ }>(wv, zero), _mm256_set1_ps(-1.0));
            st(_mm256_or_ps(pos, neg))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn l2_grad_lane(w: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let wv = ld(w);
            // 2·w is exact in f32 (exponent bump), identical to the
            // portable lane.
            st(_mm256_add_ps(wv, wv))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let gv = ld(g);
            let a = _mm256_fmadd_ps(gv, gv, ld(acc));
            *acc = st(a);
            st(_mm256_div_ps(
                _mm256_set1_ps(e0),
                _mm256_sqrt_ps(_mm256_add_ps(_mm256_set1_ps(eps), a)),
            ))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn predict_fold_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        n: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        debug_assert!(n <= LANES && base + LANES <= cols.len() && base + LANES <= vals.len());
        // SAFETY: (whole body) caller guarantees base + LANES within
        // cols/vals and every stored column id — sentinels included —
        // < w.len(); ids fit i32 (serve's packer refuses d > i32::MAX),
        // so the sign-extending i32 gather indices are non-negative.
        unsafe {
            let idx = _mm256_loadu_si256(cols.as_ptr().add(base) as *const __m256i);
            // One hardware gather replaces the chunk's 8 scalar indexed
            // w loads; the speculative sentinel lanes read w[0] (valid)
            // and are discarded by the bounded fold below.
            let wv = st(_mm256_i32gather_ps::<4>(w.as_ptr(), idx));
            let xv = st(_mm256_loadu_ps(vals.as_ptr().add(base)));
            // The fold stays scalar f64 in storage order — bit-identical
            // to the portable backend and to `Csr::row_dot` (see the
            // trait docs); the gather is the memory-bound win.
            for k in 0..n {
                *acc += xv[k] as f64 * wv[k] as f64;
            }
        }
    }
}

// ---------------------------------------------------------------------
// AVX-512 backend (x86_64): paired 16-wide chunks, 8-wide epilogue
// ---------------------------------------------------------------------

/// AVX-512F backend: `PAIRED` chunk fusion over the unchanged
/// lane-major layout. Full pairs of adjacent chunks run one 512-bit
/// gather / FMA / native-scatter pipeline per 16 entries; the odd
/// trailing chunk and short remainders (the only places sentinels can
/// appear) run the 8-wide 256-bit pipeline shared with [`Avx2`], so
/// sentinels keep AVX2's speculative in-range-gather/never-store
/// treatment and no 512-bit op ever sees padding.
///
/// Requires avx512f **and** avx2+fma (the epilogue), detected as a
/// unit by `super::avx512_supported`. Besides width, the native win
/// over AVX2 is `_mm512_i32scatter_ps`: the w-side writeback that AVX2
/// performs as per-lane scalar stores becomes one instruction per 16
/// weights.
#[cfg(target_arch = "x86_64")]
#[derive(Clone, Copy, Debug, Default)]
pub struct Avx512;

// SAFETY: the 8-wide ops delegate to the avx2 free functions and the
// paired ops to `#[target_feature(enable = "avx512f", ...)]` functions;
// the trait contract (module docs) makes the caller guarantee
// avx512f+avx2+fma are present (`super::avx512_supported`) before an
// Avx512 monomorphization executes.
#[cfg(target_arch = "x86_64")]
unsafe impl SimdBackend for Avx512 {
    const NAME: &'static str = "avx512";
    const PAIRED: bool = true;

    #[inline(always)]
    unsafe fn gather_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES], Lane, Lane, Lane) {
        // SAFETY: bounds per the trait contract; avx2+fma are part of
        // this backend's feature set (epilogue runs the 256-bit ops).
        unsafe { avx2::gather_chunk(cols, vals, base, w, inv) }
    }

    #[inline(always)]
    unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane {
        // SAFETY: indices per the trait contract; features as above.
        unsafe { avx2::gather_idx(src, lj) }
    }

    #[inline(always)]
    fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane {
        // SAFETY: pure lane arithmetic on stack arrays; features per
        // the backend-selection contract.
        unsafe { avx2::w_grad(lam, rv, iv, av, xv) }
    }

    #[inline(always)]
    fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::w_step_clamp(wv, etav, gw, b) }
    }

    #[inline(always)]
    fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::affine_coeffs(bias, wv, xv) }
    }

    #[inline(always)]
    fn l1_grad_lane(w: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::l1_grad_lane(w) }
    }

    #[inline(always)]
    fn l2_grad_lane(w: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::l2_grad_lane(w) }
    }

    #[inline(always)]
    fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::adagrad_eta_lane(e0, eps, acc, g) }
    }

    #[inline(always)]
    unsafe fn predict_fold_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        n: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        // SAFETY: bounds per the trait contract; features as above.
        unsafe { avx2::predict_fold_chunk(cols, vals, base, n, w, acc) }
    }

    #[inline(always)]
    unsafe fn gather_chunk2(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES2], Lane2, Lane2, Lane2) {
        // SAFETY: bounds per the trait contract; avx512f present per
        // the backend-selection contract.
        unsafe { avx512::gather_chunk2(cols, vals, base, w, inv) }
    }

    #[inline(always)]
    unsafe fn gather_idx2(src: &[f32], lj: &[usize; LANES2]) -> Lane2 {
        // SAFETY: indices per the trait contract; features as above.
        unsafe { avx512::gather_idx2(src, lj) }
    }

    #[inline(always)]
    fn w_grad2(lam: f32, rv: &Lane2, iv: &Lane2, av: &Lane2, xv: &Lane2) -> Lane2 {
        // SAFETY: pure lane arithmetic on stack arrays; features per
        // the backend-selection contract.
        unsafe { avx512::w_grad2(lam, rv, iv, av, xv) }
    }

    #[inline(always)]
    fn w_step_clamp2(wv: &Lane2, etav: &Lane2, gw: &Lane2, b: f32) -> Lane2 {
        // SAFETY: as in `w_grad2`.
        unsafe { avx512::w_step_clamp2(wv, etav, gw, b) }
    }

    #[inline(always)]
    fn affine_coeffs2(bias: f32, wv: &Lane2, xv: &Lane2) -> Lane2 {
        // SAFETY: as in `w_grad2`.
        unsafe { avx512::affine_coeffs2(bias, wv, xv) }
    }

    #[inline(always)]
    fn l1_grad_lane2(w: &Lane2) -> Lane2 {
        // SAFETY: as in `w_grad2`.
        unsafe { avx512::l1_grad_lane2(w) }
    }

    #[inline(always)]
    fn l2_grad_lane2(w: &Lane2) -> Lane2 {
        // SAFETY: as in `w_grad2`.
        unsafe { avx512::l2_grad_lane2(w) }
    }

    #[inline(always)]
    fn adagrad_eta_lane2(e0: f32, eps: f32, acc: &mut Lane2, g: &Lane2) -> Lane2 {
        // SAFETY: as in `w_grad2`.
        unsafe { avx512::adagrad_eta_lane2(e0, eps, acc, g) }
    }

    #[inline(always)]
    unsafe fn scatter2(dst: &mut [f32], lj: &[usize; LANES2], v: &Lane2) {
        // SAFETY: indices per the trait contract (distinct, in
        // bounds); features as above.
        unsafe { avx512::scatter2(dst, lj, v) }
    }

    #[inline(always)]
    unsafe fn predict_fold_chunk2(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        // SAFETY: bounds per the trait contract; features as above.
        unsafe { avx512::predict_fold_chunk2(cols, vals, base, w, acc) }
    }
}

/// The 512-bit paired-chunk bodies — same free-function pattern as
/// [`avx2`] (`#[target_feature]` cannot decorate trait methods). The
/// feature set also enables avx2+fma so the shared 8-wide epilogue
/// inlines into the avx512 whole-sweep wrappers.
///
/// Note the AVX-512 gather/scatter operand order: `(indices, pointer)`
/// — reversed from the AVX2 gather intrinsic — with a byte pointer and
/// an explicit ×4 scale.
#[cfg(target_arch = "x86_64")]
mod avx512 {
    use super::{Lane2, LANES2};
    use core::arch::x86_64::*;

    #[inline]
    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    unsafe fn ld2(l: &Lane2) -> __m512 {
        // SAFETY: `l` is a valid [f32; 16]; loadu has no alignment
        // requirement.
        unsafe { _mm512_loadu_ps(l.as_ptr()) }
    }

    #[inline]
    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    unsafe fn st2(v: __m512) -> Lane2 {
        let mut out: Lane2 = [0.0; LANES2];
        // SAFETY: `out` is a valid 16-f32 destination; storeu has no
        // alignment requirement.
        unsafe { _mm512_storeu_ps(out.as_mut_ptr(), v) };
        out
    }

    /// 16 i32 gather/scatter indices from a paired chunk's column ids.
    #[inline]
    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    unsafe fn idx16(lj: &[usize; LANES2]) -> __m512i {
        let mut ix = [0i32; LANES2];
        for k in 0..LANES2 {
            // Ids were validated to fit i32 with the stripe width, so
            // the narrowing keeps them non-negative.
            ix[k] = lj[k] as i32;
        }
        // SAFETY: `ix` is a valid 16-i32 source; loadu is unaligned.
        unsafe { _mm512_loadu_epi32(ix.as_ptr()) }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn gather_chunk2(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES2], Lane2, Lane2, Lane2) {
        debug_assert!(base + LANES2 <= cols.len() && base + LANES2 <= vals.len());
        // SAFETY: (whole body) caller guarantees base + 2·LANES within
        // cols/vals and every stored column id < w.len() <= inv.len().
        // Column ids fit i32 (checked against the stripe width by
        // `check_packed_bounds`), so the i32 gather indices are
        // non-negative.
        unsafe {
            let idx = _mm512_loadu_epi32(cols.as_ptr().add(base) as *const i32);
            // One 16-wide hardware gather per table: two adjacent
            // chunks' w and reciprocal values in a single instruction
            // each.
            let wv = _mm512_i32gather_ps::<4>(idx, w.as_ptr() as *const u8);
            let iv = _mm512_i32gather_ps::<4>(idx, inv.as_ptr() as *const u8);
            let xv = _mm512_loadu_ps(vals.as_ptr().add(base));
            let mut lj = [0usize; LANES2];
            for (k, slot) in lj.iter_mut().enumerate() {
                *slot = *cols.get_unchecked(base + k) as usize;
            }
            (lj, st2(wv), st2(xv), st2(iv))
        }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn gather_idx2(src: &[f32], lj: &[usize; LANES2]) -> Lane2 {
        debug_assert!(lj.iter().all(|&j| j < src.len()));
        // SAFETY: caller guarantees every lj[k] < src.len(); ids fit
        // i32 per the packing validation.
        unsafe { st2(_mm512_i32gather_ps::<4>(idx16(lj), src.as_ptr() as *const u8)) }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn scatter2(dst: &mut [f32], lj: &[usize; LANES2], v: &Lane2) {
        debug_assert!(lj.iter().all(|&j| j < dst.len()));
        // SAFETY: caller guarantees every lj[k] < dst.len() and that
        // the pair's ids are distinct (a full pair of one row group),
        // so the native scatter writes 16 disjoint in-bounds f32 slots.
        unsafe { _mm512_i32scatter_ps::<4>(dst.as_mut_ptr() as *mut u8, idx16(lj), ld2(v)) };
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn w_grad2(
        lam: f32,
        rv: &Lane2,
        iv: &Lane2,
        av: &Lane2,
        xv: &Lane2,
    ) -> Lane2 {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            // Same contraction as the 256-bit pipeline at double width:
            // t = λ·∇φ·(1/|Ω̄_j|); gw = t − α·x with one fused rounding.
            let t = _mm512_mul_ps(_mm512_mul_ps(_mm512_set1_ps(lam), ld2(rv)), ld2(iv));
            st2(_mm512_fnmadd_ps(ld2(av), ld2(xv), t))
        }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn w_step_clamp2(wv: &Lane2, etav: &Lane2, gw: &Lane2, b: f32) -> Lane2 {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let wn = _mm512_fnmadd_ps(ld2(etav), ld2(gw), ld2(wv));
            st2(_mm512_min_ps(_mm512_max_ps(wn, _mm512_set1_ps(-b)), _mm512_set1_ps(b)))
        }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn affine_coeffs2(bias: f32, wv: &Lane2, xv: &Lane2) -> Lane2 {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe { st2(_mm512_fnmadd_ps(ld2(wv), ld2(xv), _mm512_set1_ps(bias))) }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn l1_grad_lane2(w: &Lane2) -> Lane2 {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let wv = ld2(w);
            let zero = _mm512_setzero_ps();
            // sign(w) with 0 at the kink, via mask-selects. AVX-512F
            // has no 512-bit float OR (that's DQ), so the two selects
            // combine with an add — exact, because each lane is +1/−1
            // in exactly one operand and +0.0 in the other, and
            // x + (+0.0) preserves the bit pattern (+0.0 + +0.0 = +0.0
            // matches the portable kink convention bitwise).
            let pos = _mm512_maskz_mov_ps(
                _mm512_cmp_ps_mask::<{ _CMP_GT_OQ }>(wv, zero),
                _mm512_set1_ps(1.0),
            );
            let neg = _mm512_maskz_mov_ps(
                _mm512_cmp_ps_mask::<{ _CMP_LT_OQ }>(wv, zero),
                _mm512_set1_ps(-1.0),
            );
            st2(_mm512_add_ps(pos, neg))
        }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn l2_grad_lane2(w: &Lane2) -> Lane2 {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let wv = ld2(w);
            st2(_mm512_add_ps(wv, wv))
        }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn adagrad_eta_lane2(
        e0: f32,
        eps: f32,
        acc: &mut Lane2,
        g: &Lane2,
    ) -> Lane2 {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let gv = ld2(g);
            let a = _mm512_fmadd_ps(gv, gv, ld2(acc));
            *acc = st2(a);
            st2(_mm512_div_ps(
                _mm512_set1_ps(e0),
                _mm512_sqrt_ps(_mm512_add_ps(_mm512_set1_ps(eps), a)),
            ))
        }
    }

    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    pub(super) unsafe fn predict_fold_chunk2(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        debug_assert!(base + LANES2 <= cols.len() && base + LANES2 <= vals.len());
        // SAFETY: (whole body) caller guarantees base + 2·LANES within
        // cols/vals, all 16 entries real, and every column id <
        // w.len(); ids fit i32 (the packer refuses d > i32::MAX).
        unsafe {
            let idx = _mm512_loadu_epi32(cols.as_ptr().add(base) as *const i32);
            let wv = st2(_mm512_i32gather_ps::<4>(idx, w.as_ptr() as *const u8));
            let xv = st2(_mm512_loadu_ps(vals.as_ptr().add(base)));
            // The fold stays scalar f64 in storage order — bit-identical
            // to two 8-wide folds on any backend (the cross-backend
            // predict contract); the single 16-wide gather is the win.
            for k in 0..LANES2 {
                *acc += xv[k] as f64 * wv[k] as f64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const W: Lane = [-1.5, -0.25, 0.0, 0.4, 1.0, -0.0, 3.25, -7.5];

    #[test]
    fn portable_matches_the_former_inline_loops() {
        // The backend is the moved PR 2/3 code; pin a few identities so
        // a future edit can't silently drift the bit-exact baseline.
        let rv = Portable::l2_grad_lane(&W);
        for k in 0..LANES {
            assert_eq!(rv[k], 2.0 * W[k]);
        }
        let gw = Portable::w_grad(0.5, &W, &W, &W, &W);
        for k in 0..LANES {
            assert_eq!(gw[k], 0.5 * W[k] * W[k] - W[k] * W[k]);
        }
        let mut acc: Lane = [1.0; LANES];
        let eta = Portable::adagrad_eta_lane(0.1, 1e-8, &mut acc, &W);
        for k in 0..LANES {
            assert_eq!(acc[k], 1.0 + W[k] * W[k]);
            assert_eq!(eta[k], 0.1 / (1e-8 + acc[k]).sqrt());
        }
    }

    #[test]
    fn portable_gathers_respect_indices() {
        let cols: Vec<u32> = vec![3, 1, 4, 1, 5, 2, 6, 5];
        let vals: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let w: Vec<f32> = (0..8).map(|i| 10.0 + i as f32).collect();
        let inv: Vec<f32> = (0..8).map(|i| 1.0 / (1.0 + i as f32)).collect();
        // SAFETY: all of cols[0..8] index within w/inv, base 0 + LANES
        // == cols.len().
        let (lj, wv, xv, iv) = unsafe { Portable::gather_chunk(&cols, &vals, 0, &w, &inv) };
        for k in 0..LANES {
            assert_eq!(lj[k], cols[k] as usize);
            assert_eq!(wv[k], w[cols[k] as usize]);
            assert_eq!(xv[k], vals[k]);
            assert_eq!(iv[k], inv[cols[k] as usize]);
        }
        // SAFETY: lj entries validated above.
        let acc = unsafe { Portable::gather_idx(&w, &lj) };
        for k in 0..LANES {
            assert_eq!(acc[k], w[lj[k]]);
        }
    }

    #[test]
    fn portable_predict_fold_is_row_dot_order() {
        let cols: Vec<u32> = vec![3, 1, 4, 1, 5, 2, 6, 5];
        let vals: Vec<f32> = (0..8).map(|i| 0.5 + i as f32).collect();
        let w: Vec<f32> = (0..8).map(|i| (i as f32) * 0.3 - 1.0).collect();
        for n in [0usize, 3, 8] {
            let mut acc = 0.25f64;
            // SAFETY: cols[0..8] all < 8 == w.len(), base 0 + LANES ==
            // cols.len(), n <= LANES.
            unsafe { Portable::predict_fold_chunk(&cols, &vals, 0, n, &w, &mut acc) };
            let mut want = 0.25f64;
            for k in 0..n {
                want += vals[k] as f64 * w[cols[k] as usize] as f64;
            }
            assert_eq!(acc, want, "n = {n} fold must be storage-order f64");
        }
    }

    /// AVX2 vs portable on every backend op — the fine-grained leg of
    /// the differential story (the kernel-level legs live in
    /// `tests/lane_kernel.rs` / `tests/alpha_lane.rs`). Gathers and
    /// selects must agree bitwise; FMA-contracted arithmetic to ≤1 ulp
    /// against the twice-rounded portable result.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_ops_match_portable() {
        if !(is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")) {
            eprintln!("skipping: avx2+fma not available on this host");
            return;
        }
        let x: Lane = [0.5, -1.25, 2.0, -0.75, 0.125, 3.5, -2.25, 1.0];
        let close = |a: &Lane, b: &Lane, what: &str| {
            for k in 0..LANES {
                let rel = (a[k] - b[k]).abs() / b[k].abs().max(1e-6);
                assert!(rel <= 1e-6, "{what}[{k}]: {} vs {}", a[k], b[k]);
            }
        };
        assert_eq!(Avx2::l1_grad_lane(&W), Portable::l1_grad_lane(&W));
        assert_eq!(Avx2::l2_grad_lane(&W), Portable::l2_grad_lane(&W));
        close(
            &Avx2::w_grad(0.3, &W, &x, &x, &W),
            &Portable::w_grad(0.3, &W, &x, &x, &W),
            "w_grad",
        );
        close(
            &Avx2::w_step_clamp(&W, &x, &x, 2.5),
            &Portable::w_step_clamp(&W, &x, &x, 2.5),
            "w_step_clamp",
        );
        close(
            &Avx2::affine_coeffs(0.7, &W, &x),
            &Portable::affine_coeffs(0.7, &W, &x),
            "affine_coeffs",
        );
        let mut acc_a: Lane = [0.5; LANES];
        let mut acc_p: Lane = [0.5; LANES];
        let ea = Avx2::adagrad_eta_lane(0.1, 1e-8, &mut acc_a, &x);
        let ep = Portable::adagrad_eta_lane(0.1, 1e-8, &mut acc_p, &x);
        close(&ea, &ep, "adagrad_eta");
        close(&acc_a, &acc_p, "adagrad_acc");

        let cols: Vec<u32> = vec![7, 0, 3, 3, 2, 6, 1, 5, 4, 4, 0, 7, 1, 2, 5, 6];
        let vals: Vec<f32> = (0..16).map(|i| 0.25 * i as f32).collect();
        let w: Vec<f32> = (0..8).map(|i| (i as f32).sin()).collect();
        let inv: Vec<f32> = (0..8).map(|i| 1.0 / (2.0 + i as f32)).collect();
        for base in [0usize, 8] {
            // SAFETY: cols[base..base+8] all < 8 == w.len() == inv.len().
            let a = unsafe { Avx2::gather_chunk(&cols, &vals, base, &w, &inv) };
            // SAFETY: as above.
            let p = unsafe { Portable::gather_chunk(&cols, &vals, base, &w, &inv) };
            assert_eq!(a.0, p.0);
            assert_eq!(a.1, p.1, "gather w bitwise");
            assert_eq!(a.2, p.2, "load x bitwise");
            assert_eq!(a.3, p.3, "gather inv bitwise");
            // SAFETY: index set validated above.
            let (aa, pa) = unsafe { (Avx2::gather_idx(&w, &a.0), Portable::gather_idx(&w, &p.0)) };
            assert_eq!(aa, pa, "gather_idx bitwise");
            for n in [0usize, 5, 8] {
                let (mut fa, mut fp) = (1.5f64, 1.5f64);
                // SAFETY: same bounds as the gathers above; n <= LANES.
                unsafe {
                    Avx2::predict_fold_chunk(&cols, &vals, base, n, &w, &mut fa);
                    Portable::predict_fold_chunk(&cols, &vals, base, n, &w, &mut fp);
                }
                // The predict fold is f64 storage-order on both
                // backends, so — unlike the FMA pipeline — bitwise.
                assert_eq!(fa, fp, "predict_fold bitwise (base {base}, n {n})");
            }
        }
    }

    const W2: Lane2 = [
        -1.5, -0.25, 0.0, 0.4, 1.0, -0.0, 3.25, -7.5, //
        2.0, -3.0, 0.125, -0.5, 9.0, -0.0, 0.0, 1e-3,
    ];

    /// The composed pair-op defaults are *definitionally* two adjacent
    /// 8-wide chunks — pin that on the portable backend so the pair
    /// surface every backend inherits can't drift from the lane ops it
    /// claims to fuse.
    #[test]
    fn paired_defaults_compose_two_lane_chunks_bitwise() {
        let x2: Lane2 = [
            0.5, -1.25, 2.0, -0.75, 0.125, 3.5, -2.25, 1.0, //
            -0.5, 1.75, -3.0, 0.25, 4.5, -0.125, 2.5, -1.0,
        ];
        let (wlo, whi) = split_lanes(&W2);
        let (xlo, xhi) = split_lanes(&x2);

        let gw2 = Portable::w_grad2(0.3, &W2, &x2, &x2, &W2);
        let glo = Portable::w_grad(0.3, &wlo, &xlo, &xlo, &wlo);
        let ghi = Portable::w_grad(0.3, &whi, &xhi, &xhi, &whi);
        assert_eq!(gw2, join_lanes(&glo, &ghi), "w_grad2");

        let wn2 = Portable::w_step_clamp2(&W2, &x2, &x2, 2.5);
        assert_eq!(
            wn2,
            join_lanes(
                &Portable::w_step_clamp(&wlo, &xlo, &xlo, 2.5),
                &Portable::w_step_clamp(&whi, &xhi, &xhi, 2.5)
            ),
            "w_step_clamp2"
        );

        assert_eq!(
            Portable::affine_coeffs2(0.7, &W2, &x2),
            join_lanes(
                &Portable::affine_coeffs(0.7, &wlo, &xlo),
                &Portable::affine_coeffs(0.7, &whi, &xhi)
            ),
            "affine_coeffs2"
        );
        assert_eq!(
            Portable::l1_grad_lane2(&W2),
            join_lanes(&Portable::l1_grad_lane(&wlo), &Portable::l1_grad_lane(&whi)),
        );
        assert_eq!(
            Portable::l2_grad_lane2(&W2),
            join_lanes(&Portable::l2_grad_lane(&wlo), &Portable::l2_grad_lane(&whi)),
        );

        let mut acc2: Lane2 = [0.5; LANES2];
        let (mut alo, mut ahi): (Lane, Lane) = ([0.5; LANES], [0.5; LANES]);
        let e2 = Portable::adagrad_eta_lane2(0.1, 1e-8, &mut acc2, &x2);
        let elo = Portable::adagrad_eta_lane(0.1, 1e-8, &mut alo, &xlo);
        let ehi = Portable::adagrad_eta_lane(0.1, 1e-8, &mut ahi, &xhi);
        assert_eq!(e2, join_lanes(&elo, &ehi), "adagrad_eta_lane2");
        assert_eq!(acc2, join_lanes(&alo, &ahi), "adagrad acc2");

        // Paired gathers/scatter/fold over a synthetic two-chunk block
        // with distinct ids per pair (the row-group invariant).
        let cols: Vec<u32> = (0..16u32).map(|i| (i * 7 + 3) % 16).collect();
        let vals: Vec<f32> = (0..16).map(|i| 0.25 * i as f32 - 1.0).collect();
        let w: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let inv: Vec<f32> = (0..16).map(|i| 1.0 / (2.0 + i as f32)).collect();
        // SAFETY: cols[0..16] all < 16 == w.len() == inv.len().
        let pair = unsafe { Portable::gather_chunk2(&cols, &vals, 0, &w, &inv) };
        // SAFETY: as above, chunk by chunk.
        let (c0, c1) = unsafe {
            (
                Portable::gather_chunk(&cols, &vals, 0, &w, &inv),
                Portable::gather_chunk(&cols, &vals, LANES, &w, &inv),
            )
        };
        assert_eq!(pair.0, join_idx(&c0.0, &c1.0));
        assert_eq!(pair.1, join_lanes(&c0.1, &c1.1), "gather2 w");
        assert_eq!(pair.2, join_lanes(&c0.2, &c1.2), "gather2 x");
        assert_eq!(pair.3, join_lanes(&c0.3, &c1.3), "gather2 inv");
        // SAFETY: ids validated above.
        let acc_pair = unsafe { Portable::gather_idx2(&w, &pair.0) };
        for k in 0..LANES2 {
            assert_eq!(acc_pair[k], w[pair.0[k]], "gather_idx2 lane {k}");
        }
        let mut dst = vec![0f32; 16];
        // SAFETY: ids validated above; cols covers each id exactly once
        // (i*7+3 mod 16 is a bijection), so the scatter is conflict-free.
        unsafe { Portable::scatter2(&mut dst, &pair.0, &W2) };
        for k in 0..LANES2 {
            assert_eq!(dst[pair.0[k]], W2[k], "scatter2 lane {k}");
        }
        let (mut f2, mut f88) = (0.75f64, 0.75f64);
        // SAFETY: bounds as above; the pair fold requires both chunks
        // real, which this synthetic block satisfies.
        unsafe {
            Portable::predict_fold_chunk2(&cols, &vals, 0, &w, &mut f2);
            Portable::predict_fold_chunk(&cols, &vals, 0, LANES, &w, &mut f88);
            Portable::predict_fold_chunk(&cols, &vals, LANES, LANES, &w, &mut f88);
        }
        assert_eq!(f2, f88, "predict_fold_chunk2 == two folds bitwise");
    }

    /// AVX-512 pair ops vs the composed portable defaults — the
    /// fine-grained leg of the 512-bit differential story (kernel-level
    /// legs in `tests/lane_kernel.rs` / `tests/alpha_lane.rs`).
    /// Gathers, selects, the scatter, and the predict fold must agree
    /// bitwise; FMA-contracted arithmetic to ≤1 ulp.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx512_pair_ops_match_portable() {
        if !crate::simd::avx512_supported() {
            eprintln!("skipping: avx512f+avx2+fma not available on this host");
            return;
        }
        let x2: Lane2 = [
            0.5, -1.25, 2.0, -0.75, 0.125, 3.5, -2.25, 1.0, //
            -0.5, 1.75, -3.0, 0.25, 4.5, -0.125, 2.5, -1.0,
        ];
        let close = |a: &Lane2, b: &Lane2, what: &str| {
            for k in 0..LANES2 {
                let rel = (a[k] - b[k]).abs() / b[k].abs().max(1e-6);
                assert!(rel <= 1e-6, "{what}[{k}]: {} vs {}", a[k], b[k]);
            }
        };
        // Exact selects: bitwise against portable (kink convention
        // included — W2 carries ±0.0 lanes).
        assert_eq!(Avx512::l1_grad_lane2(&W2), Portable::l1_grad_lane2(&W2));
        assert_eq!(Avx512::l2_grad_lane2(&W2), Portable::l2_grad_lane2(&W2));
        close(
            &Avx512::w_grad2(0.3, &W2, &x2, &x2, &W2),
            &Portable::w_grad2(0.3, &W2, &x2, &x2, &W2),
            "w_grad2",
        );
        close(
            &Avx512::w_step_clamp2(&W2, &x2, &x2, 2.5),
            &Portable::w_step_clamp2(&W2, &x2, &x2, 2.5),
            "w_step_clamp2",
        );
        close(
            &Avx512::affine_coeffs2(0.7, &W2, &x2),
            &Portable::affine_coeffs2(0.7, &W2, &x2),
            "affine_coeffs2",
        );
        let mut acc_a: Lane2 = [0.5; LANES2];
        let mut acc_p: Lane2 = [0.5; LANES2];
        let ea = Avx512::adagrad_eta_lane2(0.1, 1e-8, &mut acc_a, &x2);
        let ep = Portable::adagrad_eta_lane2(0.1, 1e-8, &mut acc_p, &x2);
        close(&ea, &ep, "adagrad_eta2");
        close(&acc_a, &acc_p, "adagrad_acc2");

        let cols: Vec<u32> = vec![7, 0, 3, 12, 2, 6, 1, 5, 4, 15, 8, 11, 9, 13, 10, 14];
        let vals: Vec<f32> = (0..16).map(|i| 0.25 * i as f32 - 2.0).collect();
        let w: Vec<f32> = (0..16).map(|i| (i as f32).cos()).collect();
        let inv: Vec<f32> = (0..16).map(|i| 1.0 / (3.0 + i as f32)).collect();
        // SAFETY: cols[0..16] all < 16 == w.len() == inv.len(); the
        // avx512 guard above ran.
        let a = unsafe { Avx512::gather_chunk2(&cols, &vals, 0, &w, &inv) };
        // SAFETY: as above.
        let p = unsafe { Portable::gather_chunk2(&cols, &vals, 0, &w, &inv) };
        assert_eq!(a.0, p.0);
        assert_eq!(a.1, p.1, "gather2 w bitwise");
        assert_eq!(a.2, p.2, "load2 x bitwise");
        assert_eq!(a.3, p.3, "gather2 inv bitwise");
        // SAFETY: ids validated above.
        let (aa, pa) = unsafe { (Avx512::gather_idx2(&w, &a.0), Portable::gather_idx2(&w, &p.0)) };
        assert_eq!(aa, pa, "gather_idx2 bitwise");
        let (mut da, mut dp) = (vec![0f32; 16], vec![0f32; 16]);
        // SAFETY: ids validated above and distinct (cols is a
        // permutation of 0..16), so both scatters are conflict-free.
        unsafe {
            Avx512::scatter2(&mut da, &a.0, &x2);
            Portable::scatter2(&mut dp, &p.0, &x2);
        }
        assert_eq!(da, dp, "scatter2 bitwise");
        let (mut fa, mut fp) = (1.5f64, 1.5f64);
        // SAFETY: bounds as above; both chunks real.
        unsafe {
            Avx512::predict_fold_chunk2(&cols, &vals, 0, &w, &mut fa);
            Portable::predict_fold_chunk2(&cols, &vals, 0, &w, &mut fp);
        }
        assert_eq!(fa, fp, "predict_fold_chunk2 bitwise");

        // And the 8-wide epilogue ops are the AVX2 pipeline verbatim.
        let (lo, _) = split_lanes(&W2);
        assert_eq!(Avx512::l1_grad_lane(&lo), Avx2::l1_grad_lane(&lo));
        assert_eq!(Avx512::l2_grad_lane(&lo), Avx2::l2_grad_lane(&lo));
    }
}
