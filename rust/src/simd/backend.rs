//! `SimdBackend` — the explicit-SIMD kernel backend abstraction.
//!
//! PR 2's lane kernel expressed the 8-wide w-side arithmetic as plain
//! per-lane loops over `Lane = [f32; LANES]`, relying on LLVM
//! autovectorization. That covers the arithmetic but not the **column
//! gathers**: loading 8 `(w_j, 1/|Ω̄_j|)` pairs through block-local
//! `u32` column ids compiles to 8 scalar loads per chunk — by PR 4 the
//! dominant cost of the hot loop (the ROADMAP gather-intrinsics item).
//!
//! This trait factors every lane-granular operation of the sweep —
//! chunk gather, ∇φ, gradient FMA, AdaGrad accumulate/√/divide, box
//! clamp, the affine-α coefficient lanes — behind one monomorphization
//! parameter, with two implementations:
//!
//! * [`Portable`] — the PR 2/3 per-lane loops, **bit-identical by
//!   construction** to the pre-backend kernels (it is the same code,
//!   moved). Compiles on every architecture; stable-Rust
//!   autovectorizable.
//! * [`Avx2`] (`x86_64` only) — `core::arch` intrinsics:
//!   `_mm256_i32gather_ps` for the column gathers, 256-bit FMA for the
//!   gradient/step pipeline, `_mm256_sqrt_ps`/`_mm256_div_ps` for the
//!   AdaGrad η batch, min/max for the clamp. The scatter of the `wn`
//!   lanes stays explicit per-lane stores in the shared kernel code
//!   (AVX2 has no scatter instruction; only the first `len` lanes of a
//!   chunk may be written).
//!
//! Which backend runs is decided **once per run** by
//! `coordinator::plan::SweepPlan` from runtime CPU-feature detection
//! ([`super::resolve`]) — kernels monomorphize over `B: SimdBackend`,
//! so there is zero per-chunk (or even per-sweep) dispatch, and
//! engines never touch feature detection (`scripts/ci.sh` greps them).
//!
//! ## Float-summation-order caveat, per backend
//!
//! [`Portable`] reproduces the PR 3 kernels bit for bit, so every
//! pinned suite keeps passing unchanged. [`Avx2`] contracts
//! multiply-adds into fused FMAs (single rounding where the portable
//! path rounds twice), so it is *tolerance-equivalent* to the portable
//! backend — ≤1e-5 relative per sweep against the COO oracle,
//! property-tested in `tests/lane_kernel.rs`/`tests/alpha_lane.rs` —
//! not bit-identical across backends. Threaded ≡ replay bit-identity
//! holds *within* a backend (both executions run the same plan).
//!
//! # Safety
//!
//! This is an `unsafe trait`: an implementation asserts that its
//! methods are sound to execute on the CPU the process is running on.
//! [`Portable`] is unconditionally sound; [`Avx2`] requires AVX2+FMA,
//! which every production path guarantees by construction — the only
//! producers of an `Avx2`-monomorphized call are
//! `SweepPlan`/[`super::resolve`] (behind `is_x86_feature_detected!`)
//! and tests that perform the same guard.

use crate::losses::kernel::Lane;
use crate::partition::omega::LANES;

/// Lane-granular kernel operations, monomorphized into the sweeps.
///
/// The two `unsafe fn`s carry the kernels' usual unchecked-indexing
/// contract: the caller has validated (via `check_packed_bounds`) that
/// `base + LANES` is within `cols`/`vals` and that every stored column
/// id — sentinels included — indexes within `w` and `inv`.
///
/// # Safety
///
/// Implementations must be sound on the running CPU; see the module
/// docs for how `Avx2` discharges this via runtime detection.
pub unsafe trait SimdBackend: Copy + Send + Sync + 'static {
    /// Backend tag recorded by `SweepPlan` and the benches.
    const NAME: &'static str;

    /// Full-width gather of one LANES chunk at physical `base`:
    /// (column ids, w values, x/m values, 1/|Ω̄_j|).
    ///
    /// # Safety
    /// `base + LANES <= cols.len() == vals.len()`, and every
    /// `cols[base..base + LANES]` is `< w.len() <= inv.len()` (resp.
    /// `<= w.len()`); both validated once per sweep by the caller.
    unsafe fn gather_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES], Lane, Lane, Lane);

    /// Gather 8 f32 by the chunk's precomputed column ids (the AdaGrad
    /// w-accumulator load).
    ///
    /// # Safety
    /// Every `lj[k] < src.len()` — the same validated column ids
    /// returned by [`SimdBackend::gather_chunk`].
    unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane;

    /// The w gradient lanes: `gw[k] = lam·rv[k]·iv[k] − av[k]·xv[k]`.
    fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane;

    /// Step + box clamp: `wn[k] = clamp(wv[k] − etav[k]·gw[k], −b, b)`.
    fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane;

    /// Affine-α coefficient lanes: `cv[k] = bias − wv[k]·xv[k]`.
    fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane;

    /// ∇φ for L1: `sign(w)` with 0 at the kink.
    fn l1_grad_lane(w: &Lane) -> Lane;

    /// ∇φ for L2: `2·w`.
    fn l2_grad_lane(w: &Lane) -> Lane;

    /// AdaGrad η batch: `acc[k] += g[k]²; out[k] = e0/√(eps + acc[k])`.
    fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane;

    /// Batched-predict fold (the serving kernel's one lane op): gather
    /// the chunk's w values through its column ids, multiply by the
    /// stored feature values, and fold the first `n` *real* lanes into
    /// `acc` — widening each f32·f32 product to f64 (exact: a product
    /// of two f32s is representable in f64) and accumulating in storage
    /// order, exactly `Csr::row_dot`'s recurrence. Sentinel lanes
    /// (`k >= n`) may be gathered speculatively but are never folded,
    /// so padding cannot perturb a score. Because the fold is f64 in
    /// storage order on every backend and the gather moves bits, this
    /// op — unlike the FMA-contracted training pipeline — is
    /// **bit-identical across backends**; AVX2's win is the hardware
    /// gather replacing 8 scalar indexed loads.
    ///
    /// # Safety
    /// `base + LANES <= cols.len() == vals.len()`, `n <= LANES`, and
    /// every `cols[base..base + LANES]` — sentinels included — is
    /// `< w.len()`; validated once per batch by `serve::predict`.
    unsafe fn predict_fold_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        n: usize,
        w: &[f32],
        acc: &mut f64,
    );
}

// ---------------------------------------------------------------------
// Portable backend — the PR 2/3 per-lane loops, verbatim
// ---------------------------------------------------------------------

/// Autovectorized baseline backend. Bit-identical to the pre-backend
/// (PR 3) kernels: these bodies are the exact loops that previously
/// lived inline in `coordinator::updates` / `losses::kernel`.
#[derive(Clone, Copy, Debug, Default)]
pub struct Portable;

// SAFETY: plain per-lane Rust with no target-feature requirements —
// sound on every CPU.
unsafe impl SimdBackend for Portable {
    const NAME: &'static str = "portable";

    #[inline(always)]
    unsafe fn gather_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES], Lane, Lane, Lane) {
        let mut lj = [0usize; LANES];
        let mut wv: Lane = [0.0; LANES];
        let mut xv: Lane = [0.0; LANES];
        let mut iv: Lane = [0.0; LANES];
        for k in 0..LANES {
            // SAFETY: the caller's contract — base + LANES in bounds of
            // cols/vals, every stored column validated in-stripe.
            unsafe {
                let c = *cols.get_unchecked(base + k) as usize;
                debug_assert!(c < w.len() && c < inv.len());
                lj[k] = c;
                wv[k] = *w.get_unchecked(c);
                xv[k] = *vals.get_unchecked(base + k);
                iv[k] = *inv.get_unchecked(c);
            }
        }
        (lj, wv, xv, iv)
    }

    #[inline(always)]
    unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane {
        let mut out: Lane = [0.0; LANES];
        for k in 0..LANES {
            debug_assert!(lj[k] < src.len());
            // SAFETY: caller guarantees lj[k] < src.len() (validated
            // column ids from gather_chunk).
            out[k] = unsafe { *src.get_unchecked(lj[k]) };
        }
        out
    }

    #[inline(always)]
    fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane {
        let mut gw: Lane = [0.0; LANES];
        for k in 0..LANES {
            gw[k] = lam * rv[k] * iv[k] - av[k] * xv[k];
        }
        gw
    }

    #[inline(always)]
    fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane {
        let mut wn: Lane = [0.0; LANES];
        for k in 0..LANES {
            wn[k] = (wv[k] - etav[k] * gw[k]).clamp(-b, b);
        }
        wn
    }

    #[inline(always)]
    fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane {
        let mut cv: Lane = [0.0; LANES];
        for k in 0..LANES {
            cv[k] = bias - wv[k] * xv[k];
        }
        cv
    }

    #[inline(always)]
    fn l1_grad_lane(w: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            // sign(w) with 0 at the kink — exact in f32, branch-free
            // select after vectorization.
            out[k] = if w[k] > 0.0 {
                1.0
            } else if w[k] < 0.0 {
                -1.0
            } else {
                0.0
            };
        }
        out
    }

    #[inline(always)]
    fn l2_grad_lane(w: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            out[k] = 2.0 * w[k];
        }
        out
    }

    #[inline(always)]
    fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane {
        let mut out = [0f32; LANES];
        for k in 0..LANES {
            let a = acc[k] + g[k] * g[k];
            acc[k] = a;
            out[k] = e0 / (eps + a).sqrt();
        }
        out
    }

    #[inline(always)]
    unsafe fn predict_fold_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        n: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        debug_assert!(n <= LANES && base + LANES <= cols.len() && base + LANES <= vals.len());
        for k in 0..n {
            // SAFETY: the caller's contract — base + LANES in bounds of
            // cols/vals, every stored column id < w.len().
            unsafe {
                let c = *cols.get_unchecked(base + k) as usize;
                debug_assert!(c < w.len());
                *acc += *vals.get_unchecked(base + k) as f64 * *w.get_unchecked(c) as f64;
            }
        }
    }
}

// ---------------------------------------------------------------------
// AVX2/FMA backend (x86_64)
// ---------------------------------------------------------------------

/// Explicit AVX2 + FMA backend: hardware gathers for the column loads,
/// fused multiply-adds for the arithmetic pipeline.
///
/// Every production `Avx2`-monomorphized call is produced behind
/// `is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")`
/// ([`super::resolve`], recorded in `SweepPlan`); tests perform the
/// same guard. See the trait-level safety contract.
#[cfg(target_arch = "x86_64")]
#[derive(Clone, Copy, Debug, Default)]
pub struct Avx2;

// SAFETY: all methods delegate to `#[target_feature(enable = "avx2",
// enable = "fma")]` functions; the trait contract (module docs) makes
// the caller guarantee those features are present before an Avx2
// monomorphization executes.
#[cfg(target_arch = "x86_64")]
unsafe impl SimdBackend for Avx2 {
    const NAME: &'static str = "avx2";

    #[inline(always)]
    unsafe fn gather_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES], Lane, Lane, Lane) {
        // SAFETY: bounds per the trait contract; AVX2+FMA present per
        // the backend-selection contract (module docs).
        unsafe { avx2::gather_chunk(cols, vals, base, w, inv) }
    }

    #[inline(always)]
    unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane {
        // SAFETY: indices per the trait contract; features per the
        // backend-selection contract.
        unsafe { avx2::gather_idx(src, lj) }
    }

    #[inline(always)]
    fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane {
        // SAFETY: pure lane arithmetic on stack arrays; AVX2+FMA
        // present per the backend-selection contract.
        unsafe { avx2::w_grad(lam, rv, iv, av, xv) }
    }

    #[inline(always)]
    fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::w_step_clamp(wv, etav, gw, b) }
    }

    #[inline(always)]
    fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::affine_coeffs(bias, wv, xv) }
    }

    #[inline(always)]
    fn l1_grad_lane(w: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::l1_grad_lane(w) }
    }

    #[inline(always)]
    fn l2_grad_lane(w: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::l2_grad_lane(w) }
    }

    #[inline(always)]
    fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane {
        // SAFETY: as in `w_grad`.
        unsafe { avx2::adagrad_eta_lane(e0, eps, acc, g) }
    }

    #[inline(always)]
    unsafe fn predict_fold_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        n: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        // SAFETY: bounds per the trait contract; AVX2+FMA present per
        // the backend-selection contract.
        unsafe { avx2::predict_fold_chunk(cols, vals, base, n, w, acc) }
    }
}

/// The intrinsic bodies. `#[target_feature]` cannot be applied to
/// trait methods, so the `SimdBackend for Avx2` impl wraps these free
/// functions. All are `unsafe fn`: callers guarantee AVX2+FMA (and the
/// gathers' index bounds).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{Lane, LANES};
    use core::arch::x86_64::*;

    /// Round-trip helpers: `Lane` is only 4-byte aligned, so use
    /// unaligned vector moves (same throughput as aligned on AVX2).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn ld(l: &Lane) -> __m256 {
        // SAFETY: `l` is a valid [f32; 8]; loadu has no alignment
        // requirement.
        unsafe { _mm256_loadu_ps(l.as_ptr()) }
    }

    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn st(v: __m256) -> Lane {
        let mut out: Lane = [0.0; LANES];
        // SAFETY: `out` is a valid 8-f32 destination; storeu has no
        // alignment requirement.
        unsafe { _mm256_storeu_ps(out.as_mut_ptr(), v) };
        out
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn gather_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        w: &[f32],
        inv: &[f32],
    ) -> ([usize; LANES], Lane, Lane, Lane) {
        debug_assert!(base + LANES <= cols.len() && base + LANES <= vals.len());
        // SAFETY: (whole body) caller guarantees base + LANES within
        // cols/vals and every stored column id < w.len() <= inv.len().
        // Column ids fit i32 (checked against the stripe width by
        // `check_packed_bounds`), so the sign-extending i32 gather
        // indices are non-negative.
        unsafe {
            let idx = _mm256_loadu_si256(cols.as_ptr().add(base) as *const __m256i);
            // Hardware gathers: 8 w values and 8 reciprocal-table
            // values through one index vector each — this replaces the
            // 8 + 8 scalar loads that dominated the autovec kernel.
            let wv = _mm256_i32gather_ps::<4>(w.as_ptr(), idx);
            let iv = _mm256_i32gather_ps::<4>(inv.as_ptr(), idx);
            let xv = _mm256_loadu_ps(vals.as_ptr().add(base));
            let mut lj = [0usize; LANES];
            for (k, slot) in lj.iter_mut().enumerate() {
                *slot = *cols.get_unchecked(base + k) as usize;
            }
            (lj, st(wv), st(xv), st(iv))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn gather_idx(src: &[f32], lj: &[usize; LANES]) -> Lane {
        debug_assert!(lj.iter().all(|&j| j < src.len()));
        // SAFETY: caller guarantees every lj[k] < src.len(); ids were
        // validated < i32::MAX with the stripe width.
        unsafe {
            let idx = _mm256_setr_epi32(
                lj[0] as i32,
                lj[1] as i32,
                lj[2] as i32,
                lj[3] as i32,
                lj[4] as i32,
                lj[5] as i32,
                lj[6] as i32,
                lj[7] as i32,
            );
            st(_mm256_i32gather_ps::<4>(src.as_ptr(), idx))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn w_grad(lam: f32, rv: &Lane, iv: &Lane, av: &Lane, xv: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            // t = λ·∇φ·(1/|Ω̄_j|); gw = t − α·x  (fused: one rounding
            // on the subtract-multiply, vs two on the portable path —
            // the per-backend float-order caveat).
            let t = _mm256_mul_ps(_mm256_mul_ps(_mm256_set1_ps(lam), ld(rv)), ld(iv));
            st(_mm256_fnmadd_ps(ld(av), ld(xv), t))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn w_step_clamp(wv: &Lane, etav: &Lane, gw: &Lane, b: f32) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let wn = _mm256_fnmadd_ps(ld(etav), ld(gw), ld(wv));
            st(_mm256_min_ps(_mm256_max_ps(wn, _mm256_set1_ps(-b)), _mm256_set1_ps(b)))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn affine_coeffs(bias: f32, wv: &Lane, xv: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe { st(_mm256_fnmadd_ps(ld(wv), ld(xv), _mm256_set1_ps(bias))) }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn l1_grad_lane(w: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let wv = ld(w);
            let zero = _mm256_setzero_ps();
            // sign(w) with 0 at the kink (±0.0 compare equal to 0):
            // mask-select +1 where w > 0, −1 where w < 0.
            let pos =
                _mm256_and_ps(_mm256_cmp_ps::<{ _CMP_GT_OQ }>(wv, zero), _mm256_set1_ps(1.0));
            let neg =
                _mm256_and_ps(_mm256_cmp_ps::<{ _CMP_LT_OQ }>(wv, zero), _mm256_set1_ps(-1.0));
            st(_mm256_or_ps(pos, neg))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn l2_grad_lane(w: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let wv = ld(w);
            // 2·w is exact in f32 (exponent bump), identical to the
            // portable lane.
            st(_mm256_add_ps(wv, wv))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn adagrad_eta_lane(e0: f32, eps: f32, acc: &mut Lane, g: &Lane) -> Lane {
        // SAFETY: stack-only lane arithmetic; features per caller.
        unsafe {
            let gv = ld(g);
            let a = _mm256_fmadd_ps(gv, gv, ld(acc));
            *acc = st(a);
            st(_mm256_div_ps(
                _mm256_set1_ps(e0),
                _mm256_sqrt_ps(_mm256_add_ps(_mm256_set1_ps(eps), a)),
            ))
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn predict_fold_chunk(
        cols: &[u32],
        vals: &[f32],
        base: usize,
        n: usize,
        w: &[f32],
        acc: &mut f64,
    ) {
        debug_assert!(n <= LANES && base + LANES <= cols.len() && base + LANES <= vals.len());
        // SAFETY: (whole body) caller guarantees base + LANES within
        // cols/vals and every stored column id — sentinels included —
        // < w.len(); ids fit i32 (serve's packer refuses d > i32::MAX),
        // so the sign-extending i32 gather indices are non-negative.
        unsafe {
            let idx = _mm256_loadu_si256(cols.as_ptr().add(base) as *const __m256i);
            // One hardware gather replaces the chunk's 8 scalar indexed
            // w loads; the speculative sentinel lanes read w[0] (valid)
            // and are discarded by the bounded fold below.
            let wv = st(_mm256_i32gather_ps::<4>(w.as_ptr(), idx));
            let xv = st(_mm256_loadu_ps(vals.as_ptr().add(base)));
            // The fold stays scalar f64 in storage order — bit-identical
            // to the portable backend and to `Csr::row_dot` (see the
            // trait docs); the gather is the memory-bound win.
            for k in 0..n {
                *acc += xv[k] as f64 * wv[k] as f64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const W: Lane = [-1.5, -0.25, 0.0, 0.4, 1.0, -0.0, 3.25, -7.5];

    #[test]
    fn portable_matches_the_former_inline_loops() {
        // The backend is the moved PR 2/3 code; pin a few identities so
        // a future edit can't silently drift the bit-exact baseline.
        let rv = Portable::l2_grad_lane(&W);
        for k in 0..LANES {
            assert_eq!(rv[k], 2.0 * W[k]);
        }
        let gw = Portable::w_grad(0.5, &W, &W, &W, &W);
        for k in 0..LANES {
            assert_eq!(gw[k], 0.5 * W[k] * W[k] - W[k] * W[k]);
        }
        let mut acc: Lane = [1.0; LANES];
        let eta = Portable::adagrad_eta_lane(0.1, 1e-8, &mut acc, &W);
        for k in 0..LANES {
            assert_eq!(acc[k], 1.0 + W[k] * W[k]);
            assert_eq!(eta[k], 0.1 / (1e-8 + acc[k]).sqrt());
        }
    }

    #[test]
    fn portable_gathers_respect_indices() {
        let cols: Vec<u32> = vec![3, 1, 4, 1, 5, 2, 6, 5];
        let vals: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let w: Vec<f32> = (0..8).map(|i| 10.0 + i as f32).collect();
        let inv: Vec<f32> = (0..8).map(|i| 1.0 / (1.0 + i as f32)).collect();
        // SAFETY: all of cols[0..8] index within w/inv, base 0 + LANES
        // == cols.len().
        let (lj, wv, xv, iv) = unsafe { Portable::gather_chunk(&cols, &vals, 0, &w, &inv) };
        for k in 0..LANES {
            assert_eq!(lj[k], cols[k] as usize);
            assert_eq!(wv[k], w[cols[k] as usize]);
            assert_eq!(xv[k], vals[k]);
            assert_eq!(iv[k], inv[cols[k] as usize]);
        }
        // SAFETY: lj entries validated above.
        let acc = unsafe { Portable::gather_idx(&w, &lj) };
        for k in 0..LANES {
            assert_eq!(acc[k], w[lj[k]]);
        }
    }

    #[test]
    fn portable_predict_fold_is_row_dot_order() {
        let cols: Vec<u32> = vec![3, 1, 4, 1, 5, 2, 6, 5];
        let vals: Vec<f32> = (0..8).map(|i| 0.5 + i as f32).collect();
        let w: Vec<f32> = (0..8).map(|i| (i as f32) * 0.3 - 1.0).collect();
        for n in [0usize, 3, 8] {
            let mut acc = 0.25f64;
            // SAFETY: cols[0..8] all < 8 == w.len(), base 0 + LANES ==
            // cols.len(), n <= LANES.
            unsafe { Portable::predict_fold_chunk(&cols, &vals, 0, n, &w, &mut acc) };
            let mut want = 0.25f64;
            for k in 0..n {
                want += vals[k] as f64 * w[cols[k] as usize] as f64;
            }
            assert_eq!(acc, want, "n = {n} fold must be storage-order f64");
        }
    }

    /// AVX2 vs portable on every backend op — the fine-grained leg of
    /// the differential story (the kernel-level legs live in
    /// `tests/lane_kernel.rs` / `tests/alpha_lane.rs`). Gathers and
    /// selects must agree bitwise; FMA-contracted arithmetic to ≤1 ulp
    /// against the twice-rounded portable result.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_ops_match_portable() {
        if !(is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")) {
            eprintln!("skipping: avx2+fma not available on this host");
            return;
        }
        let x: Lane = [0.5, -1.25, 2.0, -0.75, 0.125, 3.5, -2.25, 1.0];
        let close = |a: &Lane, b: &Lane, what: &str| {
            for k in 0..LANES {
                let rel = (a[k] - b[k]).abs() / b[k].abs().max(1e-6);
                assert!(rel <= 1e-6, "{what}[{k}]: {} vs {}", a[k], b[k]);
            }
        };
        assert_eq!(Avx2::l1_grad_lane(&W), Portable::l1_grad_lane(&W));
        assert_eq!(Avx2::l2_grad_lane(&W), Portable::l2_grad_lane(&W));
        close(
            &Avx2::w_grad(0.3, &W, &x, &x, &W),
            &Portable::w_grad(0.3, &W, &x, &x, &W),
            "w_grad",
        );
        close(
            &Avx2::w_step_clamp(&W, &x, &x, 2.5),
            &Portable::w_step_clamp(&W, &x, &x, 2.5),
            "w_step_clamp",
        );
        close(
            &Avx2::affine_coeffs(0.7, &W, &x),
            &Portable::affine_coeffs(0.7, &W, &x),
            "affine_coeffs",
        );
        let mut acc_a: Lane = [0.5; LANES];
        let mut acc_p: Lane = [0.5; LANES];
        let ea = Avx2::adagrad_eta_lane(0.1, 1e-8, &mut acc_a, &x);
        let ep = Portable::adagrad_eta_lane(0.1, 1e-8, &mut acc_p, &x);
        close(&ea, &ep, "adagrad_eta");
        close(&acc_a, &acc_p, "adagrad_acc");

        let cols: Vec<u32> = vec![7, 0, 3, 3, 2, 6, 1, 5, 4, 4, 0, 7, 1, 2, 5, 6];
        let vals: Vec<f32> = (0..16).map(|i| 0.25 * i as f32).collect();
        let w: Vec<f32> = (0..8).map(|i| (i as f32).sin()).collect();
        let inv: Vec<f32> = (0..8).map(|i| 1.0 / (2.0 + i as f32)).collect();
        for base in [0usize, 8] {
            // SAFETY: cols[base..base+8] all < 8 == w.len() == inv.len().
            let a = unsafe { Avx2::gather_chunk(&cols, &vals, base, &w, &inv) };
            // SAFETY: as above.
            let p = unsafe { Portable::gather_chunk(&cols, &vals, base, &w, &inv) };
            assert_eq!(a.0, p.0);
            assert_eq!(a.1, p.1, "gather w bitwise");
            assert_eq!(a.2, p.2, "load x bitwise");
            assert_eq!(a.3, p.3, "gather inv bitwise");
            // SAFETY: index set validated above.
            let (aa, pa) = unsafe { (Avx2::gather_idx(&w, &a.0), Portable::gather_idx(&w, &p.0)) };
            assert_eq!(aa, pa, "gather_idx bitwise");
            for n in [0usize, 5, 8] {
                let (mut fa, mut fp) = (1.5f64, 1.5f64);
                // SAFETY: same bounds as the gathers above; n <= LANES.
                unsafe {
                    Avx2::predict_fold_chunk(&cols, &vals, base, n, &w, &mut fa);
                    Portable::predict_fold_chunk(&cols, &vals, base, n, &w, &mut fp);
                }
                // The predict fold is f64 storage-order on both
                // backends, so — unlike the FMA pipeline — bitwise.
                assert_eq!(fa, fp, "predict_fold bitwise (base {base}, n {n})");
            }
        }
    }
}
