//! Measured `--simd auto` — the setup-time micro-autotune.
//!
//! PR 5 resolved `Auto` by CPU feature flags: widest detected backend
//! wins. Flags are a proxy, and a wrong one on real silicon — 512-bit
//! gathers/scatters on some parts downclock or split into µops such
//! that AVX2 wins despite avx512f being present, and on narrow
//! workloads the portable autovec loop can beat both. This module
//! replaces the proxy with the measurement itself: time one pass of
//! the representative sweep pipeline per supported backend for a few
//! milliseconds each and keep the observed winner.
//!
//! Three layers, separated so determinism is testable without a clock:
//!
//! * [`measure`] — wall-clock harness: reps of a caller-supplied
//!   workload per level under a budget, yielding units/sec.
//! * [`report_from`] — the **pure** winner rule: highest measured
//!   throughput, ties to the wider level, nothing measured → the
//!   widest supported level (PR 5's flag order). Same sample ⇒ same
//!   winner, pinned by test; the wall clock only enters through the
//!   sample.
//! * [`auto_report`] / [`auto_report_with`] — the process-wide memo.
//!   The **first** `Auto` resolution measures (the training setup path
//!   injects a probe over the run's real packed blocks; everyone else
//!   gets the synthetic [`ProbeWorkload`]); every later resolution —
//!   cache fingerprint, serve, API predict — reuses the same winner.
//!   This is the fingerprint-consistency contract: within a process
//!   `resolve(Auto)` is a constant. Across *processes* of one run the
//!   supervisor pins the winner into the config it ships
//!   (`SimdLevel::as_kind`), so workers never re-measure; across
//!   *runs*, a drifted winner changes the checkpoint/cache fingerprint
//!   and is conservatively refused — exactly how a hardware change is
//!   treated.
//!
//! No wall-clock reading is ever part of a fingerprint: the run
//! fingerprint hashes the resolved level name only. `BENCH_autotune.
//! json` (emitted by `benches/bench_updates.rs` via the shared bench
//! runner) records the same per-backend throughputs for the cross-PR
//! trajectory.

use super::backend::SimdBackend;
#[cfg(target_arch = "x86_64")]
use super::backend::{Avx2, Avx512};
use super::{supported_levels, Portable, SimdLevel};
use crate::losses::kernel::LANES2;
use crate::partition::omega::LANES;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Per-level budget for the default probe: long enough to amortize
/// gather warm-up, short enough that three backends stay ~10 ms of
/// setup.
const PROBE_BUDGET: Duration = Duration::from_millis(3);
/// Floor so a coarse clock can't decide a winner on one noisy rep.
const MIN_REPS: u32 = 3;
/// Ceiling so a pathologically fast clock/workload can't spin.
const MAX_REPS: u32 = 10_000;

/// One backend's measured throughput.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub level: SimdLevel,
    /// Workload units (processed entries) per second.
    pub units_per_sec: f64,
    /// Timed repetitions behind the estimate (excludes the warm-up).
    pub reps: u32,
}

/// The autotune's outcome: the winner plus everything it was judged
/// against — recorded on `SweepPlan` / the serve stack and surfaced in
/// `BENCH_autotune.json`.
#[derive(Clone, Debug)]
pub struct AutotuneReport {
    pub chosen: SimdLevel,
    pub measured: Vec<Measurement>,
}

impl AutotuneReport {
    /// The recorded throughput for `level`, if it was measured.
    pub fn units_per_sec(&self, level: SimdLevel) -> Option<f64> {
        self.measured.iter().find(|m| m.level == level).map(|m| m.units_per_sec)
    }
}

/// Wall-clock measurement harness: per level, one warm-up rep (page-in,
/// branch/µcode warm), then timed reps until `budget_per_level` (at
/// least [`MIN_REPS`]). `run` returns the units it processed; levels
/// it cannot handle should process 0 (they then never win — see
/// [`report_from`]).
pub fn measure<F>(levels: &[SimdLevel], budget_per_level: Duration, mut run: F) -> Vec<Measurement>
where
    F: FnMut(SimdLevel) -> usize,
{
    let mut out = Vec::with_capacity(levels.len());
    for &level in levels {
        let _ = run(level);
        let start = Instant::now();
        let mut units = 0u64;
        let mut reps = 0u32;
        loop {
            units += run(level) as u64;
            reps += 1;
            if (reps >= MIN_REPS && start.elapsed() >= budget_per_level) || reps >= MAX_REPS {
                break;
            }
        }
        let secs = start.elapsed().as_secs_f64().max(1e-9);
        out.push(Measurement { level, units_per_sec: units as f64 / secs, reps });
    }
    out
}

/// The pure winner rule — deterministic in its inputs (no clock):
///
/// * highest `units_per_sec` among measurements of *supported* levels
///   wins (a measurement for a level outside `levels` is discarded, so
///   an injected probe can never select a backend this CPU lacks);
/// * exact ties go to the wider level (the order of `levels`);
/// * nothing (valid) measured — e.g. no lane-eligible work to time —
///   falls back to the widest supported level, PR 5's flag behavior.
pub fn report_from(levels: &[SimdLevel], measured: Vec<Measurement>) -> AutotuneReport {
    fn rank(l: SimdLevel) -> u8 {
        match l {
            SimdLevel::Portable => 0,
            SimdLevel::Avx2 => 1,
            SimdLevel::Avx512 => 2,
        }
    }
    let mut best: Option<(f64, SimdLevel)> = None;
    for m in measured.iter().filter(|m| levels.contains(&m.level)) {
        let better = match best {
            None => true,
            Some((ups, lvl)) => {
                m.units_per_sec > ups || (m.units_per_sec == ups && rank(m.level) > rank(lvl))
            }
        };
        if better {
            best = Some((m.units_per_sec, m.level));
        }
    }
    let chosen = match best {
        Some((_, lvl)) => lvl,
        None => *levels.last().unwrap_or(&SimdLevel::Portable),
    };
    AutotuneReport { chosen, measured }
}

static AUTO: OnceLock<AutotuneReport> = OnceLock::new();

/// The process-wide measured `Auto` winner, probing the synthetic
/// [`ProbeWorkload`] if no earlier resolution has measured yet.
pub fn auto_report() -> &'static AutotuneReport {
    auto_report_with(|levels| {
        let mut wk = ProbeWorkload::standard();
        measure(levels, PROBE_BUDGET, |level| wk.run(level))
    })
}

/// The process-wide measured `Auto` winner, with the caller's probe
/// supplying the sample if (and only if) this is the first `Auto`
/// resolution in the process. The training setup path uses this to
/// measure on the run's **real packed blocks**; once memoized, every
/// probe is ignored and the recorded report is returned as-is.
///
/// Single-backend hosts short-circuit without measuring: there is
/// nothing to choose between.
pub fn auto_report_with<F>(probe: F) -> &'static AutotuneReport
where
    F: FnOnce(&[SimdLevel]) -> Vec<Measurement>,
{
    AUTO.get_or_init(|| {
        let levels = supported_levels();
        if levels.len() == 1 {
            return AutotuneReport { chosen: SimdLevel::Portable, measured: Vec::new() };
        }
        report_from(&levels, probe(&levels))
    })
}

/// Deterministic synthetic stand-in for a run's packed blocks: one
/// long lane-eligible row group (4096 entries of full pairs + one
/// trailing 8-wide chunk, so both the paired path and the epilogue are
/// timed) over a 512-column stripe. Column ids stride by 7, so every
/// 16-entry window holds distinct ids (the row-group invariant the
/// scatter relies on) while still exercising gather locality.
///
/// Used when `Auto` must resolve without a run in hand (serve, API
/// predict, cache fingerprints) and by the bench harness for
/// `BENCH_autotune.json`.
pub struct ProbeWorkload {
    cols: Vec<u32>,
    vals: Vec<f32>,
    w: Vec<f32>,
    acc: Vec<f32>,
    inv: Vec<f32>,
}

impl ProbeWorkload {
    pub fn standard() -> ProbeWorkload {
        const N_COLS: usize = 512;
        const NNZ: usize = 4096 + LANES;
        ProbeWorkload {
            cols: (0..NNZ).map(|i| ((i * 7 + 3) % N_COLS) as u32).collect(),
            vals: (0..NNZ).map(|i| 0.25 + 0.001 * (i % 97) as f32).collect(),
            w: (0..N_COLS).map(|j| 0.01 * (j % 13) as f32 - 0.05).collect(),
            acc: vec![0.5; N_COLS],
            inv: (0..N_COLS).map(|j| 1.0 / (1.0 + (j % 31) as f32)).collect(),
        }
    }

    /// One pass of the representative sweep pipeline (gather → ∇φ(L2)
    /// → gradient FMA → AdaGrad η → clamp → writeback) on `level`;
    /// returns the entries processed. The state evolves across reps
    /// (clamped, so it stays finite) — throughput, not values, is the
    /// output.
    pub fn run(&mut self, level: SimdLevel) -> usize {
        match level {
            SimdLevel::Portable => probe_pass::<Portable>(self),
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => {
                assert!(super::avx2_supported(), "probe on unsupported backend");
                // SAFETY: avx2+fma verified on the line above.
                unsafe { probe_pass_avx2(self) }
            }
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx512 => {
                assert!(super::avx512_supported(), "probe on unsupported backend");
                // SAFETY: avx512f+avx2+fma verified on the line above.
                unsafe { probe_pass_avx512(self) }
            }
            #[cfg(not(target_arch = "x86_64"))]
            SimdLevel::Avx2 | SimdLevel::Avx512 => {
                unreachable!("supported_levels never yields {level:?} off x86_64")
            }
        }
    }
}

/// The generic probe body — the same chunk pipeline shape as
/// `coordinator::updates::sweep_lanes` (paired loop for `PAIRED`
/// backends, 8-wide remainder), inlined into the per-backend
/// `#[target_feature]` wrappers below so the measured code has the
/// same fused codegen as the real whole-sweep entry points.
#[inline(always)]
fn probe_pass<B: SimdBackend>(wk: &mut ProbeWorkload) -> usize {
    let n = wk.cols.len();
    let mut base = 0usize;
    if B::PAIRED {
        while base + LANES2 <= n {
            // SAFETY: base + LANES2 <= cols.len() == vals.len(); every
            // column id < 512 == w/acc/inv lengths by construction;
            // ids within a 16-window are distinct (stride-7 pattern).
            let (lj, wv, xv, iv) = unsafe { B::gather_chunk2(&wk.cols, &wk.vals, base, &wk.w, &wk.inv) };
            let rv = B::l2_grad_lane2(&wv);
            let gw = B::w_grad2(0.01, &rv, &iv, &xv, &xv);
            // SAFETY: ids from gather_chunk2, all < acc.len().
            let mut accv = unsafe { B::gather_idx2(&wk.acc, &lj) };
            let etav = B::adagrad_eta_lane2(0.1, 1e-6, &mut accv, &gw);
            let wn = B::w_step_clamp2(&wv, &etav, &gw, 10.0);
            // SAFETY: ids validated above and distinct within the pair.
            unsafe {
                B::scatter2(&mut wk.w, &lj, &wn);
                B::scatter2(&mut wk.acc, &lj, &accv);
            }
            base += LANES2;
        }
    }
    while base + LANES <= n {
        // SAFETY: base + LANES <= cols.len() == vals.len(); ids < 512.
        let (lj, wv, xv, iv) = unsafe { B::gather_chunk(&wk.cols, &wk.vals, base, &wk.w, &wk.inv) };
        let rv = B::l2_grad_lane(&wv);
        let gw = B::w_grad(0.01, &rv, &iv, &xv, &xv);
        // SAFETY: ids from gather_chunk, all < acc.len().
        let mut accv = unsafe { B::gather_idx(&wk.acc, &lj) };
        let etav = B::adagrad_eta_lane(0.1, 1e-6, &mut accv, &gw);
        let wn = B::w_step_clamp(&wv, &etav, &gw, 10.0);
        for k in 0..LANES {
            wk.w[lj[k]] = wn[k];
            wk.acc[lj[k]] = accv[k];
        }
        base += LANES;
    }
    base
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn probe_pass_avx2(wk: &mut ProbeWorkload) -> usize {
    probe_pass::<Avx2>(wk)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
unsafe fn probe_pass_avx512(wk: &mut ProbeWorkload) -> usize {
    probe_pass::<Avx512>(wk)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(level: SimdLevel, ups: f64) -> Measurement {
        Measurement { level, units_per_sec: ups, reps: 5 }
    }

    const ALL: [SimdLevel; 3] = [SimdLevel::Portable, SimdLevel::Avx2, SimdLevel::Avx512];

    /// Same sample ⇒ same recorded winner — the determinism contract.
    /// The wall clock only enters through the sample; the rule itself
    /// is pure.
    #[test]
    fn winner_is_deterministic_for_a_fixed_sample() {
        let sample = vec![
            m(SimdLevel::Portable, 1.0e9),
            m(SimdLevel::Avx2, 2.5e9),
            m(SimdLevel::Avx512, 2.1e9),
        ];
        let a = report_from(&ALL, sample.clone());
        let b = report_from(&ALL, sample);
        assert_eq!(a.chosen, b.chosen);
        // And the measured winner is the measured winner — avx2 beat
        // avx512 in this sample, so flags must not override it.
        assert_eq!(a.chosen, SimdLevel::Avx2);
        assert_eq!(a.units_per_sec(SimdLevel::Avx512), Some(2.1e9));
    }

    #[test]
    fn ties_prefer_the_wider_level() {
        let report = report_from(
            &ALL,
            vec![m(SimdLevel::Portable, 2.0e9), m(SimdLevel::Avx2, 2.0e9)],
        );
        assert_eq!(report.chosen, SimdLevel::Avx2);
        // ...regardless of measurement order.
        let report = report_from(
            &ALL,
            vec![m(SimdLevel::Avx2, 2.0e9), m(SimdLevel::Portable, 2.0e9)],
        );
        assert_eq!(report.chosen, SimdLevel::Avx2);
    }

    #[test]
    fn empty_sample_falls_back_to_widest_supported() {
        // No lane-eligible work to time: keep PR 5's flag order.
        assert_eq!(report_from(&ALL, Vec::new()).chosen, SimdLevel::Avx512);
        assert_eq!(
            report_from(&[SimdLevel::Portable], Vec::new()).chosen,
            SimdLevel::Portable
        );
    }

    #[test]
    fn unsupported_levels_are_never_chosen() {
        // A measurement for a level this host lacks (e.g. injected by
        // a buggy probe) must be discarded, not executed.
        let report = report_from(
            &[SimdLevel::Portable],
            vec![m(SimdLevel::Avx512, 9.9e9), m(SimdLevel::Portable, 1.0e9)],
        );
        assert_eq!(report.chosen, SimdLevel::Portable);
    }

    #[test]
    fn measure_harness_times_every_level() {
        let mut calls = 0u32;
        let sample = measure(&[SimdLevel::Portable], Duration::from_micros(200), |_| {
            calls += 1;
            1000
        });
        assert_eq!(sample.len(), 1);
        assert!(sample[0].reps >= 3, "at least MIN_REPS timed reps");
        assert!(calls > sample[0].reps, "plus one warm-up rep");
        assert!(sample[0].units_per_sec > 0.0);
    }

    #[test]
    fn probe_workload_runs_on_every_supported_level() {
        let mut wk = ProbeWorkload::standard();
        for level in supported_levels() {
            let units = wk.run(level);
            assert_eq!(units, 4096 + crate::partition::omega::LANES, "level {level:?}");
            for &v in &wk.w {
                assert!(v.is_finite(), "probe state must stay finite on {level:?}");
            }
        }
    }

    #[test]
    fn auto_report_is_memoized_process_wide() {
        let a = auto_report();
        let b = auto_report_with(|_| panic!("second probe must never run"));
        assert!(std::ptr::eq(a, b), "one report per process");
        assert!(supported_levels().contains(&a.chosen));
        // Whenever more than one backend exists, each was measured.
        let levels = supported_levels();
        if levels.len() > 1 {
            for level in levels {
                assert!(a.units_per_sec(level).is_some(), "missing measurement for {level:?}");
            }
        }
    }
}
