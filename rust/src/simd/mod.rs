//! Explicit-SIMD kernel backends (§Perf, DESIGN.md §SIMD-backend).
//!
//! Three pieces:
//!
//! * [`aligned::AVec`] — the 64-byte-aligned storage the packed-block
//!   lane regions and per-stripe tables live in.
//! * [`backend::SimdBackend`] — the lane-granular kernel operations
//!   (chunk gather, gradient FMA, AdaGrad η batch, clamp, affine-α
//!   coefficients) behind one monomorphization parameter, with the
//!   [`backend::Portable`] autovec baseline and the x86_64
//!   [`backend::Avx2`] gather/FMA implementation.
//! * [`resolve`] — the one place runtime CPU-feature detection runs.
//!   Engines never detect features (ci.sh greps them); the resolved
//!   [`SimdLevel`] is recorded in `coordinator::plan::SweepPlan`, which
//!   monomorphizes the sweeps per backend so there is zero per-chunk
//!   dispatch.

// `unsafe fn` bodies in this subtree are NOT implicit unsafe contexts:
// every unsafe operation needs its own explicit block with a
// `// SAFETY:` argument (scripts/ci.sh gates the comments).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod aligned;
pub mod backend;

pub use aligned::{is_aligned, AVec, ALIGN};
#[cfg(target_arch = "x86_64")]
pub use backend::Avx2;
pub use backend::{Portable, SimdBackend};

use crate::config::SimdKind;

/// The backend a run executes with, resolved once at setup time and
/// recorded in the sweep plan. (The *request* — auto/portable/avx2 —
/// is [`crate::config::SimdKind`]; this is the answer.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Autovectorized per-lane loops; bit-identical to the PR 3
    /// kernels on every architecture.
    Portable,
    /// AVX2 gathers + FMA pipeline (x86_64 with avx2+fma detected, or
    /// forced via `--simd avx2` on such a host).
    Avx2,
}

impl SimdLevel {
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Portable => backend::Portable::NAME,
            SimdLevel::Avx2 => "avx2",
        }
    }
}

/// Whether the running CPU supports the AVX2 backend (AVX2 *and* FMA —
/// the kernel pipeline uses both instruction sets).
pub fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Resolve the configured backend request against the running CPU.
/// `Auto` picks AVX2 when supported and falls back to portable
/// otherwise; explicit requests are honored exactly. A forced `Avx2`
/// on an unsupported host **panics** with the same actionable message
/// `TrainConfig::validate` reports: validating callers (the `Trainer`
/// facade, the CLI) never reach the panic, and callers that skip
/// validation (the deprecated free-function shims) still can never get
/// a silent portable run out of an explicit avx2 request.
pub fn resolve(kind: SimdKind) -> SimdLevel {
    match kind {
        SimdKind::Portable => SimdLevel::Portable,
        SimdKind::Auto => {
            if avx2_supported() {
                SimdLevel::Avx2
            } else {
                SimdLevel::Portable
            }
        }
        SimdKind::Avx2 => {
            assert!(
                avx2_supported(),
                "cluster.simd = \"avx2\" but this CPU does not support avx2+fma; \
                 use simd = \"auto\" (runtime detection) or \"portable\""
            );
            SimdLevel::Avx2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn portable_always_honored() {
        assert_eq!(resolve(SimdKind::Portable), SimdLevel::Portable);
    }

    #[test]
    fn auto_matches_detection() {
        let want = if avx2_supported() { SimdLevel::Avx2 } else { SimdLevel::Portable };
        assert_eq!(resolve(SimdKind::Auto), want);
    }

    #[test]
    fn forced_avx2_never_degrades_silently() {
        // An explicit avx2 request is honored exactly or refused
        // loudly — the "--simd avx2" promise holds even for callers
        // that skip TrainConfig::validate (the deprecated shims).
        let got = std::panic::catch_unwind(|| resolve(SimdKind::Avx2));
        if avx2_supported() {
            assert_eq!(got.unwrap(), SimdLevel::Avx2);
        } else {
            assert!(got.is_err(), "forced avx2 must not fall back to portable");
        }
    }

    #[test]
    fn level_names_are_stable() {
        // Recorded in benches/JSON artifacts — renaming breaks the
        // cross-PR trajectory.
        assert_eq!(SimdLevel::Portable.name(), "portable");
        assert_eq!(SimdLevel::Avx2.name(), "avx2");
    }

    #[cfg(not(target_arch = "x86_64"))]
    #[test]
    fn non_x86_never_reports_avx2() {
        assert!(!avx2_supported());
        assert_eq!(resolve(SimdKind::Auto), SimdLevel::Portable);
    }
}
