//! Explicit-SIMD kernel backends (§Perf, DESIGN.md §SIMD-backend).
//!
//! Four pieces:
//!
//! * [`aligned::AVec`] — the 64-byte-aligned storage the packed-block
//!   lane regions and per-stripe tables live in.
//! * [`backend::SimdBackend`] — the lane-granular kernel operations
//!   (chunk gather, gradient FMA, AdaGrad η batch, clamp, affine-α
//!   coefficients, paired-chunk fusion) behind one monomorphization
//!   parameter, with the [`backend::Portable`] autovec baseline and
//!   the x86_64 [`backend::Avx2`] gather/FMA and
//!   [`backend::Avx512`] paired 16-wide implementations.
//! * [`resolve`] — the one place runtime CPU-feature detection runs.
//!   Engines never detect features (ci.sh greps them); the resolved
//!   [`SimdLevel`] is recorded in `coordinator::plan::SweepPlan`, which
//!   monomorphizes the sweeps per backend so there is zero per-chunk
//!   dispatch.
//! * [`autotune`] — the measured `auto` policy: instead of trusting
//!   CPU feature flags, `resolve(Auto)` times every host-supported
//!   backend for a few milliseconds and keeps the observed winner
//!   (memoized process-wide so every fingerprint site agrees).
//!   Forced levels never measure: they validate and obey.

// `unsafe fn` bodies in this subtree are NOT implicit unsafe contexts:
// every unsafe operation needs its own explicit block with a
// `// SAFETY:` argument (scripts/ci.sh gates the comments).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod aligned;
pub mod autotune;
pub mod backend;

pub use aligned::{is_aligned, AVec, ALIGN};
#[cfg(target_arch = "x86_64")]
pub use backend::{Avx2, Avx512};
pub use backend::{Portable, SimdBackend};

use crate::config::SimdKind;

/// The backend a run executes with, resolved once at setup time and
/// recorded in the sweep plan. (The *request* —
/// auto/portable/avx2/avx512 — is [`crate::config::SimdKind`]; this is
/// the answer.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Autovectorized per-lane loops; bit-identical to the PR 3
    /// kernels on every architecture.
    Portable,
    /// AVX2 gathers + FMA pipeline (x86_64 with avx2+fma detected, or
    /// forced via `--simd avx2` on such a host).
    Avx2,
    /// AVX-512 paired-chunk pipeline — 16-wide gather/FMA/scatter over
    /// the unchanged 8-lane layout, 8-wide epilogue (x86_64 with
    /// avx512f+avx2+fma detected, or forced via `--simd avx512` on
    /// such a host).
    Avx512,
}

impl SimdLevel {
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Portable => backend::Portable::NAME,
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
        }
    }

    /// The forced [`SimdKind`] that resolves to exactly this level (on
    /// a host that supports it). Used to pin a measured `auto` winner
    /// into the config shipped to worker processes, so every process
    /// of one run computes the same fingerprint without re-measuring.
    pub fn as_kind(&self) -> SimdKind {
        match self {
            SimdLevel::Portable => SimdKind::Portable,
            SimdLevel::Avx2 => SimdKind::Avx2,
            SimdLevel::Avx512 => SimdKind::Avx512,
        }
    }
}

/// Whether the running CPU supports the AVX2 backend (AVX2 *and* FMA —
/// the kernel pipeline uses both instruction sets).
pub fn avx2_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Whether the running CPU supports the AVX-512 backend. The paired
/// pipeline needs avx512f (512-bit gather/scatter/FMA) *and* the
/// avx2+fma epilogue — detected as a unit, so `Avx512` implies the
/// 256-bit ops it delegates short remainders to are sound.
pub fn avx512_supported() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx512f") && avx2_supported()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Every backend the running CPU can execute, narrowest first
/// (portable is always first; the widest supported level is last).
/// This is the candidate set the [`autotune`] measures and the order
/// its deterministic tie-break prefers wider entries over.
pub fn supported_levels() -> Vec<SimdLevel> {
    let mut levels = vec![SimdLevel::Portable];
    if avx2_supported() {
        levels.push(SimdLevel::Avx2);
    }
    if avx512_supported() {
        levels.push(SimdLevel::Avx512);
    }
    levels
}

/// The refusal message for a forced backend the CPU lacks —
/// enumerating every configurable kind, so the message stays correct
/// as backends are added. Shared verbatim by [`resolve`]'s panic and
/// `TrainConfig::validate`'s error: validating callers (the `Trainer`
/// facade, the CLI) report it as a clean error, and callers that skip
/// validation still can never get a silent fallback out of an explicit
/// request.
pub fn forced_unsupported_msg(kind: SimdKind) -> String {
    let needs = match kind {
        SimdKind::Avx2 => "avx2+fma",
        SimdKind::Avx512 => "avx512f+avx2+fma",
        // Portable/Auto are supported everywhere; no caller builds
        // this message for them.
        SimdKind::Portable | SimdKind::Auto => "(always supported)",
    };
    let supported: Vec<&str> = supported_levels().iter().map(|l| l.name()).collect();
    format!(
        "cluster.simd = \"{}\" but this CPU does not support {needs}; \
         supported on this host: {} — use one of those, or \"auto\" \
         (measures every supported backend and picks the fastest)",
        kind.name(),
        supported.join("|"),
    )
}

/// Resolve the configured backend request against the running CPU.
///
/// Explicit requests are honored exactly: a forced level on an
/// unsupported host **panics** with [`forced_unsupported_msg`] (the
/// same string `TrainConfig::validate` reports), never silently
/// degrades. `Auto` is resolved by **measurement**, not feature
/// flags: the first `Auto` resolution in the process runs the
/// [`autotune`] micro-benchmark over every supported backend and the
/// winner is memoized (see [`autotune::auto_report`]) so every later
/// `Auto` site — plan build, cache fingerprint, serve, API predict —
/// agrees within the process.
pub fn resolve(kind: SimdKind) -> SimdLevel {
    match kind {
        SimdKind::Portable => SimdLevel::Portable,
        SimdKind::Auto => autotune::auto_report().chosen,
        SimdKind::Avx2 => {
            assert!(avx2_supported(), "{}", forced_unsupported_msg(kind));
            SimdLevel::Avx2
        }
        SimdKind::Avx512 => {
            assert!(avx512_supported(), "{}", forced_unsupported_msg(kind));
            SimdLevel::Avx512
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn portable_always_honored() {
        assert_eq!(resolve(SimdKind::Portable), SimdLevel::Portable);
    }

    #[test]
    fn auto_is_measured_and_supported() {
        // `Auto` no longer maps to a feature flag: it is whatever the
        // micro-autotune measured fastest — necessarily one of the
        // host-supported backends — and it is memoized, so every
        // resolution in one process agrees (the fingerprint-consistency
        // contract).
        let got = resolve(SimdKind::Auto);
        assert!(supported_levels().contains(&got), "winner {got:?} must be supported");
        assert_eq!(resolve(SimdKind::Auto), got, "auto resolution must be stable in-process");
    }

    #[test]
    fn forced_avx2_never_degrades_silently() {
        // An explicit avx2 request is honored exactly or refused
        // loudly — the "--simd avx2" promise holds even for callers
        // that skip TrainConfig::validate (the deprecated shims).
        let got = std::panic::catch_unwind(|| resolve(SimdKind::Avx2));
        if avx2_supported() {
            assert_eq!(got.unwrap(), SimdLevel::Avx2);
        } else {
            assert!(got.is_err(), "forced avx2 must not fall back to portable");
        }
    }

    #[test]
    fn forced_avx512_never_degrades_silently() {
        let got = std::panic::catch_unwind(|| resolve(SimdKind::Avx512));
        if avx512_supported() {
            assert_eq!(got.unwrap(), SimdLevel::Avx512);
        } else {
            assert!(got.is_err(), "forced avx512 must not fall back");
        }
    }

    #[test]
    fn refusal_messages_enumerate_all_kinds() {
        // The forced-level refusal must name the requested kind, its
        // missing feature set, and the full host-supported menu — no
        // more hardcoding the portable/avx2 pair.
        let msg = forced_unsupported_msg(SimdKind::Avx512);
        assert!(msg.contains("avx512") && msg.contains("avx512f+avx2+fma"), "{msg}");
        assert!(msg.contains("portable"), "{msg}");
        assert!(msg.contains("auto"), "{msg}");
        let msg2 = forced_unsupported_msg(SimdKind::Avx2);
        assert!(msg2.contains("\"avx2\"") && msg2.contains("avx2+fma"), "{msg2}");
    }

    #[test]
    fn level_names_are_stable() {
        // Recorded in benches/JSON artifacts — renaming breaks the
        // cross-PR trajectory.
        assert_eq!(SimdLevel::Portable.name(), "portable");
        assert_eq!(SimdLevel::Avx2.name(), "avx2");
        assert_eq!(SimdLevel::Avx512.name(), "avx512");
    }

    #[test]
    fn levels_round_trip_through_forced_kinds() {
        // as_kind is how a measured winner is pinned into a worker's
        // config; the round trip through parse must be the identity.
        for level in [SimdLevel::Portable, SimdLevel::Avx2, SimdLevel::Avx512] {
            let kind = level.as_kind();
            assert_eq!(SimdKind::parse(kind.name()).unwrap(), kind, "{level:?}");
            assert_eq!(kind.name(), level.name(), "{level:?}");
        }
    }

    #[test]
    fn supported_levels_is_ordered_and_consistent() {
        let levels = supported_levels();
        assert_eq!(levels[0], SimdLevel::Portable, "portable is always supported and first");
        // avx512 support implies avx2 support by construction (the
        // epilogue delegates to the 256-bit pipeline).
        if avx512_supported() {
            assert!(avx2_supported());
            assert_eq!(levels, vec![SimdLevel::Portable, SimdLevel::Avx2, SimdLevel::Avx512]);
        }
        assert!(levels.contains(&resolve(SimdKind::Auto)));
    }

    #[cfg(not(target_arch = "x86_64"))]
    #[test]
    fn non_x86_never_reports_avx2() {
        assert!(!avx2_supported());
        assert!(!avx512_supported());
        assert_eq!(resolve(SimdKind::Auto), SimdLevel::Portable);
        assert_eq!(supported_levels(), vec![SimdLevel::Portable]);
    }
}
