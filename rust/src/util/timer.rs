//! Wall-clock timing helpers and a phase profiler used by the
//! coordinator (compute vs communication accounting, Theorem 1's
//! `T_u` / `T_c` split) and by the bench harness.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates durations per named phase — e.g. "update", "sync",
/// "monitor" — so experiments can report the compute/communication
/// breakdown that Theorem 1's cost model predicts.
#[derive(Clone, Debug, Default)]
pub struct PhaseProfiler {
    acc: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl PhaseProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.acc.entry(phase).or_default() += d;
        *self.counts.entry(phase).or_default() += 1;
    }

    /// Time `f` and account it to `phase`.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn secs(&self, phase: &str) -> f64 {
        self.acc.get(phase).map(|d| d.as_secs_f64()).unwrap_or(0.0)
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.counts.get(phase).copied().unwrap_or(0)
    }

    pub fn merge(&mut self, other: &PhaseProfiler) {
        for (k, v) in &other.acc {
            *self.acc.entry(k).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k).or_default() += *v;
        }
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.acc.iter().map(|(k, v)| (*k, v.as_secs_f64()))
    }

    pub fn report(&self) -> String {
        let total: f64 = self.acc.values().map(|d| d.as_secs_f64()).sum();
        let mut out = String::new();
        for (k, v) in &self.acc {
            let s = v.as_secs_f64();
            let pct = if total > 0.0 { 100.0 * s / total } else { 0.0 };
            out.push_str(&format!(
                "{k:>12}: {s:>9.4}s ({pct:>5.1}%)  n={}\n",
                self.counts.get(k).copied().unwrap_or(0)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::new();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn restart_resets() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        let e = sw.restart();
        assert!(e.as_millis() >= 1);
        assert!(sw.elapsed() < e + Duration::from_millis(100));
    }

    #[test]
    fn profiler_accumulates() {
        let mut p = PhaseProfiler::new();
        p.add("update", Duration::from_millis(10));
        p.add("update", Duration::from_millis(5));
        p.add("sync", Duration::from_millis(1));
        assert!((p.secs("update") - 0.015).abs() < 1e-9);
        assert_eq!(p.count("update"), 2);
        assert_eq!(p.count("sync"), 1);
        assert_eq!(p.secs("missing"), 0.0);
    }

    #[test]
    fn profiler_time_returns_value() {
        let mut p = PhaseProfiler::new();
        let v = p.time("work", || 42);
        assert_eq!(v, 42);
        assert_eq!(p.count("work"), 1);
    }

    #[test]
    fn profiler_merge() {
        let mut a = PhaseProfiler::new();
        a.add("x", Duration::from_millis(3));
        let mut b = PhaseProfiler::new();
        b.add("x", Duration::from_millis(7));
        b.add("y", Duration::from_millis(2));
        a.merge(&b);
        assert!((a.secs("x") - 0.010).abs() < 1e-9);
        assert_eq!(a.count("y"), 1);
    }

    #[test]
    fn report_contains_phases() {
        let mut p = PhaseProfiler::new();
        p.add("update", Duration::from_millis(1));
        let r = p.report();
        assert!(r.contains("update"));
        assert!(r.contains("n=1"));
    }
}
