//! Micro-benchmark harness.
//!
//! criterion is not in the offline crate set, so DSO ships a compact
//! harness with the same core discipline: warmup, fixed-time batched
//! measurement, and robust summary statistics. Benches under
//! `rust/benches/` use `harness = false` and drive this module from
//! their own `main`, so `cargo bench` works end to end.

use super::stats::quantile;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum number of measured samples regardless of time budget.
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_samples: 10,
            max_samples: 200,
        }
    }
}

impl BenchConfig {
    /// Fast settings for CI / `cargo test` smoke usage.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            min_samples: 5,
            max_samples: 50,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
    pub iters_per_sample: u64,
    /// Work units (e.g. coordinate updates) performed per iteration;
    /// lets reports derive units/sec. 1 when not specified.
    pub units_per_iter: u64,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        super::stats::mean(&self.samples)
    }

    pub fn median(&self) -> f64 {
        super::stats::median(&self.samples)
    }

    pub fn stddev(&self) -> f64 {
        let mut s = super::stats::Streaming::new();
        for &x in &self.samples {
            s.push(x);
        }
        s.stddev()
    }

    pub fn p05(&self) -> f64 {
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        quantile(&v, 0.05)
    }

    pub fn p95(&self) -> f64 {
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        quantile(&v, 0.95)
    }

    /// Iterations (calls of the benched closure) per second.
    pub fn throughput(&self) -> f64 {
        1.0 / self.median().max(1e-18)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  median={} mean={} p95={} (n={} x{})",
            self.name,
            human_time(self.median()),
            human_time(self.median()),
            human_time(self.mean()),
            human_time(self.p95()),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

pub fn human_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Run one benchmark: auto-calibrated batch size, warmup, then timed
/// samples until the time budget or sample cap is reached.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    // Calibrate: how many iterations fit in ~1ms?
    let t0 = Instant::now();
    black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters_per_sample = ((1e-3 / once).ceil() as u64).clamp(1, 1_000_000);

    // Warmup.
    let warm_end = Instant::now() + cfg.warmup;
    while Instant::now() < warm_end {
        for _ in 0..iters_per_sample {
            black_box(f());
        }
    }

    // Measure.
    let mut samples = Vec::new();
    let measure_end = Instant::now() + cfg.measure;
    while (Instant::now() < measure_end || samples.len() < cfg.min_samples)
        && samples.len() < cfg.max_samples
    {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            black_box(f());
        }
        samples.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
    }

    BenchResult { name: name.to_string(), samples, iters_per_sample, units_per_iter: 1 }
}

/// Bench group runner: prints criterion-style lines and collects results
/// so bench binaries can also dump CSVs.
pub struct Runner {
    pub cfg: BenchConfig,
    pub results: Vec<BenchResult>,
    filter: Option<String>,
}

impl Runner {
    /// Honors the `--bench <filter>` / positional filter that `cargo
    /// bench -- <filter>` passes on argv, plus `DSO_BENCH_QUICK=1`.
    pub fn from_env(group: &str) -> Self {
        let cfg = if std::env::var("DSO_BENCH_QUICK").is_ok() {
            BenchConfig::quick()
        } else {
            BenchConfig::default()
        };
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with("--") && !a.is_empty());
        println!("== bench group: {group} ==");
        Self { cfg, results: Vec::new(), filter }
    }

    pub fn bench<T>(&mut self, name: &str, f: impl FnMut() -> T) {
        self.bench_units(name, 1, f);
    }

    /// Like [`Runner::bench`], declaring that each iteration performs
    /// `units` units of work (e.g. one coordinate update per nonzero),
    /// so reports can derive units/sec.
    pub fn bench_units<T>(&mut self, name: &str, units: u64, f: impl FnMut() -> T) {
        if let Some(ref flt) = self.filter {
            if !name.contains(flt.as_str()) {
                return;
            }
        }
        let mut r = bench(name, &self.cfg, f);
        r.units_per_iter = units.max(1);
        println!("{}", r.report());
        self.results.push(r);
    }

    /// Write a summary CSV under results/bench/, plus — when the
    /// `DSO_BENCH_JSON` env var is set to anything but "0" — a
    /// machine-readable `BENCH_<group>.json` in the working directory
    /// so the perf trajectory can be tracked across PRs.
    pub fn finish(&self, group: &str) {
        let mut t = super::csv::Table::new(&["median_s", "mean_s", "p95_s", "samples"]);
        for r in &self.results {
            t.push(vec![r.median(), r.mean(), r.p95(), r.samples.len() as f64]);
        }
        let dir = std::path::Path::new("results/bench");
        let _ = std::fs::create_dir_all(dir);
        // Names live in a side file because Table is numeric-only.
        let names: Vec<String> = self.results.iter().map(|r| r.name.clone()).collect();
        let _ = std::fs::write(dir.join(format!("{group}.names.txt")), names.join("\n"));
        let _ = t.write_csv(&dir.join(format!("{group}.csv")));
        if matches!(std::env::var("DSO_BENCH_JSON"), Ok(v) if v != "0") {
            let _ = std::fs::write(format!("BENCH_{group}.json"), self.emit_json(group));
        }
    }

    /// Machine-readable results: name, median s/iter, units/sec.
    pub fn emit_json(&self, group: &str) -> String {
        use super::json::{obj, Json};
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("median_s_per_iter", Json::Num(r.median())),
                    ("mean_s_per_iter", Json::Num(r.mean())),
                    ("p95_s_per_iter", Json::Num(r.p95())),
                    ("samples", Json::Num(r.samples.len() as f64)),
                    ("units_per_iter", Json::Num(r.units_per_iter as f64)),
                    (
                        "units_per_sec",
                        Json::Num(r.units_per_iter as f64 / r.median().max(1e-18)),
                    ),
                ])
            })
            .collect();
        obj(vec![("group", Json::Str(group.to_string())), ("results", Json::Arr(results))])
            .emit()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let cfg = BenchConfig::quick();
        let r = bench("noop", &cfg, || 1 + 1);
        assert!(r.samples.len() >= cfg.min_samples);
        assert!(r.median() >= 0.0);
        assert!(r.mean() >= 0.0);
    }

    #[test]
    fn bench_orders_fast_vs_slow() {
        let cfg = BenchConfig::quick();
        let fast = bench("fast", &cfg, || black_box(0u64));
        let slow = bench("slow", &cfg, || {
            let mut s = 0u64;
            for i in 0..2000 {
                s = s.wrapping_add(black_box(i));
            }
            s
        });
        assert!(slow.median() > fast.median());
    }

    #[test]
    fn emit_json_is_parseable_and_carries_units() {
        use crate::util::json::Json;
        let mut runner = Runner {
            cfg: BenchConfig::quick(),
            results: Vec::new(),
            filter: None,
        };
        runner.bench_units("sweep_smoke", 1000, || std::hint::black_box(7u64));
        let text = runner.emit_json("updates");
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("group").unwrap().as_str(), Some("updates"));
        let rs = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("sweep_smoke"));
        assert_eq!(rs[0].get("units_per_iter").unwrap().as_i64(), Some(1000));
        assert!(rs[0].get("units_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(rs[0].get("median_s_per_iter").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.0).ends_with('s'));
        assert!(human_time(2e-3).ends_with("ms"));
        assert!(human_time(2e-6).ends_with("us"));
        assert!(human_time(2e-9).ends_with("ns"));
    }

    #[test]
    fn result_percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0, 5.0],
            iters_per_sample: 1,
            units_per_iter: 1,
        };
        assert!(r.p05() <= r.median());
        assert!(r.median() <= r.p95());
        assert!((r.throughput() - 1.0 / 3.0).abs() < 1e-12);
    }
}
