//! Streaming statistics and small numeric helpers used by the bench
//! harness, the convergence monitor and the experiment drivers.

/// Welford streaming mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Streaming {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Streaming {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Streaming) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact quantile of a sample (interpolated, type-7 like numpy default).
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of an unsorted slice (copies).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile(&v, 0.5)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Ordinary least squares slope of y on x — used to measure convergence
/// rates (e.g. log gap vs log T should have slope ≈ −1/2 per Theorem 1).
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..x.len() {
        num += (x[i] - mx) * (y[i] - my);
        den += (x[i] - mx) * (x[i] - mx);
    }
    num / den
}

/// Relative difference |a-b| / max(|a|, |b|, eps).
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let scale = a.abs().max(b.abs()).max(1e-12);
    (a - b).abs() / scale
}

/// Dot product (f64 accumulation over f32 slices).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for i in 0..a.len() {
        s += a[i] as f64 * b[i] as f64;
    }
    s
}

/// L2 norm squared.
#[inline]
pub fn norm2_f32(a: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for &x in a {
        s += x as f64 * x as f64;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0, -5.0];
        let mut s = Streaming::new();
        for &x in &xs {
            s.push(x);
        }
        let m = mean(&xs);
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((s.mean() - m).abs() < 1e-12);
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), -5.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.count(), 6);
    }

    #[test]
    fn streaming_merge_equals_combined() {
        let xs: Vec<f64> = (0..50).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Streaming::new();
        let mut b = Streaming::new();
        let mut all = Streaming::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn merge_with_empty() {
        let mut a = Streaming::new();
        a.push(1.0);
        a.push(2.0);
        let b = Streaming::new();
        let before = a.clone();
        a.merge(&b);
        assert_eq!(a.mean(), before.mean());
        let mut e = Streaming::new();
        e.merge(&before);
        assert_eq!(e.mean(), before.mean());
    }

    #[test]
    fn quantile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 4.0);
        assert!((quantile(&v, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((median(&[4.0, 1.0, 2.0, 3.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ols_slope_recovers_line() {
        let x: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.5 * v - 7.0).collect();
        assert!((ols_slope(&x, &y) - 3.5).abs() < 1e-9);
    }

    #[test]
    fn rel_diff_symmetric() {
        assert!((rel_diff(1.0, 1.1) - rel_diff(1.1, 1.0)).abs() < 1e-15);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
        assert!(rel_diff(1.0, 2.0) > 0.49);
    }

    #[test]
    fn dot_and_norm() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, -5.0, 6.0];
        assert!((dot_f32(&a, &b) - 12.0).abs() < 1e-9);
        assert!((norm2_f32(&a) - 14.0).abs() < 1e-9);
    }
}
