//! CSV series writer used by the experiment drivers to emit the data
//! behind every reproduced figure. Kept deliberately simple: numeric
//! columns, a header, and an atomic write-to-temp-then-rename.

use std::io::Write;
use std::path::{Path, PathBuf};

/// A table of named numeric columns collected row by row.
#[derive(Clone, Debug)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Self {
        Self { columns: columns.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Vec<f64>) {
        assert_eq!(
            row.len(),
            self.columns.len(),
            "row width {} != header width {}",
            row.len(),
            self.columns.len()
        );
        self.rows.push(row);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Column by name.
    pub fn col(&self, name: &str) -> Option<Vec<f64>> {
        let idx = self.columns.iter().position(|c| c == name)?;
        Some(self.rows.iter().map(|r| r[idx]).collect())
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.columns.join(",");
        s.push('\n');
        for row in &self.rows {
            let line: Vec<String> = row.iter().map(|v| format_num(*v)).collect();
            s.push_str(&line.join(","));
            s.push('\n');
        }
        s
    }

    /// Atomic write (temp + rename) so partially-written result files are
    /// never observed by plotting scripts.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let tmp: PathBuf = path.with_extension("csv.tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(self.to_csv().as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)
    }

    pub fn read_csv(path: &Path) -> std::io::Result<Table> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse_csv(&text))
    }

    pub fn parse_csv(text: &str) -> Table {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("");
        let columns: Vec<String> = header.split(',').map(|s| s.trim().to_string()).collect();
        let mut rows = Vec::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let row: Vec<f64> =
                line.split(',').map(|v| v.trim().parse::<f64>().unwrap_or(f64::NAN)).collect();
            rows.push(row);
        }
        Table { columns, rows }
    }

    /// Render as an aligned ASCII table (for terminal output of the
    /// experiment drivers, mirroring the paper's reported rows).
    pub fn render(&self, max_rows: usize) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        let shown = self.rows.iter().take(max_rows);
        let formatted: Vec<Vec<String>> =
            shown.map(|r| r.iter().map(|v| format_num(*v)).collect()).collect();
        for row in &formatted {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        for (i, c) in self.columns.iter().enumerate() {
            out.push_str(&format!("{:>w$}  ", c, w = widths[i]));
        }
        out.push('\n');
        for row in &formatted {
            for (i, cell) in row.iter().enumerate() {
                out.push_str(&format!("{:>w$}  ", cell, w = widths[i]));
            }
            out.push('\n');
        }
        if self.rows.len() > max_rows {
            out.push_str(&format!("... ({} more rows)\n", self.rows.len() - max_rows));
        }
        out
    }
}

fn format_num(v: f64) -> String {
    if v.is_nan() {
        return "nan".to_string();
    }
    if v == v.trunc() && v.abs() < 1e12 {
        format!("{}", v as i64)
    } else if v.abs() >= 1e-3 && v.abs() < 1e7 {
        let s = format!("{v:.6}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    } else {
        format!("{v:.6e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_string() {
        let mut t = Table::new(&["epoch", "obj", "gap"]);
        t.push(vec![1.0, 0.5, 0.25]);
        t.push(vec![2.0, 0.45, 0.125]);
        let t2 = Table::parse_csv(&t.to_csv());
        assert_eq!(t2.columns, t.columns);
        assert_eq!(t2.rows.len(), 2);
        assert!((t2.rows[1][1] - 0.45).abs() < 1e-12);
    }

    #[test]
    fn roundtrip_through_file() {
        let dir = std::env::temp_dir().join("dso_csv_test");
        let path = dir.join("t.csv");
        let mut t = Table::new(&["a", "b"]);
        t.push(vec![1e-9, 123456789.0]);
        t.write_csv(&path).unwrap();
        let t2 = Table::read_csv(&path).unwrap();
        assert!((t2.rows[0][0] - 1e-9).abs() < 1e-21);
        assert_eq!(t2.rows[0][1], 123456789.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn col_access() {
        let mut t = Table::new(&["x", "y"]);
        t.push(vec![1.0, 10.0]);
        t.push(vec![2.0, 20.0]);
        assert_eq!(t.col("y").unwrap(), vec![10.0, 20.0]);
        assert!(t.col("z").is_none());
    }

    #[test]
    #[should_panic]
    fn mismatched_row_width_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.push(vec![1.0]);
    }

    #[test]
    fn render_produces_header_and_rows() {
        let mut t = Table::new(&["epoch", "objective"]);
        t.push(vec![1.0, 0.693147]);
        let r = t.render(10);
        assert!(r.contains("epoch"));
        assert!(r.contains("0.693147"));
    }

    #[test]
    fn render_truncates() {
        let mut t = Table::new(&["i"]);
        for i in 0..20 {
            t.push(vec![i as f64]);
        }
        let r = t.render(5);
        assert!(r.contains("more rows"));
    }

    #[test]
    fn format_num_styles() {
        assert_eq!(format_num(3.0), "3");
        assert_eq!(format_num(0.5), "0.5");
        assert_eq!(format_num(f64::NAN), "nan");
        assert!(format_num(1.23e-8).contains('e'));
    }
}
